//! Hot-path buffer pooling (paper §3 "Technical highlights").
//!
//! Espresso replaces per-forward `malloc`/`free` with a custom allocator
//! that pre-allocates at start-up; dynamic allocation on the hot path is
//! one of the overheads it removes. This module is the CPU analogue: a
//! size-classed pool of typed buffers. Layers acquire scratch
//! (unroll matrices, GEMM accumulators, packed activations) from the
//! pool; buffers return automatically on drop, so steady-state forward
//! passes perform no heap allocation.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Statistics for observing pool behaviour (tested + reported by the CLI).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Buffers handed out that were recycled from a freelist.
    pub hits: u64,
    /// Buffers that had to be freshly allocated.
    pub misses: u64,
    /// Buffers currently parked in freelists.
    pub free_buffers: usize,
    /// Total elements parked in freelists.
    pub free_elems: usize,
}

struct Inner<T> {
    free: HashMap<usize, Vec<Vec<T>>>,
    hits: u64,
    misses: u64,
}

/// A size-classed pool of `Vec<T>` buffers. Clone is cheap (Arc).
pub struct BufferPool<T> {
    inner: Arc<Mutex<Inner<T>>>,
}

impl<T> Clone for BufferPool<T> {
    fn clone(&self) -> Self {
        Self {
            inner: Arc::clone(&self.inner),
        }
    }
}

impl<T: Default + Clone> Default for BufferPool<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Round a requested length up to its size class (next power of two, so
/// reuse tolerates small shape differences without unbounded classes).
fn size_class(len: usize) -> usize {
    len.next_power_of_two().max(64)
}

impl<T: Default + Clone> BufferPool<T> {
    pub fn new() -> Self {
        Self {
            inner: Arc::new(Mutex::new(Inner {
                free: HashMap::new(),
                hits: 0,
                misses: 0,
            })),
        }
    }

    /// Acquire a zero-initialized buffer of exactly `len` elements
    /// (capacity = size class). Returned buffer re-enters the pool on drop.
    pub fn acquire(&self, len: usize) -> PoolBuf<T> {
        let class = size_class(len);
        let mut inner = self.inner.lock().unwrap();
        let mut buf = match inner.free.get_mut(&class).and_then(|v| v.pop()) {
            Some(b) => {
                inner.hits += 1;
                b
            }
            None => {
                inner.misses += 1;
                Vec::with_capacity(class)
            }
        };
        drop(inner);
        buf.clear();
        buf.resize(len, T::default());
        PoolBuf {
            buf,
            class,
            pool: Arc::clone(&self.inner),
        }
    }

    /// Pre-allocate `count` buffers of length `len` (start-up warm-up, as
    /// the paper's allocator does at network-load time).
    pub fn preallocate(&self, len: usize, count: usize) {
        let class = size_class(len);
        let mut inner = self.inner.lock().unwrap();
        let list = inner.free.entry(class).or_default();
        for _ in 0..count {
            list.push(Vec::with_capacity(class));
        }
    }

    pub fn stats(&self) -> PoolStats {
        let inner = self.inner.lock().unwrap();
        PoolStats {
            hits: inner.hits,
            misses: inner.misses,
            free_buffers: inner.free.values().map(|v| v.len()).sum(),
            free_elems: inner
                .free
                .values()
                .flat_map(|v| v.iter().map(|b| b.capacity()))
                .sum(),
        }
    }
}

/// RAII buffer handle; derefs to a slice / Vec and returns its storage to
/// the pool when dropped.
pub struct PoolBuf<T> {
    buf: Vec<T>,
    class: usize,
    pool: Arc<Mutex<Inner<T>>>,
}

impl<T> PoolBuf<T> {
    /// Take the buffer out of pool management (it will not be recycled).
    pub fn into_vec(mut self) -> Vec<T> {
        std::mem::take(&mut self.buf)
    }
}

impl<T> std::ops::Deref for PoolBuf<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        &self.buf
    }
}

impl<T> std::ops::DerefMut for PoolBuf<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        &mut self.buf
    }
}

impl<T> Drop for PoolBuf<T> {
    fn drop(&mut self) {
        if self.buf.capacity() == 0 {
            return; // taken by into_vec
        }
        let buf = std::mem::take(&mut self.buf);
        if let Ok(mut inner) = self.pool.lock() {
            inner.free.entry(self.class).or_default().push(buf);
        }
    }
}

/// The set of pools a forward pass needs, bundled for convenience.
#[derive(Clone, Default)]
pub struct Workspace {
    pub f32s: BufferPool<f32>,
    pub i32s: BufferPool<i32>,
    pub words64: BufferPool<u64>,
    pub words32: BufferPool<u32>,
    pub bytes: BufferPool<u8>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Selects the word pool matching a packing width (lets layers generic
/// over `Word` draw scratch from the right pool).
pub trait WordPool: Sized {
    fn pool(ws: &Workspace) -> &BufferPool<Self>;
}

impl WordPool for u64 {
    fn pool(ws: &Workspace) -> &BufferPool<u64> {
        &ws.words64
    }
}

impl WordPool for u32 {
    fn pool(ws: &Workspace) -> &BufferPool<u32> {
        &ws.words32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_returns_zeroed_exact_len() {
        let pool: BufferPool<f32> = BufferPool::new();
        let mut b = pool.acquire(100);
        assert_eq!(b.len(), 100);
        assert!(b.iter().all(|&x| x == 0.0));
        b[0] = 5.0;
        drop(b);
        // recycled buffer must be re-zeroed
        let b2 = pool.acquire(100);
        assert_eq!(b2[0], 0.0);
    }

    #[test]
    fn buffers_are_recycled() {
        let pool: BufferPool<i32> = BufferPool::new();
        {
            let _a = pool.acquire(1000);
        }
        {
            let _b = pool.acquire(900); // same class (1024)
        }
        let s = pool.stats();
        assert_eq!(s.misses, 1, "{s:?}");
        assert_eq!(s.hits, 1, "{s:?}");
    }

    #[test]
    fn preallocate_avoids_misses() {
        let pool: BufferPool<u64> = BufferPool::new();
        pool.preallocate(512, 4);
        let a = pool.acquire(512);
        let b = pool.acquire(512);
        let s = pool.stats();
        assert_eq!(s.misses, 0, "{s:?}");
        assert_eq!(s.hits, 2, "{s:?}");
        drop((a, b));
        assert_eq!(pool.stats().free_buffers, 4);
    }

    #[test]
    fn steady_state_forward_allocates_nothing() {
        // simulate repeated forward passes: same shapes every time
        let pool: BufferPool<f32> = BufferPool::new();
        for _ in 0..10 {
            let x = pool.acquire(4096);
            let y = pool.acquire(1024);
            drop((x, y));
        }
        let s = pool.stats();
        assert_eq!(s.misses, 2, "only the first pass allocates: {s:?}");
        assert_eq!(s.hits, 18);
    }

    #[test]
    fn into_vec_detaches() {
        let pool: BufferPool<u8> = BufferPool::new();
        let v = pool.acquire(10).into_vec();
        assert_eq!(v.len(), 10);
        assert_eq!(pool.stats().free_buffers, 0);
    }

    #[test]
    fn pool_is_shareable_across_threads() {
        let pool: BufferPool<f32> = BufferPool::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let p = pool.clone();
                s.spawn(move || {
                    for _ in 0..50 {
                        let b = p.acquire(256);
                        assert_eq!(b.len(), 256);
                    }
                });
            }
        });
        let st = pool.stats();
        assert_eq!(st.hits + st.misses, 200);
    }
}
