//! Hot-path buffer pooling (paper §3 "Technical highlights").
//!
//! Espresso replaces per-forward `malloc`/`free` with a custom allocator
//! that pre-allocates at start-up; dynamic allocation on the hot path is
//! one of the overheads it removes. This module is the CPU analogue: a
//! size-classed pool of typed buffers. Layers acquire scratch
//! (unroll matrices, GEMM accumulators, packed activations) from the
//! pool; buffers return automatically on drop, so steady-state forward
//! passes perform no heap allocation.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Statistics for observing pool behaviour (tested + reported by the CLI).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Buffers handed out that were recycled from a freelist.
    pub hits: u64,
    /// Subset of `hits` served warm from the requesting worker's own
    /// affinity slot (same storage the worker released last time).
    pub affine_hits: u64,
    /// Buffers that had to be freshly allocated.
    pub misses: u64,
    /// Buffers dropped on release because their size class was at its
    /// high-water cap (bounds freelist growth under shape churn).
    pub evicted: u64,
    /// Buffers currently parked in freelists.
    pub free_buffers: usize,
    /// Total elements parked in freelists.
    pub free_elems: usize,
    /// High-water mark of `free_elems` over the pool's lifetime — how
    /// much scratch a long-running serve has pinned at its worst (the
    /// number `trim` releases back to the OS).
    pub peak_free_elems: usize,
}

/// Default per-size-class high-water mark: enough for any plan's
/// same-class concurrency with headroom, small enough that a burst of
/// odd shapes can't pin unbounded memory.
pub const DEFAULT_CLASS_CAP: usize = 32;

struct Inner<T> {
    free: HashMap<usize, Vec<Vec<T>>>,
    /// Per-worker warm slots, keyed `(scheduler slot, size class)`: the
    /// buffer a worker released last, handed back to the same worker so
    /// its L2-resident panel/accumulator stays warm across tiles, layers
    /// and requests. At most one buffer per key; overflow and foreign
    /// releases take the ordinary freelist path.
    affine: HashMap<(usize, usize), Vec<T>>,
    /// Buffers parked in `affine` per size class — kept in lockstep with
    /// `affine` so the release-path cap check is O(1) instead of a key
    /// scan under the pool mutex.
    affine_per_class: HashMap<usize, usize>,
    hits: u64,
    affine_hits: u64,
    misses: u64,
    evicted: u64,
    /// Max buffers parked per size class; releases beyond it drop.
    cap: usize,
    /// Elements currently parked, counted in size-class units (tracked
    /// incrementally so `stats` is O(1) and the high-water mark is exact;
    /// class units sidestep `Vec::with_capacity` over-allocation).
    free_elems: usize,
    /// Lifetime high-water mark of `free_elems`.
    peak_free_elems: usize,
}

impl<T> Inner<T> {
    fn note_parked(&mut self, elems: usize) {
        self.free_elems += elems;
        self.peak_free_elems = self.peak_free_elems.max(self.free_elems);
    }

    fn note_affine_removed(&mut self, class: usize) {
        if let Some(c) = self.affine_per_class.get_mut(&class) {
            *c = c.saturating_sub(1);
        }
    }
}

/// A size-classed pool of `Vec<T>` buffers. Clone is cheap (Arc).
pub struct BufferPool<T> {
    inner: Arc<Mutex<Inner<T>>>,
}

impl<T> Clone for BufferPool<T> {
    fn clone(&self) -> Self {
        Self {
            inner: Arc::clone(&self.inner),
        }
    }
}

impl<T: Default + Clone> Default for BufferPool<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Round a requested length up to its size class (next power of two, so
/// reuse tolerates small shape differences without unbounded classes).
fn size_class(len: usize) -> usize {
    len.next_power_of_two().max(64)
}

impl<T: Default + Clone> BufferPool<T> {
    pub fn new() -> Self {
        Self {
            inner: Arc::new(Mutex::new(Inner {
                free: HashMap::new(),
                affine: HashMap::new(),
                affine_per_class: HashMap::new(),
                hits: 0,
                affine_hits: 0,
                misses: 0,
                evicted: 0,
                cap: DEFAULT_CLASS_CAP,
                free_elems: 0,
                peak_free_elems: 0,
            })),
        }
    }

    /// Change the per-size-class high-water cap (release-time eviction
    /// threshold). A cap of 0 disables recycling entirely.
    pub fn set_cap(&self, cap: usize) {
        self.inner.lock().unwrap().cap = cap;
    }

    /// Acquire a zero-initialized buffer of exactly `len` elements
    /// (capacity = size class). Returned buffer re-enters the pool on drop.
    pub fn acquire(&self, len: usize) -> PoolBuf<T> {
        self.acquire_inner(len, None)
    }

    /// Worker-affine acquire: prefer the buffer scheduler slot `slot`
    /// released last (its cache-warm panel/accumulator), then the shared
    /// freelist, then another slot's warm buffer of the same class —
    /// a fresh allocation only when all three are empty, so plan-time
    /// [`BufferPool::reserve`] keeps its no-miss guarantee. The buffer
    /// returns to the slot's warm cache on drop (freelist if occupied).
    pub fn acquire_affine(&self, slot: usize, len: usize) -> PoolBuf<T> {
        self.acquire_inner(len, Some(slot))
    }

    fn acquire_inner(&self, len: usize, owner: Option<usize>) -> PoolBuf<T> {
        let class = size_class(len);
        let mut inner = self.inner.lock().unwrap();
        let mut recycled: Option<Vec<T>> = None;
        if let Some(slot) = owner {
            if let Some(b) = inner.affine.remove(&(slot, class)) {
                inner.affine_hits += 1;
                inner.note_affine_removed(class);
                recycled = Some(b);
            }
        }
        if recycled.is_none() {
            recycled = inner.free.get_mut(&class).and_then(|v| v.pop());
        }
        if recycled.is_none() && inner.affine_per_class.get(&class).copied().unwrap_or(0) > 0 {
            // affine-parked buffers are still pool property: ANY acquirer
            // (affine or plain) steals one of the right class before
            // allocating cold, so warm parking never turns a reserved
            // buffer into a miss for some other call site
            let key = inner.affine.keys().find(|k| k.1 == class).copied();
            if let Some(k) = key {
                recycled = inner.affine.remove(&k);
                inner.note_affine_removed(class);
            }
        }
        let mut buf = match recycled {
            Some(b) => {
                inner.hits += 1;
                inner.free_elems -= class;
                b
            }
            None => {
                inner.misses += 1;
                Vec::with_capacity(class)
            }
        };
        drop(inner);
        buf.clear();
        buf.resize(len, T::default());
        PoolBuf {
            buf,
            class,
            owner,
            pool: Arc::clone(&self.inner),
        }
    }

    /// Pre-allocate `count` buffers of length `len` (start-up warm-up, as
    /// the paper's allocator does at network-load time).
    pub fn preallocate(&self, len: usize, count: usize) {
        let class = size_class(len);
        let mut inner = self.inner.lock().unwrap();
        {
            let list = inner.free.entry(class).or_default();
            for _ in 0..count {
                list.push(Vec::with_capacity(class));
            }
        }
        inner.note_parked(class * count);
    }

    /// Plan-time reservation: ensure enough free buffers exist to satisfy
    /// `lens` *simultaneously* (one forward step's worth of acquires).
    /// Lengths sharing a size class are counted together; classes already
    /// holding enough buffers are left alone, so repeated reservations
    /// (per step, per plan rebuild) converge instead of accumulating.
    pub fn reserve(&self, lens: &[usize]) {
        if lens.is_empty() {
            return;
        }
        let mut need: HashMap<usize, usize> = HashMap::new();
        for &len in lens {
            *need.entry(size_class(len)).or_insert(0) += 1;
        }
        let mut inner = self.inner.lock().unwrap();
        for (class, count) in need {
            let added = {
                let list = inner.free.entry(class).or_default();
                let mut added = 0usize;
                while list.len() < count {
                    list.push(Vec::with_capacity(class));
                    added += 1;
                }
                added
            };
            inner.note_parked(class * added);
        }
    }

    /// Drop every parked buffer (e.g. after an unusually large batch, or
    /// on serve idle), warm per-worker slots included; returns the number
    /// of buffers freed.
    pub fn trim(&self) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let n = inner.free.values().map(|v| v.len()).sum::<usize>() + inner.affine.len();
        inner.free.clear();
        inner.affine.clear();
        inner.affine_per_class.clear();
        inner.free_elems = 0;
        n
    }

    pub fn stats(&self) -> PoolStats {
        let inner = self.inner.lock().unwrap();
        PoolStats {
            hits: inner.hits,
            affine_hits: inner.affine_hits,
            misses: inner.misses,
            evicted: inner.evicted,
            free_buffers: inner.free.values().map(|v| v.len()).sum::<usize>()
                + inner.affine.len(),
            free_elems: inner.free_elems,
            peak_free_elems: inner.peak_free_elems,
        }
    }
}

/// RAII buffer handle; derefs to a slice / Vec and returns its storage to
/// the pool when dropped.
pub struct PoolBuf<T> {
    buf: Vec<T>,
    class: usize,
    /// Scheduler slot whose warm cache this buffer returns to on drop
    /// (`acquire_affine`); `None` releases to the shared freelist.
    owner: Option<usize>,
    pool: Arc<Mutex<Inner<T>>>,
}

impl<T> PoolBuf<T> {
    /// Take the buffer out of pool management (it will not be recycled).
    pub fn into_vec(mut self) -> Vec<T> {
        std::mem::take(&mut self.buf)
    }
}

impl<T> std::ops::Deref for PoolBuf<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        &self.buf
    }
}

impl<T> std::ops::DerefMut for PoolBuf<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        &mut self.buf
    }
}

impl<T> Drop for PoolBuf<T> {
    fn drop(&mut self) {
        if self.buf.capacity() == 0 {
            return; // taken by into_vec
        }
        let buf = std::mem::take(&mut self.buf);
        let elems = self.class;
        if let Ok(mut inner) = self.pool.lock() {
            let cap = inner.cap;
            if let Some(slot) = self.owner {
                // park warm in the owner's slot so the same worker gets
                // the same storage back next acquire; the per-class cap
                // applies across affine slots too, so worker-slot churn
                // cannot pin more than `cap` extra copies of a class
                let parked_same_class =
                    inner.affine_per_class.get(&self.class).copied().unwrap_or(0);
                if parked_same_class < cap && !inner.affine.contains_key(&(slot, self.class)) {
                    inner.affine.insert((slot, self.class), buf);
                    *inner.affine_per_class.entry(self.class).or_insert(0) += 1;
                    inner.note_parked(elems);
                    return;
                }
            }
            let evict = {
                let list = inner.free.entry(self.class).or_default();
                if list.len() < cap {
                    list.push(buf);
                    false
                } else {
                    true
                }
            };
            if evict {
                inner.evicted += 1;
            } else {
                inner.note_parked(elems);
            }
        }
    }
}

/// The set of pools a forward pass needs, bundled for convenience.
#[derive(Clone, Default)]
pub struct Workspace {
    pub f32s: BufferPool<f32>,
    pub i32s: BufferPool<i32>,
    pub words64: BufferPool<u64>,
    pub words32: BufferPool<u32>,
    pub bytes: BufferPool<u8>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve the buffers named by a [`ScratchSpec`] (one plan step's
    /// simultaneous acquires). `W` selects which word pool the `words`
    /// lengths land in.
    pub fn reserve<W: crate::bitpack::Word>(&self, spec: &crate::layers::ScratchSpec) {
        self.f32s.reserve(&spec.f32s);
        self.i32s.reserve(&spec.i32s);
        self.bytes.reserve(&spec.bytes);
        W::pool(self).reserve(&spec.words);
    }

    /// Drop every parked buffer in every pool; returns buffers freed.
    pub fn trim_all(&self) -> usize {
        self.f32s.trim()
            + self.i32s.trim()
            + self.words64.trim()
            + self.words32.trim()
            + self.bytes.trim()
    }

    /// Aggregate stats across the typed pools (hot-path observability).
    pub fn stats_total(&self) -> PoolStats {
        let mut total = PoolStats::default();
        for s in [
            self.f32s.stats(),
            self.i32s.stats(),
            self.words64.stats(),
            self.words32.stats(),
            self.bytes.stats(),
        ] {
            total.hits += s.hits;
            total.affine_hits += s.affine_hits;
            total.misses += s.misses;
            total.evicted += s.evicted;
            total.free_buffers += s.free_buffers;
            total.free_elems += s.free_elems;
            // per-pool peaks need not coincide in time; the sum is the
            // conservative whole-workspace high-water bound
            total.peak_free_elems += s.peak_free_elems;
        }
        total
    }
}

/// Selects the word pool matching a packing width (lets layers generic
/// over `Word` draw scratch from the right pool).
pub trait WordPool: Sized {
    fn pool(ws: &Workspace) -> &BufferPool<Self>;
}

impl WordPool for u64 {
    fn pool(ws: &Workspace) -> &BufferPool<u64> {
        &ws.words64
    }
}

impl WordPool for u32 {
    fn pool(ws: &Workspace) -> &BufferPool<u32> {
        &ws.words32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_returns_zeroed_exact_len() {
        let pool: BufferPool<f32> = BufferPool::new();
        let mut b = pool.acquire(100);
        assert_eq!(b.len(), 100);
        assert!(b.iter().all(|&x| x == 0.0));
        b[0] = 5.0;
        drop(b);
        // recycled buffer must be re-zeroed
        let b2 = pool.acquire(100);
        assert_eq!(b2[0], 0.0);
    }

    #[test]
    fn buffers_are_recycled() {
        let pool: BufferPool<i32> = BufferPool::new();
        {
            let _a = pool.acquire(1000);
        }
        {
            let _b = pool.acquire(900); // same class (1024)
        }
        let s = pool.stats();
        assert_eq!(s.misses, 1, "{s:?}");
        assert_eq!(s.hits, 1, "{s:?}");
    }

    #[test]
    fn preallocate_avoids_misses() {
        let pool: BufferPool<u64> = BufferPool::new();
        pool.preallocate(512, 4);
        let a = pool.acquire(512);
        let b = pool.acquire(512);
        let s = pool.stats();
        assert_eq!(s.misses, 0, "{s:?}");
        assert_eq!(s.hits, 2, "{s:?}");
        drop((a, b));
        assert_eq!(pool.stats().free_buffers, 4);
    }

    #[test]
    fn steady_state_forward_allocates_nothing() {
        // simulate repeated forward passes: same shapes every time
        let pool: BufferPool<f32> = BufferPool::new();
        for _ in 0..10 {
            let x = pool.acquire(4096);
            let y = pool.acquire(1024);
            drop((x, y));
        }
        let s = pool.stats();
        assert_eq!(s.misses, 2, "only the first pass allocates: {s:?}");
        assert_eq!(s.hits, 18);
    }

    #[test]
    fn into_vec_detaches() {
        let pool: BufferPool<u8> = BufferPool::new();
        let v = pool.acquire(10).into_vec();
        assert_eq!(v.len(), 10);
        assert_eq!(pool.stats().free_buffers, 0);
    }

    #[test]
    fn release_beyond_cap_evicts() {
        let pool: BufferPool<f32> = BufferPool::new();
        pool.set_cap(2);
        // three live buffers in one class, released together: the third
        // release finds the class full and must drop its storage
        let a = pool.acquire(100);
        let b = pool.acquire(100);
        let c = pool.acquire(100);
        drop((a, b, c));
        let s = pool.stats();
        assert_eq!(s.free_buffers, 2, "{s:?}");
        assert_eq!(s.evicted, 1, "{s:?}");
        // a zero cap recycles nothing: the acquire pops one parked
        // buffer, the release drops it instead of re-parking it
        pool.set_cap(0);
        drop(pool.acquire(100));
        let s = pool.stats();
        assert_eq!(s.free_buffers, 1, "{s:?}");
        assert_eq!(s.evicted, 2, "{s:?}");
    }

    #[test]
    fn peak_free_elems_tracks_high_water() {
        let pool: BufferPool<f32> = BufferPool::new();
        pool.preallocate(100, 2); // class 128 -> 256 elems parked
        let s = pool.stats();
        assert_eq!(s.free_elems, 256, "{s:?}");
        assert_eq!(s.peak_free_elems, 256, "{s:?}");
        let a = pool.acquire(100);
        assert_eq!(pool.stats().free_elems, 128);
        drop(a);
        let s = pool.stats();
        assert_eq!(s.free_elems, 256, "{s:?}");
        assert_eq!(s.peak_free_elems, 256, "{s:?}");
        // the high-water mark survives a trim — that is its point
        pool.trim();
        let s = pool.stats();
        assert_eq!(s.free_elems, 0, "{s:?}");
        assert_eq!(s.peak_free_elems, 256, "{s:?}");
    }

    #[test]
    fn trim_empties_freelists() {
        let pool: BufferPool<u64> = BufferPool::new();
        pool.preallocate(256, 3);
        assert_eq!(pool.stats().free_buffers, 3);
        assert_eq!(pool.trim(), 3);
        let s = pool.stats();
        assert_eq!(s.free_buffers, 0, "{s:?}");
        assert_eq!(s.free_elems, 0, "{s:?}");
        // pool still works after a trim
        let b = pool.acquire(256);
        assert_eq!(b.len(), 256);
    }

    #[test]
    fn reserve_counts_same_class_lengths_together() {
        let pool: BufferPool<i32> = BufferPool::new();
        // 900 and 1000 share the 1024 class: two buffers must appear
        pool.reserve(&[900, 1000, 64]);
        assert_eq!(pool.stats().free_buffers, 3);
        // re-reserving is idempotent, not cumulative
        pool.reserve(&[900, 1000, 64]);
        assert_eq!(pool.stats().free_buffers, 3);
        // simultaneous acquires of the reserved shapes never miss
        let a = pool.acquire(900);
        let b = pool.acquire(1000);
        let c = pool.acquire(64);
        let s = pool.stats();
        assert_eq!(s.misses, 0, "{s:?}");
        assert_eq!(s.hits, 3, "{s:?}");
        drop((a, b, c));
    }

    #[test]
    fn workspace_reserve_routes_word_pool() {
        use crate::layers::ScratchSpec;
        let ws = Workspace::new();
        let spec = ScratchSpec {
            f32s: vec![128],
            i32s: vec![64],
            words: vec![32],
            bytes: vec![16],
        };
        ws.reserve::<u32>(&spec);
        assert_eq!(ws.words32.stats().free_buffers, 1);
        assert_eq!(ws.words64.stats().free_buffers, 0);
        ws.reserve::<u64>(&spec);
        assert_eq!(ws.words64.stats().free_buffers, 1);
        assert_eq!(ws.f32s.stats().free_buffers, 1);
        assert_eq!(ws.stats_total().free_buffers, 5);
        assert_eq!(ws.trim_all(), 5);
        assert_eq!(ws.stats_total().free_buffers, 0);
    }

    #[test]
    fn affine_acquire_returns_same_storage_to_same_slot() {
        let pool: BufferPool<i32> = BufferPool::new();
        let ptr0 = {
            let b = pool.acquire_affine(3, 100);
            b.as_ptr()
        };
        // same slot, same class: the warm buffer comes back
        let b = pool.acquire_affine(3, 90);
        assert_eq!(b.as_ptr(), ptr0, "slot 3 must reacquire its own buffer");
        let s = pool.stats();
        assert_eq!(s.affine_hits, 1, "{s:?}");
        assert_eq!(s.hits, 1, "{s:?}");
        assert_eq!(s.misses, 1, "{s:?}");
    }

    #[test]
    fn affine_miss_steals_before_allocating() {
        let pool: BufferPool<u8> = BufferPool::new();
        drop(pool.acquire_affine(1, 256)); // parked under slot 1
        // slot 2 has no warm buffer and the freelist is empty: it must
        // steal slot 1's parked buffer instead of allocating cold
        let b = pool.acquire_affine(2, 256);
        assert_eq!(b.len(), 256);
        let s = pool.stats();
        assert_eq!(s.misses, 1, "only the first acquire allocates: {s:?}");
        assert_eq!(s.hits, 1, "{s:?}");
        assert_eq!(s.affine_hits, 0, "a steal is not an affine hit: {s:?}");
    }

    #[test]
    fn affine_overflow_falls_back_to_freelist() {
        let pool: BufferPool<f32> = BufferPool::new();
        let a = pool.acquire_affine(0, 128);
        let b = pool.acquire_affine(0, 128);
        drop(a); // parks in slot (0, class)
        drop(b); // slot occupied -> freelist
        let s = pool.stats();
        assert_eq!(s.free_buffers, 2, "{s:?}");
        // both buffers are reusable and trim releases both
        let x = pool.acquire_affine(0, 128);
        let y = pool.acquire_affine(0, 128);
        assert_eq!(pool.stats().misses, 2, "no cold allocs after warmup");
        drop((x, y));
        assert_eq!(pool.trim(), 2);
        assert_eq!(pool.stats().free_buffers, 0);
    }

    /// Warm parking must never turn a reserved buffer into a miss for a
    /// plain (non-affine) acquire: plain acquires steal from the affine
    /// cache before allocating cold.
    #[test]
    fn plain_acquire_steals_affine_parked_buffers() {
        let pool: BufferPool<i32> = BufferPool::new();
        pool.reserve(&[500]);
        drop(pool.acquire_affine(5, 500)); // reserved buffer parked under slot 5
        let b = pool.acquire(500);
        assert_eq!(b.len(), 500);
        let s = pool.stats();
        assert_eq!(s.misses, 0, "plain acquire must reuse the parked buffer: {s:?}");
        assert_eq!(s.hits, 2, "{s:?}");
    }

    /// The per-class cap bounds affine slots too: worker-slot churn can
    /// park at most `cap` warm copies of a class beyond the freelist.
    #[test]
    fn affine_parks_respect_class_cap() {
        let pool: BufferPool<u8> = BufferPool::new();
        pool.set_cap(1);
        let a = pool.acquire_affine(0, 64);
        let b = pool.acquire_affine(1, 64);
        drop(a); // parks under (0, class): affine at cap 1
        drop(b); // affine full -> freelist (room at cap 1)
        assert_eq!(pool.stats().free_buffers, 2);
        let c = pool.acquire_affine(2, 64); // freelist
        let d = pool.acquire_affine(3, 64); // steals slot 0's park
        let e = pool.acquire_affine(4, 64); // nothing left: fresh alloc
        drop(c); // affine empty again -> parks
        drop(d); // affine full -> freelist
        drop(e); // both full -> evicted
        let s = pool.stats();
        assert_eq!(s.free_buffers, 2, "{s:?}");
        assert_eq!(s.evicted, 1, "{s:?}");
    }

    #[test]
    fn reserve_still_covers_affine_acquires() {
        // reservations fill the freelist; affine acquires must consume
        // them without ever missing, whatever slots ask
        let pool: BufferPool<i32> = BufferPool::new();
        pool.reserve(&[1000, 1000, 1000]);
        for round in 0..3 {
            let a = pool.acquire_affine(0, 1000);
            let b = pool.acquire_affine(7, 1000);
            let c = pool.acquire_affine(31, 1000);
            let s = pool.stats();
            assert_eq!(s.misses, 0, "round {round}: {s:?}");
            drop((a, b, c));
        }
    }

    #[test]
    fn pool_is_shareable_across_threads() {
        let pool: BufferPool<f32> = BufferPool::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let p = pool.clone();
                s.spawn(move || {
                    for _ in 0..50 {
                        let b = p.acquire(256);
                        assert_eq!(b.len(), 256);
                    }
                });
            }
        });
        let st = pool.stats();
        assert_eq!(st.hits + st.misses, 200);
    }
}
