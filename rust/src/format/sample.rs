//! Random, shape-valid model specs for property-based tests.
//!
//! `sample` draws a small random architecture (CNN or MLP) whose layer
//! geometry is guaranteed consistent: conv output shapes are tracked
//! through kernel/stride/pad/pool choices so the trailing dense layer
//! always matches the flattened activation. Sizes are kept small enough
//! that property harnesses can build and run dozens of networks per test.

use super::{BnSpec, InputKind, LayerSpec, ModelSpec};
use crate::layers::OutRepr;
use crate::tensor::{out_dim, Shape};
use crate::util::rng::Rng;

/// Random BatchNorm parameters with well-conditioned statistics (γ kept
/// away from 0 so folded thresholds are well-defined either direction).
pub fn sample_bn(rng: &mut Rng, f: usize) -> BnSpec {
    BnSpec {
        eps: 1e-4,
        gamma: (0..f)
            .map(|_| rng.f32_range(0.2, 2.0) * rng.sign())
            .collect(),
        beta: (0..f).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
        mean: (0..f).map(|_| rng.f32_range(-3.0, 3.0)).collect(),
        var: (0..f).map(|_| rng.f32_range(0.3, 4.0)).collect(),
    }
}

/// Random kernel extent for a spatial dimension of size `d`: 1, 2 or 3,
/// never exceeding `d` (asymmetric kernels arise because the two axes
/// draw independently).
fn sample_k(rng: &mut Rng, d: usize) -> usize {
    let k = 1 + rng.below(3);
    k.min(d)
}

/// Random output representation for a hidden (binarizing) block:
/// `(repr, act_delta, alpha)`. Plain sign stays the most common draw so
/// legacy paths keep coverage; the XNOR-scaled and multi-bit kinds each
/// get a steady share, and α scales ride along half the time.
fn sample_repr(rng: &mut Rng, features: usize) -> (OutRepr, f32, Option<Vec<f32>>) {
    let repr = match rng.below(10) {
        0..=3 => OutRepr::Sign,
        4 | 5 => OutRepr::ScaledSign,
        6 | 7 => OutRepr::Quant2,
        _ => OutRepr::Ternary,
    };
    let act_delta = if repr.planes() > 1 {
        rng.f32_range(0.5, 1.5)
    } else {
        1.0
    };
    let alpha = rng.bernoulli(0.5).then(|| {
        (0..features).map(|_| rng.f32_range(0.2, 1.8)).collect()
    });
    (repr, act_delta, alpha)
}

/// Random small CNN: 1–2 conv blocks (random — possibly asymmetric —
/// kernels, stride up to 3, random pad, optional fused pool, BN+sign)
/// followed by a dense score layer.
pub fn sample_cnn(rng: &mut Rng) -> ModelSpec {
    let mut shape = Shape::new(6 + rng.below(4), 6 + rng.below(4), 1 + rng.below(4));
    let input_shape = shape;
    let mut layers = Vec::new();
    let blocks = 1 + rng.below(2);
    for _ in 0..blocks {
        // kernel extents draw per-axis, so kh ≠ kw happens regularly
        let kh = sample_k(rng, shape.m);
        let kw = sample_k(rng, shape.n);
        let pad = rng.below(kh.min(kw) / 2 + 1);
        let stride = 1 + rng.below(3);
        let filters = 4 + rng.below(9);
        let oh = out_dim(shape.m, kh, stride, pad);
        let ow = out_dim(shape.n, kw, stride, pad);
        // fused pool only when the conv output is big enough for a 2x2
        let pool = if oh >= 2 && ow >= 2 && rng.bernoulli(0.5) {
            Some((2u32, 2u32))
        } else {
            None
        };
        let (repr, act_delta, alpha) = sample_repr(rng, filters);
        layers.push(LayerSpec::Conv {
            in_channels: shape.l as u32,
            filters: filters as u32,
            kh: kh as u32,
            kw: kw as u32,
            stride: stride as u32,
            pad: pad as u32,
            sign: true,
            bitplane_first: layers.is_empty() && rng.bernoulli(0.5),
            repr,
            act_delta,
            alpha,
            pool,
            weights: rng.signs(filters * kh * kw * shape.l).into(),
            bn: Some(sample_bn(rng, filters)),
        });
        shape = match pool {
            Some((pk, ps)) => Shape::new(
                out_dim(oh, pk as usize, ps as usize, 0),
                out_dim(ow, pk as usize, ps as usize, 0),
                filters,
            ),
            None => Shape::new(oh, ow, filters),
        };
    }
    let flat = shape.len();
    let classes = 10;
    layers.push(LayerSpec::Dense {
        in_features: flat as u32,
        out_features: classes as u32,
        sign: false,
        bitplane_first: false,
        repr: OutRepr::Sign,
        act_delta: 1.0,
        alpha: rng.bernoulli(0.3).then(|| {
            (0..classes).map(|_| rng.f32_range(0.2, 1.8)).collect()
        }),
        weights: rng.signs(flat * classes).into(),
        bn: Some(sample_bn(rng, classes)),
    });
    ModelSpec {
        name: "sample-cnn".into(),
        input_shape,
        input_kind: InputKind::Bytes,
        layers,
    }
}

/// Random small MLP: 1–2 hidden Dense→BN→sign blocks + a score layer.
pub fn sample_mlp(rng: &mut Rng) -> ModelSpec {
    let input = 16 + rng.below(49);
    let mut layers = Vec::new();
    let mut prev = input;
    let hidden_layers = 1 + rng.below(2);
    for i in 0..hidden_layers {
        let h = 8 + rng.below(25);
        let (repr, act_delta, alpha) = sample_repr(rng, h);
        layers.push(LayerSpec::Dense {
            in_features: prev as u32,
            out_features: h as u32,
            sign: true,
            bitplane_first: i == 0 && rng.bernoulli(0.5),
            repr,
            act_delta,
            alpha,
            weights: rng.signs(prev * h).into(),
            bn: Some(sample_bn(rng, h)),
        });
        prev = h;
    }
    layers.push(LayerSpec::Dense {
        in_features: prev as u32,
        out_features: 10,
        sign: false,
        bitplane_first: false,
        repr: OutRepr::Sign,
        act_delta: 1.0,
        alpha: rng.bernoulli(0.3).then(|| {
            (0..10).map(|_| rng.f32_range(0.2, 1.8)).collect()
        }),
        weights: rng.signs(prev * 10).into(),
        bn: Some(sample_bn(rng, 10)),
    });
    ModelSpec {
        name: "sample-mlp".into(),
        input_shape: Shape::vector(input),
        input_kind: InputKind::Bytes,
        layers,
    }
}

/// Random spec: CNN or MLP, evenly.
pub fn sample(rng: &mut Rng) -> ModelSpec {
    if rng.bernoulli(0.5) {
        sample_cnn(rng)
    } else {
        sample_mlp(rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layers::Backend;
    use crate::net::Network;

    #[test]
    fn sampled_specs_build_and_run() {
        let mut rng = Rng::new(241);
        for trial in 0..20 {
            let spec = sample(&mut rng);
            let net = Network::<u64>::from_spec(&spec, Backend::Binary)
                .unwrap_or_else(|e| panic!("trial {trial}: {e}"));
            let img: Vec<u8> = (0..spec.input_shape.len())
                .map(|_| rng.next_u32() as u8)
                .collect();
            let t = crate::tensor::Tensor::from_vec(spec.input_shape, img);
            let scores = net.predict_bytes(&t);
            assert_eq!(scores.len(), 10, "trial {trial}");
        }
    }

    /// The sampler must exercise the geometries the fused conv suite
    /// relies on: asymmetric kernels (kh ≠ kw) and stride 3.
    #[test]
    fn sample_cnn_covers_asymmetric_kernels_and_stride3() {
        let mut rng = Rng::new(243);
        let (mut asym, mut s3, mut padded) = (false, false, false);
        for _ in 0..100 {
            let spec = sample_cnn(&mut rng);
            for l in &spec.layers {
                if let LayerSpec::Conv {
                    kh, kw, stride, pad, ..
                } = l
                {
                    asym |= kh != kw;
                    s3 |= *stride == 3;
                    padded |= *pad > 0;
                }
            }
        }
        assert!(asym, "no asymmetric kernel sampled");
        assert!(s3, "no stride-3 conv sampled");
        assert!(padded, "no padded conv sampled");
    }

    /// The sampler must exercise every output representation plus the
    /// α / Δ axes, so the property suites downstream see them all.
    #[test]
    fn sampler_covers_representations() {
        let mut rng = Rng::new(244);
        let (mut sign, mut xnor, mut q2, mut tern) = (false, false, false, false);
        let (mut with_alpha, mut with_delta) = (false, false);
        for _ in 0..100 {
            let spec = sample(&mut rng);
            for l in &spec.layers {
                if let LayerSpec::Dense {
                    sign: true,
                    repr,
                    act_delta,
                    alpha,
                    ..
                }
                | LayerSpec::Conv {
                    sign: true,
                    repr,
                    act_delta,
                    alpha,
                    ..
                } = l
                {
                    match repr {
                        OutRepr::Sign => sign = true,
                        OutRepr::ScaledSign => xnor = true,
                        OutRepr::Quant2 => q2 = true,
                        OutRepr::Ternary => tern = true,
                    }
                    with_alpha |= alpha.is_some();
                    with_delta |= *act_delta != 1.0;
                }
            }
        }
        assert!(sign && xnor && q2 && tern, "missing a representation");
        assert!(with_alpha, "no alpha scales sampled");
        assert!(with_delta, "no non-unit activation delta sampled");
    }

    #[test]
    fn sampled_specs_roundtrip_esp() {
        let mut rng = Rng::new(242);
        for _ in 0..5 {
            let spec = sample(&mut rng);
            let mut buf = Vec::new();
            spec.write_to(&mut buf).unwrap();
            let back = ModelSpec::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(spec, back);
        }
    }
}
