//! The `.esp` parameter-file format (paper §5.2 "Converting a network to
//! Espresso").
//!
//! A DNN is completely specified by its parameters file: layers are
//! stored sequentially with their storage format and weights. Training
//! happens elsewhere (the JAX straight-through-estimator trainer in
//! `python/compile/train.py`, standing in for BinaryNet); the exporter
//! (`python/compile/convert.py`) writes this format, and the Rust side
//! reads it once at load time — at which point weights are binarized,
//! bit-packed, BN folded to thresholds, and padding corrections
//! precomputed.
//!
//! Layout (all little-endian):
//! ```text
//! magic "ESP1" | version u32 | name (u32 len + utf8)
//! input: m,n,l u32×3 | kind u8 (0 = u8 pixels, 1 = f32)
//! layer count u32, then per layer a tag u8 + payload (see LayerSpec)
//! ```
//!
//! Version 2 inserts 0–3 zero bytes after every f32-array length so the
//! array payload lands on a 4-byte file offset. That is what makes the
//! mmap load path zero-copy: `ModelSpec::load` maps the file
//! (page-aligned by construction) and hands each weight tensor out as a
//! [`Weights::Mapped`] window borrowing the mapping — parsing is
//! O(header), and every engine replica built from the spec shares one
//! physical copy of the parameters. Version-1 files (and misaligned
//! arrays, and non-Linux hosts) fall back to owned heap copies with
//! identical semantics.
//!
//! Version 3 appends a representation tail to every Dense/Conv record
//! (after the optional BN block): `repr u8 | act_delta f32 | [alpha
//! f32s]`, with the alpha array's presence flagged in the layer's flag
//! byte (Dense bit 3, Conv bit 4). `repr` selects the layer's output
//! quantization ([`OutRepr`]: sign / XNOR-scaled / 2-bit / ternary),
//! `act_delta` the activation step Δ, `alpha` the per-output-channel
//! weight scales. Version-2 files parse with the defaults (`Sign`, Δ=1,
//! no α) and [`ModelSpec::write_to_version`] can still emit v2 for
//! models that carry only those defaults.
//!
//! Version 4 keeps the v3 body byte-for-byte and appends an integrity
//! trailer so a truncated or bit-flipped file is rejected *before* any
//! tensor is built (serving keeps the old model version and reports the
//! cause). The body is divided into sections — the header (magic
//! through the layer count) and then one section per layer — and the
//! trailer records a CRC32 per section:
//! ```text
//! n_sections u32 | n × (section_len u32, section_crc32 u32)
//! body_len u32 | trailer_len u32 | trailer magic "ESPT"
//! ```
//! The trailer is self-locating from EOF (final 8 bytes are
//! `trailer_len | "ESPT"`), and verification cross-checks the table
//! size against `n`, the recorded body length against the file length,
//! the section lengths against the body, and every CRC — so any
//! single-bit flip or truncation anywhere in the file is caught. The
//! mmap zero-copy path is unchanged: verification reads the mapping
//! once, then parsing borrows weight windows from the same pages.

pub mod sample;

use crate::layers::{BnParams, OutRepr, PoolSpec};
use crate::tensor::Shape;
use crate::util::crc32::crc32;
use crate::util::fault;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::sync::Arc;

pub const MAGIC: &[u8; 4] = b"ESP1";
/// Current on-disk version: v3's layout (aligned arrays + the per-layer
/// representation tail) plus an integrity trailer — a per-section CRC32
/// table and total-length record appended after the body, verified on
/// load **before any tensor is built**. Versions 1–3 are still accepted
/// (without integrity verification — they carry no checksums).
pub const FORMAT_VERSION: u32 = 4;
pub const MIN_FORMAT_VERSION: u32 = 1;
/// Magic closing the v4 integrity trailer (the last 4 bytes of a v4
/// file); its absence on a version-4 file is a precise "truncated or
/// not-fully-written" signal rather than a parse error deep in a layer.
pub const TRAILER_MAGIC: &[u8; 4] = b"ESPT";

/// A weight file refused by integrity verification (truncated, bit
/// flipped, or partially written). Typed so the serving layer can count
/// `integrity_rejects` and report the cause distinctly — `anyhow`'s
/// downcast searches the whole context chain for it.
#[derive(Debug)]
pub struct IntegrityError(pub String);

impl std::fmt::Display for IntegrityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "integrity check failed: {}", self.0)
    }
}

impl std::error::Error for IntegrityError {}

// ---------------------------------------------------------------------
// file mapping
// ---------------------------------------------------------------------

/// Raw `mmap(2)` binding in the same no-libc style as
/// `coordinator::event::sys`; Linux-only, with the loader falling back
/// to a buffered heap read elsewhere.
#[cfg(target_os = "linux")]
mod mapping {
    mod sys {
        pub const PROT_READ: i32 = 0x1;
        pub const MAP_PRIVATE: i32 = 0x2;
        extern "C" {
            pub fn mmap(
                addr: *mut u8,
                length: usize,
                prot: i32,
                flags: i32,
                fd: i32,
                offset: i64,
            ) -> *mut u8;
            pub fn munmap(addr: *mut u8, length: usize) -> i32;
        }
    }

    /// An immutable, page-aligned mapping of a whole file. Weight
    /// tensors borrow windows of it; the mapping stays alive (and the
    /// pages stay shared) as long as any borrowing `Weights` clone does.
    pub struct Mmap {
        ptr: *const u8,
        len: usize,
    }

    // Read-only and never remapped after construction.
    unsafe impl Send for Mmap {}
    unsafe impl Sync for Mmap {}

    impl Mmap {
        pub fn map(f: &std::fs::File) -> std::io::Result<Self> {
            use std::os::fd::AsRawFd;
            let len = f.metadata()?.len();
            if len == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    "empty file",
                ));
            }
            let len = usize::try_from(len).map_err(|_| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, "file too large to map")
            })?;
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    f.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize == -1 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(Self { ptr, len })
        }

        pub fn as_ptr(&self) -> *const u8 {
            self.ptr
        }
    }

    impl Drop for Mmap {
        fn drop(&mut self) {
            unsafe { sys::munmap(self.ptr as *mut u8, self.len) };
        }
    }

    impl std::ops::Deref for Mmap {
        type Target = [u8];
        fn deref(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }
    }
}

/// Portability stub: `map` always fails, so the loader takes the
/// heap-read path, but the type keeps `Weights` uniform across targets.
#[cfg(not(target_os = "linux"))]
mod mapping {
    pub struct Mmap(());

    impl Mmap {
        pub fn map(_f: &std::fs::File) -> std::io::Result<Self> {
            Err(std::io::Error::new(
                std::io::ErrorKind::Unsupported,
                "mmap unavailable on this target",
            ))
        }

        pub fn as_ptr(&self) -> *const u8 {
            std::ptr::null()
        }
    }

    impl std::ops::Deref for Mmap {
        type Target = [u8];
        fn deref(&self) -> &[u8] {
            &[]
        }
    }
}

pub use mapping::Mmap;

// ---------------------------------------------------------------------
// weight storage
// ---------------------------------------------------------------------

/// A layer's weight tensor: either an owned heap vector (stream reads,
/// hand-built specs, misaligned arrays) or a 4-byte-aligned window
/// borrowing a shared file mapping. Cloning a mapped tensor clones an
/// `Arc`, so N engine replicas share one physical copy.
pub enum Weights {
    Owned(Vec<f32>),
    Mapped {
        map: Arc<Mmap>,
        off: usize,
        len: usize,
    },
}

impl Weights {
    /// True when the tensor borrows a file mapping instead of owning a
    /// heap copy.
    pub fn is_mapped(&self) -> bool {
        matches!(self, Weights::Mapped { .. })
    }
}

impl std::ops::Deref for Weights {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        match self {
            Weights::Owned(v) => v,
            Weights::Mapped { map, off, len } => unsafe {
                // alignment holds by construction: the mapping base is
                // page-aligned and `off` is a multiple of 4
                std::slice::from_raw_parts(map.as_ptr().add(*off) as *const f32, *len)
            },
        }
    }
}

impl Clone for Weights {
    fn clone(&self) -> Self {
        match self {
            Weights::Owned(v) => Weights::Owned(v.clone()),
            Weights::Mapped { map, off, len } => Weights::Mapped {
                map: Arc::clone(map),
                off: *off,
                len: *len,
            },
        }
    }
}

impl PartialEq for Weights {
    fn eq(&self, other: &Self) -> bool {
        self[..] == other[..]
    }
}

impl std::fmt::Debug for Weights {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Weights[{} f32; {}]",
            self.len(),
            if self.is_mapped() { "mapped" } else { "owned" }
        )
    }
}

impl From<Vec<f32>> for Weights {
    fn from(v: Vec<f32>) -> Self {
        Weights::Owned(v)
    }
}

/// What `ModelSpec::load` did with the file's weight bytes.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadStats {
    pub file_bytes: usize,
    /// Whether the file was parsed out of an `mmap`.
    pub mapped: bool,
    /// Weight-tensor bytes lent out of the mapping with no heap copy.
    pub weight_bytes_borrowed: usize,
    /// Weight-tensor bytes copied to the heap (v1 misaligned arrays or
    /// the non-mmap fallback path).
    pub weight_bytes_copied: usize,
}

// ---------------------------------------------------------------------
// layer / model types
// ---------------------------------------------------------------------

/// How the network's input is presented.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InputKind {
    /// 8-bit fixed-precision pixels (bit-plane eligible).
    Bytes = 0,
    /// Float input.
    Float = 1,
}

/// A serialized layer description.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerSpec {
    Dense {
        in_features: u32,
        out_features: u32,
        sign: bool,
        bitplane_first: bool,
        /// Output representation of the binarizing tail (format v3;
        /// `Sign` for older files).
        repr: OutRepr,
        /// Activation quantization step Δ for multi-bit outputs (v3).
        act_delta: f32,
        /// Per-output-channel weight scales α (v3; `None` = unscaled).
        alpha: Option<Vec<f32>>,
        weights: Weights,
        bn: Option<BnSpec>,
    },
    Conv {
        in_channels: u32,
        filters: u32,
        kh: u32,
        kw: u32,
        stride: u32,
        pad: u32,
        sign: bool,
        /// Bit-plane-optimize a fixed-precision (first-layer) input.
        bitplane_first: bool,
        /// Output representation of the binarizing tail (format v3;
        /// `Sign` for older files).
        repr: OutRepr,
        /// Activation quantization step Δ for multi-bit outputs (v3).
        act_delta: f32,
        /// Per-filter weight scales α (v3; `None` = unscaled).
        alpha: Option<Vec<f32>>,
        pool: Option<(u32, u32)>,
        weights: Weights,
        bn: Option<BnSpec>,
    },
    MaxPool {
        k: u32,
        stride: u32,
    },
    BatchNorm(BnSpec),
    Sign,
}

/// Serialized BatchNorm parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct BnSpec {
    pub eps: f32,
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
}

impl BnSpec {
    pub fn to_params(&self) -> BnParams {
        BnParams {
            gamma: self.gamma.clone(),
            beta: self.beta.clone(),
            mean: self.mean.clone(),
            var: self.var.clone(),
            eps: self.eps,
        }
    }

    pub fn from_params(p: &BnParams) -> Self {
        Self {
            eps: p.eps,
            gamma: p.gamma.clone(),
            beta: p.beta.clone(),
            mean: p.mean.clone(),
            var: p.var.clone(),
        }
    }
}

/// A complete serialized model.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub input_shape: Shape,
    pub input_kind: InputKind,
    pub layers: Vec<LayerSpec>,
}

impl LayerSpec {
    /// Pool geometry helper.
    pub fn pool_spec(k: u32, stride: u32) -> PoolSpec {
        PoolSpec {
            k: k as usize,
            stride: stride as usize,
        }
    }
}

// ---------------------------------------------------------------------
// writer (position-tracking, so v2 can pad arrays to 4-byte offsets)
// ---------------------------------------------------------------------

struct CountWriter<'a, W: Write> {
    w: &'a mut W,
    pos: usize,
}

impl<'a, W: Write> CountWriter<'a, W> {
    fn put(&mut self, b: &[u8]) -> Result<()> {
        self.w.write_all(b)?;
        self.pos += b.len();
        Ok(())
    }

    fn u8(&mut self, v: u8) -> Result<()> {
        self.put(&[v])
    }

    fn u32(&mut self, v: u32) -> Result<()> {
        self.put(&v.to_le_bytes())
    }

    fn f32(&mut self, v: f32) -> Result<()> {
        self.put(&v.to_le_bytes())
    }

    fn str(&mut self, s: &str) -> Result<()> {
        self.u32(s.len() as u32)?;
        self.put(s.as_bytes())
    }

    fn f32s(&mut self, vs: &[f32]) -> Result<()> {
        self.u32(vs.len() as u32)?;
        let pad = (4 - self.pos % 4) % 4;
        self.put(&[0u8; 3][..pad])?;
        // bulk write: reinterpret as LE bytes
        let mut buf = Vec::with_capacity(vs.len() * 4);
        for v in vs {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.put(&buf)
    }

    fn bn(&mut self, bn: &BnSpec) -> Result<()> {
        self.f32(bn.eps)?;
        self.f32s(&bn.gamma)?;
        self.f32s(&bn.beta)?;
        self.f32s(&bn.mean)?;
        self.f32s(&bn.var)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// reader (byte cursor over a resident image: mapping or heap buffer)
// ---------------------------------------------------------------------

const MAX_ELEMS: u32 = 1 << 28; // 1 GiB of f32s — sanity bound on corrupt files

struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Present when `buf` is a file mapping: weight arrays borrow it.
    map: Option<&'a Arc<Mmap>>,
    version: u32,
    borrowed: usize,
    copied: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8], map: Option<&'a Arc<Mmap>>) -> Self {
        Self {
            buf,
            pos: 0,
            map,
            version: MIN_FORMAT_VERSION,
            borrowed: 0,
            copied: 0,
        }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!("unexpected end of file at byte {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()?;
        if n > 1 << 16 {
            bail!("string length {n} exceeds sanity bound");
        }
        String::from_utf8(self.take(n as usize)?.to_vec()).context("model name not utf8")
    }

    /// Skip the v2 alignment pad that follows every array length.
    fn align4(&mut self) -> Result<()> {
        if self.version >= 2 {
            let pad = (4 - self.pos % 4) % 4;
            self.take(pad)?;
        }
        Ok(())
    }

    fn array_bytes(&mut self) -> Result<(usize, &'a [u8])> {
        let n = self.u32()?;
        if n > MAX_ELEMS {
            bail!("array length {n} exceeds sanity bound (corrupt file?)");
        }
        self.align4()?;
        let off = self.pos;
        Ok((off, self.take(n as usize * 4)?))
    }

    /// Small arrays (BN vectors): always copied to the heap.
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let (_, bytes) = self.array_bytes()?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Weight tensors: borrow the mapping when the payload sits on a
    /// 4-byte boundary (always true for v2 files), copy otherwise.
    fn weights(&mut self) -> Result<Weights> {
        let (off, bytes) = self.array_bytes()?;
        if let Some(map) = self.map {
            if (map.as_ptr() as usize + off) % 4 == 0 {
                self.borrowed += bytes.len();
                return Ok(Weights::Mapped {
                    map: Arc::clone(map),
                    off,
                    len: bytes.len() / 4,
                });
            }
        }
        self.copied += bytes.len();
        Ok(Weights::Owned(
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ))
    }

    fn bn(&mut self) -> Result<BnSpec> {
        Ok(BnSpec {
            eps: self.f32()?,
            gamma: self.f32s()?,
            beta: self.f32s()?,
            mean: self.f32s()?,
            var: self.f32s()?,
        })
    }

    /// The v3 representation tail of a Dense/Conv record: `repr u8 |
    /// act_delta f32 | [alpha f32s]` (alpha presence is in the layer's
    /// flag byte). Pre-v3 files get the defaults.
    fn repr_tail(
        &mut self,
        has_alpha: bool,
        sign: bool,
        features: usize,
        i: u32,
    ) -> Result<(OutRepr, f32, Option<Vec<f32>>)> {
        if self.version < 3 {
            return Ok((OutRepr::Sign, 1.0, None));
        }
        let tag = self.u8()?;
        let repr = match OutRepr::from_tag(tag) {
            Some(r) => r,
            None => bail!("layer {i}: unknown representation tag {tag}"),
        };
        if repr != OutRepr::Sign && !sign {
            bail!("layer {i}: representation {repr} requires a binarizing tail");
        }
        let act_delta = self.f32()?;
        if !(act_delta.is_finite() && act_delta > 0.0) {
            bail!("layer {i}: activation delta {act_delta} must be positive");
        }
        let alpha = if has_alpha {
            let a = self.f32s()?;
            if a.len() != features {
                bail!("layer {i}: alpha length {} != features {features}", a.len());
            }
            if !a.iter().all(|v| v.is_finite() && *v > 0.0) {
                bail!("layer {i}: alpha scales must be positive");
            }
            Some(a)
        } else {
            None
        };
        Ok((repr, act_delta, alpha))
    }
}

// ---------------------------------------------------------------------
// model ser/de
// ---------------------------------------------------------------------

impl ModelSpec {
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        self.write_to_version(w, FORMAT_VERSION)
    }

    /// Write a specific on-disk version (compat tooling and the CI
    /// back-compat suite). Bails when a layer carries representation
    /// state the requested version cannot encode (non-`sign` repr,
    /// Δ ≠ 1, or α scales need v3), or when `version` predates the
    /// aligned-array layout (v1 files are read-only legacy).
    pub fn write_to_version<W: Write>(&self, w: &mut W, version: u32) -> Result<()> {
        if !(2..=FORMAT_VERSION).contains(&version) {
            bail!("cannot write .esp version {version}");
        }
        // The body is buffered so v4 can checksum it section by section;
        // positions inside the buffer equal file offsets (the body is a
        // prefix of the file), so v2+ array alignment is unaffected.
        let mut body: Vec<u8> = Vec::new();
        // End offset of each checksummed section: the header, then one
        // entry per layer.
        let mut marks: Vec<usize> = Vec::with_capacity(self.layers.len() + 1);
        let mut cw = CountWriter { w: &mut body, pos: 0 };
        cw.put(MAGIC)?;
        cw.u32(version)?;
        cw.str(&self.name)?;
        cw.u32(self.input_shape.m as u32)?;
        cw.u32(self.input_shape.n as u32)?;
        cw.u32(self.input_shape.l as u32)?;
        cw.u8(self.input_kind as u8)?;
        cw.u32(self.layers.len() as u32)?;
        marks.push(cw.pos);
        for layer in &self.layers {
            if version < 3 {
                if let LayerSpec::Dense {
                    repr,
                    act_delta,
                    alpha,
                    ..
                }
                | LayerSpec::Conv {
                    repr,
                    act_delta,
                    alpha,
                    ..
                } = layer
                {
                    if *repr != OutRepr::Sign || *act_delta != 1.0 || alpha.is_some() {
                        bail!(
                            "version {version} cannot encode representation state \
                             (repr={repr}, delta={act_delta}, alpha={})",
                            alpha.is_some()
                        );
                    }
                }
            }
            match layer {
                LayerSpec::Dense {
                    in_features,
                    out_features,
                    sign,
                    bitplane_first,
                    repr,
                    act_delta,
                    alpha,
                    weights,
                    bn,
                } => {
                    cw.u8(1)?;
                    cw.u32(*in_features)?;
                    cw.u32(*out_features)?;
                    let mut flags = u8::from(*sign)
                        | (u8::from(bn.is_some()) << 1)
                        | (u8::from(*bitplane_first) << 2);
                    if version >= 3 {
                        flags |= u8::from(alpha.is_some()) << 3;
                    }
                    cw.u8(flags)?;
                    cw.f32s(weights)?;
                    if let Some(b) = bn {
                        cw.bn(b)?;
                    }
                    if version >= 3 {
                        cw.u8(repr.tag())?;
                        cw.f32(*act_delta)?;
                        if let Some(a) = alpha {
                            cw.f32s(a)?;
                        }
                    }
                }
                LayerSpec::Conv {
                    in_channels,
                    filters,
                    kh,
                    kw,
                    stride,
                    pad,
                    sign,
                    bitplane_first,
                    repr,
                    act_delta,
                    alpha,
                    pool,
                    weights,
                    bn,
                } => {
                    cw.u8(2)?;
                    for v in [in_channels, filters, kh, kw, stride, pad] {
                        cw.u32(*v)?;
                    }
                    let mut flags = u8::from(*sign)
                        | (u8::from(bn.is_some()) << 1)
                        | (u8::from(pool.is_some()) << 2)
                        | (u8::from(*bitplane_first) << 3);
                    if version >= 3 {
                        flags |= u8::from(alpha.is_some()) << 4;
                    }
                    cw.u8(flags)?;
                    if let Some((pk, ps)) = pool {
                        cw.u32(*pk)?;
                        cw.u32(*ps)?;
                    }
                    cw.f32s(weights)?;
                    if let Some(b) = bn {
                        cw.bn(b)?;
                    }
                    if version >= 3 {
                        cw.u8(repr.tag())?;
                        cw.f32(*act_delta)?;
                        if let Some(a) = alpha {
                            cw.f32s(a)?;
                        }
                    }
                }
                LayerSpec::MaxPool { k, stride } => {
                    cw.u8(3)?;
                    cw.u32(*k)?;
                    cw.u32(*stride)?;
                }
                LayerSpec::BatchNorm(bn) => {
                    cw.u8(4)?;
                    cw.bn(bn)?;
                }
                LayerSpec::Sign => cw.u8(5)?,
            }
            marks.push(cw.pos);
        }
        w.write_all(&body)?;
        if version >= 4 {
            if body.len() > u32::MAX as usize {
                bail!("model body too large for a v4 integrity trailer");
            }
            let mut trailer = Vec::with_capacity(8 * marks.len() + 16);
            trailer.extend_from_slice(&(marks.len() as u32).to_le_bytes());
            let mut start = 0usize;
            for &end in &marks {
                trailer.extend_from_slice(&((end - start) as u32).to_le_bytes());
                trailer.extend_from_slice(&crc32(&body[start..end]).to_le_bytes());
                start = end;
            }
            trailer.extend_from_slice(&(body.len() as u32).to_le_bytes());
            // trailer_len covers everything from n_sections through the
            // trailing magic: what has been written plus these 8 bytes.
            trailer.extend_from_slice(&((trailer.len() + 8) as u32).to_le_bytes());
            trailer.extend_from_slice(TRAILER_MAGIC);
            w.write_all(&trailer)?;
        }
        Ok(())
    }

    fn parse(cur: &mut Cur) -> Result<Self> {
        let magic = cur.take(4)?;
        if magic != MAGIC {
            bail!("not an .esp file (bad magic {magic:?})");
        }
        let version = cur.u32()?;
        if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version) {
            bail!("unsupported .esp version {version}");
        }
        cur.version = version;
        let name = cur.str()?;
        let input_shape = Shape::new(
            cur.u32()? as usize,
            cur.u32()? as usize,
            cur.u32()? as usize,
        );
        let input_kind = match cur.u8()? {
            0 => InputKind::Bytes,
            1 => InputKind::Float,
            k => bail!("unknown input kind {k}"),
        };
        let n_layers = cur.u32()?;
        if n_layers > 10_000 {
            bail!("layer count {n_layers} exceeds sanity bound");
        }
        let mut layers = Vec::with_capacity(n_layers as usize);
        for i in 0..n_layers {
            let tag = cur.u8().with_context(|| format!("layer {i} tag"))?;
            let layer = match tag {
                1 => {
                    let in_features = cur.u32()?;
                    let out_features = cur.u32()?;
                    let flags = cur.u8()?;
                    let weights = cur.weights()?;
                    if weights.len() != (in_features * out_features) as usize {
                        bail!("dense layer {i}: weight count mismatch");
                    }
                    let bn = if flags & 2 != 0 {
                        Some(cur.bn()?)
                    } else {
                        None
                    };
                    let sign = flags & 1 != 0;
                    let (repr, act_delta, alpha) =
                        cur.repr_tail(flags & 8 != 0, sign, out_features as usize, i)?;
                    LayerSpec::Dense {
                        in_features,
                        out_features,
                        sign,
                        bitplane_first: flags & 4 != 0,
                        repr,
                        act_delta,
                        alpha,
                        weights,
                        bn,
                    }
                }
                2 => {
                    let in_channels = cur.u32()?;
                    let filters = cur.u32()?;
                    let kh = cur.u32()?;
                    let kw = cur.u32()?;
                    let stride = cur.u32()?;
                    let pad = cur.u32()?;
                    let flags = cur.u8()?;
                    let pool = if flags & 4 != 0 {
                        Some((cur.u32()?, cur.u32()?))
                    } else {
                        None
                    };
                    let weights = cur.weights()?;
                    if weights.len() != (filters * kh * kw * in_channels) as usize {
                        bail!("conv layer {i}: weight count mismatch");
                    }
                    let bn = if flags & 2 != 0 {
                        Some(cur.bn()?)
                    } else {
                        None
                    };
                    let sign = flags & 1 != 0;
                    let (repr, act_delta, alpha) =
                        cur.repr_tail(flags & 16 != 0, sign, filters as usize, i)?;
                    LayerSpec::Conv {
                        in_channels,
                        filters,
                        kh,
                        kw,
                        stride,
                        pad,
                        sign,
                        bitplane_first: flags & 8 != 0,
                        repr,
                        act_delta,
                        alpha,
                        pool,
                        weights,
                        bn,
                    }
                }
                3 => LayerSpec::MaxPool {
                    k: cur.u32()?,
                    stride: cur.u32()?,
                },
                4 => LayerSpec::BatchNorm(cur.bn()?),
                5 => LayerSpec::Sign,
                t => bail!("unknown layer tag {t} at layer {i}"),
            };
            layers.push(layer);
        }
        Ok(Self {
            name,
            input_shape,
            input_kind,
            layers,
        })
    }

    /// Stream read: buffers the stream and parses with owned weights
    /// (the copy fallback path — `load` is the zero-copy one).
    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        let mut buf = Vec::new();
        r.read_to_end(&mut buf)?;
        let body = split_verified(&buf)?;
        let mut cur = Cur::new(body, None);
        Self::parse(&mut cur)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        self.write_to(&mut f)?;
        use std::io::Write as _;
        f.flush()?;
        drop(f);
        if fault::should_fire("partial-write") {
            // Simulate a writer dying mid-file: chop the tail off so the
            // trailer (and possibly part of the body) is gone.
            let len = std::fs::metadata(path)?.len();
            let f = std::fs::OpenOptions::new().write(true).open(path)?;
            f.set_len(len * 2 / 3)?;
        }
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::load_with_stats(path).map(|(spec, _)| spec)
    }

    /// Load a model, preferring a shared file mapping: on Linux the
    /// file is `mmap`ed and weight tensors borrow the mapping (no heap
    /// copy of the parameter bytes); elsewhere, or if the map fails,
    /// the whole file is read and parsed with owned weights.
    pub fn load_with_stats(path: &std::path::Path) -> Result<(Self, LoadStats)> {
        if fault::should_fire("corrupt-load") {
            return Err(anyhow::Error::new(IntegrityError(format!(
                "fault injection: corrupt-load for {path:?}"
            ))));
        }
        let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        if let Ok(map) = Mmap::map(&f) {
            let map = Arc::new(map);
            let data: &[u8] = &map;
            let body = split_verified(data).with_context(|| format!("verify {path:?}"))?;
            let mut cur = Cur::new(body, Some(&map));
            let spec = Self::parse(&mut cur).with_context(|| format!("parse {path:?}"))?;
            let stats = LoadStats {
                file_bytes: data.len(),
                mapped: true,
                weight_bytes_borrowed: cur.borrowed,
                weight_bytes_copied: cur.copied,
            };
            return Ok((spec, stats));
        }
        let mut buf = Vec::new();
        std::io::BufReader::new(f)
            .read_to_end(&mut buf)
            .with_context(|| format!("read {path:?}"))?;
        let body = split_verified(&buf).with_context(|| format!("verify {path:?}"))?;
        let mut cur = Cur::new(body, None);
        let spec = Self::parse(&mut cur).with_context(|| format!("parse {path:?}"))?;
        let stats = LoadStats {
            file_bytes: buf.len(),
            mapped: false,
            weight_bytes_borrowed: 0,
            weight_bytes_copied: cur.copied,
        };
        Ok((spec, stats))
    }
}

/// Verify a resident file image's v4 integrity trailer and return the
/// body slice the parser should see. Pre-v4 images (and images too
/// short or mis-magicked for `parse` to diagnose precisely) pass
/// through unchanged — they carry no checksums. Runs before any tensor
/// is built, on the mmap path, the heap fallback, and stream reads.
fn split_verified(buf: &[u8]) -> Result<&[u8]> {
    if buf.len() < 8 || &buf[0..4] != MAGIC {
        return Ok(buf);
    }
    let rd = |off: usize| u32::from_le_bytes(buf[off..off + 4].try_into().unwrap()) as usize;
    // Only versions we know carry a trailer; anything else (older files,
    // future or corrupted version fields) falls through so `parse` can
    // report "unsupported version" rather than a misleading trailer error.
    if !(4..=FORMAT_VERSION as usize).contains(&rd(4)) {
        return Ok(buf);
    }
    let reject = |msg: String| Err(anyhow::Error::new(IntegrityError(msg)));
    let len = buf.len();
    if len < 16 || &buf[len - 4..] != TRAILER_MAGIC {
        return reject("missing integrity trailer (truncated or partially written file)".into());
    }
    let trailer_len = rd(len - 8);
    if trailer_len < 16 || trailer_len > len {
        return reject(format!(
            "trailer length {trailer_len} out of range for a {len}-byte file"
        ));
    }
    let tstart = len - trailer_len;
    let n = rd(tstart);
    // header + at most 10_000 layers (the parser's own bound)
    if n > 10_001 || trailer_len != 8 * n + 16 {
        return reject(format!(
            "section table malformed ({n} sections in a {trailer_len}-byte trailer)"
        ));
    }
    let body_len = rd(len - 12);
    if body_len != tstart {
        return reject(format!(
            "recorded body length {body_len} does not match the {tstart} bytes before the trailer"
        ));
    }
    let mut off = 0usize;
    for i in 0..n {
        let rec = tstart + 4 + 8 * i;
        let slen = rd(rec);
        let want = rd(rec + 4) as u32;
        if slen > body_len - off {
            return reject(format!("section {i} overruns the body"));
        }
        let got = crc32(&buf[off..off + slen]);
        if got != want {
            return reject(format!(
                "checksum mismatch in section {i} (bytes {off}..{}): expected {want:#010x}, got {got:#010x}",
                off + slen
            ));
        }
        off += slen;
    }
    if off != body_len {
        return reject(format!(
            "section lengths cover {off} of {body_len} body bytes"
        ));
    }
    Ok(&buf[..body_len])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_bn(rng: &mut Rng, f: usize) -> BnSpec {
        BnSpec {
            eps: 1e-4,
            gamma: (0..f).map(|_| rng.f32_range(0.1, 2.0)).collect(),
            beta: (0..f).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
            mean: (0..f).map(|_| rng.f32_range(-3.0, 3.0)).collect(),
            var: (0..f).map(|_| rng.f32_range(0.2, 4.0)).collect(),
        }
    }

    fn sample_model(rng: &mut Rng) -> ModelSpec {
        ModelSpec {
            name: "unit-test-model".into(),
            input_shape: Shape::new(8, 8, 3),
            input_kind: InputKind::Bytes,
            layers: vec![
                LayerSpec::Conv {
                    in_channels: 3,
                    filters: 16,
                    kh: 3,
                    kw: 3,
                    stride: 1,
                    pad: 1,
                    sign: true,
                    bitplane_first: true,
                    repr: OutRepr::Sign,
                    act_delta: 1.0,
                    alpha: None,
                    pool: Some((2, 2)),
                    weights: rng.signs(16 * 9 * 3).into(),
                    bn: Some(sample_bn(rng, 16)),
                },
                LayerSpec::MaxPool { k: 2, stride: 2 },
                LayerSpec::Sign,
                LayerSpec::Dense {
                    in_features: 64,
                    out_features: 10,
                    sign: false,
                    bitplane_first: false,
                    repr: OutRepr::Sign,
                    act_delta: 1.0,
                    alpha: None,
                    weights: rng.signs(640).into(),
                    bn: Some(sample_bn(rng, 10)),
                },
                LayerSpec::BatchNorm(sample_bn(rng, 10)),
            ],
        }
    }

    #[test]
    fn roundtrip_preserves_model() {
        let mut rng = Rng::new(121);
        let spec = sample_model(&mut rng);
        let mut buf = Vec::new();
        spec.write_to(&mut buf).unwrap();
        let back = ModelSpec::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(spec, back);
    }

    /// A model carrying every v3 representation field: scaled/quantized
    /// reprs, non-unit Δ, α vectors.
    fn repr_model(rng: &mut Rng) -> ModelSpec {
        ModelSpec {
            name: "repr-model".into(),
            input_shape: Shape::new(8, 8, 3),
            input_kind: InputKind::Bytes,
            layers: vec![
                LayerSpec::Conv {
                    in_channels: 3,
                    filters: 16,
                    kh: 3,
                    kw: 3,
                    stride: 1,
                    pad: 1,
                    sign: true,
                    bitplane_first: true,
                    repr: OutRepr::Ternary,
                    act_delta: 0.75,
                    alpha: Some((0..16).map(|_| rng.f32_range(0.1, 2.0)).collect()),
                    pool: None,
                    weights: rng.signs(16 * 9 * 3).into(),
                    bn: Some(sample_bn(rng, 16)),
                },
                LayerSpec::Dense {
                    in_features: 8 * 8 * 16,
                    out_features: 32,
                    sign: true,
                    bitplane_first: false,
                    repr: OutRepr::ScaledSign,
                    act_delta: 1.0,
                    alpha: Some((0..32).map(|_| rng.f32_range(0.1, 2.0)).collect()),
                    weights: rng.signs(8 * 8 * 16 * 32).into(),
                    bn: Some(sample_bn(rng, 32)),
                },
                LayerSpec::Dense {
                    in_features: 32,
                    out_features: 10,
                    sign: false,
                    bitplane_first: false,
                    repr: OutRepr::Sign,
                    act_delta: 1.0,
                    alpha: None,
                    weights: rng.signs(320).into(),
                    bn: None,
                },
            ],
        }
    }

    #[test]
    fn v3_roundtrips_repr_delta_alpha() {
        let mut rng = Rng::new(126);
        let spec = repr_model(&mut rng);
        let mut buf = Vec::new();
        spec.write_to(&mut buf).unwrap();
        let back = ModelSpec::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(spec, back);
        // and through the file/mmap loader
        let path = std::env::temp_dir().join("espresso_fmt_v3_test.esp");
        spec.save(&path).unwrap();
        let loaded = ModelSpec::load(&path).unwrap();
        assert_eq!(spec, loaded);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn v2_write_roundtrips_default_repr_models() {
        // a model with only default representation state still writes as
        // v2, and a v2 file loads with the defaults filled in
        let mut rng = Rng::new(127);
        let spec = sample_model(&mut rng);
        let mut buf = Vec::new();
        spec.write_to_version(&mut buf, 2).unwrap();
        assert_eq!(u32::from_le_bytes(buf[4..8].try_into().unwrap()), 2);
        let back = ModelSpec::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(spec, back);
        for l in &back.layers {
            if let LayerSpec::Dense {
                repr,
                act_delta,
                alpha,
                ..
            }
            | LayerSpec::Conv {
                repr,
                act_delta,
                alpha,
                ..
            } = l
            {
                assert_eq!(*repr, OutRepr::Sign);
                assert_eq!(*act_delta, 1.0);
                assert!(alpha.is_none());
            }
        }
    }

    #[test]
    fn v2_write_rejects_repr_state() {
        let mut rng = Rng::new(128);
        let spec = repr_model(&mut rng);
        let err = spec
            .write_to_version(&mut Vec::new(), 2)
            .unwrap_err()
            .to_string();
        assert!(err.contains("cannot encode"), "{err}");
        assert!(spec
            .write_to_version(&mut Vec::new(), 1)
            .unwrap_err()
            .to_string()
            .contains("cannot write"));
    }

    #[test]
    fn rejects_bad_repr_tail() {
        // repr on a non-binarizing layer must be rejected
        let mut rng = Rng::new(129);
        let mut spec = repr_model(&mut rng);
        if let LayerSpec::Conv { sign, .. } = &mut spec.layers[0] {
            *sign = false;
        }
        let mut buf = Vec::new();
        spec.write_to(&mut buf).unwrap();
        let err = ModelSpec::read_from(&mut buf.as_slice())
            .unwrap_err()
            .to_string();
        assert!(err.contains("binarizing tail"), "{err}");
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::new(122);
        let spec = sample_model(&mut rng);
        let path = std::env::temp_dir().join("espresso_fmt_test.esp");
        spec.save(&path).unwrap();
        let back = ModelSpec::load(&path).unwrap();
        assert_eq!(spec, back);
        let _ = std::fs::remove_file(&path);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn load_borrows_weights_without_heap_copy() {
        // the mmap acceptance probe: a current-version file lends every
        // weight tensor straight out of the mapping — zero copied bytes
        let mut rng = Rng::new(124);
        let spec = sample_model(&mut rng);
        let path = std::env::temp_dir().join("espresso_fmt_mmap_test.esp");
        spec.save(&path).unwrap();
        let (back, stats) = ModelSpec::load_with_stats(&path).unwrap();
        assert_eq!(spec, back);
        assert!(stats.mapped, "expected an mmap-backed load on Linux");
        assert_eq!(
            stats.weight_bytes_copied, 0,
            "v2 load must not heap-copy weight tensors: {stats:?}"
        );
        assert_eq!(stats.weight_bytes_borrowed, (16 * 9 * 3 + 640) * 4);
        for l in &back.layers {
            match l {
                LayerSpec::Dense { weights, .. } | LayerSpec::Conv { weights, .. } => {
                    assert!(weights.is_mapped(), "{weights:?} should borrow the mapping");
                }
                _ => {}
            }
        }
        // clones share the one mapping: cheap, no new heap weights
        let c = back.layers[0].clone();
        match &c {
            LayerSpec::Conv { weights, .. } => assert!(weights.is_mapped()),
            _ => unreachable!(),
        }
        drop(back);
        // the mapping outlives the drop order via the Arc in `c`
        match &c {
            LayerSpec::Conv { weights, .. } => assert_eq!(weights.len(), 16 * 9 * 3),
            _ => unreachable!(),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stream_read_takes_copy_fallback() {
        let mut rng = Rng::new(125);
        let spec = sample_model(&mut rng);
        let mut buf = Vec::new();
        spec.write_to(&mut buf).unwrap();
        let back = ModelSpec::read_from(&mut buf.as_slice()).unwrap();
        for l in &back.layers {
            if let LayerSpec::Dense { weights, .. } | LayerSpec::Conv { weights, .. } = l {
                assert!(!weights.is_mapped(), "stream reads must own their weights");
            }
        }
    }

    /// Hand-build a v1 (unpadded) file whose weight array lands on an
    /// odd offset: the reader must accept the old version and fall back
    /// to copying the misaligned tensor.
    fn v1_misaligned_dense() -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&1u32.to_le_bytes()); // version 1
        buf.extend_from_slice(&2u32.to_le_bytes()); // name len 2 → odd payload offset
        buf.extend_from_slice(b"m1");
        for v in [4u32, 1, 1] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf.push(1); // float input
        buf.extend_from_slice(&1u32.to_le_bytes()); // one layer
        buf.push(1); // dense tag
        buf.extend_from_slice(&4u32.to_le_bytes()); // in
        buf.extend_from_slice(&2u32.to_le_bytes()); // out
        buf.push(0); // flags: no bn, no sign
        buf.extend_from_slice(&8u32.to_le_bytes());
        for i in 0..8 {
            buf.extend_from_slice(&(i as f32).to_le_bytes());
        }
        buf
    }

    #[test]
    fn v1_files_still_load() {
        let buf = v1_misaligned_dense();
        let spec = ModelSpec::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(spec.name, "m1");
        match &spec.layers[0] {
            LayerSpec::Dense { weights, .. } => {
                assert_eq!(&weights[..], &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
            }
            other => panic!("expected dense, got {other:?}"),
        }

        // through the mmap loader: the misaligned v1 array must take
        // the copy fallback, not a misaligned borrow
        let path = std::env::temp_dir().join("espresso_fmt_v1_test.esp");
        std::fs::write(&path, &buf).unwrap();
        let (back, stats) = ModelSpec::load_with_stats(&path).unwrap();
        assert_eq!(back, spec);
        if stats.mapped {
            assert_eq!(stats.weight_bytes_copied, 8 * 4, "{stats:?}");
            match &back.layers[0] {
                LayerSpec::Dense { weights, .. } => assert!(!weights.is_mapped()),
                _ => unreachable!(),
            }
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_bad_magic() {
        let err = ModelSpec::read_from(&mut &b"NOPE\x01\x00\x00\x00"[..]).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");
    }

    #[test]
    fn rejects_future_version() {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&99u32.to_le_bytes());
        let err = ModelSpec::read_from(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("unsupported"), "{err}");
    }

    #[test]
    fn rejects_truncated_file() {
        let mut rng = Rng::new(123);
        let spec = sample_model(&mut rng);
        let mut buf = Vec::new();
        spec.write_to(&mut buf).unwrap();
        buf.truncate(buf.len() / 2);
        assert!(ModelSpec::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_weight_count_mismatch() {
        // hand-craft a dense layer whose weight array is short
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.push(b'x');
        for v in [1u32, 4, 1] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf.push(1); // float input
        buf.extend_from_slice(&1u32.to_le_bytes()); // one layer
        buf.push(1); // dense tag
        buf.extend_from_slice(&4u32.to_le_bytes()); // in
        buf.extend_from_slice(&2u32.to_le_bytes()); // out
        buf.push(0); // flags
        buf.extend_from_slice(&3u32.to_le_bytes()); // wrong: 3 weights not 8
        for _ in 0..3 {
            buf.extend_from_slice(&1.0f32.to_le_bytes());
        }
        let err = ModelSpec::read_from(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("mismatch"), "{err}");
    }

    // -----------------------------------------------------------------
    // v4 integrity trailer
    // -----------------------------------------------------------------

    /// Section end offsets of a v4 image, read back from its trailer.
    fn v4_section_ends(buf: &[u8]) -> Vec<usize> {
        let len = buf.len();
        assert_eq!(&buf[len - 4..], TRAILER_MAGIC);
        let rd = |off: usize| u32::from_le_bytes(buf[off..off + 4].try_into().unwrap()) as usize;
        let tstart = len - rd(len - 8);
        let n = rd(tstart);
        let mut ends = Vec::with_capacity(n);
        let mut off = 0;
        for i in 0..n {
            off += rd(tstart + 4 + 8 * i);
            ends.push(off);
        }
        ends
    }

    #[test]
    fn v4_writes_trailer_and_roundtrips() {
        let mut rng = Rng::new(130);
        let spec = repr_model(&mut rng);
        let mut buf = Vec::new();
        spec.write_to(&mut buf).unwrap();
        assert_eq!(u32::from_le_bytes(buf[4..8].try_into().unwrap()), 4);
        assert_eq!(&buf[buf.len() - 4..], TRAILER_MAGIC);
        // one section for the header plus one per layer, covering the body
        let ends = v4_section_ends(&buf);
        assert_eq!(ends.len(), 1 + spec.layers.len());
        let trailer_len = 8 * ends.len() + 16;
        assert_eq!(*ends.last().unwrap(), buf.len() - trailer_len);
        let back = ModelSpec::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(spec, back);
        // and through the file loader
        let path = std::env::temp_dir().join("espresso_fmt_v4_test.esp");
        spec.save(&path).unwrap();
        let loaded = ModelSpec::load(&path).unwrap();
        assert_eq!(spec, loaded);
        let _ = std::fs::remove_file(&path);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn v4_mmap_load_stays_zero_copy() {
        // verification reads the mapping once; parsing must still lend
        // weight tensors straight out of it
        let mut rng = Rng::new(131);
        let spec = sample_model(&mut rng);
        let path = std::env::temp_dir().join("espresso_fmt_v4_mmap_test.esp");
        spec.save(&path).unwrap();
        let (back, stats) = ModelSpec::load_with_stats(&path).unwrap();
        assert_eq!(spec, back);
        assert!(stats.mapped);
        assert_eq!(stats.weight_bytes_copied, 0, "{stats:?}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn v4_accepts_v3_files_without_trailer() {
        // the compat direction: a v3 writer's output still loads, and
        // carries no trailer to verify
        let mut rng = Rng::new(132);
        let spec = repr_model(&mut rng);
        let mut buf = Vec::new();
        spec.write_to_version(&mut buf, 3).unwrap();
        assert_eq!(u32::from_le_bytes(buf[4..8].try_into().unwrap()), 3);
        assert_ne!(&buf[buf.len() - 4..], TRAILER_MAGIC);
        let back = ModelSpec::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(spec, back);
        // file path too (exercises verification's pass-through on mmap)
        let path = std::env::temp_dir().join("espresso_fmt_v3_compat_test.esp");
        std::fs::write(&path, &buf).unwrap();
        assert_eq!(ModelSpec::load(&path).unwrap(), spec);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn v4_rejects_truncation_at_every_section_boundary() {
        let mut rng = Rng::new(133);
        let spec = sample_model(&mut rng);
        let mut buf = Vec::new();
        spec.write_to(&mut buf).unwrap();
        let mut cuts = v4_section_ends(&buf);
        // plus cuts inside the trailer itself and one mid-section
        cuts.extend([buf.len() - 1, buf.len() - 4, buf.len() - 9, 100]);
        for cut in cuts {
            let short = &buf[..cut];
            let err = ModelSpec::read_from(&mut &short[..]).unwrap_err();
            assert!(
                err.downcast_ref::<IntegrityError>().is_some(),
                "truncation to {cut} bytes must be an integrity reject, got: {err:#}"
            );
        }
    }

    #[test]
    fn v4_rejects_every_single_bit_flip() {
        let mut rng = Rng::new(134);
        let spec = sample_model(&mut rng);
        let mut buf = Vec::new();
        spec.write_to(&mut buf).unwrap();
        // sweep a sample of byte positions (every 7th) plus the trailer
        let len = buf.len();
        let mut positions: Vec<usize> = (0..len).step_by(7).collect();
        positions.extend(len - (8 * v4_section_ends(&buf).len() + 16)..len);
        for i in positions {
            let bit = 1u8 << (i % 8);
            buf[i] ^= bit;
            assert!(
                ModelSpec::read_from(&mut buf.as_slice()).is_err(),
                "bit flip at byte {i} must be rejected"
            );
            buf[i] ^= bit;
        }
        // the pristine buffer still loads — the sweep restored every byte
        assert_eq!(ModelSpec::read_from(&mut buf.as_slice()).unwrap(), spec);
    }

    #[test]
    fn v4_integrity_error_is_typed_for_metrics() {
        let mut rng = Rng::new(135);
        let spec = sample_model(&mut rng);
        let path = std::env::temp_dir().join("espresso_fmt_v4_typed_test.esp");
        spec.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[64] ^= 0x10; // flip a bit mid-header
        std::fs::write(&path, &bytes).unwrap();
        let err = ModelSpec::load(&path).unwrap_err();
        assert!(
            err.downcast_ref::<IntegrityError>().is_some(),
            "loader must surface a typed IntegrityError: {err:#}"
        );
        assert!(format!("{err:#}").contains("checksum mismatch"), "{err:#}");
        let _ = std::fs::remove_file(&path);
    }
}
