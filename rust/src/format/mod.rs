//! The `.esp` parameter-file format (paper §5.2 "Converting a network to
//! Espresso").
//!
//! A DNN is completely specified by its parameters file: layers are
//! stored sequentially with their storage format and weights. Training
//! happens elsewhere (the JAX straight-through-estimator trainer in
//! `python/compile/train.py`, standing in for BinaryNet); the exporter
//! (`python/compile/convert.py`) writes this format, and the Rust side
//! reads it once at load time — at which point weights are binarized,
//! bit-packed, BN folded to thresholds, and padding corrections
//! precomputed.
//!
//! Layout (all little-endian):
//! ```text
//! magic "ESP1" | version u32 | name (u32 len + utf8)
//! input: m,n,l u32×3 | kind u8 (0 = u8 pixels, 1 = f32)
//! layer count u32, then per layer a tag u8 + payload (see LayerSpec)
//! ```

pub mod sample;

use crate::layers::{BnParams, PoolSpec};
use crate::tensor::Shape;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

pub const MAGIC: &[u8; 4] = b"ESP1";
pub const FORMAT_VERSION: u32 = 1;

/// How the network's input is presented.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InputKind {
    /// 8-bit fixed-precision pixels (bit-plane eligible).
    Bytes = 0,
    /// Float input.
    Float = 1,
}

/// A serialized layer description.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerSpec {
    Dense {
        in_features: u32,
        out_features: u32,
        sign: bool,
        bitplane_first: bool,
        weights: Vec<f32>,
        bn: Option<BnSpec>,
    },
    Conv {
        in_channels: u32,
        filters: u32,
        kh: u32,
        kw: u32,
        stride: u32,
        pad: u32,
        sign: bool,
        /// Bit-plane-optimize a fixed-precision (first-layer) input.
        bitplane_first: bool,
        pool: Option<(u32, u32)>,
        weights: Vec<f32>,
        bn: Option<BnSpec>,
    },
    MaxPool {
        k: u32,
        stride: u32,
    },
    BatchNorm(BnSpec),
    Sign,
}

/// Serialized BatchNorm parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct BnSpec {
    pub eps: f32,
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
}

impl BnSpec {
    pub fn to_params(&self) -> BnParams {
        BnParams {
            gamma: self.gamma.clone(),
            beta: self.beta.clone(),
            mean: self.mean.clone(),
            var: self.var.clone(),
            eps: self.eps,
        }
    }

    pub fn from_params(p: &BnParams) -> Self {
        Self {
            eps: p.eps,
            gamma: p.gamma.clone(),
            beta: p.beta.clone(),
            mean: p.mean.clone(),
            var: p.var.clone(),
        }
    }
}

/// A complete serialized model.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub input_shape: Shape,
    pub input_kind: InputKind,
    pub layers: Vec<LayerSpec>,
}

impl LayerSpec {
    /// Pool geometry helper.
    pub fn pool_spec(k: u32, stride: u32) -> PoolSpec {
        PoolSpec {
            k: k as usize,
            stride: stride as usize,
        }
    }
}

// ---------------------------------------------------------------------
// primitive writers/readers
// ---------------------------------------------------------------------

fn w_u32<W: Write>(w: &mut W, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_f32<W: Write>(w: &mut W, v: f32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_u8<W: Write>(w: &mut W, v: u8) -> Result<()> {
    w.write_all(&[v])?;
    Ok(())
}

fn w_f32s<W: Write>(w: &mut W, vs: &[f32]) -> Result<()> {
    w_u32(w, vs.len() as u32)?;
    // bulk write: reinterpret as LE bytes
    let mut buf = Vec::with_capacity(vs.len() * 4);
    for v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

fn w_str<W: Write>(w: &mut W, s: &str) -> Result<()> {
    w_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn r_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn r_f32<R: Read>(r: &mut R) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

fn r_u8<R: Read>(r: &mut R) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

const MAX_ELEMS: u32 = 1 << 28; // 1 GiB of f32s — sanity bound on corrupt files

fn r_f32s<R: Read>(r: &mut R) -> Result<Vec<f32>> {
    let n = r_u32(r)?;
    if n > MAX_ELEMS {
        bail!("array length {n} exceeds sanity bound (corrupt file?)");
    }
    let mut buf = vec![0u8; n as usize * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn r_str<R: Read>(r: &mut R) -> Result<String> {
    let n = r_u32(r)?;
    if n > 1 << 16 {
        bail!("string length {n} exceeds sanity bound");
    }
    let mut buf = vec![0u8; n as usize];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf).context("model name not utf8")
}

fn w_bn<W: Write>(w: &mut W, bn: &BnSpec) -> Result<()> {
    w_f32(w, bn.eps)?;
    w_f32s(w, &bn.gamma)?;
    w_f32s(w, &bn.beta)?;
    w_f32s(w, &bn.mean)?;
    w_f32s(w, &bn.var)?;
    Ok(())
}

fn r_bn<R: Read>(r: &mut R) -> Result<BnSpec> {
    Ok(BnSpec {
        eps: r_f32(r)?,
        gamma: r_f32s(r)?,
        beta: r_f32s(r)?,
        mean: r_f32s(r)?,
        var: r_f32s(r)?,
    })
}

// ---------------------------------------------------------------------
// model ser/de
// ---------------------------------------------------------------------

impl ModelSpec {
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(MAGIC)?;
        w_u32(w, FORMAT_VERSION)?;
        w_str(w, &self.name)?;
        w_u32(w, self.input_shape.m as u32)?;
        w_u32(w, self.input_shape.n as u32)?;
        w_u32(w, self.input_shape.l as u32)?;
        w_u8(w, self.input_kind as u8)?;
        w_u32(w, self.layers.len() as u32)?;
        for layer in &self.layers {
            match layer {
                LayerSpec::Dense {
                    in_features,
                    out_features,
                    sign,
                    bitplane_first,
                    weights,
                    bn,
                } => {
                    w_u8(w, 1)?;
                    w_u32(w, *in_features)?;
                    w_u32(w, *out_features)?;
                    let flags = u8::from(*sign)
                        | (u8::from(bn.is_some()) << 1)
                        | (u8::from(*bitplane_first) << 2);
                    w_u8(w, flags)?;
                    w_f32s(w, weights)?;
                    if let Some(b) = bn {
                        w_bn(w, b)?;
                    }
                }
                LayerSpec::Conv {
                    in_channels,
                    filters,
                    kh,
                    kw,
                    stride,
                    pad,
                    sign,
                    bitplane_first,
                    pool,
                    weights,
                    bn,
                } => {
                    w_u8(w, 2)?;
                    for v in [in_channels, filters, kh, kw, stride, pad] {
                        w_u32(w, *v)?;
                    }
                    let flags = u8::from(*sign)
                        | (u8::from(bn.is_some()) << 1)
                        | (u8::from(pool.is_some()) << 2)
                        | (u8::from(*bitplane_first) << 3);
                    w_u8(w, flags)?;
                    if let Some((pk, ps)) = pool {
                        w_u32(w, *pk)?;
                        w_u32(w, *ps)?;
                    }
                    w_f32s(w, weights)?;
                    if let Some(b) = bn {
                        w_bn(w, b)?;
                    }
                }
                LayerSpec::MaxPool { k, stride } => {
                    w_u8(w, 3)?;
                    w_u32(w, *k)?;
                    w_u32(w, *stride)?;
                }
                LayerSpec::BatchNorm(bn) => {
                    w_u8(w, 4)?;
                    w_bn(w, bn)?;
                }
                LayerSpec::Sign => w_u8(w, 5)?,
            }
        }
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not an .esp file (bad magic {magic:?})");
        }
        let version = r_u32(r)?;
        if version != FORMAT_VERSION {
            bail!("unsupported .esp version {version}");
        }
        let name = r_str(r)?;
        let input_shape = Shape::new(r_u32(r)? as usize, r_u32(r)? as usize, r_u32(r)? as usize);
        let input_kind = match r_u8(r)? {
            0 => InputKind::Bytes,
            1 => InputKind::Float,
            k => bail!("unknown input kind {k}"),
        };
        let n_layers = r_u32(r)?;
        if n_layers > 10_000 {
            bail!("layer count {n_layers} exceeds sanity bound");
        }
        let mut layers = Vec::with_capacity(n_layers as usize);
        for i in 0..n_layers {
            let tag = r_u8(r).with_context(|| format!("layer {i} tag"))?;
            let layer = match tag {
                1 => {
                    let in_features = r_u32(r)?;
                    let out_features = r_u32(r)?;
                    let flags = r_u8(r)?;
                    let weights = r_f32s(r)?;
                    if weights.len() != (in_features * out_features) as usize {
                        bail!("dense layer {i}: weight count mismatch");
                    }
                    let bn = if flags & 2 != 0 { Some(r_bn(r)?) } else { None };
                    LayerSpec::Dense {
                        in_features,
                        out_features,
                        sign: flags & 1 != 0,
                        bitplane_first: flags & 4 != 0,
                        weights,
                        bn,
                    }
                }
                2 => {
                    let in_channels = r_u32(r)?;
                    let filters = r_u32(r)?;
                    let kh = r_u32(r)?;
                    let kw = r_u32(r)?;
                    let stride = r_u32(r)?;
                    let pad = r_u32(r)?;
                    let flags = r_u8(r)?;
                    let pool = if flags & 4 != 0 {
                        Some((r_u32(r)?, r_u32(r)?))
                    } else {
                        None
                    };
                    let weights = r_f32s(r)?;
                    if weights.len() != (filters * kh * kw * in_channels) as usize {
                        bail!("conv layer {i}: weight count mismatch");
                    }
                    let bn = if flags & 2 != 0 { Some(r_bn(r)?) } else { None };
                    LayerSpec::Conv {
                        in_channels,
                        filters,
                        kh,
                        kw,
                        stride,
                        pad,
                        sign: flags & 1 != 0,
                        bitplane_first: flags & 8 != 0,
                        pool,
                        weights,
                        bn,
                    }
                }
                3 => LayerSpec::MaxPool {
                    k: r_u32(r)?,
                    stride: r_u32(r)?,
                },
                4 => LayerSpec::BatchNorm(r_bn(r)?),
                5 => LayerSpec::Sign,
                t => bail!("unknown layer tag {t} at layer {i}"),
            };
            layers.push(layer);
        }
        Ok(Self {
            name,
            input_shape,
            input_kind,
            layers,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        self.write_to(&mut f)
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        Self::read_from(&mut f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_bn(rng: &mut Rng, f: usize) -> BnSpec {
        BnSpec {
            eps: 1e-4,
            gamma: (0..f).map(|_| rng.f32_range(0.1, 2.0)).collect(),
            beta: (0..f).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
            mean: (0..f).map(|_| rng.f32_range(-3.0, 3.0)).collect(),
            var: (0..f).map(|_| rng.f32_range(0.2, 4.0)).collect(),
        }
    }

    fn sample_model(rng: &mut Rng) -> ModelSpec {
        ModelSpec {
            name: "unit-test-model".into(),
            input_shape: Shape::new(8, 8, 3),
            input_kind: InputKind::Bytes,
            layers: vec![
                LayerSpec::Conv {
                    in_channels: 3,
                    filters: 16,
                    kh: 3,
                    kw: 3,
                    stride: 1,
                    pad: 1,
                    sign: true,
                    bitplane_first: true,
                    pool: Some((2, 2)),
                    weights: rng.signs(16 * 9 * 3),
                    bn: Some(sample_bn(rng, 16)),
                },
                LayerSpec::MaxPool { k: 2, stride: 2 },
                LayerSpec::Sign,
                LayerSpec::Dense {
                    in_features: 64,
                    out_features: 10,
                    sign: false,
                    bitplane_first: false,
                    weights: rng.signs(640),
                    bn: Some(sample_bn(rng, 10)),
                },
                LayerSpec::BatchNorm(sample_bn(rng, 10)),
            ],
        }
    }

    #[test]
    fn roundtrip_preserves_model() {
        let mut rng = Rng::new(121);
        let spec = sample_model(&mut rng);
        let mut buf = Vec::new();
        spec.write_to(&mut buf).unwrap();
        let back = ModelSpec::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::new(122);
        let spec = sample_model(&mut rng);
        let path = std::env::temp_dir().join("espresso_fmt_test.esp");
        spec.save(&path).unwrap();
        let back = ModelSpec::load(&path).unwrap();
        assert_eq!(spec, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_bad_magic() {
        let err = ModelSpec::read_from(&mut &b"NOPE\x01\x00\x00\x00"[..]).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");
    }

    #[test]
    fn rejects_truncated_file() {
        let mut rng = Rng::new(123);
        let spec = sample_model(&mut rng);
        let mut buf = Vec::new();
        spec.write_to(&mut buf).unwrap();
        buf.truncate(buf.len() / 2);
        assert!(ModelSpec::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_weight_count_mismatch() {
        // hand-craft a dense layer whose weight array is short
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.push(b'x');
        for v in [1u32, 4, 1] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf.push(1); // float input
        buf.extend_from_slice(&1u32.to_le_bytes()); // one layer
        buf.push(1); // dense tag
        buf.extend_from_slice(&4u32.to_le_bytes()); // in
        buf.extend_from_slice(&2u32.to_le_bytes()); // out
        buf.push(0); // flags
        buf.extend_from_slice(&3u32.to_le_bytes()); // wrong: 3 weights not 8
        for _ in 0..3 {
            buf.extend_from_slice(&1.0f32.to_le_bytes());
        }
        let err = ModelSpec::read_from(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("mismatch"), "{err}");
    }
}
