//! Bit-packed tensors (paper §5.1, "GPU^opt" tensor variant).
//!
//! Packing direction follows the paper: when `L > 1` bits pack along the
//! channel dimension `l` (each pixel owns a whole number of words —
//! `lw = ceil(L/64)` — so convolution unrolling copies contiguous word
//! groups); when `L == 1` bits pack along `n` (dense activations are row
//! vectors whose width shrinks through the network).

use super::{Shape, Tensor};
use crate::bitpack::{pack_signs_into, unpack_signs, words_for, Word};

/// Which logical dimension the bits are packed along.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackDir {
    /// Pack along `l` (used when `L > 1`; pixel-major word groups).
    Channels,
    /// Pack along `n` (used when `L == 1`; row-major packed rows).
    Cols,
}

/// A bit-packed ±1 tensor. Generic over word width `W` (u64 / u32).
#[derive(Clone, Debug, PartialEq)]
pub struct BitTensor<W: Word = u64> {
    pub shape: Shape,
    pub dir: PackDir,
    /// Words per packed group (per pixel for `Channels`, per row for `Cols`).
    pub group_words: usize,
    pub data: Vec<W>,
}

impl<W: Word> BitTensor<W> {
    /// Paper rule: channels when L>1, else columns.
    pub fn natural_dir(shape: Shape) -> PackDir {
        if shape.l > 1 {
            PackDir::Channels
        } else {
            PackDir::Cols
        }
    }

    /// Binarize (sign) and pack a float tensor using the natural direction.
    pub fn from_tensor(t: &Tensor<f32>) -> Self {
        Self::from_tensor_dir(t, Self::natural_dir(t.shape))
    }

    /// Binarize (sign) and pack with an explicit direction.
    pub fn from_tensor_dir(t: &Tensor<f32>, dir: PackDir) -> Self {
        let shape = t.shape;
        match dir {
            PackDir::Channels => {
                let lw = words_for::<W>(shape.l);
                let groups = shape.m * shape.n;
                let mut data = vec![W::ZERO; groups * lw];
                for m in 0..shape.m {
                    for n in 0..shape.n {
                        let g = m * shape.n + n;
                        pack_signs_into(t.pixel(m, n), &mut data[g * lw..(g + 1) * lw]);
                    }
                }
                Self {
                    shape,
                    dir,
                    group_words: lw,
                    data,
                }
            }
            PackDir::Cols => {
                assert_eq!(shape.l, 1, "Cols packing requires L == 1");
                let nw = words_for::<W>(shape.n);
                let mut data = vec![W::ZERO; shape.m * nw];
                for m in 0..shape.m {
                    let base = m * shape.n;
                    pack_signs_into(
                        &t.data[base..base + shape.n],
                        &mut data[m * nw..(m + 1) * nw],
                    );
                }
                Self {
                    shape,
                    dir,
                    group_words: nw,
                    data,
                }
            }
        }
    }

    /// Unpack to a ±1 float tensor (inverse of `from_tensor` up to sign
    /// binarization).
    pub fn to_tensor(&self) -> Tensor<f32> {
        let s = self.shape;
        let mut out = Tensor::zeros(s);
        match self.dir {
            PackDir::Channels => {
                for m in 0..s.m {
                    for n in 0..s.n {
                        let vals = unpack_signs(self.pixel(m, n), s.l);
                        let base = (m * s.n + n) * s.l;
                        out.data[base..base + s.l].copy_from_slice(&vals);
                    }
                }
            }
            PackDir::Cols => {
                for m in 0..s.m {
                    let vals = unpack_signs(self.row(m), s.n);
                    out.data[m * s.n..(m + 1) * s.n].copy_from_slice(&vals);
                }
            }
        }
        out
    }

    /// Packed channel group of pixel `(m, n)` (`Channels` mode).
    #[inline(always)]
    pub fn pixel(&self, m: usize, n: usize) -> &[W] {
        debug_assert_eq!(self.dir, PackDir::Channels);
        let g = m * self.shape.n + n;
        &self.data[g * self.group_words..(g + 1) * self.group_words]
    }

    /// Packed row `m` (`Cols` mode).
    #[inline(always)]
    pub fn row(&self, m: usize) -> &[W] {
        debug_assert_eq!(self.dir, PackDir::Cols);
        &self.data[m * self.group_words..(m + 1) * self.group_words]
    }

    /// Flatten to a packed row vector (shape `1 × len × 1`, `Cols`
    /// packing) — the conv→dense transition.
    ///
    /// Fast path: when every packed group is exactly full (`L` a multiple
    /// of the word width for `Channels`, `N` a multiple for `Cols`), the
    /// words are already the flat packed vector in `(m, n, l)` order and
    /// no bit shuffling happens — this is the layout dividend of §5.1.
    /// Otherwise falls back to unpack + repack.
    pub fn flatten(self) -> BitTensor<W> {
        let len = self.shape.len();
        let full_groups = match self.dir {
            PackDir::Channels => self.shape.l % W::BITS == 0,
            // a single Cols row is already a flat packed vector
            PackDir::Cols => self.shape.n % W::BITS == 0 || self.shape.m == 1,
        };
        if full_groups {
            return BitTensor {
                shape: Shape::vector(len),
                dir: PackDir::Cols,
                group_words: self.data.len(),
                data: self.data,
            };
        }
        let t = self.to_tensor();
        BitTensor::from_tensor(&t.flatten())
    }

    /// Bytes of packed storage (the paper's ≈31-32× memory-saving claim
    /// is `float_bytes() / packed_bytes()`).
    pub fn packed_bytes(&self) -> usize {
        self.data.len() * (W::BITS / 8)
    }

    /// Bytes the same tensor would occupy as f32.
    pub fn float_bytes(&self) -> usize {
        self.shape.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_tensor(rng: &mut Rng, s: Shape) -> Tensor<f32> {
        let mut data = vec![0f32; s.len()];
        rng.fill_signs(&mut data);
        Tensor::from_vec(s, data)
    }

    #[test]
    fn natural_dir_rule() {
        assert_eq!(
            BitTensor::<u64>::natural_dir(Shape::new(4, 4, 3)),
            PackDir::Channels
        );
        assert_eq!(
            BitTensor::<u64>::natural_dir(Shape::new(1, 100, 1)),
            PackDir::Cols
        );
    }

    #[test]
    fn roundtrip_channels_u64() {
        let mut rng = Rng::new(51);
        for s in [Shape::new(3, 3, 4), Shape::new(5, 7, 65), Shape::new(2, 2, 128)] {
            let t = random_tensor(&mut rng, s);
            let bt = BitTensor::<u64>::from_tensor(&t);
            assert_eq!(bt.dir, PackDir::Channels);
            assert_eq!(bt.to_tensor(), t, "shape {s}");
        }
    }

    #[test]
    fn roundtrip_cols_u64() {
        let mut rng = Rng::new(52);
        for s in [Shape::vector(10), Shape::new(4, 100, 1), Shape::new(1, 65, 1)] {
            let t = random_tensor(&mut rng, s);
            let bt = BitTensor::<u64>::from_tensor(&t);
            assert_eq!(bt.dir, PackDir::Cols);
            assert_eq!(bt.to_tensor(), t, "shape {s}");
        }
    }

    #[test]
    fn roundtrip_u32() {
        let mut rng = Rng::new(53);
        let t = random_tensor(&mut rng, Shape::new(3, 4, 33));
        let bt = BitTensor::<u32>::from_tensor(&t);
        assert_eq!(bt.group_words, 2); // 33 bits -> 2 u32 words
        assert_eq!(bt.to_tensor(), t);
    }

    #[test]
    fn binarizes_non_pm_one_input() {
        let t = Tensor::from_vec(Shape::vector(4), vec![0.3, -2.0, 0.0, -0.1]);
        let bt = BitTensor::<u64>::from_tensor(&t);
        assert_eq!(bt.to_tensor().data, vec![1.0, -1.0, 1.0, -1.0]);
    }

    #[test]
    fn memory_saving_ratio() {
        // 128-channel tensor: 128 f32 bytes per pixel vs 2 u64 words
        let t = Tensor::zeros(Shape::new(8, 8, 128));
        let bt = BitTensor::<u64>::from_tensor(&t);
        assert_eq!(bt.float_bytes() / bt.packed_bytes(), 32);
    }

    #[test]
    fn pixel_group_is_word_aligned() {
        let mut rng = Rng::new(54);
        let t = random_tensor(&mut rng, Shape::new(2, 3, 70)); // 70 bits -> 2 words
        let bt = BitTensor::<u64>::from_tensor(&t);
        assert_eq!(bt.group_words, 2);
        assert_eq!(bt.data.len(), 2 * 3 * 2);
        // each pixel's packed group decodes to that pixel's channels
        for m in 0..2 {
            for n in 0..3 {
                let vals = unpack_signs(bt.pixel(m, n), 70);
                assert_eq!(&vals[..], t.pixel(m, n));
            }
        }
    }
}
