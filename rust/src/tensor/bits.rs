//! Bit-packed tensors (paper §5.1, "GPU^opt" tensor variant), with a
//! batch axis.
//!
//! Packing direction follows the paper: when `L > 1` bits pack along the
//! channel dimension `l` (each pixel owns a whole number of words —
//! `lw = ceil(L/64)` — so convolution unrolling copies contiguous word
//! groups); when `L == 1` bits pack along `n` (dense activations are row
//! vectors whose width shrinks through the network).
//!
//! **Batch axis.** Like [`Tensor`], a `BitTensor` holds `batch` stacked
//! images of one per-image `shape`; packed images are contiguous word
//! blocks in `data`. Under `Channels` packing the group of pixel
//! `(b, m, n)` starts at word `((b·M + m)·N + n)·lw`; under `Cols`
//! packing row `(b, m)` starts at `(b·M + m)·nw`. Because the float
//! layout stacks images contiguously too, batch-aware packing is simply
//! "more groups": the packers below walk `data.chunks(l)` (or rows) and
//! are batch-agnostic by construction.

use super::{Shape, Tensor};
use crate::bitpack::{pack_matrix_rows, pack_signs_into, unpack_signs, words_for, Word};

/// Which logical dimension the bits are packed along.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackDir {
    /// Pack along `l` (used when `L > 1`; pixel-major word groups).
    Channels,
    /// Pack along `n` (used when `L == 1`; row-major packed rows).
    Cols,
}

/// A bit-packed ±1 tensor. Generic over word width `W` (u64 / u32).
#[derive(Clone, Debug, PartialEq)]
pub struct BitTensor<W: Word = u64> {
    /// Per-image shape (batch axis is separate).
    pub shape: Shape,
    /// Number of stacked images.
    pub batch: usize,
    pub dir: PackDir,
    /// Words per packed group (per pixel for `Channels`, per row for `Cols`).
    pub group_words: usize,
    pub data: Vec<W>,
}

impl<W: Word> BitTensor<W> {
    /// Paper rule: channels when L>1, else columns.
    pub fn natural_dir(shape: Shape) -> PackDir {
        if shape.l > 1 {
            PackDir::Channels
        } else {
            PackDir::Cols
        }
    }

    /// Binarize (sign) and pack a float tensor using the natural direction.
    pub fn from_tensor(t: &Tensor<f32>) -> Self {
        Self::from_tensor_dir(t, Self::natural_dir(t.shape))
    }

    /// Binarize (sign) and pack with an explicit direction. Batch-aware:
    /// every image of `t` is packed into a contiguous word block.
    pub fn from_tensor_dir(t: &Tensor<f32>, dir: PackDir) -> Self {
        let shape = t.shape;
        let batch = t.batch;
        match dir {
            PackDir::Channels => {
                let lw = words_for::<W>(shape.l);
                let groups = batch * shape.m * shape.n;
                let mut data = vec![W::ZERO; groups * lw];
                for (g, px) in t.data.chunks(shape.l).enumerate() {
                    pack_signs_into(px, &mut data[g * lw..(g + 1) * lw]);
                }
                Self {
                    shape,
                    batch,
                    dir,
                    group_words: lw,
                    data,
                }
            }
            PackDir::Cols => {
                assert_eq!(shape.l, 1, "Cols packing requires L == 1");
                let nw = words_for::<W>(shape.n);
                let rows = batch * shape.m;
                let mut data = vec![W::ZERO; rows * nw];
                for (r, row) in t.data.chunks(shape.n).enumerate() {
                    pack_signs_into(row, &mut data[r * nw..(r + 1) * nw]);
                }
                Self {
                    shape,
                    batch,
                    dir,
                    group_words: nw,
                    data,
                }
            }
        }
    }

    /// Unpack to a ±1 float tensor (inverse of `from_tensor` up to sign
    /// binarization). Preserves the batch axis.
    pub fn to_tensor(&self) -> Tensor<f32> {
        let s = self.shape;
        let gw = self.group_words;
        let mut out = Vec::with_capacity(self.batch * s.len());
        match self.dir {
            PackDir::Channels => {
                let groups = self.batch * s.m * s.n;
                for g in 0..groups {
                    out.extend_from_slice(&unpack_signs(
                        &self.data[g * gw..(g + 1) * gw],
                        s.l,
                    ));
                }
            }
            PackDir::Cols => {
                let rows = self.batch * s.m;
                for r in 0..rows {
                    out.extend_from_slice(&unpack_signs(
                        &self.data[r * gw..(r + 1) * gw],
                        s.n,
                    ));
                }
            }
        }
        Tensor::from_stacked(self.batch, s, out)
    }

    /// Packed channel group of pixel `(m, n)` of image 0 (`Channels`).
    #[inline(always)]
    pub fn pixel(&self, m: usize, n: usize) -> &[W] {
        self.pixel_at(0, m, n)
    }

    /// Packed channel group of pixel `(m, n)` of image `b` (`Channels`).
    #[inline(always)]
    pub fn pixel_at(&self, b: usize, m: usize, n: usize) -> &[W] {
        debug_assert_eq!(self.dir, PackDir::Channels);
        let g = (b * self.shape.m + m) * self.shape.n + n;
        &self.data[g * self.group_words..(g + 1) * self.group_words]
    }

    /// Packed row `m` of image 0 (`Cols` mode).
    #[inline(always)]
    pub fn row(&self, m: usize) -> &[W] {
        debug_assert_eq!(self.dir, PackDir::Cols);
        &self.data[m * self.group_words..(m + 1) * self.group_words]
    }

    /// Flatten to packed row vectors — the conv→dense transition. The
    /// result is `Cols`-packed with shape `batch × len × 1` and
    /// `batch = 1` (each former image becomes one packed row, the row
    /// convention dense layers consume).
    ///
    /// Fast path: when every packed group is exactly full (`L` a multiple
    /// of the word width for `Channels`, `N` a multiple for `Cols`), the
    /// words are already the flat packed vectors in `(b, m, n, l)` order
    /// and no bit shuffling happens — this is the layout dividend of
    /// §5.1. Otherwise falls back to unpack + repack.
    pub fn flatten(self) -> BitTensor<W> {
        let len = self.shape.len();
        let batch = self.batch;
        let full_groups = match self.dir {
            PackDir::Channels => self.shape.l % W::BITS == 0,
            // a single Cols row per image is already a flat packed vector
            PackDir::Cols => self.shape.n % W::BITS == 0 || self.shape.m == 1,
        };
        let rows_shape = Shape {
            m: batch,
            n: len,
            l: 1,
        };
        if full_groups {
            let per_image = self.data.len() / batch;
            debug_assert_eq!(per_image, words_for::<W>(len));
            return BitTensor {
                shape: rows_shape,
                batch: 1,
                dir: PackDir::Cols,
                group_words: per_image,
                data: self.data,
            };
        }
        let t = self.to_tensor();
        let data = pack_matrix_rows::<W>(&t.data, batch, len);
        BitTensor {
            shape: rows_shape,
            batch: 1,
            dir: PackDir::Cols,
            group_words: words_for::<W>(len),
            data,
        }
    }

    /// Bytes of packed storage (the paper's ≈31-32× memory-saving claim
    /// is `float_bytes() / packed_bytes()`).
    pub fn packed_bytes(&self) -> usize {
        self.data.len() * (W::BITS / 8)
    }

    /// Bytes the same tensor would occupy as f32.
    pub fn float_bytes(&self) -> usize {
        self.batch * self.shape.len() * 4
    }

    /// Number of packed groups (pixels under `Channels`, rows under
    /// `Cols`) across the whole batch.
    pub fn groups(&self) -> usize {
        self.data.len() / self.group_words
    }
}

/// XNOR-Net scaled binary tensor: ±1 sign bits plus one positive scale
/// per packed group — per pixel under `Channels` packing (the conv
/// activation form, A = mean over channels of |y|), per row under `Cols`
/// (the dense form, one scale per image row). The carried value of an
/// element is `scale[group] · sign`.
#[derive(Clone, Debug, PartialEq)]
pub struct ScaledBitTensor<W: Word = u64> {
    pub bits: BitTensor<W>,
    /// One scale per packed group; `scale.len() == bits.groups()`.
    pub scale: Vec<f32>,
}

impl<W: Word> ScaledBitTensor<W> {
    /// Binarize a float tensor XNOR-Net style: per-group scale
    /// A = mean |x|, bits = sign(x).
    pub fn from_tensor(t: &Tensor<f32>) -> Self {
        let bits = BitTensor::from_tensor(t);
        let group = match bits.dir {
            PackDir::Channels => t.shape.l,
            PackDir::Cols => t.shape.n,
        };
        let scale = t
            .data
            .chunks(group)
            .map(|g| g.iter().map(|v| v.abs()).sum::<f32>() / group as f32)
            .collect();
        Self { bits, scale }
    }

    /// Dequantize to floats: `scale[group] · sign`.
    pub fn to_tensor(&self) -> Tensor<f32> {
        let mut t = self.bits.to_tensor();
        let group = t.data.len() / self.scale.len();
        for (g, chunk) in t.data.chunks_mut(group).enumerate() {
            for v in chunk.iter_mut() {
                *v *= self.scale[g];
            }
        }
        t
    }

    /// Bytes of packed storage (words + the scale vector).
    pub fn packed_bytes(&self) -> usize {
        self.bits.packed_bytes() + self.scale.len() * 4
    }
}

/// Multi-bit thermometer-plane tensor (BMXNet-style): `P` stacked ±1
/// bit-planes over one quantization step Δ. Plane `t`'s bit is
/// `x ≥ Δ·t_t` for ascending level thresholds `t_t`; with `u` the number
/// of set planes, the carried value is `Δ·(a·u + b)` where `(a, b)` are
/// the symmetric-level coefficients of [`QuantTensor::coeffs`]. Two
/// planes encode ternary `Δ·{-1, 0, 1}`; three planes encode the 2-bit
/// levels `Δ·{-3, -1, 1, 3}`. Symmetry makes the per-plane ±1 GEMMs sum
/// exactly to the quantized dot product (the rowsum term vanishes), so
/// the existing packed kernels run unchanged, once per plane.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantTensor<W: Word = u64> {
    /// Thermometer planes, lowest threshold first; all share
    /// shape / batch / dir / group_words.
    pub planes: Vec<BitTensor<W>>,
    /// Quantization step Δ (> 0).
    pub delta: f32,
}

impl<W: Word> QuantTensor<W> {
    /// Level-value coefficients `(a, b)`: value = Δ·(a·u + b) for `u` set
    /// planes. Defined so the levels are symmetric around zero, which is
    /// what lets plane GEMMs combine without a rowsum correction.
    pub fn coeffs(planes: usize) -> (i32, i32) {
        match planes {
            2 => (1, -1),
            3 => (2, -3),
            p => panic!("unsupported plane count {p}"),
        }
    }

    /// Plane thresholds in multiples of Δ, ascending.
    pub fn level_thresholds(planes: usize) -> &'static [f32] {
        match planes {
            2 => &[-0.5, 0.5],
            3 => &[-2.0, 0.0, 2.0],
            p => panic!("unsupported plane count {p}"),
        }
    }

    /// Quantize a float tensor onto `planes` thermometer planes.
    pub fn from_tensor(t: &Tensor<f32>, delta: f32, planes: usize) -> Self {
        assert!(delta > 0.0, "quantization step must be positive");
        let planes = Self::level_thresholds(planes)
            .iter()
            .map(|&thr| {
                let shifted = Tensor::from_stacked(
                    t.batch,
                    t.shape,
                    t.data.iter().map(|&v| v - delta * thr).collect(),
                );
                BitTensor::from_tensor(&shifted)
            })
            .collect();
        Self { planes, delta }
    }

    /// The activation kind this tensor carries.
    pub fn kind(&self) -> crate::layers::ActKind {
        match self.planes.len() {
            3 => crate::layers::ActKind::Bits2,
            _ => crate::layers::ActKind::Ternary,
        }
    }

    pub fn shape(&self) -> Shape {
        self.planes[0].shape
    }

    pub fn batch(&self) -> usize {
        self.planes[0].batch
    }

    /// Dequantize to floats: Δ·(a·u + b).
    pub fn to_tensor(&self) -> Tensor<f32> {
        let (a, b) = Self::coeffs(self.planes.len());
        let unpacked: Vec<Tensor<f32>> = self.planes.iter().map(|p| p.to_tensor()).collect();
        let mut out = unpacked[0].clone();
        for v in out.data.iter_mut() {
            *v = 0.0;
        }
        for p in &unpacked {
            for (o, &s) in out.data.iter_mut().zip(&p.data) {
                // each plane contributes (s+1)/2 ∈ {0,1} to u
                *o += (s + 1.0) * 0.5;
            }
        }
        for v in out.data.iter_mut() {
            *v = self.delta * (a * (*v as i32) + b) as f32;
        }
        out
    }

    /// Bytes of packed storage across all planes.
    pub fn packed_bytes(&self) -> usize {
        self.planes.iter().map(|p| p.packed_bytes()).sum::<usize>() + 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_tensor(rng: &mut Rng, s: Shape) -> Tensor<f32> {
        let mut data = vec![0f32; s.len()];
        rng.fill_signs(&mut data);
        Tensor::from_vec(s, data)
    }

    #[test]
    fn natural_dir_rule() {
        assert_eq!(
            BitTensor::<u64>::natural_dir(Shape::new(4, 4, 3)),
            PackDir::Channels
        );
        assert_eq!(
            BitTensor::<u64>::natural_dir(Shape::new(1, 100, 1)),
            PackDir::Cols
        );
    }

    #[test]
    fn roundtrip_channels_u64() {
        let mut rng = Rng::new(51);
        for s in [Shape::new(3, 3, 4), Shape::new(5, 7, 65), Shape::new(2, 2, 128)] {
            let t = random_tensor(&mut rng, s);
            let bt = BitTensor::<u64>::from_tensor(&t);
            assert_eq!(bt.dir, PackDir::Channels);
            assert_eq!(bt.to_tensor(), t, "shape {s}");
        }
    }

    #[test]
    fn roundtrip_cols_u64() {
        let mut rng = Rng::new(52);
        for s in [Shape::vector(10), Shape::new(4, 100, 1), Shape::new(1, 65, 1)] {
            let t = random_tensor(&mut rng, s);
            let bt = BitTensor::<u64>::from_tensor(&t);
            assert_eq!(bt.dir, PackDir::Cols);
            assert_eq!(bt.to_tensor(), t, "shape {s}");
        }
    }

    #[test]
    fn roundtrip_u32() {
        let mut rng = Rng::new(53);
        let t = random_tensor(&mut rng, Shape::new(3, 4, 33));
        let bt = BitTensor::<u32>::from_tensor(&t);
        assert_eq!(bt.group_words, 2); // 33 bits -> 2 u32 words
        assert_eq!(bt.to_tensor(), t);
    }

    #[test]
    fn binarizes_non_pm_one_input() {
        let t = Tensor::from_vec(Shape::vector(4), vec![0.3, -2.0, 0.0, -0.1]);
        let bt = BitTensor::<u64>::from_tensor(&t);
        assert_eq!(bt.to_tensor().data, vec![1.0, -1.0, 1.0, -1.0]);
    }

    #[test]
    fn memory_saving_ratio() {
        // 128-channel tensor: 128 f32 bytes per pixel vs 2 u64 words
        let t = Tensor::zeros(Shape::new(8, 8, 128));
        let bt = BitTensor::<u64>::from_tensor(&t);
        assert_eq!(bt.float_bytes() / bt.packed_bytes(), 32);
    }

    #[test]
    fn pixel_group_is_word_aligned() {
        let mut rng = Rng::new(54);
        let t = random_tensor(&mut rng, Shape::new(2, 3, 70)); // 70 bits -> 2 words
        let bt = BitTensor::<u64>::from_tensor(&t);
        assert_eq!(bt.group_words, 2);
        assert_eq!(bt.data.len(), 2 * 3 * 2);
        // each pixel's packed group decodes to that pixel's channels
        for m in 0..2 {
            for n in 0..3 {
                let vals = unpack_signs(bt.pixel(m, n), 70);
                assert_eq!(&vals[..], t.pixel(m, n));
            }
        }
    }

    #[test]
    fn batched_pack_equals_per_image_pack() {
        let mut rng = Rng::new(55);
        for s in [Shape::new(3, 3, 5), Shape::new(2, 4, 64), Shape::new(4, 4, 1)] {
            let imgs: Vec<Tensor<f32>> =
                (0..3).map(|_| random_tensor(&mut rng, s)).collect();
            let refs: Vec<&Tensor<f32>> = imgs.iter().collect();
            let stacked = Tensor::stack(&refs);
            let bt = BitTensor::<u64>::from_tensor(&stacked);
            assert_eq!(bt.batch, 3);
            // the packed block of image b equals packing image b alone
            let per = bt.data.len() / 3;
            for (b, img) in imgs.iter().enumerate() {
                let single = BitTensor::<u64>::from_tensor(img);
                assert_eq!(
                    &bt.data[b * per..(b + 1) * per],
                    &single.data[..],
                    "image {b} shape {s}"
                );
            }
            // and the roundtrip preserves the stacked data
            assert_eq!(bt.to_tensor(), stacked, "shape {s}");
        }
    }

    #[test]
    fn batched_pixel_at_addresses_images() {
        let mut rng = Rng::new(56);
        let s = Shape::new(2, 2, 70);
        let imgs: Vec<Tensor<f32>> = (0..2).map(|_| random_tensor(&mut rng, s)).collect();
        let refs: Vec<&Tensor<f32>> = imgs.iter().collect();
        let bt = BitTensor::<u64>::from_tensor(&Tensor::stack(&refs));
        for (b, img) in imgs.iter().enumerate() {
            for m in 0..2 {
                for n in 0..2 {
                    let vals = unpack_signs(bt.pixel_at(b, m, n), 70);
                    assert_eq!(&vals[..], img.pixel(m, n), "b={b} m={m} n={n}");
                }
            }
        }
    }

    #[test]
    fn batched_flatten_gives_row_per_image() {
        let mut rng = Rng::new(57);
        // one word-aligned case (fast path) and one ragged case (repack)
        for s in [Shape::new(2, 2, 64), Shape::new(3, 3, 5)] {
            let imgs: Vec<Tensor<f32>> =
                (0..4).map(|_| random_tensor(&mut rng, s)).collect();
            let refs: Vec<&Tensor<f32>> = imgs.iter().collect();
            let flat = BitTensor::<u64>::from_tensor(&Tensor::stack(&refs)).flatten();
            assert_eq!(flat.dir, PackDir::Cols);
            assert_eq!(flat.batch, 1);
            assert_eq!(flat.shape, Shape::new(4, s.len(), 1));
            assert_eq!(flat.group_words, words_for::<u64>(s.len()));
            let un = flat.to_tensor();
            for (b, img) in imgs.iter().enumerate() {
                let signs: Vec<f32> = img
                    .data
                    .iter()
                    .map(|&x| if x >= 0.0 { 1.0 } else { -1.0 })
                    .collect();
                assert_eq!(
                    &un.data[b * s.len()..(b + 1) * s.len()],
                    &signs[..],
                    "image {b} shape {s}"
                );
            }
        }
    }
}
