//! Tensors with the paper's memory layout (§5.1), extended with a batch
//! axis.
//!
//! A tensor `A ∈ R^{M×N×L}` is stored row-major with **interleaved
//! channels**: element `(m, n, l)` lives at `(m·N + n)·L + l`. This makes
//! a pixel's channel vector contiguous, which is what lets convolution
//! unrolling gather neighborhoods with plain memcpys and lets the lifted
//! GEMM output *already be* the output tensor (zero-cost lift, Fig. 1).
//!
//! **Batch axis.** A [`Tensor`] carries `batch` stacked images of the same
//! per-image [`Shape`]: element `(b, m, n, l)` lives at
//! `b·M·N·L + (m·N + n)·L + l`, i.e. images are contiguous blocks in
//! `data`. `shape` always describes ONE image; `data.len() == batch *
//! shape.len()`. Single-image code never has to care: every constructor
//! defaults `batch = 1` and image-0 accessors (`at`, `pixel`) behave as
//! before. The batched CNN forward path stacks B images here, unrolls all
//! of them into one `(B·oh·ow) × k` matrix, and issues a single GEMM per
//! layer — the batching dividend the serving coordinator exploits.

pub mod bits;
pub mod unroll;

pub use bits::{BitTensor, PackDir, QuantTensor, ScaledBitTensor};
pub use unroll::{
    out_dim, pack_filters, unroll_bits, unroll_bits_rows, unroll_f32, unroll_f32_rows,
    unroll_u8, unroll_u8_rows, unrolled_cols,
};

/// Logical per-image tensor dimensions: `m` rows, `n` cols, `l` channels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shape {
    pub m: usize,
    pub n: usize,
    pub l: usize,
}

impl Shape {
    pub fn new(m: usize, n: usize, l: usize) -> Self {
        Self { m, n, l }
    }

    /// Total element count (of one image).
    pub fn len(&self) -> usize {
        self.m * self.n * self.l
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flat offset of `(m, n, l)` under the interleaved-channel layout.
    #[inline(always)]
    pub fn offset(&self, m: usize, n: usize, l: usize) -> usize {
        debug_assert!(m < self.m && n < self.n && l < self.l);
        (m * self.n + n) * self.l + l
    }

    /// A flat vector shape `1×n×1` (dense-layer activations).
    pub fn vector(n: usize) -> Self {
        Self { m: 1, n, l: 1 }
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.m, self.n, self.l)
    }
}

/// Dense tensor over an arbitrary element type (`f32` activations,
/// `u8` fixed-precision inputs, `i32` accumulators), holding `batch`
/// stacked images of identical per-image `shape`.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T = f32> {
    /// Per-image shape (the batch axis is NOT part of `shape`).
    pub shape: Shape,
    /// Number of stacked images; `data.len() == batch * shape.len()`.
    pub batch: usize,
    pub data: Vec<T>,
}

impl<T: Clone + Default> Tensor<T> {
    pub fn zeros(shape: Shape) -> Self {
        Self {
            data: vec![T::default(); shape.len()],
            batch: 1,
            shape,
        }
    }

    pub fn from_vec(shape: Shape, data: Vec<T>) -> Self {
        assert_eq!(data.len(), shape.len(), "shape/data mismatch");
        Self {
            shape,
            batch: 1,
            data,
        }
    }

    /// Build a batched tensor from pre-stacked data
    /// (`data.len() == batch * shape.len()`).
    pub fn from_stacked(batch: usize, shape: Shape, data: Vec<T>) -> Self {
        assert!(batch > 0, "batch must be positive");
        assert_eq!(data.len(), batch * shape.len(), "shape/data mismatch");
        Self { shape, batch, data }
    }

    /// Stack single-image tensors along a new batch axis. All images must
    /// share one element count; the first image's shape is used.
    pub fn stack(imgs: &[&Tensor<T>]) -> Self {
        assert!(!imgs.is_empty(), "cannot stack zero images");
        let shape = imgs[0].shape;
        let mut data = Vec::with_capacity(imgs.len() * shape.len());
        for img in imgs {
            assert_eq!(img.batch, 1, "stack expects single-image tensors");
            assert_eq!(img.shape.len(), shape.len(), "stack: image sizes differ");
            data.extend_from_slice(&img.data);
        }
        Self {
            shape,
            batch: imgs.len(),
            data,
        }
    }

    /// Element count of one image.
    #[inline(always)]
    pub fn image_len(&self) -> usize {
        self.shape.len()
    }

    /// Contiguous data block of image `b`.
    #[inline(always)]
    pub fn image(&self, b: usize) -> &[T] {
        let len = self.shape.len();
        &self.data[b * len..(b + 1) * len]
    }

    #[inline(always)]
    pub fn at(&self, m: usize, n: usize, l: usize) -> &T {
        &self.data[self.shape.offset(m, n, l)]
    }

    #[inline(always)]
    pub fn at_mut(&mut self, m: usize, n: usize, l: usize) -> &mut T {
        let off = self.shape.offset(m, n, l);
        &mut self.data[off]
    }

    /// Contiguous channel slice of pixel `(m, n)` of image 0 — `A_{m,n,:}`.
    #[inline(always)]
    pub fn pixel(&self, m: usize, n: usize) -> &[T] {
        self.pixel_at(0, m, n)
    }

    /// Contiguous channel slice of pixel `(m, n)` of image `b`.
    #[inline(always)]
    pub fn pixel_at(&self, b: usize, m: usize, n: usize) -> &[T] {
        let base = (b * self.shape.m * self.shape.n + m * self.shape.n + n) * self.shape.l;
        &self.data[base..base + self.shape.l]
    }

    /// Reinterpret each image as a flat vector (dense-layer view); the
    /// batch axis is preserved.
    pub fn flatten(self) -> Tensor<T> {
        let n = self.shape.len();
        Tensor {
            shape: Shape::vector(n),
            batch: self.batch,
            data: self.data,
        }
    }
}

impl Tensor<f32> {
    /// Elementwise sign binarization to a ±1 float tensor (Eq. 1).
    pub fn signum(&self) -> Tensor<f32> {
        Tensor {
            shape: self.shape,
            batch: self.batch,
            data: self
                .data
                .iter()
                .map(|&x| if x >= 0.0 { 1.0 } else { -1.0 })
                .collect(),
        }
    }
}

impl Tensor<u8> {
    /// Widen fixed-precision input to float (for the float comparator
    /// engines; the binary engine consumes bit-planes instead).
    pub fn to_f32(&self) -> Tensor<f32> {
        Tensor {
            shape: self.shape,
            batch: self.batch,
            data: self.data.iter().map(|&x| x as f32).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_channel_interleaved() {
        let s = Shape::new(2, 3, 4);
        assert_eq!(s.offset(0, 0, 0), 0);
        assert_eq!(s.offset(0, 0, 3), 3);
        assert_eq!(s.offset(0, 1, 0), 4);
        assert_eq!(s.offset(1, 0, 0), 12);
        assert_eq!(s.offset(1, 2, 3), 23);
        assert_eq!(s.len(), 24);
    }

    #[test]
    fn pixel_slice_is_contiguous_channels() {
        let s = Shape::new(2, 2, 3);
        let t = Tensor::from_vec(s, (0..12).map(|x| x as f32).collect());
        assert_eq!(t.pixel(1, 0), &[6.0, 7.0, 8.0]);
        assert_eq!(*t.at(1, 0, 2), 8.0);
    }

    #[test]
    fn signum_maps_zero_to_plus_one() {
        let t = Tensor::from_vec(Shape::vector(3), vec![0.0, -0.1, 2.0]);
        assert_eq!(t.signum().data, vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn flatten_preserves_data() {
        let t = Tensor::from_vec(Shape::new(2, 2, 2), (0..8).map(|x| x as f32).collect());
        let f = t.clone().flatten();
        assert_eq!(f.shape, Shape::vector(8));
        assert_eq!(f.data, t.data);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_validates() {
        let _ = Tensor::<f32>::from_vec(Shape::new(2, 2, 1), vec![0.0; 3]);
    }

    #[test]
    fn stack_concatenates_images() {
        let s = Shape::new(1, 2, 2);
        let a = Tensor::from_vec(s, vec![0.0, 1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(s, vec![4.0, 5.0, 6.0, 7.0]);
        let st = Tensor::stack(&[&a, &b]);
        assert_eq!(st.batch, 2);
        assert_eq!(st.shape, s);
        assert_eq!(st.image(0), &a.data[..]);
        assert_eq!(st.image(1), &b.data[..]);
        assert_eq!(st.pixel_at(1, 0, 1), &[6.0, 7.0]);
    }

    #[test]
    fn batched_flatten_keeps_batch() {
        let s = Shape::new(2, 1, 2);
        let t = Tensor::from_stacked(3, s, (0..12).map(|x| x as f32).collect());
        let f = t.flatten();
        assert_eq!(f.batch, 3);
        assert_eq!(f.shape, Shape::vector(4));
        assert_eq!(f.image(2), &[8.0, 9.0, 10.0, 11.0]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_stacked_validates() {
        let _ = Tensor::<f32>::from_stacked(2, Shape::vector(3), vec![0.0; 5]);
    }
}
