//! Convolution unrolling / lifting (paper §5.2, Fig. 1), batch-aware.
//!
//! 2D convolution is computed as a GEMM over the *unrolled* input: each
//! output pixel contributes one row holding the flattened `kh×kw×L`
//! sliding volume. Because tensors are channel-interleaved and the packed
//! variant packs along `l`, each tap's channel block is contiguous (one
//! memcpy per tap for floats, one word-group copy for bits), and the GEMM
//! output — rows of output pixels × filter columns — already *is* the
//! output tensor in channel-interleaved layout, so lifting is free.
//!
//! **Batching.** All three unrollers consume the input tensor's `batch`
//! axis: image `b`'s patch rows land in the contiguous row block
//! `[b·oh·ow, (b+1)·oh·ow)` of `out`, so a batch of B images unrolls into
//! one `(B·oh·ow) × k` matrix and the whole batch flows through a single
//! GEMM against the shared packed filters — this is where dynamic
//! batching turns from bookkeeping into kernel-level reuse (§5.2's
//! amortized weight sweeps). Windows never cross image boundaries.
//!
//! Binary padding semantics: out-of-bounds taps are left as all-zero
//! words, i.e. −1 under the bit encoding. The convolution layer fixes the
//! difference to true zero-padding with the paper's precomputed
//! correction matrix (§5.2 "Zero-padding for convolutions"), applied
//! per image.
//!
//! **Tile streaming.** The patch matrix is *virtual*: the `*_rows`
//! variants emit an arbitrary row slice `[row0, row1)` of it — global row
//! `r` is tap window `(oy, ox)` of image `b = r / (oh·ow)` — so the fused
//! convolution path can stream L2-resident panels straight into the GEMM
//! micro-kernel without ever materializing the whole `(B·oh·ow) × k`
//! matrix. The full unrollers below are thin `[0, total)` wrappers and
//! remain the oracle the tile emitters are property-tested against.

use super::{BitTensor, PackDir, Shape, Tensor};
use crate::bitpack::{pack_signs_into, words_for, Word};

/// Output spatial size for one dimension.
pub fn out_dim(size: usize, k: usize, stride: usize, pad: usize) -> usize {
    assert!(stride > 0);
    assert!(size + 2 * pad >= k, "kernel larger than padded input");
    (size + 2 * pad - k) / stride + 1
}

/// Geometry of one image's unrolled matrix: (`rows`, `k_cols`) where
/// `rows = oh·ow` and `k_cols = kh·kw·L`. A batched unroll produces
/// `batch · rows` rows.
pub fn unrolled_cols(shape: Shape, kh: usize, kw: usize, stride: usize, pad: usize) -> (usize, usize) {
    let oh = out_dim(shape.m, kh, stride, pad);
    let ow = out_dim(shape.n, kw, stride, pad);
    (oh * ow, kh * kw * shape.l)
}

/// Core tile emitter: write rows `[row0, row1)` of the virtual batched
/// patch matrix, generic over the element type. `data` is the stacked
/// image data (`batch · s.len()` elements); row `r` covers tap window
/// `(oy, ox) = (r' / ow, r' % ow)` of image `b = r / (oh·ow)` with
/// `r' = r % (oh·ow)`, so tile boundaries may fall anywhere, including
/// mid-image.
#[inline]
#[allow(clippy::too_many_arguments)]
fn unroll_rows_generic<T: Copy + Default>(
    data: &[T],
    batch: usize,
    s: Shape,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    row0: usize,
    row1: usize,
    out: &mut [T],
) {
    let oh = out_dim(s.m, kh, stride, pad);
    let ow = out_dim(s.n, kw, stride, pad);
    let rows_img = oh * ow;
    let l = s.l;
    let k = kh * kw * l;
    let img_len = s.len();
    assert!(row0 <= row1 && row1 <= batch * rows_img, "row slice bounds");
    assert_eq!(out.len(), (row1 - row0) * k, "tile buffer size");
    for (ri, r) in (row0..row1).enumerate() {
        let b = r / rows_img;
        let rr = r % rows_img;
        let (oy, ox) = (rr / ow, rr % ow);
        let img = &data[b * img_len..(b + 1) * img_len];
        let row = &mut out[ri * k..(ri + 1) * k];
        let mut c = 0usize;
        for ky in 0..kh {
            let iy = (oy * stride + ky) as isize - pad as isize;
            for kx in 0..kw {
                let ix = (ox * stride + kx) as isize - pad as isize;
                let dst = &mut row[c..c + l];
                if iy >= 0 && (iy as usize) < s.m && ix >= 0 && (ix as usize) < s.n {
                    let base = (iy as usize * s.n + ix as usize) * l;
                    dst.copy_from_slice(&img[base..base + l]);
                } else {
                    dst.fill(T::default());
                }
                c += l;
            }
        }
    }
}

/// Float tile unroller: rows `[row0, row1)` of the virtual zero-padded
/// `(batch·oh·ow) × k` patch matrix into `out`. Handles padding, stride
/// and batch-image boundaries; windows never cross images.
#[allow(clippy::too_many_arguments)]
pub fn unroll_f32_rows(
    t: &Tensor<f32>,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    row0: usize,
    row1: usize,
    out: &mut [f32],
) {
    unroll_rows_generic(&t.data, t.batch, t.shape, kh, kw, stride, pad, row0, row1, out);
}

/// u8 tile unroller (first-layer bit-plane conv path: pixel value 0 in
/// the padding is exact in the integer domain). See [`unroll_f32_rows`].
#[allow(clippy::too_many_arguments)]
pub fn unroll_u8_rows(
    t: &Tensor<u8>,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    row0: usize,
    row1: usize,
    out: &mut [u8],
) {
    unroll_rows_generic(&t.data, t.batch, t.shape, kh, kw, stride, pad, row0, row1, out);
}

/// Float im2col with zero padding. Consumes the tensor's batch axis:
/// returns a row-major `(batch·rows) × k` matrix in `out`.
pub fn unroll_f32(
    t: &Tensor<f32>,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    out: &mut [f32],
) {
    let (rows, _) = unrolled_cols(t.shape, kh, kw, stride, pad);
    unroll_f32_rows(t, kh, kw, stride, pad, 0, t.batch * rows, out);
}

/// u8 im2col with zero padding (first-layer bit-plane conv path). Batch-
/// aware like [`unroll_f32`].
pub fn unroll_u8(
    t: &Tensor<u8>,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    out: &mut [u8],
) {
    let (rows, _) = unrolled_cols(t.shape, kh, kw, stride, pad);
    unroll_u8_rows(t, kh, kw, stride, pad, 0, t.batch * rows, out);
}

/// Packed binary unroll. Input must be channel-packed. Each output row is
/// `kh·kw` word-groups of `lw` words; OOB taps stay all-zero (−1).
/// Consumes the batch axis: image `b` fills rows `[b·oh·ow, (b+1)·oh·ow)`.
///
/// Returns `(total_rows, row_words)` with `total_rows = batch·oh·ow`;
/// caller derives logical `k = kh·kw·L` for the GEMM's bit count —
/// intra-group padding bits are zero in both the unrolled activations and
/// the packed filters, so they never mismatch.
pub fn unroll_bits<W: Word>(
    bt: &BitTensor<W>,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    out: &mut [W],
) -> (usize, usize) {
    let oh = out_dim(bt.shape.m, kh, stride, pad);
    let ow = out_dim(bt.shape.n, kw, stride, pad);
    let total = bt.batch * oh * ow;
    let row_words = unroll_bits_rows(bt, kh, kw, stride, pad, 0, total, out);
    (total, row_words)
}

/// Packed tile unroller: word rows `[row0, row1)` of the virtual patch
/// matrix (same row geometry as [`unroll_f32_rows`], `row_words = kh·kw·
/// lw` words per row). OOB taps stay all-zero (−1); returns `row_words`.
#[allow(clippy::too_many_arguments)]
pub fn unroll_bits_rows<W: Word>(
    bt: &BitTensor<W>,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    row0: usize,
    row1: usize,
    out: &mut [W],
) -> usize {
    assert_eq!(bt.dir, PackDir::Channels, "binary unroll needs channel packing");
    let s = bt.shape;
    let lw = bt.group_words;
    let oh = out_dim(s.m, kh, stride, pad);
    let ow = out_dim(s.n, kw, stride, pad);
    let rows_img = oh * ow;
    let row_words = kh * kw * lw;
    assert!(row0 <= row1 && row1 <= bt.batch * rows_img, "row slice bounds");
    assert_eq!(out.len(), (row1 - row0) * row_words, "tile buffer size");
    for (ri, r) in (row0..row1).enumerate() {
        let b = r / rows_img;
        let rr = r % rows_img;
        let (oy, ox) = (rr / ow, rr % ow);
        let row = &mut out[ri * row_words..(ri + 1) * row_words];
        let mut c = 0usize;
        for ky in 0..kh {
            let iy = (oy * stride + ky) as isize - pad as isize;
            for kx in 0..kw {
                let ix = (ox * stride + kx) as isize - pad as isize;
                let dst = &mut row[c..c + lw];
                if iy >= 0 && (iy as usize) < s.m && ix >= 0 && (ix as usize) < s.n {
                    dst.copy_from_slice(bt.pixel_at(b, iy as usize, ix as usize));
                } else {
                    for w in dst.iter_mut() {
                        *w = W::ZERO; // −1 padding; corrected by the layer
                    }
                }
                c += lw;
            }
        }
    }
    row_words
}

/// Pack `f` conv filters (float, layout `[f][ky][kx][l]`, values ±1-ish)
/// into the word layout `unroll_bits` produces: per filter, `kh·kw`
/// groups of `lw = ceil(L/W::BITS)` words.
pub fn pack_filters<W: Word>(
    weights: &[f32],
    f: usize,
    kh: usize,
    kw: usize,
    l: usize,
) -> Vec<W> {
    assert_eq!(weights.len(), f * kh * kw * l);
    let lw = words_for::<W>(l);
    let row_words = kh * kw * lw;
    let mut out = vec![W::ZERO; f * row_words];
    for fi in 0..f {
        for t in 0..kh * kw {
            let src = &weights[(fi * kh * kw + t) * l..(fi * kh * kw + t + 1) * l];
            let dst = &mut out[fi * row_words + t * lw..fi * row_words + (t + 1) * lw];
            pack_signs_into(src, dst);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitpack;
    use crate::util::rng::Rng;

    fn random_pm1(rng: &mut Rng, s: Shape) -> Tensor<f32> {
        let mut d = vec![0f32; s.len()];
        rng.fill_signs(&mut d);
        Tensor::from_vec(s, d)
    }

    /// Direct (non-unrolled) float convolution with zero padding; oracle.
    fn conv_direct(
        t: &Tensor<f32>,
        w: &[f32],
        f: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
    ) -> Vec<f32> {
        let s = t.shape;
        let oh = out_dim(s.m, kh, stride, pad);
        let ow = out_dim(s.n, kw, stride, pad);
        let mut out = vec![0f32; oh * ow * f];
        for oy in 0..oh {
            for ox in 0..ow {
                for fi in 0..f {
                    let mut acc = 0f32;
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if iy < 0 || iy as usize >= s.m || ix < 0 || ix as usize >= s.n {
                                continue; // zero pad
                            }
                            for c in 0..s.l {
                                acc += t.at(iy as usize, ix as usize, c)
                                    * w[((fi * kh + ky) * kw + kx) * s.l + c];
                            }
                        }
                    }
                    out[(oy * ow + ox) * f + fi] = acc;
                }
            }
        }
        out
    }

    #[test]
    fn out_dim_formula() {
        assert_eq!(out_dim(32, 3, 1, 1), 32); // "same"
        assert_eq!(out_dim(32, 3, 1, 0), 30); // "valid"
        assert_eq!(out_dim(32, 2, 2, 0), 16); // pool-like
        assert_eq!(out_dim(5, 5, 1, 0), 1);
    }

    #[test]
    fn float_unroll_gemm_equals_direct_conv() {
        let mut rng = Rng::new(61);
        for &(m, n, l, f, k, pad) in &[
            (6usize, 6usize, 3usize, 4usize, 3usize, 1usize),
            (8, 5, 2, 3, 3, 0),
            (4, 4, 1, 2, 2, 1),
        ] {
            let t = random_pm1(&mut rng, Shape::new(m, n, l));
            let w = rng.signs(f * k * k * l);
            let (rows, kc) = unrolled_cols(t.shape, k, k, 1, pad);
            let mut unrolled = vec![0f32; rows * kc];
            unroll_f32(&t, k, k, 1, pad, &mut unrolled);
            // GEMM: rows × f with filters as B rows of length kc
            let got = crate::linalg::sgemm(&unrolled, &w, rows, f, kc);
            let want = conv_direct(&t, &w, f, k, k, 1, pad);
            for (g, wv) in got.iter().zip(&want) {
                assert!((g - wv).abs() < 1e-3, "{g} vs {wv}");
            }
        }
    }

    #[test]
    fn batched_unroll_equals_per_image_unroll() {
        let mut rng = Rng::new(65);
        for &(m, n, l, k, stride, pad) in &[
            (6usize, 6usize, 3usize, 3usize, 1usize, 1usize),
            (7, 5, 2, 3, 2, 1),
            (5, 5, 4, 2, 1, 0),
        ] {
            let s = Shape::new(m, n, l);
            let imgs: Vec<Tensor<f32>> = (0..3).map(|_| random_pm1(&mut rng, s)).collect();
            let refs: Vec<&Tensor<f32>> = imgs.iter().collect();
            let stacked = Tensor::stack(&refs);
            let (rows, kc) = unrolled_cols(s, k, k, stride, pad);
            // float
            let mut batched = vec![0f32; 3 * rows * kc];
            unroll_f32(&stacked, k, k, stride, pad, &mut batched);
            for (b, img) in imgs.iter().enumerate() {
                let mut single = vec![0f32; rows * kc];
                unroll_f32(img, k, k, stride, pad, &mut single);
                assert_eq!(
                    &batched[b * rows * kc..(b + 1) * rows * kc],
                    &single[..],
                    "float image {b}"
                );
            }
            // bits
            let bstacked = BitTensor::<u64>::from_tensor_dir(&stacked, PackDir::Channels);
            let lw = bstacked.group_words;
            let row_words = k * k * lw;
            let mut bbatched = vec![0u64; 3 * rows * row_words];
            let (total, rw) = unroll_bits(&bstacked, k, k, stride, pad, &mut bbatched);
            assert_eq!(total, 3 * rows);
            assert_eq!(rw, row_words);
            for (b, img) in imgs.iter().enumerate() {
                let bimg = BitTensor::<u64>::from_tensor_dir(img, PackDir::Channels);
                let mut bsingle = vec![0u64; rows * row_words];
                unroll_bits(&bimg, k, k, stride, pad, &mut bsingle);
                assert_eq!(
                    &bbatched[b * rows * row_words..(b + 1) * rows * row_words],
                    &bsingle[..],
                    "bits image {b}"
                );
            }
        }
    }

    #[test]
    fn binary_unroll_matches_float_unroll_without_padding() {
        // pad=0: no −1-vs-0 divergence, binary GEMM must equal float conv.
        let mut rng = Rng::new(62);
        let (m, n, l, f, k) = (7, 6, 5, 3, 3);
        let t = random_pm1(&mut rng, Shape::new(m, n, l));
        let w = rng.signs(f * k * k * l);
        let bt = BitTensor::<u64>::from_tensor(&t);
        let lw = bt.group_words;
        let (rows, kc) = unrolled_cols(t.shape, k, k, 1, 0);
        let mut packed = vec![0u64; rows * k * k * lw];
        let (rows2, row_words) = unroll_bits(&bt, k, k, 1, 0, &mut packed);
        assert_eq!(rows, rows2);
        let pf = pack_filters::<u64>(&w, f, k, k, l);
        assert_eq!(pf.len(), f * row_words);
        // logical bit count per row: kc real bits; padded group bits are 0
        // on both sides so they contribute no mismatches — but the `K -
        // 2·mis` formula must use the *real* K.
        let got_i32 = binary_conv_gemm(&packed, &pf, rows, f, row_words, kc);
        let want = conv_direct(&t, &w, f, k, k, 1, 0);
        for (g, wv) in got_i32.iter().zip(&want) {
            assert_eq!(*g, *wv as i32);
        }
    }

    /// GEMM over unrolled word rows with explicit row_words (groups may
    /// include padding bits; mismatches are unaffected).
    fn binary_conv_gemm(
        a: &[u64],
        b: &[u64],
        rows: usize,
        f: usize,
        row_words: usize,
        k_bits: usize,
    ) -> Vec<i32> {
        let mut out = vec![0i32; rows * f];
        for r in 0..rows {
            for j in 0..f {
                let mis = bitpack::mismatches(
                    &a[r * row_words..(r + 1) * row_words],
                    &b[j * row_words..(j + 1) * row_words],
                );
                out[r * f + j] = k_bits as i32 - 2 * mis as i32;
            }
        }
        out
    }

    /// Tile emitters must reproduce the matching slice of the full unroll
    /// for ANY `[row0, row1)` — including slices that start and end
    /// mid-image — on random geometries: u64 + u32 packing, B > 1,
    /// pad > 0, asymmetric kernels, stride up to 3.
    #[test]
    fn prop_tile_unrollers_match_full_unroll() {
        use crate::util::prop::check_simple;
        check_simple(
            "tile-unroll-equals-full",
            40,
            66,
            |r| r.next_u64(),
            |&seed| {
                let mut rng = Rng::new(seed);
                let s = Shape::new(4 + rng.below(5), 4 + rng.below(5), 1 + rng.below(70));
                let (kh, kw) = (1 + rng.below(3), 1 + rng.below(3));
                let stride = 1 + rng.below(3);
                let pad = rng.below(2); // covers pad = 0 and pad = 1
                let batch = 2 + rng.below(3);
                let imgs: Vec<Tensor<f32>> =
                    (0..batch).map(|_| random_pm1(&mut rng, s)).collect();
                let refs: Vec<&Tensor<f32>> = imgs.iter().collect();
                let t = Tensor::stack(&refs);
                let (rows_img, k) = unrolled_cols(s, kh, kw, stride, pad);
                let total = batch * rows_img;
                // random slice, biased to cross an image boundary
                let row0 = rng.below(total);
                let row1 = row0 + 1 + rng.below(total - row0);
                // float
                let mut full = vec![0f32; total * k];
                unroll_f32(&t, kh, kw, stride, pad, &mut full);
                let mut tile = vec![0f32; (row1 - row0) * k];
                unroll_f32_rows(&t, kh, kw, stride, pad, row0, row1, &mut tile);
                if tile != full[row0 * k..row1 * k] {
                    return false;
                }
                // u8
                let tu = Tensor::from_stacked(
                    batch,
                    s,
                    t.data.iter().map(|&x| if x >= 0.0 { 7u8 } else { 3u8 }).collect(),
                );
                let mut full8 = vec![0u8; total * k];
                unroll_u8(&tu, kh, kw, stride, pad, &mut full8);
                let mut tile8 = vec![0u8; (row1 - row0) * k];
                unroll_u8_rows(&tu, kh, kw, stride, pad, row0, row1, &mut tile8);
                if tile8 != full8[row0 * k..row1 * k] {
                    return false;
                }
                // bits, both word widths
                let b64 = BitTensor::<u64>::from_tensor_dir(&t, PackDir::Channels);
                let rw64 = kh * kw * b64.group_words;
                let mut fullb = vec![0u64; total * rw64];
                unroll_bits(&b64, kh, kw, stride, pad, &mut fullb);
                let mut tileb = vec![0u64; (row1 - row0) * rw64];
                let rw = unroll_bits_rows(&b64, kh, kw, stride, pad, row0, row1, &mut tileb);
                if rw != rw64 || tileb != fullb[row0 * rw64..row1 * rw64] {
                    return false;
                }
                let b32 = BitTensor::<u32>::from_tensor_dir(&t, PackDir::Channels);
                let rw32 = kh * kw * b32.group_words;
                let mut fullb32 = vec![0u32; total * rw32];
                unroll_bits(&b32, kh, kw, stride, pad, &mut fullb32);
                let mut tileb32 = vec![0u32; (row1 - row0) * rw32];
                unroll_bits_rows(&b32, kh, kw, stride, pad, row0, row1, &mut tileb32);
                tileb32 == fullb32[row0 * rw32..row1 * rw32]
            },
        );
    }

    #[test]
    fn binary_unroll_oob_taps_are_minus_one() {
        let mut rng = Rng::new(63);
        let (m, n, l) = (3, 3, 2);
        let t = random_pm1(&mut rng, Shape::new(m, n, l));
        let bt = BitTensor::<u64>::from_tensor(&t);
        let (rows, _) = unrolled_cols(t.shape, 3, 3, 1, 1);
        let mut packed = vec![0u64; rows * 9 * bt.group_words];
        unroll_bits(&bt, 3, 3, 1, 1, &mut packed);
        // corner output (0,0): taps (ky,kx) with iy or ix < 0 must be zero
        let row0 = &packed[0..9 * bt.group_words];
        for (tap, grp) in row0.chunks(bt.group_words).enumerate() {
            let (ky, kx) = (tap / 3, tap % 3);
            if ky == 0 || kx == 0 {
                assert!(grp.iter().all(|&w| w == 0), "tap {tap} should be padding");
            }
        }
    }

    #[test]
    fn lift_is_identity_on_layout() {
        // The GEMM output (rows of output pixels × filter channels) must
        // already be channel-interleaved: position (oy,ox,f) at
        // (oy*ow+ox)*F + f. conv_direct writes exactly that layout; the
        // gemm in float_unroll test produced it too — check the two index
        // schemes coincide on a known impulse.
        let s = Shape::new(3, 3, 1);
        let mut t = Tensor::zeros(s);
        *t.at_mut(1, 1, 0) = 1.0; // impulse at center, rest 0
        let f = 2;
        let k = 3;
        // filter 0 = all ones, filter 1 = identity at center tap
        let mut w = vec![0f32; f * k * k];
        for v in w[..9].iter_mut() {
            *v = 1.0;
        }
        w[9 + 4] = 1.0;
        let (rows, kc) = unrolled_cols(s, k, k, 1, 1);
        let mut u = vec![0f32; rows * kc];
        unroll_f32(&t, k, k, 1, 1, &mut u);
        let out = crate::linalg::sgemm(&u, &w, rows, f, kc);
        let out_t = Tensor::from_vec(Shape::new(3, 3, f), out);
        // filter-1 response reproduces the impulse
        assert_eq!(*out_t.at(1, 1, 1), 1.0);
        assert_eq!(*out_t.at(0, 0, 1), 0.0);
        // filter-0 response at center = 1 (sum over impulse)
        assert_eq!(*out_t.at(1, 1, 0), 1.0);
    }
}
