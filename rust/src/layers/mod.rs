//! Network layers (paper §5.2).
//!
//! Every layer implements both execution variants of the paper's hybrid
//! design: a **float** path (the `CPU`/`GPU` comparator — same binary
//! network, ±1 values held in f32) and a **binary-optimized** path
//! (`GPU^opt` analogue — packed activations, XNOR-popcount GEMMs, folded
//! BatchNorm thresholds). Activations flow between layers as [`Act`]
//! values; conversions are explicit and cheap, which is what enables
//! mixed-backend ("hybrid") networks.
//!
//! The `.esp` loader emits *fused* Dense/Conv blocks (GEMM + optional
//! pool + BatchNorm + sign in one layer) — the form the binary engine
//! wants; standalone [`pool::MaxPoolLayer`], [`norm::BatchNormLayer`] and
//! [`norm::SignLayer`] are also provided for hand-built networks.

pub mod conv;
pub mod dense;
pub mod norm;
pub mod pool;

pub use conv::ConvLayer;
pub use dense::DenseLayer;
pub use norm::{BatchNormLayer, SignLayer};
pub use pool::MaxPoolLayer;

use crate::alloc::Workspace;
use crate::bitpack::Word;
use crate::tensor::{BitTensor, QuantTensor, ScaledBitTensor, Shape, Tensor};

/// Which execution variant a layer runs under (paper's {CPU|GPU} float vs
/// GPU^opt binary split; the XLA engine lives in `runtime`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Float representation of the binary net (comparator).
    Float,
    /// Bit-packed XNOR-popcount path.
    Binary,
}

/// The *representation* of an activation, without its data — what the
/// ahead-of-time [`crate::net::plan::ForwardPlan`] builder reasons about
/// when it resolves layer boundaries (a Binary→Binary boundary stays
/// packed; Float interludes exist only where the plan says so).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActKind {
    /// Fixed-precision 8-bit input (first layer only).
    Bytes,
    /// Float activations.
    Float,
    /// Bit-packed ±1 activations.
    Bits,
    /// XNOR-Net scaled binary: ±1 bits plus one positive scale per
    /// packed group (per pixel / per row).
    ScaledBits,
    /// 2-bit thermometer planes (3 planes, levels Δ·{-3,-1,1,3}).
    Bits2,
    /// Ternary thermometer planes (2 planes, levels Δ·{-1,0,1}).
    Ternary,
}

impl ActKind {
    /// Packed (single- or multi-plane) binary representations.
    pub fn is_packed(self) -> bool {
        matches!(
            self,
            ActKind::Bits | ActKind::ScaledBits | ActKind::Bits2 | ActKind::Ternary
        )
    }

    /// Bit-planes a packed representation stores per value.
    pub fn planes(self) -> usize {
        match self {
            ActKind::Bits2 => 3,
            ActKind::Ternary => 2,
            _ => 1,
        }
    }
}

impl std::fmt::Display for ActKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ActKind::Bytes => "Bytes",
            ActKind::Float => "Float",
            ActKind::Bits => "Bits",
            ActKind::ScaledBits => "SBits",
            ActKind::Bits2 => "Bits2",
            ActKind::Ternary => "Tern",
        })
    }
}

/// Output representation of a fused GEMM layer (conv / dense): what the
/// layer's binarizing tail emits under the binary backend. `Sign` is the
/// paper's plain sign-binarization; the others are the XNOR-Net /
/// BMXNet-family extensions. The float backend applies the *same*
/// quantization in the float domain, so hybrid placements stay
/// comparable layer by layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutRepr {
    /// Plain ±1 sign bits.
    Sign,
    /// Sign bits plus a per-group scale A = mean |y| (XNOR-Net).
    ScaledSign,
    /// 2-bit thermometer activation, levels Δ·{-3,-1,1,3}.
    Quant2,
    /// Ternary thermometer activation, levels Δ·{-1,0,1}.
    Ternary,
}

impl OutRepr {
    /// Bit-planes this representation packs per activation value.
    pub fn planes(self) -> usize {
        match self {
            OutRepr::Sign | OutRepr::ScaledSign => 1,
            OutRepr::Ternary => 2,
            OutRepr::Quant2 => 3,
        }
    }

    /// Thermometer level thresholds, in multiples of the activation Δ.
    /// Plane `t` of the packed output is `y ≥ Δ·t_t`.
    pub fn level_thresholds(self) -> &'static [f32] {
        match self {
            OutRepr::Sign | OutRepr::ScaledSign => &[0.0],
            OutRepr::Ternary => &[-0.5, 0.5],
            OutRepr::Quant2 => &[-2.0, 0.0, 2.0],
        }
    }

    /// The activation kind this representation emits under the binary
    /// backend.
    pub fn out_kind(self) -> ActKind {
        match self {
            OutRepr::Sign => ActKind::Bits,
            OutRepr::ScaledSign => ActKind::ScaledBits,
            OutRepr::Quant2 => ActKind::Bits2,
            OutRepr::Ternary => ActKind::Ternary,
        }
    }

    /// Serialization tag (format v3).
    pub fn tag(self) -> u8 {
        match self {
            OutRepr::Sign => 0,
            OutRepr::ScaledSign => 1,
            OutRepr::Quant2 => 2,
            OutRepr::Ternary => 3,
        }
    }

    /// Inverse of [`OutRepr::tag`].
    pub fn from_tag(tag: u8) -> Option<Self> {
        Some(match tag {
            0 => OutRepr::Sign,
            1 => OutRepr::ScaledSign,
            2 => OutRepr::Quant2,
            3 => OutRepr::Ternary,
            _ => return None,
        })
    }
}

impl std::fmt::Display for OutRepr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            OutRepr::Sign => "sign",
            OutRepr::ScaledSign => "xnor",
            OutRepr::Quant2 => "2bit",
            OutRepr::Ternary => "tern",
        })
    }
}

/// An activation flowing between layers. Every variant carries a batch
/// axis (`batch` stacked images of one per-image `shape`); single-image
/// forwards are simply `batch == 1`. Conv/pool layers consume and produce
/// batched activations natively — a batch runs as ONE GEMM per layer —
/// while dense layers fold the batch into their row convention
/// (`shape.m` rows of features).
#[derive(Clone, Debug)]
pub enum Act<W: Word = u64> {
    /// Fixed-precision input (8-bit pixels) — first layer only.
    Bytes(Tensor<u8>),
    /// Float activations (±1 after a sign layer, arbitrary before BN).
    Float(Tensor<f32>),
    /// Bit-packed ±1 activations.
    Bits(BitTensor<W>),
    /// XNOR-Net scaled binary activations (bits + per-group scale).
    Scaled(ScaledBitTensor<W>),
    /// Multi-bit thermometer-plane activations (2-bit / ternary).
    Quant(QuantTensor<W>),
}

/// A borrowed activation. The plan executor feeds the FIRST step of a
/// forward through this, so `Network::predict_*` never clones the
/// caller's input tensor; GEMM layers consume the borrow directly via
/// [`Layer::forward_view`], every other layer falls back to an owned
/// copy.
#[derive(Clone, Copy, Debug)]
pub enum ActView<'a, W: Word = u64> {
    Bytes(&'a Tensor<u8>),
    Float(&'a Tensor<f32>),
    Bits(&'a BitTensor<W>),
    Scaled(&'a ScaledBitTensor<W>),
    Quant(&'a QuantTensor<W>),
}

impl<'a, W: Word> ActView<'a, W> {
    pub fn kind_of(&self) -> ActKind {
        match self {
            ActView::Bytes(_) => ActKind::Bytes,
            ActView::Float(_) => ActKind::Float,
            ActView::Bits(_) => ActKind::Bits,
            ActView::Scaled(_) => ActKind::ScaledBits,
            ActView::Quant(t) => t.kind(),
        }
    }

    /// Per-image shape (the batch axis is separate).
    pub fn shape(&self) -> Shape {
        match self {
            ActView::Bytes(t) => t.shape,
            ActView::Float(t) => t.shape,
            ActView::Bits(t) => t.shape,
            ActView::Scaled(t) => t.bits.shape,
            ActView::Quant(t) => t.shape(),
        }
    }

    /// Number of stacked images in this activation.
    pub fn batch(&self) -> usize {
        match self {
            ActView::Bytes(t) => t.batch,
            ActView::Float(t) => t.batch,
            ActView::Bits(t) => t.batch,
            ActView::Scaled(t) => t.bits.batch,
            ActView::Quant(t) => t.batch(),
        }
    }

    /// Materialize an owned activation (clones the data).
    pub fn to_act(&self) -> Act<W> {
        match self {
            ActView::Bytes(t) => Act::Bytes((*t).clone()),
            ActView::Float(t) => Act::Float((*t).clone()),
            ActView::Bits(t) => Act::Bits((*t).clone()),
            ActView::Scaled(t) => Act::Scaled((*t).clone()),
            ActView::Quant(t) => Act::Quant((*t).clone()),
        }
    }
}

impl<W: Word> Act<W> {
    /// Borrow this activation as an [`ActView`].
    pub fn view(&self) -> ActView<'_, W> {
        match self {
            Act::Bytes(t) => ActView::Bytes(t),
            Act::Float(t) => ActView::Float(t),
            Act::Bits(t) => ActView::Bits(t),
            Act::Scaled(t) => ActView::Scaled(t),
            Act::Quant(t) => ActView::Quant(t),
        }
    }

    /// Representation tag (plan-time bookkeeping).
    pub fn kind_of(&self) -> ActKind {
        match self {
            Act::Bytes(_) => ActKind::Bytes,
            Act::Float(_) => ActKind::Float,
            Act::Bits(_) => ActKind::Bits,
            Act::Scaled(_) => ActKind::ScaledBits,
            Act::Quant(t) => t.kind(),
        }
    }

    /// Total bytes of activation payload (profiling).
    pub fn payload_bytes(&self) -> usize {
        match self {
            Act::Bytes(t) => t.data.len(),
            Act::Float(t) => t.data.len() * 4,
            Act::Bits(t) => t.data.len() * (W::BITS / 8),
            Act::Scaled(t) => t.packed_bytes(),
            Act::Quant(t) => t.packed_bytes(),
        }
    }

    /// Per-image shape (the batch axis is separate; see [`Act::batch`]).
    pub fn shape(&self) -> Shape {
        match self {
            Act::Bytes(t) => t.shape,
            Act::Float(t) => t.shape,
            Act::Bits(t) => t.shape,
            Act::Scaled(t) => t.bits.shape,
            Act::Quant(t) => t.shape(),
        }
    }

    /// Number of stacked images in this activation.
    pub fn batch(&self) -> usize {
        match self {
            Act::Bytes(t) => t.batch,
            Act::Float(t) => t.batch,
            Act::Bits(t) => t.batch,
            Act::Scaled(t) => t.bits.batch,
            Act::Quant(t) => t.batch(),
        }
    }

    /// Force to float (unpacking / widening / dequantizing as needed).
    pub fn into_float(self) -> Tensor<f32> {
        match self {
            Act::Bytes(t) => t.to_f32(),
            Act::Float(t) => t,
            Act::Bits(t) => t.to_tensor(),
            Act::Scaled(t) => t.to_tensor(),
            Act::Quant(t) => t.to_tensor(),
        }
    }

    /// Force to packed bits (sign-binarizing floats as needed; scaled and
    /// multi-bit representations re-binarize by sign of their value).
    /// `Bytes` inputs cannot be represented as ±1 bits — layers consume
    /// them via bit-planes instead — so this panics on `Bytes`.
    pub fn into_bits(self) -> BitTensor<W> {
        match self {
            Act::Bytes(_) => panic!("fixed-precision input has no ±1 bit representation"),
            Act::Float(t) => BitTensor::from_tensor(&t),
            Act::Bits(t) => t,
            Act::Scaled(t) => t.bits,
            Act::Quant(t) => BitTensor::from_tensor(&t.to_tensor()),
        }
    }

    pub fn expect_float(&self) -> &Tensor<f32> {
        match self {
            Act::Float(t) => t,
            other => panic!("expected Float activation, got {}", other.kind()),
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Act::Bytes(_) => "Bytes",
            Act::Float(_) => "Float",
            Act::Bits(_) => "Bits",
            Act::Scaled(_) => "SBits",
            Act::Quant(t) => {
                if t.planes.len() == 3 {
                    "Bits2"
                } else {
                    "Tern"
                }
            }
        }
    }
}

/// Per-feature BatchNorm parameters (inference form).
#[derive(Clone, Debug, PartialEq)]
pub struct BnParams {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
    pub eps: f32,
}

impl BnParams {
    pub fn features(&self) -> usize {
        self.gamma.len()
    }

    pub fn validate(&self) {
        let n = self.gamma.len();
        assert_eq!(self.beta.len(), n, "beta length");
        assert_eq!(self.mean.len(), n, "mean length");
        assert_eq!(self.var.len(), n, "var length");
        assert!(self.var.iter().all(|&v| v + self.eps > 0.0), "variance");
    }

    /// Apply in float: `y = γ(x−μ)/σ + β` per feature, features
    /// interleaved along the innermost axis of `x`.
    pub fn apply(&self, x: &mut [f32]) {
        let f = self.features();
        assert_eq!(x.len() % f, 0);
        for group in x.chunks_mut(f) {
            for (i, v) in group.iter_mut().enumerate() {
                let sigma = (self.var[i] + self.eps).sqrt();
                *v = self.gamma[i] * (*v - self.mean[i]) / sigma + self.beta[i];
            }
        }
    }

    /// Fold `sign(BN(x))` into per-feature integer-threshold form
    /// (paper-style fused binarization): `bit = x ≥ τ` when γ>0,
    /// `bit = x ≤ τ` when γ<0, constant when γ=0.
    pub fn fold(&self) -> FoldedBn {
        let f = self.features();
        let mut tau = Vec::with_capacity(f);
        let mut gamma_pos = Vec::with_capacity(f);
        for i in 0..f {
            let sigma = (self.var[i] + self.eps).sqrt();
            let g = self.gamma[i];
            if g == 0.0 {
                // sign(β) constant: encode as always-true / always-false
                gamma_pos.push(true);
                tau.push(if self.beta[i] >= 0.0 {
                    f32::NEG_INFINITY
                } else {
                    f32::INFINITY
                });
            } else {
                gamma_pos.push(g > 0.0);
                tau.push(self.mean[i] - self.beta[i] * sigma / g);
            }
        }
        FoldedBn { tau, gamma_pos }
    }
}

/// Folded BatchNorm + sign thresholds (binary hot path).
#[derive(Clone, Debug, PartialEq)]
pub struct FoldedBn {
    pub tau: Vec<f32>,
    pub gamma_pos: Vec<bool>,
}

/// Per-plane folded thresholds for a quantized output representation.
/// Plane `t` of the packed output is `y ≥ taus[t][f]` (direction flipped
/// when `!gamma_pos[f]`), with `y` the *scaled* pre-BN accumulator
/// `y = Δ_in · α_f · acc`. Layers divide these by `Δ_in · α_f` at pack
/// time so the comparison runs directly on the integer accumulator —
/// both factors are positive, so the γ-sign direction is preserved.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantFold {
    /// `planes × features` thresholds in the y domain.
    pub taus: Vec<Vec<f32>>,
    pub gamma_pos: Vec<bool>,
}

/// Fold `quantize(BN(y))` into per-plane thresholds: plane `t`'s bit is
/// `BN(y) ≥ Δ_out·t_t`, rewritten as a threshold on `y` itself. With no
/// BN the thresholds are the raw levels `Δ_out·t_t`. Reduces to
/// [`BnParams::fold`] exactly for `Sign` (one plane, threshold 0).
pub fn fold_quant(bn: Option<&BnParams>, repr: OutRepr, act_delta: f32, f: usize) -> QuantFold {
    let levels = repr.level_thresholds();
    let mut taus = Vec::with_capacity(levels.len());
    let mut gamma_pos = vec![true; f];
    for &t in levels {
        let c = act_delta * t;
        let mut tau = Vec::with_capacity(f);
        match bn {
            None => tau.resize(f, c),
            Some(bn) => {
                for i in 0..f {
                    let sigma = (bn.var[i] + bn.eps).sqrt();
                    let g = bn.gamma[i];
                    if g == 0.0 {
                        // BN(y) = β constant: always / never above the level
                        gamma_pos[i] = true;
                        tau.push(if bn.beta[i] >= c {
                            f32::NEG_INFINITY
                        } else {
                            f32::INFINITY
                        });
                    } else {
                        gamma_pos[i] = g > 0.0;
                        tau.push(bn.mean[i] + (c - bn.beta[i]) * sigma / g);
                    }
                }
            }
        }
        taus.push(tau);
    }
    QuantFold { taus, gamma_pos }
}

/// Apply the output quantization of `repr` in the *float* domain, in
/// place — the float-backend mirror of the binary threshold-pack tails,
/// so hybrid placements quantize identically on both backends. `y` holds
/// BN-applied pre-activations with `f` features innermost (one packed
/// group per chunk).
pub fn quantize_float_scores(repr: OutRepr, act_delta: f32, y: &mut [f32], f: usize) {
    debug_assert_eq!(y.len() % f, 0);
    match repr {
        OutRepr::Sign => {
            for v in y.iter_mut() {
                *v = if *v >= 0.0 { 1.0 } else { -1.0 };
            }
        }
        OutRepr::ScaledSign => {
            for group in y.chunks_mut(f) {
                let a = group.iter().map(|v| v.abs()).sum::<f32>() / f as f32;
                for v in group.iter_mut() {
                    *v = if *v >= 0.0 { a } else { -a };
                }
            }
        }
        OutRepr::Quant2 | OutRepr::Ternary => {
            let levels = repr.level_thresholds();
            let (a, b) = crate::tensor::QuantTensor::<u64>::coeffs(levels.len());
            for v in y.iter_mut() {
                let u = levels.iter().filter(|&&t| *v >= act_delta * t).count() as i32;
                *v = act_delta * (a * u + b) as f32;
            }
        }
    }
}

/// Max-pool geometry attached to a fused conv block (pool runs on the
/// int32 accumulator *before* the BN threshold — exact for any γ sign).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolSpec {
    pub k: usize,
    pub stride: usize,
}

/// Scratch-buffer reservation request: the pool-buffer lengths one
/// `forward` call will acquire at a given geometry. Computed at plan time
/// (see [`Layer::scratch`]) so the [`Workspace`] can pre-size its
/// freelists and steady-state forwards never touch the heap.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ScratchSpec {
    /// Lengths of `f32` buffers acquired (all live simultaneously).
    pub f32s: Vec<usize>,
    /// Lengths of `i32` buffers acquired.
    pub i32s: Vec<usize>,
    /// Lengths of packed-word (`W`) buffers acquired.
    pub words: Vec<usize>,
    /// Lengths of `u8` buffers acquired.
    pub bytes: Vec<usize>,
}

impl ScratchSpec {
    pub fn is_empty(&self) -> bool {
        self.f32s.is_empty()
            && self.i32s.is_empty()
            && self.words.is_empty()
            && self.bytes.is_empty()
    }

    /// Total scratch footprint in bytes (word width supplied by caller).
    pub fn total_bytes(&self, word_bytes: usize) -> usize {
        self.f32s.iter().sum::<usize>() * 4
            + self.i32s.iter().sum::<usize>() * 4
            + self.words.iter().sum::<usize>() * word_bytes
            + self.bytes.iter().sum::<usize>()
    }
}

/// Common layer interface.
///
/// Besides `forward`, layers expose **plan-time hooks** consumed by
/// [`crate::net::plan::ForwardPlan`]: `out_kind` resolves the activation
/// representation at each boundary ahead of time, `scratch` sizes the
/// pool buffers a forward will need, `gemm_dims` feeds the hybrid
/// backend cost model, and `forward_view` lets the first plan step
/// consume a borrowed input without cloning it.
pub trait Layer<W: Word>: Send + Sync {
    /// Human-readable description for reports.
    fn describe(&self) -> String;

    /// Bind input shape; precompute anything shape-dependent (padding
    /// correction matrices); return the output shape.
    fn prepare(&mut self, in_shape: Shape) -> Shape;

    /// Forward under the given backend.
    fn forward(&self, x: Act<W>, backend: Backend, ws: &Workspace) -> Act<W>;

    /// Reference forward that materializes every intermediate in full —
    /// for conv layers, the whole `(B·oh·ow) × k` unrolled patch matrix
    /// the fused tile-streaming path never builds. Kept as the
    /// equivalence oracle (mirroring `Network::forward_layerwalk`); must
    /// be bit-identical to `forward`. Layers without a fused variant
    /// simply run `forward`.
    fn forward_materialized(&self, x: Act<W>, backend: Backend, ws: &Workspace) -> Act<W> {
        self.forward(x, backend, ws)
    }

    /// Activation kind this layer emits under `backend` for an input of
    /// `in_kind` — must agree with what `forward` actually returns (the
    /// plan executor asserts this in debug builds).
    fn out_kind(&self, backend: Backend, in_kind: ActKind) -> ActKind;

    /// Pool buffers one `forward` call acquires at this geometry
    /// (plan-time reservation). Empty means the layer draws nothing from
    /// the workspace pools.
    fn scratch(
        &self,
        _in_shape: Shape,
        _in_kind: ActKind,
        _backend: Backend,
        _batch: usize,
    ) -> ScratchSpec {
        ScratchSpec::default()
    }

    /// Pool buffers the *materializing* reference forward would acquire —
    /// what [`Layer::scratch`] reported before tile streaming. The delta
    /// against `scratch` is the fused path's memory win, surfaced per
    /// step by `espresso profile` and the t3 bench.
    fn scratch_materialized(
        &self,
        in_shape: Shape,
        in_kind: ActKind,
        backend: Backend,
        batch: usize,
    ) -> ScratchSpec {
        self.scratch(in_shape, in_kind, backend, batch)
    }

    /// GEMM dimensions `(rows per image, out features, reduction len)`
    /// when this layer's hot loop is a GEMM — what the plan's backend
    /// cost model keys on. `None` for data-movement layers.
    fn gemm_dims(&self, _in_shape: Shape) -> Option<(usize, usize, usize)> {
        None
    }

    /// Autotuner key for this layer's hot GEMM under the given backend
    /// and input representation: `(family, m, n, k)` with `k` in *family
    /// units* (packed words for `Binary`, u8 elements for `Bitplane`,
    /// f32s for `Float`) — exactly what [`crate::util::tune::tune_gemm`]
    /// and the kernel-side registry lookups key on. `None` for layers
    /// whose forward is not a tunable GEMM.
    fn tune_dims(
        &self,
        _in_shape: Shape,
        _in_kind: ActKind,
        _backend: Backend,
    ) -> Option<(crate::util::tune::Family, usize, usize, usize)> {
        None
    }

    /// Short label for the scale factors this layer folds into its
    /// epilogue / threshold tail under the planned input kind, shown in
    /// the plan and profile tables: `α` per-output-channel weight scales,
    /// `Δ` a quantized activation step, `K` the XNOR-Net per-pixel input
    /// scale. `-` when the layer runs the plain unscaled path.
    fn scale_mode(&self, _in_kind: ActKind) -> String {
        "-".into()
    }

    /// Forward from a borrowed input (the first plan step). The default
    /// clones; GEMM layers override it to consume the borrow directly so
    /// `predict_*` performs zero input copies.
    fn forward_view(&self, x: ActView<'_, W>, backend: Backend, ws: &Workspace) -> Act<W> {
        self.forward(x.to_act(), backend, ws)
    }

    /// Parameter storage in bytes for the float representation.
    fn param_bytes_float(&self) -> usize;

    /// Parameter storage in bytes for the packed representation.
    fn param_bytes_packed(&self) -> usize;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn act_conversions_roundtrip() {
        let mut rng = Rng::new(71);
        let t = Tensor::from_vec(Shape::vector(100), rng.signs(100));
        let a: Act<u64> = Act::Float(t.clone());
        let bits = a.clone().into_bits();
        assert_eq!(Act::<u64>::Bits(bits).into_float(), t);
        assert_eq!(a.into_float(), t);
    }

    #[test]
    #[should_panic(expected = "no ±1 bit representation")]
    fn bytes_to_bits_panics() {
        let t = Tensor::<u8>::zeros(Shape::vector(4));
        let _ = Act::<u64>::Bytes(t).into_bits();
    }

    #[test]
    fn views_track_kind_shape_and_payload() {
        let t = Tensor::from_vec(Shape::vector(6), vec![1.0f32; 6]);
        let a: Act<u64> = Act::Float(t);
        assert_eq!(a.kind_of(), ActKind::Float);
        assert_eq!(a.payload_bytes(), 24);
        let v = a.view();
        assert_eq!(v.kind_of(), ActKind::Float);
        assert_eq!(v.shape(), Shape::vector(6));
        assert_eq!(v.batch(), 1);
        // materializing the view clones the payload bit-for-bit
        assert_eq!(v.to_act().into_float(), a.into_float());
        let bytes: Act<u64> = Act::Bytes(Tensor::<u8>::zeros(Shape::vector(8)));
        assert_eq!(bytes.view().kind_of(), ActKind::Bytes);
        assert_eq!(bytes.payload_bytes(), 8);
    }

    #[test]
    fn scratch_spec_totals() {
        let spec = ScratchSpec {
            f32s: vec![10],
            i32s: vec![4, 4],
            words: vec![2],
            bytes: vec![3],
        };
        assert!(!spec.is_empty());
        // 10·4 + 8·4 + 2·8 (u64 words) + 3
        assert_eq!(spec.total_bytes(8), 40 + 32 + 16 + 3);
        assert!(ScratchSpec::default().is_empty());
    }

    #[test]
    fn bn_apply_matches_formula() {
        let bn = BnParams {
            gamma: vec![2.0, -1.0],
            beta: vec![0.5, 1.0],
            mean: vec![1.0, -1.0],
            var: vec![4.0, 0.25],
            eps: 0.0,
        };
        bn.validate();
        let mut x = vec![3.0, 0.0, 1.0, -1.0];
        bn.apply(&mut x);
        // feature 0: 2*(3-1)/2 + 0.5 = 2.5 ; feature 1: -1*(0+1)/0.5 + 1 = -1
        assert!((x[0] - 2.5).abs() < 1e-6);
        assert!((x[1] - -1.0).abs() < 1e-6);
        // second pixel: 2*(1-1)/2+0.5 = 0.5 ; -1*(-1+1)/0.5+1 = 1
        assert!((x[2] - 0.5).abs() < 1e-6);
        assert!((x[3] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn fold_agrees_with_float_bn_sign() {
        let mut rng = Rng::new(72);
        let f = 64;
        let bn = BnParams {
            gamma: (0..f)
                .map(|_| {
                    let g = rng.f32_range(-2.0, 2.0);
                    if g.abs() < 0.05 {
                        1.0
                    } else {
                        g
                    }
                })
                .collect(),
            beta: (0..f).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
            mean: (0..f).map(|_| rng.f32_range(-10.0, 10.0)).collect(),
            var: (0..f).map(|_| rng.f32_range(0.3, 4.0)).collect(),
            eps: 1e-4,
        };
        let folded = bn.fold();
        for trial in 0..500 {
            let i = trial % f;
            let x = rng.range_i64(-100, 100) as i32;
            let mut xf = vec![0f32; f];
            xf[i] = x as f32;
            // build a full group so apply() works; only check feature i
            let mut grp = xf.clone();
            bn.apply(&mut grp);
            if grp[i].abs() < 1e-3 {
                continue; // boundary: fp ordering may differ
            }
            let float_bit = grp[i] >= 0.0;
            let fold_bit = if folded.gamma_pos[i] {
                x as f32 >= folded.tau[i]
            } else {
                x as f32 <= folded.tau[i]
            };
            assert_eq!(float_bit, fold_bit, "i={i} x={x}");
        }
    }

    #[test]
    fn fold_zero_gamma_constant() {
        let bn = BnParams {
            gamma: vec![0.0, 0.0],
            beta: vec![1.0, -1.0],
            mean: vec![0.0, 0.0],
            var: vec![1.0, 1.0],
            eps: 0.0,
        };
        let f = bn.fold();
        // beta >= 0 -> always true; beta < 0 -> always false
        assert!(100.0f32 >= f.tau[0] && -100.0f32 >= f.tau[0]);
        assert!(!(100.0f32 >= f.tau[1]) && !(-100.0f32 >= f.tau[1]));
    }
}
