//! Fused dense (fully-connected) layer.
//!
//! One layer covers the whole BinaryNet block `y = sign(BN(W·x))`:
//! * **float path** — ±1 weights held in f32, blocked sgemm, float BN,
//!   float sign (the paper's CPU/GPU variants);
//! * **binary path** — weights pre-packed *once at construction* (the
//!   paper's key fix over BinaryNet's pack-every-forward, §6.2), binary
//!   GEMV/GEMM over packed activations, BN+sign folded to per-feature
//!   thresholds on the int32 accumulator, output re-packed on the fly.
//!
//! **Batching.** Dense layers represent a batch as packed rows (`shape.m`
//! samples × features, the MLP row convention). Activations arriving with
//! a batch axis — e.g. the output of a batched conv stack — are folded
//! into that convention by `flatten_to_rows`/`batch_count`, so a batch of
//! B samples runs as one `B × out` binary GEMM against the shared packed
//! weights.
//!
//! First-layer handling: a `Bytes` (8-bit) input is consumed either by
//! bit-plane decomposition (paper §4.3 — binary-optimized first layer,
//! experiment A1) or by a plain float GEMM when `bitplane_first` is off.

use super::{
    fold_quant, quantize_float_scores, Act, ActKind, ActView, Backend, BnParams, FoldedBn, Layer,
    OutRepr, QuantFold, ScratchSpec,
};
use crate::alloc::Workspace;
use crate::bitpack::{
    self, bitplane_gemm_into, pack_matrix_rows, pack_signs_into, pack_thresholds_f32_into,
    pack_thresholds_into, words_for, BitPlanes, Word,
};
use crate::linalg;
use crate::tensor::{BitTensor, PackDir, QuantTensor, ScaledBitTensor, Shape, Tensor};
use crate::util::parallel::current_slot;

/// Fused dense block: GEMM (+ BatchNorm) (+ sign).
#[derive(Clone)]
pub struct DenseLayer<W: Word = u64> {
    pub in_features: usize,
    pub out_features: usize,
    /// ±1 weights, row-major `out×in` (row per output neuron).
    w: Vec<f32>,
    /// Pre-packed rows (packed once, at load time).
    w_packed: Vec<W>,
    bn: Option<BnParams>,
    folded: Option<FoldedBn>,
    sign: bool,
    /// Output representation of the binarizing tail (`Sign` = legacy).
    repr: OutRepr,
    /// Activation quantization step Δ for the multi-bit output reprs.
    act_delta: f32,
    /// Per-output-channel XNOR-Net weight scales α (all > 0).
    alpha: Option<Vec<f32>>,
    /// Per-plane folded thresholds in the scaled-accumulator (y) domain;
    /// present whenever a sign tail exists.
    qfold: Option<QuantFold>,
    /// Binary-optimize a `Bytes` first layer via bit-planes (A1).
    pub bitplane_first: bool,
    /// Force the GEMM kernel even at batch 1 (ablation A3 only).
    pub force_gemm: bool,
}

impl<W: Word> DenseLayer<W> {
    /// Build from float weights (binarized by sign on entry), optional
    /// BatchNorm, and whether a sign activation follows.
    pub fn new(
        in_features: usize,
        out_features: usize,
        weights: &[f32],
        bn: Option<BnParams>,
        sign: bool,
    ) -> Self {
        assert_eq!(weights.len(), out_features * in_features, "weight size");
        if let Some(b) = &bn {
            b.validate();
            assert_eq!(b.features(), out_features, "BN features");
        }
        let w: Vec<f32> = weights
            .iter()
            .map(|&x| if x >= 0.0 { 1.0 } else { -1.0 })
            .collect();
        let w_packed = pack_matrix_rows::<W>(&w, out_features, in_features);
        let folded = match (&bn, sign) {
            (Some(b), true) => Some(b.fold()),
            (None, true) => Some(FoldedBn {
                tau: vec![0.0; out_features],
                gamma_pos: vec![true; out_features],
            }),
            _ => None,
        };
        let qfold = sign.then(|| fold_quant(bn.as_ref(), OutRepr::Sign, 1.0, out_features));
        Self {
            in_features,
            out_features,
            w,
            w_packed,
            bn,
            folded,
            sign,
            repr: OutRepr::Sign,
            act_delta: 1.0,
            alpha: None,
            qfold,
            bitplane_first: true,
            force_gemm: false,
        }
    }

    /// Select the output representation and scale epilogue: `repr` is the
    /// activation tail (requires `sign` for anything but plain scores),
    /// `act_delta` the output quantization step, `alpha` optional
    /// per-output-channel XNOR-Net weight scales (all positive).
    pub fn configure_repr(&mut self, repr: OutRepr, act_delta: f32, alpha: Option<Vec<f32>>) {
        assert!(
            self.sign || repr == OutRepr::Sign,
            "quantized output reprs require a sign/activation tail"
        );
        assert!(act_delta > 0.0, "act_delta must be positive");
        if let Some(a) = &alpha {
            assert_eq!(a.len(), self.out_features, "alpha length");
            assert!(a.iter().all(|&v| v > 0.0), "alpha must be positive");
        }
        self.repr = repr;
        self.act_delta = act_delta;
        self.alpha = alpha;
        self.qfold = self
            .sign
            .then(|| fold_quant(self.bn.as_ref(), repr, act_delta, self.out_features));
    }

    /// Output representation of the activation tail.
    pub fn repr(&self) -> OutRepr {
        self.repr
    }

    /// Output activation quantization step.
    pub fn act_delta(&self) -> f32 {
        self.act_delta
    }

    /// Per-output-channel weight scales, if configured.
    pub fn alpha(&self) -> Option<&[f32]> {
        self.alpha.as_deref()
    }

    #[inline(always)]
    fn alpha_at(&self, f: usize) -> f32 {
        self.alpha.as_ref().map_or(1.0, |a| a[f])
    }

    /// Batch count for a per-image activation shape under the row
    /// convention: `1` when the whole shape is one sample, `shape.m` when
    /// rows are samples.
    fn batch_of(&self, s: Shape) -> usize {
        if s.len() == self.in_features {
            1
        } else if s.n * s.l == self.in_features {
            s.m
        } else {
            panic!(
                "dense layer expects {} features, got activation shape {s}",
                self.in_features
            )
        }
    }

    /// Sample count for an activation that may carry a batch axis (conv
    /// stacks) or use the row convention (MLPs). With a batch axis each
    /// image must flatten to exactly `in_features`; rows-within-image and
    /// the batch axis multiply.
    fn batch_count(&self, s: Shape, batch: usize) -> usize {
        if batch > 1 {
            assert_eq!(
                s.len(),
                self.in_features,
                "dense layer expects {} features per image, got image shape {s}",
                self.in_features
            );
            batch
        } else {
            self.batch_of(s)
        }
    }

    /// Int32 accumulators -> output activation (shared binary-path tail):
    /// threshold-pack when a sign follows, else float (+BN) scores.
    /// `in_scale` is the input quantization step Δ_in (1.0 for ±1 inputs).
    fn finish_binary(&self, acc: &[i32], batch: usize, in_scale: f32) -> Act<W> {
        let out = self.out_features;
        let plain = self.alpha.is_none() && in_scale == 1.0;
        if plain && self.repr == OutRepr::Sign {
            // legacy path: bit-identical to the pre-repr pipeline
            if let Some(f) = &self.folded {
                let nw = words_for::<W>(out);
                let mut data = vec![W::ZERO; batch * nw];
                for b in 0..batch {
                    pack_thresholds_into(
                        &acc[b * out..(b + 1) * out],
                        &f.tau,
                        &f.gamma_pos,
                        &mut data[b * nw..(b + 1) * nw],
                    );
                }
                return Act::Bits(BitTensor {
                    shape: Shape {
                        m: batch,
                        n: out,
                        l: 1,
                    },
                    batch: 1,
                    dir: PackDir::Cols,
                    group_words: nw,
                    data,
                });
            }
            let mut scores: Vec<f32> = acc.iter().map(|&v| v as f32).collect();
            if let Some(bn) = &self.bn {
                bn.apply(&mut scores);
            }
            return Act::Float(Tensor::from_vec(
                Shape {
                    m: batch,
                    n: out,
                    l: 1,
                },
                scores,
            ));
        }
        if !self.sign || self.repr == OutRepr::ScaledSign {
            // scale epilogue needs (or emits) real f32 scores
            let mut y = Vec::with_capacity(batch * out);
            for b in 0..batch {
                for f in 0..out {
                    y.push(acc[b * out + f] as f32 * (in_scale * self.alpha_at(f)));
                }
            }
            return self.finish_float_domain(y, batch);
        }
        // integer-domain threshold pack: y = acc·Δ_in·α ≥ τ  ⇔
        // acc ≥ τ/(Δ_in·α)  (both divisors positive ⇒ direction kept)
        let qf = self.qfold.as_ref().expect("sign tail folded");
        let planes = self.repr.planes();
        let nw = words_for::<W>(out);
        let taus_rt: Vec<Vec<f32>> = qf
            .taus
            .iter()
            .map(|tau| {
                (0..out)
                    .map(|f| tau[f] / (in_scale * self.alpha_at(f)))
                    .collect()
            })
            .collect();
        let mut plane_data: Vec<Vec<W>> = (0..planes).map(|_| vec![W::ZERO; batch * nw]).collect();
        for b in 0..batch {
            let row = &acc[b * out..(b + 1) * out];
            for (t, data) in plane_data.iter_mut().enumerate() {
                pack_thresholds_into(
                    row,
                    &taus_rt[t],
                    &qf.gamma_pos,
                    &mut data[b * nw..(b + 1) * nw],
                );
            }
        }
        self.pack_planes(plane_data, batch)
    }

    /// Wrap per-plane packed rows into the output activation variant.
    fn pack_planes(&self, plane_data: Vec<Vec<W>>, batch: usize) -> Act<W> {
        let out = self.out_features;
        let nw = words_for::<W>(out);
        let shape = Shape {
            m: batch,
            n: out,
            l: 1,
        };
        let mk = |data: Vec<W>| BitTensor {
            shape,
            batch: 1,
            dir: PackDir::Cols,
            group_words: nw,
            data,
        };
        let mut it = plane_data.into_iter();
        if self.repr.planes() == 1 {
            Act::Bits(mk(it.next().expect("one plane")))
        } else {
            Act::Quant(QuantTensor {
                planes: it.map(mk).collect(),
                delta: self.act_delta,
            })
        }
    }

    /// Finish from real-valued scores `y` (pre-BN): apply BN, then the
    /// configured representation tail. Used by the scaled-input path and
    /// the ScaledSign output tail (which needs |y| for its A scales).
    fn finish_float_domain(&self, mut y: Vec<f32>, batch: usize) -> Act<W> {
        let out = self.out_features;
        if let Some(bn) = &self.bn {
            bn.apply(&mut y);
        }
        let shape = Shape {
            m: batch,
            n: out,
            l: 1,
        };
        if !self.sign {
            return Act::Float(Tensor::from_vec(shape, y));
        }
        let nw = words_for::<W>(out);
        match self.repr {
            OutRepr::Sign => {
                let mut data = vec![W::ZERO; batch * nw];
                for b in 0..batch {
                    pack_signs_into(&y[b * out..(b + 1) * out], &mut data[b * nw..(b + 1) * nw]);
                }
                Act::Bits(BitTensor {
                    shape,
                    batch: 1,
                    dir: PackDir::Cols,
                    group_words: nw,
                    data,
                })
            }
            OutRepr::ScaledSign => {
                let mut data = vec![W::ZERO; batch * nw];
                let mut scale = Vec::with_capacity(batch);
                for b in 0..batch {
                    let row = &y[b * out..(b + 1) * out];
                    let a = row.iter().map(|v| v.abs()).sum::<f32>() / out as f32;
                    scale.push(a);
                    pack_signs_into(row, &mut data[b * nw..(b + 1) * nw]);
                }
                Act::Scaled(ScaledBitTensor {
                    bits: BitTensor {
                        shape,
                        batch: 1,
                        dir: PackDir::Cols,
                        group_words: nw,
                        data,
                    },
                    scale,
                })
            }
            OutRepr::Quant2 | OutRepr::Ternary => {
                let planes = self.repr.planes();
                let pos = vec![true; out];
                let mut plane_data: Vec<Vec<W>> =
                    (0..planes).map(|_| vec![W::ZERO; batch * nw]).collect();
                for (t, &thr) in self.repr.level_thresholds().iter().enumerate() {
                    let tau = vec![self.act_delta * thr; out];
                    for b in 0..batch {
                        pack_thresholds_f32_into(
                            &y[b * out..(b + 1) * out],
                            &tau,
                            &pos,
                            &mut plane_data[t][b * nw..(b + 1) * nw],
                        );
                    }
                }
                self.pack_planes(plane_data, batch)
            }
        }
    }

    fn forward_float_t(&self, xf: &Tensor<f32>, _ws: &Workspace) -> Act<W> {
        let batch = self.batch_count(xf.shape, xf.batch);
        let (k, n) = (self.in_features, self.out_features);
        let mut y = if batch == 1 && !self.force_gemm {
            linalg::sgemv(&xf.data, &self.w, n, k)
        } else {
            linalg::sgemm(&xf.data, &self.w, batch, n, k)
        };
        if let Some(al) = &self.alpha {
            for row in y.chunks_mut(n) {
                for (v, &a) in row.iter_mut().zip(al.iter()) {
                    *v *= a;
                }
            }
        }
        if let Some(bn) = &self.bn {
            bn.apply(&mut y);
        }
        if self.sign {
            quantize_float_scores(self.repr, self.act_delta, &mut y, n);
        }
        Act::Float(Tensor::from_vec(
            Shape {
                m: batch,
                n,
                l: 1,
            },
            y,
        ))
    }

    fn forward_binary_bytes(&self, t: &Tensor<u8>, ws: &Workspace) -> Act<W> {
        let (k, n) = (self.in_features, self.out_features);
        let batch = self.batch_count(t.shape, t.batch);
        if self.bitplane_first {
            // binary-optimized first layer (bit-plane decomposition);
            // caller-affine scratch stays warm across requests
            let mut acc = ws.i32s.acquire_affine(current_slot(), batch * n);
            if batch == 1 && !self.force_gemm {
                let planes = BitPlanes::<W>::decompose(&t.data);
                bitpack::bitplane_gemv_into(&planes, &self.w_packed, &mut acc, n);
            } else {
                bitplane_gemm_into(&t.data, &self.w_packed, &mut acc, batch, n, k);
            }
            self.finish_binary(&acc, batch, 1.0)
        } else {
            // non-optimized first layer: float GEMM on raw pixels
            // (the BinaryNet behaviour the paper improves on)
            let xf = t.to_f32();
            let y = if batch == 1 && !self.force_gemm {
                linalg::sgemv(&xf.data, &self.w, n, k)
            } else {
                linalg::sgemm(&xf.data, &self.w, batch, n, k)
            };
            // pixel dot products are exact small integers in f32
            let mut acc = ws.i32s.acquire_affine(current_slot(), batch * n);
            for (a, &v) in acc.iter_mut().zip(y.iter()) {
                *a = v as i32;
            }
            self.finish_binary(&acc, batch, 1.0)
        }
    }

    /// Pack a borrowed float activation into the packed-rows convention
    /// without consuming (or copying) the float storage.
    fn pack_float_rows(&self, t: &Tensor<f32>) -> BitTensor<W> {
        let k = self.in_features;
        let batch = self.batch_count(t.shape, t.batch);
        let data = pack_matrix_rows::<W>(&t.data, batch, k);
        BitTensor {
            shape: Shape {
                m: batch,
                n: k,
                l: 1,
            },
            batch: 1,
            dir: PackDir::Cols,
            group_words: words_for::<W>(k),
            data,
        }
    }

    /// Binary GEMM tail over an owned packed activation (any arrival
    /// layout: `flatten_to_rows` normalizes without copying words).
    fn forward_binary_bits(&self, bt: BitTensor<W>, ws: &Workspace) -> Act<W> {
        let (k, n) = (self.in_features, self.out_features);
        let bt = bt.flatten_to_rows(k);
        let batch = bt.shape.m;
        let kw = words_for::<W>(k);
        debug_assert_eq!(bt.group_words, kw);
        let mut acc = ws.i32s.acquire_affine(current_slot(), batch * n);
        if batch == 1 && !self.force_gemm {
            bitpack::gemv_into(&bt.data, &self.w_packed, &mut acc, n, k);
        } else {
            bitpack::gemm_into(&bt.data, &self.w_packed, &mut acc, batch, n, k);
        }
        self.finish_binary(&acc, batch, 1.0)
    }

    /// Multi-bit (thermometer-plane) input: one binary GEMM per plane,
    /// combined exactly into a single integer accumulator — for symmetric
    /// level grids the per-plane rowsums cancel, so
    /// `Σ_t g_t = a·Σ x·w / Δ` up to the documented plane coefficients
    /// (ternary: (g0+g1)/2, always even; 2-bit: g0+g1+g2).
    fn forward_binary_quant(&self, qt: QuantTensor<W>, ws: &Workspace) -> Act<W> {
        let (k, n) = (self.in_features, self.out_features);
        let pcount = qt.planes.len();
        let delta = qt.delta;
        let mut it = qt.planes.into_iter();
        let first = it.next().expect("quant tensor has planes").flatten_to_rows(k);
        let batch = first.shape.m;
        let mut acc = ws.i32s.acquire_affine(current_slot(), batch * n);
        let gemm = |bt: &BitTensor<W>, out: &mut [i32]| {
            if batch == 1 && !self.force_gemm {
                bitpack::gemv_into(&bt.data, &self.w_packed, out, n, k);
            } else {
                bitpack::gemm_into(&bt.data, &self.w_packed, out, batch, n, k);
            }
        };
        gemm(&first, &mut acc);
        let mut tmp = ws.i32s.acquire_affine(current_slot(), batch * n);
        for plane in it {
            let bt = plane.flatten_to_rows(k);
            debug_assert_eq!(bt.shape.m, batch);
            gemm(&bt, &mut tmp);
            for (a, &t) in acc.iter_mut().zip(tmp.iter()) {
                *a += t;
            }
        }
        if pcount == 2 {
            // ternary plane sum is always even: each plane acc ≡ k (mod 2)
            for v in acc.iter_mut() {
                debug_assert_eq!(*v % 2, 0, "ternary plane sum must be even");
                *v /= 2;
            }
        }
        self.finish_binary(&acc, batch, delta)
    }

    /// Scaled-binary (XNOR-Net) input: binary GEMM on the sign bits, then
    /// a float epilogue with the per-sample input scale `s` (mean of the
    /// carrier's per-group A values) and the layer's α weight scales.
    fn forward_binary_scaled(&self, st: ScaledBitTensor<W>, ws: &Workspace) -> Act<W> {
        let (k, n) = (self.in_features, self.out_features);
        let bt = st.bits.flatten_to_rows(k);
        let batch = bt.shape.m;
        assert_eq!(
            st.scale.len() % batch,
            0,
            "scale groups must divide evenly over samples"
        );
        let gpi = st.scale.len() / batch;
        let mut acc = ws.i32s.acquire_affine(current_slot(), batch * n);
        if batch == 1 && !self.force_gemm {
            bitpack::gemv_into(&bt.data, &self.w_packed, &mut acc, n, k);
        } else {
            bitpack::gemm_into(&bt.data, &self.w_packed, &mut acc, batch, n, k);
        }
        let mut y = Vec::with_capacity(batch * n);
        for b in 0..batch {
            let s = st.scale[b * gpi..(b + 1) * gpi].iter().sum::<f32>() / gpi as f32;
            for f in 0..n {
                y.push(acc[b * n + f] as f32 * (s * self.alpha_at(f)));
            }
        }
        self.finish_float_domain(y, batch)
    }
}

impl<W: Word> Layer<W> for DenseLayer<W> {
    fn describe(&self) -> String {
        let tail = if self.sign {
            match self.repr {
                OutRepr::Sign => " +sign".to_string(),
                r => format!(" +{r}"),
            }
        } else {
            String::new()
        };
        format!(
            "Dense {}x{}{}{}{}",
            self.in_features,
            self.out_features,
            if self.bn.is_some() { " +BN" } else { "" },
            tail,
            if self.alpha.is_some() { " +a" } else { "" }
        )
    }

    fn prepare(&mut self, in_shape: Shape) -> Shape {
        let batch = self.batch_of(in_shape);
        Shape {
            m: batch,
            n: self.out_features,
            l: 1,
        }
    }

    fn forward(&self, x: Act<W>, backend: Backend, ws: &Workspace) -> Act<W> {
        match (backend, x) {
            // owned packed inputs keep their no-copy reshape paths
            (Backend::Binary, Act::Bits(bt)) => self.forward_binary_bits(bt, ws),
            (Backend::Binary, Act::Quant(qt)) => self.forward_binary_quant(qt, ws),
            (Backend::Binary, Act::Scaled(st)) => self.forward_binary_scaled(st, ws),
            (backend, x) => self.forward_view(x.view(), backend, ws),
        }
    }

    fn forward_view(&self, x: ActView<'_, W>, backend: Backend, ws: &Workspace) -> Act<W> {
        match backend {
            Backend::Float => match x {
                ActView::Float(t) => self.forward_float_t(t, ws),
                ActView::Bytes(t) => {
                    let xf = t.to_f32();
                    self.forward_float_t(&xf, ws)
                }
                ActView::Bits(bt) => {
                    let xf = bt.to_tensor();
                    self.forward_float_t(&xf, ws)
                }
                ActView::Scaled(st) => {
                    let xf = st.to_tensor();
                    self.forward_float_t(&xf, ws)
                }
                ActView::Quant(qt) => {
                    let xf = qt.to_tensor();
                    self.forward_float_t(&xf, ws)
                }
            },
            Backend::Binary => match x {
                ActView::Bytes(t) => self.forward_binary_bytes(t, ws),
                ActView::Float(t) => self.forward_binary_bits(self.pack_float_rows(t), ws),
                ActView::Bits(bt) => self.forward_binary_bits(bt.clone(), ws),
                ActView::Scaled(st) => self.forward_binary_scaled(st.clone(), ws),
                ActView::Quant(qt) => self.forward_binary_quant(qt.clone(), ws),
            },
        }
    }

    fn out_kind(&self, backend: Backend, _in_kind: ActKind) -> ActKind {
        match backend {
            Backend::Float => ActKind::Float,
            Backend::Binary => {
                if self.sign {
                    self.repr.out_kind()
                } else {
                    ActKind::Float
                }
            }
        }
    }

    fn scratch(
        &self,
        in_shape: Shape,
        in_kind: ActKind,
        backend: Backend,
        batch: usize,
    ) -> ScratchSpec {
        let mut spec = ScratchSpec::default();
        if backend == Backend::Binary {
            let b = self.batch_count(in_shape, batch);
            spec.i32s.push(b * self.out_features);
            if matches!(in_kind, ActKind::Bits2 | ActKind::Ternary) {
                // second accumulator for the per-plane GEMM combine
                spec.i32s.push(b * self.out_features);
            }
        }
        spec
    }

    fn scale_mode(&self, in_kind: ActKind) -> String {
        let mut parts: Vec<&str> = Vec::new();
        if self.alpha.is_some() {
            parts.push("a");
        }
        match in_kind {
            ActKind::ScaledBits => parts.push("s"),
            ActKind::Bits2 | ActKind::Ternary => parts.push("d"),
            _ => {}
        }
        if self.sign && matches!(self.repr, OutRepr::Quant2 | OutRepr::Ternary) {
            parts.push("d'");
        }
        if parts.is_empty() {
            "-".to_string()
        } else {
            parts.join("+")
        }
    }

    fn gemm_dims(&self, _in_shape: Shape) -> Option<(usize, usize, usize)> {
        Some((1, self.out_features, self.in_features))
    }

    fn tune_dims(
        &self,
        _in_shape: Shape,
        in_kind: ActKind,
        backend: Backend,
    ) -> Option<(crate::util::tune::Family, usize, usize, usize)> {
        use crate::util::tune::Family;
        let n = self.out_features;
        Some(match (backend, in_kind) {
            (Backend::Float, _) => (Family::Float, 1, n, self.in_features),
            (Backend::Binary, ActKind::Bytes) => {
                if self.bitplane_first {
                    (Family::Bitplane, 1, n, self.in_features)
                } else {
                    (Family::Float, 1, n, self.in_features)
                }
            }
            (Backend::Binary, _) => (Family::Binary, 1, n, words_for::<W>(self.in_features)),
        })
    }

    fn param_bytes_float(&self) -> usize {
        self.w.len() * 4 + self.bn.as_ref().map_or(0, |b| b.features() * 16)
    }

    fn param_bytes_packed(&self) -> usize {
        // extra threshold planes + α vectors only for non-default reprs,
        // so the legacy 32x memory claim is unaffected
        let extra = (self.repr.planes() - 1) * self.out_features * 4
            + self.alpha.as_ref().map_or(0, |a| a.len() * 4);
        self.w_packed.len() * (W::BITS / 8)
            + self
                .folded
                .as_ref()
                .map_or(self.bn.as_ref().map_or(0, |b| b.features() * 16), |f| {
                    f.tau.len() * 5 // tau f32 + gamma_pos bit-ish byte
                })
            + extra
    }
}

impl<W: Word> BitTensor<W> {
    /// View/convert this tensor as packed rows of `features` bits each
    /// (row convention: `shape.m` samples, `batch == 1`), for consumption
    /// by a dense layer. Handles all three arrivals: a single image
    /// (flatten), a batched conv activation (flatten per image), and an
    /// already-rows tensor (identity / batch fold).
    pub(crate) fn flatten_to_rows(self, features: usize) -> BitTensor<W> {
        if self.shape.len() == features {
            // single image or batched images: flatten() handles both and
            // emits one packed row per image
            self.flatten()
        } else if self.dir == PackDir::Cols && self.shape.n * self.shape.l == features {
            if self.batch == 1 {
                self // already batch rows
            } else {
                // rows tensor with an extra batch axis: fold it into m
                BitTensor {
                    shape: Shape {
                        m: self.batch * self.shape.m,
                        n: self.shape.n,
                        l: self.shape.l,
                    },
                    batch: 1,
                    dir: self.dir,
                    group_words: self.group_words,
                    data: self.data,
                }
            }
        } else {
            panic!(
                "cannot view shape {} (batch {}) as rows of {features} features",
                self.shape, self.batch
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_bn(rng: &mut Rng, f: usize) -> BnParams {
        BnParams {
            gamma: (0..f)
                .map(|_| {
                    let g = rng.f32_range(-2.0, 2.0);
                    if g.abs() < 0.05 {
                        0.7
                    } else {
                        g
                    }
                })
                .collect(),
            beta: (0..f).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
            mean: (0..f).map(|_| rng.f32_range(-5.0, 5.0)).collect(),
            var: (0..f).map(|_| rng.f32_range(0.3, 4.0)).collect(),
            eps: 1e-4,
        }
    }

    /// Binary and float paths must agree bit-for-bit on ±1 inputs.
    #[test]
    fn binary_equals_float_hidden_layer() {
        let mut rng = Rng::new(81);
        let ws = Workspace::new();
        let (k, n) = (300, 170);
        let layer: DenseLayer<u64> =
            DenseLayer::new(k, n, &rng.signs(n * k), Some(random_bn(&mut rng, n)), true);
        for _ in 0..10 {
            let x = Tensor::from_vec(Shape::vector(k), rng.signs(k));
            let f = layer
                .forward(Act::Float(x.clone()), Backend::Float, &ws)
                .into_float();
            let b = layer
                .forward(Act::Float(x), Backend::Binary, &ws)
                .into_float();
            assert_eq!(f.data, b.data);
        }
    }

    #[test]
    fn binary_accepts_prepacked_bits() {
        let mut rng = Rng::new(82);
        let ws = Workspace::new();
        let (k, n) = (128, 64);
        let layer: DenseLayer<u64> = DenseLayer::new(k, n, &rng.signs(n * k), None, true);
        let x = Tensor::from_vec(Shape::vector(k), rng.signs(k));
        let bits = BitTensor::from_tensor(&x);
        let via_float = layer
            .forward(Act::Float(x), Backend::Binary, &ws)
            .into_float();
        let via_bits = layer
            .forward(Act::Bits(bits), Backend::Binary, &ws)
            .into_float();
        assert_eq!(via_float.data, via_bits.data);
    }

    #[test]
    fn bitplane_first_layer_is_exact() {
        let mut rng = Rng::new(83);
        let ws = Workspace::new();
        let (k, n) = (784, 100);
        let mut layer: DenseLayer<u64> =
            DenseLayer::new(k, n, &rng.signs(n * k), Some(random_bn(&mut rng, n)), true);
        let img: Vec<u8> = (0..k).map(|_| rng.next_u32() as u8).collect();
        let x = Tensor::from_vec(Shape::vector(k), img);
        let f = layer
            .forward(Act::Bytes(x.clone()), Backend::Float, &ws)
            .into_float();
        layer.bitplane_first = true;
        let b1 = layer
            .forward(Act::Bytes(x.clone()), Backend::Binary, &ws)
            .into_float();
        layer.bitplane_first = false;
        let b2 = layer
            .forward(Act::Bytes(x), Backend::Binary, &ws)
            .into_float();
        assert_eq!(f.data, b1.data, "bitplane first layer");
        assert_eq!(f.data, b2.data, "float first layer");
    }

    #[test]
    fn output_layer_scores_match() {
        let mut rng = Rng::new(84);
        let ws = Workspace::new();
        let (k, n) = (256, 10);
        let layer: DenseLayer<u64> =
            DenseLayer::new(k, n, &rng.signs(n * k), Some(random_bn(&mut rng, n)), false);
        let x = Tensor::from_vec(Shape::vector(k), rng.signs(k));
        let f = layer
            .forward(Act::Float(x.clone()), Backend::Float, &ws)
            .into_float();
        let b = layer
            .forward(Act::Float(x), Backend::Binary, &ws)
            .into_float();
        for (a, c) in f.data.iter().zip(&b.data) {
            assert!((a - c).abs() < 1e-3, "{a} vs {c}");
        }
    }

    #[test]
    fn batched_forward_matches_per_sample() {
        let mut rng = Rng::new(85);
        let ws = Workspace::new();
        let (k, n, batch) = (96, 40, 5);
        let layer: DenseLayer<u64> =
            DenseLayer::new(k, n, &rng.signs(n * k), Some(random_bn(&mut rng, n)), true);
        let xs = rng.signs(batch * k);
        let xb = Tensor::from_vec(
            Shape {
                m: batch,
                n: k,
                l: 1,
            },
            xs.clone(),
        );
        let yb = layer
            .forward(Act::Float(xb), Backend::Binary, &ws)
            .into_float();
        for b in 0..batch {
            let x1 = Tensor::from_vec(Shape::vector(k), xs[b * k..(b + 1) * k].to_vec());
            let y1 = layer
                .forward(Act::Float(x1), Backend::Binary, &ws)
                .into_float();
            assert_eq!(&yb.data[b * n..(b + 1) * n], &y1.data[..], "sample {b}");
        }
    }

    /// Batch-axis inputs (conv-style stacked images) must match the row
    /// convention and per-sample forwards, on both backends.
    #[test]
    fn batch_axis_input_matches_rows_and_singles() {
        let mut rng = Rng::new(87);
        let ws = Workspace::new();
        let (k, n, batch) = (72, 30, 4);
        let layer: DenseLayer<u64> =
            DenseLayer::new(k, n, &rng.signs(n * k), Some(random_bn(&mut rng, n)), true);
        let xs = rng.signs(batch * k);
        // batch-axis representation: B images of shape vector(k)
        let stacked = Tensor::from_stacked(batch, Shape::vector(k), xs.clone());
        for backend in [Backend::Binary, Backend::Float] {
            let via_batch_axis = layer
                .forward(Act::Float(stacked.clone()), backend, &ws)
                .into_float();
            let rows = Tensor::from_vec(
                Shape {
                    m: batch,
                    n: k,
                    l: 1,
                },
                xs.clone(),
            );
            let via_rows = layer.forward(Act::Float(rows), backend, &ws).into_float();
            assert_eq!(via_batch_axis.data, via_rows.data, "{backend:?}");
            for b in 0..batch {
                let x1 =
                    Tensor::from_vec(Shape::vector(k), xs[b * k..(b + 1) * k].to_vec());
                let y1 = layer.forward(Act::Float(x1), backend, &ws).into_float();
                assert_eq!(
                    &via_batch_axis.data[b * n..(b + 1) * n],
                    &y1.data[..],
                    "{backend:?} sample {b}"
                );
            }
        }
    }

    #[test]
    fn gemv_and_gemm_paths_agree() {
        let mut rng = Rng::new(86);
        let ws = Workspace::new();
        let (k, n) = (200, 80);
        let mut layer: DenseLayer<u64> = DenseLayer::new(k, n, &rng.signs(n * k), None, true);
        let x = Tensor::from_vec(Shape::vector(k), rng.signs(k));
        let a = layer
            .forward(Act::Float(x.clone()), Backend::Binary, &ws)
            .into_float();
        layer.force_gemm = true;
        let b = layer
            .forward(Act::Float(x), Backend::Binary, &ws)
            .into_float();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn memory_ratio_is_about_32x() {
        let layer: DenseLayer<u64> = DenseLayer::new(4096, 4096, &vec![1.0; 4096 * 4096], None, true);
        let ratio = layer.param_bytes_float() as f64 / layer.param_bytes_packed() as f64;
        assert!(ratio > 31.0 && ratio <= 32.5, "ratio={ratio}");
    }

    #[test]
    #[should_panic(expected = "expects 300 features")]
    fn shape_mismatch_panics() {
        let ws = Workspace::new();
        let layer: DenseLayer<u64> = DenseLayer::new(300, 10, &vec![1.0; 3000], None, true);
        let x = Tensor::from_vec(Shape::vector(299), vec![1.0; 299]);
        let _ = layer.forward(Act::Float(x), Backend::Float, &ws);
    }
}
