//! Fused dense (fully-connected) layer.
//!
//! One layer covers the whole BinaryNet block `y = sign(BN(W·x))`:
//! * **float path** — ±1 weights held in f32, blocked sgemm, float BN,
//!   float sign (the paper's CPU/GPU variants);
//! * **binary path** — weights pre-packed *once at construction* (the
//!   paper's key fix over BinaryNet's pack-every-forward, §6.2), binary
//!   GEMV/GEMM over packed activations, BN+sign folded to per-feature
//!   thresholds on the int32 accumulator, output re-packed on the fly.
//!
//! **Batching.** Dense layers represent a batch as packed rows (`shape.m`
//! samples × features, the MLP row convention). Activations arriving with
//! a batch axis — e.g. the output of a batched conv stack — are folded
//! into that convention by `flatten_to_rows`/`batch_count`, so a batch of
//! B samples runs as one `B × out` binary GEMM against the shared packed
//! weights.
//!
//! First-layer handling: a `Bytes` (8-bit) input is consumed either by
//! bit-plane decomposition (paper §4.3 — binary-optimized first layer,
//! experiment A1) or by a plain float GEMM when `bitplane_first` is off.

use super::{Act, ActKind, ActView, Backend, BnParams, FoldedBn, Layer, ScratchSpec};
use crate::alloc::Workspace;
use crate::bitpack::{
    self, bitplane_gemm_into, pack_matrix_rows, pack_thresholds_into, words_for, BitPlanes, Word,
};
use crate::linalg;
use crate::tensor::{BitTensor, PackDir, Shape, Tensor};
use crate::util::parallel::current_slot;

/// Fused dense block: GEMM (+ BatchNorm) (+ sign).
#[derive(Clone)]
pub struct DenseLayer<W: Word = u64> {
    pub in_features: usize,
    pub out_features: usize,
    /// ±1 weights, row-major `out×in` (row per output neuron).
    w: Vec<f32>,
    /// Pre-packed rows (packed once, at load time).
    w_packed: Vec<W>,
    bn: Option<BnParams>,
    folded: Option<FoldedBn>,
    sign: bool,
    /// Binary-optimize a `Bytes` first layer via bit-planes (A1).
    pub bitplane_first: bool,
    /// Force the GEMM kernel even at batch 1 (ablation A3 only).
    pub force_gemm: bool,
}

impl<W: Word> DenseLayer<W> {
    /// Build from float weights (binarized by sign on entry), optional
    /// BatchNorm, and whether a sign activation follows.
    pub fn new(
        in_features: usize,
        out_features: usize,
        weights: &[f32],
        bn: Option<BnParams>,
        sign: bool,
    ) -> Self {
        assert_eq!(weights.len(), out_features * in_features, "weight size");
        if let Some(b) = &bn {
            b.validate();
            assert_eq!(b.features(), out_features, "BN features");
        }
        let w: Vec<f32> = weights
            .iter()
            .map(|&x| if x >= 0.0 { 1.0 } else { -1.0 })
            .collect();
        let w_packed = pack_matrix_rows::<W>(&w, out_features, in_features);
        let folded = match (&bn, sign) {
            (Some(b), true) => Some(b.fold()),
            (None, true) => Some(FoldedBn {
                tau: vec![0.0; out_features],
                gamma_pos: vec![true; out_features],
            }),
            _ => None,
        };
        Self {
            in_features,
            out_features,
            w,
            w_packed,
            bn,
            folded,
            sign,
            bitplane_first: true,
            force_gemm: false,
        }
    }

    /// Batch count for a per-image activation shape under the row
    /// convention: `1` when the whole shape is one sample, `shape.m` when
    /// rows are samples.
    fn batch_of(&self, s: Shape) -> usize {
        if s.len() == self.in_features {
            1
        } else if s.n * s.l == self.in_features {
            s.m
        } else {
            panic!(
                "dense layer expects {} features, got activation shape {s}",
                self.in_features
            )
        }
    }

    /// Sample count for an activation that may carry a batch axis (conv
    /// stacks) or use the row convention (MLPs). With a batch axis each
    /// image must flatten to exactly `in_features`; rows-within-image and
    /// the batch axis multiply.
    fn batch_count(&self, s: Shape, batch: usize) -> usize {
        if batch > 1 {
            assert_eq!(
                s.len(),
                self.in_features,
                "dense layer expects {} features per image, got image shape {s}",
                self.in_features
            );
            batch
        } else {
            self.batch_of(s)
        }
    }

    /// Int32 accumulators -> output activation (shared binary-path tail):
    /// threshold-pack when a sign follows, else float (+BN) scores.
    fn finish_binary(&self, acc: &[i32], batch: usize) -> Act<W> {
        let out = self.out_features;
        if let Some(f) = &self.folded {
            let nw = words_for::<W>(out);
            let mut data = vec![W::ZERO; batch * nw];
            for b in 0..batch {
                pack_thresholds_into(
                    &acc[b * out..(b + 1) * out],
                    &f.tau,
                    &f.gamma_pos,
                    &mut data[b * nw..(b + 1) * nw],
                );
            }
            Act::Bits(BitTensor {
                shape: Shape {
                    m: batch,
                    n: out,
                    l: 1,
                },
                batch: 1,
                dir: PackDir::Cols,
                group_words: nw,
                data,
            })
        } else {
            let mut scores: Vec<f32> = acc.iter().map(|&v| v as f32).collect();
            if let Some(bn) = &self.bn {
                bn.apply(&mut scores);
            }
            Act::Float(Tensor::from_vec(
                Shape {
                    m: batch,
                    n: out,
                    l: 1,
                },
                scores,
            ))
        }
    }

    fn forward_float_t(&self, xf: &Tensor<f32>, _ws: &Workspace) -> Act<W> {
        let batch = self.batch_count(xf.shape, xf.batch);
        let (k, n) = (self.in_features, self.out_features);
        let mut y = if batch == 1 && !self.force_gemm {
            linalg::sgemv(&xf.data, &self.w, n, k)
        } else {
            linalg::sgemm(&xf.data, &self.w, batch, n, k)
        };
        if let Some(bn) = &self.bn {
            bn.apply(&mut y);
        }
        if self.sign {
            for v in y.iter_mut() {
                *v = if *v >= 0.0 { 1.0 } else { -1.0 };
            }
        }
        Act::Float(Tensor::from_vec(
            Shape {
                m: batch,
                n,
                l: 1,
            },
            y,
        ))
    }

    fn forward_binary_bytes(&self, t: &Tensor<u8>, ws: &Workspace) -> Act<W> {
        let (k, n) = (self.in_features, self.out_features);
        let batch = self.batch_count(t.shape, t.batch);
        if self.bitplane_first {
            // binary-optimized first layer (bit-plane decomposition);
            // caller-affine scratch stays warm across requests
            let mut acc = ws.i32s.acquire_affine(current_slot(), batch * n);
            if batch == 1 && !self.force_gemm {
                let planes = BitPlanes::<W>::decompose(&t.data);
                bitpack::bitplane_gemv_into(&planes, &self.w_packed, &mut acc, n);
            } else {
                bitplane_gemm_into(&t.data, &self.w_packed, &mut acc, batch, n, k);
            }
            self.finish_binary(&acc, batch)
        } else {
            // non-optimized first layer: float GEMM on raw pixels
            // (the BinaryNet behaviour the paper improves on)
            let xf = t.to_f32();
            let y = if batch == 1 && !self.force_gemm {
                linalg::sgemv(&xf.data, &self.w, n, k)
            } else {
                linalg::sgemm(&xf.data, &self.w, batch, n, k)
            };
            // pixel dot products are exact small integers in f32
            let mut acc = ws.i32s.acquire_affine(current_slot(), batch * n);
            for (a, &v) in acc.iter_mut().zip(y.iter()) {
                *a = v as i32;
            }
            self.finish_binary(&acc, batch)
        }
    }

    /// Pack a borrowed float activation into the packed-rows convention
    /// without consuming (or copying) the float storage.
    fn pack_float_rows(&self, t: &Tensor<f32>) -> BitTensor<W> {
        let k = self.in_features;
        let batch = self.batch_count(t.shape, t.batch);
        let data = pack_matrix_rows::<W>(&t.data, batch, k);
        BitTensor {
            shape: Shape {
                m: batch,
                n: k,
                l: 1,
            },
            batch: 1,
            dir: PackDir::Cols,
            group_words: words_for::<W>(k),
            data,
        }
    }

    /// Binary GEMM tail over an owned packed activation (any arrival
    /// layout: `flatten_to_rows` normalizes without copying words).
    fn forward_binary_bits(&self, bt: BitTensor<W>, ws: &Workspace) -> Act<W> {
        let (k, n) = (self.in_features, self.out_features);
        let bt = bt.flatten_to_rows(k);
        let batch = bt.shape.m;
        let kw = words_for::<W>(k);
        debug_assert_eq!(bt.group_words, kw);
        let mut acc = ws.i32s.acquire_affine(current_slot(), batch * n);
        if batch == 1 && !self.force_gemm {
            bitpack::gemv_into(&bt.data, &self.w_packed, &mut acc, n, k);
        } else {
            bitpack::gemm_into(&bt.data, &self.w_packed, &mut acc, batch, n, k);
        }
        self.finish_binary(&acc, batch)
    }
}

impl<W: Word> Layer<W> for DenseLayer<W> {
    fn describe(&self) -> String {
        format!(
            "Dense {}x{}{}{}",
            self.in_features,
            self.out_features,
            if self.bn.is_some() { " +BN" } else { "" },
            if self.sign { " +sign" } else { "" }
        )
    }

    fn prepare(&mut self, in_shape: Shape) -> Shape {
        let batch = self.batch_of(in_shape);
        Shape {
            m: batch,
            n: self.out_features,
            l: 1,
        }
    }

    fn forward(&self, x: Act<W>, backend: Backend, ws: &Workspace) -> Act<W> {
        match (backend, x) {
            // owned packed input keeps its no-copy reshape path
            (Backend::Binary, Act::Bits(bt)) => self.forward_binary_bits(bt, ws),
            (backend, x) => self.forward_view(x.view(), backend, ws),
        }
    }

    fn forward_view(&self, x: ActView<'_, W>, backend: Backend, ws: &Workspace) -> Act<W> {
        match backend {
            Backend::Float => match x {
                ActView::Float(t) => self.forward_float_t(t, ws),
                ActView::Bytes(t) => {
                    let xf = t.to_f32();
                    self.forward_float_t(&xf, ws)
                }
                ActView::Bits(bt) => {
                    let xf = bt.to_tensor();
                    self.forward_float_t(&xf, ws)
                }
            },
            Backend::Binary => match x {
                ActView::Bytes(t) => self.forward_binary_bytes(t, ws),
                ActView::Float(t) => self.forward_binary_bits(self.pack_float_rows(t), ws),
                ActView::Bits(bt) => self.forward_binary_bits(bt.clone(), ws),
            },
        }
    }

    fn out_kind(&self, backend: Backend, _in_kind: ActKind) -> ActKind {
        match backend {
            Backend::Float => ActKind::Float,
            Backend::Binary => {
                if self.folded.is_some() {
                    ActKind::Bits
                } else {
                    ActKind::Float
                }
            }
        }
    }

    fn scratch(
        &self,
        in_shape: Shape,
        _in_kind: ActKind,
        backend: Backend,
        batch: usize,
    ) -> ScratchSpec {
        let mut spec = ScratchSpec::default();
        if backend == Backend::Binary {
            let b = self.batch_count(in_shape, batch);
            spec.i32s.push(b * self.out_features);
        }
        spec
    }

    fn gemm_dims(&self, _in_shape: Shape) -> Option<(usize, usize, usize)> {
        Some((1, self.out_features, self.in_features))
    }

    fn tune_dims(
        &self,
        _in_shape: Shape,
        in_kind: ActKind,
        backend: Backend,
    ) -> Option<(crate::util::tune::Family, usize, usize, usize)> {
        use crate::util::tune::Family;
        let n = self.out_features;
        Some(match (backend, in_kind) {
            (Backend::Float, _) => (Family::Float, 1, n, self.in_features),
            (Backend::Binary, ActKind::Bytes) => {
                if self.bitplane_first {
                    (Family::Bitplane, 1, n, self.in_features)
                } else {
                    (Family::Float, 1, n, self.in_features)
                }
            }
            (Backend::Binary, _) => (Family::Binary, 1, n, words_for::<W>(self.in_features)),
        })
    }

    fn param_bytes_float(&self) -> usize {
        self.w.len() * 4 + self.bn.as_ref().map_or(0, |b| b.features() * 16)
    }

    fn param_bytes_packed(&self) -> usize {
        self.w_packed.len() * (W::BITS / 8)
            + self
                .folded
                .as_ref()
                .map_or(self.bn.as_ref().map_or(0, |b| b.features() * 16), |f| {
                    f.tau.len() * 5 // tau f32 + gamma_pos bit-ish byte
                })
    }
}

impl<W: Word> BitTensor<W> {
    /// View/convert this tensor as packed rows of `features` bits each
    /// (row convention: `shape.m` samples, `batch == 1`), for consumption
    /// by a dense layer. Handles all three arrivals: a single image
    /// (flatten), a batched conv activation (flatten per image), and an
    /// already-rows tensor (identity / batch fold).
    pub(crate) fn flatten_to_rows(self, features: usize) -> BitTensor<W> {
        if self.shape.len() == features {
            // single image or batched images: flatten() handles both and
            // emits one packed row per image
            self.flatten()
        } else if self.dir == PackDir::Cols && self.shape.n * self.shape.l == features {
            if self.batch == 1 {
                self // already batch rows
            } else {
                // rows tensor with an extra batch axis: fold it into m
                BitTensor {
                    shape: Shape {
                        m: self.batch * self.shape.m,
                        n: self.shape.n,
                        l: self.shape.l,
                    },
                    batch: 1,
                    dir: self.dir,
                    group_words: self.group_words,
                    data: self.data,
                }
            }
        } else {
            panic!(
                "cannot view shape {} (batch {}) as rows of {features} features",
                self.shape, self.batch
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_bn(rng: &mut Rng, f: usize) -> BnParams {
        BnParams {
            gamma: (0..f)
                .map(|_| {
                    let g = rng.f32_range(-2.0, 2.0);
                    if g.abs() < 0.05 {
                        0.7
                    } else {
                        g
                    }
                })
                .collect(),
            beta: (0..f).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
            mean: (0..f).map(|_| rng.f32_range(-5.0, 5.0)).collect(),
            var: (0..f).map(|_| rng.f32_range(0.3, 4.0)).collect(),
            eps: 1e-4,
        }
    }

    /// Binary and float paths must agree bit-for-bit on ±1 inputs.
    #[test]
    fn binary_equals_float_hidden_layer() {
        let mut rng = Rng::new(81);
        let ws = Workspace::new();
        let (k, n) = (300, 170);
        let layer: DenseLayer<u64> =
            DenseLayer::new(k, n, &rng.signs(n * k), Some(random_bn(&mut rng, n)), true);
        for _ in 0..10 {
            let x = Tensor::from_vec(Shape::vector(k), rng.signs(k));
            let f = layer
                .forward(Act::Float(x.clone()), Backend::Float, &ws)
                .into_float();
            let b = layer
                .forward(Act::Float(x), Backend::Binary, &ws)
                .into_float();
            assert_eq!(f.data, b.data);
        }
    }

    #[test]
    fn binary_accepts_prepacked_bits() {
        let mut rng = Rng::new(82);
        let ws = Workspace::new();
        let (k, n) = (128, 64);
        let layer: DenseLayer<u64> = DenseLayer::new(k, n, &rng.signs(n * k), None, true);
        let x = Tensor::from_vec(Shape::vector(k), rng.signs(k));
        let bits = BitTensor::from_tensor(&x);
        let via_float = layer
            .forward(Act::Float(x), Backend::Binary, &ws)
            .into_float();
        let via_bits = layer
            .forward(Act::Bits(bits), Backend::Binary, &ws)
            .into_float();
        assert_eq!(via_float.data, via_bits.data);
    }

    #[test]
    fn bitplane_first_layer_is_exact() {
        let mut rng = Rng::new(83);
        let ws = Workspace::new();
        let (k, n) = (784, 100);
        let mut layer: DenseLayer<u64> =
            DenseLayer::new(k, n, &rng.signs(n * k), Some(random_bn(&mut rng, n)), true);
        let img: Vec<u8> = (0..k).map(|_| rng.next_u32() as u8).collect();
        let x = Tensor::from_vec(Shape::vector(k), img);
        let f = layer
            .forward(Act::Bytes(x.clone()), Backend::Float, &ws)
            .into_float();
        layer.bitplane_first = true;
        let b1 = layer
            .forward(Act::Bytes(x.clone()), Backend::Binary, &ws)
            .into_float();
        layer.bitplane_first = false;
        let b2 = layer
            .forward(Act::Bytes(x), Backend::Binary, &ws)
            .into_float();
        assert_eq!(f.data, b1.data, "bitplane first layer");
        assert_eq!(f.data, b2.data, "float first layer");
    }

    #[test]
    fn output_layer_scores_match() {
        let mut rng = Rng::new(84);
        let ws = Workspace::new();
        let (k, n) = (256, 10);
        let layer: DenseLayer<u64> =
            DenseLayer::new(k, n, &rng.signs(n * k), Some(random_bn(&mut rng, n)), false);
        let x = Tensor::from_vec(Shape::vector(k), rng.signs(k));
        let f = layer
            .forward(Act::Float(x.clone()), Backend::Float, &ws)
            .into_float();
        let b = layer
            .forward(Act::Float(x), Backend::Binary, &ws)
            .into_float();
        for (a, c) in f.data.iter().zip(&b.data) {
            assert!((a - c).abs() < 1e-3, "{a} vs {c}");
        }
    }

    #[test]
    fn batched_forward_matches_per_sample() {
        let mut rng = Rng::new(85);
        let ws = Workspace::new();
        let (k, n, batch) = (96, 40, 5);
        let layer: DenseLayer<u64> =
            DenseLayer::new(k, n, &rng.signs(n * k), Some(random_bn(&mut rng, n)), true);
        let xs = rng.signs(batch * k);
        let xb = Tensor::from_vec(
            Shape {
                m: batch,
                n: k,
                l: 1,
            },
            xs.clone(),
        );
        let yb = layer
            .forward(Act::Float(xb), Backend::Binary, &ws)
            .into_float();
        for b in 0..batch {
            let x1 = Tensor::from_vec(Shape::vector(k), xs[b * k..(b + 1) * k].to_vec());
            let y1 = layer
                .forward(Act::Float(x1), Backend::Binary, &ws)
                .into_float();
            assert_eq!(&yb.data[b * n..(b + 1) * n], &y1.data[..], "sample {b}");
        }
    }

    /// Batch-axis inputs (conv-style stacked images) must match the row
    /// convention and per-sample forwards, on both backends.
    #[test]
    fn batch_axis_input_matches_rows_and_singles() {
        let mut rng = Rng::new(87);
        let ws = Workspace::new();
        let (k, n, batch) = (72, 30, 4);
        let layer: DenseLayer<u64> =
            DenseLayer::new(k, n, &rng.signs(n * k), Some(random_bn(&mut rng, n)), true);
        let xs = rng.signs(batch * k);
        // batch-axis representation: B images of shape vector(k)
        let stacked = Tensor::from_stacked(batch, Shape::vector(k), xs.clone());
        for backend in [Backend::Binary, Backend::Float] {
            let via_batch_axis = layer
                .forward(Act::Float(stacked.clone()), backend, &ws)
                .into_float();
            let rows = Tensor::from_vec(
                Shape {
                    m: batch,
                    n: k,
                    l: 1,
                },
                xs.clone(),
            );
            let via_rows = layer.forward(Act::Float(rows), backend, &ws).into_float();
            assert_eq!(via_batch_axis.data, via_rows.data, "{backend:?}");
            for b in 0..batch {
                let x1 =
                    Tensor::from_vec(Shape::vector(k), xs[b * k..(b + 1) * k].to_vec());
                let y1 = layer.forward(Act::Float(x1), backend, &ws).into_float();
                assert_eq!(
                    &via_batch_axis.data[b * n..(b + 1) * n],
                    &y1.data[..],
                    "{backend:?} sample {b}"
                );
            }
        }
    }

    #[test]
    fn gemv_and_gemm_paths_agree() {
        let mut rng = Rng::new(86);
        let ws = Workspace::new();
        let (k, n) = (200, 80);
        let mut layer: DenseLayer<u64> = DenseLayer::new(k, n, &rng.signs(n * k), None, true);
        let x = Tensor::from_vec(Shape::vector(k), rng.signs(k));
        let a = layer
            .forward(Act::Float(x.clone()), Backend::Binary, &ws)
            .into_float();
        layer.force_gemm = true;
        let b = layer
            .forward(Act::Float(x), Backend::Binary, &ws)
            .into_float();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn memory_ratio_is_about_32x() {
        let layer: DenseLayer<u64> = DenseLayer::new(4096, 4096, &vec![1.0; 4096 * 4096], None, true);
        let ratio = layer.param_bytes_float() as f64 / layer.param_bytes_packed() as f64;
        assert!(ratio > 31.0 && ratio <= 32.5, "ratio={ratio}");
    }

    #[test]
    #[should_panic(expected = "expects 300 features")]
    fn shape_mismatch_panics() {
        let ws = Workspace::new();
        let layer: DenseLayer<u64> = DenseLayer::new(300, 10, &vec![1.0; 3000], None, true);
        let x = Tensor::from_vec(Shape::vector(299), vec![1.0; 299]);
        let _ = layer.forward(Act::Float(x), Backend::Float, &ws);
    }
}
