//! Standalone BatchNorm and Sign layers.
//!
//! The `.esp` loader fuses BN+sign into the preceding GEMM layer for the
//! binary engine; these standalone versions exist for hand-built and
//! hybrid networks (and as the reference semantics the fused thresholds
//! are tested against). Their binary path materializes floats, applies
//! the op, and re-packs — deliberately the "slow but obviously right"
//! formulation.

use super::{Act, ActKind, Backend, BnParams, Layer};
use crate::alloc::Workspace;
use crate::bitpack::Word;
use crate::tensor::{BitTensor, Shape};
#[cfg(test)]
use crate::tensor::Tensor;

/// Inference-time batch normalization over the innermost (channel) axis.
#[derive(Clone, Debug)]
pub struct BatchNormLayer {
    pub bn: BnParams,
}

impl BatchNormLayer {
    pub fn new(bn: BnParams) -> Self {
        bn.validate();
        Self { bn }
    }
}

impl<W: Word> Layer<W> for BatchNormLayer {
    fn describe(&self) -> String {
        format!("BatchNorm f={}", self.bn.features())
    }

    fn prepare(&mut self, in_shape: Shape) -> Shape {
        let f = self.bn.features();
        assert!(
            in_shape.l == f || (in_shape.l == 1 && in_shape.n == f),
            "BN features {f} incompatible with shape {in_shape}"
        );
        in_shape
    }

    fn out_kind(&self, _backend: Backend, _in_kind: ActKind) -> ActKind {
        ActKind::Float
    }

    fn forward(&self, x: Act<W>, _backend: Backend, _ws: &Workspace) -> Act<W> {
        let mut t = x.into_float();
        self.bn.apply(&mut t.data);
        Act::Float(t)
    }

    fn param_bytes_float(&self) -> usize {
        self.bn.features() * 16
    }

    fn param_bytes_packed(&self) -> usize {
        self.bn.features() * 16
    }
}

/// Sign activation (Eq. 1): `+1` if `x ≥ 0`, `-1` otherwise.
#[derive(Clone, Debug, Default)]
pub struct SignLayer;

impl<W: Word> Layer<W> for SignLayer {
    fn describe(&self) -> String {
        "Sign".to_string()
    }

    fn prepare(&mut self, in_shape: Shape) -> Shape {
        in_shape
    }

    fn out_kind(&self, backend: Backend, _in_kind: ActKind) -> ActKind {
        match backend {
            Backend::Float => ActKind::Float,
            Backend::Binary => ActKind::Bits,
        }
    }

    fn forward(&self, x: Act<W>, backend: Backend, _ws: &Workspace) -> Act<W> {
        match backend {
            Backend::Float => Act::Float(x.into_float().signum()),
            Backend::Binary => {
                // binarize + pack: downstream binary layers consume bits
                let t = x.into_float();
                Act::Bits(BitTensor::from_tensor(&t))
            }
        }
    }

    fn param_bytes_float(&self) -> usize {
        0
    }

    fn param_bytes_packed(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn standalone_bn_then_sign_matches_fused_dense() {
        // dense (no bn) -> BatchNormLayer -> SignLayer  ==  fused dense
        let mut rng = Rng::new(111);
        let ws = Workspace::new();
        let (k, n) = (150, 60);
        let w = rng.signs(n * k);
        let bn = BnParams {
            gamma: (0..n).map(|_| rng.f32_range(0.2, 2.0)).collect(),
            beta: (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
            mean: (0..n).map(|_| rng.f32_range(-5.0, 5.0)).collect(),
            var: (0..n).map(|_| rng.f32_range(0.3, 4.0)).collect(),
            eps: 1e-4,
        };
        let fused: super::super::DenseLayer<u64> =
            super::super::DenseLayer::new(k, n, &w, Some(bn.clone()), true);
        let plain: super::super::DenseLayer<u64> = super::super::DenseLayer::new(k, n, &w, None, false);
        let bn_layer = BatchNormLayer::new(bn);
        let sign = SignLayer;
        for _ in 0..5 {
            let x = Tensor::from_vec(Shape::vector(k), rng.signs(k));
            let fused_out = fused
                .forward(Act::Float(x.clone()), Backend::Binary, &ws)
                .into_float();
            let mut a = plain.forward(Act::Float(x), Backend::Binary, &ws);
            a = Layer::<u64>::forward(&bn_layer, a, Backend::Float, &ws);
            a = Layer::<u64>::forward(&sign, a, Backend::Float, &ws);
            let staged = a.into_float();
            assert_eq!(fused_out.data, staged.data);
        }
    }

    #[test]
    fn sign_layer_binary_emits_bits() {
        let ws = Workspace::new();
        let t = Tensor::from_vec(Shape::vector(4), vec![0.5, -0.5, 0.0, -2.0]);
        let out = Layer::<u64>::forward(&SignLayer, Act::Float(t), Backend::Binary, &ws);
        match out {
            Act::Bits(b) => assert_eq!(b.to_tensor().data, vec![1.0, -1.0, 1.0, -1.0]),
            other => panic!("expected bits, got {}", other.kind()),
        }
    }

    #[test]
    #[should_panic(expected = "incompatible")]
    fn bn_shape_mismatch_panics() {
        let bn = BnParams {
            gamma: vec![1.0; 4],
            beta: vec![0.0; 4],
            mean: vec![0.0; 4],
            var: vec![1.0; 4],
            eps: 1e-5,
        };
        let mut l = BatchNormLayer::new(bn);
        Layer::<u64>::prepare(&mut l, Shape::new(2, 3, 5));
    }
}
