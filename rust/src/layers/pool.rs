//! Standalone max-pool layer.
//!
//! For post-sign pooling (values already ±1) the binary path pools packed
//! words directly with bitwise OR — `max` over {-1,+1} is exactly OR on
//! the bit encoding — so pooling a 128-channel window touches 2 words per
//! pixel instead of 128 floats (the paper's `GPU^opt` pooling kernel).
//! The float path is a standard per-channel max.

use super::{Act, ActKind, Backend, Layer, PoolSpec};
use crate::alloc::Workspace;
use crate::bitpack::Word;
use crate::tensor::{out_dim, BitTensor, PackDir, Shape, Tensor};

/// Max-pool over `k×k` windows with the given stride.
#[derive(Clone, Debug)]
pub struct MaxPoolLayer {
    pub spec: PoolSpec,
}

impl MaxPoolLayer {
    pub fn new(k: usize, stride: usize) -> Self {
        assert!(k > 0 && stride > 0);
        Self {
            spec: PoolSpec { k, stride },
        }
    }

    fn out_shape(&self, s: Shape) -> Shape {
        Shape::new(
            out_dim(s.m, self.spec.k, self.spec.stride, 0),
            out_dim(s.n, self.spec.k, self.spec.stride, 0),
            s.l,
        )
    }
}

impl<W: Word> Layer<W> for MaxPoolLayer {
    fn describe(&self) -> String {
        format!("MaxPool {}x{} s{}", self.spec.k, self.spec.k, self.spec.stride)
    }

    fn prepare(&mut self, in_shape: Shape) -> Shape {
        self.out_shape(in_shape)
    }

    fn out_kind(&self, backend: Backend, in_kind: ActKind) -> ActKind {
        // OR-pool keeps packed input packed; everything else goes through
        // the float max-pool
        match (backend, in_kind) {
            (Backend::Binary, ActKind::Bits) => ActKind::Bits,
            _ => ActKind::Float,
        }
    }

    fn forward(&self, x: Act<W>, backend: Backend, _ws: &Workspace) -> Act<W> {
        match (backend, x) {
            (Backend::Binary, Act::Bits(bt)) => {
                // OR-pool on packed channel groups; windows never cross
                // image boundaries of a batched activation
                assert_eq!(bt.dir, PackDir::Channels, "bit pooling needs channel packing");
                let s = bt.shape;
                let os = self.out_shape(s);
                let lw = bt.group_words;
                let mut data = vec![W::ZERO; bt.batch * os.m * os.n * lw];
                for b in 0..bt.batch {
                    for py in 0..os.m {
                        for px in 0..os.n {
                            let dst_base = ((b * os.m + py) * os.n + px) * lw;
                            for wy in 0..self.spec.k {
                                for wx in 0..self.spec.k {
                                    let iy = py * self.spec.stride + wy;
                                    let ix = px * self.spec.stride + wx;
                                    if iy >= s.m || ix >= s.n {
                                        continue;
                                    }
                                    let src = bt.pixel_at(b, iy, ix);
                                    for (d, &sw) in
                                        data[dst_base..dst_base + lw].iter_mut().zip(src)
                                    {
                                        *d = *d | sw;
                                    }
                                }
                            }
                        }
                    }
                }
                Act::Bits(BitTensor {
                    shape: os,
                    batch: bt.batch,
                    dir: PackDir::Channels,
                    group_words: lw,
                    data,
                })
            }
            (_, x) => {
                // float max-pool (also the binary fallback for non-packed
                // input); per-image over the batch axis
                let t = x.into_float();
                let s = t.shape;
                let os = self.out_shape(s);
                let mut data = vec![0f32; t.batch * os.len()];
                for b in 0..t.batch {
                    let img = t.image(b);
                    let out_img = &mut data[b * os.len()..(b + 1) * os.len()];
                    for py in 0..os.m {
                        for px in 0..os.n {
                            for c in 0..s.l {
                                let mut best = f32::NEG_INFINITY;
                                for wy in 0..self.spec.k {
                                    for wx in 0..self.spec.k {
                                        let iy = py * self.spec.stride + wy;
                                        let ix = px * self.spec.stride + wx;
                                        if iy >= s.m || ix >= s.n {
                                            continue;
                                        }
                                        best = best.max(img[(iy * s.n + ix) * s.l + c]);
                                    }
                                }
                                out_img[(py * os.n + px) * os.l + c] = best;
                            }
                        }
                    }
                }
                Act::Float(Tensor::from_stacked(t.batch, os, data))
            }
        }
    }

    fn param_bytes_float(&self) -> usize {
        0
    }

    fn param_bytes_packed(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn float_pool_basic() {
        let ws = Workspace::new();
        let t = Tensor::from_vec(
            Shape::new(2, 2, 1),
            vec![1.0, -3.0, 2.0, 0.5],
        );
        let mut p = MaxPoolLayer::new(2, 2);
        let os = Layer::<u64>::prepare(&mut p, t.shape);
        assert_eq!(os, Shape::new(1, 1, 1));
        let y = Layer::<u64>::forward(&p, Act::Float(t), Backend::Float, &ws).into_float();
        assert_eq!(y.data, vec![2.0]);
    }

    #[test]
    fn or_pool_equals_float_pool_on_signs() {
        let mut rng = Rng::new(101);
        let ws = Workspace::new();
        for &(m, n, l) in &[(4usize, 4usize, 8usize), (6, 6, 70), (5, 5, 3)] {
            let s = Shape::new(m, n, l);
            let mut d = vec![0f32; s.len()];
            rng.fill_signs(&mut d);
            let t = Tensor::from_vec(s, d);
            let p = MaxPoolLayer::new(2, 2);
            let ff = Layer::<u64>::forward(&p, Act::Float(t.clone()), Backend::Float, &ws)
                .into_float();
            let bt = BitTensor::<u64>::from_tensor_dir(&t, PackDir::Channels);
            let bb = Layer::<u64>::forward(&p, Act::Bits(bt), Backend::Binary, &ws)
                .into_float();
            assert_eq!(ff.shape, bb.shape);
            assert_eq!(ff.data, bb.data, "shape {s}");
        }
    }

    #[test]
    fn batched_pool_equals_per_image_pool() {
        let mut rng = Rng::new(102);
        let ws = Workspace::new();
        let s = Shape::new(4, 4, 70);
        let imgs: Vec<Tensor<f32>> = (0..3)
            .map(|_| {
                let mut d = vec![0f32; s.len()];
                rng.fill_signs(&mut d);
                Tensor::from_vec(s, d)
            })
            .collect();
        let refs: Vec<&Tensor<f32>> = imgs.iter().collect();
        let stacked = Tensor::stack(&refs);
        let p = MaxPoolLayer::new(2, 2);
        // float path
        let fb = Layer::<u64>::forward(&p, Act::Float(stacked.clone()), Backend::Float, &ws)
            .into_float();
        assert_eq!(fb.batch, 3);
        // binary OR-pool path
        let bt = BitTensor::<u64>::from_tensor_dir(&stacked, PackDir::Channels);
        let bb = Layer::<u64>::forward(&p, Act::Bits(bt), Backend::Binary, &ws).into_float();
        assert_eq!(bb.batch, 3);
        let per = fb.data.len() / 3;
        for (b, img) in imgs.iter().enumerate() {
            let single = Layer::<u64>::forward(&p, Act::Float(img.clone()), Backend::Float, &ws)
                .into_float();
            assert_eq!(&fb.data[b * per..(b + 1) * per], &single.data[..], "float {b}");
            assert_eq!(&bb.data[b * per..(b + 1) * per], &single.data[..], "bits {b}");
        }
    }

    #[test]
    fn overlapping_windows() {
        let ws = Workspace::new();
        let t = Tensor::from_vec(
            Shape::new(3, 3, 1),
            vec![1., 2., 3., 4., 5., 6., 7., 8., 9.],
        );
        let p = MaxPoolLayer::new(2, 1);
        let y = Layer::<u64>::forward(&p, Act::Float(t), Backend::Float, &ws).into_float();
        assert_eq!(y.shape, Shape::new(2, 2, 1));
        assert_eq!(y.data, vec![5., 6., 8., 9.]);
    }
}
