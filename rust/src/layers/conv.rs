//! Fused convolutional layer — the capability the paper contributes that
//! BinaryNet/neon lack (§5.2, §6.3) — with a batched hot path.
//!
//! Both paths compute convolution as unroll → GEMM → (free) lift:
//! * **float path** — zero-padded im2col + blocked sgemm;
//! * **binary path** — packed word-group unroll (out-of-bounds taps stay
//!   all-zero = −1), XNOR-popcount GEMM, then the paper's **zero-padding
//!   correction**: a matrix precomputed at `prepare` time (the filter
//!   taps' channel sums accumulated over each border pixel's
//!   out-of-bounds taps — exactly "the convolution of the layer's weights
//!   with a (+1)-padded zero-tensor") is added to the accumulator so the
//!   result equals true zero-padded convolution while the GEMM kernel
//!   stays branch-free.
//!
//! **Batching.** A batch of B images unrolls into one `(B·oh·ow) × k`
//! patch matrix and issues a SINGLE binary GEMM against the shared packed
//! filters — each loaded filter panel is amortized across every queued
//! image (the §5.2 weight-sweep reuse, extended along the batch axis).
//! The zero-padding correction is geometry-only, so one per-image matrix
//! is reused for all B images; pooling and threshold-packing run on
//! per-image blocks of the shared accumulator.
//!
//! Optional max-pool runs on the int32 accumulator *before* the folded
//! BN threshold (BinaryNet's conv→pool→BN→sign ordering), which is exact
//! for any γ sign; the packed OR-pool lives in `layers::pool` for
//! post-sign pooling.
//!
//! **Tile streaming (fused path).** The hot forwards never materialize
//! the `(B·oh·ow) × k` unrolled patch matrix: patches are unrolled
//! tile-by-tile into an L2-resident panel that feeds the GEMM
//! micro-kernel directly (`gemm_tiles_into` / `sgemm_tiles_into` /
//! `bitplane_gemm_tiles_into` with the `unroll_*_rows` producers), and
//! the batch is cut into **image groups** so the int32 accumulator and
//! the per-group tail (correction → pool → threshold-pack) stay bounded
//! by [`GROUP_ACC_BYTES`] instead of growing with B. Conv scratch is
//! thus O(tile · workers + group) rather than O(B·oh·ow·k); parallelism
//! runs at (tile × C-rows) granularity inside each group. The old
//! materializing path is retained as [`Layer::forward_materialized`] —
//! the equivalence oracle, mirroring the `forward_layerwalk` pattern —
//! and its reservations as `scratch_materialized`.

use super::{
    fold_quant, quantize_float_scores, Act, ActKind, ActView, Backend, BnParams, FoldedBn, Layer,
    OutRepr, PoolSpec, QuantFold, ScratchSpec,
};
use crate::alloc::Workspace;
use crate::bitpack::{
    bitplane_gemm_tiles_into, gemm_tiles_into, gemm_words_into, pack_signs_into,
    pack_thresholds_f32_into, pack_thresholds_into, words_for, Word,
};
use crate::linalg;
use crate::tensor::{
    out_dim, pack_filters, unroll_bits, unroll_bits_rows, unroll_f32, unroll_f32_rows,
    unroll_u8, unroll_u8_rows, unrolled_cols, BitTensor, PackDir, QuantTensor, ScaledBitTensor,
    Shape, Tensor,
};
use crate::util::parallel::{current_slot, parallel_for_mut_chunks};
use crate::util::tune::{self, Family};

/// Target footprint of the per-group int32 conv accumulator (and the f32
/// conv buffer on float-GEMM paths): the batch streams through in image
/// groups of at most this many accumulator bytes, so conv scratch no
/// longer scales with B.
const GROUP_ACC_BYTES: usize = 1 << 20;

/// Rows per unroll tile for this layer's GEMM — the autotuner registry's
/// choice when one exists, the legacy L2-sizing formula otherwise (the
/// registry default reproduces it exactly). Forward and `scratch` both
/// go through here, so panel reservations always match execution.
fn tuned_tile_rows(family: Family, word_bits: u32, n: usize, k: usize) -> usize {
    tune::lookup(family, word_bits, n, k).tile_rows
}

/// Fused conv block: conv (+ pool) (+ BatchNorm) (+ sign).
#[derive(Clone)]
pub struct ConvLayer<W: Word = u64> {
    /// Number of filters (output channels).
    pub filters: usize,
    pub kh: usize,
    pub kw: usize,
    /// Input channels.
    pub in_channels: usize,
    pub stride: usize,
    pub pad: usize,
    /// ±1 filter weights, layout `[f][ky][kx][l]`.
    w: Vec<f32>,
    /// Pre-packed filters (word-group layout matching `unroll_bits`).
    w_packed: Vec<W>,
    bn: Option<BnParams>,
    folded: Option<FoldedBn>,
    sign: bool,
    /// Output representation of the binarizing tail (`Sign` = legacy).
    repr: OutRepr,
    /// Activation quantization step Δ for the multi-bit output reprs.
    act_delta: f32,
    /// Per-output-channel XNOR-Net weight scales α (all > 0).
    alpha: Option<Vec<f32>>,
    /// Per-plane folded thresholds in the scaled-score (y) domain;
    /// present whenever a sign tail exists.
    qfold: Option<QuantFold>,
    pub pool: Option<PoolSpec>,
    /// Binary-optimize a `Bytes` (fixed-precision) input via bit-plane
    /// decomposition of the unrolled patches — the paper's first-layer
    /// optimization (§4.3) generalized to convolutions. When false, the
    /// first layer falls back to a float GEMM (BinaryNet behaviour).
    pub bitplane_first: bool,
    /// Flat-packed ±1 filters (`f × words(kh·kw·l)`) for the bit-plane
    /// path (tap channels NOT word-padded, unlike `w_packed`).
    w_packed_flat: Vec<W>,
    /// Bound input shape (set by `prepare`).
    in_shape: Option<Shape>,
    /// Zero-padding correction for ONE image, `oh·ow·filters`, empty when
    /// pad = 0. Geometry-only, so batches reuse it per image.
    correction: Vec<i32>,
}

impl<W: Word> ConvLayer<W> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        in_channels: usize,
        filters: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        weights: &[f32],
        bn: Option<BnParams>,
        sign: bool,
        pool: Option<PoolSpec>,
    ) -> Self {
        assert_eq!(weights.len(), filters * kh * kw * in_channels, "weights");
        if let Some(b) = &bn {
            b.validate();
            assert_eq!(b.features(), filters, "BN features == filters");
        }
        let w: Vec<f32> = weights
            .iter()
            .map(|&x| if x >= 0.0 { 1.0 } else { -1.0 })
            .collect();
        let w_packed = pack_filters::<W>(&w, filters, kh, kw, in_channels);
        let w_packed_flat =
            crate::bitpack::pack_matrix_rows::<W>(&w, filters, kh * kw * in_channels);
        let folded = match (&bn, sign) {
            (Some(b), true) => Some(b.fold()),
            (None, true) => Some(FoldedBn {
                tau: vec![0.0; filters],
                gamma_pos: vec![true; filters],
            }),
            _ => None,
        };
        let qfold = sign.then(|| fold_quant(bn.as_ref(), OutRepr::Sign, 1.0, filters));
        Self {
            filters,
            kh,
            kw,
            in_channels,
            stride,
            pad,
            w,
            w_packed,
            bn,
            folded,
            sign,
            repr: OutRepr::Sign,
            act_delta: 1.0,
            alpha: None,
            qfold,
            pool,
            // default off: profitable only for wide patches (k ≳ a few
            // hundred bits); the CIFAR first layer is 3×3×3 = 27 bits,
            // where per-dot bit-plane overhead exceeds the float GEMM
            // (measured in the A1-conv ablation)
            bitplane_first: false,
            w_packed_flat,
            in_shape: None,
            correction: Vec::new(),
        }
    }

    /// Select the output representation and scale epilogue (see
    /// [`DenseLayer::configure_repr`](super::DenseLayer::configure_repr)).
    pub fn configure_repr(&mut self, repr: OutRepr, act_delta: f32, alpha: Option<Vec<f32>>) {
        assert!(
            self.sign || repr == OutRepr::Sign,
            "quantized output reprs require a sign/activation tail"
        );
        assert!(act_delta > 0.0, "act_delta must be positive");
        if let Some(a) = &alpha {
            assert_eq!(a.len(), self.filters, "alpha length");
            assert!(a.iter().all(|&v| v > 0.0), "alpha must be positive");
        }
        self.repr = repr;
        self.act_delta = act_delta;
        self.alpha = alpha;
        self.qfold = self
            .sign
            .then(|| fold_quant(self.bn.as_ref(), repr, act_delta, self.filters));
    }

    /// Output representation of the activation tail.
    pub fn repr(&self) -> OutRepr {
        self.repr
    }

    /// Output activation quantization step.
    pub fn act_delta(&self) -> f32 {
        self.act_delta
    }

    /// Per-output-channel weight scales, if configured.
    pub fn alpha(&self) -> Option<&[f32]> {
        self.alpha.as_deref()
    }

    #[inline(always)]
    fn alpha_at(&self, f: usize) -> f32 {
        self.alpha.as_ref().map_or(1.0, |a| a[f])
    }

    /// Legacy tail shape: plain ±1 semantics with no scale epilogue.
    /// Guarantees bit-identical outputs for pre-repr networks.
    fn plain_tail(&self, in_delta: f32) -> bool {
        self.repr == OutRepr::Sign && self.alpha.is_none() && in_delta == 1.0
    }

    fn conv_out_shape(&self, s: Shape) -> Shape {
        Shape {
            m: out_dim(s.m, self.kh, self.stride, self.pad),
            n: out_dim(s.n, self.kw, self.stride, self.pad),
            l: self.filters,
        }
    }

    /// Post-pool per-image output geometry: `(out_shape, out_elems)`;
    /// identity when no pool is fused. The single source of truth the
    /// streamed forwards and the scratch reservations share — they must
    /// agree for the no-miss pool story to hold.
    fn pooled_geom(&self, conv_shape: Shape) -> (Shape, usize) {
        match self.pool {
            Some(spec) => {
                let ph = out_dim(conv_shape.m, spec.k, spec.stride, 0);
                let pw = out_dim(conv_shape.n, spec.k, spec.stride, 0);
                (Shape::new(ph, pw, self.filters), ph * pw * self.filters)
            }
            None => (conv_shape, conv_shape.len()),
        }
    }

    /// Paper §5.2: correction = conv(W, +1-padded zero tensor). For each
    /// output pixel, sum — over taps that fall outside the input — the
    /// filter's channel sum at that tap. Adding this to the (−1)-padded
    /// binary GEMM yields exact zero-padded convolution.
    fn build_correction(&self, s: Shape) -> Vec<i32> {
        if self.pad == 0 {
            return Vec::new();
        }
        let (f, kh, kw, l) = (self.filters, self.kh, self.kw, self.in_channels);
        // tap_sum[fi][tap] = Σ_c w[fi][tap][c]
        let mut tap_sum = vec![0i32; f * kh * kw];
        for fi in 0..f {
            for t in 0..kh * kw {
                let base = (fi * kh * kw + t) * l;
                tap_sum[fi * kh * kw + t] =
                    self.w[base..base + l].iter().map(|&x| x as i32).sum();
            }
        }
        let oh = out_dim(s.m, kh, self.stride, self.pad);
        let ow = out_dim(s.n, kw, self.stride, self.pad);
        let mut corr = vec![0i32; oh * ow * f];
        for oy in 0..oh {
            for ox in 0..ow {
                // interior pixels have no OOB taps — skip fast
                for ky in 0..kh {
                    let iy = (oy * self.stride + ky) as isize - self.pad as isize;
                    for kx in 0..kw {
                        let ix = (ox * self.stride + kx) as isize - self.pad as isize;
                        let oob =
                            iy < 0 || iy as usize >= s.m || ix < 0 || ix as usize >= s.n;
                        if !oob {
                            continue;
                        }
                        let tap = ky * kw + kx;
                        for fi in 0..f {
                            corr[(oy * ow + ox) * f + fi] += tap_sum[fi * kh * kw + tap];
                        }
                    }
                }
            }
        }
        corr
    }

    /// Add the per-image zero-padding correction to every image block of
    /// a batched accumulator. Output pixels are independent, so the add
    /// sweep parallelizes across pixel rows (part of keeping the conv
    /// tail off the critical path at batch 1). `mul` is the per-plane
    /// multiplier of the input representation: every out-of-bounds tap
    /// contributes `-tap_sum` per bit plane, so a P-plane combined
    /// accumulator needs `P×` the ±1 correction to model true real-zero
    /// padding (ternary combines 2 planes then halves → ×1; 2-bit sums
    /// 3 planes → ×3; plain/scaled sign bits → ×1; byte paths → ×0).
    fn apply_correction(&self, acc: &mut [i32], batch: usize, mul: i32) {
        if self.correction.is_empty() || mul == 0 {
            return;
        }
        let block = self.correction.len();
        debug_assert_eq!(acc.len(), batch * block);
        let f = self.filters;
        let rows_img = block / f;
        let corr = &self.correction;
        let grain = ((1 << 17) / f.max(1)).max(16);
        parallel_for_mut_chunks(acc, f, grain, |r0, chunk| {
            for (rr, dst) in chunk.chunks_mut(f).enumerate() {
                let pixel = (r0 + rr) % rows_img;
                for (a, &c) in dst.iter_mut().zip(&corr[pixel * f..(pixel + 1) * f]) {
                    *a += c * mul;
                }
            }
        });
    }

    /// Max-pool one image's int32 accumulator (`oh·ow` rows, `f` channels
    /// interleaved) down to the pooled geometry. Pooled pixels are
    /// independent, so the sweep parallelizes across output rows.
    fn pool_i32(&self, acc: &[i32], oh: usize, ow: usize, spec: PoolSpec, out: &mut [i32]) {
        let f = self.filters;
        let ph = out_dim(oh, spec.k, spec.stride, 0);
        let pw = out_dim(ow, spec.k, spec.stride, 0);
        assert_eq!(out.len(), ph * pw * f);
        let grain = ((1 << 17) / (spec.k * spec.k * f).max(1)).max(8);
        parallel_for_mut_chunks(out, f, grain, |p0, chunk| {
            for (pp, dst) in chunk.chunks_mut(f).enumerate() {
                let p = p0 + pp;
                let (py, px) = (p / pw, p % pw);
                dst.fill(i32::MIN);
                for wy in 0..spec.k {
                    for wx in 0..spec.k {
                        let iy = py * spec.stride + wy;
                        let ix = px * spec.stride + wx;
                        if iy >= oh || ix >= ow {
                            continue;
                        }
                        let src = &acc[(iy * ow + ix) * f..(iy * ow + ix + 1) * f];
                        for (d, &s) in dst.iter_mut().zip(src) {
                            *d = (*d).max(s);
                        }
                    }
                }
            }
        });
    }

    /// Images per streamed group: the group's int32 accumulator stays at
    /// or under [`GROUP_ACC_BYTES`] (always at least one image). Shared
    /// by the fused forwards and [`Layer::scratch`] so reservations match
    /// the hot path exactly.
    fn group_images(&self, rows_img: usize, batch: usize) -> usize {
        let per_image = rows_img * self.filters * 4;
        (GROUP_ACC_BYTES / per_image.max(1)).clamp(1, batch.max(1))
    }

    /// Streaming executor shared by every fused binary path. The batch is
    /// cut into image groups; `gemm_group(row0, row1, acc)` fills the
    /// group's int32 accumulator for global patch rows `[row0, row1)` of
    /// the virtual unrolled matrix; the tail (−1-padding `correct`ion,
    /// int pool, threshold-pack or score lift) then runs per group, so
    /// scratch stays O(group) regardless of batch size. Bit-identical to
    /// the materialized path: the per-row GEMM order and the per-pixel
    /// tail operations are unchanged, only their interleaving differs.
    fn forward_binary_streamed(
        &self,
        in_shape: Shape,
        batch: usize,
        corr_mul: i32,
        in_delta: f32,
        ws: &Workspace,
        gemm_group: &mut dyn FnMut(usize, usize, &mut [i32]),
    ) -> Act<W> {
        let f = self.filters;
        let conv_shape = self.conv_out_shape(in_shape);
        let rows_img = conv_shape.m * conv_shape.n;
        let group = self.group_images(rows_img, batch);
        let src_block = rows_img * f;
        let (out_shape, dst_block) = self.pooled_geom(conv_shape);
        // tail flavour: `plain` is the pre-repr pipeline (bit-identical);
        // `needs_float` lifts scaled scores to f32 (score output or the
        // ScaledSign tail, which requires |y|); the remainder
        // threshold-packs each output plane straight off the integers
        let plain = self.plain_tail(in_delta);
        let needs_float = !plain && (!self.sign || self.repr == OutRepr::ScaledSign);
        let plane_pack = !plain && !needs_float;
        // caller-affine: the request thread reacquires the same warm
        // accumulators across layers and requests
        let mut acc = ws.i32s.acquire_affine(current_slot(), group * src_block);
        let mut pooled = self
            .pool
            .map(|_| ws.i32s.acquire_affine(current_slot(), group * dst_block));
        let lw = words_for::<W>(f);
        let out_pixels_img = out_shape.m * out_shape.n;
        // the escaping output activation is the only allocation here
        let mut packed = if plain && self.folded.is_some() {
            vec![W::ZERO; batch * out_pixels_img * lw]
        } else {
            Vec::new()
        };
        let mut scores = if (plain && self.folded.is_none()) || needs_float {
            vec![0f32; batch * dst_block]
        } else {
            Vec::new()
        };
        // integer-domain runtime thresholds: y = acc·Δ_in·α ≥ τ  ⇔
        // acc ≥ τ/(Δ_in·α)  (both divisors positive ⇒ direction kept)
        let taus_rt: Vec<Vec<f32>> = if plane_pack {
            let qf = self.qfold.as_ref().expect("sign tail folded");
            qf.taus
                .iter()
                .map(|tau| {
                    (0..f)
                        .map(|fi| tau[fi] / (in_delta * self.alpha_at(fi)))
                        .collect()
                })
                .collect()
        } else {
            Vec::new()
        };
        let mut plane_bufs: Vec<Vec<W>> = if plane_pack {
            (0..self.repr.planes())
                .map(|_| vec![W::ZERO; batch * out_pixels_img * lw])
                .collect()
        } else {
            Vec::new()
        };
        let mut g0 = 0usize;
        while g0 < batch {
            let g1 = (g0 + group).min(batch);
            let g = g1 - g0;
            let acc_g = &mut acc[..g * src_block];
            gemm_group(g0 * rows_img, g1 * rows_img, &mut acc_g[..]);
            self.apply_correction(acc_g, g, corr_mul);
            let acc2: &[i32] = if let Some(spec) = self.pool {
                let pb = pooled.as_mut().unwrap();
                for b in 0..g {
                    self.pool_i32(
                        &acc_g[b * src_block..(b + 1) * src_block],
                        conv_shape.m,
                        conv_shape.n,
                        spec,
                        &mut pb[b * dst_block..(b + 1) * dst_block],
                    );
                }
                &pb[..g * dst_block]
            } else {
                &acc_g[..]
            };
            if plain {
                if let Some(fold) = &self.folded {
                    // output pixels threshold-pack independently: parallel
                    // across pixel rows so the tail scales with the GEMM
                    let base = g0 * out_pixels_img;
                    let rows = g * out_pixels_img;
                    let dst = &mut packed[base * lw..(base + rows) * lw];
                    let grain = ((1 << 17) / f.max(1)).max(16);
                    parallel_for_mut_chunks(dst, lw, grain, |p0, chunk| {
                        for (pp, row) in chunk.chunks_mut(lw).enumerate() {
                            let p = p0 + pp;
                            pack_thresholds_into(
                                &acc2[p * f..(p + 1) * f],
                                &fold.tau,
                                &fold.gamma_pos,
                                row,
                            );
                        }
                    });
                } else {
                    for (d, &v) in scores[g0 * dst_block..g1 * dst_block].iter_mut().zip(acc2)
                    {
                        *d = v as f32;
                    }
                }
            } else if needs_float {
                let dst = &mut scores[g0 * dst_block..g1 * dst_block];
                for (px, chunk) in dst.chunks_mut(f).enumerate() {
                    let src = &acc2[px * f..(px + 1) * f];
                    for (fi, (d, &v)) in chunk.iter_mut().zip(src).enumerate() {
                        *d = v as f32 * (in_delta * self.alpha_at(fi));
                    }
                }
            } else {
                let base = g0 * out_pixels_img;
                let rows = g * out_pixels_img;
                let grain = ((1 << 17) / f.max(1)).max(16);
                let qf = self.qfold.as_ref().expect("sign tail folded");
                for (t, buf) in plane_bufs.iter_mut().enumerate() {
                    let dst = &mut buf[base * lw..(base + rows) * lw];
                    let tau = &taus_rt[t];
                    parallel_for_mut_chunks(dst, lw, grain, |p0, chunk| {
                        for (pp, row) in chunk.chunks_mut(lw).enumerate() {
                            let p = p0 + pp;
                            pack_thresholds_into(
                                &acc2[p * f..(p + 1) * f],
                                tau,
                                &qf.gamma_pos,
                                row,
                            );
                        }
                    });
                }
            }
            g0 = g1;
        }
        if plain {
            if self.folded.is_some() {
                Act::Bits(BitTensor {
                    shape: out_shape,
                    batch,
                    dir: PackDir::Channels,
                    group_words: lw,
                    data: packed,
                })
            } else {
                if let Some(bn) = &self.bn {
                    bn.apply(&mut scores);
                }
                if self.sign {
                    for v in scores.iter_mut() {
                        *v = if *v >= 0.0 { 1.0 } else { -1.0 };
                    }
                }
                Act::Float(Tensor::from_stacked(batch, out_shape, scores))
            }
        } else if needs_float {
            self.finish_float_channels(scores, out_shape, batch)
        } else {
            self.wrap_planes(plane_bufs, out_shape, batch)
        }
    }

    /// Wrap per-plane channel-packed pixel rows into the output variant.
    fn wrap_planes(&self, plane_bufs: Vec<Vec<W>>, out_shape: Shape, batch: usize) -> Act<W> {
        let lw = words_for::<W>(self.filters);
        let mk = |data: Vec<W>| BitTensor {
            shape: out_shape,
            batch,
            dir: PackDir::Channels,
            group_words: lw,
            data,
        };
        let mut it = plane_bufs.into_iter();
        if self.repr.planes() == 1 {
            Act::Bits(mk(it.next().expect("one plane")))
        } else {
            Act::Quant(QuantTensor {
                planes: it.map(mk).collect(),
                delta: self.act_delta,
            })
        }
    }

    /// Finish from real-valued post-pool scores `y` (pre-BN, channel
    /// interleaved, `batch·out_pixels·filters` long): apply BN, then the
    /// configured representation tail, grouped per output pixel.
    fn finish_float_channels(&self, mut y: Vec<f32>, out_shape: Shape, batch: usize) -> Act<W> {
        if let Some(bn) = &self.bn {
            bn.apply(&mut y);
        }
        if !self.sign {
            return Act::Float(Tensor::from_stacked(batch, out_shape, y));
        }
        let f = self.filters;
        let lw = words_for::<W>(f);
        let pixels = batch * out_shape.m * out_shape.n;
        match self.repr {
            OutRepr::Sign => {
                let mut data = vec![W::ZERO; pixels * lw];
                for p in 0..pixels {
                    pack_signs_into(&y[p * f..(p + 1) * f], &mut data[p * lw..(p + 1) * lw]);
                }
                Act::Bits(BitTensor {
                    shape: out_shape,
                    batch,
                    dir: PackDir::Channels,
                    group_words: lw,
                    data,
                })
            }
            OutRepr::ScaledSign => {
                let mut data = vec![W::ZERO; pixels * lw];
                let mut scale = Vec::with_capacity(pixels);
                for p in 0..pixels {
                    let px = &y[p * f..(p + 1) * f];
                    scale.push(px.iter().map(|v| v.abs()).sum::<f32>() / f as f32);
                    pack_signs_into(px, &mut data[p * lw..(p + 1) * lw]);
                }
                Act::Scaled(ScaledBitTensor {
                    bits: BitTensor {
                        shape: out_shape,
                        batch,
                        dir: PackDir::Channels,
                        group_words: lw,
                        data,
                    },
                    scale,
                })
            }
            OutRepr::Quant2 | OutRepr::Ternary => {
                let planes = self.repr.planes();
                let pos = vec![true; f];
                let mut bufs: Vec<Vec<W>> =
                    (0..planes).map(|_| vec![W::ZERO; pixels * lw]).collect();
                for (t, &thr) in self.repr.level_thresholds().iter().enumerate() {
                    let tau = vec![self.act_delta * thr; f];
                    for p in 0..pixels {
                        pack_thresholds_f32_into(
                            &y[p * f..(p + 1) * f],
                            &tau,
                            &pos,
                            &mut bufs[t][p * lw..(p + 1) * lw],
                        );
                    }
                }
                self.wrap_planes(bufs, out_shape, batch)
            }
        }
    }

    /// Float-backend analogue of [`ConvLayer::forward_binary_streamed`]:
    /// `gemm_group` fills the group's f32 conv buffer; pooling writes
    /// straight into the escaping output, BN/sign run once at the end.
    fn forward_float_streamed(
        &self,
        in_shape: Shape,
        batch: usize,
        ws: &Workspace,
        gemm_group: &mut dyn FnMut(usize, usize, &mut [f32]),
    ) -> Act<W> {
        let f = self.filters;
        let conv_shape = self.conv_out_shape(in_shape);
        let rows_img = conv_shape.m * conv_shape.n;
        let group = self.group_images(rows_img, batch);
        let src_block = rows_img * f;
        let (out_shape, dst_block) = self.pooled_geom(conv_shape);
        let mut conv = ws.f32s.acquire_affine(current_slot(), group * src_block);
        let mut y = vec![0f32; batch * dst_block];
        let mut g0 = 0usize;
        while g0 < batch {
            let g1 = (g0 + group).min(batch);
            let g = g1 - g0;
            let conv_g = &mut conv[..g * src_block];
            gemm_group(g0 * rows_img, g1 * rows_img, &mut conv_g[..]);
            if let Some(spec) = self.pool {
                for b in 0..g {
                    pool_f32(
                        &conv_g[b * src_block..(b + 1) * src_block],
                        conv_shape.m,
                        conv_shape.n,
                        f,
                        spec,
                        &mut y[(g0 + b) * dst_block..(g0 + b + 1) * dst_block],
                    );
                }
            } else {
                y[g0 * dst_block..g1 * dst_block].copy_from_slice(conv_g);
            }
            g0 = g1;
        }
        self.float_epilogue(&mut y);
        Act::Float(Tensor::from_stacked(batch, out_shape, y))
    }

    /// Float-backend tail: α weight scales, BN, then the representation's
    /// float-domain quantizer (plain ± sign for the legacy repr).
    fn float_epilogue(&self, y: &mut Vec<f32>) {
        let f = self.filters;
        if let Some(al) = &self.alpha {
            for chunk in y.chunks_mut(f) {
                for (v, &a) in chunk.iter_mut().zip(al.iter()) {
                    *v *= a;
                }
            }
        }
        if let Some(bn) = &self.bn {
            bn.apply(y);
        }
        if self.sign {
            quantize_float_scores(self.repr, self.act_delta, y, f);
        }
    }

    /// Shared tail of the *materialized* reference path: batched int32
    /// accumulator (+per-image pool) → threshold-pack or float. `acc`
    /// holds `batch` image blocks of `conv_shape.m · conv_shape.n ·
    /// filters` values.
    fn finish_binary(
        &self,
        acc: &[i32],
        conv_shape: Shape,
        batch: usize,
        in_delta: f32,
        ws: &Workspace,
    ) -> Act<W> {
        let f = self.filters;
        let pooled_buf;
        let (acc2, shape): (&[i32], Shape) = if let Some(spec) = self.pool {
            let ph = out_dim(conv_shape.m, spec.k, spec.stride, 0);
            let pw = out_dim(conv_shape.n, spec.k, spec.stride, 0);
            let src_block = conv_shape.m * conv_shape.n * f;
            let dst_block = ph * pw * f;
            let mut pooled = ws.i32s.acquire(batch * dst_block);
            {
                let pooled_s: &mut [i32] = &mut pooled;
                for b in 0..batch {
                    self.pool_i32(
                        &acc[b * src_block..(b + 1) * src_block],
                        conv_shape.m,
                        conv_shape.n,
                        spec,
                        &mut pooled_s[b * dst_block..(b + 1) * dst_block],
                    );
                }
            }
            pooled_buf = pooled;
            (&pooled_buf[..], Shape::new(ph, pw, f))
        } else {
            (acc, conv_shape)
        };
        if self.plain_tail(in_delta) {
            if let Some(fold) = &self.folded {
                let lw = words_for::<W>(f);
                let pixels = batch * shape.m * shape.n;
                let mut data = vec![W::ZERO; pixels * lw];
                for p in 0..pixels {
                    pack_thresholds_into(
                        &acc2[p * f..(p + 1) * f],
                        &fold.tau,
                        &fold.gamma_pos,
                        &mut data[p * lw..(p + 1) * lw],
                    );
                }
                return Act::Bits(BitTensor {
                    shape,
                    batch,
                    dir: PackDir::Channels,
                    group_words: lw,
                    data,
                });
            }
            let mut scores: Vec<f32> = acc2.iter().map(|&v| v as f32).collect();
            if let Some(bn) = &self.bn {
                bn.apply(&mut scores);
            }
            if self.sign {
                for v in scores.iter_mut() {
                    *v = if *v >= 0.0 { 1.0 } else { -1.0 };
                }
            }
            return Act::Float(Tensor::from_stacked(batch, shape, scores));
        }
        if !self.sign || self.repr == OutRepr::ScaledSign {
            let mut y = Vec::with_capacity(acc2.len());
            for chunk in acc2.chunks(f) {
                for (fi, &v) in chunk.iter().enumerate() {
                    y.push(v as f32 * (in_delta * self.alpha_at(fi)));
                }
            }
            return self.finish_float_channels(y, shape, batch);
        }
        // integer-domain plane pack (same thresholds as the fused tail)
        let qf = self.qfold.as_ref().expect("sign tail folded");
        let planes = self.repr.planes();
        let lw = words_for::<W>(f);
        let pixels = batch * shape.m * shape.n;
        let mut bufs: Vec<Vec<W>> = (0..planes).map(|_| vec![W::ZERO; pixels * lw]).collect();
        for (t, tau_y) in qf.taus.iter().enumerate() {
            let tau: Vec<f32> = (0..f)
                .map(|fi| tau_y[fi] / (in_delta * self.alpha_at(fi)))
                .collect();
            for p in 0..pixels {
                pack_thresholds_into(
                    &acc2[p * f..(p + 1) * f],
                    &tau,
                    &qf.gamma_pos,
                    &mut bufs[t][p * lw..(p + 1) * lw],
                );
            }
        }
        self.wrap_planes(bufs, shape, batch)
    }

    /// Fused float forward: tile-streamed unroll → panel sgemm → grouped
    /// pool/BN/sign tail.
    fn forward_float_t(&self, xf: &Tensor<f32>, ws: &Workspace) -> Act<W> {
        let s = xf.shape;
        let batch = xf.batch;
        assert_eq!(s.l, self.in_channels, "input channels");
        let (_, kc) = unrolled_cols(s, self.kh, self.kw, self.stride, self.pad);
        let tile = tuned_tile_rows(Family::Float, 32, self.filters, kc);
        let mut gemm_group = |r0: usize, r1: usize, conv_g: &mut [f32]| {
            linalg::sgemm_tiles_into(
                &self.w,
                conv_g,
                r1 - r0,
                self.filters,
                kc,
                tile,
                &ws.f32s,
                &|t0, t1, panel: &mut [f32]| {
                    unroll_f32_rows(
                        xf,
                        self.kh,
                        self.kw,
                        self.stride,
                        self.pad,
                        r0 + t0,
                        r0 + t1,
                        panel,
                    );
                },
            );
        };
        self.forward_float_streamed(s, batch, ws, &mut gemm_group)
    }

    /// Materialized-oracle float forward: full im2col + one sgemm.
    fn forward_float_materialized(&self, xf: &Tensor<f32>, ws: &Workspace) -> Act<W> {
        let s = xf.shape;
        let batch = xf.batch;
        assert_eq!(s.l, self.in_channels, "input channels");
        let (rows_img, kc) = unrolled_cols(s, self.kh, self.kw, self.stride, self.pad);
        let rows = batch * rows_img;
        let mut unrolled = ws.f32s.acquire(rows * kc);
        unroll_f32(xf, self.kh, self.kw, self.stride, self.pad, &mut unrolled);
        let mut conv = ws.f32s.acquire(rows * self.filters);
        linalg::sgemm_into(&unrolled, &self.w, &mut conv, rows, self.filters, kc);
        let conv_shape = self.conv_out_shape(s);
        // float path mirrors the binary tail in float domain
        let (mut y, shape) = if let Some(spec) = self.pool {
            let ph = out_dim(conv_shape.m, spec.k, spec.stride, 0);
            let pw = out_dim(conv_shape.n, spec.k, spec.stride, 0);
            let src_block = rows_img * self.filters;
            let dst_block = ph * pw * self.filters;
            let conv_s: &[f32] = &conv;
            let mut pooled = vec![f32::NEG_INFINITY; batch * dst_block];
            for b in 0..batch {
                pool_f32(
                    &conv_s[b * src_block..(b + 1) * src_block],
                    conv_shape.m,
                    conv_shape.n,
                    self.filters,
                    spec,
                    &mut pooled[b * dst_block..(b + 1) * dst_block],
                );
            }
            (pooled, Shape::new(ph, pw, self.filters))
        } else {
            (conv.to_vec(), conv_shape)
        };
        self.float_epilogue(&mut y);
        Act::Float(Tensor::from_stacked(batch, shape, y))
    }

    /// Fused first-layer forward on fixed-precision bytes: tile-streamed
    /// u8 unroll feeding either the bit-plane GEMM or (BinaryNet mode) a
    /// float panel GEMM whose group results widen into the shared int32
    /// tail. Zero padding is exact in the integer domain — no correction.
    fn forward_binary_bytes(&self, t: &Tensor<u8>, ws: &Workspace) -> Act<W> {
        let s = t.shape;
        let batch = t.batch;
        assert_eq!(s.l, self.in_channels, "input channels");
        let (rows_img, kc) = unrolled_cols(s, self.kh, self.kw, self.stride, self.pad);
        if self.bitplane_first {
            let tile = tuned_tile_rows(Family::Bitplane, W::BITS as u32, self.filters, kc);
            let mut gemm_group = |r0: usize, r1: usize, acc_g: &mut [i32]| {
                bitplane_gemm_tiles_into::<W>(
                    &self.w_packed_flat,
                    acc_g,
                    r1 - r0,
                    self.filters,
                    kc,
                    tile,
                    &ws.bytes,
                    &|t0, t1, panel: &mut [u8]| {
                        unroll_u8_rows(
                            t,
                            self.kh,
                            self.kw,
                            self.stride,
                            self.pad,
                            r0 + t0,
                            r0 + t1,
                            panel,
                        );
                    },
                );
            };
            self.forward_binary_streamed(s, batch, 0, 1.0, ws, &mut gemm_group)
        } else {
            // BinaryNet behaviour: float GEMM on raw pixels (accumulators
            // are exact small integers). The widened input is O(input);
            // the patch matrix stays virtual.
            let xf = t.to_f32();
            let tile = tuned_tile_rows(Family::Float, 32, self.filters, kc);
            let group = self.group_images(rows_img, batch);
            let mut conv =
                ws.f32s.acquire_affine(current_slot(), group * rows_img * self.filters);
            let mut gemm_group = |r0: usize, r1: usize, acc_g: &mut [i32]| {
                let conv_g = &mut conv[..acc_g.len()];
                linalg::sgemm_tiles_into(
                    &self.w,
                    conv_g,
                    r1 - r0,
                    self.filters,
                    kc,
                    tile,
                    &ws.f32s,
                    &|t0, t1, panel: &mut [f32]| {
                        unroll_f32_rows(
                            &xf,
                            self.kh,
                            self.kw,
                            self.stride,
                            self.pad,
                            r0 + t0,
                            r0 + t1,
                            panel,
                        );
                    },
                );
                for (a, &v) in acc_g.iter_mut().zip(conv_g.iter()) {
                    *a = v as i32;
                }
            };
            self.forward_binary_streamed(s, batch, 0, 1.0, ws, &mut gemm_group)
        }
    }

    /// Materialized-oracle first-layer forward (full patch matrix).
    fn forward_binary_bytes_materialized(&self, t: &Tensor<u8>, ws: &Workspace) -> Act<W> {
        let s = t.shape;
        let batch = t.batch;
        assert_eq!(s.l, self.in_channels, "input channels");
        let conv_shape = self.conv_out_shape(s);
        let rows = batch * conv_shape.m * conv_shape.n;
        let (rows_img, kc) = unrolled_cols(s, self.kh, self.kw, self.stride, self.pad);
        debug_assert_eq!(rows, batch * rows_img);
        if self.bitplane_first {
            // Bit-plane first conv layer (paper §4.3 extended to
            // conv): unroll the u8 patches (zero padding = pixel
            // value 0 — exact, no correction matrix needed in the
            // integer domain), then bit-plane GEMM against the
            // flat-packed filters. The whole batch shares one GEMM.
            let mut patches = ws.bytes.acquire(rows * kc);
            unroll_u8(t, self.kh, self.kw, self.stride, self.pad, &mut patches);
            let mut acc = ws.i32s.acquire(rows * self.filters);
            crate::bitpack::bitplane_gemm_into::<W>(
                &patches,
                &self.w_packed_flat,
                &mut acc,
                rows,
                self.filters,
                kc,
            );
            self.finish_binary(&acc, conv_shape, batch, 1.0, ws)
        } else {
            // BinaryNet behaviour: float GEMM on raw pixels
            // (accumulators are exact small integers).
            let xf = t.to_f32();
            let mut unrolled = ws.f32s.acquire(rows * kc);
            unroll_f32(&xf, self.kh, self.kw, self.stride, self.pad, &mut unrolled);
            let mut conv = ws.f32s.acquire(rows * self.filters);
            linalg::sgemm_into(&unrolled, &self.w, &mut conv, rows, self.filters, kc);
            let mut acc = ws.i32s.acquire(rows * self.filters);
            for (a, &v) in acc.iter_mut().zip(conv.iter()) {
                *a = v as i32;
            }
            self.finish_binary(&acc, conv_shape, batch, 1.0, ws)
        }
    }

    /// Fused packed-input forward: tile-streamed word unroll → panel
    /// XNOR-popcount GEMM → grouped correction/pool/threshold tail. The
    /// unrolled word matrix is never materialized.
    fn forward_binary_bits(&self, bt: &BitTensor<W>, ws: &Workspace) -> Act<W> {
        assert_eq!(bt.dir, PackDir::Channels, "conv input packing");
        let s = bt.shape;
        let batch = bt.batch;
        assert_eq!(s.l, self.in_channels, "input channels");
        let lw = bt.group_words;
        let row_words = self.kh * self.kw * lw;
        let k_bits = self.kh * self.kw * self.in_channels;
        let tile = tuned_tile_rows(Family::Binary, W::BITS as u32, self.filters, row_words);
        let mut gemm_group = |r0: usize, r1: usize, acc_g: &mut [i32]| {
            gemm_tiles_into::<W>(
                &self.w_packed,
                acc_g,
                r1 - r0,
                self.filters,
                row_words,
                k_bits,
                tile,
                W::pool(ws),
                &|t0, t1, panel: &mut [W]| {
                    unroll_bits_rows(
                        bt,
                        self.kh,
                        self.kw,
                        self.stride,
                        self.pad,
                        r0 + t0,
                        r0 + t1,
                        panel,
                    );
                },
            );
        };
        self.forward_binary_streamed(s, batch, 1, 1.0, ws, &mut gemm_group)
    }

    /// Materialized-oracle packed-input forward (full word matrix + one
    /// GEMM), retained as the equivalence oracle for the fused path.
    fn forward_binary_bits_materialized(&self, bt: &BitTensor<W>, ws: &Workspace) -> Act<W> {
        assert_eq!(bt.dir, PackDir::Channels, "conv input packing");
        let s = bt.shape;
        let batch = bt.batch;
        assert_eq!(s.l, self.in_channels, "input channels");
        let conv_shape = self.conv_out_shape(s);
        let rows = batch * conv_shape.m * conv_shape.n;
        let lw = bt.group_words;
        let row_words = self.kh * self.kw * lw;
        let k_bits = self.kh * self.kw * self.in_channels;
        let mut unrolled = W::pool(ws).acquire(rows * row_words);
        unroll_bits(bt, self.kh, self.kw, self.stride, self.pad, &mut unrolled);
        let mut acc = ws.i32s.acquire(rows * self.filters);
        gemm_words_into::<W>(
            &unrolled,
            &self.w_packed,
            &mut acc,
            rows,
            self.filters,
            row_words,
            k_bits,
        );
        self.apply_correction(&mut acc, batch, 1);
        self.finish_binary(&acc, conv_shape, batch, 1.0, ws)
    }

    /// Per-plane correction multiplier and halving flag for a multi-bit
    /// input: ternary sums 2 plane GEMMs and halves (plane sums are always
    /// even — each plane dot ≡ k (mod 2)); 2-bit sums 3 planes unhalved.
    fn quant_combine(planes: usize) -> (bool, i32) {
        match planes {
            2 => (true, 1),
            3 => (false, 3),
            p => panic!("unsupported plane count {p}"),
        }
    }

    /// Fused multi-bit (thermometer-plane) input forward: one tile-
    /// streamed XNOR GEMM per plane into a shared group accumulator; the
    /// exact plane combination keeps the integer tail unchanged.
    fn forward_binary_quant(&self, qt: &QuantTensor<W>, ws: &Workspace) -> Act<W> {
        let bt0 = &qt.planes[0];
        assert_eq!(bt0.dir, PackDir::Channels, "conv input packing");
        let s = bt0.shape;
        let batch = bt0.batch;
        assert_eq!(s.l, self.in_channels, "input channels");
        let lw = bt0.group_words;
        let row_words = self.kh * self.kw * lw;
        let k_bits = self.kh * self.kw * self.in_channels;
        let tile = tuned_tile_rows(Family::Binary, W::BITS as u32, self.filters, row_words);
        let (halve, corr_mul) = Self::quant_combine(qt.planes.len());
        let conv_shape = self.conv_out_shape(s);
        let rows_img = conv_shape.m * conv_shape.n;
        let group = self.group_images(rows_img, batch);
        let mut plane_acc = ws
            .i32s
            .acquire_affine(current_slot(), group * rows_img * self.filters);
        let run = |plane: &BitTensor<W>, dst: &mut [i32], r0: usize, r1: usize| {
            gemm_tiles_into::<W>(
                &self.w_packed,
                dst,
                r1 - r0,
                self.filters,
                row_words,
                k_bits,
                tile,
                W::pool(ws),
                &|t0, t1, panel: &mut [W]| {
                    unroll_bits_rows(
                        plane,
                        self.kh,
                        self.kw,
                        self.stride,
                        self.pad,
                        r0 + t0,
                        r0 + t1,
                        panel,
                    );
                },
            );
        };
        let mut gemm_group = |r0: usize, r1: usize, acc_g: &mut [i32]| {
            for (pi, plane) in qt.planes.iter().enumerate() {
                if pi == 0 {
                    run(plane, acc_g, r0, r1);
                } else {
                    let tmp = &mut plane_acc[..acc_g.len()];
                    run(plane, tmp, r0, r1);
                    for (a, &t) in acc_g.iter_mut().zip(tmp.iter()) {
                        *a += t;
                    }
                }
            }
            if halve {
                for v in acc_g.iter_mut() {
                    debug_assert_eq!(*v % 2, 0, "ternary plane sum must be even");
                    *v /= 2;
                }
            }
        };
        self.forward_binary_streamed(s, batch, corr_mul, qt.delta, ws, &mut gemm_group)
    }

    /// Materialized oracle of [`ConvLayer::forward_binary_quant`].
    fn forward_binary_quant_materialized(&self, qt: &QuantTensor<W>, ws: &Workspace) -> Act<W> {
        let bt0 = &qt.planes[0];
        assert_eq!(bt0.dir, PackDir::Channels, "conv input packing");
        let s = bt0.shape;
        let batch = bt0.batch;
        assert_eq!(s.l, self.in_channels, "input channels");
        let conv_shape = self.conv_out_shape(s);
        let rows = batch * conv_shape.m * conv_shape.n;
        let lw = bt0.group_words;
        let row_words = self.kh * self.kw * lw;
        let k_bits = self.kh * self.kw * self.in_channels;
        let (halve, corr_mul) = Self::quant_combine(qt.planes.len());
        let mut acc = ws.i32s.acquire(rows * self.filters);
        let mut tmp = ws.i32s.acquire(rows * self.filters);
        for (pi, plane) in qt.planes.iter().enumerate() {
            let mut unrolled = W::pool(ws).acquire(rows * row_words);
            unroll_bits(plane, self.kh, self.kw, self.stride, self.pad, &mut unrolled);
            let dst: &mut [i32] = if pi == 0 { &mut acc } else { &mut tmp };
            gemm_words_into::<W>(
                &unrolled,
                &self.w_packed,
                dst,
                rows,
                self.filters,
                row_words,
                k_bits,
            );
            if pi > 0 {
                for (a, &t) in acc.iter_mut().zip(tmp.iter()) {
                    *a += t;
                }
            }
        }
        if halve {
            for v in acc.iter_mut() {
                debug_assert_eq!(*v % 2, 0, "ternary plane sum must be even");
                *v /= 2;
            }
        }
        self.apply_correction(&mut acc, batch, corr_mul);
        self.finish_binary(&acc, conv_shape, batch, qt.delta, ws)
    }

    /// XNOR-Net input-scale map: `K[p] = Σ in-bounds A / (kh·kw)` for
    /// each output pixel `p` of global patch rows `[row0, row1)` — the
    /// convolution of the per-pixel A map with the mean filter under zero
    /// padding (out-of-bounds taps contribute A = 0).
    fn scale_window_k(&self, scale: &[f32], in_shape: Shape, row0: usize, row1: usize, out: &mut [f32]) {
        let conv_shape = self.conv_out_shape(in_shape);
        let (oh, ow) = (conv_shape.m, conv_shape.n);
        let rows_img = oh * ow;
        let (m, n) = (in_shape.m, in_shape.n);
        let norm = 1.0 / (self.kh * self.kw) as f32;
        for (i, r) in (row0..row1).enumerate() {
            let b = r / rows_img;
            let p = r % rows_img;
            let (oy, ox) = (p / ow, p % ow);
            let mut sum = 0.0f32;
            for ky in 0..self.kh {
                let iy = (oy * self.stride + ky) as isize - self.pad as isize;
                if iy < 0 || iy as usize >= m {
                    continue;
                }
                for kx in 0..self.kw {
                    let ix = (ox * self.stride + kx) as isize - self.pad as isize;
                    if ix < 0 || ix as usize >= n {
                        continue;
                    }
                    sum += scale[b * m * n + iy as usize * n + ix as usize];
                }
            }
            out[i] = sum * norm;
        }
    }

    /// Shared scaled-binary (XNOR-Net) tail: corrected sign-bit GEMM
    /// accumulators for global rows `[r0, r1)` → `α·K` float epilogue →
    /// conv-domain scores. Pooling must run *after* scaling (K varies per
    /// pixel), so this fills the f32 conv buffer the caller then pools.
    fn scaled_epilogue(
        &self,
        acc_g: &[i32],
        k_buf: &mut [f32],
        st: &ScaledBitTensor<W>,
        in_shape: Shape,
        r0: usize,
        r1: usize,
        conv_g: &mut [f32],
    ) {
        let f = self.filters;
        let g_rows = r1 - r0;
        self.scale_window_k(&st.scale, in_shape, r0, r1, &mut k_buf[..g_rows]);
        for p in 0..g_rows {
            let kp = k_buf[p];
            let src = &acc_g[p * f..(p + 1) * f];
            let dst = &mut conv_g[p * f..(p + 1) * f];
            for (fi, (d, &v)) in dst.iter_mut().zip(src).enumerate() {
                *d = v as f32 * (self.alpha_at(fi) * kp);
            }
        }
    }

    /// Fused scaled-binary input forward: tile-streamed XNOR GEMM on the
    /// sign carrier, per-pixel `α·K` float epilogue, f32 pooling, then
    /// the representation tail.
    fn forward_binary_scaled(&self, st: &ScaledBitTensor<W>, ws: &Workspace) -> Act<W> {
        let bt = &st.bits;
        assert_eq!(bt.dir, PackDir::Channels, "conv input packing");
        let s = bt.shape;
        let batch = bt.batch;
        assert_eq!(s.l, self.in_channels, "input channels");
        let f = self.filters;
        let lw = bt.group_words;
        let row_words = self.kh * self.kw * lw;
        let k_bits = self.kh * self.kw * self.in_channels;
        let tile = tuned_tile_rows(Family::Binary, W::BITS as u32, f, row_words);
        let conv_shape = self.conv_out_shape(s);
        let rows_img = conv_shape.m * conv_shape.n;
        let group = self.group_images(rows_img, batch);
        let src_block = rows_img * f;
        let (out_shape, dst_block) = self.pooled_geom(conv_shape);
        let mut acc = ws.i32s.acquire_affine(current_slot(), group * src_block);
        let mut conv = ws.f32s.acquire_affine(current_slot(), group * src_block);
        let mut k_buf = ws.f32s.acquire_affine(current_slot(), group * rows_img);
        let mut y = vec![0f32; batch * dst_block];
        let mut g0 = 0usize;
        while g0 < batch {
            let g1 = (g0 + group).min(batch);
            let g = g1 - g0;
            let acc_g = &mut acc[..g * src_block];
            gemm_tiles_into::<W>(
                &self.w_packed,
                acc_g,
                g * rows_img,
                f,
                row_words,
                k_bits,
                tile,
                W::pool(ws),
                &|t0, t1, panel: &mut [W]| {
                    unroll_bits_rows(
                        bt,
                        self.kh,
                        self.kw,
                        self.stride,
                        self.pad,
                        g0 * rows_img + t0,
                        g0 * rows_img + t1,
                        panel,
                    );
                },
            );
            self.apply_correction(acc_g, g, 1);
            let conv_g = &mut conv[..g * src_block];
            self.scaled_epilogue(
                acc_g,
                &mut k_buf,
                st,
                s,
                g0 * rows_img,
                g1 * rows_img,
                conv_g,
            );
            if let Some(spec) = self.pool {
                for b in 0..g {
                    pool_f32(
                        &conv_g[b * src_block..(b + 1) * src_block],
                        conv_shape.m,
                        conv_shape.n,
                        f,
                        spec,
                        &mut y[(g0 + b) * dst_block..(g0 + b + 1) * dst_block],
                    );
                }
            } else {
                y[g0 * dst_block..g1 * dst_block].copy_from_slice(conv_g);
            }
            g0 = g1;
        }
        self.finish_float_channels(y, out_shape, batch)
    }

    /// Materialized oracle of [`ConvLayer::forward_binary_scaled`].
    fn forward_binary_scaled_materialized(
        &self,
        st: &ScaledBitTensor<W>,
        ws: &Workspace,
    ) -> Act<W> {
        let bt = &st.bits;
        assert_eq!(bt.dir, PackDir::Channels, "conv input packing");
        let s = bt.shape;
        let batch = bt.batch;
        assert_eq!(s.l, self.in_channels, "input channels");
        let f = self.filters;
        let conv_shape = self.conv_out_shape(s);
        let rows_img = conv_shape.m * conv_shape.n;
        let rows = batch * rows_img;
        let lw = bt.group_words;
        let row_words = self.kh * self.kw * lw;
        let k_bits = self.kh * self.kw * self.in_channels;
        let mut unrolled = W::pool(ws).acquire(rows * row_words);
        unroll_bits(bt, self.kh, self.kw, self.stride, self.pad, &mut unrolled);
        let mut acc = ws.i32s.acquire(rows * f);
        gemm_words_into::<W>(&unrolled, &self.w_packed, &mut acc, rows, f, row_words, k_bits);
        self.apply_correction(&mut acc, batch, 1);
        let mut conv = ws.f32s.acquire(rows * f);
        let mut k_buf = ws.f32s.acquire(rows);
        self.scaled_epilogue(&acc, &mut k_buf, st, s, 0, rows, &mut conv);
        let (out_shape, dst_block) = self.pooled_geom(conv_shape);
        let src_block = rows_img * f;
        let y = if let Some(spec) = self.pool {
            let mut y = vec![0f32; batch * dst_block];
            for b in 0..batch {
                pool_f32(
                    &conv[b * src_block..(b + 1) * src_block],
                    conv_shape.m,
                    conv_shape.n,
                    f,
                    spec,
                    &mut y[b * dst_block..(b + 1) * dst_block],
                );
            }
            y
        } else {
            conv.to_vec()
        };
        self.finish_float_channels(y, out_shape, batch)
    }
}

/// Float max-pool over one image's interleaved-channel buffer.
fn pool_f32(src: &[f32], oh: usize, ow: usize, f: usize, spec: PoolSpec, out: &mut [f32]) {
    let ph = out_dim(oh, spec.k, spec.stride, 0);
    let pw = out_dim(ow, spec.k, spec.stride, 0);
    assert_eq!(out.len(), ph * pw * f);
    for py in 0..ph {
        for px in 0..pw {
            let dst = &mut out[(py * pw + px) * f..(py * pw + px + 1) * f];
            dst.fill(f32::NEG_INFINITY);
            for wy in 0..spec.k {
                for wx in 0..spec.k {
                    let iy = py * spec.stride + wy;
                    let ix = px * spec.stride + wx;
                    if iy >= oh || ix >= ow {
                        continue;
                    }
                    let srcp = &src[(iy * ow + ix) * f..(iy * ow + ix + 1) * f];
                    for (d, &s) in dst.iter_mut().zip(srcp) {
                        *d = d.max(s);
                    }
                }
            }
        }
    }
}

impl<W: Word> Layer<W> for ConvLayer<W> {
    fn describe(&self) -> String {
        let tail = if self.sign {
            match self.repr {
                OutRepr::Sign => " +sign".to_string(),
                r => format!(" +{r}"),
            }
        } else {
            String::new()
        };
        format!(
            "Conv {}x{}x{}->{} s{} p{}{}{}{}{}",
            self.kh,
            self.kw,
            self.in_channels,
            self.filters,
            self.stride,
            self.pad,
            self.pool
                .map(|p| format!(" +MP{}", p.k))
                .unwrap_or_default(),
            if self.bn.is_some() { " +BN" } else { "" },
            tail,
            if self.alpha.is_some() { " +a" } else { "" }
        )
    }

    fn prepare(&mut self, in_shape: Shape) -> Shape {
        assert_eq!(in_shape.l, self.in_channels, "input channels");
        self.in_shape = Some(in_shape);
        self.correction = self.build_correction(in_shape);
        let c = self.conv_out_shape(in_shape);
        if let Some(spec) = self.pool {
            Shape::new(
                out_dim(c.m, spec.k, spec.stride, 0),
                out_dim(c.n, spec.k, spec.stride, 0),
                self.filters,
            )
        } else {
            c
        }
    }

    fn forward(&self, x: Act<W>, backend: Backend, ws: &Workspace) -> Act<W> {
        self.forward_view(x.view(), backend, ws)
    }

    /// Both backends only *read* their input, so the borrowed form is the
    /// real implementation and owned `forward` is a thin wrapper.
    fn forward_view(&self, x: ActView<'_, W>, backend: Backend, ws: &Workspace) -> Act<W> {
        match backend {
            Backend::Float => match x {
                ActView::Float(t) => self.forward_float_t(t, ws),
                ActView::Bytes(t) => {
                    let xf = t.to_f32();
                    self.forward_float_t(&xf, ws)
                }
                ActView::Bits(bt) => {
                    let xf = bt.to_tensor();
                    self.forward_float_t(&xf, ws)
                }
                ActView::Scaled(st) => {
                    let xf = st.to_tensor();
                    self.forward_float_t(&xf, ws)
                }
                ActView::Quant(qt) => {
                    let xf = qt.to_tensor();
                    self.forward_float_t(&xf, ws)
                }
            },
            Backend::Binary => match x {
                ActView::Bytes(t) => self.forward_binary_bytes(t, ws),
                ActView::Float(t) => {
                    let bt = BitTensor::from_tensor_dir(t, PackDir::Channels);
                    self.forward_binary_bits(&bt, ws)
                }
                ActView::Bits(bt) => self.forward_binary_bits(bt, ws),
                ActView::Scaled(st) => self.forward_binary_scaled(st, ws),
                ActView::Quant(qt) => self.forward_binary_quant(qt, ws),
            },
        }
    }

    /// The pre-fusion execution semantics: full patch-matrix unroll + one
    /// GEMM + batched tail. The equivalence oracle for the fused
    /// tile-streaming forward; bit-identical by construction.
    fn forward_materialized(&self, x: Act<W>, backend: Backend, ws: &Workspace) -> Act<W> {
        match backend {
            Backend::Float => match x.view() {
                ActView::Float(t) => self.forward_float_materialized(t, ws),
                ActView::Bytes(t) => {
                    let xf = t.to_f32();
                    self.forward_float_materialized(&xf, ws)
                }
                ActView::Bits(bt) => {
                    let xf = bt.to_tensor();
                    self.forward_float_materialized(&xf, ws)
                }
                ActView::Scaled(st) => {
                    let xf = st.to_tensor();
                    self.forward_float_materialized(&xf, ws)
                }
                ActView::Quant(qt) => {
                    let xf = qt.to_tensor();
                    self.forward_float_materialized(&xf, ws)
                }
            },
            Backend::Binary => match x.view() {
                ActView::Bytes(t) => self.forward_binary_bytes_materialized(t, ws),
                ActView::Float(t) => {
                    let bt = BitTensor::from_tensor_dir(t, PackDir::Channels);
                    self.forward_binary_bits_materialized(&bt, ws)
                }
                ActView::Bits(bt) => self.forward_binary_bits_materialized(bt, ws),
                ActView::Scaled(st) => self.forward_binary_scaled_materialized(st, ws),
                ActView::Quant(qt) => self.forward_binary_quant_materialized(qt, ws),
            },
        }
    }

    fn out_kind(&self, backend: Backend, _in_kind: ActKind) -> ActKind {
        match backend {
            Backend::Float => ActKind::Float,
            // the binary tail packs the configured repr when a sign
            // activation follows; score layers stay float
            Backend::Binary => {
                if self.sign {
                    self.repr.out_kind()
                } else {
                    ActKind::Float
                }
            }
        }
    }

    /// Fused-path reservations: per-worker unroll panels (tile-sized, one
    /// per thread the tiled GEMM may run on) plus the per-*group*
    /// accumulators — O(tile + group), not O(B·oh·ow·k).
    fn scratch(
        &self,
        in_shape: Shape,
        in_kind: ActKind,
        backend: Backend,
        batch: usize,
    ) -> ScratchSpec {
        let c = self.conv_out_shape(in_shape);
        let rows_img = c.m * c.n;
        let group = self.group_images(rows_img, batch.max(1));
        let g_rows = group * rows_img;
        let (_, kc) = unrolled_cols(in_shape, self.kh, self.kw, self.stride, self.pad);
        let f = self.filters;
        let mut spec = ScratchSpec::default();
        match (backend, in_kind) {
            (Backend::Float, _) => {
                spec.f32s.push(g_rows * f);
                let tile = tuned_tile_rows(Family::Float, 32, f, kc);
                let nw = linalg::sgemm_tiles_workers(g_rows, f, kc, tile);
                spec.f32s.resize(spec.f32s.len() + nw, tile * kc);
            }
            (Backend::Binary, ActKind::Bytes) => {
                if self.bitplane_first {
                    let tile = tuned_tile_rows(Family::Bitplane, W::BITS as u32, f, kc);
                    let nw = crate::bitpack::bitplane_tiles_workers::<W>(g_rows, f, kc);
                    spec.bytes.resize(spec.bytes.len() + nw, tile * kc);
                } else {
                    spec.f32s.push(g_rows * f);
                    let tile = tuned_tile_rows(Family::Float, 32, f, kc);
                    let nw = linalg::sgemm_tiles_workers(g_rows, f, kc, tile);
                    spec.f32s.resize(spec.f32s.len() + nw, tile * kc);
                }
                spec.i32s.push(g_rows * f);
            }
            (Backend::Binary, _) => {
                let lw = words_for::<W>(in_shape.l);
                let row_words = self.kh * self.kw * lw;
                let tile = tuned_tile_rows(Family::Binary, W::BITS as u32, f, row_words);
                let nw = crate::bitpack::gemm_tiles_workers::<W>(g_rows, f, row_words, tile);
                spec.words.resize(spec.words.len() + nw, tile * row_words);
                spec.i32s.push(g_rows * f);
                match in_kind {
                    // plane combine buffer (planes reuse one panel set)
                    ActKind::Bits2 | ActKind::Ternary => spec.i32s.push(g_rows * f),
                    // α·K epilogue: f32 conv scores + per-pixel K map
                    ActKind::ScaledBits => {
                        spec.f32s.push(g_rows * f);
                        spec.f32s.push(g_rows);
                    }
                    _ => {}
                }
            }
        }
        if backend == Backend::Binary
            && self.pool.is_some()
            && in_kind != ActKind::ScaledBits
        {
            // the scaled-input path pools in f32 straight into the output
            spec.i32s.push(group * self.pooled_geom(c).1);
        }
        spec
    }

    /// What the materialized oracle reserves: the full `(B·oh·ow) × k`
    /// patch matrix plus batch-wide accumulators — the pre-fusion memory
    /// story the fused path's `scratch` is measured against.
    fn scratch_materialized(
        &self,
        in_shape: Shape,
        in_kind: ActKind,
        backend: Backend,
        batch: usize,
    ) -> ScratchSpec {
        let c = self.conv_out_shape(in_shape);
        let rows = batch * c.m * c.n;
        let (_, kc) = unrolled_cols(in_shape, self.kh, self.kw, self.stride, self.pad);
        let mut spec = ScratchSpec::default();
        match (backend, in_kind) {
            (Backend::Float, _) => {
                spec.f32s.push(rows * kc);
                spec.f32s.push(rows * self.filters);
            }
            (Backend::Binary, ActKind::Bytes) => {
                if self.bitplane_first {
                    spec.bytes.push(rows * kc);
                } else {
                    spec.f32s.push(rows * kc);
                    spec.f32s.push(rows * self.filters);
                }
                spec.i32s.push(rows * self.filters);
            }
            (Backend::Binary, _) => {
                let lw = words_for::<W>(in_shape.l);
                spec.words.push(rows * self.kh * self.kw * lw);
                spec.i32s.push(rows * self.filters);
                match in_kind {
                    ActKind::Bits2 | ActKind::Ternary => spec.i32s.push(rows * self.filters),
                    ActKind::ScaledBits => {
                        spec.f32s.push(rows * self.filters);
                        spec.f32s.push(rows);
                    }
                    _ => {}
                }
            }
        }
        if backend == Backend::Binary
            && self.pool.is_some()
            && in_kind != ActKind::ScaledBits
        {
            spec.i32s.push(batch * self.pooled_geom(c).1);
        }
        spec
    }

    fn gemm_dims(&self, in_shape: Shape) -> Option<(usize, usize, usize)> {
        let c = self.conv_out_shape(in_shape);
        Some((c.m * c.n, self.filters, self.kh * self.kw * self.in_channels))
    }

    fn tune_dims(
        &self,
        in_shape: Shape,
        in_kind: ActKind,
        backend: Backend,
    ) -> Option<(Family, usize, usize, usize)> {
        let c = self.conv_out_shape(in_shape);
        let m = c.m * c.n;
        let (_, kc) = unrolled_cols(in_shape, self.kh, self.kw, self.stride, self.pad);
        Some(match (backend, in_kind) {
            (Backend::Float, _) => (Family::Float, m, self.filters, kc),
            (Backend::Binary, ActKind::Bytes) => {
                if self.bitplane_first {
                    (Family::Bitplane, m, self.filters, kc)
                } else {
                    (Family::Float, m, self.filters, kc)
                }
            }
            (Backend::Binary, _) => {
                let row_words = self.kh * self.kw * words_for::<W>(in_shape.l);
                (Family::Binary, m, self.filters, row_words)
            }
        })
    }

    fn param_bytes_float(&self) -> usize {
        self.w.len() * 4 + self.bn.as_ref().map_or(0, |b| b.features() * 16)
    }

    fn param_bytes_packed(&self) -> usize {
        // extra threshold planes + α vectors only for non-default reprs,
        // so the legacy packed-size claims are unaffected
        let extra = (self.repr.planes() - 1) * self.filters * 4
            + self.alpha.as_ref().map_or(0, |a| a.len() * 4);
        self.w_packed.len() * (W::BITS / 8)
            + self
                .folded
                .as_ref()
                .map_or(self.bn.as_ref().map_or(0, |b| b.features() * 16), |f| {
                    f.tau.len() * 5
                })
            + extra
    }

    fn scale_mode(&self, in_kind: ActKind) -> String {
        let mut parts: Vec<&str> = Vec::new();
        if self.alpha.is_some() {
            parts.push("a");
        }
        match in_kind {
            ActKind::ScaledBits => parts.push("K"),
            ActKind::Bits2 | ActKind::Ternary => parts.push("d"),
            _ => {}
        }
        if self.sign && matches!(self.repr, OutRepr::Quant2 | OutRepr::Ternary) {
            parts.push("d'");
        }
        if parts.is_empty() {
            "-".to_string()
        } else {
            parts.join("+")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_bn(rng: &mut Rng, f: usize) -> BnParams {
        BnParams {
            gamma: (0..f)
                .map(|_| {
                    let g = rng.f32_range(-2.0, 2.0);
                    if g.abs() < 0.05 {
                        0.7
                    } else {
                        g
                    }
                })
                .collect(),
            beta: (0..f).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
            mean: (0..f).map(|_| rng.f32_range(-5.0, 5.0)).collect(),
            var: (0..f).map(|_| rng.f32_range(0.3, 4.0)).collect(),
            eps: 1e-4,
        }
    }

    fn random_pm1(rng: &mut Rng, s: Shape) -> Tensor<f32> {
        let mut d = vec![0f32; s.len()];
        rng.fill_signs(&mut d);
        Tensor::from_vec(s, d)
    }

    /// The load-bearing test: binary path (packed unroll + XNOR GEMM +
    /// padding correction + int pool + thresholds) must equal the float
    /// path bit-for-bit, including "same" padding.
    #[test]
    fn binary_equals_float_with_padding_bn_sign() {
        let mut rng = Rng::new(91);
        let ws = Workspace::new();
        for &(m, n, l, f, k, pad) in &[
            (8usize, 8usize, 64usize, 32usize, 3usize, 1usize),
            (6, 6, 3, 16, 3, 1),
            (10, 7, 65, 8, 3, 1),
            (8, 8, 16, 8, 5, 2),
            (7, 7, 32, 8, 3, 0),
        ] {
            let mut layer: ConvLayer<u64> = ConvLayer::new(
                l,
                f,
                k,
                k,
                1,
                pad,
                &rng.signs(f * k * k * l),
                Some(random_bn(&mut rng, f)),
                true,
                None,
            );
            let s = Shape::new(m, n, l);
            layer.prepare(s);
            let x = random_pm1(&mut rng, s);
            let ff = layer
                .forward(Act::Float(x.clone()), Backend::Float, &ws)
                .into_float();
            let bb = layer
                .forward(Act::Float(x), Backend::Binary, &ws)
                .into_float();
            assert_eq!(ff.shape, bb.shape);
            assert_eq!(ff.data, bb.data, "shape ({m},{n},{l},{f},{k},{pad})");
        }
    }

    #[test]
    fn binary_equals_float_with_pool() {
        let mut rng = Rng::new(92);
        let ws = Workspace::new();
        let (m, n, l, f, k) = (8, 8, 32, 16, 3);
        let mut layer: ConvLayer<u64> = ConvLayer::new(
            l,
            f,
            k,
            k,
            1,
            1,
            &rng.signs(f * k * k * l),
            Some(random_bn(&mut rng, f)),
            true,
            Some(PoolSpec { k: 2, stride: 2 }),
        );
        let s = Shape::new(m, n, l);
        let out_shape = layer.prepare(s);
        assert_eq!(out_shape, Shape::new(4, 4, f));
        let x = random_pm1(&mut rng, s);
        let ff = layer
            .forward(Act::Float(x.clone()), Backend::Float, &ws)
            .into_float();
        let bb = layer
            .forward(Act::Float(x), Backend::Binary, &ws)
            .into_float();
        assert_eq!(ff.shape, out_shape);
        assert_eq!(ff.data, bb.data);
    }

    #[test]
    fn bytes_first_layer_matches_float() {
        // both first-layer strategies (bit-plane and float GEMM) must
        // reproduce the float path exactly, including "same" padding
        let mut rng = Rng::new(93);
        let ws = Workspace::new();
        let (m, n, l, f, k) = (8, 8, 3, 8, 3);
        let mut layer: ConvLayer<u64> = ConvLayer::new(
            l,
            f,
            k,
            k,
            1,
            1,
            &rng.signs(f * k * k * l),
            Some(random_bn(&mut rng, f)),
            true,
            None,
        );
        layer.prepare(Shape::new(m, n, l));
        let img: Vec<u8> = (0..m * n * l).map(|_| rng.next_u32() as u8).collect();
        let x = Tensor::from_vec(Shape::new(m, n, l), img);
        let ff = layer
            .forward(Act::Bytes(x.clone()), Backend::Float, &ws)
            .into_float();
        layer.bitplane_first = true;
        let b1 = layer
            .forward(Act::Bytes(x.clone()), Backend::Binary, &ws)
            .into_float();
        layer.bitplane_first = false;
        let b2 = layer
            .forward(Act::Bytes(x), Backend::Binary, &ws)
            .into_float();
        assert_eq!(ff.data, b1.data, "bit-plane first conv layer");
        assert_eq!(ff.data, b2.data, "float first conv layer");
    }

    #[test]
    fn bitplane_conv_with_pool_and_stride() {
        let mut rng = Rng::new(97);
        let ws = Workspace::new();
        let (m, n, l, f, k) = (10, 10, 3, 16, 5);
        let mut layer: ConvLayer<u64> = ConvLayer::new(
            l,
            f,
            k,
            k,
            1,
            2,
            &rng.signs(f * k * k * l),
            Some(random_bn(&mut rng, f)),
            true,
            Some(PoolSpec { k: 2, stride: 2 }),
        );
        layer.prepare(Shape::new(m, n, l));
        let img: Vec<u8> = (0..m * n * l).map(|_| rng.next_u32() as u8).collect();
        let x = Tensor::from_vec(Shape::new(m, n, l), img);
        let ff = layer
            .forward(Act::Bytes(x.clone()), Backend::Float, &ws)
            .into_float();
        let bb = layer
            .forward(Act::Bytes(x), Backend::Binary, &ws)
            .into_float();
        assert_eq!(ff.data, bb.data);
    }

    #[test]
    fn correction_matrix_zero_in_interior() {
        let mut rng = Rng::new(94);
        let (l, f, k) = (4, 4, 3);
        let mut layer: ConvLayer<u64> =
            ConvLayer::new(l, f, k, k, 1, 1, &rng.signs(f * k * k * l), None, true, None);
        let s = Shape::new(6, 6, l);
        layer.prepare(s);
        let corr = &layer.correction;
        assert_eq!(corr.len(), 36 * f);
        // interior pixels (1..5, 1..5) have all taps in-bounds -> zero
        for oy in 1..5 {
            for ox in 1..5 {
                for fi in 0..f {
                    assert_eq!(corr[(oy * 6 + ox) * f + fi], 0, "({oy},{ox},{fi})");
                }
            }
        }
        // corner must correct 5 OOB taps (3x3 kernel at corner)
        let corner: i32 = (0..f).map(|fi| corr[fi].abs()).sum();
        assert!(corner >= 0); // presence check; exactness covered by e2e test
    }

    #[test]
    fn stacked_conv_blocks_stay_equivalent() {
        // conv -> conv chained through packed activations
        let mut rng = Rng::new(95);
        let ws = Workspace::new();
        let s = Shape::new(8, 8, 16);
        let mut c1: ConvLayer<u64> = ConvLayer::new(
            16,
            64,
            3,
            3,
            1,
            1,
            &rng.signs(64 * 9 * 16),
            Some(random_bn(&mut rng, 64)),
            true,
            None,
        );
        let s1 = c1.prepare(s);
        let mut c2: ConvLayer<u64> = ConvLayer::new(
            64,
            32,
            3,
            3,
            1,
            1,
            &rng.signs(32 * 9 * 64),
            Some(random_bn(&mut rng, 32)),
            true,
            Some(PoolSpec { k: 2, stride: 2 }),
        );
        c2.prepare(s1);
        let x = random_pm1(&mut rng, s);
        let f1 = c1.forward(Act::Float(x.clone()), Backend::Float, &ws);
        let f2 = c2.forward(f1, Backend::Float, &ws).into_float();
        let b1 = c1.forward(Act::Float(x), Backend::Binary, &ws);
        assert!(matches!(b1, Act::Bits(_)), "hidden conv emits bits");
        let b2 = c2.forward(b1, Backend::Binary, &ws).into_float();
        assert_eq!(f2.data, b2.data);
    }

    #[test]
    fn output_conv_without_sign_returns_scores() {
        let mut rng = Rng::new(96);
        let ws = Workspace::new();
        let (l, f, k) = (8, 4, 3);
        let mut layer: ConvLayer<u64> =
            ConvLayer::new(l, f, k, k, 1, 0, &rng.signs(f * k * k * l), None, false, None);
        let s = Shape::new(5, 5, l);
        layer.prepare(s);
        let x = random_pm1(&mut rng, s);
        let ff = layer
            .forward(Act::Float(x.clone()), Backend::Float, &ws)
            .into_float();
        let bb = layer
            .forward(Act::Float(x), Backend::Binary, &ws)
            .into_float();
        for (a, b) in ff.data.iter().zip(&bb.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    /// Batched forward must be bit-identical to per-image forwards on
    /// every path: padding correction, pooling, BN thresholds included.
    #[test]
    fn batched_forward_equals_per_image_forwards() {
        let mut rng = Rng::new(98);
        let ws = Workspace::new();
        for &(m, n, l, f, k, stride, pad, pool) in &[
            (8usize, 8usize, 16usize, 8usize, 3usize, 1usize, 1usize, true),
            (7, 6, 5, 4, 3, 1, 1, false),
            (9, 9, 3, 8, 5, 2, 2, false),
            (6, 6, 64, 16, 3, 1, 0, true),
        ] {
            let s = Shape::new(m, n, l);
            let mut layer: ConvLayer<u64> = ConvLayer::new(
                l,
                f,
                k,
                k,
                stride,
                pad,
                &rng.signs(f * k * k * l),
                Some(random_bn(&mut rng, f)),
                true,
                pool.then_some(PoolSpec { k: 2, stride: 2 }),
            );
            layer.prepare(s);
            let imgs: Vec<Tensor<f32>> = (0..3).map(|_| random_pm1(&mut rng, s)).collect();
            let refs: Vec<&Tensor<f32>> = imgs.iter().collect();
            let stacked = Tensor::stack(&refs);
            for backend in [Backend::Binary, Backend::Float] {
                let batched = layer
                    .forward(Act::Float(stacked.clone()), backend, &ws)
                    .into_float();
                assert_eq!(batched.batch, 3, "{backend:?}");
                let per = batched.data.len() / 3;
                for (b, img) in imgs.iter().enumerate() {
                    let single = layer
                        .forward(Act::Float(img.clone()), backend, &ws)
                        .into_float();
                    assert_eq!(single.data.len(), per);
                    assert_eq!(
                        &batched.data[b * per..(b + 1) * per],
                        &single.data[..],
                        "{backend:?} image {b} geom ({m},{n},{l},{f},{k},s{stride},p{pad})"
                    );
                }
            }
        }
    }

    /// Batched Bytes (first-layer) forward — both the bit-plane and the
    /// float-GEMM strategies — must equal per-image forwards.
    #[test]
    fn batched_bytes_first_layer_equals_per_image() {
        let mut rng = Rng::new(99);
        let ws = Workspace::new();
        let (m, n, l, f, k) = (8, 8, 3, 8, 3);
        let s = Shape::new(m, n, l);
        let mut layer: ConvLayer<u64> = ConvLayer::new(
            l,
            f,
            k,
            k,
            1,
            1,
            &rng.signs(f * k * k * l),
            Some(random_bn(&mut rng, f)),
            true,
            Some(PoolSpec { k: 2, stride: 2 }),
        );
        layer.prepare(s);
        let imgs: Vec<Tensor<u8>> = (0..4)
            .map(|_| {
                Tensor::from_vec(
                    s,
                    (0..s.len()).map(|_| rng.next_u32() as u8).collect(),
                )
            })
            .collect();
        let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
        let stacked = Tensor::stack(&refs);
        for bitplane in [true, false] {
            layer.bitplane_first = bitplane;
            let batched = layer
                .forward(Act::Bytes(stacked.clone()), Backend::Binary, &ws)
                .into_float();
            let per = batched.data.len() / 4;
            for (b, img) in imgs.iter().enumerate() {
                let single = layer
                    .forward(Act::Bytes(img.clone()), Backend::Binary, &ws)
                    .into_float();
                assert_eq!(
                    &batched.data[b * per..(b + 1) * per],
                    &single.data[..],
                    "bitplane={bitplane} image {b}"
                );
            }
        }
    }

    /// The fused tile-streaming forward must be bit-identical to the
    /// materialized oracle on every path: both backends, batched inputs,
    /// padding, stride, pooling, and both first-layer byte strategies.
    #[test]
    fn fused_equals_materialized_all_paths() {
        let mut rng = Rng::new(101);
        let ws = Workspace::new();
        for &(m, n, l, f, k, stride, pad, pool) in &[
            (8usize, 8usize, 16usize, 8usize, 3usize, 1usize, 1usize, true),
            (9, 7, 5, 4, 3, 2, 1, false),
            (10, 10, 3, 8, 5, 1, 2, true),
            (6, 6, 70, 12, 3, 1, 0, false),
        ] {
            let s = Shape::new(m, n, l);
            let mut layer: ConvLayer<u64> = ConvLayer::new(
                l,
                f,
                k,
                k,
                stride,
                pad,
                &rng.signs(f * k * k * l),
                Some(random_bn(&mut rng, f)),
                true,
                pool.then_some(PoolSpec { k: 2, stride: 2 }),
            );
            layer.prepare(s);
            let imgs: Vec<Tensor<f32>> = (0..5).map(|_| random_pm1(&mut rng, s)).collect();
            let refs: Vec<&Tensor<f32>> = imgs.iter().collect();
            let stacked = Tensor::stack(&refs);
            for backend in [Backend::Binary, Backend::Float] {
                let fused = layer
                    .forward(Act::Float(stacked.clone()), backend, &ws)
                    .into_float();
                let mat = layer
                    .forward_materialized(Act::Float(stacked.clone()), backend, &ws)
                    .into_float();
                assert_eq!(
                    fused.data, mat.data,
                    "{backend:?} geom ({m},{n},{l},{f},{k},s{stride},p{pad})"
                );
            }
        }
        // first-layer Bytes paths: bit-plane and float-GEMM strategies
        let (m, n, l, f, k) = (8, 8, 3, 8, 3);
        let s = Shape::new(m, n, l);
        let mut layer: ConvLayer<u64> = ConvLayer::new(
            l,
            f,
            k,
            k,
            1,
            1,
            &rng.signs(f * k * k * l),
            Some(random_bn(&mut rng, f)),
            true,
            Some(PoolSpec { k: 2, stride: 2 }),
        );
        layer.prepare(s);
        let imgs: Vec<Tensor<u8>> = (0..3)
            .map(|_| {
                Tensor::from_vec(s, (0..s.len()).map(|_| rng.next_u32() as u8).collect())
            })
            .collect();
        let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
        let stacked = Tensor::stack(&refs);
        for bitplane in [true, false] {
            layer.bitplane_first = bitplane;
            let fused = layer
                .forward(Act::Bytes(stacked.clone()), Backend::Binary, &ws)
                .into_float();
            let mat = layer
                .forward_materialized(Act::Bytes(stacked.clone()), Backend::Binary, &ws)
                .into_float();
            assert_eq!(fused.data, mat.data, "bitplane={bitplane}");
        }
    }

    /// Fused scratch must undercut materialized scratch by ≥ 4× once the
    /// batch is large enough that the full patch matrix dominates.
    #[test]
    fn fused_scratch_shrinks_vs_materialized() {
        let mut rng = Rng::new(102);
        let (l, f, k) = (64, 64, 3);
        let mut layer: ConvLayer<u64> =
            ConvLayer::new(l, f, k, k, 1, 1, &rng.signs(f * k * k * l), None, true, None);
        let s = Shape::new(32, 32, l);
        layer.prepare(s);
        let fused = layer
            .scratch(s, ActKind::Bits, Backend::Binary, 64)
            .total_bytes(8);
        let mat = layer
            .scratch_materialized(s, ActKind::Bits, Backend::Binary, 64)
            .total_bytes(8);
        assert!(
            mat >= 4 * fused,
            "materialized {mat} B vs fused {fused} B — expected ≥ 4×"
        );
    }

    /// Batched binary conv against the naive direct-convolution oracle at
    /// B > 1, covering pad > 0 and stride > 1 (score output, no BN/sign).
    #[test]
    fn batched_conv_matches_naive_reference() {
        let mut rng = Rng::new(100);
        let ws = Workspace::new();
        for &(m, n, l, f, k, stride, pad) in &[
            (7usize, 7usize, 3usize, 4usize, 3usize, 1usize, 1usize),
            (9, 8, 5, 3, 3, 2, 1),
            (10, 10, 2, 4, 5, 2, 2),
        ] {
            let s = Shape::new(m, n, l);
            let w = rng.signs(f * k * k * l);
            let mut layer: ConvLayer<u64> =
                ConvLayer::new(l, f, k, k, stride, pad, &w, None, false, None);
            layer.prepare(s);
            let imgs: Vec<Tensor<f32>> = (0..3).map(|_| random_pm1(&mut rng, s)).collect();
            let refs: Vec<&Tensor<f32>> = imgs.iter().collect();
            let batched = layer
                .forward(Act::Float(Tensor::stack(&refs)), Backend::Binary, &ws)
                .into_float();
            let oh = out_dim(m, k, stride, pad);
            let ow = out_dim(n, k, stride, pad);
            let per = oh * ow * f;
            for (b, img) in imgs.iter().enumerate() {
                let want = naive_conv(img, &w, f, k, stride, pad);
                let got = &batched.data[b * per..(b + 1) * per];
                for (g, wv) in got.iter().zip(&want) {
                    assert_eq!(
                        *g as i32, *wv,
                        "image {b} geom ({m},{n},{l},{f},{k},s{stride},p{pad})"
                    );
                }
            }
        }
    }

    /// Naive zero-padded direct convolution, integer-exact on ±1 inputs.
    fn naive_conv(
        t: &Tensor<f32>,
        w: &[f32],
        f: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Vec<i32> {
        let s = t.shape;
        let oh = out_dim(s.m, k, stride, pad);
        let ow = out_dim(s.n, k, stride, pad);
        let mut out = vec![0i32; oh * ow * f];
        for oy in 0..oh {
            for ox in 0..ow {
                for fi in 0..f {
                    let mut acc = 0i32;
                    for ky in 0..k {
                        for kx in 0..k {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if iy < 0 || iy as usize >= s.m || ix < 0 || ix as usize >= s.n {
                                continue;
                            }
                            for c in 0..s.l {
                                acc += (*t.at(iy as usize, ix as usize, c)
                                    * w[((fi * k + ky) * k + kx) * s.l + c])
                                    as i32;
                            }
                        }
                    }
                    out[(oy * ow + ox) * f + fi] = acc;
                }
            }
        }
        out
    }
}
