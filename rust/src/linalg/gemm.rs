//! Cache-blocked float GEMM / GEMV.
//!
//! Layout convention matches the binary kernels: `C = A · Bᵀ` with
//! `A: m×k` row-major and `B: n×k` row-major (row per output neuron), so
//! dense layers use identical weight storage for the float and binary
//! paths. The kernel tiles B into L1-size panels and register-blocks a
//! 1×4 micro-kernel with 4-wide unrolled FMA accumulation that LLVM
//! auto-vectorizes to AVX.

use crate::alloc::BufferPool;
use crate::util::parallel::{current_slot, max_workers_for, parallel_for_mut_chunks};
use crate::util::tune::{self, Family, KernelChoice, MicroKernel};

/// B rows per register block.
const NR: usize = 4;
/// B rows per cache panel.
const NB: usize = 32;

/// `C[i*n + j] = Σ_t A[i*k + t] * B[j*k + t]`.
pub fn sgemm_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    let choice = tune::lookup(Family::Float, 32, n, k);
    sgemm_with_choice(a, b, out, m, n, k, choice)
}

/// [`sgemm_into`] with an explicit kernel configuration.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_with_choice(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    choice: KernelChoice,
) {
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(b.len(), n * k, "B size");
    assert_eq!(out.len(), m * n, "C size");
    if m == 0 || n == 0 {
        return;
    }
    parallel_for_mut_chunks(out, n, choice.grain.max(1), |row0, c_chunk| {
        let rows = c_chunk.len() / n;
        for nb0 in (0..n).step_by(NB) {
            let nb1 = (nb0 + NB).min(n);
            for r in 0..rows {
                let arow = &a[(row0 + r) * k..(row0 + r + 1) * k];
                let crow = &mut c_chunk[r * n + nb0..r * n + nb1];
                row_panel(arow, b, crow, nb0, k, choice.micro);
            }
        }
    });
}

/// One A row against B rows `[b_start, b_start + c.len())`. A 2×4
/// request maps to the 1×8 ladder (the float path has no row pairing —
/// both shapes widen the B block, which is what matters here).
#[inline]
fn row_panel(arow: &[f32], b: &[f32], c: &mut [f32], b_start: usize, k: usize, micro: MicroKernel) {
    let count = c.len();
    let mut j = 0;
    if micro != MicroKernel::Mk1x4 {
        while j + 8 <= count {
            let base = (b_start + j) * k;
            let bs: [&[f32]; 8] = std::array::from_fn(|t| &b[base + t * k..base + (t + 1) * k]);
            let s = dot8(arow, bs);
            c[j..j + 8].copy_from_slice(&s);
            j += 8;
        }
    }
    while j + NR <= count {
        let base = (b_start + j) * k;
        let b0 = &b[base..base + k];
        let b1 = &b[base + k..base + 2 * k];
        let b2 = &b[base + 2 * k..base + 3 * k];
        let b3 = &b[base + 3 * k..base + 4 * k];
        let (s0, s1, s2, s3) = dot4(arow, b0, b1, b2, b3);
        c[j] = s0;
        c[j + 1] = s1;
        c[j + 2] = s2;
        c[j + 3] = s3;
        j += NR;
    }
    while j < count {
        let base = (b_start + j) * k;
        c[j] = dot1(arow, &b[base..base + k]);
        j += 1;
    }
}

/// Accumulator lane width: explicit lane arrays express the reassociated
/// reduction LLVM cannot infer for float (perf-pass L3, EXPERIMENTS.md
/// §Perf) — each lane array vectorizes to one SIMD register.
const LANES: usize = 16;

#[inline(always)]
fn dot1(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let mut acc = [0f32; LANES];
    let mut i = 0;
    while i + LANES <= n {
        for l in 0..LANES {
            acc[l] += a[i + l] * b[i + l];
        }
        i += LANES;
    }
    let mut s = acc.iter().sum::<f32>();
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

#[inline(always)]
fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> (f32, f32, f32, f32) {
    let n = a.len();
    let (mut a0, mut a1, mut a2, mut a3) =
        ([0f32; LANES], [0f32; LANES], [0f32; LANES], [0f32; LANES]);
    let mut i = 0;
    while i + LANES <= n {
        let av = &a[i..i + LANES];
        let v0 = &b0[i..i + LANES];
        let v1 = &b1[i..i + LANES];
        let v2 = &b2[i..i + LANES];
        let v3 = &b3[i..i + LANES];
        for l in 0..LANES {
            a0[l] += av[l] * v0[l];
            a1[l] += av[l] * v1[l];
            a2[l] += av[l] * v2[l];
            a3[l] += av[l] * v3[l];
        }
        i += LANES;
    }
    let mut s = [
        a0.iter().sum::<f32>(),
        a1.iter().sum::<f32>(),
        a2.iter().sum::<f32>(),
        a3.iter().sum::<f32>(),
    ];
    while i < n {
        let av = a[i];
        s[0] += av * b0[i];
        s[1] += av * b1[i];
        s[2] += av * b2[i];
        s[3] += av * b3[i];
        i += 1;
    }
    (s[0], s[1], s[2], s[3])
}

/// 8-accumulator lane width: 8 B streams × 8 lanes = 64 live f32
/// accumulators plus the A lane — the widest block that stays out of
/// register-spill territory on 16-register SIMD files.
const LANES8: usize = 8;

/// One A row against eight B rows (the tunable 1×8 float micro-kernel).
#[inline(always)]
fn dot8(a: &[f32], bs: [&[f32]; 8]) -> [f32; 8] {
    let n = a.len();
    let mut acc = [[0f32; LANES8]; 8];
    let mut i = 0;
    while i + LANES8 <= n {
        let av = &a[i..i + LANES8];
        for (r, accr) in acc.iter_mut().enumerate() {
            let bv = &bs[r][i..i + LANES8];
            for l in 0..LANES8 {
                accr[l] += av[l] * bv[l];
            }
        }
        i += LANES8;
    }
    let mut s = [0f32; 8];
    for (r, sr) in s.iter_mut().enumerate() {
        *sr = acc[r].iter().sum::<f32>();
    }
    while i < n {
        let av = a[i];
        for (r, sr) in s.iter_mut().enumerate() {
            *sr += av * bs[r][i];
        }
        i += 1;
    }
    s
}

/// Tile-streaming float GEMM: the A operand is virtual — `fill(row0,
/// row1, panel)` produces A rows `[row0, row1)` on demand into a reused
/// per-worker panel (drawn from `panels`), which feeds the 1×4
/// micro-kernel directly. Bit-identical to materializing A and calling
/// [`sgemm_into`]: each output element is the same dot over the same row
/// contents. The fused convolution path drives this with
/// `tensor::unroll::unroll_f32_rows`.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_tiles_into(
    b: &[f32],
    out: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    tile_rows: usize,
    panels: &BufferPool<f32>,
    fill: &(dyn Fn(usize, usize, &mut [f32]) + Sync),
) {
    let lc = tune::lookup(Family::Float, 32, n, k);
    let choice = KernelChoice { tile_rows: tile_rows.max(1), ..lc };
    sgemm_tiles_with_choice(b, out, m, n, k, choice, panels, fill)
}

/// [`sgemm_tiles_into`] with an explicit kernel configuration.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_tiles_with_choice(
    b: &[f32],
    out: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    choice: KernelChoice,
    panels: &BufferPool<f32>,
    fill: &(dyn Fn(usize, usize, &mut [f32]) + Sync),
) {
    assert_eq!(b.len(), n * k, "B size");
    assert_eq!(out.len(), m * n, "C size");
    if m == 0 || n == 0 {
        return;
    }
    let tile = choice.tile_rows.max(1);
    let grain = tile.max(choice.grain.max(1));
    parallel_for_mut_chunks(out, n, grain, |row0, c_chunk| {
        let rows = c_chunk.len() / n;
        // worker-affine: same warm panel per scheduler slot (see
        // bitpack::gemm::gemm_tiles_into)
        let mut panel = panels.acquire_affine(current_slot(), tile * k);
        for t0 in (0..rows).step_by(tile) {
            let t1 = (t0 + tile).min(rows);
            fill(row0 + t0, row0 + t1, &mut panel[..(t1 - t0) * k]);
            for nb0 in (0..n).step_by(NB) {
                let nb1 = (nb0 + NB).min(n);
                for r in t0..t1 {
                    let arow = &panel[(r - t0) * k..(r - t0 + 1) * k];
                    let crow = &mut c_chunk[r * n + nb0..r * n + nb1];
                    row_panel(arow, b, crow, nb0, k, choice.micro);
                }
            }
        }
    });
}

/// Upper bound on simultaneously live A panels a [`sgemm_tiles_into`]
/// call with these dimensions will draw from its pool — what
/// `Layer::scratch` reserves, so fused forwards never miss. Shares the
/// registry lookup with the forward path so the two agree on the grain.
pub fn sgemm_tiles_workers(m: usize, n: usize, k: usize, tile_rows: usize) -> usize {
    let lc = tune::lookup(Family::Float, 32, n, k);
    max_workers_for(m, tile_rows.max(1).max(lc.grain.max(1)))
}

/// Allocating wrapper around [`sgemm_into`].
pub fn sgemm(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    sgemm_into(a, b, &mut out, m, n, k);
    out
}

/// Float GEMV (`m = 1` fast path).
pub fn sgemv_into(x: &[f32], b: &[f32], out: &mut [f32], n: usize, k: usize) {
    let choice = tune::lookup(Family::Float, 32, n, k);
    sgemv_with_choice(x, b, out, n, k, choice)
}

/// [`sgemv_into`] with an explicit kernel configuration (micro shape
/// only; the grain stays on the GEMV-specific formula).
pub fn sgemv_with_choice(
    x: &[f32],
    b: &[f32],
    out: &mut [f32],
    n: usize,
    k: usize,
    choice: KernelChoice,
) {
    assert_eq!(x.len(), k);
    assert_eq!(b.len(), n * k);
    assert_eq!(out.len(), n);
    let grain = ((1 << 16) / k.max(1)).max(8);
    parallel_for_mut_chunks(out, 1, grain, |j0, yc| {
        row_panel(x, b, yc, j0, k, choice.micro);
    });
}

/// Allocating wrapper around [`sgemv_into`].
pub fn sgemv(x: &[f32], b: &[f32], n: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0f32; n];
    sgemv_into(x, b, &mut out, n, k);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                // accumulate in f64 to expose f32 summation error in the kernel
                let mut acc = 0f64;
                for t in 0..k {
                    acc += a[i * k + t] as f64 * b[j * k + t] as f64;
                }
                out[i * n + j] = acc as f32;
            }
        }
        out
    }

    #[test]
    fn sgemm_matches_naive() {
        let mut rng = Rng::new(41);
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (3, 5, 7), (8, 33, 65), (17, 4, 129)] {
            let mut a = vec![0f32; m * k];
            let mut b = vec![0f32; n * k];
            rng.fill_uniform(&mut a, -1.0, 1.0);
            rng.fill_uniform(&mut b, -1.0, 1.0);
            let got = sgemm(&a, &b, m, n, k);
            let want = naive(&a, &b, m, n, k);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3 * k as f32, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn sgemm_exact_on_pm_one() {
        // With ±1 entries every partial sum is an exact small integer, so
        // the float kernel must agree with the binary kernel bit-for-bit.
        let mut rng = Rng::new(42);
        let (m, n, k) = (9, 14, 200);
        let a = rng.signs(m * k);
        let b = rng.signs(n * k);
        let got = sgemm(&a, &b, m, n, k);
        let pa = crate::bitpack::pack_matrix_rows::<u64>(&a, m, k);
        let pb = crate::bitpack::pack_matrix_rows::<u64>(&b, n, k);
        let bin = crate::bitpack::gemm::<u64>(&pa, &pb, m, n, k);
        for (g, w) in got.iter().zip(&bin) {
            assert_eq!(*g as i32, *w);
        }
    }

    /// Tile-streaming float GEMM must be bit-identical to the
    /// materializing kernel (same per-row accumulation order), for tile
    /// sizes that do and do not divide the row count.
    #[test]
    fn sgemm_tiles_matches_materialized() {
        let mut rng = Rng::new(44);
        let pool = crate::alloc::BufferPool::<f32>::new();
        for &(m, n, k, tile) in &[
            (17usize, 4usize, 129usize, 5usize),
            (8, 33, 65, 16),
            (3, 5, 7, 100),
        ] {
            let mut a = vec![0f32; m * k];
            let mut b = vec![0f32; n * k];
            rng.fill_uniform(&mut a, -1.0, 1.0);
            rng.fill_uniform(&mut b, -1.0, 1.0);
            let mut out = vec![0f32; m * n];
            sgemm_tiles_into(&b, &mut out, m, n, k, tile, &pool, &|r0, r1, panel| {
                panel.copy_from_slice(&a[r0 * k..r1 * k])
            });
            assert_eq!(out, sgemm(&a, &b, m, n, k), "({m},{n},{k},{tile})");
        }
    }

    /// Every float micro-kernel shape computes the same matrix. ±1
    /// entries make each dot an exact small integer, so the widened
    /// summation order cannot hide behind a tolerance.
    #[test]
    fn micro_kernel_shapes_agree() {
        let mut rng = Rng::new(45);
        let pool = crate::alloc::BufferPool::<f32>::new();
        for &(m, n, k) in &[(5usize, 9usize, 130usize), (8, 16, 64), (3, 33, 200), (1, 13, 100)] {
            let a = rng.signs(m * k);
            let b = rng.signs(n * k);
            let want = naive(&a, &b, m, n, k);
            for micro in [MicroKernel::Mk1x4, MicroKernel::Mk1x8, MicroKernel::Mk2x4] {
                let choice = KernelChoice { micro, tile_rows: 3, grain: 1 };
                let mut out = vec![0f32; m * n];
                sgemm_with_choice(&a, &b, &mut out, m, n, k, choice);
                assert_eq!(out, want, "sgemm {micro} ({m},{n},{k})");
                out.fill(0.0);
                sgemm_tiles_with_choice(&b, &mut out, m, n, k, choice, &pool, &|r0, r1, panel| {
                    panel.copy_from_slice(&a[r0 * k..r1 * k])
                });
                assert_eq!(out, want, "tiles {micro} ({m},{n},{k})");
                if m == 1 {
                    out.fill(0.0);
                    sgemv_with_choice(&a, &b, &mut out, n, k, choice);
                    assert_eq!(out, want, "sgemv {micro} ({n},{k})");
                }
            }
        }
    }

    #[test]
    fn sgemv_matches_sgemm_row() {
        let mut rng = Rng::new(43);
        let (n, k) = (77, 50);
        let mut x = vec![0f32; k];
        let mut b = vec![0f32; n * k];
        rng.fill_uniform(&mut x, -2.0, 2.0);
        rng.fill_uniform(&mut b, -2.0, 2.0);
        let via_mm = sgemm(&x, &b, 1, n, k);
        let via_mv = sgemv(&x, &b, n, k);
        for (a, b) in via_mm.iter().zip(&via_mv) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
