//! Dense float linear algebra — the non-binary comparator.
//!
//! The paper's `CPU` variant uses OpenBLAS and its `GPU` variant uses
//! MAGMA-derived sgemm kernels; offline we carry our own cache-blocked,
//! multithreaded sgemm/sgemv. It is not MKL, but it is a fair,
//! vectorizable float baseline for the speedup ratios the evaluation
//! reports (Tables 1–3).

pub mod gemm;

pub use gemm::{sgemm, sgemm_into, sgemm_tiles_into, sgemm_tiles_workers, sgemv, sgemv_into};
