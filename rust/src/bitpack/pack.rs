//! Sign binarization and bit-packing (paper §4.1–§4.2, §5.1).
//!
//! `sign(x) = +1 if x >= 0 else -1`; +1 packs to bit 1. Packing is done
//! *once at load time* for parameters (one of Espresso's headline
//! advantages over BinaryNet, which re-packs every forward call —
//! experiment **A2**), and on the hot path for activations via the
//! threshold packers below.

use super::word::{words_for, Word};

/// Pack a float slice into words: bit i of the output = `src[i] >= 0`.
/// Tail bits beyond `src.len()` are zero.
pub fn pack_signs<W: Word>(src: &[f32]) -> Vec<W> {
    let mut out = vec![W::ZERO; words_for::<W>(src.len())];
    pack_signs_into(src, &mut out);
    out
}

/// In-place variant of [`pack_signs`]; `out` must hold
/// `words_for::<W>(src.len())` words. Extra tail bits are cleared.
pub fn pack_signs_into<W: Word>(src: &[f32], out: &mut [W]) {
    let nw = words_for::<W>(src.len());
    assert!(out.len() >= nw, "out too small: {} < {}", out.len(), nw);
    for (wi, chunk) in src.chunks(W::BITS).enumerate() {
        let mut w = 0u64;
        for (bi, &v) in chunk.iter().enumerate() {
            // sign(0) = +1 per paper Eq. (1)
            w |= u64::from(v >= 0.0) << bi;
        }
        out[wi] = W::from_u64(w);
    }
    for w in out[nw..].iter_mut() {
        *w = W::ZERO;
    }
}

/// Pack int32 pre-activations against per-element float thresholds:
/// bit i = `(x[i] as f32) >= tau[i]` when `gamma_pos[i]`, else
/// `(x[i] as f32) <= tau[i]`.
///
/// This is the folded BatchNorm + sign activation of §6-style binary
/// pipelines: `sign(γ(x−μ)/σ + β)` reduces to a threshold comparison on
/// the integer GEMM accumulator (direction flips when γ < 0).
pub fn pack_thresholds_into<W: Word>(
    x: &[i32],
    tau: &[f32],
    gamma_pos: &[bool],
    out: &mut [W],
) {
    assert_eq!(x.len(), tau.len());
    assert_eq!(x.len(), gamma_pos.len());
    let nw = words_for::<W>(x.len());
    assert!(out.len() >= nw);
    for wi in 0..nw {
        let base = wi * W::BITS;
        let end = (base + W::BITS).min(x.len());
        let mut w = 0u64;
        for i in base..end {
            let v = x[i] as f32;
            let bit = if gamma_pos[i] { v >= tau[i] } else { v <= tau[i] };
            w |= u64::from(bit) << (i - base);
        }
        out[wi] = W::from_u64(w);
    }
    for w in out[nw..].iter_mut() {
        *w = W::ZERO;
    }
}

/// Float-domain variant of [`pack_thresholds_into`]: bit i =
/// `x[i] >= tau[i]` when `gamma_pos[i]`, else `x[i] <= tau[i]`. Used by
/// the scaled-epilogue tails (XNOR-Net K path), where the comparison runs
/// on f32 scores rather than the raw integer accumulator.
pub fn pack_thresholds_f32_into<W: Word>(
    x: &[f32],
    tau: &[f32],
    gamma_pos: &[bool],
    out: &mut [W],
) {
    assert_eq!(x.len(), tau.len());
    assert_eq!(x.len(), gamma_pos.len());
    let nw = words_for::<W>(x.len());
    assert!(out.len() >= nw);
    for wi in 0..nw {
        let base = wi * W::BITS;
        let end = (base + W::BITS).min(x.len());
        let mut w = 0u64;
        for i in base..end {
            let v = x[i];
            let bit = if gamma_pos[i] { v >= tau[i] } else { v <= tau[i] };
            w |= u64::from(bit) << (i - base);
        }
        out[wi] = W::from_u64(w);
    }
    for w in out[nw..].iter_mut() {
        *w = W::ZERO;
    }
}

/// Unpack words back to ±1 floats (`n` = logical length).
pub fn unpack_signs<W: Word>(src: &[W], n: usize) -> Vec<f32> {
    assert!(src.len() >= words_for::<W>(n));
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let w = src[i / W::BITS];
        out.push(if w.get_bit(i % W::BITS) { 1.0 } else { -1.0 });
    }
    out
}

/// Pack an `m x k` row-major float matrix by rows: each row is padded to
/// a word boundary, giving `m * words_for(k)` words. This is the layout
/// binary GEMM consumes for both operands (B is stored transposed,
/// one row per output neuron).
pub fn pack_matrix_rows<W: Word>(src: &[f32], m: usize, k: usize) -> Vec<W> {
    assert_eq!(src.len(), m * k);
    let kw = words_for::<W>(k);
    let mut out = vec![W::ZERO; m * kw];
    for r in 0..m {
        pack_signs_into(&src[r * k..(r + 1) * k], &mut out[r * kw..(r + 1) * kw]);
    }
    out
}

/// Pack an `m x k` row-major float matrix by **columns**: output is
/// `k x words_for(m)`, column j of the input becomes packed row j of the
/// output. Strided reads make this inherently slower than row packing —
/// this is the access pattern the paper blames for BinaryNet's
/// "pack-by-columns kernel ≈4× slower" (§6.2); kept here as the correct
/// reference, and measured in the baselines.
pub fn pack_matrix_cols<W: Word>(src: &[f32], m: usize, k: usize) -> Vec<W> {
    assert_eq!(src.len(), m * k);
    let mw = words_for::<W>(m);
    let mut out = vec![W::ZERO; k * mw];
    for j in 0..k {
        for i in 0..m {
            if src[i * k + j] >= 0.0 {
                let w = &mut out[j * mw + i / W::BITS];
                *w = *w | W::bit(i % W::BITS);
            }
        }
    }
    out
}

/// Count logical memory for a packed `m x k` matrix in bytes.
pub fn packed_bytes<W: Word>(m: usize, k: usize) -> usize {
    m * words_for::<W>(k) * (W::BITS / 8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pack_unpack_roundtrip_u64() {
        let mut rng = Rng::new(3);
        for n in [1, 7, 63, 64, 65, 127, 128, 1000] {
            let v = rng.signs(n);
            let packed = pack_signs::<u64>(&v);
            assert_eq!(packed.len(), words_for::<u64>(n));
            assert_eq!(unpack_signs(&packed, n), v);
        }
    }

    #[test]
    fn pack_unpack_roundtrip_u32() {
        let mut rng = Rng::new(4);
        for n in [1, 31, 32, 33, 100] {
            let v = rng.signs(n);
            let packed = pack_signs::<u32>(&v);
            assert_eq!(unpack_signs(&packed, n), v);
        }
    }

    #[test]
    fn sign_zero_is_plus_one() {
        let packed = pack_signs::<u64>(&[0.0, -0.5, 0.5]);
        let un = unpack_signs(&packed, 3);
        assert_eq!(un, vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn tail_bits_are_zero() {
        let v = vec![1.0f32; 5]; // bits 0..5 set
        let packed = pack_signs::<u64>(&v);
        assert_eq!(packed[0], 0b11111);
    }

    #[test]
    fn pack_rows_matches_per_row_pack() {
        let mut rng = Rng::new(5);
        let (m, k) = (7, 130);
        let mat = rng.signs(m * k);
        let packed = pack_matrix_rows::<u64>(&mat, m, k);
        let kw = words_for::<u64>(k);
        for r in 0..m {
            let row = pack_signs::<u64>(&mat[r * k..(r + 1) * k]);
            assert_eq!(&packed[r * kw..(r + 1) * kw], &row[..]);
        }
    }

    #[test]
    fn pack_cols_is_transpose_of_pack_rows() {
        let mut rng = Rng::new(6);
        let (m, k) = (70, 9);
        let mat = rng.signs(m * k);
        // transpose manually then row-pack; must equal col-pack
        let mut t = vec![0f32; m * k];
        for i in 0..m {
            for j in 0..k {
                t[j * m + i] = mat[i * k + j];
            }
        }
        let via_t = pack_matrix_rows::<u64>(&t, k, m);
        let direct = pack_matrix_cols::<u64>(&mat, m, k);
        assert_eq!(via_t, direct);
    }

    #[test]
    fn threshold_pack_matches_float_bn_sign() {
        let mut rng = Rng::new(7);
        let n = 200;
        let x: Vec<i32> = (0..n).map(|_| rng.range_i64(-500, 500) as i32).collect();
        // random BN params, gamma nonzero
        let gamma: Vec<f32> = (0..n)
            .map(|_| {
                let g = rng.f32_range(-2.0, 2.0);
                if g.abs() < 0.1 {
                    0.5
                } else {
                    g
                }
            })
            .collect();
        let beta: Vec<f32> = (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let mu: Vec<f32> = (0..n).map(|_| rng.f32_range(-20.0, 20.0)).collect();
        let sigma: Vec<f32> = (0..n).map(|_| rng.f32_range(0.5, 3.0)).collect();
        let tau: Vec<f32> = (0..n)
            .map(|i| mu[i] - beta[i] * sigma[i] / gamma[i])
            .collect();
        let gamma_pos: Vec<bool> = gamma.iter().map(|&g| g > 0.0).collect();
        let mut out = vec![0u64; words_for::<u64>(n)];
        pack_thresholds_into(&x, &tau, &gamma_pos, &mut out);
        let bits = unpack_signs(&out, n);
        for i in 0..n {
            let bn = gamma[i] * (x[i] as f32 - mu[i]) / sigma[i] + beta[i];
            // skip near-boundary cases where fp assoc could differ
            if bn.abs() < 1e-3 {
                continue;
            }
            let expect = if bn >= 0.0 { 1.0 } else { -1.0 };
            assert_eq!(bits[i], expect, "i={i} bn={bn}");
        }
    }

    #[test]
    fn packed_bytes_reports_32x_saving() {
        // 4096x4096 float = 64 MiB; packed u64 = 2 MiB
        let float_bytes = 4096 * 4096 * 4;
        let packed = packed_bytes::<u64>(4096, 4096);
        assert_eq!(float_bytes / packed, 32);
    }
}
