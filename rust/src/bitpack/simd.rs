//! SIMD popcount paths for the packed kernels (perf-pass L3 iteration 2,
//! see EXPERIMENTS.md §Perf).
//!
//! The scalar kernel is POPCNT-port-limited (~1 word-pair/cycle); the
//! AVX2 path uses the classic PSHUFB nibble-LUT positional popcount +
//! SAD accumulation (Muła et al.), processing 4 packed u64 words per
//! vector op. Dispatch is runtime-detected once and cached; the scalar
//! path remains both the fallback and the reference in tests.

#[cfg(target_arch = "aarch64")]
use std::arch::aarch64::*;
#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;
use std::sync::atomic::{AtomicU8, Ordering};

/// Cached runtime CPU-feature dispatch level.
pub const LEVEL_SCALAR: u8 = 1;
/// AVX2 PSHUFB-LUT / Harley–Seal popcount kernels.
pub const LEVEL_AVX2: u8 = 2;
/// AVX-512 VPOPCNTDQ kernels (needs an `espresso_avx512`-capable build).
pub const LEVEL_AVX512: u8 = 3;
/// AArch64 NEON `cnt`-based kernels.
pub const LEVEL_NEON: u8 = 4;

/// Cached runtime CPU-feature dispatch (0 = unknown, then one of the
/// `LEVEL_*` constants).
static LEVEL: AtomicU8 = AtomicU8::new(0);

/// Whether this build + this CPU can actually run dispatch level `l`.
pub fn level_available(l: u8) -> bool {
    match l {
        LEVEL_SCALAR => true,
        #[cfg(target_arch = "x86_64")]
        LEVEL_AVX2 => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(all(target_arch = "x86_64", espresso_avx512))]
        LEVEL_AVX512 => {
            std::arch::is_x86_feature_detected!("avx512f")
                && std::arch::is_x86_feature_detected!("avx512vpopcntdq")
        }
        #[cfg(target_arch = "aarch64")]
        LEVEL_NEON => std::arch::is_aarch64_feature_detected!("neon"),
        _ => false,
    }
}

/// Best dispatch level this build + CPU supports (what
/// `ESPRESSO_SIMD=auto` resolves to).
pub fn best_level() -> u8 {
    for l in [LEVEL_NEON, LEVEL_AVX512, LEVEL_AVX2] {
        if level_available(l) {
            return l;
        }
    }
    LEVEL_SCALAR
}

/// The dispatch level currently in effect (detects on first use).
#[inline]
pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 0 {
        return l;
    }
    // Default is the scalar formulation: built with `-C target-cpu=native`
    // LLVM auto-vectorizes it with the widest available ISA (measured
    // faster than the hand-written AVX2 LUT on AVX-512 hosts — see
    // EXPERIMENTS.md §Perf). `ESPRESSO_SIMD` opts into a manual path for
    // baseline builds where autovec cannot use popcount: `avx2`, `avx512`
    // and `neon` select that kernel family when the CPU (and, for
    // AVX-512, the toolchain) supports it, silently falling back to
    // scalar when it does not; `auto` picks the best available; `scalar`
    // / `off` / empty pin the scalar path.
    let detected = match std::env::var("ESPRESSO_SIMD").as_deref() {
        Ok("avx2") if level_available(LEVEL_AVX2) => LEVEL_AVX2,
        Ok("avx512") if level_available(LEVEL_AVX512) => LEVEL_AVX512,
        Ok("neon") if level_available(LEVEL_NEON) => LEVEL_NEON,
        Ok("auto") => best_level(),
        Ok("avx2" | "avx512" | "neon" | "scalar" | "off" | "") | Err(_) => LEVEL_SCALAR,
        Ok(other) => {
            eprintln!(
                "espresso: unknown ESPRESSO_SIMD value {other:?} \
                 (valid: scalar|off|avx2|avx512|neon|auto); using scalar"
            );
            LEVEL_SCALAR
        }
    };
    LEVEL.store(detected, Ordering::Relaxed);
    detected
}

/// Short name of a dispatch level (bench/tune reporting).
pub fn level_name(l: u8) -> &'static str {
    match l {
        LEVEL_SCALAR => "scalar",
        LEVEL_AVX2 => "avx2",
        LEVEL_AVX512 => "avx512",
        LEVEL_NEON => "neon",
        _ => "unknown",
    }
}

/// Override dispatch (tests/benches): 0 = re-detect, else a `LEVEL_*`
/// constant. Callers must only force levels `level_available` accepts.
pub fn force_level(l: u8) {
    LEVEL.store(l, Ordering::Relaxed);
}

/// Row length (in u64 words) from which the Harley–Seal carry-save
/// accumulator beats the plain LUT loop: the CSA tree retires 16 vectors
/// per PSHUFB-popcount, so its advantage needs long streams to amortize
/// (wide unrolled conv rows and MLP reductions qualify).
const HS_MIN_WORDS: usize = 64;

/// popcount(xor) over one pair of packed rows.
#[inline]
pub fn mismatches_u64(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    {
        let l = level();
        if l == LEVEL_AVX2 && a.len() >= 8 {
            // SAFETY: avx2 presence checked by `level`
            return unsafe { mismatches_dispatch_avx2(a, b) };
        }
        #[cfg(espresso_avx512)]
        if l == LEVEL_AVX512 && a.len() >= 8 {
            // SAFETY: avx512f+vpopcntdq presence checked by `level`
            return unsafe { mismatches_avx512(a, b) };
        }
    }
    #[cfg(target_arch = "aarch64")]
    if level() == LEVEL_NEON && a.len() >= 2 {
        // SAFETY: neon presence checked by `level`
        return unsafe { mismatches_neon(a, b) };
    }
    mismatches_scalar(a, b)
}

/// Length-based choice between the LUT loop and the Harley–Seal
/// accumulator (both AVX2; caller guarantees the feature).
#[cfg(target_arch = "x86_64")]
#[inline]
unsafe fn mismatches_dispatch_avx2(a: &[u64], b: &[u64]) -> u32 {
    if a.len() >= HS_MIN_WORDS {
        mismatches_hs_avx2(a, b)
    } else {
        mismatches_avx2(a, b)
    }
}

/// u32-word variant: same byte stream, reinterpreted. The vector kernels
/// are width-agnostic (popcount over bytes); the scalar tail runs per
/// word.
#[inline]
pub fn mismatches_u32(a: &[u32], b: &[u32]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    if level() != LEVEL_SCALAR && a.len() >= 16 {
        let pairs = a.len() / 2;
        // SAFETY: u32 slices reinterpreted as u64 pairs (every vector
        // load below is unaligned-tolerant, so only size matters); the
        // odd tail word runs scalar. `mismatches_u64` re-checks the
        // dispatch level, so a level without a kernel on this arch still
        // lands on the scalar path.
        let head = unsafe {
            mismatches_u64(
                std::slice::from_raw_parts(a.as_ptr() as *const u64, pairs),
                std::slice::from_raw_parts(b.as_ptr() as *const u64, pairs),
            )
        };
        let mut total = head;
        for i in pairs * 2..a.len() {
            total += (a[i] ^ b[i]).count_ones();
        }
        return total;
    }
    let mut acc = 0u32;
    for i in 0..a.len() {
        acc += (a[i] ^ b[i]).count_ones();
    }
    acc
}

/// 4-row u32 variant (see `mismatches4_u64`).
#[inline]
pub fn mismatches4_u32(
    a: &[u32],
    b0: &[u32],
    b1: &[u32],
    b2: &[u32],
    b3: &[u32],
) -> (u32, u32, u32, u32) {
    if level() != LEVEL_SCALAR && a.len() >= 16 {
        let pairs = a.len() / 2;
        // SAFETY: as in `mismatches_u32`; `mismatches4_u64` re-checks the
        // dispatch level itself
        let (mut c0, mut c1, mut c2, mut c3) = unsafe {
            mismatches4_u64(
                std::slice::from_raw_parts(a.as_ptr() as *const u64, pairs),
                std::slice::from_raw_parts(b0.as_ptr() as *const u64, pairs),
                std::slice::from_raw_parts(b1.as_ptr() as *const u64, pairs),
                std::slice::from_raw_parts(b2.as_ptr() as *const u64, pairs),
                std::slice::from_raw_parts(b3.as_ptr() as *const u64, pairs),
            )
        };
        for i in pairs * 2..a.len() {
            let av = a[i];
            c0 += (av ^ b0[i]).count_ones();
            c1 += (av ^ b1[i]).count_ones();
            c2 += (av ^ b2[i]).count_ones();
            c3 += (av ^ b3[i]).count_ones();
        }
        return (c0, c1, c2, c3);
    }
    let n = a.len();
    let (mut c0, mut c1, mut c2, mut c3) = (0u32, 0u32, 0u32, 0u32);
    for i in 0..n {
        let av = a[i];
        c0 += (av ^ b0[i]).count_ones();
        c1 += (av ^ b1[i]).count_ones();
        c2 += (av ^ b2[i]).count_ones();
        c3 += (av ^ b3[i]).count_ones();
    }
    (c0, c1, c2, c3)
}

/// popcount(xor) of one packed row against four rows simultaneously
/// (register-blocked micro-kernel: the `a` load is amortized 4×).
#[inline]
pub fn mismatches4_u64(
    a: &[u64],
    b0: &[u64],
    b1: &[u64],
    b2: &[u64],
    b3: &[u64],
) -> (u32, u32, u32, u32) {
    #[cfg(target_arch = "x86_64")]
    {
        let l = level();
        if l == LEVEL_AVX2 && a.len() >= 8 {
            // SAFETY: avx2 presence checked by `level`
            return unsafe { mismatches4_avx2(a, b0, b1, b2, b3) };
        }
        #[cfg(espresso_avx512)]
        if l == LEVEL_AVX512 && a.len() >= 8 {
            // SAFETY: avx512f+vpopcntdq presence checked by `level`
            return unsafe { mismatches4_avx512(a, b0, b1, b2, b3) };
        }
    }
    #[cfg(target_arch = "aarch64")]
    if level() == LEVEL_NEON && a.len() >= 2 {
        // SAFETY: neon presence checked by `level`
        return unsafe { mismatches4_neon(a, b0, b1, b2, b3) };
    }
    mismatches4_scalar(a, b0, b1, b2, b3)
}

// ---------------------------------------------------------------------
// scalar reference paths
// ---------------------------------------------------------------------

#[inline]
pub fn mismatches_scalar(a: &[u64], b: &[u64]) -> u32 {
    let mut acc = 0u32;
    let mut acc2 = 0u32;
    let n = a.len();
    let mut i = 0;
    while i + 2 <= n {
        acc += (a[i] ^ b[i]).count_ones();
        acc2 += (a[i + 1] ^ b[i + 1]).count_ones();
        i += 2;
    }
    if i < n {
        acc += (a[i] ^ b[i]).count_ones();
    }
    acc + acc2
}

#[inline]
fn mismatches4_scalar(
    a: &[u64],
    b0: &[u64],
    b1: &[u64],
    b2: &[u64],
    b3: &[u64],
) -> (u32, u32, u32, u32) {
    let n = a.len();
    let (mut c0, mut c1, mut c2, mut c3) = (0u32, 0u32, 0u32, 0u32);
    for i in 0..n {
        let av = a[i];
        c0 += (av ^ b0[i]).count_ones();
        c1 += (av ^ b1[i]).count_ones();
        c2 += (av ^ b2[i]).count_ones();
        c3 += (av ^ b3[i]).count_ones();
    }
    (c0, c1, c2, c3)
}

// ---------------------------------------------------------------------
// AVX2: PSHUFB nibble-LUT popcount, SAD accumulation
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[inline]
unsafe fn popcount256(v: __m256i, lut: __m256i, mask: __m256i) -> __m256i {
    // byte-wise popcount of v, then horizontal SAD into 4 u64 lanes
    let lo = _mm256_and_si256(v, mask);
    let hi = _mm256_and_si256(_mm256_srli_epi32(v, 4), mask);
    let pc = _mm256_add_epi8(
        _mm256_shuffle_epi8(lut, lo),
        _mm256_shuffle_epi8(lut, hi),
    );
    _mm256_sad_epu8(pc, _mm256_setzero_si256())
}

#[cfg(target_arch = "x86_64")]
#[inline]
unsafe fn hsum256_epi64(v: __m256i) -> u64 {
    let lo = _mm256_castsi256_si128(v);
    let hi = _mm256_extracti128_si256(v, 1);
    let s = _mm_add_epi64(lo, hi);
    (_mm_extract_epi64(s, 0) + _mm_extract_epi64(s, 1)) as u64
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mismatches_avx2(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len();
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2,
        3, 3, 4,
    );
    let mask = _mm256_set1_epi8(0x0f);
    let mut acc = _mm256_setzero_si256();
    let chunks = n / 4;
    let ap = a.as_ptr() as *const __m256i;
    let bp = b.as_ptr() as *const __m256i;
    for i in 0..chunks {
        let x = _mm256_xor_si256(_mm256_loadu_si256(ap.add(i)), _mm256_loadu_si256(bp.add(i)));
        acc = _mm256_add_epi64(acc, popcount256(x, lut, mask));
    }
    let mut total = hsum256_epi64(acc) as u32;
    for i in chunks * 4..n {
        total += (a[i] ^ b[i]).count_ones();
    }
    total
}

/// Carry-save adder: `(higher, lower)` bit-planes of `a + b + c`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn csa(a: __m256i, b: __m256i, c: __m256i) -> (__m256i, __m256i) {
    let u = _mm256_xor_si256(a, b);
    (
        _mm256_or_si256(_mm256_and_si256(a, b), _mm256_and_si256(u, c)),
        _mm256_xor_si256(u, c),
    )
}

/// Load the `i`-th 256-bit lanes of both streams and xor them.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn xor_at(ap: *const __m256i, bp: *const __m256i, i: usize) -> __m256i {
    _mm256_xor_si256(_mm256_loadu_si256(ap.add(i)), _mm256_loadu_si256(bp.add(i)))
}

/// Harley–Seal popcount of `xor(a, b)` for long rows (Muła, Kurz,
/// Lemire): a CSA tree folds 16 xor vectors into ones/twos/fours/eights
/// counter planes and runs the PSHUFB popcount only on the "sixteens"
/// overflow — 1 byte-popcount per 16 vectors instead of 1 per vector, so
/// the popcount port stops being the bottleneck on kw ≥ [`HS_MIN_WORDS`]
/// rows. Remainder vectors take the LUT path, remainder words scalar.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mismatches_hs_avx2(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len();
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2,
        3, 3, 4,
    );
    let mask = _mm256_set1_epi8(0x0f);
    let ap = a.as_ptr() as *const __m256i;
    let bp = b.as_ptr() as *const __m256i;
    let vecs = n / 4;
    let blocks = vecs / 16;
    let mut total = _mm256_setzero_si256();
    let mut ones = _mm256_setzero_si256();
    let mut twos = _mm256_setzero_si256();
    let mut fours = _mm256_setzero_si256();
    let mut eights = _mm256_setzero_si256();
    for blk in 0..blocks {
        let i = blk * 16;
        let (twos_a, l) = csa(ones, xor_at(ap, bp, i), xor_at(ap, bp, i + 1));
        ones = l;
        let (twos_b, l) = csa(ones, xor_at(ap, bp, i + 2), xor_at(ap, bp, i + 3));
        ones = l;
        let (fours_a, l) = csa(twos, twos_a, twos_b);
        twos = l;
        let (twos_a, l) = csa(ones, xor_at(ap, bp, i + 4), xor_at(ap, bp, i + 5));
        ones = l;
        let (twos_b, l) = csa(ones, xor_at(ap, bp, i + 6), xor_at(ap, bp, i + 7));
        ones = l;
        let (fours_b, l) = csa(twos, twos_a, twos_b);
        twos = l;
        let (eights_a, l) = csa(fours, fours_a, fours_b);
        fours = l;
        let (twos_a, l) = csa(ones, xor_at(ap, bp, i + 8), xor_at(ap, bp, i + 9));
        ones = l;
        let (twos_b, l) = csa(ones, xor_at(ap, bp, i + 10), xor_at(ap, bp, i + 11));
        ones = l;
        let (fours_a, l) = csa(twos, twos_a, twos_b);
        twos = l;
        let (twos_a, l) = csa(ones, xor_at(ap, bp, i + 12), xor_at(ap, bp, i + 13));
        ones = l;
        let (twos_b, l) = csa(ones, xor_at(ap, bp, i + 14), xor_at(ap, bp, i + 15));
        ones = l;
        let (fours_b, l) = csa(twos, twos_a, twos_b);
        twos = l;
        let (eights_b, l) = csa(fours, fours_a, fours_b);
        fours = l;
        let (sixteens, l) = csa(eights, eights_a, eights_b);
        eights = l;
        total = _mm256_add_epi64(total, popcount256(sixteens, lut, mask));
    }
    // weight the residual counter planes: total·16 + eights·8 + fours·4
    // + twos·2 + ones
    total = _mm256_slli_epi64(total, 4);
    total = _mm256_add_epi64(
        total,
        _mm256_slli_epi64(popcount256(eights, lut, mask), 3),
    );
    total = _mm256_add_epi64(
        total,
        _mm256_slli_epi64(popcount256(fours, lut, mask), 2),
    );
    total = _mm256_add_epi64(total, _mm256_slli_epi64(popcount256(twos, lut, mask), 1));
    total = _mm256_add_epi64(total, popcount256(ones, lut, mask));
    for i in blocks * 16..vecs {
        total = _mm256_add_epi64(total, popcount256(xor_at(ap, bp, i), lut, mask));
    }
    let mut count = hsum256_epi64(total) as u32;
    for i in vecs * 4..n {
        count += (a[i] ^ b[i]).count_ones();
    }
    count
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mismatches4_avx2(
    a: &[u64],
    b0: &[u64],
    b1: &[u64],
    b2: &[u64],
    b3: &[u64],
) -> (u32, u32, u32, u32) {
    let n = a.len();
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2,
        3, 3, 4,
    );
    let mask = _mm256_set1_epi8(0x0f);
    let (mut s0, mut s1, mut s2, mut s3) = (
        _mm256_setzero_si256(),
        _mm256_setzero_si256(),
        _mm256_setzero_si256(),
        _mm256_setzero_si256(),
    );
    let chunks = n / 4;
    let ap = a.as_ptr() as *const __m256i;
    let p0 = b0.as_ptr() as *const __m256i;
    let p1 = b1.as_ptr() as *const __m256i;
    let p2 = b2.as_ptr() as *const __m256i;
    let p3 = b3.as_ptr() as *const __m256i;
    for i in 0..chunks {
        let av = _mm256_loadu_si256(ap.add(i));
        s0 = _mm256_add_epi64(
            s0,
            popcount256(_mm256_xor_si256(av, _mm256_loadu_si256(p0.add(i))), lut, mask),
        );
        s1 = _mm256_add_epi64(
            s1,
            popcount256(_mm256_xor_si256(av, _mm256_loadu_si256(p1.add(i))), lut, mask),
        );
        s2 = _mm256_add_epi64(
            s2,
            popcount256(_mm256_xor_si256(av, _mm256_loadu_si256(p2.add(i))), lut, mask),
        );
        s3 = _mm256_add_epi64(
            s3,
            popcount256(_mm256_xor_si256(av, _mm256_loadu_si256(p3.add(i))), lut, mask),
        );
    }
    let (mut c0, mut c1, mut c2, mut c3) = (
        hsum256_epi64(s0) as u32,
        hsum256_epi64(s1) as u32,
        hsum256_epi64(s2) as u32,
        hsum256_epi64(s3) as u32,
    );
    for i in chunks * 4..n {
        let av = a[i];
        c0 += (av ^ b0[i]).count_ones();
        c1 += (av ^ b1[i]).count_ones();
        c2 += (av ^ b2[i]).count_ones();
        c3 += (av ^ b3[i]).count_ones();
    }
    (c0, c1, c2, c3)
}

// ---------------------------------------------------------------------
// AVX-512: VPOPCNTDQ (native 64-bit-lane popcount)
// ---------------------------------------------------------------------

/// VPOPCNTDQ path: xor + per-u64-lane popcount + lane-wise add, 8 words
/// per vector op. No LUT, no SAD — the popcount runs in one instruction,
/// so unlike AVX2 there is no long-row Harley–Seal variant to amortize
/// it. Requires a 1.89+ toolchain (`espresso_avx512` cfg from build.rs).
#[cfg(all(target_arch = "x86_64", espresso_avx512))]
#[target_feature(enable = "avx512f,avx512vpopcntdq")]
unsafe fn mismatches_avx512(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len();
    let chunks = n / 8;
    let ap = a.as_ptr() as *const i64;
    let bp = b.as_ptr() as *const i64;
    let mut acc = _mm512_setzero_si512();
    for i in 0..chunks {
        let x = _mm512_xor_si512(
            _mm512_loadu_epi64(ap.add(i * 8)),
            _mm512_loadu_epi64(bp.add(i * 8)),
        );
        acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(x));
    }
    let mut total = _mm512_reduce_add_epi64(acc) as u32;
    for i in chunks * 8..n {
        total += (a[i] ^ b[i]).count_ones();
    }
    total
}

#[cfg(all(target_arch = "x86_64", espresso_avx512))]
#[target_feature(enable = "avx512f,avx512vpopcntdq")]
unsafe fn mismatches4_avx512(
    a: &[u64],
    b0: &[u64],
    b1: &[u64],
    b2: &[u64],
    b3: &[u64],
) -> (u32, u32, u32, u32) {
    let n = a.len();
    let chunks = n / 8;
    let ap = a.as_ptr() as *const i64;
    let p0 = b0.as_ptr() as *const i64;
    let p1 = b1.as_ptr() as *const i64;
    let p2 = b2.as_ptr() as *const i64;
    let p3 = b3.as_ptr() as *const i64;
    let (mut s0, mut s1, mut s2, mut s3) = (
        _mm512_setzero_si512(),
        _mm512_setzero_si512(),
        _mm512_setzero_si512(),
        _mm512_setzero_si512(),
    );
    for i in 0..chunks {
        let av = _mm512_loadu_epi64(ap.add(i * 8));
        s0 = _mm512_add_epi64(
            s0,
            _mm512_popcnt_epi64(_mm512_xor_si512(av, _mm512_loadu_epi64(p0.add(i * 8)))),
        );
        s1 = _mm512_add_epi64(
            s1,
            _mm512_popcnt_epi64(_mm512_xor_si512(av, _mm512_loadu_epi64(p1.add(i * 8)))),
        );
        s2 = _mm512_add_epi64(
            s2,
            _mm512_popcnt_epi64(_mm512_xor_si512(av, _mm512_loadu_epi64(p2.add(i * 8)))),
        );
        s3 = _mm512_add_epi64(
            s3,
            _mm512_popcnt_epi64(_mm512_xor_si512(av, _mm512_loadu_epi64(p3.add(i * 8)))),
        );
    }
    let (mut c0, mut c1, mut c2, mut c3) = (
        _mm512_reduce_add_epi64(s0) as u32,
        _mm512_reduce_add_epi64(s1) as u32,
        _mm512_reduce_add_epi64(s2) as u32,
        _mm512_reduce_add_epi64(s3) as u32,
    );
    for i in chunks * 8..n {
        let av = a[i];
        c0 += (av ^ b0[i]).count_ones();
        c1 += (av ^ b1[i]).count_ones();
        c2 += (av ^ b2[i]).count_ones();
        c3 += (av ^ b3[i]).count_ones();
    }
    (c0, c1, c2, c3)
}

// ---------------------------------------------------------------------
// AArch64 NEON: CNT (byte popcount) + pairwise-widening accumulation
// ---------------------------------------------------------------------

/// Flush the u16-lane NEON accumulator at least this often: each
/// pair-iteration adds ≤ 16 to a lane (vpaddlq of two fully-set bytes),
/// and 1024 × 16 = 16384 stays far below the u16 ceiling.
#[cfg(target_arch = "aarch64")]
const NEON_FLUSH_PAIRS: usize = 1024;

/// NEON path: xor + `cnt` byte popcount + `vpaddlq` pairwise widening
/// into u16 lanes, 2 words per vector op, flushed to a scalar total via
/// `vaddlvq` every [`NEON_FLUSH_PAIRS`] iterations so lanes cannot wrap.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn mismatches_neon(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len();
    let pairs = n / 2;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut total = 0u32;
    let mut i = 0usize;
    while i < pairs {
        let block = (pairs - i).min(NEON_FLUSH_PAIRS);
        let mut acc = vdupq_n_u16(0);
        for j in i..i + block {
            let x = veorq_u64(vld1q_u64(ap.add(j * 2)), vld1q_u64(bp.add(j * 2)));
            acc = vaddq_u16(acc, vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(x))));
        }
        total += vaddlvq_u16(acc);
        i += block;
    }
    for w in pairs * 2..n {
        total += (a[w] ^ b[w]).count_ones();
    }
    total
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn mismatches4_neon(
    a: &[u64],
    b0: &[u64],
    b1: &[u64],
    b2: &[u64],
    b3: &[u64],
) -> (u32, u32, u32, u32) {
    let n = a.len();
    let pairs = n / 2;
    let ap = a.as_ptr();
    let p0 = b0.as_ptr();
    let p1 = b1.as_ptr();
    let p2 = b2.as_ptr();
    let p3 = b3.as_ptr();
    let (mut c0, mut c1, mut c2, mut c3) = (0u32, 0u32, 0u32, 0u32);
    let mut i = 0usize;
    while i < pairs {
        let block = (pairs - i).min(NEON_FLUSH_PAIRS);
        let mut s0 = vdupq_n_u16(0);
        let mut s1 = vdupq_n_u16(0);
        let mut s2 = vdupq_n_u16(0);
        let mut s3 = vdupq_n_u16(0);
        for j in i..i + block {
            let av = vld1q_u64(ap.add(j * 2));
            let x0 = veorq_u64(av, vld1q_u64(p0.add(j * 2)));
            s0 = vaddq_u16(s0, vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(x0))));
            let x1 = veorq_u64(av, vld1q_u64(p1.add(j * 2)));
            s1 = vaddq_u16(s1, vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(x1))));
            let x2 = veorq_u64(av, vld1q_u64(p2.add(j * 2)));
            s2 = vaddq_u16(s2, vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(x2))));
            let x3 = veorq_u64(av, vld1q_u64(p3.add(j * 2)));
            s3 = vaddq_u16(s3, vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(x3))));
        }
        c0 += vaddlvq_u16(s0);
        c1 += vaddlvq_u16(s1);
        c2 += vaddlvq_u16(s2);
        c3 += vaddlvq_u16(s3);
        i += block;
    }
    for w in pairs * 2..n {
        let av = a[w];
        c0 += (av ^ b0[w]).count_ones();
        c1 += (av ^ b1[w]).count_ones();
        c2 += (av ^ b2[w]).count_ones();
        c3 += (av ^ b3[w]).count_ones();
    }
    (c0, c1, c2, c3)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn avx2_matches_scalar_mismatches() {
        let mut rng = Rng::new(211);
        for n in [1usize, 3, 4, 7, 8, 9, 16, 31, 64, 100, 257] {
            let a = rng.words(n);
            let b = rng.words(n);
            let scalar = mismatches_scalar(&a, &b);
            force_level(0); // re-detect
            let auto = mismatches_u64(&a, &b);
            assert_eq!(scalar, auto, "n={n}");
        }
    }

    #[test]
    fn avx2_matches_scalar_mismatches4() {
        let mut rng = Rng::new(212);
        for n in [1usize, 4, 8, 12, 33, 128] {
            let a = rng.words(n);
            let b: Vec<Vec<u64>> = (0..4).map(|_| rng.words(n)).collect();
            let want = mismatches4_scalar(&a, &b[0], &b[1], &b[2], &b[3]);
            force_level(0);
            let got = mismatches4_u64(&a, &b[0], &b[1], &b[2], &b[3]);
            assert_eq!(want, got, "n={n}");
        }
    }

    /// Scalar parity of the Harley–Seal accumulator across the dispatch
    /// boundary and every remainder shape: block multiples (64, 128),
    /// vector remainders, word remainders, and lengths just under the
    /// HS cutoff (which exercise the LUT path through the same entry).
    #[test]
    fn harley_seal_matches_scalar_long_rows() {
        #[cfg(target_arch = "x86_64")]
        {
            if !std::arch::is_x86_feature_detected!("avx2") {
                return;
            }
            let mut rng = Rng::new(214);
            for n in [
                63usize, 64, 65, 67, 68, 96, 100, 127, 128, 129, 192, 257, 1000, 1024,
            ] {
                let a = rng.words(n);
                let b = rng.words(n);
                let want = mismatches_scalar(&a, &b);
                force_level(2);
                let got = mismatches_u64(&a, &b);
                force_level(0);
                assert_eq!(want, got, "n={n}");
            }
            // extremes survive the CSA weighting (every plane saturated)
            let zeros = vec![0u64; 200];
            let ones = vec![!0u64; 200];
            force_level(2);
            assert_eq!(mismatches_u64(&zeros, &ones), 200 * 64);
            assert_eq!(mismatches_u64(&ones, &ones), 0);
            force_level(0);
        }
    }

    /// The u32 entry reinterprets word pairs and so crosses the same
    /// HS/LUT dispatch; parity must hold there too.
    #[test]
    fn harley_seal_matches_scalar_u32_rows() {
        #[cfg(target_arch = "x86_64")]
        {
            if !std::arch::is_x86_feature_detected!("avx2") {
                return;
            }
            let mut rng = Rng::new(215);
            for n in [128usize, 129, 130, 256, 301] {
                let a: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
                let b: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
                let want: u32 = a.iter().zip(&b).map(|(x, y)| (x ^ y).count_ones()).sum();
                force_level(2);
                let got = mismatches_u32(&a, &b);
                force_level(0);
                assert_eq!(want, got, "n={n}");
            }
        }
    }

    #[test]
    fn forced_scalar_path_works() {
        let mut rng = Rng::new(213);
        let a = rng.words(64);
        let b = rng.words(64);
        force_level(1);
        let scalar = mismatches_u64(&a, &b);
        force_level(0);
        let auto = mismatches_u64(&a, &b);
        assert_eq!(scalar, auto);
    }

    #[test]
    fn extremes() {
        let zeros = vec![0u64; 16];
        let ones = vec![!0u64; 16];
        assert_eq!(mismatches_u64(&zeros, &zeros), 0);
        assert_eq!(mismatches_u64(&zeros, &ones), 16 * 64);
    }

    const ALL_LEVELS: [u8; 4] = [LEVEL_SCALAR, LEVEL_AVX2, LEVEL_AVX512, LEVEL_NEON];

    /// Scalar parity of `mismatches_u64` at every dispatch level this
    /// build + CPU can run, across min-length boundaries, vector
    /// remainders, and accumulator-flush block sizes.
    #[test]
    fn every_level_matches_scalar_mismatches() {
        let mut rng = Rng::new(216);
        for l in ALL_LEVELS {
            if !level_available(l) {
                continue;
            }
            for n in [1usize, 2, 3, 7, 8, 9, 15, 16, 17, 63, 64, 65, 100, 257, 1024, 2050] {
                let a = rng.words(n);
                let b = rng.words(n);
                let want = mismatches_scalar(&a, &b);
                force_level(l);
                let got = mismatches_u64(&a, &b);
                force_level(0);
                assert_eq!(want, got, "level={} n={n}", level_name(l));
            }
        }
    }

    /// Same parity sweep for the 4-row register-blocked entry.
    #[test]
    fn every_level_matches_scalar_mismatches4() {
        let mut rng = Rng::new(217);
        for l in ALL_LEVELS {
            if !level_available(l) {
                continue;
            }
            for n in [1usize, 2, 4, 7, 8, 9, 12, 33, 64, 128, 257] {
                let a = rng.words(n);
                let b: Vec<Vec<u64>> = (0..4).map(|_| rng.words(n)).collect();
                let want = mismatches4_scalar(&a, &b[0], &b[1], &b[2], &b[3]);
                force_level(l);
                let got = mismatches4_u64(&a, &b[0], &b[1], &b[2], &b[3]);
                force_level(0);
                assert_eq!(want, got, "level={} n={n}", level_name(l));
            }
        }
    }

    /// The u32 entries reinterpret word pairs and delegate to the u64
    /// kernels; parity must hold at every level including odd tails.
    #[test]
    fn every_level_matches_scalar_u32_paths() {
        let mut rng = Rng::new(218);
        for l in ALL_LEVELS {
            if !level_available(l) {
                continue;
            }
            for n in [15usize, 16, 17, 31, 32, 33, 128, 129, 301] {
                let a: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
                let b: Vec<Vec<u32>> =
                    (0..4).map(|_| (0..n).map(|_| rng.next_u32()).collect()).collect();
                let want1: u32 =
                    a.iter().zip(&b[0]).map(|(x, y)| (x ^ y).count_ones()).sum();
                let want4 = {
                    let per = |bi: &[u32]| -> u32 {
                        a.iter().zip(bi).map(|(x, y)| (x ^ y).count_ones()).sum()
                    };
                    (per(&b[0]), per(&b[1]), per(&b[2]), per(&b[3]))
                };
                force_level(l);
                let got1 = mismatches_u32(&a, &b[0]);
                let got4 = mismatches4_u32(&a, &b[0], &b[1], &b[2], &b[3]);
                force_level(0);
                assert_eq!(want1, got1, "level={} n={n}", level_name(l));
                assert_eq!(want4, got4, "level={} n={n}", level_name(l));
            }
        }
    }
}
