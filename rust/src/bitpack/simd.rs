//! SIMD popcount paths for the packed kernels (perf-pass L3 iteration 2,
//! see EXPERIMENTS.md §Perf).
//!
//! The scalar kernel is POPCNT-port-limited (~1 word-pair/cycle); the
//! AVX2 path uses the classic PSHUFB nibble-LUT positional popcount +
//! SAD accumulation (Muła et al.), processing 4 packed u64 words per
//! vector op. Dispatch is runtime-detected once and cached; the scalar
//! path remains both the fallback and the reference in tests.

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;
use std::sync::atomic::{AtomicU8, Ordering};

/// Cached runtime CPU-feature dispatch (0 = unknown, 1 = scalar, 2 = avx2).
static LEVEL: AtomicU8 = AtomicU8::new(0);

#[inline]
fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 0 {
        return l;
    }
    // Default is the scalar formulation: built with `-C target-cpu=native`
    // LLVM auto-vectorizes it with the widest available ISA (measured
    // faster than the hand-written AVX2 LUT on AVX-512 hosts — see
    // EXPERIMENTS.md §Perf). `ESPRESSO_SIMD=avx2` opts into the manual
    // path for baseline-x86-64 builds where autovec cannot use popcount.
    let detected = match std::env::var("ESPRESSO_SIMD").as_deref() {
        #[cfg(target_arch = "x86_64")]
        Ok("avx2") if std::arch::is_x86_feature_detected!("avx2") => 2,
        _ => 1,
    };
    LEVEL.store(detected, Ordering::Relaxed);
    detected
}

/// Override dispatch (tests/benches): 1 = scalar, 2 = avx2.
pub fn force_level(l: u8) {
    LEVEL.store(l, Ordering::Relaxed);
}

/// Row length (in u64 words) from which the Harley–Seal carry-save
/// accumulator beats the plain LUT loop: the CSA tree retires 16 vectors
/// per PSHUFB-popcount, so its advantage needs long streams to amortize
/// (wide unrolled conv rows and MLP reductions qualify).
const HS_MIN_WORDS: usize = 64;

/// popcount(xor) over one pair of packed rows.
#[inline]
pub fn mismatches_u64(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if level() == 2 && a.len() >= 8 {
        // SAFETY: avx2 presence checked by `level`
        return unsafe { mismatches_dispatch_avx2(a, b) };
    }
    mismatches_scalar(a, b)
}

/// Length-based choice between the LUT loop and the Harley–Seal
/// accumulator (both AVX2; caller guarantees the feature).
#[cfg(target_arch = "x86_64")]
#[inline]
unsafe fn mismatches_dispatch_avx2(a: &[u64], b: &[u64]) -> u32 {
    if a.len() >= HS_MIN_WORDS {
        mismatches_hs_avx2(a, b)
    } else {
        mismatches_avx2(a, b)
    }
}

/// u32-word variant: same byte stream, reinterpreted. The AVX2 kernel is
/// width-agnostic (popcount over bytes); the scalar tail runs per word.
#[inline]
pub fn mismatches_u32(a: &[u32], b: &[u32]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if level() == 2 && a.len() >= 16 {
        let pairs = a.len() / 2;
        // SAFETY: u32 slices reinterpreted as u64 pairs (alignment of the
        // AVX2 loads is `loadu`, so only size matters); tail per-word.
        let head = unsafe {
            mismatches_dispatch_avx2(
                std::slice::from_raw_parts(a.as_ptr() as *const u64, pairs),
                std::slice::from_raw_parts(b.as_ptr() as *const u64, pairs),
            )
        };
        let mut total = head;
        for i in pairs * 2..a.len() {
            total += (a[i] ^ b[i]).count_ones();
        }
        return total;
    }
    let mut acc = 0u32;
    for i in 0..a.len() {
        acc += (a[i] ^ b[i]).count_ones();
    }
    acc
}

/// 4-row u32 variant (see `mismatches4_u64`).
#[inline]
pub fn mismatches4_u32(
    a: &[u32],
    b0: &[u32],
    b1: &[u32],
    b2: &[u32],
    b3: &[u32],
) -> (u32, u32, u32, u32) {
    #[cfg(target_arch = "x86_64")]
    if level() == 2 && a.len() >= 16 {
        let pairs = a.len() / 2;
        // SAFETY: as in `mismatches_u32`
        let (mut c0, mut c1, mut c2, mut c3) = unsafe {
            mismatches4_avx2(
                std::slice::from_raw_parts(a.as_ptr() as *const u64, pairs),
                std::slice::from_raw_parts(b0.as_ptr() as *const u64, pairs),
                std::slice::from_raw_parts(b1.as_ptr() as *const u64, pairs),
                std::slice::from_raw_parts(b2.as_ptr() as *const u64, pairs),
                std::slice::from_raw_parts(b3.as_ptr() as *const u64, pairs),
            )
        };
        for i in pairs * 2..a.len() {
            let av = a[i];
            c0 += (av ^ b0[i]).count_ones();
            c1 += (av ^ b1[i]).count_ones();
            c2 += (av ^ b2[i]).count_ones();
            c3 += (av ^ b3[i]).count_ones();
        }
        return (c0, c1, c2, c3);
    }
    let n = a.len();
    let (mut c0, mut c1, mut c2, mut c3) = (0u32, 0u32, 0u32, 0u32);
    for i in 0..n {
        let av = a[i];
        c0 += (av ^ b0[i]).count_ones();
        c1 += (av ^ b1[i]).count_ones();
        c2 += (av ^ b2[i]).count_ones();
        c3 += (av ^ b3[i]).count_ones();
    }
    (c0, c1, c2, c3)
}

/// popcount(xor) of one packed row against four rows simultaneously
/// (register-blocked micro-kernel: the `a` load is amortized 4×).
#[inline]
pub fn mismatches4_u64(
    a: &[u64],
    b0: &[u64],
    b1: &[u64],
    b2: &[u64],
    b3: &[u64],
) -> (u32, u32, u32, u32) {
    #[cfg(target_arch = "x86_64")]
    if level() == 2 && a.len() >= 8 {
        // SAFETY: avx2 presence checked by `level`
        return unsafe { mismatches4_avx2(a, b0, b1, b2, b3) };
    }
    mismatches4_scalar(a, b0, b1, b2, b3)
}

// ---------------------------------------------------------------------
// scalar reference paths
// ---------------------------------------------------------------------

#[inline]
pub fn mismatches_scalar(a: &[u64], b: &[u64]) -> u32 {
    let mut acc = 0u32;
    let mut acc2 = 0u32;
    let n = a.len();
    let mut i = 0;
    while i + 2 <= n {
        acc += (a[i] ^ b[i]).count_ones();
        acc2 += (a[i + 1] ^ b[i + 1]).count_ones();
        i += 2;
    }
    if i < n {
        acc += (a[i] ^ b[i]).count_ones();
    }
    acc + acc2
}

#[inline]
fn mismatches4_scalar(
    a: &[u64],
    b0: &[u64],
    b1: &[u64],
    b2: &[u64],
    b3: &[u64],
) -> (u32, u32, u32, u32) {
    let n = a.len();
    let (mut c0, mut c1, mut c2, mut c3) = (0u32, 0u32, 0u32, 0u32);
    for i in 0..n {
        let av = a[i];
        c0 += (av ^ b0[i]).count_ones();
        c1 += (av ^ b1[i]).count_ones();
        c2 += (av ^ b2[i]).count_ones();
        c3 += (av ^ b3[i]).count_ones();
    }
    (c0, c1, c2, c3)
}

// ---------------------------------------------------------------------
// AVX2: PSHUFB nibble-LUT popcount, SAD accumulation
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[inline]
unsafe fn popcount256(v: __m256i, lut: __m256i, mask: __m256i) -> __m256i {
    // byte-wise popcount of v, then horizontal SAD into 4 u64 lanes
    let lo = _mm256_and_si256(v, mask);
    let hi = _mm256_and_si256(_mm256_srli_epi32(v, 4), mask);
    let pc = _mm256_add_epi8(
        _mm256_shuffle_epi8(lut, lo),
        _mm256_shuffle_epi8(lut, hi),
    );
    _mm256_sad_epu8(pc, _mm256_setzero_si256())
}

#[cfg(target_arch = "x86_64")]
#[inline]
unsafe fn hsum256_epi64(v: __m256i) -> u64 {
    let lo = _mm256_castsi256_si128(v);
    let hi = _mm256_extracti128_si256(v, 1);
    let s = _mm_add_epi64(lo, hi);
    (_mm_extract_epi64(s, 0) + _mm_extract_epi64(s, 1)) as u64
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mismatches_avx2(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len();
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2,
        3, 3, 4,
    );
    let mask = _mm256_set1_epi8(0x0f);
    let mut acc = _mm256_setzero_si256();
    let chunks = n / 4;
    let ap = a.as_ptr() as *const __m256i;
    let bp = b.as_ptr() as *const __m256i;
    for i in 0..chunks {
        let x = _mm256_xor_si256(_mm256_loadu_si256(ap.add(i)), _mm256_loadu_si256(bp.add(i)));
        acc = _mm256_add_epi64(acc, popcount256(x, lut, mask));
    }
    let mut total = hsum256_epi64(acc) as u32;
    for i in chunks * 4..n {
        total += (a[i] ^ b[i]).count_ones();
    }
    total
}

/// Carry-save adder: `(higher, lower)` bit-planes of `a + b + c`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn csa(a: __m256i, b: __m256i, c: __m256i) -> (__m256i, __m256i) {
    let u = _mm256_xor_si256(a, b);
    (
        _mm256_or_si256(_mm256_and_si256(a, b), _mm256_and_si256(u, c)),
        _mm256_xor_si256(u, c),
    )
}

/// Load the `i`-th 256-bit lanes of both streams and xor them.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn xor_at(ap: *const __m256i, bp: *const __m256i, i: usize) -> __m256i {
    _mm256_xor_si256(_mm256_loadu_si256(ap.add(i)), _mm256_loadu_si256(bp.add(i)))
}

/// Harley–Seal popcount of `xor(a, b)` for long rows (Muła, Kurz,
/// Lemire): a CSA tree folds 16 xor vectors into ones/twos/fours/eights
/// counter planes and runs the PSHUFB popcount only on the "sixteens"
/// overflow — 1 byte-popcount per 16 vectors instead of 1 per vector, so
/// the popcount port stops being the bottleneck on kw ≥ [`HS_MIN_WORDS`]
/// rows. Remainder vectors take the LUT path, remainder words scalar.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mismatches_hs_avx2(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len();
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2,
        3, 3, 4,
    );
    let mask = _mm256_set1_epi8(0x0f);
    let ap = a.as_ptr() as *const __m256i;
    let bp = b.as_ptr() as *const __m256i;
    let vecs = n / 4;
    let blocks = vecs / 16;
    let mut total = _mm256_setzero_si256();
    let mut ones = _mm256_setzero_si256();
    let mut twos = _mm256_setzero_si256();
    let mut fours = _mm256_setzero_si256();
    let mut eights = _mm256_setzero_si256();
    for blk in 0..blocks {
        let i = blk * 16;
        let (twos_a, l) = csa(ones, xor_at(ap, bp, i), xor_at(ap, bp, i + 1));
        ones = l;
        let (twos_b, l) = csa(ones, xor_at(ap, bp, i + 2), xor_at(ap, bp, i + 3));
        ones = l;
        let (fours_a, l) = csa(twos, twos_a, twos_b);
        twos = l;
        let (twos_a, l) = csa(ones, xor_at(ap, bp, i + 4), xor_at(ap, bp, i + 5));
        ones = l;
        let (twos_b, l) = csa(ones, xor_at(ap, bp, i + 6), xor_at(ap, bp, i + 7));
        ones = l;
        let (fours_b, l) = csa(twos, twos_a, twos_b);
        twos = l;
        let (eights_a, l) = csa(fours, fours_a, fours_b);
        fours = l;
        let (twos_a, l) = csa(ones, xor_at(ap, bp, i + 8), xor_at(ap, bp, i + 9));
        ones = l;
        let (twos_b, l) = csa(ones, xor_at(ap, bp, i + 10), xor_at(ap, bp, i + 11));
        ones = l;
        let (fours_a, l) = csa(twos, twos_a, twos_b);
        twos = l;
        let (twos_a, l) = csa(ones, xor_at(ap, bp, i + 12), xor_at(ap, bp, i + 13));
        ones = l;
        let (twos_b, l) = csa(ones, xor_at(ap, bp, i + 14), xor_at(ap, bp, i + 15));
        ones = l;
        let (fours_b, l) = csa(twos, twos_a, twos_b);
        twos = l;
        let (eights_b, l) = csa(fours, fours_a, fours_b);
        fours = l;
        let (sixteens, l) = csa(eights, eights_a, eights_b);
        eights = l;
        total = _mm256_add_epi64(total, popcount256(sixteens, lut, mask));
    }
    // weight the residual counter planes: total·16 + eights·8 + fours·4
    // + twos·2 + ones
    total = _mm256_slli_epi64(total, 4);
    total = _mm256_add_epi64(
        total,
        _mm256_slli_epi64(popcount256(eights, lut, mask), 3),
    );
    total = _mm256_add_epi64(
        total,
        _mm256_slli_epi64(popcount256(fours, lut, mask), 2),
    );
    total = _mm256_add_epi64(total, _mm256_slli_epi64(popcount256(twos, lut, mask), 1));
    total = _mm256_add_epi64(total, popcount256(ones, lut, mask));
    for i in blocks * 16..vecs {
        total = _mm256_add_epi64(total, popcount256(xor_at(ap, bp, i), lut, mask));
    }
    let mut count = hsum256_epi64(total) as u32;
    for i in vecs * 4..n {
        count += (a[i] ^ b[i]).count_ones();
    }
    count
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mismatches4_avx2(
    a: &[u64],
    b0: &[u64],
    b1: &[u64],
    b2: &[u64],
    b3: &[u64],
) -> (u32, u32, u32, u32) {
    let n = a.len();
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2,
        3, 3, 4,
    );
    let mask = _mm256_set1_epi8(0x0f);
    let (mut s0, mut s1, mut s2, mut s3) = (
        _mm256_setzero_si256(),
        _mm256_setzero_si256(),
        _mm256_setzero_si256(),
        _mm256_setzero_si256(),
    );
    let chunks = n / 4;
    let ap = a.as_ptr() as *const __m256i;
    let p0 = b0.as_ptr() as *const __m256i;
    let p1 = b1.as_ptr() as *const __m256i;
    let p2 = b2.as_ptr() as *const __m256i;
    let p3 = b3.as_ptr() as *const __m256i;
    for i in 0..chunks {
        let av = _mm256_loadu_si256(ap.add(i));
        s0 = _mm256_add_epi64(
            s0,
            popcount256(_mm256_xor_si256(av, _mm256_loadu_si256(p0.add(i))), lut, mask),
        );
        s1 = _mm256_add_epi64(
            s1,
            popcount256(_mm256_xor_si256(av, _mm256_loadu_si256(p1.add(i))), lut, mask),
        );
        s2 = _mm256_add_epi64(
            s2,
            popcount256(_mm256_xor_si256(av, _mm256_loadu_si256(p2.add(i))), lut, mask),
        );
        s3 = _mm256_add_epi64(
            s3,
            popcount256(_mm256_xor_si256(av, _mm256_loadu_si256(p3.add(i))), lut, mask),
        );
    }
    let (mut c0, mut c1, mut c2, mut c3) = (
        hsum256_epi64(s0) as u32,
        hsum256_epi64(s1) as u32,
        hsum256_epi64(s2) as u32,
        hsum256_epi64(s3) as u32,
    );
    for i in chunks * 4..n {
        let av = a[i];
        c0 += (av ^ b0[i]).count_ones();
        c1 += (av ^ b1[i]).count_ones();
        c2 += (av ^ b2[i]).count_ones();
        c3 += (av ^ b3[i]).count_ones();
    }
    (c0, c1, c2, c3)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn avx2_matches_scalar_mismatches() {
        let mut rng = Rng::new(211);
        for n in [1usize, 3, 4, 7, 8, 9, 16, 31, 64, 100, 257] {
            let a = rng.words(n);
            let b = rng.words(n);
            let scalar = mismatches_scalar(&a, &b);
            force_level(0); // re-detect
            let auto = mismatches_u64(&a, &b);
            assert_eq!(scalar, auto, "n={n}");
        }
    }

    #[test]
    fn avx2_matches_scalar_mismatches4() {
        let mut rng = Rng::new(212);
        for n in [1usize, 4, 8, 12, 33, 128] {
            let a = rng.words(n);
            let b: Vec<Vec<u64>> = (0..4).map(|_| rng.words(n)).collect();
            let want = mismatches4_scalar(&a, &b[0], &b[1], &b[2], &b[3]);
            force_level(0);
            let got = mismatches4_u64(&a, &b[0], &b[1], &b[2], &b[3]);
            assert_eq!(want, got, "n={n}");
        }
    }

    /// Scalar parity of the Harley–Seal accumulator across the dispatch
    /// boundary and every remainder shape: block multiples (64, 128),
    /// vector remainders, word remainders, and lengths just under the
    /// HS cutoff (which exercise the LUT path through the same entry).
    #[test]
    fn harley_seal_matches_scalar_long_rows() {
        #[cfg(target_arch = "x86_64")]
        {
            if !std::arch::is_x86_feature_detected!("avx2") {
                return;
            }
            let mut rng = Rng::new(214);
            for n in [
                63usize, 64, 65, 67, 68, 96, 100, 127, 128, 129, 192, 257, 1000, 1024,
            ] {
                let a = rng.words(n);
                let b = rng.words(n);
                let want = mismatches_scalar(&a, &b);
                force_level(2);
                let got = mismatches_u64(&a, &b);
                force_level(0);
                assert_eq!(want, got, "n={n}");
            }
            // extremes survive the CSA weighting (every plane saturated)
            let zeros = vec![0u64; 200];
            let ones = vec![!0u64; 200];
            force_level(2);
            assert_eq!(mismatches_u64(&zeros, &ones), 200 * 64);
            assert_eq!(mismatches_u64(&ones, &ones), 0);
            force_level(0);
        }
    }

    /// The u32 entry reinterprets word pairs and so crosses the same
    /// HS/LUT dispatch; parity must hold there too.
    #[test]
    fn harley_seal_matches_scalar_u32_rows() {
        #[cfg(target_arch = "x86_64")]
        {
            if !std::arch::is_x86_feature_detected!("avx2") {
                return;
            }
            let mut rng = Rng::new(215);
            for n in [128usize, 129, 130, 256, 301] {
                let a: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
                let b: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
                let want: u32 = a.iter().zip(&b).map(|(x, y)| (x ^ y).count_ones()).sum();
                force_level(2);
                let got = mismatches_u32(&a, &b);
                force_level(0);
                assert_eq!(want, got, "n={n}");
            }
        }
    }

    #[test]
    fn forced_scalar_path_works() {
        let mut rng = Rng::new(213);
        let a = rng.words(64);
        let b = rng.words(64);
        force_level(1);
        let scalar = mismatches_u64(&a, &b);
        force_level(0);
        let auto = mismatches_u64(&a, &b);
        assert_eq!(scalar, auto);
    }

    #[test]
    fn extremes() {
        let zeros = vec![0u64; 16];
        let ones = vec![!0u64; 16];
        assert_eq!(mismatches_u64(&zeros, &zeros), 0);
        assert_eq!(mismatches_u64(&zeros, &ones), 16 * 64);
    }
}
