//! Packed binary dot products (paper §4.2, Eq. 2).
//!
//! With the {0 ⇔ -1, 1 ⇔ +1} encoding:
//!
//! ```text
//! dot(a, b) = matches - mismatches = n - 2 * popcount(a XOR b)
//! ```
//!
//! We use the XOR (mismatch-counting) form rather than the paper's XNOR
//! notation: zero tail-padding in both operands XORs to zero and
//! contributes nothing, so vectors whose length is not a multiple of the
//! word width need no masking. (The XNOR form would count the padding as
//! spurious matches.)

use super::word::Word;

/// Number of mismatching bit positions between two packed vectors.
/// Dispatches to the AVX2 PSHUFB-popcount path on capable hosts
/// (`bitpack::simd`); the scalar path remains the reference.
#[inline]
pub fn mismatches<W: Word>(a: &[W], b: &[W]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    W::mismatch_rows(a, b)
}

/// ±1 dot product of two packed vectors of logical length `n_bits`.
#[inline]
pub fn dot<W: Word>(a: &[W], b: &[W], n_bits: usize) -> i32 {
    n_bits as i32 - 2 * mismatches(a, b) as i32
}

/// Dot product between a {0,1} *bit-plane* `p` and a ±1 packed vector
/// `w`: contributes `+w_i` wherever `p_i = 1`, `0` elsewhere:
///
/// ```text
/// plane_dot(p, w) = popcount(p AND w) - popcount(p AND NOT w)
/// ```
///
/// Used by first-layer bit-plane decomposition (paper §4.3). Tail padding
/// of `p` is zero so `p AND NOT w` cannot pick up padding bits of `w`.
#[inline]
pub fn plane_dot<W: Word>(p: &[W], w: &[W]) -> i32 {
    debug_assert_eq!(p.len(), w.len());
    let mut pos = 0u32;
    let mut neg = 0u32;
    for i in 0..p.len() {
        pos += (p[i] & w[i]).popcount();
        neg += (p[i] & !w[i]).popcount();
    }
    pos as i32 - neg as i32
}

/// Bitwise OR reduction over packed rows — max-pool over {-1,+1} bits
/// (max(±1 set) = +1 iff any bit set).
#[inline]
pub fn or_rows<W: Word>(rows: &[&[W]], out: &mut [W]) {
    for w in out.iter_mut() {
        *w = W::ZERO;
    }
    for row in rows {
        debug_assert_eq!(row.len(), out.len());
        for (o, &r) in out.iter_mut().zip(row.iter()) {
            *o = *o | r;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitpack::pack::pack_signs;
    use crate::util::rng::Rng;

    fn naive_dot(a: &[f32], b: &[f32]) -> i32 {
        a.iter().zip(b).map(|(x, y)| (x * y) as i32).sum()
    }

    #[test]
    fn dot_matches_naive_u64() {
        let mut rng = Rng::new(11);
        for n in [1, 5, 64, 65, 100, 192, 1000] {
            let a = rng.signs(n);
            let b = rng.signs(n);
            let pa = pack_signs::<u64>(&a);
            let pb = pack_signs::<u64>(&b);
            assert_eq!(dot(&pa, &pb, n), naive_dot(&a, &b), "n={n}");
        }
    }

    #[test]
    fn dot_matches_naive_u32() {
        let mut rng = Rng::new(12);
        for n in [1, 31, 32, 33, 100, 257] {
            let a = rng.signs(n);
            let b = rng.signs(n);
            let pa = pack_signs::<u32>(&a);
            let pb = pack_signs::<u32>(&b);
            assert_eq!(dot(&pa, &pb, n), naive_dot(&a, &b), "n={n}");
        }
    }

    #[test]
    fn dot_extremes() {
        let n = 130;
        let ones = vec![1.0f32; n];
        let negs = vec![-1.0f32; n];
        let p1 = pack_signs::<u64>(&ones);
        let pn = pack_signs::<u64>(&negs);
        assert_eq!(dot(&p1, &p1, n), n as i32);
        assert_eq!(dot(&p1, &pn, n), -(n as i32));
        assert_eq!(dot(&pn, &pn, n), n as i32);
    }

    #[test]
    fn plane_dot_matches_naive() {
        let mut rng = Rng::new(13);
        for n in [1, 64, 100, 300] {
            // plane: random {0,1}; weights: random ±1
            let plane: Vec<f32> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
            let w = rng.signs(n);
            // pack plane as bits: 1.0 -> 1, -1.0 -> 0 (pack_signs works)
            let pp = pack_signs::<u64>(&plane);
            let pw = pack_signs::<u64>(&w);
            let expect: i32 = plane
                .iter()
                .zip(&w)
                .map(|(&p, &wv)| if p > 0.0 { wv as i32 } else { 0 })
                .sum();
            assert_eq!(plane_dot(&pp, &pw), expect, "n={n}");
        }
    }

    #[test]
    fn or_rows_is_bit_max() {
        let a = [0b0011u64];
        let b = [0b0101u64];
        let mut out = [0u64];
        or_rows(&[&a, &b], &mut out);
        assert_eq!(out[0], 0b0111);
    }
}
