//! First-layer input binarization via bit-plane decomposition
//! (paper §4.3 and §6.2 "First-layer binary optimization").
//!
//! Fixed-precision inputs (8-bit pixels) are split into 8 {0,1}
//! bit-planes; each plane takes a binary-optimized dot against the ±1
//! weights, and the results recombine as `Σᵢ 2ⁱ · plane_dotᵢ`. The paper
//! reports ≈3× whole-network speedup from binarizing the first layer this
//! way (experiment **A1**).
//!
//! Kernel notes (perf pass, EXPERIMENTS.md §Perf): planes are stored
//! *interleaved* (`data[word·8 + plane]`) so one sweep touches all eight
//! planes of a word consecutively, and the {0,1}×{±1} dot uses
//! `plane_dot = 2·popcount(p AND w) − popcount(p)` with the per-plane
//! popcounts precomputed at decompose time — half the popcount work of
//! the naive `pos − neg` formulation and no `NOT w` stream.

use super::word::{words_for, Word};
use crate::alloc::BufferPool;
use crate::util::parallel::{current_slot, max_workers_for, parallel_for_mut_chunks};
use crate::util::tune::{self, Family, KernelChoice, MicroKernel};

/// Bit-planes of a `u8` vector, plane-interleaved per word:
/// `data[w*8 + p]` holds bits `w*BITS..` of plane `p`. Tail bits zero.
#[derive(Clone, Debug)]
pub struct BitPlanes<W: Word> {
    pub data: Vec<W>,
    /// Total set bits per plane (for the `2·pc − pop` recombination).
    pub plane_pop: [u32; 8],
    /// Logical element count.
    pub n: usize,
}

impl<W: Word> BitPlanes<W> {
    /// Decompose `src` into 8 packed, interleaved bit-planes.
    pub fn decompose(src: &[u8]) -> Self {
        let n = src.len();
        let nw = words_for::<W>(n);
        let mut data = vec![W::ZERO; nw * 8];
        for (i, &v) in src.iter().enumerate() {
            let wi = i / W::BITS;
            let bi = i % W::BITS;
            let base = wi * 8;
            for p in 0..8 {
                if (v >> p) & 1 == 1 {
                    data[base + p] = data[base + p] | W::bit(bi);
                }
            }
        }
        let mut plane_pop = [0u32; 8];
        for wi in 0..nw {
            for p in 0..8 {
                plane_pop[p] += data[wi * 8 + p].popcount();
            }
        }
        Self { data, plane_pop, n }
    }

    /// Words per plane.
    pub fn words(&self) -> usize {
        words_for::<W>(self.n)
    }

    /// Packed words of plane `p` (testing/debug accessor).
    pub fn plane(&self, p: usize) -> Vec<W> {
        (0..self.words()).map(|wi| self.data[wi * 8 + p]).collect()
    }
}

/// Dot product of a u8 input vector (as bit-planes) against one packed
/// ±1 weight row: exactly `Σ_j x_j · w_j` over the integer pixel values.
pub fn bitplane_dot<W: Word>(x: &BitPlanes<W>, wrow: &[W]) -> i32 {
    debug_assert_eq!(wrow.len(), x.words());
    let mut pc = [0u32; 8];
    for (wi, &wv) in wrow.iter().enumerate() {
        let base = wi * 8;
        // all 8 planes of this word are adjacent: one w load, 8 AND+popcnt
        pc[0] += (x.data[base] & wv).popcount();
        pc[1] += (x.data[base + 1] & wv).popcount();
        pc[2] += (x.data[base + 2] & wv).popcount();
        pc[3] += (x.data[base + 3] & wv).popcount();
        pc[4] += (x.data[base + 4] & wv).popcount();
        pc[5] += (x.data[base + 5] & wv).popcount();
        pc[6] += (x.data[base + 6] & wv).popcount();
        pc[7] += (x.data[base + 7] & wv).popcount();
    }
    let mut acc = 0i32;
    for p in 0..8 {
        // plane_dot = pos − neg = 2·popcount(p AND w) − popcount(p)
        acc += ((2 * pc[p] as i32) - x.plane_pop[p] as i32) << p;
    }
    acc
}

/// `NR` weight rows against all 8 planes of one input, sharing every
/// plane load across the rows (register-blocked widening of
/// [`bitplane_dot`]; integer accumulation, so results are identical to
/// `NR` independent dots).
#[inline(always)]
fn bitplane_dotn<W: Word, const NR: usize>(x: &BitPlanes<W>, ws: [&[W]; NR]) -> [i32; NR] {
    let kw = x.words();
    let mut pc = [[0u32; 8]; NR];
    for wi in 0..kw {
        let base = wi * 8;
        let planes: [W; 8] = std::array::from_fn(|p| x.data[base + p]);
        for (r, pcr) in pc.iter_mut().enumerate() {
            let wv = ws[r][wi];
            for p in 0..8 {
                pcr[p] += (planes[p] & wv).popcount();
            }
        }
    }
    let mut out = [0i32; NR];
    for (r, pcr) in pc.iter().enumerate() {
        let mut acc = 0i32;
        for p in 0..8 {
            acc += ((2 * pcr[p] as i32) - x.plane_pop[p] as i32) << p;
        }
        out[r] = acc;
    }
    out
}

/// One input against weight rows `[j0, j0 + orow.len())`, register-
/// blocked by the chosen micro shape (2×4 degrades to 1×4 — there is a
/// single input), with a 1-row tail.
#[inline]
fn bitplane_row_sweep<W: Word>(
    x: &BitPlanes<W>,
    w: &[W],
    kw: usize,
    orow: &mut [i32],
    j0: usize,
    micro: MicroKernel,
) {
    match micro {
        MicroKernel::Mk1x8 => bitplane_row_sweep_n::<W, 8>(x, w, kw, orow, j0),
        _ => bitplane_row_sweep_n::<W, 4>(x, w, kw, orow, j0),
    }
}

#[inline]
fn bitplane_row_sweep_n<W: Word, const NR: usize>(
    x: &BitPlanes<W>,
    w: &[W],
    kw: usize,
    orow: &mut [i32],
    j0: usize,
) {
    let count = orow.len();
    let mut j = 0;
    while j + NR <= count {
        let base = (j0 + j) * kw;
        let ws: [&[W]; NR] = std::array::from_fn(|t| &w[base + t * kw..base + (t + 1) * kw]);
        let vals = bitplane_dotn::<W, NR>(x, ws);
        orow[j..j + NR].copy_from_slice(&vals);
        j += NR;
    }
    while j < count {
        let jj = j0 + j;
        orow[j] = bitplane_dot(x, &w[jj * kw..(jj + 1) * kw]);
        j += 1;
    }
}

/// First-layer GEMV: u8 input against `n` packed weight rows of logical
/// width `k = x.n`. `out[j] = Σ_t x_t · w_{j,t}` (integer exact).
pub fn bitplane_gemv_into<W: Word>(x: &BitPlanes<W>, w: &[W], out: &mut [i32], n: usize) {
    let choice = tune::lookup(Family::Bitplane, W::BITS as u32, n, x.n);
    bitplane_gemv_with_choice(x, w, out, n, choice)
}

/// [`bitplane_gemv_into`] with an explicit kernel configuration (micro
/// shape only; the grain stays on the GEMV-specific formula).
pub fn bitplane_gemv_with_choice<W: Word>(
    x: &BitPlanes<W>,
    w: &[W],
    out: &mut [i32],
    n: usize,
    choice: KernelChoice,
) {
    let kw = x.words();
    assert_eq!(w.len(), n * kw, "W words");
    assert_eq!(out.len(), n);
    let grain = ((1 << 16) / kw.max(1)).max(8);
    parallel_for_mut_chunks(out, 1, grain, |j0, yc| {
        bitplane_row_sweep(x, w, kw, yc, j0, choice.micro);
    });
}

/// Batched first layer: `m` u8 input rows (each of length `k`) against
/// `n` packed weight rows; `out` is `m×n`.
pub fn bitplane_gemm_into<W: Word>(
    xs: &[u8],
    w: &[W],
    out: &mut [i32],
    m: usize,
    n: usize,
    k: usize,
) {
    assert_eq!(xs.len(), m * k);
    assert_eq!(out.len(), m * n);
    let kw = words_for::<W>(k);
    assert_eq!(w.len(), n * kw);
    let choice = tune::lookup(Family::Bitplane, W::BITS as u32, n, k);
    parallel_for_mut_chunks(out, n, 1, |row0, chunk| {
        for (r, orow) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + r;
            let planes = BitPlanes::<W>::decompose(&xs[i * k..(i + 1) * k]);
            bitplane_row_sweep(&planes, w, kw, orow, 0, choice.micro);
        }
    });
}

/// Tile-streaming first-layer GEMM: the `m × k` u8 patch matrix is
/// virtual — `fill(row0, row1, panel)` produces rows `[row0, row1)` on
/// demand into a reused per-worker panel (from `panels`), each row is
/// bit-plane-decomposed and dotted against all `n` packed weight rows.
/// Bit-identical to materializing the patches and calling
/// [`bitplane_gemm_into`].
#[allow(clippy::too_many_arguments)]
pub fn bitplane_gemm_tiles_into<W: Word>(
    w: &[W],
    out: &mut [i32],
    m: usize,
    n: usize,
    k: usize,
    tile_rows: usize,
    panels: &BufferPool<u8>,
    fill: &(dyn Fn(usize, usize, &mut [u8]) + Sync),
) {
    let lc = tune::lookup(Family::Bitplane, W::BITS as u32, n, k);
    let choice = KernelChoice { tile_rows: tile_rows.max(1), ..lc };
    bitplane_gemm_tiles_with_choice::<W>(w, out, m, n, k, choice, panels, fill)
}

/// [`bitplane_gemm_tiles_into`] with an explicit kernel configuration.
/// The grain is work-priced (not one C row): a chunk carries enough
/// plane dots to amortize its panel acquire and producer calls — the
/// default formula targets ~(1<<19) word-ops per spawn-priced chunk,
/// which the pool scheduler splits 16× finer (`util::parallel`).
#[allow(clippy::too_many_arguments)]
pub fn bitplane_gemm_tiles_with_choice<W: Word>(
    w: &[W],
    out: &mut [i32],
    m: usize,
    n: usize,
    k: usize,
    choice: KernelChoice,
    panels: &BufferPool<u8>,
    fill: &(dyn Fn(usize, usize, &mut [u8]) + Sync),
) {
    assert_eq!(out.len(), m * n);
    let kw = words_for::<W>(k);
    assert_eq!(w.len(), n * kw);
    if m == 0 || n == 0 {
        return;
    }
    let tile = choice.tile_rows.max(1);
    let grain = choice.grain.max(1);
    parallel_for_mut_chunks(out, n, grain, |row0, chunk| {
        let rows = chunk.len() / n;
        // worker-affine: same warm u8 patch panel per scheduler slot
        let mut panel = panels.acquire_affine(current_slot(), tile * k);
        for t0 in (0..rows).step_by(tile) {
            let t1 = (t0 + tile).min(rows);
            fill(row0 + t0, row0 + t1, &mut panel[..(t1 - t0) * k]);
            for r in t0..t1 {
                let planes = BitPlanes::<W>::decompose(&panel[(r - t0) * k..(r - t0 + 1) * k]);
                bitplane_row_sweep(&planes, w, kw, &mut chunk[r * n..(r + 1) * n], 0, choice.micro);
            }
        }
    });
}

/// Upper bound on simultaneously live u8 panels a
/// [`bitplane_gemm_tiles_into`] call with these dimensions will draw
/// from its pool — what `Layer::scratch` reserves. Shares the registry
/// lookup with the forward path so reservation and execution agree.
pub fn bitplane_tiles_workers<W: Word>(m: usize, n: usize, k: usize) -> usize {
    let lc = tune::lookup(Family::Bitplane, W::BITS as u32, n, k);
    max_workers_for(m, lc.grain.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitpack::pack::pack_matrix_rows;
    use crate::util::rng::Rng;

    #[test]
    fn decompose_reconstructs_values() {
        let mut rng = Rng::new(31);
        let src: Vec<u8> = (0..300).map(|_| rng.next_u32() as u8).collect();
        let bp = BitPlanes::<u64>::decompose(&src);
        for (i, &v) in src.iter().enumerate() {
            let mut rec = 0u8;
            for p in 0..8 {
                if bp.plane(p)[i / 64].get_bit(i % 64) {
                    rec |= 1 << p;
                }
            }
            assert_eq!(rec, v, "i={i}");
        }
    }

    #[test]
    fn plane_pop_counts_set_bits() {
        let src = vec![0xFFu8; 70];
        let bp = BitPlanes::<u64>::decompose(&src);
        for p in 0..8 {
            assert_eq!(bp.plane_pop[p], 70);
        }
    }

    #[test]
    fn bitplane_dot_matches_integer_dot() {
        let mut rng = Rng::new(32);
        for k in [1usize, 17, 64, 100, 784] {
            let x: Vec<u8> = (0..k).map(|_| rng.next_u32() as u8).collect();
            let w = rng.signs(k);
            let pw = pack_matrix_rows::<u64>(&w, 1, k);
            let bp = BitPlanes::<u64>::decompose(&x);
            let expect: i32 = x
                .iter()
                .zip(&w)
                .map(|(&xv, &wv)| xv as i32 * wv as i32)
                .sum();
            assert_eq!(bitplane_dot(&bp, &pw), expect, "k={k}");
        }
    }

    #[test]
    fn bitplane_gemv_matches_naive() {
        let mut rng = Rng::new(33);
        let (n, k) = (50, 784);
        let x: Vec<u8> = (0..k).map(|_| rng.next_u32() as u8).collect();
        let w = rng.signs(n * k);
        let pw = pack_matrix_rows::<u64>(&w, n, k);
        let bp = BitPlanes::<u64>::decompose(&x);
        let mut out = vec![0i32; n];
        bitplane_gemv_into(&bp, &pw, &mut out, n);
        for j in 0..n {
            let expect: i32 = (0..k)
                .map(|t| x[t] as i32 * w[j * k + t] as i32)
                .sum();
            assert_eq!(out[j], expect, "j={j}");
        }
    }

    #[test]
    fn bitplane_gemm_matches_gemv_rows() {
        let mut rng = Rng::new(34);
        let (m, n, k) = (5, 20, 100);
        let xs: Vec<u8> = (0..m * k).map(|_| rng.next_u32() as u8).collect();
        let w = rng.signs(n * k);
        let pw = pack_matrix_rows::<u64>(&w, n, k);
        let mut out = vec![0i32; m * n];
        bitplane_gemm_into(&xs, &pw, &mut out, m, n, k);
        for i in 0..m {
            let bp = BitPlanes::<u64>::decompose(&xs[i * k..(i + 1) * k]);
            let mut row = vec![0i32; n];
            bitplane_gemv_into(&bp, &pw, &mut row, n);
            assert_eq!(&out[i * n..(i + 1) * n], &row[..], "row {i}");
        }
    }

    /// The batched first-layer path against a from-scratch integer
    /// reference (not via gemv): every row of the batched GEMM must equal
    /// the plain `Σ_t x_t · w_t` over the u8 pixel values, including
    /// ragged widths (k not a multiple of the word width) and m > 1.
    #[test]
    fn batched_rows_match_naive_integer_reference() {
        let mut rng = Rng::new(36);
        for &(m, n, k) in &[(2usize, 7usize, 50usize), (6, 11, 129), (4, 3, 784)] {
            let xs: Vec<u8> = (0..m * k).map(|_| rng.next_u32() as u8).collect();
            let w = rng.signs(n * k);
            let pw = pack_matrix_rows::<u64>(&w, n, k);
            let mut out = vec![0i32; m * n];
            bitplane_gemm_into(&xs, &pw, &mut out, m, n, k);
            for i in 0..m {
                for j in 0..n {
                    let want: i32 = (0..k)
                        .map(|t| xs[i * k + t] as i32 * w[j * k + t] as i32)
                        .sum();
                    assert_eq!(out[i * n + j], want, "({m},{n},{k}) row {i} col {j}");
                }
            }
        }
    }

    /// The tile-streaming first-layer GEMM must match the materializing
    /// one for tile sizes that do and do not divide the row count.
    #[test]
    fn bitplane_gemm_tiles_matches_materialized() {
        let mut rng = Rng::new(37);
        let pool = crate::alloc::BufferPool::<u8>::new();
        for &(m, n, k, tile) in &[
            (6usize, 11usize, 129usize, 4usize),
            (5, 20, 100, 2),
            (3, 7, 50, 16),
        ] {
            let xs: Vec<u8> = (0..m * k).map(|_| rng.next_u32() as u8).collect();
            let w = rng.signs(n * k);
            let pw = pack_matrix_rows::<u64>(&w, n, k);
            let mut want = vec![0i32; m * n];
            bitplane_gemm_into(&xs, &pw, &mut want, m, n, k);
            let mut got = vec![0i32; m * n];
            bitplane_gemm_tiles_into::<u64>(&pw, &mut got, m, n, k, tile, &pool, &|r0, r1, panel| {
                panel.copy_from_slice(&xs[r0 * k..r1 * k])
            });
            assert_eq!(got, want, "({m},{n},{k},{tile})");
        }
    }

    /// The 4- and 8-wide register-blocked sweeps must be value-identical
    /// to row-by-row [`bitplane_dot`] (integer accumulation, any order).
    #[test]
    fn micro_kernel_widths_agree() {
        use crate::util::tune::{KernelChoice, MicroKernel};
        let mut rng = Rng::new(38);
        for &(n, k) in &[(3usize, 50usize), (9, 129), (20, 100), (7, 784)] {
            let x: Vec<u8> = (0..k).map(|_| rng.next_u32() as u8).collect();
            let w = rng.signs(n * k);
            let pw = pack_matrix_rows::<u64>(&w, n, k);
            let bp = BitPlanes::<u64>::decompose(&x);
            let want: Vec<i32> = (0..n)
                .map(|j| bitplane_dot(&bp, &pw[j * bp.words()..(j + 1) * bp.words()]))
                .collect();
            for micro in [MicroKernel::Mk1x4, MicroKernel::Mk1x8, MicroKernel::Mk2x4] {
                let choice = KernelChoice { micro, tile_rows: 16, grain: 4 };
                let mut out = vec![0i32; n];
                bitplane_gemv_with_choice(&bp, &pw, &mut out, n, choice);
                assert_eq!(out, want, "micro {micro} ({n},{k})");
            }
        }
    }

    #[test]
    fn u32_words_agree_with_u64() {
        let mut rng = Rng::new(35);
        let k = 129;
        let x: Vec<u8> = (0..k).map(|_| rng.next_u32() as u8).collect();
        let w = rng.signs(k);
        let d64 = bitplane_dot(
            &BitPlanes::<u64>::decompose(&x),
            &pack_matrix_rows::<u64>(&w, 1, k),
        );
        let d32 = bitplane_dot(
            &BitPlanes::<u32>::decompose(&x),
            &pack_matrix_rows::<u32>(&w, 1, k),
        );
        assert_eq!(d64, d32);
    }

    #[test]
    fn extreme_pixel_values() {
        let x = vec![255u8; 64];
        let w = vec![1.0f32; 64];
        let bp = BitPlanes::<u64>::decompose(&x);
        let pw = pack_matrix_rows::<u64>(&w, 1, 64);
        assert_eq!(bitplane_dot(&bp, &pw), 255 * 64);
        let wneg = vec![-1.0f32; 64];
        let pwn = pack_matrix_rows::<u64>(&wneg, 1, 64);
        assert_eq!(bitplane_dot(&bp, &pwn), -255 * 64);
    }
}
