//! Machine-word abstraction for bit-packed binary values.
//!
//! The paper evaluates both 32-bit (BinaryNet-style) and 64-bit packing
//! (Espresso `GPU^opt` vs `GPU^opt 32`, Table 1). All packed kernels in
//! this crate are generic over [`Word`] so the same code paths are
//! measured for both widths (experiment **A4**).
//!
//! Encoding convention (paper §4.1): bit `1` ⇔ value `+1`, bit `0` ⇔
//! value `-1`. With the XOR form of the dot product, zero tail-padding in
//! *both* operands contributes exactly zero, so no masking is needed on
//! the hot path.

/// A fixed-width unsigned machine word usable for bit-packing.
pub trait Word:
    Copy
    + Clone
    + Send
    + Sync
    + Eq
    + Default
    + std::fmt::Debug
    + std::ops::BitXor<Output = Self>
    + std::ops::BitAnd<Output = Self>
    + std::ops::BitOr<Output = Self>
    + std::ops::Not<Output = Self>
    + crate::alloc::WordPool
    + 'static
{
    /// Number of bits per word (64 or 32).
    const BITS: usize;
    /// All-zero word (encodes a run of -1s; also the tail padding value).
    const ZERO: Self;
    /// All-one word.
    const ONES: Self;

    /// Population count.
    fn popcount(self) -> u32;
    /// Word with only bit `i` set (`i < BITS`).
    fn bit(i: usize) -> Self;
    /// Test bit `i`.
    fn get_bit(self, i: usize) -> bool;
    /// Lossy conversion from u64 (truncates high bits for u32).
    fn from_u64(x: u64) -> Self;
    /// Widening conversion to u64.
    fn to_u64(self) -> u64;

    /// popcount(xor) over packed rows — width-specific SIMD dispatch.
    fn mismatch_rows(a: &[Self], b: &[Self]) -> u32;
    /// One row against four (register-blocked micro-kernel).
    fn mismatch_rows4(
        a: &[Self],
        b0: &[Self],
        b1: &[Self],
        b2: &[Self],
        b3: &[Self],
    ) -> (u32, u32, u32, u32);

    /// One row against eight — the widest micro-kernel (perf-pass L3
    /// iteration 3: amortizes each `a` load over 8 B streams; the plain
    /// loop body lets LLVM auto-vectorize with the widest available ISA,
    /// which beats hand-written AVX2 on AVX-512 hosts — see
    /// EXPERIMENTS.md §Perf).
    #[inline(always)]
    fn mismatch_rows8(a: &[Self], bs: [&[Self]; 8]) -> [u32; 8] {
        let n = a.len();
        let mut c = [0u32; 8];
        for i in 0..n {
            let av = a[i];
            c[0] += (av ^ bs[0][i]).popcount();
            c[1] += (av ^ bs[1][i]).popcount();
            c[2] += (av ^ bs[2][i]).popcount();
            c[3] += (av ^ bs[3][i]).popcount();
            c[4] += (av ^ bs[4][i]).popcount();
            c[5] += (av ^ bs[5][i]).popcount();
            c[6] += (av ^ bs[6][i]).popcount();
            c[7] += (av ^ bs[7][i]).popcount();
        }
        c
    }

    /// Two rows against four — the 2×4 register block the autotuner can
    /// pick (PR 7): both `a` loads and all four `b` loads are amortized
    /// across 8 accumulators, halving B-panel traffic vs two 1×4 calls.
    /// Same plain auto-vectorizable shape as [`Word::mismatch_rows8`].
    /// Returns `[c(a0,b0..b3), c(a1,b0..b3)]` flattened row-major.
    #[inline(always)]
    fn mismatch_rows2x4(a0: &[Self], a1: &[Self], bs: [&[Self]; 4]) -> [u32; 8] {
        let n = a0.len();
        let mut c = [0u32; 8];
        for i in 0..n {
            let av0 = a0[i];
            let av1 = a1[i];
            let b0 = bs[0][i];
            let b1 = bs[1][i];
            let b2 = bs[2][i];
            let b3 = bs[3][i];
            c[0] += (av0 ^ b0).popcount();
            c[1] += (av0 ^ b1).popcount();
            c[2] += (av0 ^ b2).popcount();
            c[3] += (av0 ^ b3).popcount();
            c[4] += (av1 ^ b0).popcount();
            c[5] += (av1 ^ b1).popcount();
            c[6] += (av1 ^ b2).popcount();
            c[7] += (av1 ^ b3).popcount();
        }
        c
    }
}

impl Word for u64 {
    const BITS: usize = 64;
    const ZERO: Self = 0;
    const ONES: Self = !0;

    #[inline(always)]
    fn popcount(self) -> u32 {
        self.count_ones()
    }

    #[inline(always)]
    fn bit(i: usize) -> Self {
        1u64 << i
    }

    #[inline(always)]
    fn get_bit(self, i: usize) -> bool {
        (self >> i) & 1 == 1
    }

    #[inline(always)]
    fn from_u64(x: u64) -> Self {
        x
    }

    #[inline(always)]
    fn to_u64(self) -> u64 {
        self
    }

    #[inline(always)]
    fn mismatch_rows(a: &[Self], b: &[Self]) -> u32 {
        super::simd::mismatches_u64(a, b)
    }

    #[inline(always)]
    fn mismatch_rows4(
        a: &[Self],
        b0: &[Self],
        b1: &[Self],
        b2: &[Self],
        b3: &[Self],
    ) -> (u32, u32, u32, u32) {
        super::simd::mismatches4_u64(a, b0, b1, b2, b3)
    }
}

impl Word for u32 {
    const BITS: usize = 32;
    const ZERO: Self = 0;
    const ONES: Self = !0;

    #[inline(always)]
    fn popcount(self) -> u32 {
        self.count_ones()
    }

    #[inline(always)]
    fn bit(i: usize) -> Self {
        1u32 << i
    }

    #[inline(always)]
    fn get_bit(self, i: usize) -> bool {
        (self >> i) & 1 == 1
    }

    #[inline(always)]
    fn from_u64(x: u64) -> Self {
        x as u32
    }

    #[inline(always)]
    fn to_u64(self) -> u64 {
        self as u64
    }

    #[inline(always)]
    fn mismatch_rows(a: &[Self], b: &[Self]) -> u32 {
        super::simd::mismatches_u32(a, b)
    }

    #[inline(always)]
    fn mismatch_rows4(
        a: &[Self],
        b0: &[Self],
        b1: &[Self],
        b2: &[Self],
        b3: &[Self],
    ) -> (u32, u32, u32, u32) {
        super::simd::mismatches4_u32(a, b0, b1, b2, b3)
    }
}

/// Number of words needed to hold `bits` bits.
#[inline(always)]
pub fn words_for<W: Word>(bits: usize) -> usize {
    bits.div_ceil(W::BITS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_constants() {
        assert_eq!(<u64 as Word>::BITS, 64);
        assert_eq!(<u32 as Word>::BITS, 32);
        assert_eq!(<u64 as Word>::ONES.popcount(), 64);
        assert_eq!(<u32 as Word>::ONES.popcount(), 32);
        assert_eq!(<u64 as Word>::ZERO.popcount(), 0);
    }

    #[test]
    fn bit_roundtrip() {
        for i in 0..64 {
            let w = <u64 as Word>::bit(i);
            assert!(w.get_bit(i));
            assert_eq!(w.popcount(), 1);
        }
        for i in 0..32 {
            let w = <u32 as Word>::bit(i);
            assert!(w.get_bit(i));
            assert_eq!(w.popcount(), 1);
        }
    }

    #[test]
    fn words_for_rounding() {
        assert_eq!(words_for::<u64>(0), 0);
        assert_eq!(words_for::<u64>(1), 1);
        assert_eq!(words_for::<u64>(64), 1);
        assert_eq!(words_for::<u64>(65), 2);
        assert_eq!(words_for::<u32>(64), 2);
        assert_eq!(words_for::<u32>(33), 2);
    }
}
