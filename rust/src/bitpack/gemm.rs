//! Blocked, multithreaded binary GEMM / GEMV on packed words.
//!
//! This is the XNOR-popcount matrix multiply at the heart of the paper
//! (§5.2 "Efficient Matrix multiplication"): `C[m][n] = dot(A_m, B_n)`
//! where both operands are bit-packed rows. `B` is stored row-per-output
//! (i.e. already transposed), matching the weight layout of dense and
//! unrolled convolutional layers.
//!
//! Structure mirrors the paper's CUDA kernel, translated to CPU caches
//! (§Hardware-Adaptation in DESIGN.md): the paper tiles into
//! shared-memory then register-blocks sub-tiles; here we tile B into
//! L1-sized panels and register-block a 1×4 micro-kernel (one A row
//! against four B rows) so each loaded A word is reused four times from
//! registers, with two-way unrolling over K to keep both popcount ports
//! busy.

use super::word::{words_for, Word};
use crate::alloc::BufferPool;
use crate::util::parallel::{current_slot, max_workers_for, parallel_for_mut_chunks};
use crate::util::tune::{self, Family, KernelChoice, MicroKernel};

/// Number of B rows processed per micro-kernel invocation.
const NR: usize = 4;
/// B-panel rows per cache block (perf-tuned: 1024 rows keeps the panel
/// in L2 on this host; 64 was 16% slower — EXPERIMENTS.md §Perf).
const NB: usize = 1024;

/// `C = A ⊛ B^T` over packed operands.
///
/// * `a`: `m` rows × `kw` words (pack of an `m×k` ±1 matrix by rows)
/// * `b`: `n` rows × `kw` words (pack of an `n×k` ±1 matrix by rows)
/// * `out`: `m×n` i32, `out[i*n + j] = k - 2·mismatch(a_i, b_j)`
pub fn gemm_into<W: Word>(a: &[W], b: &[W], out: &mut [i32], m: usize, n: usize, k: usize) {
    gemm_words_into::<W>(a, b, out, m, n, words_for::<W>(k), k)
}

/// [`gemm_into`] with an explicit per-row word count.
///
/// Unrolled convolution rows are `kh·kw` word-*groups* (each tap's
/// channels padded to a word boundary), so `row_words` can exceed
/// `words_for(k)`; padding bits are zero in both operands and contribute
/// no mismatches, while the `k − 2·mis` affine uses the *logical* k.
pub fn gemm_words_into<W: Word>(
    a: &[W],
    b: &[W],
    out: &mut [i32],
    m: usize,
    n: usize,
    kw: usize,
    k: usize,
) {
    let choice = tune::lookup(Family::Binary, W::BITS as u32, n, kw);
    gemm_words_with_choice::<W>(a, b, out, m, n, kw, k, choice)
}

/// [`gemm_words_into`] with an explicit kernel configuration (the
/// autotuner's timing harness drives this directly; everything else goes
/// through the registry lookup in the plain entry points).
#[allow(clippy::too_many_arguments)]
pub fn gemm_words_with_choice<W: Word>(
    a: &[W],
    b: &[W],
    out: &mut [i32],
    m: usize,
    n: usize,
    kw: usize,
    k: usize,
    choice: KernelChoice,
) {
    assert_eq!(a.len(), m * kw, "A words");
    assert_eq!(b.len(), n * kw, "B words");
    assert_eq!(out.len(), m * n, "C size");
    if m == 0 || n == 0 {
        return;
    }
    // Parallelize over disjoint row-chunks of C (grain: keep each task
    // >= ~1 MOP so spawn cost is invisible).
    parallel_for_mut_chunks(out, n, choice.grain.max(1), |row0, c_chunk| {
        let rows = c_chunk.len() / n;
        for nb0 in (0..n).step_by(NB) {
            let nb1 = (nb0 + NB).min(n);
            gemm_rows_block(a, row0, b, c_chunk, 0, rows, nb0, nb1, n, kw, k, choice.micro);
        }
    });
}

/// Sweep a block of C rows against B panel `[nb0, nb1)`. A rows come
/// from `a` starting at row `ar0`; C rows start at `cr0` within
/// `c_chunk`. Under the 2×4 micro-kernel, row pairs share one B-panel
/// sweep; odd rows (and the other micro shapes) take the 1-row ladder.
#[allow(clippy::too_many_arguments)]
#[inline]
fn gemm_rows_block<W: Word>(
    a: &[W],
    ar0: usize,
    b: &[W],
    c_chunk: &mut [i32],
    cr0: usize,
    rows: usize,
    nb0: usize,
    nb1: usize,
    n: usize,
    kw: usize,
    k: usize,
    micro: MicroKernel,
) {
    let mut i = 0;
    if micro == MicroKernel::Mk2x4 {
        while i + 2 <= rows {
            let a0 = &a[(ar0 + i) * kw..(ar0 + i + 1) * kw];
            let a1 = &a[(ar0 + i + 1) * kw..(ar0 + i + 2) * kw];
            let r = cr0 + i;
            let (lo, hi) = c_chunk.split_at_mut((r + 1) * n);
            gemm_row_pair_panel(
                a0,
                a1,
                b,
                &mut lo[r * n + nb0..r * n + nb1],
                &mut hi[nb0..nb1],
                nb0,
                kw,
                k,
            );
            i += 2;
        }
    }
    while i < rows {
        let r = cr0 + i;
        let arow = &a[(ar0 + i) * kw..(ar0 + i + 1) * kw];
        let crow = &mut c_chunk[r * n + nb0..r * n + nb1];
        gemm_row_panel(arow, b, crow, nb0, kw, k, micro);
        i += 1;
    }
}

/// One A row against B rows `[b_start, b_start + c.len())`, writing the
/// corresponding dot products into `c[0..]`.
#[inline]
fn gemm_row_panel<W: Word>(
    arow: &[W],
    b: &[W],
    c: &mut [i32],
    b_start: usize,
    kw: usize,
    k: usize,
    micro: MicroKernel,
) {
    let count = c.len();
    let mut j = 0;
    if micro == MicroKernel::Mk1x8 {
        // widest micro-kernel first: 8 B rows per A sweep
        while j + 8 <= count {
            let base = (b_start + j) * kw;
            let bs: [&[W]; 8] = std::array::from_fn(|t| &b[base + t * kw..base + (t + 1) * kw]);
            let m = W::mismatch_rows8(arow, bs);
            for (t, mt) in m.iter().enumerate() {
                c[j + t] = k as i32 - 2 * *mt as i32;
            }
            j += 8;
        }
    }
    while j + NR <= count {
        let base = (b_start + j) * kw;
        let b0 = &b[base..base + kw];
        let b1 = &b[base + kw..base + 2 * kw];
        let b2 = &b[base + 2 * kw..base + 3 * kw];
        let b3 = &b[base + 3 * kw..base + 4 * kw];
        let (m0, m1, m2, m3) = mismatch4(arow, b0, b1, b2, b3);
        c[j] = k as i32 - 2 * m0 as i32;
        c[j + 1] = k as i32 - 2 * m1 as i32;
        c[j + 2] = k as i32 - 2 * m2 as i32;
        c[j + 3] = k as i32 - 2 * m3 as i32;
        j += NR;
    }
    while j < count {
        let base = (b_start + j) * kw;
        let brow = &b[base..base + kw];
        c[j] = k as i32 - 2 * super::dot::mismatches(arow, brow) as i32;
        j += 1;
    }
}

/// Two A rows against B rows `[b_start, b_start + c0.len())` — the 2×4
/// register block: each loaded B word feeds both A rows, halving B-panel
/// traffic relative to two 1×4 sweeps.
#[allow(clippy::too_many_arguments)]
#[inline]
fn gemm_row_pair_panel<W: Word>(
    a0: &[W],
    a1: &[W],
    b: &[W],
    c0: &mut [i32],
    c1: &mut [i32],
    b_start: usize,
    kw: usize,
    k: usize,
) {
    let count = c0.len();
    let mut j = 0;
    while j + NR <= count {
        let base = (b_start + j) * kw;
        let bs: [&[W]; NR] = std::array::from_fn(|t| &b[base + t * kw..base + (t + 1) * kw]);
        let mm = W::mismatch_rows2x4(a0, a1, bs);
        for t in 0..NR {
            c0[j + t] = k as i32 - 2 * mm[t] as i32;
            c1[j + t] = k as i32 - 2 * mm[NR + t] as i32;
        }
        j += NR;
    }
    while j < count {
        let base = (b_start + j) * kw;
        let brow = &b[base..base + kw];
        c0[j] = k as i32 - 2 * super::dot::mismatches(a0, brow) as i32;
        c1[j] = k as i32 - 2 * super::dot::mismatches(a1, brow) as i32;
        j += 1;
    }
}

/// Micro-kernel: mismatch counts of one packed row against four others.
/// Each `a` load is amortized over four B streams; dispatches to the
/// AVX2 popcount path on capable hosts (`bitpack::simd`).
#[inline(always)]
fn mismatch4<W: Word>(a: &[W], b0: &[W], b1: &[W], b2: &[W], b3: &[W]) -> (u32, u32, u32, u32) {
    W::mismatch_rows4(a, b0, b1, b2, b3)
}

/// Tile-streaming GEMM: like [`gemm_words_into`], but the A operand is
/// *virtual* — `fill(row0, row1, panel)` is called to produce packed A
/// rows `[row0, row1)` on demand into an L2-resident panel that feeds the
/// 1×4/1×8 micro-kernels directly. The full `m × kw` A matrix is never
/// materialized; peak A storage is one `tile_rows × kw` panel per worker,
/// drawn from `panels` (so plan-time reservations keep the hot path
/// allocation-free).
///
/// The fused convolution path drives this with the tile unrollers in
/// `tensor::unroll`; results are bit-identical to materializing A and
/// calling [`gemm_words_into`] because each output row still sweeps the
/// same packed words in the same order.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tiles_into<W: Word>(
    b: &[W],
    out: &mut [i32],
    m: usize,
    n: usize,
    kw: usize,
    k: usize,
    tile_rows: usize,
    panels: &BufferPool<W>,
    fill: &(dyn Fn(usize, usize, &mut [W]) + Sync),
) {
    let lc = tune::lookup(Family::Binary, W::BITS as u32, n, kw);
    let choice = KernelChoice { tile_rows: tile_rows.max(1), ..lc };
    gemm_tiles_with_choice::<W>(b, out, m, n, kw, k, choice, panels, fill)
}

/// [`gemm_tiles_into`] with an explicit kernel configuration.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tiles_with_choice<W: Word>(
    b: &[W],
    out: &mut [i32],
    m: usize,
    n: usize,
    kw: usize,
    k: usize,
    choice: KernelChoice,
    panels: &BufferPool<W>,
    fill: &(dyn Fn(usize, usize, &mut [W]) + Sync),
) {
    assert_eq!(b.len(), n * kw, "B words");
    assert_eq!(out.len(), m * n, "C size");
    if m == 0 || n == 0 {
        return;
    }
    let tile = choice.tile_rows.max(1);
    // Parallel over row-chunks of C (each at least one tile, and big
    // enough that spawn cost stays invisible); each worker streams its
    // rows tile by tile through one reused panel.
    let grain = tile.max(choice.grain.max(1));
    parallel_for_mut_chunks(out, n, grain, |row0, c_chunk| {
        let rows = c_chunk.len() / n;
        // worker-affine: each scheduler slot reacquires the same warm
        // L2 panel across chunks, layers and requests
        let mut panel = panels.acquire_affine(current_slot(), tile * kw);
        for t0 in (0..rows).step_by(tile) {
            let t1 = (t0 + tile).min(rows);
            fill(row0 + t0, row0 + t1, &mut panel[..(t1 - t0) * kw]);
            for nb0 in (0..n).step_by(NB) {
                let nb1 = (nb0 + NB).min(n);
                gemm_rows_block(
                    &panel[..],
                    0,
                    b,
                    c_chunk,
                    t0,
                    t1 - t0,
                    nb0,
                    nb1,
                    n,
                    kw,
                    k,
                    choice.micro,
                );
            }
        }
    });
}

/// Upper bound on simultaneously live A panels a [`gemm_tiles_into`] call
/// with these dimensions will draw from its pool — what `Layer::scratch`
/// reserves, so fused forwards never miss. Uses the same registry lookup
/// as the forward path, so reservation and execution agree on the grain
/// (provided reservations are re-taken after tuning — `Network::tune`).
pub fn gemm_tiles_workers<W: Word>(m: usize, n: usize, kw: usize, tile_rows: usize) -> usize {
    let lc = tune::lookup(Family::Binary, W::BITS as u32, n, kw);
    max_workers_for(m, tile_rows.max(1).max(lc.grain.max(1)))
}

/// Allocating wrapper around [`gemm_into`].
pub fn gemm<W: Word>(a: &[W], b: &[W], m: usize, n: usize, k: usize) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    gemm_into::<W>(a, b, &mut out, m, n, k);
    out
}

/// Binary GEMV: `y[j] = dot(x, B_j)` for a single packed input row.
///
/// Dense layers at batch size 1 take this path — the paper reports ≈15%
/// from swapping GEMM for a dedicated GEMV at batch 1 (experiment **A3**).
/// The win here is the same as in the paper: no panel blocking / loop
/// overhead, just a straight sweep over B with the 1×4 micro-kernel.
pub fn gemv_into<W: Word>(x: &[W], b: &[W], out: &mut [i32], n: usize, k: usize) {
    gemv_words_into::<W>(x, b, out, n, words_for::<W>(k), k)
}

/// [`gemv_into`] with an explicit word count (see [`gemm_words_into`]).
pub fn gemv_words_into<W: Word>(x: &[W], b: &[W], out: &mut [i32], n: usize, kw: usize, k: usize) {
    let choice = tune::lookup(Family::Binary, W::BITS as u32, n, kw);
    gemv_words_with_choice::<W>(x, b, out, n, kw, k, choice)
}

/// [`gemv_words_into`] with an explicit kernel configuration. Only the
/// micro shape applies (a 2×4 request degrades to the 1×4 ladder — there
/// is one input row); the grain stays on the GEMV-specific formula.
pub fn gemv_words_with_choice<W: Word>(
    x: &[W],
    b: &[W],
    out: &mut [i32],
    n: usize,
    kw: usize,
    k: usize,
    choice: KernelChoice,
) {
    assert_eq!(x.len(), kw, "x words");
    assert_eq!(b.len(), n * kw, "B words");
    assert_eq!(out.len(), n, "y size");
    // Parallel over output chunks for large layers; inline for small.
    // Grain in spawn-cost units (~1<<17 word-ops); the pool scheduler
    // splits it POOL_GRAIN_DIV× finer, which is what lets a ~10 µs
    // batch-1 dense reduction split at all (see util::parallel).
    let grain = ((1 << 17) / kw.max(1)).max(8);
    parallel_for_mut_chunks(out, 1, grain, |j0, yc| {
        gemm_row_panel(x, b, yc, j0, kw, k, choice.micro);
    });
}

/// Allocating wrapper around [`gemv_into`].
pub fn gemv<W: Word>(x: &[W], b: &[W], n: usize, k: usize) -> Vec<i32> {
    let mut out = vec![0i32; n];
    gemv_into::<W>(x, b, &mut out, n, k);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitpack::pack::pack_matrix_rows;
    use crate::util::rng::Rng;

    fn naive_gemm(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for t in 0..k {
                    acc += (a[i * k + t] * b[j * k + t]) as i32;
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn gemm_matches_naive_various_shapes() {
        let mut rng = Rng::new(21);
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (1, 10, 64),
            (3, 5, 7),
            (4, 4, 128),
            (17, 9, 130),
            (33, 65, 200),
            (8, 128, 1024),
        ] {
            let a = rng.signs(m * k);
            let b = rng.signs(n * k);
            let pa = pack_matrix_rows::<u64>(&a, m, k);
            let pb = pack_matrix_rows::<u64>(&b, n, k);
            assert_eq!(
                gemm::<u64>(&pa, &pb, m, n, k),
                naive_gemm(&a, &b, m, n, k),
                "shape ({m},{n},{k})"
            );
        }
    }

    #[test]
    fn gemm_u32_matches_u64() {
        let mut rng = Rng::new(22);
        let (m, n, k) = (13, 29, 190);
        let a = rng.signs(m * k);
        let b = rng.signs(n * k);
        let out64 = gemm::<u64>(
            &pack_matrix_rows::<u64>(&a, m, k),
            &pack_matrix_rows::<u64>(&b, n, k),
            m,
            n,
            k,
        );
        let out32 = gemm::<u32>(
            &pack_matrix_rows::<u32>(&a, m, k),
            &pack_matrix_rows::<u32>(&b, n, k),
            m,
            n,
            k,
        );
        assert_eq!(out64, out32);
    }

    #[test]
    fn gemv_matches_gemm_row() {
        let mut rng = Rng::new(23);
        let (n, k) = (301, 257);
        let x = rng.signs(k);
        let b = rng.signs(n * k);
        let px = pack_matrix_rows::<u64>(&x, 1, k);
        let pb = pack_matrix_rows::<u64>(&b, n, k);
        let via_gemm = gemm::<u64>(&px, &pb, 1, n, k);
        let via_gemv = gemv::<u64>(&px, &pb, n, k);
        assert_eq!(via_gemm, via_gemv);
    }

    /// The tile-streaming entry point must be bit-identical to the
    /// materializing GEMM for any tile size, including tiles that do not
    /// divide the row count.
    #[test]
    fn gemm_tiles_matches_materialized() {
        let mut rng = Rng::new(25);
        let pool = crate::alloc::BufferPool::<u64>::new();
        for &(m, n, k, tile) in &[
            (17usize, 9usize, 130usize, 4usize),
            (33, 65, 200, 16),
            (8, 128, 1024, 3),
            (5, 3, 7, 64),
        ] {
            let a = rng.signs(m * k);
            let b = rng.signs(n * k);
            let pa = pack_matrix_rows::<u64>(&a, m, k);
            let pb = pack_matrix_rows::<u64>(&b, n, k);
            let kw = words_for::<u64>(k);
            let mut out = vec![0i32; m * n];
            gemm_tiles_into::<u64>(&pb, &mut out, m, n, kw, k, tile, &pool, &|r0, r1, panel| {
                panel.copy_from_slice(&pa[r0 * kw..r1 * kw])
            });
            assert_eq!(out, gemm::<u64>(&pa, &pb, m, n, k), "({m},{n},{k},{tile})");
        }
    }

    /// Every tunable micro-kernel shape must produce identical results
    /// through both the materializing and tile-streaming entry points —
    /// the autotuner may pick any of them per dims.
    #[test]
    fn micro_kernel_shapes_agree() {
        use crate::util::tune::{KernelChoice, MicroKernel};
        let mut rng = Rng::new(26);
        let pool = crate::alloc::BufferPool::<u64>::new();
        for &(m, n, k) in &[
            (5usize, 9usize, 130usize),
            (8, 16, 64),
            (7, 33, 200),
            (2, 4, 64),
            (1, 13, 100),
        ] {
            let a = rng.signs(m * k);
            let b = rng.signs(n * k);
            let pa = pack_matrix_rows::<u64>(&a, m, k);
            let pb = pack_matrix_rows::<u64>(&b, n, k);
            let kw = words_for::<u64>(k);
            let want = gemm::<u64>(&pa, &pb, m, n, k);
            for micro in [MicroKernel::Mk1x4, MicroKernel::Mk1x8, MicroKernel::Mk2x4] {
                let choice = KernelChoice { micro, tile_rows: 3, grain: 1 };
                let mut out = vec![0i32; m * n];
                gemm_words_with_choice::<u64>(&pa, &pb, &mut out, m, n, kw, k, choice);
                assert_eq!(out, want, "materialized micro {micro} ({m},{n},{k})");
                let mut tiled = vec![0i32; m * n];
                gemm_tiles_with_choice::<u64>(
                    &pb,
                    &mut tiled,
                    m,
                    n,
                    kw,
                    k,
                    choice,
                    &pool,
                    &|r0, r1, panel| panel.copy_from_slice(&pa[r0 * kw..r1 * kw]),
                );
                assert_eq!(tiled, want, "tiled micro {micro} ({m},{n},{k})");
            }
        }
    }

    #[test]
    fn gemm_handles_empty() {
        let out = gemm::<u64>(&[], &[], 0, 0, 0);
        assert!(out.is_empty());
    }

    #[test]
    fn gemm_output_range_bound() {
        // all outputs must lie in [-k, k] with parity of k
        let mut rng = Rng::new(24);
        let (m, n, k) = (9, 11, 77);
        let a = rng.signs(m * k);
        let b = rng.signs(n * k);
        let out = gemm::<u64>(
            &pack_matrix_rows::<u64>(&a, m, k),
            &pack_matrix_rows::<u64>(&b, n, k),
            m,
            n,
            k,
        );
        for &v in &out {
            assert!(v.abs() <= k as i32);
            assert_eq!((v - k as i32) % 2, 0, "parity");
        }
    }
}
