//! Bit-packed binary linear algebra — the paper's core contribution
//! (§4: XNOR/popcount dot products over packed words, bit-plane input
//! decomposition; §5.2: blocked binary GEMM/GEMV kernels).
//!
//! All kernels are generic over the packing width ([`word::Word`]:
//! `u64` / `u32`) so the paper's 64-bit-vs-32-bit comparison (Table 1,
//! experiment A4) measures the same code.

pub mod bitplane;
pub mod dot;
pub mod gemm;
pub mod pack;
pub mod simd;
pub mod word;

pub use bitplane::{
    bitplane_dot, bitplane_gemm_into, bitplane_gemm_tiles_into, bitplane_gemv_into,
    bitplane_tiles_workers, BitPlanes,
};
pub use dot::{dot, mismatches, or_rows, plane_dot};
pub use gemm::{
    gemm, gemm_into, gemm_tiles_into, gemm_tiles_workers, gemm_words_into, gemv, gemv_into,
    gemv_words_into,
};
pub use pack::{
    pack_matrix_cols, pack_matrix_rows, pack_signs, pack_signs_into, pack_thresholds_f32_into,
    pack_thresholds_into, packed_bytes, unpack_signs,
};
pub use word::{words_for, Word};
