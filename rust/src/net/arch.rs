//! The two evaluation architectures (paper §6.2, §6.3), as `ModelSpec`
//! builders.
//!
//! * **BMLP** — the MNIST MLP of Courbariaux et al. (2016) §2.1: three
//!   4096-unit binary hidden layers + a 10-way output, each block
//!   Dense→BN→sign (no sign on the output).
//! * **BCNN** — the CIFAR-10 VGG-like ConvNet of Hubara et al. (2016)
//!   §2.3: (2×128C3)–MP2–(2×256C3)–MP2–(2×512C3)–MP2–1024FC–1024FC–10,
//!   "same" 3×3 convolutions, conv→(pool)→BN→sign blocks.
//!
//! Weights/BN here are seeded-random stand-ins with trained-network
//! statistics for benchmarking (timing does not depend on weight values);
//! real trained parameters arrive through `.esp` files exported by
//! `python/compile/train.py` + `convert.py`.

use crate::format::{BnSpec, InputKind, LayerSpec, ModelSpec};
use crate::layers::OutRepr;
use crate::tensor::Shape;
use crate::util::rng::Rng;

/// Random BN parameters with plausible trained statistics: γ around ±1,
/// β small, running mean near zero relative to the layer's fan-in.
fn random_bn(rng: &mut Rng, f: usize, fan_in: usize) -> BnSpec {
    let scale = (fan_in as f32).sqrt();
    BnSpec {
        eps: 1e-4,
        gamma: (0..f)
            .map(|_| {
                let g = rng.f32_range(0.5, 1.5) * rng.sign();
                if g.abs() < 0.05 {
                    1.0
                } else {
                    g
                }
            })
            .collect(),
        beta: (0..f).map(|_| rng.f32_range(-0.5, 0.5)).collect(),
        mean: (0..f).map(|_| rng.f32_range(-0.3, 0.3) * scale).collect(),
        var: (0..f).map(|_| rng.f32_range(0.5, 2.0) * fan_in as f32).collect(),
    }
}

/// Dense→BN(→sign) block.
fn dense_block(
    rng: &mut Rng,
    inf: usize,
    outf: usize,
    sign: bool,
    bitplane_first: bool,
) -> LayerSpec {
    LayerSpec::Dense {
        in_features: inf as u32,
        out_features: outf as u32,
        sign,
        bitplane_first,
        repr: OutRepr::Sign,
        act_delta: 1.0,
        alpha: None,
        weights: rng.signs(inf * outf).into(),
        bn: Some(random_bn(rng, outf, inf)),
    }
}

/// Conv(→pool)→BN(→sign) block, 3×3 "same".
fn conv_block(rng: &mut Rng, inc: usize, f: usize, pool: bool) -> LayerSpec {
    LayerSpec::Conv {
        in_channels: inc as u32,
        filters: f as u32,
        kh: 3,
        kw: 3,
        stride: 1,
        pad: 1,
        sign: true,
        bitplane_first: false,
        repr: OutRepr::Sign,
        act_delta: 1.0,
        alpha: None,
        pool: if pool { Some((2, 2)) } else { None },
        weights: rng.signs(f * 9 * inc).into(),
        bn: Some(random_bn(rng, f, 9 * inc)),
    }
}

/// Retarget every *hidden* binarizing Dense/Conv block of `spec` to a
/// different output representation: `new_repr` with activation step
/// `delta`, and (when `with_alpha`) fresh positive per-channel α scales.
/// Score layers (`sign == false`) keep plain float outputs. Used by the
/// representation-sweep bench and the property suites to derive
/// scaled-binary / multi-bit variants of the stock architectures.
pub fn retarget_repr(
    spec: &mut ModelSpec,
    rng: &mut Rng,
    new_repr: OutRepr,
    delta: f32,
    with_alpha: bool,
) {
    for l in &mut spec.layers {
        match l {
            LayerSpec::Dense {
                sign: true,
                out_features,
                repr,
                act_delta,
                alpha,
                ..
            } => {
                *repr = new_repr;
                *act_delta = delta;
                *alpha = with_alpha.then(|| {
                    (0..*out_features).map(|_| rng.f32_range(0.2, 1.8)).collect()
                });
            }
            LayerSpec::Conv {
                sign: true,
                filters,
                repr,
                act_delta,
                alpha,
                ..
            } => {
                *repr = new_repr;
                *act_delta = delta;
                *alpha = with_alpha.then(|| {
                    (0..*filters).map(|_| rng.f32_range(0.2, 1.8)).collect()
                });
            }
            _ => {}
        }
    }
    spec.name = format!("{}-{new_repr}", spec.name);
}

/// The paper's MNIST MLP: 784 → 4096 → 4096 → 4096 → 10.
/// `hidden` and `layers` are parameterizable for scaled-down tests.
pub fn bmlp_spec(rng: &mut Rng, hidden: usize, hidden_layers: usize) -> ModelSpec {
    let input = 28 * 28;
    let mut layers = Vec::new();
    let mut prev = input;
    for i in 0..hidden_layers {
        layers.push(dense_block(rng, prev, hidden, true, i == 0));
        prev = hidden;
    }
    layers.push(dense_block(rng, prev, 10, false, false));
    ModelSpec {
        name: format!("bmlp-{hidden}x{hidden_layers}"),
        input_shape: Shape::vector(input),
        input_kind: InputKind::Bytes,
        layers,
    }
}

/// Canonical paper-size BMLP (3×4096).
pub fn mnist_arch(rng: &mut Rng) -> ModelSpec {
    bmlp_spec(rng, 4096, 3)
}

/// The paper's CIFAR-10 BCNN, parameterized by a width factor so tests
/// can run a narrow version (`width = 1.0` → 128/256/512 channels).
pub fn bcnn_spec(rng: &mut Rng, width: f32) -> ModelSpec {
    let c = |base: usize| ((base as f32 * width) as usize).max(8);
    let (c1, c2, c3) = (c(128), c(256), c(512));
    let fc = c(1024);
    // input 32x32x3; three conv stages halve spatial dims each
    let flat = 4 * 4 * c3;
    let layers = vec![
        conv_block(rng, 3, c1, false),
        conv_block(rng, c1, c1, true), // -> 16x16
        conv_block(rng, c1, c2, false),
        conv_block(rng, c2, c2, true), // -> 8x8
        conv_block(rng, c2, c3, false),
        conv_block(rng, c3, c3, true), // -> 4x4
        dense_block(rng, flat, fc, true, false),
        dense_block(rng, fc, fc, true, false),
        dense_block(rng, fc, 10, false, false),
    ];
    ModelSpec {
        name: format!("bcnn-w{width}"),
        input_shape: Shape::new(32, 32, 3),
        input_kind: InputKind::Bytes,
        layers,
    }
}

/// Canonical paper-size BCNN.
pub fn cifar_arch(rng: &mut Rng) -> ModelSpec {
    bcnn_spec(rng, 1.0)
}

/// A LeNet-style binary CNN for MNIST (28×28×1), parameterized by a
/// width factor (`width = 1.0` → 32/64 conv channels, 256 FC). Used by
/// the T3 batch-sweep bench: small enough that the batched binary GEMM's
/// amortization — not raw layer width — dominates the measurement.
pub fn mnist_cnn_spec(rng: &mut Rng, width: f32) -> ModelSpec {
    let c = |base: usize| ((base as f32 * width) as usize).max(4);
    let (c1, c2) = (c(32), c(64));
    let fc = c(256);
    // 28x28 -> conv(same)+MP2 -> 14x14 -> conv(same)+MP2 -> 7x7
    let flat = 7 * 7 * c2;
    let layers = vec![
        conv_block(rng, 1, c1, true),  // -> 14x14
        conv_block(rng, c1, c2, true), // -> 7x7
        dense_block(rng, flat, fc, true, false),
        dense_block(rng, fc, 10, false, false),
    ];
    ModelSpec {
        name: format!("mcnn-w{width}"),
        input_shape: Shape::new(28, 28, 1),
        input_kind: InputKind::Bytes,
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layers::Backend;
    use crate::net::Network;
    use crate::tensor::Tensor;

    #[test]
    fn bmlp_shapes() {
        let mut rng = Rng::new(141);
        let spec = bmlp_spec(&mut rng, 128, 3);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        assert_eq!(net.layer_count(), 4);
        assert_eq!(net.output_shape.n, 10);
    }

    #[test]
    fn bcnn_shapes_and_flatten() {
        let mut rng = Rng::new(142);
        let spec = bcnn_spec(&mut rng, 0.125); // 16/32/64 channels
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        assert_eq!(net.output_shape.n, 10);
    }

    #[test]
    fn small_bcnn_float_binary_agree_end_to_end() {
        let mut rng = Rng::new(143);
        let spec = bcnn_spec(&mut rng, 0.125);
        let nf = Network::<u64>::from_spec(&spec, Backend::Float).unwrap();
        let nb = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let img: Vec<u8> = (0..32 * 32 * 3).map(|_| rng.next_u32() as u8).collect();
        let t = Tensor::from_vec(Shape::new(32, 32, 3), img);
        let sf = nf.predict_bytes(&t);
        let sb = nb.predict_bytes(&t);
        for (a, b) in sf.iter().zip(&sb) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
        assert_eq!(crate::net::argmax(&sf), crate::net::argmax(&sb));
    }

    #[test]
    fn small_bmlp_float_binary_agree_end_to_end() {
        let mut rng = Rng::new(144);
        let spec = bmlp_spec(&mut rng, 256, 2);
        let nf = Network::<u64>::from_spec(&spec, Backend::Float).unwrap();
        let nb = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        for _ in 0..5 {
            let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
            let t = Tensor::from_vec(Shape::vector(784), img);
            let sf = nf.predict_bytes(&t);
            let sb = nb.predict_bytes(&t);
            for (a, b) in sf.iter().zip(&sb) {
                assert!((a - b).abs() < 1e-2, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn u32_and_u64_networks_agree() {
        let mut rng = Rng::new(145);
        let spec = bmlp_spec(&mut rng, 192, 2);
        let n64 = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let n32 = Network::<u32>::from_spec(&spec, Backend::Binary).unwrap();
        let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
        let t = Tensor::from_vec(Shape::vector(784), img);
        let a = n64.predict_bytes(&t);
        let b = n32.predict_bytes(&t);
        assert_eq!(a, b);
    }

    #[test]
    fn paper_size_memory_claims() {
        // M1: BMLP ≈ 140.6 MB float vs ≈ 4.57 MB packed (≈31x)
        let mut rng = Rng::new(146);
        let spec = mnist_arch(&mut rng);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let rep = net.memory_report();
        let float_mb = rep.total_float() as f64 / 1e6;
        let packed_mb = rep.total_packed() as f64 / 1e6;
        assert!((130.0..160.0).contains(&float_mb), "float {float_mb} MB");
        assert!((3.5..6.0).contains(&packed_mb), "packed {packed_mb} MB");
        assert!(rep.saving() > 25.0, "saving {}", rep.saving());
    }
}
