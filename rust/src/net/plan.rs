//! Ahead-of-time compiled forward plans.
//!
//! Espresso's wins come from doing work **once at load time** (pack-once
//! weights, the custom allocator, hybrid per-layer placement — paper §3).
//! This module extends that discipline to the forward pass itself: a
//! [`ForwardPlan`] is built once per network and records, per layer, the
//! resolved input/output activation representation ([`ActKind`]), the
//! per-image shapes, the chosen [`Backend`], the representation boundary
//! the step crosses, and the scratch buffers it will draw from the
//! [`Workspace`]. Steady-state execution is then a flat walk over
//! [`Step`]s:
//!
//! * a Binary→Binary boundary provably stays packed — the plan proves it
//!   at build time instead of re-deriving it per request;
//! * Float interludes exist only where a step's `boundary` says so;
//! * inputs flow **by reference** into the first step
//!   ([`Layer::forward_view`]), so `predict_*` never clones its input;
//! * [`ForwardPlan::reserve`] pre-sizes every pool the plan will touch, so
//!   warmed steady-state forwards perform zero pool misses.
//!
//! Plan construction can also pick per-layer backends itself
//! ([`auto_place`]) with a coarse cost model over GEMM dimensions and
//! pack/unpack transition costs — the paper's hybrid-DNN feature as a
//! computed default rather than a manual knob (`set_backends` still
//! overrides).
//!
//! The executor records a [`PlanProfile`] (per-step wall time, bytes
//! produced, boundary crossings) into lock-free counters; snapshots are
//! surfaced through `runtime::Engine::plan_profile` into coordinator
//! metrics and the `espresso profile` CLI subcommand.

use crate::alloc::Workspace;
use crate::bitpack::Word;
use crate::layers::{Act, ActKind, ActView, Backend, Layer};
use crate::tensor::Shape;
use crate::util::parallel::ParallelCtx;
use crate::util::stats::{fmt_bytes, fmt_ns};
use crate::util::tune::KernelChoice;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// The representation transition a step performs on the way from its
/// input to its output activation (derived from the resolved kinds).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Boundary {
    /// Representation flows through unchanged (e.g. packed stays packed).
    Keep,
    /// Float activations are sign-packed into words.
    Pack,
    /// Packed activations leave the bit domain (unpack / score lift).
    Unpack,
    /// Fixed-precision bytes are widened to floats.
    Widen,
    /// Fixed-precision bytes are consumed via bit-plane decomposition.
    Planes,
    /// One packed representation becomes a *different* packed one (e.g.
    /// plain bits in, ternary thermometer planes out) — the step re-
    /// quantizes without ever leaving the integer domain.
    Requant,
}

impl std::fmt::Display for Boundary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Boundary::Keep => "-",
            Boundary::Pack => "pack",
            Boundary::Unpack => "unpack",
            Boundary::Widen => "widen",
            Boundary::Planes => "planes",
            Boundary::Requant => "requant",
        })
    }
}

impl From<crate::format::InputKind> for ActKind {
    fn from(k: crate::format::InputKind) -> ActKind {
        match k {
            crate::format::InputKind::Bytes => ActKind::Bytes,
            crate::format::InputKind::Float => ActKind::Float,
        }
    }
}

fn boundary_of(in_kind: ActKind, out_kind: ActKind) -> Boundary {
    if in_kind == out_kind {
        return Boundary::Keep;
    }
    match (in_kind.is_packed(), out_kind.is_packed()) {
        (true, true) => Boundary::Requant,
        (true, false) => Boundary::Unpack,
        (false, true) if in_kind == ActKind::Bytes => Boundary::Planes,
        (false, true) => Boundary::Pack,
        (false, false) if in_kind == ActKind::Bytes => Boundary::Widen,
        (false, false) => Boundary::Keep,
    }
}

fn backend_str(b: Backend) -> &'static str {
    match b {
        Backend::Float => "float",
        Backend::Binary => "binary",
    }
}

/// One resolved layer execution in a [`ForwardPlan`].
#[derive(Clone, Debug)]
pub struct Step {
    /// Index into the network's layer list.
    pub layer: usize,
    /// `describe()` of the layer (reports).
    pub name: String,
    pub backend: Backend,
    pub in_kind: ActKind,
    pub out_kind: ActKind,
    /// Per-image input shape (the batch axis scales at execution time).
    pub in_shape: Shape,
    /// Per-image output shape.
    pub out_shape: Shape,
    /// Representation transition this step realizes.
    pub boundary: Boundary,
    /// Scale factors the layer folds into its epilogue/thresholds under
    /// the planned input kind ([`Layer::scale_mode`]): `a` per-channel
    /// weight scales, `K`/`s` XNOR-Net input scales, `d`/`d'` quantized
    /// activation steps in/out. `-` for the plain unscaled path.
    pub scale: String,
    /// Scratch footprint at batch 1 in bytes (reporting; reservations are
    /// recomputed per batch size by [`ForwardPlan::reserve`]).
    pub scratch_bytes1: usize,
    /// What the materializing oracle would reserve at batch 1 (conv
    /// layers: the full unrolled patch matrix). The delta against
    /// `scratch_bytes1` is the fused tile-streaming memory win.
    pub scratch_materialized_bytes1: usize,
    /// Tuned kernel configuration for this step's GEMM, written once by
    /// `Network::tune` after the autotuner picks a winner. Empty until
    /// tuning runs (the kernels then use their built-in defaults) and for
    /// steps with no tunable GEMM.
    pub kernel: OnceLock<KernelChoice>,
}

#[derive(Default)]
struct StepStats {
    calls: AtomicU64,
    ns: AtomicU64,
    bytes_out: AtomicU64,
    /// Largest input batch observed (drives the peak-scratch columns).
    peak_batch: AtomicU64,
    /// Scratch reservation bytes at the peak batch (fused path).
    peak_scratch: AtomicU64,
    /// Scratch the materializing oracle would need at the peak batch.
    peak_scratch_materialized: AtomicU64,
    /// Scheduler profile of this step: pool jobs vs inline ranges,
    /// chunks claimed per worker slot, wall vs cpu spans. Installed as
    /// the thread's parallel sink for the duration of the step.
    par: ParallelCtx,
}

/// A compiled forward pass: a flat `Vec<Step>` plus lock-free profiling
/// counters. Built once per `Network`; rebuilt only when backends change.
pub struct ForwardPlan {
    pub input_kind: ActKind,
    pub input_shape: Shape,
    pub output_shape: Shape,
    /// Representation the final step emits (callers usually lift to float).
    pub output_kind: ActKind,
    pub steps: Vec<Step>,
    stats: Vec<StepStats>,
}

impl ForwardPlan {
    /// Resolve the activation chain once: walk the layers, fixing each
    /// step's backend, input/output representation, shapes and scratch.
    /// `shapes` is the per-image activation chain from `prepare`
    /// (`layers.len() + 1` entries, input first).
    pub fn build<W: Word>(
        layers: &[Box<dyn Layer<W>>],
        backends: &[Backend],
        input_kind: ActKind,
        shapes: &[Shape],
    ) -> ForwardPlan {
        assert_eq!(backends.len(), layers.len(), "one backend per layer");
        assert_eq!(shapes.len(), layers.len() + 1, "shape chain length");
        let mut steps = Vec::with_capacity(layers.len());
        let mut kind = input_kind;
        for (i, layer) in layers.iter().enumerate() {
            let backend = backends[i];
            let out_kind = layer.out_kind(backend, kind);
            let scratch = layer.scratch(shapes[i], kind, backend, 1);
            let scratch_mat = layer.scratch_materialized(shapes[i], kind, backend, 1);
            steps.push(Step {
                layer: i,
                name: layer.describe(),
                backend,
                in_kind: kind,
                out_kind,
                in_shape: shapes[i],
                out_shape: shapes[i + 1],
                boundary: boundary_of(kind, out_kind),
                scale: layer.scale_mode(kind),
                scratch_bytes1: scratch.total_bytes(W::BITS / 8),
                scratch_materialized_bytes1: scratch_mat.total_bytes(W::BITS / 8),
                kernel: OnceLock::new(),
            });
            kind = out_kind;
        }
        let stats = steps.iter().map(|_| StepStats::default()).collect();
        ForwardPlan {
            input_kind,
            input_shape: shapes[0],
            output_shape: *shapes.last().unwrap(),
            output_kind: kind,
            steps,
            stats,
        }
    }

    /// Pre-size every workspace pool the plan will touch at this batch
    /// size. Idempotent: repeated reservations converge (the pool only
    /// tops classes up), so callers may reserve for several batch sizes.
    pub fn reserve<W: Word>(
        &self,
        layers: &[Box<dyn Layer<W>>],
        ws: &Workspace,
        batch: usize,
    ) {
        for step in &self.steps {
            let spec = layers[step.layer].scratch(step.in_shape, step.in_kind, step.backend, batch);
            ws.reserve::<W>(&spec);
        }
    }

    /// Execute the plan on a **borrowed** input: the first step consumes
    /// the reference directly (no input clone), every later step flows
    /// owned activations.
    ///
    /// An input whose representation differs from the planned
    /// `input_kind` (e.g. `predict_f32` against a Bytes-input spec) still
    /// executes correctly — every layer accepts any representation and
    /// the kind chain reconverges after the first step — it just runs
    /// off the reserved scratch sizes for that step.
    pub fn execute<W: Word>(
        &self,
        layers: &[Box<dyn Layer<W>>],
        input: ActView<'_, W>,
        ws: &Workspace,
    ) -> Act<W> {
        assert_eq!(layers.len(), self.steps.len(), "plan/layer mismatch");
        let batch = input.batch();
        if self.steps.is_empty() {
            return input.to_act();
        }
        let first = &self.steps[0];
        let t0 = Instant::now();
        let x = {
            let _par = self.stats[0].par.enter();
            layers[first.layer].forward_view(input, first.backend, ws)
        };
        self.record(0, t0, &x, batch, layers[first.layer].as_ref());
        self.run_tail(layers, x, ws, batch)
    }

    /// Execute the plan on an owned input (batched stacks, packed
    /// activations): the first step takes it by value, preserving the
    /// layers' move-based fast paths.
    pub fn execute_owned<W: Word>(
        &self,
        layers: &[Box<dyn Layer<W>>],
        input: Act<W>,
        ws: &Workspace,
    ) -> Act<W> {
        assert_eq!(layers.len(), self.steps.len(), "plan/layer mismatch");
        let batch = input.batch();
        if self.steps.is_empty() {
            return input;
        }
        let first = &self.steps[0];
        let t0 = Instant::now();
        let x = {
            let _par = self.stats[0].par.enter();
            layers[first.layer].forward(input, first.backend, ws)
        };
        self.record(0, t0, &x, batch, layers[first.layer].as_ref());
        self.run_tail(layers, x, ws, batch)
    }

    fn run_tail<W: Word>(
        &self,
        layers: &[Box<dyn Layer<W>>],
        mut x: Act<W>,
        ws: &Workspace,
        batch: usize,
    ) -> Act<W> {
        for (i, step) in self.steps.iter().enumerate().skip(1) {
            let t0 = Instant::now();
            x = {
                let _par = self.stats[i].par.enter();
                layers[step.layer].forward(x, step.backend, ws)
            };
            self.record(i, t0, &x, batch, layers[step.layer].as_ref());
        }
        x
    }

    fn record<W: Word>(
        &self,
        i: usize,
        t0: Instant,
        out: &Act<W>,
        batch_in: usize,
        layer: &dyn Layer<W>,
    ) {
        let step = &self.steps[i];
        debug_assert_eq!(
            out.kind_of(),
            step.out_kind,
            "step {i} ({}) emitted an unplanned representation",
            step.name
        );
        // batched inputs scale the planned per-image count by B; inputs
        // using the dense rows convention fold B into shape.m instead, so
        // assert divisibility rather than exact scaling
        debug_assert!(
            batch_in > 0
                && (out.shape().len() * out.batch()) % step.out_shape.len().max(1) == 0,
            "step {i} ({}) emitted an unplanned element count: {} vs per-image {}",
            step.name,
            out.shape().len() * out.batch(),
            step.out_shape.len()
        );
        let st = &self.stats[i];
        st.calls.fetch_add(1, Ordering::Relaxed);
        st.ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        st.bytes_out
            .fetch_add(out.payload_bytes() as u64, Ordering::Relaxed);
        // peak-scratch tracking: only recompute the (allocating) scratch
        // specs when a larger batch than any seen before arrives, so the
        // steady state pays one atomic RMW. fetch_max everywhere keeps
        // concurrent forwards of different batch sizes monotone (scratch
        // bytes are nondecreasing in batch, so per-field max is exact).
        if st.peak_batch.fetch_max(batch_in as u64, Ordering::Relaxed) < batch_in as u64 {
            let wb = W::BITS / 8;
            let fused = layer
                .scratch(step.in_shape, step.in_kind, step.backend, batch_in)
                .total_bytes(wb);
            let mat = layer
                .scratch_materialized(step.in_shape, step.in_kind, step.backend, batch_in)
                .total_bytes(wb);
            st.peak_scratch.fetch_max(fused as u64, Ordering::Relaxed);
            st.peak_scratch_materialized
                .fetch_max(mat as u64, Ordering::Relaxed);
        }
    }

    /// Number of steps whose boundary crosses a representation.
    pub fn transitions(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| s.boundary != Boundary::Keep)
            .count()
    }

    /// Snapshot the profiling counters.
    pub fn profile(&self) -> PlanProfile {
        let rows = self
            .steps
            .iter()
            .zip(&self.stats)
            .map(|(s, st)| ProfileRow {
                name: s.name.clone(),
                backend: s.backend,
                in_kind: s.in_kind,
                out_kind: s.out_kind,
                boundary: s.boundary,
                scale: s.scale.clone(),
                out_shape: s.out_shape,
                calls: st.calls.load(Ordering::Relaxed),
                total_ns: st.ns.load(Ordering::Relaxed),
                bytes_out: st.bytes_out.load(Ordering::Relaxed),
                peak_batch: st.peak_batch.load(Ordering::Relaxed),
                peak_scratch_bytes: st.peak_scratch.load(Ordering::Relaxed),
                peak_scratch_materialized_bytes: st
                    .peak_scratch_materialized
                    .load(Ordering::Relaxed),
                kernel: s.kernel.get().copied(),
                par: st.par.snapshot(),
            })
            .collect();
        PlanProfile { rows }
    }

    /// Zero the profiling counters (e.g. after warm-up). Peak-scratch
    /// high-water marks are kept: they describe reservations, not
    /// traffic.
    pub fn reset_profile(&self) {
        for st in &self.stats {
            st.calls.store(0, Ordering::Relaxed);
            st.ns.store(0, Ordering::Relaxed);
            st.bytes_out.store(0, Ordering::Relaxed);
            st.par.reset();
        }
    }

    /// Static plan table (no timing): what was resolved at build time.
    /// `mat@1` is the scratch the materializing oracle would need — the
    /// gap to `scratch@1` is the fused tile-streaming win.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<4} {:<40} {:>7} {:>14} {:>8} {:>8} {:>12} {:>12} {:>12} {:>15}\n",
            "step", "layer", "backend", "in->out", "bound", "scale", "out shape", "scratch@1", "mat@1", "kernel"
        ));
        for s in &self.steps {
            out.push_str(&format!(
                "{:<4} {:<40} {:>7} {:>14} {:>8} {:>8} {:>12} {:>12} {:>12} {:>15}\n",
                s.layer,
                s.name,
                backend_str(s.backend),
                format!("{}->{}", s.in_kind, s.out_kind),
                s.boundary.to_string(),
                s.scale,
                s.out_shape.to_string(),
                fmt_bytes(s.scratch_bytes1),
                fmt_bytes(s.scratch_materialized_bytes1),
                s.kernel.get().map_or_else(|| "-".to_string(), |c| c.to_string()),
            ));
        }
        out.push_str(&format!(
            "input {} ({}), output {} ({}), {} representation transitions\n",
            self.input_shape,
            self.input_kind,
            self.output_shape,
            self.output_kind,
            self.transitions()
        ));
        out
    }
}

/// Point-in-time per-step execution profile (what the `profile` CLI and
/// coordinator metrics render).
#[derive(Clone, Debug, Default)]
pub struct PlanProfile {
    pub rows: Vec<ProfileRow>,
}

#[derive(Clone, Debug)]
pub struct ProfileRow {
    pub name: String,
    pub backend: Backend,
    pub in_kind: ActKind,
    pub out_kind: ActKind,
    pub boundary: Boundary,
    /// Scale factors folded into the step's epilogue (see [`Step::scale`]).
    pub scale: String,
    pub out_shape: Shape,
    pub calls: u64,
    pub total_ns: u64,
    pub bytes_out: u64,
    /// Largest batch this step has executed.
    pub peak_batch: u64,
    /// Scratch reservation bytes at `peak_batch` (fused tile-streaming
    /// path — what the pools actually hold for this step).
    pub peak_scratch_bytes: u64,
    /// Scratch the materializing oracle would need at `peak_batch`.
    pub peak_scratch_materialized_bytes: u64,
    /// Tuned kernel configuration (`None` until `Network::tune` runs or
    /// for steps with no tunable GEMM).
    pub kernel: Option<KernelChoice>,
    /// Scheduler profile: pool jobs vs inline ranges, per-worker chunk
    /// claims, wall vs cpu span of this step's parallel work.
    pub par: crate::util::parallel::ParSnapshot,
}

impl ProfileRow {
    pub fn mean_ns(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.calls as f64
        }
    }

    /// Materialized-over-fused scratch ratio at the peak batch (≥ 1 means
    /// the fused path reserves less).
    pub fn scratch_reduction(&self) -> f64 {
        self.peak_scratch_materialized_bytes as f64 / self.peak_scratch_bytes.max(1) as f64
    }
}

impl PlanProfile {
    pub fn total_ns(&self) -> u64 {
        self.rows.iter().map(|r| r.total_ns).sum()
    }

    /// Per-forward peak scratch (bytes): the largest step reservation at
    /// the peak batch each step has seen (steps run sequentially, so the
    /// forward's high-water mark is the max, not the sum).
    pub fn peak_scratch_bytes(&self) -> u64 {
        self.rows
            .iter()
            .map(|r| r.peak_scratch_bytes)
            .max()
            .unwrap_or(0)
    }

    /// Per-forward peak scratch of the materializing oracle (bytes).
    pub fn peak_scratch_materialized_bytes(&self) -> u64 {
        self.rows
            .iter()
            .map(|r| r.peak_scratch_materialized_bytes)
            .max()
            .unwrap_or(0)
    }

    pub fn calls(&self) -> u64 {
        self.rows.first().map_or(0, |r| r.calls)
    }

    /// Per-layer table: mean step time, share of the forward, bytes
    /// produced, representation boundary, the peak scratch memory the
    /// step reserves (with the materialized-over-fused reduction, the
    /// tile-streaming win), and the effective workers the step's parallel
    /// jobs achieved (Σ cpu / Σ wall; "-" when everything ran inline).
    pub fn render(&self) -> String {
        let total = self.total_ns().max(1) as f64;
        let mut out = String::new();
        out.push_str(&format!(
            "{:<40} {:>7} {:>10} {:>6} {:>8} {:>8} {:>12} {:>14} {:>12} {:>8} {:>6} {:>15}\n",
            "layer",
            "backend",
            "mean",
            "share",
            "bound",
            "scale",
            "in->out",
            "bytes out",
            "scratch@B",
            "vs mat",
            "par",
            "kernel"
        ));
        for r in &self.rows {
            let par = if r.par.wall_ns > 0 {
                format!("{:.1}x", r.par.utilization())
            } else {
                "-".to_string()
            };
            out.push_str(&format!(
                "{:<40} {:>7} {:>10} {:>5.1}% {:>8} {:>8} {:>12} {:>14} {:>12} {:>7.1}x {:>6} {:>15}\n",
                r.name,
                backend_str(r.backend),
                fmt_ns(r.mean_ns()),
                100.0 * r.total_ns as f64 / total,
                r.boundary.to_string(),
                r.scale,
                format!("{}->{}", r.in_kind, r.out_kind),
                fmt_bytes(r.bytes_out as usize),
                fmt_bytes(r.peak_scratch_bytes as usize),
                r.scratch_reduction(),
                par,
                r.kernel.map_or_else(|| "-".to_string(), |c| c.to_string()),
            ));
        }
        let calls = self.calls();
        let mean_total = if calls == 0 {
            0.0
        } else {
            self.total_ns() as f64 / calls as f64
        };
        out.push_str(&format!(
            "TOTAL {} forwards, {} mean/forward, {} transitions/forward\n",
            calls,
            fmt_ns(mean_total),
            self.rows
                .iter()
                .filter(|r| r.boundary != Boundary::Keep)
                .count()
        ));
        out
    }

    /// Per-step worker-utilization table: pool jobs vs inline ranges,
    /// wall vs cpu span of the parallel work, effective workers, and the
    /// chunk-claim distribution across scheduler slots (slot 0 = the
    /// calling thread). Steps that issued no parallel work are skipped.
    pub fn render_workers(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<40} {:>6} {:>7} {:>10} {:>10} {:>6}  {}\n",
            "layer", "jobs", "inline", "wall", "cpu", "util", "chunks/slot"
        ));
        for r in &self.rows {
            if r.par.jobs == 0 && r.par.serial == 0 {
                continue;
            }
            let util = if r.par.wall_ns > 0 {
                format!("{:.1}x", r.par.utilization())
            } else {
                "-".to_string()
            };
            let mut dist = r
                .par
                .chunks
                .iter()
                .take(8)
                .enumerate()
                .map(|(s, c)| format!("w{s}:{c}"))
                .collect::<Vec<_>>()
                .join(" ");
            if r.par.chunks.len() > 8 {
                dist.push_str(" …");
            }
            out.push_str(&format!(
                "{:<40} {:>6} {:>7} {:>10} {:>10} {:>6}  {}\n",
                r.name,
                r.par.jobs,
                r.par.serial,
                fmt_ns(r.par.wall_ns as f64),
                fmt_ns(r.par.cpu_ns as f64),
                util,
                dist,
            ));
        }
        out
    }
}

/// Coarse per-step cost (arbitrary op units) for [`auto_place`]: GEMM
/// layers cost `m·n·k` in float, `m·n·(2k/W + c)` packed (one
/// XNOR+popcount per word plus a fixed per-output overhead), 8× the
/// packed cost (plus a larger constant) for bit-plane first layers;
/// crossing a representation boundary costs the activation size.
fn step_cost<W: Word>(
    layer: &dyn Layer<W>,
    backend: Backend,
    in_kind: ActKind,
    in_shape: Shape,
) -> f64 {
    let elems = in_shape.len() as f64;
    let wbits = W::BITS as f64;
    let boundary = match (backend, in_kind) {
        (Backend::Binary, ActKind::Float) => elems, // pack
        (Backend::Float, ActKind::Bytes) => elems,  // widen
        // unpack / dequantize any packed representation
        (Backend::Float, k) if k.is_packed() => elems,
        _ => 0.0,
    };
    let compute = match layer.gemm_dims(in_shape) {
        Some((m, n, k)) => {
            let (m, n, k) = (m as f64, n as f64, k as f64);
            match (backend, in_kind) {
                (Backend::Float, _) => m * n * k,
                // bit-plane decomposition: 8 plane GEMMs over packed
                // words; the constant keeps tiny reductions (a 3×3×3
                // first conv) on the float path, matching measurement
                (Backend::Binary, ActKind::Bytes) => m * n * (8.0 * 2.0 * k / wbits + 24.0),
                // thermometer planes: one packed GEMM per plane plus a
                // slightly heavier combine/pack tail
                (Backend::Binary, ActKind::Ternary) => m * n * (2.0 * 2.0 * k / wbits + 3.0),
                (Backend::Binary, ActKind::Bits2) => m * n * (3.0 * 2.0 * k / wbits + 4.0),
                // XNOR-Net scaled bits: one plane GEMM + f32 α·K epilogue
                (Backend::Binary, ActKind::ScaledBits) => {
                    m * n * (2.0 * k / wbits + 2.0) + m * n
                }
                (Backend::Binary, _) => m * n * (2.0 * k / wbits + 2.0),
            }
        }
        // data movement layers: packed data touches W× fewer words
        None => match (backend, in_kind) {
            (Backend::Binary, k) if k.is_packed() => {
                elems * 2.0 * k.planes() as f64 / wbits
            }
            _ => elems,
        },
    };
    boundary + compute
}

const KIND_LIST: [ActKind; 6] = [
    ActKind::Bytes,
    ActKind::Float,
    ActKind::Bits,
    ActKind::ScaledBits,
    ActKind::Bits2,
    ActKind::Ternary,
];

fn kind_index(k: ActKind) -> usize {
    match k {
        ActKind::Bytes => 0,
        ActKind::Float => 1,
        ActKind::Bits => 2,
        ActKind::ScaledBits => 3,
        ActKind::Bits2 => 4,
        ActKind::Ternary => 5,
    }
}

/// Cost-model backend auto-placement — the paper's hybrid-DNN placement
/// computed instead of hand-picked. A small DP over (layer, activation
/// kind) states chooses per-layer Float/Binary minimizing modeled compute
/// plus pack/unpack boundary costs; a packed final output pays one
/// unpack (scores are consumed as floats).
pub fn auto_place<W: Word>(
    layers: &[Box<dyn Layer<W>>],
    input_kind: ActKind,
    shapes: &[Shape],
) -> Vec<Backend> {
    let n = layers.len();
    if n == 0 {
        return Vec::new();
    }
    assert_eq!(shapes.len(), n + 1, "shape chain length");
    let backends = [Backend::Float, Backend::Binary];
    let mut dp = [f64::INFINITY; 6];
    dp[kind_index(input_kind)] = 0.0;
    // parent[i][out_kind] = (in_kind index, backend index) of the argmin
    let mut parent = vec![[(usize::MAX, usize::MAX); 6]; n];
    for (i, layer) in layers.iter().enumerate() {
        let mut next = [f64::INFINITY; 6];
        for (ki, &in_kind) in KIND_LIST.iter().enumerate() {
            if !dp[ki].is_finite() {
                continue;
            }
            for (bi, &b) in backends.iter().enumerate() {
                let cost = dp[ki] + step_cost::<W>(layer.as_ref(), b, in_kind, shapes[i]);
                let out = kind_index(layer.out_kind(b, in_kind));
                if cost < next[out] {
                    next[out] = cost;
                    parent[i][out] = (ki, bi);
                }
            }
        }
        dp = next;
    }
    // prefer plans ending in floats: packed final scores pay an unpack
    let final_elems = shapes[n].len() as f64;
    let mut best_kind = 0usize;
    let mut best_cost = f64::INFINITY;
    for (ki, &c) in dp.iter().enumerate() {
        if !c.is_finite() {
            continue;
        }
        let c = if KIND_LIST[ki].is_packed() {
            c + final_elems
        } else {
            c
        };
        if c < best_cost {
            best_cost = c;
            best_kind = ki;
        }
    }
    assert!(best_cost.is_finite(), "no feasible placement");
    let mut out = vec![Backend::Binary; n];
    let mut k = best_kind;
    for i in (0..n).rev() {
        let (pk, bi) = parent[i][k];
        out[i] = backends[bi];
        k = pk;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::InputKind;
    use crate::layers::Act;
    use crate::net::{mnist_cnn_spec, Network};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn plan_resolves_packed_chain_for_binary_cnn() {
        let mut rng = Rng::new(301);
        let spec = mnist_cnn_spec(&mut rng, 0.5);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let plan = net.plan();
        assert_eq!(plan.steps.len(), net.layer_count());
        assert_eq!(plan.input_kind, ActKind::Bytes);
        // hidden fused conv blocks emit packed bits; chained binary
        // boundaries stay packed (in_kind Bits, boundary Keep)
        let mut saw_packed_chain = false;
        for w in plan.steps.windows(2) {
            if w[0].out_kind == ActKind::Bits && w[1].backend == Backend::Binary {
                assert_eq!(w[1].in_kind, ActKind::Bits);
                assert_eq!(w[1].boundary, Boundary::Keep);
                saw_packed_chain = true;
            }
        }
        assert!(saw_packed_chain, "{}", plan.render());
        // final score layer leaves the packed domain exactly once
        assert_eq!(plan.output_kind, ActKind::Float);
        assert!(plan.render().contains("binary"));
    }

    #[test]
    fn profile_counts_forwards() {
        let mut rng = Rng::new(302);
        let spec = mnist_cnn_spec(&mut rng, 0.25);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let img: Vec<u8> = (0..28 * 28).map(|_| rng.next_u32() as u8).collect();
        let t = Tensor::from_vec(spec.input_shape, img);
        for _ in 0..3 {
            let _ = net.predict_bytes(&t);
        }
        let prof = net.profile();
        assert_eq!(prof.calls(), 3);
        assert!(prof.total_ns() > 0);
        for row in &prof.rows {
            assert_eq!(row.calls, 3, "{}", row.name);
        }
        assert!(prof.render().contains("TOTAL"));
        net.reset_profile();
        assert_eq!(net.profile().calls(), 0);
    }

    #[test]
    fn profile_records_scheduler_activity() {
        let mut rng = Rng::new(304);
        let spec = mnist_cnn_spec(&mut rng, 0.25);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let imgs: Vec<Tensor<u8>> = (0..4)
            .map(|_| {
                Tensor::from_vec(
                    spec.input_shape,
                    (0..28 * 28).map(|_| rng.next_u32() as u8).collect(),
                )
            })
            .collect();
        let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
        let _ = net.predict_batch_bytes(&refs);
        let prof = net.profile();
        let activity: u64 = prof.rows.iter().map(|r| r.par.jobs + r.par.serial).sum();
        assert!(activity > 0, "steps must report scheduler activity");
        let table = prof.render_workers();
        assert!(table.contains("chunks/slot"), "{table}");
        // reset clears the scheduler counters too
        net.reset_profile();
        let prof = net.profile();
        let activity: u64 = prof.rows.iter().map(|r| r.par.jobs + r.par.serial).sum();
        assert_eq!(activity, 0);
    }

    #[test]
    fn auto_place_prefers_binary_for_wide_layers() {
        // the MNIST MLP: wide 784-bit first reduction and hidden layers
        // should all go binary under the cost model
        let mut rng = Rng::new(303);
        let spec = crate::net::bmlp_spec(&mut rng, 512, 2);
        let mut net = Network::<u64>::from_spec(&spec, Backend::Float).unwrap();
        let placed = net.auto_place().to_vec();
        assert_eq!(placed.len(), net.layer_count());
        assert!(
            placed.iter().any(|&b| b == Backend::Binary),
            "{placed:?}"
        );
        // the plan was rebuilt under the new placement
        assert_eq!(net.plan().steps[0].backend, placed[0]);
        // and still predicts sane scores
        let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
        let t = Tensor::from_vec(spec.input_shape, img);
        assert_eq!(net.predict_bytes(&t).len(), 10);
    }

    #[test]
    fn empty_plan_passes_input_through() {
        let layers: Vec<Box<dyn Layer<u64>>> = Vec::new();
        let shapes = [Shape::vector(4)];
        let plan = ForwardPlan::build::<u64>(&layers, &[], ActKind::Float, &shapes);
        let ws = Workspace::new();
        let t = Tensor::from_vec(Shape::vector(4), vec![1.0, -1.0, 1.0, -1.0]);
        let out = plan
            .execute::<u64>(&layers, ActView::Float(&t), &ws)
            .into_float();
        assert_eq!(out.data, t.data);
        let out2 = plan
            .execute_owned::<u64>(&layers, Act::Float(t.clone()), &ws)
            .into_float();
        assert_eq!(out2.data, t.data);
    }

    #[test]
    fn input_kind_maps_from_format() {
        assert_eq!(ActKind::from(InputKind::Bytes), ActKind::Bytes);
        assert_eq!(ActKind::from(InputKind::Float), ActKind::Float);
    }
}
