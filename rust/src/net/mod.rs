//! Networks: sequences of layers with per-layer backend assignment
//! (the paper's hybrid-DNN feature, §3) plus builders for the two
//! evaluation architectures and the memory report behind the ≈31×
//! claims (§6.2/§6.3).
//!
//! The forward pass is **compiled**: construction resolves the whole
//! activation chain into a [`plan::ForwardPlan`] (per-layer backend,
//! representation, shapes, scratch reservations) and every `forward` /
//! `predict_*` runs the flat plan — see [`plan`] for the lifecycle.

pub mod arch;
pub mod plan;

pub use arch::{bcnn_spec, bmlp_spec, cifar_arch, mnist_arch, mnist_cnn_spec, retarget_repr};
pub use plan::{Boundary, ForwardPlan, PlanProfile, ProfileRow, Step};

use crate::alloc::Workspace;
use crate::bitpack::Word;
use crate::format::{InputKind, LayerSpec, ModelSpec};
use crate::layers::{
    Act, ActView, Backend, BatchNormLayer, ConvLayer, DenseLayer, Layer, MaxPoolLayer, SignLayer,
};
use crate::tensor::{Shape, Tensor};
use anyhow::{bail, Result};

/// A prepared feed-forward network.
pub struct Network<W: Word = u64> {
    pub name: String,
    pub input_shape: Shape,
    pub input_kind: InputKind,
    pub output_shape: Shape,
    layers: Vec<Box<dyn Layer<W>>>,
    /// Per-layer backend (hybrid execution). Uniform by default.
    backends: Vec<Backend>,
    /// Per-image activation shape chain from `prepare`
    /// (`layers.len() + 1` entries, input first).
    shapes: Vec<Shape>,
    /// The compiled forward pass; rebuilt whenever backends change.
    plan: ForwardPlan,
    pub ws: Workspace,
}

impl<W: Word> Network<W> {
    /// Build from a list of layers; `prepare` is run through the chain
    /// and the forward plan is compiled once, here.
    pub fn new(
        name: &str,
        input_shape: Shape,
        input_kind: InputKind,
        mut layers: Vec<Box<dyn Layer<W>>>,
        backend: Backend,
    ) -> Self {
        let mut shapes = Vec::with_capacity(layers.len() + 1);
        let mut shape = input_shape;
        shapes.push(shape);
        for layer in layers.iter_mut() {
            shape = layer.prepare(shape);
            shapes.push(shape);
        }
        let backends = vec![backend; layers.len()];
        let plan = ForwardPlan::build::<W>(&layers, &backends, input_kind.into(), &shapes);
        let net = Self {
            name: name.to_string(),
            input_shape,
            input_kind,
            output_shape: shape,
            layers,
            backends,
            shapes,
            plan,
            ws: Workspace::new(),
        };
        // load-time warm-up, as the paper's allocator does: size the
        // pools for single-image traffic before the first request
        net.reserve(1);
        net
    }

    /// Instantiate from a serialized model. BN/Sign/Pool layers directly
    /// following a Dense/Conv are fused into it (the "conversion to
    /// Espresso" step): the binary engine then sees threshold-packed
    /// blocks instead of float interludes.
    pub fn from_spec(spec: &ModelSpec, backend: Backend) -> Result<Self> {
        let fused = fuse_spec(&spec.layers)?;
        let mut layers: Vec<Box<dyn Layer<W>>> = Vec::with_capacity(fused.len());
        for l in &fused {
            layers.push(build_layer::<W>(l)?);
        }
        Ok(Self::new(
            &spec.name,
            spec.input_shape,
            spec.input_kind,
            layers,
            backend,
        ))
    }

    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    pub fn describe(&self) -> Vec<String> {
        self.layers.iter().map(|l| l.describe()).collect()
    }

    /// Set one backend for all layers (recompiles the plan).
    pub fn set_backend(&mut self, backend: Backend) {
        for b in self.backends.iter_mut() {
            *b = backend;
        }
        self.rebuild_plan();
    }

    /// Set per-layer backends (hybrid execution; recompiles the plan).
    pub fn set_backends(&mut self, backends: &[Backend]) {
        assert_eq!(backends.len(), self.layers.len(), "one backend per layer");
        self.backends.copy_from_slice(backends);
        self.rebuild_plan();
    }

    /// Pick per-layer backends with the plan's cost model (the paper's
    /// hybrid-DNN placement as a computed default); returns the chosen
    /// placement. `set_backend(s)` still overrides.
    pub fn auto_place(&mut self) -> &[Backend] {
        let placed = plan::auto_place::<W>(&self.layers, self.input_kind.into(), &self.shapes);
        self.backends.copy_from_slice(&placed);
        self.rebuild_plan();
        &self.backends
    }

    fn rebuild_plan(&mut self) {
        self.plan = ForwardPlan::build::<W>(
            &self.layers,
            &self.backends,
            self.input_kind.into(),
            &self.shapes,
        );
        self.reserve(1);
    }

    pub fn backends(&self) -> &[Backend] {
        &self.backends
    }

    /// The compiled forward plan.
    pub fn plan(&self) -> &ForwardPlan {
        &self.plan
    }

    /// Snapshot of the plan's per-step execution profile.
    pub fn profile(&self) -> PlanProfile {
        self.plan.profile()
    }

    /// Zero the plan's profiling counters.
    pub fn reset_profile(&self) {
        self.plan.reset_profile()
    }

    /// Pre-size every workspace pool the plan touches at this batch size,
    /// so steady-state forwards never miss the pool (the paper's
    /// load-time allocation discipline).
    pub fn reserve(&self, batch: usize) {
        self.plan.reserve::<W>(&self.layers, &self.ws, batch);
    }

    /// Autotune every GEMM-shaped step of the compiled plan: run the
    /// micro-benchmark harness (`util::tune`) for each step's
    /// `(family, dims)` key, record the winner into the process-wide
    /// kernel registry and the step's [`Step::kernel`] slot, then re-take
    /// the scratch reservations — tile/grain choices feed the reservation
    /// math, so pools must be re-sized for the pool no-miss guarantee to
    /// survive tuning. A no-op (defaults recorded, nothing timed) when
    /// `ESPRESSO_TUNE=off`. Tuned keys are process-wide and cached, so
    /// repeated calls — or several networks sharing layer geometry — only
    /// pay the measurement once.
    pub fn tune(&self) {
        for step in &self.plan.steps {
            let dims =
                self.layers[step.layer].tune_dims(step.in_shape, step.in_kind, step.backend);
            if let Some((family, m, n, k)) = dims {
                let choice = crate::util::tune::tune_gemm::<W>(family, m, n, k);
                let _ = step.kernel.set(choice);
            }
        }
        self.reserve(1);
    }

    /// Run the network on an activation (single image or a batch — every
    /// layer consumes the batch axis natively, so a batch of B runs as
    /// one GEMM per layer instead of B loops). Executes the compiled
    /// plan.
    pub fn forward(&self, x: Act<W>) -> Act<W> {
        self.plan.execute_owned::<W>(&self.layers, x, &self.ws)
    }

    /// Reference layer-walk forward (the pre-plan execution semantics).
    /// Kept as the equivalence oracle the plan executor is property-tested
    /// against; not used on the hot path.
    pub fn forward_layerwalk(&self, mut x: Act<W>) -> Act<W> {
        for (layer, &backend) in self.layers.iter().zip(&self.backends) {
            x = layer.forward(x, backend, &self.ws);
        }
        x
    }

    /// Materialized-oracle forward: every layer runs
    /// [`Layer::forward_materialized`] — for conv layers, the full
    /// `(B·oh·ow) × k` patch-matrix unroll + single GEMM the fused
    /// tile-streaming path replaced. The equivalence oracle for the fused
    /// conv property suite; not used on the hot path.
    pub fn forward_materialized(&self, mut x: Act<W>) -> Act<W> {
        for (layer, &backend) in self.layers.iter().zip(&self.backends) {
            x = layer.forward_materialized(x, backend, &self.ws);
        }
        x
    }

    /// Per-step scratch reservation totals at a batch size:
    /// `(step name, fused bytes, materialized bytes)` — what the fused
    /// tile-streaming path reserves vs what the materializing oracle
    /// would. Consumed by `espresso profile`, the t3 bench and the fused
    /// conv acceptance tests.
    pub fn scratch_report(&self, batch: usize) -> Vec<(String, usize, usize)> {
        let wb = W::BITS / 8;
        self.plan
            .steps
            .iter()
            .map(|s| {
                let layer = &self.layers[s.layer];
                (
                    s.name.clone(),
                    layer
                        .scratch(s.in_shape, s.in_kind, s.backend, batch)
                        .total_bytes(wb),
                    layer
                        .scratch_materialized(s.in_shape, s.in_kind, s.backend, batch)
                        .total_bytes(wb),
                )
            })
            .collect()
    }

    /// Classify a byte image: returns class scores. The input flows by
    /// reference into the first plan step — no clone.
    pub fn predict_bytes(&self, img: &Tensor<u8>) -> Vec<f32> {
        assert_eq!(img.shape.len(), self.input_shape.len(), "input size");
        self.plan
            .execute::<W>(&self.layers, ActView::Bytes(img), &self.ws)
            .into_float()
            .data
    }

    /// Classify a batch of byte images with a single batched forward:
    /// the images are stacked along the batch axis and every layer's GEMM
    /// covers the whole batch. Bit-identical to per-image
    /// [`Network::predict_bytes`] calls (the kernels keep per-row
    /// accumulation order), just faster under load. Returns one score
    /// vector per image.
    pub fn predict_batch_bytes(&self, imgs: &[&Tensor<u8>]) -> Vec<Vec<f32>> {
        if imgs.is_empty() {
            return Vec::new();
        }
        for img in imgs {
            assert_eq!(img.shape.len(), self.input_shape.len(), "input size");
            // all images must share one geometry: stacking adopts the
            // first image's shape, so a same-length different-shape image
            // would be silently convolved under the wrong geometry
            assert_eq!(img.shape, imgs[0].shape, "batch images must share a shape");
        }
        if imgs.len() == 1 {
            return vec![self.predict_bytes(imgs[0])];
        }
        let stacked = Tensor::stack(imgs);
        let out = self.forward(Act::Bytes(stacked)).into_float();
        let b = imgs.len();
        let per = out.data.len() / b;
        (0..b)
            .map(|i| out.data[i * per..(i + 1) * per].to_vec())
            .collect()
    }

    /// Classify a float input: returns class scores (borrowed into the
    /// first plan step — no clone).
    pub fn predict_f32(&self, x: &Tensor<f32>) -> Vec<f32> {
        self.plan
            .execute::<W>(&self.layers, ActView::Float(x), &self.ws)
            .into_float()
            .data
    }

    /// Argmax helper.
    pub fn classify_bytes(&self, img: &Tensor<u8>) -> usize {
        argmax(&self.predict_bytes(img))
    }

    /// Memory report: float vs packed parameter bytes per layer.
    pub fn memory_report(&self) -> MemoryReport {
        let rows = self
            .layers
            .iter()
            .map(|l| MemoryRow {
                layer: l.describe(),
                float_bytes: l.param_bytes_float(),
                packed_bytes: l.param_bytes_packed(),
            })
            .collect::<Vec<_>>();
        MemoryReport { rows }
    }
}

/// Index of the maximum score.
pub fn argmax(scores: &[f32]) -> usize {
    scores
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Per-layer memory accounting (experiments M1/M2).
pub struct MemoryReport {
    pub rows: Vec<MemoryRow>,
}

pub struct MemoryRow {
    pub layer: String,
    pub float_bytes: usize,
    pub packed_bytes: usize,
}

impl MemoryReport {
    pub fn total_float(&self) -> usize {
        self.rows.iter().map(|r| r.float_bytes).sum()
    }

    pub fn total_packed(&self) -> usize {
        self.rows.iter().map(|r| r.packed_bytes).sum()
    }

    pub fn saving(&self) -> f64 {
        self.total_float() as f64 / self.total_packed().max(1) as f64
    }

    pub fn render(&self) -> String {
        use crate::util::stats::fmt_bytes;
        let mut out = String::new();
        out.push_str(&format!(
            "{:<40} {:>12} {:>12}\n",
            "layer", "float", "packed"
        ));
        for r in &self.rows {
            out.push_str(&format!(
                "{:<40} {:>12} {:>12}\n",
                r.layer,
                fmt_bytes(r.float_bytes),
                fmt_bytes(r.packed_bytes)
            ));
        }
        out.push_str(&format!(
            "{:<40} {:>12} {:>12}   saving {:.1}x\n",
            "TOTAL",
            fmt_bytes(self.total_float()),
            fmt_bytes(self.total_packed()),
            self.saving()
        ));
        out
    }
}

/// Fuse BN / Sign / MaxPool spec entries into the preceding GEMM layer
/// where the binary engine profits: `Dense|Conv → [MaxPool] → [BN] →
/// [Sign]` collapses into one fused block. Standalone entries that don't
/// follow a GEMM layer are kept as standalone layers.
fn fuse_spec(layers: &[LayerSpec]) -> Result<Vec<LayerSpec>> {
    let mut out: Vec<LayerSpec> = Vec::with_capacity(layers.len());
    for l in layers {
        let fused = match (out.last_mut(), l) {
            (Some(LayerSpec::Conv { pool, .. }), LayerSpec::MaxPool { k, stride })
                if pool.is_none() =>
            {
                *pool = Some((*k, *stride));
                true
            }
            (
                Some(LayerSpec::Dense {
                    bn,
                    sign,
                    out_features,
                    ..
                }),
                LayerSpec::BatchNorm(b),
            ) if bn.is_none() && !*sign => {
                if b.gamma.len() != *out_features as usize {
                    bail!("BN features do not match preceding dense layer");
                }
                *bn = Some(b.clone());
                true
            }
            (Some(LayerSpec::Conv { bn, sign, filters, .. }), LayerSpec::BatchNorm(b))
                if bn.is_none() && !*sign =>
            {
                if b.gamma.len() != *filters as usize {
                    bail!("BN features do not match preceding conv layer");
                }
                *bn = Some(b.clone());
                true
            }
            (Some(LayerSpec::Dense { sign, .. }), LayerSpec::Sign) if !*sign => {
                *sign = true;
                true
            }
            (Some(LayerSpec::Conv { sign, .. }), LayerSpec::Sign) if !*sign => {
                *sign = true;
                true
            }
            _ => false,
        };
        if !fused {
            out.push(l.clone());
        }
    }
    Ok(out)
}

fn build_layer<W: Word>(spec: &LayerSpec) -> Result<Box<dyn Layer<W>>> {
    Ok(match spec {
        LayerSpec::Dense {
            in_features,
            out_features,
            sign,
            bitplane_first,
            repr,
            act_delta,
            alpha,
            weights,
            bn,
        } => {
            let mut l = DenseLayer::<W>::new(
                *in_features as usize,
                *out_features as usize,
                weights,
                bn.as_ref().map(|b| b.to_params()),
                *sign,
            );
            l.bitplane_first = *bitplane_first;
            l.configure_repr(*repr, *act_delta, alpha.clone());
            Box::new(l)
        }
        LayerSpec::Conv {
            in_channels,
            filters,
            kh,
            kw,
            stride,
            pad,
            sign,
            bitplane_first,
            repr,
            act_delta,
            alpha,
            pool,
            weights,
            bn,
        } => {
            let mut l = ConvLayer::<W>::new(
                *in_channels as usize,
                *filters as usize,
                *kh as usize,
                *kw as usize,
                *stride as usize,
                *pad as usize,
                weights,
                bn.as_ref().map(|b| b.to_params()),
                *sign,
                pool.map(|(k, s)| LayerSpec::pool_spec(k, s)),
            );
            l.bitplane_first = *bitplane_first;
            l.configure_repr(*repr, *act_delta, alpha.clone());
            Box::new(l)
        }
        LayerSpec::MaxPool { k, stride } => {
            Box::new(MaxPoolLayer::new(*k as usize, *stride as usize))
        }
        LayerSpec::BatchNorm(b) => Box::new(BatchNormLayer::new(b.to_params())),
        LayerSpec::Sign => Box::new(SignLayer),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::BnSpec;
    use crate::layers::OutRepr;
    use crate::util::rng::Rng;

    fn sample_bn(rng: &mut Rng, f: usize) -> BnSpec {
        BnSpec {
            eps: 1e-4,
            gamma: (0..f).map(|_| rng.f32_range(0.1, 2.0)).collect(),
            beta: (0..f).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
            mean: (0..f).map(|_| rng.f32_range(-3.0, 3.0)).collect(),
            var: (0..f).map(|_| rng.f32_range(0.2, 4.0)).collect(),
        }
    }

    /// A small MLP spec with separate BN/Sign layers (tests fusion).
    fn unfused_mlp(rng: &mut Rng) -> ModelSpec {
        ModelSpec {
            name: "tiny-mlp".into(),
            input_shape: Shape::vector(64),
            input_kind: InputKind::Bytes,
            layers: vec![
                LayerSpec::Dense {
                    in_features: 64,
                    out_features: 96,
                    sign: false,
                    bitplane_first: true,
                    repr: OutRepr::Sign,
                    act_delta: 1.0,
                    alpha: None,
                    weights: rng.signs(64 * 96).into(),
                    bn: None,
                },
                LayerSpec::BatchNorm(sample_bn(rng, 96)),
                LayerSpec::Sign,
                LayerSpec::Dense {
                    in_features: 96,
                    out_features: 10,
                    sign: false,
                    bitplane_first: false,
                    repr: OutRepr::Sign,
                    act_delta: 1.0,
                    alpha: None,
                    weights: rng.signs(960).into(),
                    bn: None,
                },
                LayerSpec::BatchNorm(sample_bn(rng, 10)),
            ],
        }
    }

    #[test]
    fn fusion_collapses_bn_sign() {
        let mut rng = Rng::new(131);
        let spec = unfused_mlp(&mut rng);
        let fused = fuse_spec(&spec.layers).unwrap();
        assert_eq!(fused.len(), 2, "{fused:?}");
        match &fused[0] {
            LayerSpec::Dense { bn, sign, .. } => {
                assert!(bn.is_some());
                assert!(*sign);
            }
            other => panic!("unexpected {other:?}"),
        }
        match &fused[1] {
            LayerSpec::Dense { bn, sign, .. } => {
                assert!(bn.is_some());
                assert!(!*sign, "output layer keeps scores");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn float_and_binary_networks_agree() {
        let mut rng = Rng::new(132);
        let spec = unfused_mlp(&mut rng);
        let net_f = Network::<u64>::from_spec(&spec, Backend::Float).unwrap();
        let net_b = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        for _ in 0..10 {
            let img: Vec<u8> = (0..64).map(|_| rng.next_u32() as u8).collect();
            let t = Tensor::from_vec(Shape::vector(64), img);
            let sf = net_f.predict_bytes(&t);
            let sb = net_b.predict_bytes(&t);
            assert_eq!(sf.len(), 10);
            for (a, b) in sf.iter().zip(&sb) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
            assert_eq!(argmax(&sf), argmax(&sb));
        }
    }

    #[test]
    fn hybrid_backends_agree_with_uniform() {
        let mut rng = Rng::new(133);
        let spec = unfused_mlp(&mut rng);
        let mut net = Network::<u64>::from_spec(&spec, Backend::Float).unwrap();
        let img: Vec<u8> = (0..64).map(|_| rng.next_u32() as u8).collect();
        let t = Tensor::from_vec(Shape::vector(64), img);
        let uniform = net.predict_bytes(&t);
        net.set_backends(&[Backend::Binary, Backend::Float]);
        let hybrid = net.predict_bytes(&t);
        for (a, b) in uniform.iter().zip(&hybrid) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn memory_report_totals() {
        let mut rng = Rng::new(134);
        let spec = unfused_mlp(&mut rng);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let report = net.memory_report();
        assert_eq!(report.rows.len(), 2);
        assert!(report.total_float() > report.total_packed());
        assert!(report.saving() > 10.0, "saving {}", report.saving());
        assert!(report.render().contains("TOTAL"));
    }

    #[test]
    fn output_shape_is_propagated() {
        let mut rng = Rng::new(135);
        let spec = unfused_mlp(&mut rng);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        assert_eq!(net.output_shape, Shape { m: 1, n: 10, l: 1 });
        assert_eq!(net.layer_count(), 2);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }
}
