//! # Espresso-RS
//!
//! A Rust + JAX/Pallas reproduction of *"Espresso: Efficient Forward
//! Propagation for Binary Deep Neural Networks"* (Pedersoli, Tzanetakis,
//! Tagliasacchi, 2017).
//!
//! Binary networks constrain weights and activations to {-1, +1}; Espresso
//! bit-packs them into machine words so a 64-element dot product becomes a
//! single XOR + popcount, pre-packs parameters at load time, lays tensors
//! out channel-interleaved so convolution unrolling is free, and serves
//! forward passes through a native engine, a PJRT/XLA engine, and
//! faithfully re-implemented baselines.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for measured results vs the paper.
//!
//! ## Layout
//! - [`bitpack`] — packed-word primitives: sign/pack, XOR-popcount dot,
//!   blocked binary GEMM/GEMV, bit-plane decomposition.
//! - [`linalg`] — float blocked GEMM/GEMV + im2col (the float comparator).
//! - [`tensor`] — row-major channel-interleaved tensors, packed variants.
//! - [`alloc`] — pool/arena allocator for hot-path buffers.
//! - [`layers`] — Input/Dense/Conv/Pool/BatchNorm/Sign, float & binary.
//! - [`net`] — sequential network, hybrid backends, memory reports.
//! - [`format`] — `.esp` parameter-file format.
//! - [`data`] — synthetic MNIST/CIFAR generators + IDX loader.
//! - [`baseline`] — BinaryNet-style and neon-like reference engines.
//! - [`runtime`] — PJRT client wrapper for AOT-compiled XLA artifacts.
//! - [`coordinator`] — request router, dynamic batcher, metrics.
//! - [`util`] — substrates: RNG, threadpool, bench harness, CLI, prop-test.

pub mod alloc;
pub mod baseline;
pub mod bitpack;
pub mod coordinator;
pub mod data;
pub mod format;
pub mod layers;
pub mod linalg;
pub mod net;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Crate version string (used by the CLI and the `.esp` format header).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
