//! # Espresso-RS
//!
//! A Rust + JAX/Pallas reproduction of *"Espresso: Efficient Forward
//! Propagation for Binary Deep Neural Networks"* (Pedersoli, Tzanetakis,
//! Tagliasacchi, 2017).
//!
//! Binary networks constrain weights and activations to {-1, +1}; Espresso
//! bit-packs them into machine words so a 64-element dot product becomes a
//! single XOR + popcount, pre-packs parameters at load time, lays tensors
//! out channel-interleaved so convolution unrolling is free, and serves
//! forward passes through a native engine, a PJRT/XLA engine, and
//! faithfully re-implemented baselines.
//!
//! **Batch axis.** Every activation ([`tensor::Tensor`],
//! [`tensor::BitTensor`], [`layers::Act`]) carries a `batch` count of
//! stacked images alongside its per-image `Shape`; images occupy
//! contiguous blocks of `data`. The whole native CNN forward path is
//! batch-native: a batch of B images unrolls into one `(B·oh·ow) × k`
//! patch matrix and runs ONE binary GEMM per conv layer against the
//! shared packed filters (pooling, zero-padding correction and folded-BN
//! thresholds operate on per-image blocks), and dense layers fold the
//! batch into their packed-rows convention. Batched output is
//! bit-identical to per-image forwards — locked in by the
//! `batch_equivalence` property suite — so the coordinator's dynamic
//! batcher is a pure throughput win. See `DESIGN.md` § "Batch-axis
//! layout" for the exact memory layout and which layers consume/produce
//! batched activations.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for measured results vs the paper.
//!
//! ## Layout
//! - [`bitpack`] — packed-word primitives: sign/pack, XOR-popcount dot,
//!   blocked binary GEMM/GEMV, bit-plane decomposition.
//! - [`linalg`] — float blocked GEMM/GEMV + im2col (the float comparator).
//! - [`tensor`] — row-major channel-interleaved tensors with a batch
//!   axis, packed variants, batched unrolling.
//! - [`alloc`] — pool/arena allocator for hot-path buffers (capped
//!   freelists, plan-time reservations).
//! - [`layers`] — Input/Dense/Conv/Pool/BatchNorm/Sign, float & binary,
//!   all batch-native, with plan-time hooks (out-kind, scratch, GEMM
//!   dims, borrowed-input forward).
//! - [`net`] — sequential network compiled into an ahead-of-time
//!   [`net::plan::ForwardPlan`] (slot-resolved representations, hybrid
//!   backend auto-placement, per-layer profiling), batched prediction,
//!   memory reports.
//! - [`format`] — `.esp` parameter-file format + random spec sampler
//!   ([`format::sample`]) for property tests.
//! - [`data`] — synthetic MNIST/CIFAR generators + IDX loader.
//! - [`baseline`] — BinaryNet-style and neon-like reference engines.
//! - [`runtime`] — PJRT client wrapper for AOT-compiled XLA artifacts,
//!   plus the native engine adapter with true batched `predict_batch`.
//! - [`coordinator`] — request router, dynamic batcher (one batched
//!   forward per drained queue, not a per-image loop) with bounded
//!   admission queues, pipelined TCP front end (wire-level batch op,
//!   in-order reply writer), metrics keyed by registered model name.
//! - [`util`] — substrates: RNG, threadpool, bench harness, CLI, prop-test.

pub mod alloc;
pub mod baseline;
pub mod bitpack;
pub mod coordinator;
pub mod data;
pub mod format;
pub mod layers;
pub mod linalg;
pub mod net;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Crate version string (used by the CLI and the `.esp` format header).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
