//! `espresso` CLI — leader entrypoint.
//!
//! ```text
//! espresso gen <bmlp|bcnn> --out model.esp [--hidden N] [--layers N] [--width F]
//! espresso inspect <model.esp>
//! espresso mem <model.esp>
//! espresso predict <model.esp> [--backend opt|float|auto|binarynet|neon] [--data set.espdata] [--count N]
//! espresso profile <model.esp> [--backend opt|float|auto] [--batch N] [--iters N]
//! espresso serve --model <model.esp> --addr 127.0.0.1:7878 [--placement auto|uniform] [--xla ARTIFACT]
//!                [--queue-depth N] [--max-conns N] [--replicas N] [--acceptor reuseport|single]
//!                [--request-timeout-ms MS]
//! espresso client --addr 127.0.0.1:7878 --model NAME [--count N] [--batch N] [--load PATH]
//!                 [--timeout-ms MS] [--retries N] [--deadline-ms MS] [--health] [--drain]
//! ```

use anyhow::{bail, Context, Result};
use espresso::coordinator::{tcp, BatchConfig, Coordinator};
use espresso::data;
use espresso::format::ModelSpec;
use espresso::layers::Backend;
use espresso::net::{argmax, bcnn_spec, bmlp_spec, Network};
use espresso::runtime::{self, Engine, NativeEngine, XlaEngine, XlaModelKind};
use espresso::tensor::Shape;
use espresso::util::cli::Args;
use espresso::util::rng::Rng;
use espresso::util::Timer;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const FLAGS: &[&str] = &["help", "verbose", "health", "drain"];

/// Set by the SIGTERM/SIGINT handler; the serve loop polls it and runs a
/// graceful drain (stop admission, flush queues, reply to everything in
/// flight) before exiting.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

extern "C" fn on_shutdown_signal(_sig: std::os::raw::c_int) {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Install the drain handler for SIGTERM and SIGINT. Raw `signal(2)` via
/// an `extern` declaration — the offline build has no libc crate, same
/// pattern as the epoll bindings in the event front end.
fn install_shutdown_signals() {
    extern "C" {
        fn signal(signum: std::os::raw::c_int, handler: usize) -> usize;
    }
    const SIGINT: std::os::raw::c_int = 2;
    const SIGTERM: std::os::raw::c_int = 15;
    unsafe {
        signal(SIGTERM, on_shutdown_signal as usize);
        signal(SIGINT, on_shutdown_signal as usize);
    }
}

fn main() {
    let args = Args::parse_env(FLAGS);
    let cmd = args.positional(0).unwrap_or("help").to_string();
    let result = match cmd.as_str() {
        "gen" => cmd_gen(&args),
        "inspect" => cmd_inspect(&args),
        "mem" => cmd_mem(&args),
        "predict" => cmd_predict(&args),
        "profile" => cmd_profile(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(anyhow::anyhow!("unknown command {other:?}"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "espresso {} — binary DNN forward propagation (Espresso reproduction)\n\n\
         commands:\n\
         \u{20}  gen <bmlp|bcnn> --out model.esp [--hidden N] [--layers N] [--width F] [--seed S]\n\
         \u{20}  inspect <model.esp>\n\
         \u{20}  mem <model.esp>                      memory report (float vs packed)\n\
         \u{20}  predict <model.esp> [--backend opt|float|auto|binarynet|neon] [--data set.espdata] [--count N]\n\
         \u{20}  profile <model.esp> [--backend opt|float|auto] [--batch N] [--iters N]   per-layer plan profile\n\
         \u{20}  serve --model <model.esp> [--addr 127.0.0.1:7878] [--name NAME] [--max-batch N] [--max-wait-us U]\n\
         \u{20}        [--queue-depth N] [--max-conns N] [--io-loops N] [--replicas N]\n\
         \u{20}        [--acceptor reuseport|single] [--placement auto|uniform] [--xla ARTIFACT]\n\
         \u{20}        [--request-timeout-ms MS]   shed requests still queued after MS (status: deadline exceeded)\n\
         \u{20}        (--replicas N runs N engine replicas behind least-loaded dispatch;\n\
         \u{20}         default min(cores/2, 4). SIGTERM/ctrl-c drains gracefully before exit.)\n\
         \u{20}  client --addr ADDR --model NAME [--count N] [--batch N]    (--batch > 1 sends predict_batch frames)\n\
         \u{20}  client --addr ADDR --model NAME --load /server/path.esp    hot-swap the model (OP_LOAD_MODEL)\n\
         \u{20}  client --addr ADDR [--timeout-ms MS] [--retries N]         connect/read timeout + bounded retry\n\
         \u{20}  client --addr ADDR [--deadline-ms MS]                      per-request deadline on predict frames\n\
         \u{20}  client --addr ADDR --health                                per-model replica liveness (OP_HEALTH)\n\
         \u{20}  client --addr ADDR --drain                                 graceful server drain (OP_DRAIN)",
        espresso::VERSION
    );
}

fn cmd_gen(args: &Args) -> Result<()> {
    let kind = args.positional(1).context("gen: need bmlp|bcnn")?;
    let out = args.get("out").context("gen: need --out path")?;
    let seed = args.get_parse_or("seed", 42u64);
    let mut rng = Rng::new(seed);
    let spec = match kind {
        "bmlp" => {
            let hidden = args.get_parse_or("hidden", 4096usize);
            let layers = args.get_parse_or("layers", 3usize);
            bmlp_spec(&mut rng, hidden, layers)
        }
        "bcnn" => {
            let width = args.get_parse_or("width", 1.0f32);
            bcnn_spec(&mut rng, width)
        }
        other => bail!("gen: unknown architecture {other:?}"),
    };
    spec.save(Path::new(out))?;
    println!("wrote {} ({})", out, spec.name);
    Ok(())
}

fn load_net(path: &str, backend: Backend) -> Result<Network<u64>> {
    let spec = ModelSpec::load(Path::new(path))?;
    Network::<u64>::from_spec(&spec, backend)
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args.positional(1).context("inspect: need model path")?;
    let spec = ModelSpec::load(Path::new(path))?;
    println!("model    {}", spec.name);
    println!("input    {} ({:?})", spec.input_shape, spec.input_kind);
    let net = Network::<u64>::from_spec(&spec, Backend::Binary)?;
    println!("output   {}", net.output_shape);
    println!("layers   ({} after fusion):", net.layer_count());
    for (i, d) in net.describe().iter().enumerate() {
        println!("  [{i}] {d}");
    }
    Ok(())
}

fn cmd_mem(args: &Args) -> Result<()> {
    let path = args.positional(1).context("mem: need model path")?;
    let net = load_net(path, Backend::Binary)?;
    print!("{}", net.memory_report().render());
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let path = args.positional(1).context("predict: need model path")?;
    let spec = ModelSpec::load(Path::new(path))?;
    let backend = args.get_or("backend", "opt");
    let count = args.get_parse_or("count", 16usize);
    let dataset = match args.get("data") {
        Some(p) => data::load_espdata(Path::new(p))?,
        None => data::synth(spec.input_shape, 10, count, 7),
    };
    anyhow::ensure!(
        dataset.shape.len() == spec.input_shape.len(),
        "dataset/model input size mismatch"
    );
    let engine: Box<dyn Engine> = match backend {
        "opt" => Box::new(NativeEngine::new(
            Network::<u64>::from_spec(&spec, Backend::Binary)?,
            "opt",
        )),
        "float" => Box::new(NativeEngine::new(
            Network::<u64>::from_spec(&spec, Backend::Float)?,
            "float",
        )),
        "auto" => {
            let mut net = Network::<u64>::from_spec(&spec, Backend::Binary)?;
            let placement = net.auto_place().to_vec();
            if args.flag("verbose") {
                println!("auto placement: {placement:?}");
            }
            Box::new(NativeEngine::new(net, "auto"))
        }
        "binarynet" => Box::new(espresso::baseline::BaselineEngine::from_spec(
            &spec,
            espresso::baseline::BaselineKind::BinaryNet,
        )?),
        "neon" => Box::new(espresso::baseline::BaselineEngine::from_spec(
            &spec,
            espresso::baseline::BaselineKind::NeonLike,
        )?),
        other => bail!("unknown backend {other:?}"),
    };
    let n = count.min(dataset.len());
    let mut correct = 0usize;
    let timer = Timer::start();
    for i in 0..n {
        let scores = engine.predict(&dataset.images[i])?;
        let pred = argmax(&scores);
        if pred == dataset.labels[i] {
            correct += 1;
        }
        if args.flag("verbose") {
            println!(
                "sample {i}: predicted {pred} (label {}), scores {scores:?}",
                dataset.labels[i]
            );
        }
    }
    let ms = timer.elapsed_ms();
    println!(
        "{backend}: {n} predictions in {ms:.2} ms ({:.3} ms/image), accuracy {correct}/{n} = {:.1}%",
        ms / n as f64,
        100.0 * correct as f64 / n as f64
    );
    Ok(())
}

/// Per-layer forward-plan profile: compiled plan table, timed per-step
/// breakdown over synthetic traffic, pool behaviour.
fn cmd_profile(args: &Args) -> Result<()> {
    let path = args.positional(1).context("profile: need model path")?;
    let backend = args.get_or("backend", "opt");
    let batch = args.get_parse_or("batch", 1usize).max(1);
    let iters = args.get_parse_or("iters", 20usize).max(1);
    let spec = ModelSpec::load(Path::new(path))?;
    let net = match backend {
        "opt" => Network::<u64>::from_spec(&spec, Backend::Binary)?,
        "float" => Network::<u64>::from_spec(&spec, Backend::Float)?,
        "auto" => {
            let mut n = Network::<u64>::from_spec(&spec, Backend::Binary)?;
            n.auto_place();
            n
        }
        other => bail!("profile: unknown backend {other:?} (opt|float|auto)"),
    };
    println!("model    {} ({} layers, backend {backend})", spec.name, net.layer_count());
    // pick micro-kernels before rendering so the plan's kernel column is
    // populated; with ESPRESSO_TUNE=off this records the static defaults
    net.tune();
    println!("\n== compiled plan ==");
    print!("{}", net.plan().render());
    let tuned = espresso::util::tune::summary();
    if !tuned.is_empty() {
        println!("\n== tune ==");
        print!("{}", espresso::util::tune::render_summary(&tuned));
    }
    let ds = data::synth(spec.input_shape, 10, batch, 11);
    let refs: Vec<&espresso::tensor::Tensor<u8>> = ds.images.iter().take(batch).collect();
    net.reserve(batch);
    // warm-up forward, then measure with clean counters
    let _ = net.predict_batch_bytes(&refs);
    net.reset_profile();
    let timer = Timer::start();
    for _ in 0..iters {
        let _ = net.predict_batch_bytes(&refs);
    }
    let ms = timer.elapsed_ms();
    println!("\n== per-layer profile ({iters} forwards, batch {batch}) ==");
    print!("{}", net.profile().render());
    println!("\n== per-step worker utilization ==");
    print!("{}", net.profile().render_workers());
    let ps = espresso::util::parallel::pool_status();
    println!(
        "scheduler: {} threads, {} pool workers parked, {} spawned total; \
         {} pool jobs, {} inline (below grain), {} inline (pool busy)",
        ps.threads, ps.workers_alive, ps.spawned, ps.jobs, ps.serial_jobs, ps.busy_jobs
    );
    let s = net.ws.stats_total();
    println!(
        "\npool: {} hits ({} worker-warm), {} misses, {} evicted, {} free buffers ({} elems parked, peak {})",
        s.hits, s.affine_hits, s.misses, s.evicted, s.free_buffers, s.free_elems, s.peak_free_elems
    );
    let report = net.scratch_report(batch);
    let peak_fused = report.iter().map(|r| r.1).max().unwrap_or(0);
    let peak_mat = report.iter().map(|r| r.2).max().unwrap_or(0);
    println!(
        "scratch peak @ batch {batch}: fused {} vs materialized {} ({:.1}x smaller)",
        espresso::util::stats::fmt_bytes(peak_fused),
        espresso::util::stats::fmt_bytes(peak_mat),
        peak_mat as f64 / peak_fused.max(1) as f64
    );
    println!("wall: {ms:.2} ms total, {:.3} ms/forward", ms / iters as f64);
    Ok(())
}

/// Default replica count for `serve`: half the cores (each replica's
/// forward pass is itself parallel), capped at 4, at least 1.
fn default_replicas() -> usize {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    (cores / 2).clamp(1, 4)
}

/// Build the replica engine set for the primary model from an `.esp`
/// path. Loads the spec ONCE (mmap-backed: replicas read the same
/// borrowed mapping) and compiles one hybrid-placed network per replica.
/// Doubles as the hot-swap loader for `OP_LOAD_MODEL`.
fn build_replicas(
    path: &Path,
    placement_auto: bool,
    max_batch: usize,
    replicas: usize,
) -> Result<Vec<Arc<dyn Engine>>> {
    let spec = ModelSpec::load(path)?;
    let mut engines: Vec<Arc<dyn Engine>> = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        let mut net = Network::<u64>::from_spec(&spec, Backend::Binary)?;
        if placement_auto {
            net.auto_place();
        }
        // pre-size the scratch pools for the batcher's configured
        // maximum, not just B=1: the first dynamically-batched forward
        // then draws every buffer from the freelists instead of paying
        // pool misses mid-request, and idle trims restore this same
        // working set
        engines.push(Arc::new(NativeEngine::new(net, "opt").reserved(max_batch)));
    }
    Ok(engines)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model_path = args.get("model").context("serve: need --model path")?.to_string();
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let name = args.get_or("name", "default").to_string();
    let max_batch = args.get_parse_or("max-batch", 8usize);
    let replicas = args.get_parse_or("replicas", default_replicas()).max(1);
    let spec = ModelSpec::load(Path::new(&model_path))?;
    let coord = Arc::new(Coordinator::new(BatchConfig {
        max_batch,
        max_wait: std::time::Duration::from_micros(args.get_parse_or("max-wait-us", 500u64)),
        // per-model admission bound: saturate → reject with the distinct
        // `overloaded` status. With replicas this still bounds the MODEL
        // (shared budget), not each replica
        queue_depth: args.get_parse_or("queue-depth", 1024usize).max(1),
        // 0 = no server-side deadline; queued requests then wait as long
        // as the queue does
        request_timeout: match args.get_parse_or("request-timeout-ms", 0u64) {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms)),
        },
    }));
    // the primary engine is hybrid-placed by the plan cost model (the
    // paper's hybrid-DNN feature as the serving default); --placement
    // uniform restores all-binary
    let placement_auto = match args.get_or("placement", "auto") {
        "auto" => true,
        "uniform" => false,
        other => bail!("serve: unknown placement {other:?} (auto|uniform)"),
    };
    // primary model: N replicas behind least-loaded dispatch, rebuildable
    // from any .esp path at runtime via the wire op (client --load PATH)
    let engines = build_replicas(Path::new(&model_path), placement_auto, max_batch, replicas)?;
    let loader: espresso::coordinator::EngineLoader = Arc::new(move |p: &Path| {
        build_replicas(p, placement_auto, max_batch, replicas)
    });
    coord.register_with_loader(&name, engines, loader);
    // the float reference stays a single replica (debug/accuracy checks,
    // not a throughput path)
    let float = Network::<u64>::from_spec(&spec, Backend::Float)?;
    coord.register(
        &format!("{name}.float"),
        Arc::new(NativeEngine::new(float, "float").reserved(max_batch)),
    );
    if let Some(artifact) = args.get("xla") {
        let dir = runtime::default_artifact_dir();
        let kind = if artifact.contains("binary") {
            XlaModelKind::MlpBinary
        } else if artifact.contains("cnn") {
            XlaModelKind::CnnFloat
        } else {
            XlaModelKind::MlpFloat
        };
        let engine = XlaEngine::load(&dir, artifact, &spec, kind)?;
        coord.register(&format!("{name}.xla"), Arc::new(engine));
        println!("registered XLA engine {name}.xla ({artifact})");
    }
    // the event front end is the only one; the retired "threads" value
    // is rejected by the FromStr impl with a pointer to the replacement
    let io_model: tcp::IoModel = match args.get("io-model") {
        Some(s) => s.parse()?,
        None => tcp::IoModel::default(),
    };
    let acceptor: tcp::Acceptor = match args.get("acceptor") {
        Some(s) => s.parse()?,
        None => tcp::Acceptor::default(),
    };
    let opts = tcp::ServeOptions {
        max_conns: args.get_parse_or("max-conns", 256usize).max(1),
        io_model,
        // 0 = one loop per available core
        io_loops: args.get_parse_or("io-loops", 0usize),
        acceptor,
    };
    let mut server = tcp::serve(coord.clone(), addr, opts)?;
    install_shutdown_signals();
    println!(
        "serving {} (models: {}) on {} — {} loops ({:?} acceptor), {} replicas of {:?}, \
         SIGTERM/ctrl-c drains gracefully",
        spec.name,
        coord.models().join(", "),
        server.addr(),
        opts.effective_io_loops(),
        opts.acceptor,
        replicas,
        name,
    );
    let mut last_requests = 0u64;
    let mut ticks = 0u64;
    loop {
        // short ticks so a shutdown signal is noticed promptly; the
        // stats/housekeeping cadence stays at ~10 s
        std::thread::sleep(std::time::Duration::from_millis(200));
        if SHUTDOWN.load(Ordering::SeqCst) {
            println!("shutdown signal: draining (in-flight work gets replies, new work is refused)");
            server.begin_drain();
            if !server.wait_idle(std::time::Duration::from_secs(30)) {
                eprintln!("drain incomplete after 30 s; forcing shutdown");
            }
            server.shutdown();
            print!("{}", coord.metrics.render());
            return Ok(());
        }
        ticks += 1;
        if ticks % 50 != 0 {
            continue;
        }
        coord.refresh_plan_profiles();
        print!("{}", coord.metrics.render());
        print!("{}", coord.metrics.render_plan_profiles());
        // idle housekeeping: no traffic since the last tick — release
        // parked scratch so past batch bursts stop pinning peak memory.
        // Never before the first request: that would drop the startup
        // --max-batch reservation the first batch relies on.
        let total = coord.metrics.total_requests();
        if total > 0 && total == last_requests {
            let freed = coord.trim_pools();
            if freed > 0 {
                println!("idle: trimmed {freed} parked scratch buffers");
            }
        }
        last_requests = total;
    }
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let model = args.get_or("model", "default");
    let count = args.get_parse_or("count", 100usize);
    // one wire frame carries at most MAX_BATCH_ITEMS images
    let batch = args
        .get_parse_or("batch", 1usize)
        .clamp(1, tcp::MAX_BATCH_ITEMS);
    // connect/read timeouts plus bounded retry with jittered backoff, so
    // a dead or restarting server fails the CLI fast instead of hanging
    let client_opts = tcp::ClientOptions {
        timeout: match args.get_parse_or("timeout-ms", 0u64) {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms)),
        },
        retries: args.get_parse_or("retries", 0u32),
    };
    let deadline_ms = match args.get("deadline-ms") {
        Some(s) => Some(s.parse::<u32>().context("client: bad --deadline-ms")?),
        None => None,
    };
    let mut client = tcp::Client::connect_with(addr, client_opts)?;
    client.ping()?;
    // --health: print per-model replica liveness and queue depth, exit
    if args.flag("health") {
        print!("{}", client.health()?);
        return Ok(());
    }
    // --drain: ask the server to drain gracefully and exit
    if args.flag("drain") {
        client.drain()?;
        println!("server acknowledged drain");
        return Ok(());
    }
    // --load PATH: hot-swap the model from a server-side .esp and exit
    if let Some(path) = args.get("load") {
        let version = client.load_model(model, path)?;
        println!("hot-swapped {model} to version {version} from {path}");
        return Ok(());
    }
    println!("models: {:?}", client.models()?);
    let ds = match args.get("data") {
        Some(p) => data::load_espdata(Path::new(p))?,
        None => data::synth(Shape::vector(784), 10, count, 3),
    };
    let count = count.min(ds.len());
    let timer = Timer::start();
    let mut correct = 0usize;
    let mut overloaded = 0usize;
    let mut deadline_exceeded = 0usize;
    let mut errors = 0usize;
    if batch > 1 {
        // one predict_batch frame per chunk: the server-side batcher sees
        // the whole vector at once (GEMM-level batching from one socket)
        for chunk in 0..count.div_ceil(batch) {
            let lo = chunk * batch;
            let hi = (lo + batch).min(count);
            let imgs: Vec<&[u8]> = ds.images[lo..hi].iter().map(|i| i.data.as_slice()).collect();
            for (reply, &label) in client
                .predict_batch_deadline(model, &imgs, deadline_ms)?
                .into_iter()
                .zip(&ds.labels[lo..hi])
            {
                match reply {
                    tcp::Reply::Scores(scores) if argmax(&scores) == label => correct += 1,
                    tcp::Reply::Scores(_) => {}
                    tcp::Reply::Overloaded => overloaded += 1,
                    tcp::Reply::DeadlineExceeded => deadline_exceeded += 1,
                    tcp::Reply::Err(_) => errors += 1,
                }
            }
        }
    } else {
        for (img, &label) in ds.images.iter().zip(&ds.labels).take(count) {
            match client.try_predict_deadline(model, &img.data, deadline_ms)? {
                tcp::Reply::Scores(scores) if argmax(&scores) == label => correct += 1,
                tcp::Reply::Scores(_) => {}
                tcp::Reply::Overloaded => overloaded += 1,
                tcp::Reply::DeadlineExceeded => deadline_exceeded += 1,
                tcp::Reply::Err(_) => errors += 1,
            }
        }
    }
    let ms = timer.elapsed_ms();
    println!(
        "{count} requests (batch {batch}) in {ms:.1} ms ({:.3} ms/req), accuracy {:.1}%, \
         {overloaded} overloaded, {deadline_exceeded} deadline exceeded, {errors} errors",
        ms / count as f64,
        100.0 * correct as f64 / count as f64
    );
    println!("{}", client.stats()?);
    Ok(())
}
