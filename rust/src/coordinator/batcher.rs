//! Dynamic batching (the serving-layer contribution around the paper's
//! engines).
//!
//! The paper measures batch-1 latency; a serving deployment additionally
//! wants throughput under load. The batcher collects queued requests per
//! model up to `max_batch` or `max_wait`, then executes them as one
//! batched forward (the native MLP engine runs a real batched GEMM —
//! requests share the weight-panel sweep), trading a bounded queueing
//! delay for much higher throughput. `max_batch = 1` degrades to pure
//! FIFO dispatch, which is the paper's measurement mode.

use super::metrics::Metrics;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use anyhow::Result;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_micros(500),
        }
    }
}

/// One queued prediction request.
pub struct Request {
    pub img: Tensor<u8>,
    pub enqueued: Instant,
    pub reply: Sender<Result<Vec<f32>>>,
}

/// Handle for submitting requests to a model's batcher thread.
pub struct Batcher {
    tx: Sender<Request>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Spawn a batching loop in front of `engine`.
    pub fn spawn(engine: Arc<dyn Engine>, cfg: BatchConfig, metrics: Arc<Metrics>) -> Self {
        let (tx, rx) = channel::<Request>();
        let join = std::thread::Builder::new()
            .name(format!("batcher-{}", engine.name()))
            .spawn(move || batch_loop(engine, cfg, metrics, rx))
            .expect("spawn batcher");
        Self {
            tx,
            join: Some(join),
        }
    }

    /// Enqueue a request; returns the reply channel receiver.
    pub fn submit(&self, img: Tensor<u8>) -> Receiver<Result<Vec<f32>>> {
        let (reply, rx) = channel();
        let _ = self.tx.send(Request {
            img,
            enqueued: Instant::now(),
            reply,
        });
        rx
    }

    /// Submit and wait.
    pub fn predict(&self, img: Tensor<u8>) -> Result<Vec<f32>> {
        self.submit(img)
            .recv()
            .map_err(|_| anyhow::anyhow!("batcher shut down"))?
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // closing the sender ends the loop
        let (dead_tx, _) = channel();
        self.tx = dead_tx;
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn batch_loop(
    engine: Arc<dyn Engine>,
    cfg: BatchConfig,
    metrics: Arc<Metrics>,
    rx: Receiver<Request>,
) {
    let name = engine.name();
    loop {
        // block for the first request
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        metrics.record_batch(&name, batch.len());
        let started = Instant::now();
        let imgs: Vec<&Tensor<u8>> = batch.iter().map(|r| &r.img).collect();
        let results = engine.predict_batch(&imgs);
        let elapsed = started.elapsed().as_nanos() as u64;
        for (req, result) in batch.into_iter().zip(results) {
            let queue_ns = (started - req.enqueued).as_nanos() as u64;
            metrics.record_request(&name, elapsed + queue_ns, queue_ns, result.is_ok());
            let _ = req.reply.send(result);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Shape;

    /// Engine that records the batch sizes it sees.
    struct Probe {
        sizes: std::sync::Mutex<Vec<usize>>,
        delay: Duration,
    }

    impl Engine for Probe {
        fn name(&self) -> String {
            "probe".into()
        }

        fn input_shape(&self) -> Shape {
            Shape::vector(4)
        }

        fn predict(&self, img: &Tensor<u8>) -> Result<Vec<f32>> {
            Ok(vec![img.data[0] as f32])
        }

        fn predict_batch(&self, imgs: &[&Tensor<u8>]) -> Vec<Result<Vec<f32>>> {
            self.sizes.lock().unwrap().push(imgs.len());
            std::thread::sleep(self.delay);
            imgs.iter().map(|i| self.predict(i)).collect()
        }
    }

    fn img(v: u8) -> Tensor<u8> {
        Tensor::from_vec(Shape::vector(4), vec![v, 0, 0, 0])
    }

    #[test]
    fn responses_match_requests() {
        let engine = Arc::new(Probe {
            sizes: Default::default(),
            delay: Duration::ZERO,
        });
        let b = Batcher::spawn(engine, BatchConfig::default(), Arc::new(Metrics::new()));
        let handles: Vec<_> = (0..20).map(|i| (i, b.submit(img(i as u8)))).collect();
        for (i, h) in handles {
            let scores = h.recv().unwrap().unwrap();
            assert_eq!(scores[0], i as f32);
        }
    }

    #[test]
    fn batches_form_under_load() {
        let engine = Arc::new(Probe {
            sizes: Default::default(),
            delay: Duration::from_millis(2),
        });
        let cfg = BatchConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        };
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::spawn(engine.clone(), cfg, metrics.clone());
        // flood: while the first batch executes, the rest queue up
        let handles: Vec<_> = (0..32).map(|i| b.submit(img(i as u8))).collect();
        for h in handles {
            h.recv().unwrap().unwrap();
        }
        let sizes = engine.sizes.lock().unwrap().clone();
        assert!(
            sizes.iter().any(|&s| s > 1),
            "expected some multi-request batches, got {sizes:?}"
        );
        let snap = metrics.snapshot("probe").unwrap();
        assert_eq!(snap.requests, 32);
    }

    #[test]
    fn max_batch_one_is_fifo() {
        let engine = Arc::new(Probe {
            sizes: Default::default(),
            delay: Duration::from_micros(100),
        });
        let cfg = BatchConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(5),
        };
        let b = Batcher::spawn(engine.clone(), cfg, Arc::new(Metrics::new()));
        let handles: Vec<_> = (0..10).map(|i| b.submit(img(i))).collect();
        for h in handles {
            h.recv().unwrap().unwrap();
        }
        assert!(engine.sizes.lock().unwrap().iter().all(|&s| s == 1));
    }
}
