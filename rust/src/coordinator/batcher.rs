//! Dynamic batching (the serving-layer contribution around the paper's
//! engines).
//!
//! The paper measures batch-1 latency; a serving deployment additionally
//! wants throughput under load. The batcher collects queued requests per
//! model up to `max_batch` or `max_wait`, then executes them as one
//! batched forward (the native engines run a real batched GEMM —
//! requests share the weight-panel sweep), trading a bounded queueing
//! delay for much higher throughput. `max_batch = 1` degrades to pure
//! FIFO dispatch, which is the paper's measurement mode.
//!
//! Admission control: in-flight requests (queued **or** executing, i.e.
//! admitted but not yet replied) are bounded by
//! `BatchConfig::queue_depth`.
//! When the bound is hit, [`Batcher::submit`]/[`Batcher::submit_many`]
//! reject *immediately* with [`Submission::Overloaded`] instead of
//! enqueueing — memory stays bounded under overload and the client learns
//! within `max_wait` rather than timing out. Rejections and the queue
//! high-water mark are recorded in [`Metrics`] under the **registered
//! model name** (not `Engine::name()` — two models may share an engine
//! label, and the stats table must show one row per model).

use super::metrics::Metrics;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use anyhow::Result;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Consecutive panicking batches after which a replica declares itself
/// poisoned: something is systematically wrong with this engine instance
/// (not one bad input), so the replica fails fast on every request until
/// the registry supervisor rebuilds it.
pub(crate) const POISON_AFTER: u32 = 3;

/// Typed marker for a request shed because its deadline passed before
/// execution. The wire layer downcasts (`anyhow` searches the context
/// chain) to map it onto the dedicated `deadline_exceeded` status byte
/// instead of a generic err frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeadlineExceeded;

impl std::fmt::Display for DeadlineExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "deadline exceeded before execution")
    }
}

impl std::error::Error for DeadlineExceeded {}

/// Batching + admission policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Maximum in-flight requests per model — admitted but not yet
    /// replied, i.e. waiting in the queue OR executing in a batch.
    /// Submissions beyond it are rejected as [`Submission::Overloaded`].
    /// Counting execution too makes the bound an actual memory/latency
    /// cap (a slot does not free the instant a request pops into a
    /// batch, only when its reply is on its way). `usize::MAX` disables
    /// the bound.
    pub queue_depth: usize,
    /// Server-side deadline stamped at admission: a request still queued
    /// when `now > enqueued + request_timeout` is shed with
    /// [`DeadlineExceeded`] instead of executing — one wedged batch must
    /// not make every queued request wait out the stall behind it.
    /// `None` disables server-side stamping (clients can still send a
    /// per-request deadline on the wire).
    pub request_timeout: Option<Duration>,
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_micros(500),
            queue_depth: 1024,
            request_timeout: None,
        }
    }
}

/// Completion receiver for channel-free submission: the batch loop calls
/// [`CompletionSink::complete`] directly from its own thread instead of
/// pushing through an mpsc channel that some other thread must block on.
/// This is what lets the event-driven TCP front end keep its thread count
/// at "IO loops + batchers" — replies land in the loop's completion queue
/// and wake its epoll via eventfd, no parked reader per request.
pub trait CompletionSink: Send + Sync {
    fn complete(&self, ticket: u64, result: Result<Vec<f32>>);
}

/// Where a request's result goes: a blocking mpsc channel (threaded
/// serving path, direct `predict` calls) or a [`CompletionSink`] ticket
/// (event-driven path).
pub enum ReplyTo {
    Channel(Sender<Result<Vec<f32>>>),
    Sink {
        sink: Arc<dyn CompletionSink>,
        ticket: u64,
    },
}

impl ReplyTo {
    fn send(self, result: Result<Vec<f32>>) {
        match self {
            // a dropped receiver just means the client went away
            ReplyTo::Channel(tx) => drop(tx.send(result)),
            ReplyTo::Sink { sink, ticket } => sink.complete(ticket, result),
        }
    }
}

/// One queued prediction request.
pub struct Request {
    pub img: Tensor<u8>,
    pub enqueued: Instant,
    /// Absolute shed point: the earlier of the client's wire deadline and
    /// the server's `request_timeout`, both stamped at admission.
    pub deadline: Option<Instant>,
    pub reply: ReplyTo,
}

/// Outcome of enqueueing a request under admission control.
pub enum Submission {
    /// Admitted; the receiver yields the prediction result.
    Queued(Receiver<Result<Vec<f32>>>),
    /// Rejected without enqueueing: the model's queue is at
    /// `queue_depth`. Surfaced on the wire as the `overloaded` status.
    Overloaded,
}

impl Submission {
    pub fn is_overloaded(&self) -> bool {
        matches!(self, Submission::Overloaded)
    }

    /// Block for the result; `Overloaded` becomes an error mentioning
    /// "overloaded" (the TCP layer instead maps it to its own status
    /// byte before this flattening loses the distinction).
    pub fn wait(self) -> Result<Vec<f32>> {
        match self {
            Submission::Queued(rx) => rx
                .recv()
                .map_err(|_| anyhow::anyhow!("batcher shut down"))?,
            Submission::Overloaded => Err(anyhow::anyhow!("overloaded: request queue full")),
        }
    }
}

/// Handle for submitting requests to one replica's batcher thread.
///
/// A replicated model spawns N of these over N engine instances; they
/// share one admission `budget` (so `queue_depth` bounds the model, not
/// each replica) while each keeps its own `inflight` scoreboard for the
/// registry's least-loaded dispatch.
pub struct Batcher {
    tx: Sender<Request>,
    /// Model-wide admission budget: requests admitted but not yet
    /// replied (queued + executing) across ALL replicas of the model.
    budget: Arc<AtomicUsize>,
    /// This replica's share of the in-flight count — the least-loaded
    /// dispatch scoreboard.
    inflight: Arc<AtomicUsize>,
    /// Replica index within the model (0 for unreplicated models).
    replica: usize,
    engine: Arc<dyn Engine>,
    model: String,
    cfg: BatchConfig,
    metrics: Arc<Metrics>,
    /// Set by the batch loop after [`POISON_AFTER`] consecutive panicking
    /// batches: the replica keeps its thread (so no queued request is
    /// ever stranded mid-channel) but fails everything fast until the
    /// supervisor swaps in a rebuilt replica.
    poisoned: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Spawn a batching loop in front of `engine`, recording all metrics
    /// under `model` (the registered name clients address). Single
    /// replica: the admission budget is private.
    pub fn spawn(
        model: &str,
        engine: Arc<dyn Engine>,
        cfg: BatchConfig,
        metrics: Arc<Metrics>,
    ) -> Self {
        Self::spawn_replica(model, engine, cfg, metrics, Arc::new(AtomicUsize::new(0)), 0)
    }

    /// Spawn replica `replica` of a model, drawing admission slots from
    /// the shared `budget` (one `Arc` across all replicas keeps
    /// `--queue-depth` a per-model bound).
    pub fn spawn_replica(
        model: &str,
        engine: Arc<dyn Engine>,
        cfg: BatchConfig,
        metrics: Arc<Metrics>,
        budget: Arc<AtomicUsize>,
        replica: usize,
    ) -> Self {
        // model registration is the serving warm-up point: make sure the
        // kernel worker pool is already parked before traffic arrives,
        // and let the engine autotune its kernels before the first request
        crate::util::parallel::ensure_started(crate::util::parallel::num_threads());
        engine.warm();
        let (tx, rx) = channel::<Request>();
        let inflight = Arc::new(AtomicUsize::new(0));
        let poisoned = Arc::new(AtomicBool::new(false));
        let join = std::thread::Builder::new()
            .name(format!("batcher-{model}.{replica}"))
            .spawn({
                let model = model.to_string();
                let metrics = metrics.clone();
                let budget = budget.clone();
                let inflight = inflight.clone();
                let engine = engine.clone();
                let poisoned = poisoned.clone();
                move || {
                    batch_loop(
                        model, engine, cfg, metrics, budget, inflight, replica, poisoned, rx,
                    )
                }
            })
            .expect("spawn batcher");
        Self {
            tx,
            budget,
            inflight,
            replica,
            engine,
            model: model.to_string(),
            cfg,
            metrics,
            poisoned,
            join: Some(join),
        }
    }

    /// Has this replica stopped doing useful work? True when its batch
    /// loop poisoned itself (repeated engine panics) or its thread died
    /// outright — either way the supervisor should rebuild it.
    pub fn is_dead(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
            || match self.join.as_ref() {
                Some(j) => j.is_finished(),
                None => true,
            }
    }

    /// Requests admitted to THIS replica and not yet replied — what the
    /// least-loaded dispatcher compares across replicas.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Replica index within the model.
    pub fn replica(&self) -> usize {
        self.replica
    }

    /// The engine this replica drives (pool trims, plan profiles).
    pub fn engine(&self) -> &Arc<dyn Engine> {
        &self.engine
    }

    /// Enqueue one request under admission control.
    pub fn submit(&self, img: Tensor<u8>) -> Submission {
        self.submit_many(vec![img])
            .pop()
            .expect("one submission per image")
    }

    /// Enqueue a whole vector of requests at once (the wire-level batch
    /// op): one admission decision reserves as many queue slots as fit,
    /// and the requests land on the queue back-to-back so the batch loop
    /// drains them into GEMM-level batches without needing concurrent
    /// connections. Rejected items come back as `Overloaded` in place.
    pub fn submit_many(&self, imgs: Vec<Tensor<u8>>) -> Vec<Submission> {
        self.submit_many_deadline(imgs, None)
    }

    /// [`Batcher::submit_many`] with an optional client deadline: each
    /// admitted request is stamped with the earlier of `deadline` and
    /// the server-side `request_timeout`.
    pub fn submit_many_deadline(
        &self,
        imgs: Vec<Tensor<u8>>,
        deadline: Option<Instant>,
    ) -> Vec<Submission> {
        let n = imgs.len();
        if n == 0 {
            return Vec::new();
        }
        let admitted = self.admit(n);
        let mut out = Vec::with_capacity(n);
        for (i, img) in imgs.into_iter().enumerate() {
            if i >= admitted {
                out.push(Submission::Overloaded);
                continue;
            }
            let (reply, rx) = channel();
            self.enqueue(img, deadline, ReplyTo::Channel(reply));
            out.push(Submission::Queued(rx));
        }
        out
    }

    /// Vector submission with sink-based completion (the event-driven
    /// serving path): item `i` completes under ticket `first_ticket + i`.
    /// Returns one bool per image — `true` = admitted (a completion WILL
    /// arrive, possibly an error), `false` = rejected under admission
    /// control (no completion; the caller replies `overloaded` itself).
    pub fn submit_many_sink(
        &self,
        imgs: Vec<Tensor<u8>>,
        sink: &Arc<dyn CompletionSink>,
        first_ticket: u64,
        deadline: Option<Instant>,
    ) -> Vec<bool> {
        let n = imgs.len();
        if n == 0 {
            return Vec::new();
        }
        let admitted = self.admit(n);
        let mut out = Vec::with_capacity(n);
        for (i, img) in imgs.into_iter().enumerate() {
            if i >= admitted {
                out.push(false);
                continue;
            }
            self.enqueue(
                img,
                deadline,
                ReplyTo::Sink {
                    sink: sink.clone(),
                    ticket: first_ticket + i as u64,
                },
            );
            out.push(true);
        }
        out
    }

    /// Reserve up to `n` in-flight slots in one atomic step against the
    /// model-wide budget; records the queue high-water mark and the
    /// rejection count, and charges this replica's scoreboard.
    fn admit(&self, n: usize) -> usize {
        let mut admitted = 0usize;
        let _ = self
            .budget
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |d| {
                admitted = self.cfg.queue_depth.saturating_sub(d).min(n);
                if admitted == 0 {
                    None
                } else {
                    Some(d + admitted)
                }
            });
        if admitted > 0 {
            self.inflight.fetch_add(admitted, Ordering::SeqCst);
        }
        self.metrics
            .record_queue_depth(&self.model, self.budget.load(Ordering::Relaxed));
        self.metrics
            .record_rejected(&self.model, (n - admitted) as u64);
        admitted
    }

    /// Push one admitted request onto the loop's queue. A send failure
    /// means the loop thread is gone: release the reserved slot (no reply
    /// will ever free it — otherwise the budget ratchets up until a dead
    /// model reads as Overloaded forever) and deliver "batcher shut down"
    /// so sink tickets are never orphaned.
    fn enqueue(&self, img: Tensor<u8>, client_deadline: Option<Instant>, reply: ReplyTo) {
        let enqueued = Instant::now();
        // stamp the effective deadline at admission: the earlier of the
        // client's wire deadline and the server-side request_timeout
        let server = self.cfg.request_timeout.map(|t| enqueued + t);
        let deadline = match (client_deadline, server) {
            (Some(c), Some(s)) => Some(c.min(s)),
            (d, None) | (None, d) => d,
        };
        if let Err(e) = self.tx.send(Request {
            img,
            enqueued,
            deadline,
            reply,
        }) {
            self.budget.fetch_sub(1, Ordering::SeqCst);
            self.inflight.fetch_sub(1, Ordering::SeqCst);
            e.0.reply.send(Err(anyhow::anyhow!("batcher shut down")));
        }
    }

    /// Submit and wait.
    pub fn predict(&self, img: Tensor<u8>) -> Result<Vec<f32>> {
        self.submit(img).wait()
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // closing the sender ends the loop
        let (dead_tx, _) = channel();
        self.tx = dead_tx;
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Release one admission slot and reply: the single exit point for every
/// request a replica accepted — executed, shed, poisoned, or panicked —
/// so the "slot frees exactly once, at reply time" invariant holds on
/// every failure path, not just the happy one.
fn release_and_reply(
    budget: &AtomicUsize,
    inflight: &AtomicUsize,
    req: Request,
    result: Result<Vec<f32>>,
) {
    budget.fetch_sub(1, Ordering::SeqCst);
    inflight.fetch_sub(1, Ordering::SeqCst);
    req.reply.send(result);
}

/// Best-effort text out of a caught panic payload.
fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[allow(clippy::too_many_arguments)]
fn batch_loop(
    model: String,
    engine: Arc<dyn Engine>,
    cfg: BatchConfig,
    metrics: Arc<Metrics>,
    budget: Arc<AtomicUsize>,
    inflight: Arc<AtomicUsize>,
    replica: usize,
    poisoned: Arc<AtomicBool>,
    rx: Receiver<Request>,
) {
    let mut consecutive_panics = 0u32;
    loop {
        // block for the first request
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        // a poisoned replica keeps receiving (exiting would strand any
        // request already in the channel without a reply) but fails
        // everything fast until the supervisor swaps in a rebuilt one
        if poisoned.load(Ordering::SeqCst) {
            release_and_reply(
                &budget,
                &inflight,
                first,
                Err(anyhow::anyhow!(
                    "replica {replica} of {model} is poisoned, awaiting supervisor rebuild"
                )),
            );
            continue;
        }
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            // saturating_duration_since: `deadline - now` would panic if
            // the clock passes the deadline between a check and the
            // subtraction (easy to hit with sub-microsecond max_wait)
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            match rx.recv_timeout(remaining) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        if crate::util::fault::should_fire("slow-batch") {
            std::thread::sleep(crate::util::fault::SLOW_BATCH);
        }
        // shed expired requests before spending engine time on them: the
        // client has already given up, and under a stall this is what
        // lets the queue drain instead of serving an ever-older backlog
        let now = Instant::now();
        if batch.iter().any(|r| r.deadline.is_some_and(|d| d <= now)) {
            let mut live = Vec::with_capacity(batch.len());
            let mut shed = 0u64;
            for req in batch {
                if req.deadline.is_some_and(|d| d <= now) {
                    shed += 1;
                    release_and_reply(
                        &budget,
                        &inflight,
                        req,
                        Err(anyhow::Error::new(DeadlineExceeded)),
                    );
                } else {
                    live.push(req);
                }
            }
            metrics.record_deadline_exceeded(&model, shed);
            batch = live;
            if batch.is_empty() {
                continue;
            }
        }
        metrics.record_batch(&model, batch.len());
        let exec_start = Instant::now();
        let imgs: Vec<&Tensor<u8>> = batch.iter().map(|r| &r.img).collect();
        // panic isolation boundary: the worker pool re-raises job panics
        // on this thread; catching here turns "replica thread dies with
        // its queue stranded" into "this batch fails with err replies"
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if crate::util::fault::should_fire("panic-batch") {
                panic!("fault injection: panic-batch");
            }
            engine.predict_batch(&imgs)
        }));
        let mut results = match outcome {
            Ok(r) => {
                consecutive_panics = 0;
                r
            }
            Err(p) => {
                let msg = panic_msg(&p);
                metrics.record_panic(&model);
                consecutive_panics += 1;
                if consecutive_panics >= POISON_AFTER {
                    poisoned.store(true, Ordering::SeqCst);
                }
                batch
                    .iter()
                    .map(|_| {
                        Err(anyhow::anyhow!(
                            "engine {} panicked executing a batch: {msg}",
                            engine.name()
                        ))
                    })
                    .collect()
            }
        };
        // a buggy engine returning fewer results than requests must not
        // leave clients blocked on reply channels forever
        while results.len() < batch.len() {
            results.push(Err(anyhow::anyhow!(
                "engine {} returned {} results for a batch of {}",
                engine.name(),
                results.len(),
                batch.len()
            )));
        }
        for (req, result) in batch.into_iter().zip(results) {
            // queue time stops at execution start; latency is the full
            // enqueue→reply span PER REQUEST (not one shared batch
            // elapsed), so the stats reflect what each client saw
            let queue_ns = exec_start.saturating_duration_since(req.enqueued).as_nanos() as u64;
            let total_ns = req.enqueued.elapsed().as_nanos() as u64;
            metrics.record_request(&model, total_ns, queue_ns, result.is_ok());
            metrics.record_replica_request(&model, replica);
            // the admission slot frees only now — replied, not merely
            // drained into a batch — so queue_depth bounds true in-flight
            release_and_reply(&budget, &inflight, req, result);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Shape;

    /// Engine that records the batch sizes it sees.
    struct Probe {
        sizes: std::sync::Mutex<Vec<usize>>,
        delay: Duration,
    }

    impl Engine for Probe {
        fn name(&self) -> String {
            "probe-engine".into()
        }

        fn input_shape(&self) -> Shape {
            Shape::vector(4)
        }

        fn predict(&self, img: &Tensor<u8>) -> Result<Vec<f32>> {
            Ok(vec![img.data[0] as f32])
        }

        fn predict_batch(&self, imgs: &[&Tensor<u8>]) -> Vec<Result<Vec<f32>>> {
            self.sizes.lock().unwrap().push(imgs.len());
            std::thread::sleep(self.delay);
            imgs.iter().map(|i| self.predict(i)).collect()
        }
    }

    fn img(v: u8) -> Tensor<u8> {
        Tensor::from_vec(Shape::vector(4), vec![v, 0, 0, 0])
    }

    fn queued(s: Submission) -> Receiver<Result<Vec<f32>>> {
        match s {
            Submission::Queued(rx) => rx,
            Submission::Overloaded => panic!("unexpected overload"),
        }
    }

    #[test]
    fn responses_match_requests() {
        let engine = Arc::new(Probe {
            sizes: Default::default(),
            delay: Duration::ZERO,
        });
        let b = Batcher::spawn(
            "probe",
            engine,
            BatchConfig::default(),
            Arc::new(Metrics::new()),
        );
        let handles: Vec<_> = (0..20).map(|i| (i, queued(b.submit(img(i as u8))))).collect();
        for (i, h) in handles {
            let scores = h.recv().unwrap().unwrap();
            assert_eq!(scores[0], i as f32);
        }
    }

    #[test]
    fn batches_form_under_load() {
        let engine = Arc::new(Probe {
            sizes: Default::default(),
            delay: Duration::from_millis(2),
        });
        let cfg = BatchConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            ..BatchConfig::default()
        };
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::spawn("probe", engine.clone(), cfg, metrics.clone());
        // flood: while the first batch executes, the rest queue up
        let handles: Vec<_> = (0..32).map(|i| queued(b.submit(img(i as u8)))).collect();
        for h in handles {
            h.recv().unwrap().unwrap();
        }
        let sizes = engine.sizes.lock().unwrap().clone();
        assert!(
            sizes.iter().any(|&s| s > 1),
            "expected some multi-request batches, got {sizes:?}"
        );
        let snap = metrics.snapshot("probe").unwrap();
        assert_eq!(snap.requests, 32);
        assert!(snap.queue_peak >= 1, "queue high-water recorded");
    }

    /// Regression for the metrics-keying bug: every counter must land
    /// under the registered model name, even when the engine's own label
    /// differs (Probe's is "probe-engine").
    #[test]
    fn metrics_key_by_registered_model_name() {
        let engine = Arc::new(Probe {
            sizes: Default::default(),
            delay: Duration::ZERO,
        });
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::spawn("registered", engine, BatchConfig::default(), metrics.clone());
        for i in 0..5 {
            b.predict(img(i)).unwrap();
        }
        let snap = metrics.snapshot("registered").unwrap();
        assert_eq!(snap.requests, 5);
        assert!(
            metrics.snapshot("probe-engine").is_none(),
            "engine label must not split off its own stats row"
        );
    }

    #[test]
    fn max_batch_one_is_fifo() {
        let engine = Arc::new(Probe {
            sizes: Default::default(),
            delay: Duration::from_micros(100),
        });
        let cfg = BatchConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(5),
            ..BatchConfig::default()
        };
        let b = Batcher::spawn("probe", engine.clone(), cfg, Arc::new(Metrics::new()));
        let handles: Vec<_> = (0..10).map(|i| queued(b.submit(img(i)))).collect();
        for h in handles {
            h.recv().unwrap().unwrap();
        }
        assert!(engine.sizes.lock().unwrap().iter().all(|&s| s == 1));
    }

    /// submit_many from ONE caller must fill GEMM-level batches: the
    /// requests land back-to-back so the loop drains them in max_batch
    /// groups, no concurrent sockets needed.
    #[test]
    fn submit_many_forms_batches_from_one_caller() {
        let engine = Arc::new(Probe {
            sizes: Default::default(),
            delay: Duration::from_millis(1),
        });
        let cfg = BatchConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            ..BatchConfig::default()
        };
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::spawn("probe", engine.clone(), cfg, metrics.clone());
        let subs = b.submit_many((0..24).map(img).collect());
        for (i, s) in subs.into_iter().enumerate() {
            assert_eq!(s.wait().unwrap()[0], i as f32);
        }
        let snap = metrics.snapshot("probe").unwrap();
        assert_eq!(snap.requests, 24);
        assert!(
            snap.mean_batch > 1.0,
            "single-caller vector submit should batch: mean {}",
            snap.mean_batch
        );
    }

    /// With the queue saturated, excess submissions reject immediately
    /// (bounded memory, no hang) and are counted.
    #[test]
    fn overload_rejects_immediately_and_counts() {
        let engine = Arc::new(Probe {
            sizes: Default::default(),
            delay: Duration::from_millis(50),
        });
        let cfg = BatchConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(100),
            queue_depth: 2,
            ..BatchConfig::default()
        };
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::spawn("probe", engine, cfg, metrics.clone());
        let t0 = Instant::now();
        let subs = b.submit_many((0..10).map(img).collect());
        assert!(
            t0.elapsed() < Duration::from_millis(40),
            "rejection must not wait on the engine"
        );
        let overloaded = subs.iter().filter(|s| s.is_overloaded()).count();
        assert!(overloaded >= 8, "queue_depth 2 admits at most 2: {overloaded}");
        for s in subs {
            if !s.is_overloaded() {
                s.wait().unwrap();
            }
        }
        let snap = metrics.snapshot("probe").unwrap();
        assert_eq!(snap.rejected, overloaded as u64);
        assert!(snap.queue_peak <= 2);
        // the queue drains back to empty: later traffic is admitted
        assert!(!b.submit(img(0)).is_overloaded());
    }

    /// Regression for the `deadline - now` underflow: with a max_wait so
    /// short the deadline is already in the past by the time the loop
    /// computes its timeout, the subtraction used to be able to panic
    /// (killing the batcher thread and hanging every queued client).
    /// Race it hard; every submission must still get a reply.
    #[test]
    fn deadline_race_does_not_panic_batch_loop() {
        let engine = Arc::new(Probe {
            sizes: Default::default(),
            delay: Duration::ZERO,
        });
        let cfg = BatchConfig {
            max_batch: 8,
            max_wait: Duration::from_nanos(1),
            ..BatchConfig::default()
        };
        let b = Batcher::spawn("probe", engine, cfg, Arc::new(Metrics::new()));
        for round in 0..200 {
            let subs = b.submit_many((0..4).map(|i| img(i as u8)).collect());
            for (i, s) in subs.into_iter().enumerate() {
                assert_eq!(
                    s.wait().expect("batcher thread must survive the race")[0],
                    i as f32,
                    "round {round}"
                );
            }
        }
    }

    /// Sink-based completion: tickets come back exactly once each, on the
    /// batcher thread, with results matching the submitted images.
    #[test]
    fn sink_submission_completes_every_ticket() {
        struct Collect {
            got: std::sync::Mutex<Vec<(u64, f32)>>,
        }
        impl CompletionSink for Collect {
            fn complete(&self, ticket: u64, result: Result<Vec<f32>>) {
                self.got.lock().unwrap().push((ticket, result.unwrap()[0]));
            }
        }
        let engine = Arc::new(Probe {
            sizes: Default::default(),
            delay: Duration::ZERO,
        });
        let b = Batcher::spawn(
            "probe",
            engine,
            BatchConfig::default(),
            Arc::new(Metrics::new()),
        );
        let sink = Arc::new(Collect {
            got: Default::default(),
        });
        let dyn_sink: Arc<dyn CompletionSink> = sink.clone();
        let admitted = b.submit_many_sink((0..16).map(img).collect(), &dyn_sink, 100, None);
        assert!(admitted.iter().all(|&a| a), "default depth admits 16");
        let t0 = Instant::now();
        loop {
            if sink.got.lock().unwrap().len() == 16 {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(5), "completions missing");
            std::thread::sleep(Duration::from_millis(1));
        }
        let mut got = sink.got.lock().unwrap().clone();
        got.sort_unstable_by_key(|&(t, _)| t);
        for (i, (ticket, score)) in got.into_iter().enumerate() {
            assert_eq!(ticket, 100 + i as u64);
            assert_eq!(score, i as f32);
        }
    }

    /// Sink tickets on a dead batcher must still complete (with an error)
    /// rather than leak — the event loop would otherwise hold the
    /// connection's pending slot forever.
    #[test]
    fn sink_ticket_on_dead_batcher_completes_with_error() {
        struct Collect {
            got: std::sync::Mutex<Vec<(u64, bool)>>,
        }
        impl CompletionSink for Collect {
            fn complete(&self, ticket: u64, result: Result<Vec<f32>>) {
                self.got.lock().unwrap().push((ticket, result.is_ok()));
            }
        }
        let engine = Arc::new(Probe {
            sizes: Default::default(),
            delay: Duration::ZERO,
        });
        let mut b = Batcher::spawn(
            "probe",
            engine,
            BatchConfig::default(),
            Arc::new(Metrics::new()),
        );
        // sever the loop the same way Drop does, then submit
        let (dead_tx, _) = channel();
        b.tx = dead_tx;
        if let Some(j) = b.join.take() {
            j.join().unwrap();
        }
        let sink = Arc::new(Collect {
            got: Default::default(),
        });
        let dyn_sink: Arc<dyn CompletionSink> = sink.clone();
        let admitted = b.submit_many_sink(vec![img(0)], &dyn_sink, 7, None);
        assert_eq!(admitted, vec![true]);
        let got = sink.got.lock().unwrap().clone();
        assert_eq!(got, vec![(7, false)], "errored completion, not a leak");
        assert_eq!(b.budget.load(Ordering::SeqCst), 0, "slot released");
        assert_eq!(b.inflight(), 0, "scoreboard released");
    }

    /// Engine that panics when an image's first byte is 255.
    struct Grenade;

    impl Engine for Grenade {
        fn name(&self) -> String {
            "grenade".into()
        }

        fn input_shape(&self) -> Shape {
            Shape::vector(4)
        }

        fn predict(&self, img: &Tensor<u8>) -> Result<Vec<f32>> {
            if img.data[0] == 255 {
                panic!("boom on request {}", img.data[1]);
            }
            Ok(vec![img.data[0] as f32])
        }
    }

    /// A panicking batch must fail only its own requests: the batcher
    /// thread survives, later requests succeed, and the panic is counted.
    #[test]
    fn panicking_batch_is_isolated() {
        let metrics = Arc::new(Metrics::new());
        let cfg = BatchConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(100),
            ..BatchConfig::default()
        };
        let b = Batcher::spawn("probe", Arc::new(Grenade), cfg, metrics.clone());
        assert_eq!(b.predict(img(3)).unwrap(), vec![3.0]);
        let boom = Tensor::from_vec(Shape::vector(4), vec![255, 0, 0, 0]);
        let err = b.predict(boom).unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        assert!(err.to_string().contains("boom"), "payload surfaced: {err}");
        // the replica is still alive and healthy
        assert!(!b.is_dead());
        assert_eq!(b.predict(img(7)).unwrap(), vec![7.0]);
        assert_eq!(metrics.panics("probe"), 1);
        assert_eq!(b.budget.load(Ordering::SeqCst), 0, "slots released");
        assert_eq!(b.inflight(), 0);
    }

    /// Repeated consecutive panics poison the replica: it keeps replying
    /// (fast errors, nothing stranded) and flags itself for the
    /// supervisor instead of wedging or dying with queued requests.
    #[test]
    fn repeated_panics_poison_the_replica() {
        let metrics = Arc::new(Metrics::new());
        let cfg = BatchConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(100),
            ..BatchConfig::default()
        };
        let b = Batcher::spawn("probe", Arc::new(Grenade), cfg, metrics.clone());
        let boom = || Tensor::from_vec(Shape::vector(4), vec![255, 0, 0, 0]);
        for _ in 0..POISON_AFTER {
            assert!(b.predict(boom()).is_err());
        }
        assert!(b.is_dead(), "poisoned after {POISON_AFTER} consecutive panics");
        // still answers — with errors — rather than stranding requests
        let err = b.predict(img(1)).unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        assert_eq!(metrics.panics("probe"), POISON_AFTER as u64);
        assert_eq!(b.budget.load(Ordering::SeqCst), 0);
    }

    /// Requests whose deadline passes while queued are shed with the
    /// typed `DeadlineExceeded` error before the engine runs them.
    #[test]
    fn expired_requests_are_shed_before_execution() {
        let engine = Arc::new(Probe {
            sizes: Default::default(),
            delay: Duration::from_millis(40),
        });
        let cfg = BatchConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(100),
            request_timeout: Some(Duration::from_millis(10)),
            ..BatchConfig::default()
        };
        let metrics = Arc::new(Metrics::new());
        let b = Batcher::spawn("probe", engine.clone(), cfg, metrics.clone());
        // the first request occupies the engine for 40ms; everything
        // queued behind it outlives its 10ms stamp and must be shed
        let subs = b.submit_many((0..6).map(img).collect());
        let mut ok = 0;
        let mut shed = 0;
        for s in subs {
            match s.wait() {
                Ok(_) => ok += 1,
                Err(e) => {
                    assert!(
                        e.downcast_ref::<DeadlineExceeded>().is_some(),
                        "typed deadline error, got: {e}"
                    );
                    shed += 1;
                }
            }
        }
        assert!(ok >= 1, "the batch at the head still executes");
        assert!(shed >= 1, "queued requests past their stamp are shed");
        assert_eq!(metrics.deadline_exceeded("probe"), shed as u64);
        assert_eq!(b.budget.load(Ordering::SeqCst), 0, "shed slots released");
        // batches record only executed requests
        let sizes = engine.sizes.lock().unwrap().clone();
        assert_eq!(sizes.iter().sum::<usize>(), ok);
        // a fresh request well within its deadline still works
        assert_eq!(b.predict(img(9)).unwrap(), vec![9.0]);
    }

    /// A client wire deadline earlier than the server stamp wins (and
    /// vice versa): the effective deadline is the minimum.
    #[test]
    fn client_deadline_combines_with_server_timeout() {
        let engine = Arc::new(Probe {
            sizes: Default::default(),
            delay: Duration::from_millis(30),
        });
        let cfg = BatchConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(100),
            request_timeout: Some(Duration::from_secs(60)),
            ..BatchConfig::default()
        };
        let b = Batcher::spawn("probe", engine, cfg, Arc::new(Metrics::new()));
        // tight client deadline beats the lax server timeout
        let deadline = Some(Instant::now() + Duration::from_millis(5));
        let subs = b.submit_many_deadline((0..4).map(img).collect(), deadline);
        let results: Vec<_> = subs.into_iter().map(|s| s.wait()).collect();
        assert!(
            results
                .iter()
                .any(|r| matches!(r, Err(e) if e.downcast_ref::<DeadlineExceeded>().is_some())),
            "tight client deadline must shed queued requests"
        );
    }

    /// Two replicas sharing one admission budget: `queue_depth` bounds
    /// the MODEL's in-flight total, exactly as a single replica would —
    /// replication must not multiply the admission capacity.
    #[test]
    fn replicas_share_one_admission_budget() {
        let cfg = BatchConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(100),
            queue_depth: 2,
            ..BatchConfig::default()
        };
        let metrics = Arc::new(Metrics::new());
        let budget = Arc::new(AtomicUsize::new(0));
        let mk = |replica| {
            Batcher::spawn_replica(
                "probe",
                Arc::new(Probe {
                    sizes: Default::default(),
                    delay: Duration::from_millis(50),
                }),
                cfg,
                metrics.clone(),
                budget.clone(),
                replica,
            )
        };
        let (a, b) = (mk(0), mk(1));
        // saturate through replica a, then replica b must reject too:
        // the budget is model-wide, not per replica
        let first = a.submit_many(vec![img(0), img(1)]);
        assert!(first.iter().all(|s| !s.is_overloaded()));
        assert_eq!(a.inflight(), 2);
        assert!(b.submit(img(2)).is_overloaded());
        assert_eq!(b.inflight(), 0, "rejected requests never charge the scoreboard");
        for s in first {
            s.wait().unwrap();
        }
        // drained: slots free again on either replica
        assert!(!b.submit(img(3)).is_overloaded());
        let snap = metrics.snapshot("probe").unwrap();
        assert_eq!(snap.rejected, 1);
        assert!(snap.queue_peak <= 2);
        // both replicas served under the one model key, split recorded
        drop(a);
        drop(b);
        assert_eq!(metrics.replica_served("probe").iter().sum::<u64>(), 3);
    }
}
