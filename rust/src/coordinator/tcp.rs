//! Minimal TCP serving protocol (length-prefixed binary frames).
//!
//! Request frame:  `u32 len | u8 op | payload`
//!   op 1 = predict:       `u16 name_len | name | u32 img_len | img bytes`
//!   op 2 = stats:         (empty) → utf8 metrics table
//!   op 3 = ping:          (empty) → "pong"
//!   op 4 = models:        (empty) → newline-separated model names
//!   op 5 = predict_batch: `u16 name_len | name | u32 count |
//!                          count × (u32 img_len | img bytes)`
//! Response frame: `u32 len | u8 status | payload`
//!   status 0 = ok, 1 = err (payload utf8), 2 = overloaded (the model's
//!   admission queue is at `--queue-depth`, or the acceptor is at
//!   `--max-conns`; retry later).
//!   predict ok payload = `u32 n | n × f32 scores` (LE).
//!   predict_batch ok payload = `u32 count | count × (u8 status | u32 len
//!   | item)` — one entry per submitted image, in order; each item is a
//!   predict ok payload (status 0), a utf8 error (status 1), or an
//!   `overloaded` marker (status 2). Partial admission is normal: a batch
//!   that overflows the queue gets scores for the admitted prefix and
//!   status-2 entries for the rest.
//!
//! Connections are **pipelined**: requests are submitted to the
//! coordinator tagged with a per-connection sequence id and replies go
//! back strictly in request order. A client may therefore stream many
//! requests without waiting for responses — combined with op 5 this lets
//! a single socket saturate GEMM-level batching.
//!
//! Two front ends implement the protocol (see [`IoModel`]): the default
//! event-driven model multiplexes every connection over a fixed pool of
//! epoll loops (`coordinator::event`), while `--io-model threads` keeps
//! the previous reader-thread + writer-thread per connection as an A/B
//! baseline. Wire behavior is bit-identical between the two.
//!
//! Error handling: EOF exactly at a frame boundary is a clean close.
//! Mid-frame truncation and oversize length prefixes are **protocol
//! errors** — counted in `Metrics` (they used to be swallowed as clean
//! closes) and fatal to the connection, since the byte stream cannot be
//! resynchronized. Malformed payloads inside a well-framed request
//! (truncated predict payload, `img_len` mismatch, bad UTF-8 model name,
//! unknown op) are also counted, but answered with an err frame and the
//! connection stays alive.

use super::batcher::Submission;
use super::metrics::Metrics;
use super::Coordinator;
use crate::tensor::{Shape, Tensor};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

pub const OP_PREDICT: u8 = 1;
pub const OP_STATS: u8 = 2;
pub const OP_PING: u8 = 3;
pub const OP_MODELS: u8 = 4;
pub const OP_PREDICT_BATCH: u8 = 5;

pub const STATUS_OK: u8 = 0;
pub const STATUS_ERR: u8 = 1;
pub const STATUS_OVERLOADED: u8 = 2;

pub(crate) const MAX_FRAME: u32 = 64 << 20;

/// Upper bound on images in one `predict_batch` frame: without it a
/// 64 MB frame could declare ~16M zero-length images and cost ~1 GB of
/// per-item structs before admission control ever sees them.
pub const MAX_BATCH_ITEMS: usize = 4096;

/// Cap on queued-but-unwritten responses per connection. A pipelining
/// client that never reads its replies eventually blocks the reader here
/// — and therefore its own TCP sends — instead of growing server memory
/// without bound while `queue_depth` slots recycle at batch-drain time.
/// (The event loop enforces the same cap by pausing read interest.)
pub(crate) const MAX_PIPELINE: usize = 256;

/// How reading one frame failed.
#[derive(Debug)]
enum FrameError {
    /// EOF exactly at a frame boundary — the peer closed cleanly.
    Closed,
    /// Framing violation: truncation mid-frame or an oversize length
    /// prefix. The stream cannot be resynchronized.
    Protocol(String),
    /// Transport failure (reset, shutdown, ...).
    Io(std::io::Error),
}

fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>, FrameError> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match stream.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Err(FrameError::Closed),
            Ok(0) => {
                return Err(FrameError::Protocol(format!(
                    "eof inside length prefix ({got}/4 bytes)"
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(FrameError::Protocol(format!(
            "frame length {len} exceeds maximum {MAX_FRAME}"
        )));
    }
    let mut buf = vec![0u8; len as usize];
    if let Err(e) = stream.read_exact(&mut buf) {
        return Err(if e.kind() == std::io::ErrorKind::UnexpectedEof {
            FrameError::Protocol(format!("eof inside {len}-byte frame body"))
        } else {
            FrameError::Io(e)
        });
    }
    Ok(buf)
}

/// Length prefix for a `status/op + payload` frame, or an error when the
/// frame would exceed [`MAX_FRAME`]. The old `(payload.len() + 1) as u32`
/// cast silently truncated oversize lengths, desyncing the stream for
/// every frame after it — too large must be an error, never a wrap.
pub(crate) fn frame_len_checked(payload_len: usize) -> Result<u32> {
    let total = payload_len.saturating_add(1);
    if total > MAX_FRAME as usize {
        bail!("frame too large: {payload_len} payload bytes exceed the {MAX_FRAME}-byte limit");
    }
    Ok(total as u32)
}

/// Clamp one outgoing response to the frame limit: an encodable payload
/// passes through; an oversize one is counted in [`Metrics`] and replaced
/// by a small err frame so the stream stays in sync.
pub(crate) fn checked_response(status: u8, payload: Vec<u8>, metrics: &Metrics) -> (u8, Vec<u8>) {
    if frame_len_checked(payload.len()).is_ok() {
        (status, payload)
    } else {
        metrics.record_frame_too_large();
        (STATUS_ERR, b"response exceeds frame limit".to_vec())
    }
}

fn write_frame(stream: &mut TcpStream, status: u8, payload: &[u8]) -> Result<()> {
    let len = frame_len_checked(payload.len())?;
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(&[status])?;
    stream.write_all(payload)?;
    stream.flush()?;
    Ok(())
}

pub(crate) fn encode_scores(scores: &[f32]) -> Vec<u8> {
    let mut payload = Vec::with_capacity(4 + scores.len() * 4);
    payload.extend_from_slice(&(scores.len() as u32).to_le_bytes());
    for s in scores {
        payload.extend_from_slice(&s.to_le_bytes());
    }
    payload
}

fn decode_scores(r: &[u8]) -> Result<Vec<f32>> {
    if r.len() < 4 {
        bail!("short predict response");
    }
    let n = u32::from_le_bytes([r[0], r[1], r[2], r[3]]) as usize;
    if r.len() != 4 + n * 4 {
        bail!("predict response length mismatch");
    }
    Ok(r[4..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Front-end IO model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoModel {
    /// Nonblocking epoll event loops, one per core (default on Linux):
    /// thread count scales with cores, not connections.
    Event,
    /// The previous design — 2 OS threads per connection (reader +
    /// in-order writer). Kept for one release as the A/B baseline.
    Threads,
}

impl Default for IoModel {
    fn default() -> Self {
        if cfg!(target_os = "linux") {
            IoModel::Event
        } else {
            IoModel::Threads
        }
    }
}

impl std::str::FromStr for IoModel {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "event" => Ok(IoModel::Event),
            "threads" => Ok(IoModel::Threads),
            other => bail!("unknown io model {other:?} (expected \"event\" or \"threads\")"),
        }
    }
}

/// Serving front-end policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Concurrent-connection cap; further connects are answered with one
    /// `overloaded` frame and closed.
    pub max_conns: usize,
    /// Which front end multiplexes connections (`--io-model`).
    pub io_model: IoModel,
    /// Number of event loops under [`IoModel::Event`] (`--io-loops`);
    /// 0 = one per available core. Ignored under [`IoModel::Threads`].
    pub io_loops: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            max_conns: 256,
            io_model: IoModel::default(),
            io_loops: 0,
        }
    }
}

impl ServeOptions {
    /// Resolve `io_loops = 0` to the core count.
    pub fn effective_io_loops(&self) -> usize {
        if self.io_loops > 0 {
            self.io_loops
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }
}

/// Counts live serving threads (acceptor, IO loops, per-connection
/// threads, reject drains) and wakes shutdown the moment the count hits
/// zero — replaces the old 500 ms poll-around-a-deadline wait. Tracks the
/// lifetime peak so benches can verify the thread bound.
pub(crate) struct Latch {
    /// (live, peak)
    state: Mutex<(usize, usize)>,
    cv: Condvar,
}

impl Latch {
    pub(crate) fn new() -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new((0, 0)),
            cv: Condvar::new(),
        })
    }

    /// Register one serving thread; the guard deregisters on drop.
    /// Register BEFORE spawning and move the guard into the thread, so
    /// shutdown can never observe a not-yet-counted thread.
    pub(crate) fn register(self: &Arc<Self>) -> LatchGuard {
        let mut s = self.state.lock().unwrap();
        s.0 += 1;
        s.1 = s.1.max(s.0);
        LatchGuard(self.clone())
    }

    pub(crate) fn count(&self) -> usize {
        self.state.lock().unwrap().0
    }

    pub(crate) fn peak(&self) -> usize {
        self.state.lock().unwrap().1
    }

    /// Block until every registered thread has exited; `false` on
    /// timeout.
    pub(crate) fn wait_zero(&self, timeout: Duration) -> bool {
        let s = self.state.lock().unwrap();
        let (_s, res) = self
            .cv
            .wait_timeout_while(s, timeout, |s| s.0 > 0)
            .unwrap();
        !res.timed_out()
    }
}

pub(crate) struct LatchGuard(Arc<Latch>);

impl Drop for LatchGuard {
    fn drop(&mut self) {
        let mut s = self.0.state.lock().unwrap();
        s.0 -= 1;
        if s.0 == 0 {
            self.0.cv.notify_all();
        }
    }
}

/// Threads-mode connection registry: stream clones for prompt shutdown
/// (shutting the socket unblocks both the reader and a stuck writer) plus
/// joinable connection-thread handles — these used to be spawned detached
/// and leaked on shutdown or connection error.
struct ConnRegistry {
    streams: Mutex<HashMap<u64, TcpStream>>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    next_id: AtomicU64,
}

impl ConnRegistry {
    fn new() -> Arc<Self> {
        Arc::new(Self {
            streams: Mutex::new(HashMap::new()),
            handles: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(0),
        })
    }

    fn insert(&self, stream: TcpStream) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.streams.lock().unwrap().insert(id, stream);
        id
    }

    fn remove(&self, id: u64) {
        self.streams.lock().unwrap().remove(&id);
    }

    /// Track a connection thread, reaping any that already finished so
    /// the handle list stays proportional to LIVE connections.
    fn track(&self, handle: std::thread::JoinHandle<()>) {
        let mut hs = self.handles.lock().unwrap();
        let mut live = Vec::with_capacity(hs.len() + 1);
        for h in hs.drain(..) {
            if h.is_finished() {
                let _ = h.join();
            } else {
                live.push(h);
            }
        }
        live.push(handle);
        *hs = live;
    }

    fn shutdown_streams(&self) {
        for s in self.streams.lock().unwrap().values() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }

    fn join_all(&self) {
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Handle to a running server: its bound address and a prompt shutdown.
pub struct ServerHandle {
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    latch: Arc<Latch>,
    joins: Vec<std::thread::JoinHandle<()>>,
    registry: Option<Arc<ConnRegistry>>,
    /// One wake per event loop: makes its epoll_wait return so it can
    /// observe `stop`.
    wakers: Vec<Box<dyn Fn() + Send + Sync>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.local
    }

    /// Live serving-thread count (acceptor + IO loops + connection
    /// threads + reject drains). Batcher threads are per-model, not
    /// per-connection, and are not counted here.
    pub fn serving_threads(&self) -> usize {
        self.latch.count()
    }

    /// Lifetime high-water mark of [`ServerHandle::serving_threads`].
    pub fn serving_thread_peak(&self) -> usize {
        self.latch.peak()
    }

    /// Stop serving: wakes the acceptor and every IO/connection thread,
    /// then blocks on a condvar latch that trips the moment the last one
    /// exits (no polling), and joins them all.
    pub fn shutdown(&mut self) {
        if self.joins.is_empty() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        for w in &self.wakers {
            w();
        }
        // wake the blocking accept; a wildcard bind (0.0.0.0/[::]) is not
        // connectable on every platform, so aim the wake at loopback
        let mut wake = self.local;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake {
                SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(wake);
        if let Some(reg) = &self.registry {
            reg.shutdown_streams();
        }
        let _ = self.latch.wait_zero(Duration::from_secs(10));
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
        if let Some(reg) = self.registry.take() {
            reg.join_all();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Decrements the live-connection count when a connection fully ends
/// (reader finished AND writer drained / event-loop slot closed).
pub(crate) struct ConnGuard(Arc<AtomicUsize>);

impl ConnGuard {
    pub(crate) fn new(active: Arc<AtomicUsize>) -> Self {
        active.fetch_add(1, Ordering::SeqCst);
        Self(active)
    }
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Serve the coordinator on `addr` until the returned handle is shut
/// down. Under [`IoModel::Event`] (Linux default) a dispatching acceptor
/// feeds connections round-robin to a fixed pool of epoll loops; under
/// [`IoModel::Threads`] each admitted connection gets a reader thread +
/// an in-order writer thread (the pre-event-loop design, kept as an A/B
/// baseline).
pub fn serve(coord: Arc<Coordinator>, addr: &str, opts: ServeOptions) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let latch = Latch::new();
    let active = Arc::new(AtomicUsize::new(0));
    match opts.io_model {
        #[cfg(target_os = "linux")]
        IoModel::Event => serve_event(coord, listener, local, opts, stop, latch, active),
        #[cfg(not(target_os = "linux"))]
        IoModel::Event => serve_threads(coord, listener, local, opts, stop, latch, active),
        IoModel::Threads => serve_threads(coord, listener, local, opts, stop, latch, active),
    }
}

/// Event-driven front end: N shared-nothing epoll loops plus one
/// dispatching acceptor. The acceptor stays blocking (zero idle CPU) and
/// only hands sockets off; all framing, dispatch, and writeback happen on
/// the loops.
#[cfg(target_os = "linux")]
fn serve_event(
    coord: Arc<Coordinator>,
    listener: TcpListener,
    local: SocketAddr,
    opts: ServeOptions,
    stop: Arc<AtomicBool>,
    latch: Arc<Latch>,
    active: Arc<AtomicUsize>,
) -> Result<ServerHandle> {
    use super::event;
    let n = opts.effective_io_loops().max(1);
    let mut joins = Vec::with_capacity(n + 1);
    let mut wakers: Vec<Box<dyn Fn() + Send + Sync>> = Vec::with_capacity(n);
    let mut shared = Vec::with_capacity(n);
    for i in 0..n {
        let l = event::spawn_loop(i, coord.clone(), stop.clone(), &latch)?;
        let s = l.shared.clone();
        wakers.push(Box::new({
            let s = s.clone();
            move || s.wake()
        }));
        shared.push(s);
        joins.push(l.join);
    }
    let reject_drains = Arc::new(AtomicUsize::new(0));
    let accept_guard = latch.register();
    let accept_stop = stop.clone();
    let accept_latch = latch.clone();
    let metrics = coord.metrics.clone();
    let accept_join = std::thread::Builder::new()
        .name("espresso-accept".into())
        .spawn(move || {
            let _guard = accept_guard;
            let mut next = 0usize;
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if accept_stop.load(Ordering::SeqCst) {
                            break; // shutdown wake-up connection
                        }
                        if active.load(Ordering::SeqCst) >= opts.max_conns {
                            metrics.record_conn_rejected();
                            reject_conn(
                                stream,
                                reject_drains.clone(),
                                &accept_latch,
                                accept_stop.clone(),
                            );
                            continue;
                        }
                        let guard = ConnGuard::new(active.clone());
                        shared[next % shared.len()].push_conn(stream, guard);
                        next += 1;
                    }
                    Err(_) => {
                        if accept_stop.load(Ordering::SeqCst) {
                            break;
                        }
                        // transient accept failure (e.g. ECONNABORTED):
                        // don't spin if it persists
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            }
        })
        .context("spawn acceptor")?;
    joins.insert(0, accept_join);
    Ok(ServerHandle {
        local,
        stop,
        latch,
        joins,
        registry: None,
        wakers,
    })
}

/// Thread-per-connection baseline (`--io-model threads`).
fn serve_threads(
    coord: Arc<Coordinator>,
    listener: TcpListener,
    local: SocketAddr,
    opts: ServeOptions,
    stop: Arc<AtomicBool>,
    latch: Arc<Latch>,
    active: Arc<AtomicUsize>,
) -> Result<ServerHandle> {
    let registry = ConnRegistry::new();
    let reject_drains = Arc::new(AtomicUsize::new(0));
    let accept_guard = latch.register();
    let accept_stop = stop.clone();
    let accept_latch = latch.clone();
    let reg = registry.clone();
    let join = std::thread::Builder::new()
        .name("espresso-accept".into())
        .spawn(move || {
            let _guard = accept_guard;
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if accept_stop.load(Ordering::SeqCst) {
                            break; // shutdown wake-up connection
                        }
                        if active.load(Ordering::SeqCst) >= opts.max_conns {
                            coord.metrics.record_conn_rejected();
                            reject_conn(
                                stream,
                                reject_drains.clone(),
                                &accept_latch,
                                accept_stop.clone(),
                            );
                            continue;
                        }
                        let guard = ConnGuard::new(active.clone());
                        let coord = coord.clone();
                        let conn_guard = accept_latch.register();
                        let conn_reg = reg.clone();
                        let conn_latch = accept_latch.clone();
                        let spawned = std::thread::Builder::new()
                            .name("espresso-conn".into())
                            .spawn(move || {
                                let _lg = conn_guard;
                                let _ = handle_conn(coord, stream, guard, conn_reg, conn_latch);
                            });
                        match spawned {
                            Ok(h) => reg.track(h),
                            Err(_) => {} // guards drop: conn closes, slot frees
                        }
                    }
                    Err(_) => {
                        if accept_stop.load(Ordering::SeqCst) {
                            break;
                        }
                        // transient accept failure (e.g. ECONNABORTED):
                        // don't spin if it persists
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            }
        })
        .context("spawn acceptor")?;
    Ok(ServerHandle {
        local,
        stop,
        latch,
        joins: vec![join],
        registry: Some(registry),
        wakers: Vec::new(),
    })
}

/// Cap on concurrent reject-drain threads: under a connection flood the
/// polite path below would otherwise spawn one thread per reject,
/// defeating the resource bound `--max-conns` exists to provide.
const MAX_REJECT_DRAINS: usize = 64;

/// Turn away one over-capacity connection with a readable `overloaded`
/// frame. Closing immediately would send an RST whenever the client has
/// already written its first request (unread bytes in our receive buffer
/// destroy the queued frame on Linux), so: write, half-close, then drain
/// whatever the client sent — off the acceptor thread, with a hard
/// deadline so a byte-trickling peer cannot pin the drain. Past
/// `MAX_REJECT_DRAINS` concurrent drains the connection is just dropped
/// (an RST is acceptable under that much reject pressure).
fn reject_conn(
    mut stream: TcpStream,
    drains: Arc<AtomicUsize>,
    latch: &Arc<Latch>,
    stop: Arc<AtomicBool>,
) {
    let admitted = drains
        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |d| {
            if d >= MAX_REJECT_DRAINS {
                None
            } else {
                Some(d + 1)
            }
        })
        .is_ok();
    if !admitted {
        return;
    }
    let guard = latch.register();
    let spawned = std::thread::Builder::new()
        .name("espresso-reject".into())
        .spawn(move || {
            let _lg = guard;
            let _ = write_frame(
                &mut stream,
                STATUS_OVERLOADED,
                b"server at connection capacity",
            );
            let _ = stream.shutdown(std::net::Shutdown::Write);
            let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
            let deadline = std::time::Instant::now() + Duration::from_millis(500);
            let mut sink = [0u8; 4096];
            while std::time::Instant::now() < deadline && !stop.load(Ordering::SeqCst) {
                match stream.read(&mut sink) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => continue,
                }
            }
            drains.fetch_sub(1, Ordering::SeqCst);
        });
    if spawned.is_err() {
        drains.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One queued response, tagged with the request's sequence id. The
/// reader→writer channel preserves submission order, so the writer
/// replies strictly in request order while the reader keeps parsing.
enum Outgoing {
    /// Response computed inline by the reader (ping/stats/models/errors).
    Ready {
        seq: u64,
        status: u8,
        payload: Vec<u8>,
    },
    /// A single predict pending in a model's batcher.
    Single { seq: u64, sub: Submission },
    /// A wire-level batch: one response frame covering every submission.
    Batch { seq: u64, subs: Vec<Submission> },
}

fn handle_conn(
    coord: Arc<Coordinator>,
    stream: TcpStream,
    guard: ConnGuard,
    registry: Arc<ConnRegistry>,
    latch: Arc<Latch>,
) -> Result<()> {
    stream.set_nodelay(true)?;
    let mut reader = stream.try_clone().context("clone stream")?;
    // registered so shutdown can unblock this connection's reader/writer
    let reg_id = registry.insert(stream.try_clone().context("clone stream")?);
    // bounded: a full pipeline blocks the reader (TCP backpressure to the
    // client) rather than queueing unwritten replies without limit
    let (tx, rx) = sync_channel::<Outgoing>(MAX_PIPELINE);
    let metrics = coord.metrics.clone();
    let writer_guard = latch.register();
    let writer = match std::thread::Builder::new()
        .name("espresso-conn-writer".into())
        .spawn(move || {
            let _lg = writer_guard;
            writer_loop(stream, rx, metrics)
        }) {
        Ok(w) => w,
        Err(e) => {
            registry.remove(reg_id);
            return Err(e).context("spawn connection writer");
        }
    };
    let mut seq = 0u64;
    loop {
        let frame = match read_frame(&mut reader) {
            Ok(f) => f,
            Err(FrameError::Closed) => break,
            Err(FrameError::Protocol(msg)) => {
                // mid-frame truncation / oversize prefix: count it (the
                // old front end reported these as clean closes, silently
                // dropping requests) and close — no resync is possible
                coord.metrics.record_protocol_error();
                let _ = tx.send(Outgoing::Ready {
                    seq,
                    status: STATUS_ERR,
                    payload: msg.into_bytes(),
                });
                break;
            }
            Err(FrameError::Io(_)) => break,
        };
        let out = dispatch(&coord, seq, &frame);
        if tx.send(out).is_err() {
            break; // writer lost the peer and exited
        }
        seq += 1;
    }
    drop(tx); // writer drains the remaining in-flight replies, then exits
    let _ = writer.join();
    registry.remove(reg_id);
    drop(guard);
    Ok(())
}

/// Parse one well-framed request and either answer it inline or submit
/// it to the coordinator. Malformed payloads and unknown ops are counted
/// protocol errors but keep the connection alive (the frame boundary is
/// known, so the stream is still in sync).
fn dispatch(coord: &Arc<Coordinator>, seq: u64, frame: &[u8]) -> Outgoing {
    let ready = |status: u8, payload: Vec<u8>| Outgoing::Ready {
        seq,
        status,
        payload,
    };
    if frame.is_empty() {
        coord.metrics.record_protocol_error();
        return ready(STATUS_ERR, b"empty frame".to_vec());
    }
    match frame[0] {
        OP_PING => ready(STATUS_OK, b"pong".to_vec()),
        OP_STATS => ready(STATUS_OK, coord.metrics.render().into_bytes()),
        OP_MODELS => ready(STATUS_OK, coord.models().join("\n").into_bytes()),
        OP_PREDICT => match parse_predict(&frame[1..]) {
            Ok((model, img)) => match coord.submit(&model, img) {
                Ok(sub) => Outgoing::Single { seq, sub },
                Err(e) => ready(STATUS_ERR, e.to_string().into_bytes()),
            },
            Err(e) => {
                coord.metrics.record_protocol_error();
                ready(STATUS_ERR, e.to_string().into_bytes())
            }
        },
        OP_PREDICT_BATCH => match parse_predict_batch(&frame[1..]) {
            Ok((model, imgs)) => match coord.submit_many(&model, imgs) {
                Ok(subs) => Outgoing::Batch { seq, subs },
                Err(e) => ready(STATUS_ERR, e.to_string().into_bytes()),
            },
            Err(e) => {
                coord.metrics.record_protocol_error();
                ready(STATUS_ERR, e.to_string().into_bytes())
            }
        },
        op => {
            coord.metrics.record_protocol_error();
            ready(STATUS_ERR, format!("unknown op {op}").into_bytes())
        }
    }
}

/// Resolve one pending submission into a (status, payload) pair.
fn resolve(sub: Submission) -> (u8, Vec<u8>) {
    match sub {
        Submission::Queued(rx) => match rx.recv() {
            Ok(Ok(scores)) => (STATUS_OK, encode_scores(&scores)),
            Ok(Err(e)) => (STATUS_ERR, e.to_string().into_bytes()),
            Err(_) => (STATUS_ERR, b"batcher shut down".to_vec()),
        },
        Submission::Overloaded => (STATUS_OVERLOADED, b"overloaded".to_vec()),
    }
}

/// Serialize a wire-batch response body from resolved (status, item)
/// pairs; oversize items are clamped to err entries so the `u32` item
/// length can never truncate. Shared with the event loop.
pub(crate) fn encode_batch_body(
    items: impl Iterator<Item = (u8, Vec<u8>)>,
    count: usize,
    metrics: &Metrics,
) -> Vec<u8> {
    let mut payload = Vec::new();
    payload.extend_from_slice(&(count as u32).to_le_bytes());
    for (status, item) in items {
        let (status, item) = checked_response(status, item, metrics);
        payload.push(status);
        payload.extend_from_slice(&(item.len() as u32).to_le_bytes());
        payload.extend_from_slice(&item);
    }
    payload
}

fn writer_loop(mut stream: TcpStream, rx: Receiver<Outgoing>, metrics: Arc<Metrics>) {
    let mut expect = 0u64;
    while let Ok(out) = rx.recv() {
        let (seq, status, payload) = match out {
            Outgoing::Ready {
                seq,
                status,
                payload,
            } => (seq, status, payload),
            Outgoing::Single { seq, sub } => {
                let (status, payload) = resolve(sub);
                (seq, status, payload)
            }
            Outgoing::Batch { seq, subs } => {
                let count = subs.len();
                let payload =
                    encode_batch_body(subs.into_iter().map(resolve), count, &metrics);
                (seq, STATUS_OK, payload)
            }
        };
        // an oversize response becomes an err frame, not a truncated
        // length prefix (which would desync every later frame)
        let (status, payload) = checked_response(status, payload, &metrics);
        debug_assert_eq!(seq, expect, "writer must reply in request order");
        expect = seq + 1;
        if write_frame(&mut stream, status, &payload).is_err() {
            // peer gone: unblock the reader side and stop; dropping the
            // remaining submissions just discards their replies
            let _ = stream.shutdown(std::net::Shutdown::Both);
            break;
        }
    }
}

/// Bounds-checked little cursor over a request payload.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!(
                "truncated {what}: need {n} bytes, have {}",
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self, what: &str) -> Result<u16> {
        let b = self.bytes(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.bytes(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

fn parse_model_name(c: &mut Cur) -> Result<String> {
    let name_len = c.u16("predict frame")? as usize;
    let name = c.bytes(name_len, "model name")?;
    String::from_utf8(name.to_vec()).context("model name utf8")
}

pub(crate) fn parse_predict(payload: &[u8]) -> Result<(String, Tensor<u8>)> {
    let mut c = Cur::new(payload);
    let model = parse_model_name(&mut c)?;
    let img_len = c.u32("predict frame")? as usize;
    if c.remaining() != img_len {
        bail!(
            "image length mismatch: header {img_len}, got {}",
            c.remaining()
        );
    }
    let img = c.bytes(img_len, "image")?;
    Ok((
        model,
        Tensor::from_vec(Shape::vector(img_len), img.to_vec()),
    ))
}

pub(crate) fn parse_predict_batch(payload: &[u8]) -> Result<(String, Vec<Tensor<u8>>)> {
    let mut c = Cur::new(payload);
    let model = parse_model_name(&mut c)?;
    let count = c.u32("batch frame")? as usize;
    // zero-image batches are a protocol misuse, not a degenerate success:
    // answer with a clean err frame instead of an empty response body
    if count == 0 {
        bail!("empty batch (count = 0)");
    }
    // each image needs at least its 4-byte length — an absurd count is a
    // framing lie, caught before any allocation
    if count > c.remaining() / 4 {
        bail!(
            "batch count {count} impossible in {} payload bytes",
            c.remaining()
        );
    }
    if count > MAX_BATCH_ITEMS {
        bail!("batch count {count} exceeds limit {MAX_BATCH_ITEMS}");
    }
    let mut imgs = Vec::with_capacity(count);
    for _ in 0..count {
        let img_len = c.u32("batch image length")? as usize;
        let img = c.bytes(img_len, "batch image")?;
        imgs.push(Tensor::from_vec(Shape::vector(img_len), img.to_vec()));
    }
    if c.remaining() != 0 {
        bail!("batch frame has {} trailing bytes", c.remaining());
    }
    Ok((model, imgs))
}

/// One reply from [`Client::try_predict`] / [`Client::predict_batch`]:
/// keeps the wire's ok / err / overloaded distinction instead of
/// flattening everything into an error string.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    Scores(Vec<f32>),
    Err(String),
    Overloaded,
}

impl Reply {
    pub fn scores(self) -> Result<Vec<f32>> {
        match self {
            Reply::Scores(s) => Ok(s),
            Reply::Err(e) => bail!("server error: {e}"),
            Reply::Overloaded => bail!("server overloaded"),
        }
    }
}

/// Simple blocking client for the protocol.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(Self { stream })
    }

    fn call_status(&mut self, op: u8, payload: &[u8]) -> Result<(u8, Vec<u8>)> {
        let len = frame_len_checked(payload.len())?;
        self.stream.write_all(&len.to_le_bytes())?;
        self.stream.write_all(&[op])?;
        self.stream.write_all(payload)?;
        self.stream.flush()?;
        let frame = match read_frame(&mut self.stream) {
            Ok(f) => f,
            Err(FrameError::Closed) => bail!("server closed the connection"),
            Err(FrameError::Protocol(m)) => bail!("protocol error: {m}"),
            Err(FrameError::Io(e)) => return Err(e.into()),
        };
        if frame.is_empty() {
            bail!("empty response");
        }
        Ok((frame[0], frame[1..].to_vec()))
    }

    fn call(&mut self, op: u8, payload: &[u8]) -> Result<Vec<u8>> {
        let (status, body) = self.call_status(op, payload)?;
        match status {
            STATUS_OK => Ok(body),
            STATUS_OVERLOADED => bail!("server overloaded: {}", String::from_utf8_lossy(&body)),
            _ => bail!("server error: {}", String::from_utf8_lossy(&body)),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        let r = self.call(OP_PING, &[])?;
        anyhow::ensure!(r == b"pong", "bad pong");
        Ok(())
    }

    pub fn stats(&mut self) -> Result<String> {
        Ok(String::from_utf8_lossy(&self.call(OP_STATS, &[])?).into_owned())
    }

    pub fn models(&mut self) -> Result<Vec<String>> {
        let r = self.call(OP_MODELS, &[])?;
        Ok(String::from_utf8_lossy(&r)
            .split('\n')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect())
    }

    /// Encode a model name into its `u16 len | bytes` wire field; names
    /// longer than the field can express are an error, not a truncated
    /// cast.
    fn encode_model_name(payload: &mut Vec<u8>, model: &str) -> Result<()> {
        anyhow::ensure!(
            model.len() <= u16::MAX as usize,
            "model name too long: {} bytes exceeds the u16 wire field",
            model.len()
        );
        payload.extend_from_slice(&(model.len() as u16).to_le_bytes());
        payload.extend_from_slice(model.as_bytes());
        Ok(())
    }

    fn predict_payload(model: &str, img: &[u8]) -> Result<Vec<u8>> {
        anyhow::ensure!(
            (img.len() as u64) < MAX_FRAME as u64,
            "image too large: {} bytes exceeds the {MAX_FRAME}-byte frame limit",
            img.len()
        );
        let mut payload = Vec::with_capacity(2 + model.len() + 4 + img.len());
        Self::encode_model_name(&mut payload, model)?;
        payload.extend_from_slice(&(img.len() as u32).to_le_bytes());
        payload.extend_from_slice(img);
        Ok(payload)
    }

    pub fn predict(&mut self, model: &str, img: &[u8]) -> Result<Vec<f32>> {
        self.try_predict(model, img)?.scores()
    }

    /// Like [`Client::predict`] but keeps the overloaded status
    /// distinguishable (for callers implementing backpressure/retry).
    pub fn try_predict(&mut self, model: &str, img: &[u8]) -> Result<Reply> {
        let (status, body) = self.call_status(OP_PREDICT, &Self::predict_payload(model, img)?)?;
        Ok(match status {
            STATUS_OK => Reply::Scores(decode_scores(&body)?),
            STATUS_OVERLOADED => Reply::Overloaded,
            _ => Reply::Err(String::from_utf8_lossy(&body).into_owned()),
        })
    }

    /// Submit `imgs` as ONE `predict_batch` frame (at most
    /// [`MAX_BATCH_ITEMS`] — chunk larger workloads into several frames);
    /// returns one [`Reply`] per image, in order.
    pub fn predict_batch(&mut self, model: &str, imgs: &[&[u8]]) -> Result<Vec<Reply>> {
        anyhow::ensure!(
            !imgs.is_empty(),
            "predict_batch needs at least one image (the server rejects count = 0)"
        );
        anyhow::ensure!(
            imgs.len() <= MAX_BATCH_ITEMS,
            "predict_batch takes at most {MAX_BATCH_ITEMS} images per frame (got {}); \
             split into multiple frames",
            imgs.len()
        );
        let mut payload = Vec::new();
        Self::encode_model_name(&mut payload, model)?;
        payload.extend_from_slice(&(imgs.len() as u32).to_le_bytes());
        for img in imgs {
            payload.extend_from_slice(&(img.len() as u32).to_le_bytes());
            payload.extend_from_slice(img);
        }
        let body = self.call(OP_PREDICT_BATCH, &payload)?;
        let mut c = Cur::new(&body);
        let count = c.u32("batch response")? as usize;
        anyhow::ensure!(
            count == imgs.len(),
            "batch response count {count} != submitted {}",
            imgs.len()
        );
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            let status = c.bytes(1, "batch item status")?[0];
            let len = c.u32("batch item length")? as usize;
            let item = c.bytes(len, "batch item")?;
            out.push(match status {
                STATUS_OK => Reply::Scores(decode_scores(item)?),
                STATUS_OVERLOADED => Reply::Overloaded,
                _ => Reply::Err(String::from_utf8_lossy(item).into_owned()),
            });
        }
        Ok(out)
    }
}

impl Drop for Client {
    fn drop(&mut self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

// convenience re-export for callers that only have anyhow::Error
pub use anyhow::Error as TcpError;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BatchConfig;
    use crate::layers::Backend;
    use crate::net::{bmlp_spec, Network};
    use crate::runtime::NativeEngine;
    use crate::util::rng::Rng;

    fn serve_test_coord() -> (Arc<Coordinator>, ServerHandle) {
        let mut rng = Rng::new(181);
        let spec = bmlp_spec(&mut rng, 64, 1);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let coord = Arc::new(Coordinator::new(BatchConfig::default()));
        coord.register("bmlp", Arc::new(NativeEngine::new(net, "opt")));
        let handle = serve(coord.clone(), "127.0.0.1:0", ServeOptions::default()).unwrap();
        (coord, handle)
    }

    #[test]
    fn full_protocol_roundtrip() {
        let (coord, handle) = serve_test_coord();
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        client.ping().unwrap();
        assert_eq!(client.models().unwrap(), vec!["bmlp"]);
        let mut rng = Rng::new(182);
        let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
        let scores = client.predict("bmlp", &img).unwrap();
        assert_eq!(scores.len(), 10);
        // matches in-process result
        let t = Tensor::from_vec(Shape::vector(784), img);
        let direct = coord.engine("bmlp").unwrap().predict(&t).unwrap();
        assert_eq!(scores, direct);
        // stats are keyed by the REGISTERED model name, not the engine
        // label "opt" (the metrics-keying regression)
        let stats = client.stats().unwrap();
        assert!(stats.contains("bmlp"), "{stats}");
        assert!(coord.metrics.snapshot("opt").is_none());
    }

    #[test]
    fn unknown_model_is_an_error_frame() {
        let (_coord, handle) = serve_test_coord();
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        let err = client.predict("nope", &[0u8; 784]).unwrap_err();
        assert!(err.to_string().contains("unknown model"), "{err}");
    }

    #[test]
    fn concurrent_clients() {
        let (_coord, handle) = serve_test_coord();
        let addr = handle.addr().to_string();
        std::thread::scope(|s| {
            for seed in 0..4u64 {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    let mut rng = Rng::new(seed);
                    for _ in 0..10 {
                        let img: Vec<u8> =
                            (0..784).map(|_| rng.next_u32() as u8).collect();
                        let scores = client.predict("bmlp", &img).unwrap();
                        assert_eq!(scores.len(), 10);
                    }
                });
            }
        });
    }

    #[test]
    fn wire_batch_roundtrip() {
        let (coord, handle) = serve_test_coord();
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        let mut rng = Rng::new(183);
        let imgs: Vec<Vec<u8>> = (0..5)
            .map(|_| (0..784).map(|_| rng.next_u32() as u8).collect())
            .collect();
        let refs: Vec<&[u8]> = imgs.iter().map(|i| i.as_slice()).collect();
        let replies = client.predict_batch("bmlp", &refs).unwrap();
        assert_eq!(replies.len(), 5);
        for (img, reply) in imgs.iter().zip(replies) {
            let t = Tensor::from_vec(Shape::vector(784), img.clone());
            let direct = coord.engine("bmlp").unwrap().predict(&t).unwrap();
            assert_eq!(reply.scores().unwrap(), direct);
        }
    }

    #[test]
    fn connection_cap_rejects_with_overloaded_frame() {
        let mut rng = Rng::new(184);
        let spec = bmlp_spec(&mut rng, 64, 1);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let coord = Arc::new(Coordinator::new(BatchConfig::default()));
        coord.register("bmlp", Arc::new(NativeEngine::new(net, "opt")));
        let handle = serve(
            coord.clone(),
            "127.0.0.1:0",
            ServeOptions {
                max_conns: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let addr = handle.addr().to_string();
        let mut first = Client::connect(&addr).unwrap();
        first.ping().unwrap(); // guarantees the first connection is registered
        // second connection: the server immediately answers with one
        // unsolicited overloaded frame and closes
        let mut second = TcpStream::connect(&addr).unwrap();
        let frame = read_frame(&mut second).unwrap();
        assert_eq!(frame[0], STATUS_OVERLOADED, "{frame:?}");
        assert!(coord.metrics.conns_rejected() >= 1);
        drop(first);
        drop(second);
        // capacity is released once the first connection fully ends
        for _ in 0..200 {
            if let Ok(mut c) = Client::connect(&addr) {
                if c.ping().is_ok() {
                    return;
                }
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        panic!("connection slot never released");
    }

    #[test]
    fn io_model_parses_and_defaults() {
        assert_eq!("event".parse::<IoModel>().unwrap(), IoModel::Event);
        assert_eq!("threads".parse::<IoModel>().unwrap(), IoModel::Threads);
        assert!("kqueue".parse::<IoModel>().is_err());
        if cfg!(target_os = "linux") {
            assert_eq!(IoModel::default(), IoModel::Event);
        }
        assert!(ServeOptions::default().effective_io_loops() >= 1);
    }

    /// Satellite: oversize encodes error out instead of truncating the
    /// u32 length prefix, and the response clamp counts them.
    #[test]
    fn oversize_frames_error_instead_of_truncating() {
        assert_eq!(frame_len_checked(0).unwrap(), 1);
        assert_eq!(
            frame_len_checked(MAX_FRAME as usize - 1).unwrap(),
            MAX_FRAME
        );
        let err = frame_len_checked(MAX_FRAME as usize).unwrap_err();
        assert!(err.to_string().contains("frame too large"), "{err}");
        assert!(frame_len_checked(u32::MAX as usize + 10).is_err());

        let metrics = Metrics::new();
        let (status, payload) = checked_response(STATUS_OK, vec![0u8; 16], &metrics);
        assert_eq!((status, payload.len()), (STATUS_OK, 16));
        assert_eq!(metrics.frames_too_large(), 0);
        let (status, payload) =
            checked_response(STATUS_OK, vec![0u8; MAX_FRAME as usize + 1], &metrics);
        assert_eq!(status, STATUS_ERR);
        assert_eq!(payload, b"response exceeds frame limit".to_vec());
        assert_eq!(metrics.frames_too_large(), 1);
    }

    /// Satellite: a tiny frame claiming a huge (or zero) image count is
    /// rejected before any allocation.
    #[test]
    fn batch_count_lies_are_rejected() {
        let mut payload = Vec::new();
        payload.extend_from_slice(&4u16.to_le_bytes());
        payload.extend_from_slice(b"bmlp");
        payload.extend_from_slice(&0u32.to_le_bytes());
        let err = parse_predict_batch(&payload).unwrap_err();
        assert!(err.to_string().contains("empty batch"), "{err}");

        let mut payload = Vec::new();
        payload.extend_from_slice(&4u16.to_le_bytes());
        payload.extend_from_slice(b"bmlp");
        payload.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = parse_predict_batch(&payload).unwrap_err();
        assert!(err.to_string().contains("impossible"), "{err}");
    }

    #[test]
    fn client_rejects_unencodable_requests() {
        let (_coord, handle) = serve_test_coord();
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        let long_name = "m".repeat(u16::MAX as usize + 1);
        let err = client.predict(&long_name, &[0u8; 4]).unwrap_err();
        assert!(err.to_string().contains("model name too long"), "{err}");
        let err = client.predict_batch("bmlp", &[]).unwrap_err();
        assert!(err.to_string().contains("at least one image"), "{err}");
        // the connection is still usable: nothing was written
        client.ping().unwrap();
    }

    /// The latch releases shutdown as soon as the last serving thread
    /// exits, and both IO models join everything they spawned.
    #[test]
    fn shutdown_joins_serving_threads_in_both_models() {
        for model in [IoModel::Event, IoModel::Threads] {
            let mut rng = Rng::new(190);
            let spec = bmlp_spec(&mut rng, 64, 1);
            let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
            let coord = Arc::new(Coordinator::new(BatchConfig::default()));
            coord.register("bmlp", Arc::new(NativeEngine::new(net, "opt")));
            let mut handle = serve(
                coord,
                "127.0.0.1:0",
                ServeOptions {
                    io_model: model,
                    ..Default::default()
                },
            )
            .unwrap();
            let addr = handle.addr().to_string();
            let mut clients: Vec<_> = (0..4)
                .map(|_| Client::connect(&addr).unwrap())
                .collect();
            for c in &mut clients {
                c.ping().unwrap();
            }
            assert!(handle.serving_threads() >= 1, "{model:?}");
            drop(clients);
            handle.shutdown();
            assert_eq!(
                handle.serving_threads(),
                0,
                "{model:?}: all serving threads joined"
            );
        }
    }
}
