//! Minimal TCP serving protocol (length-prefixed binary frames).
//!
//! Request frame:  `u32 len | u8 op | payload`
//!   op 1 = predict:  `u16 name_len | name | u32 img_len | img bytes`
//!   op 2 = stats:    (empty) → utf8 metrics table
//!   op 3 = ping:     (empty) → "pong"
//!   op 4 = models:   (empty) → newline-separated model names
//! Response frame: `u32 len | u8 status (0 ok / 1 err) | payload`
//!   predict payload = `u32 n | n × f32 scores` (LE); err payload = utf8.

use super::Coordinator;
use crate::tensor::{Shape, Tensor};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

pub const OP_PREDICT: u8 = 1;
pub const OP_STATS: u8 = 2;
pub const OP_PING: u8 = 3;
pub const OP_MODELS: u8 = 4;

const MAX_FRAME: u32 = 64 << 20;

fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        bail!("frame too large: {len}");
    }
    let mut buf = vec![0u8; len as usize];
    stream.read_exact(&mut buf)?;
    Ok(buf)
}

fn write_frame(stream: &mut TcpStream, status: u8, payload: &[u8]) -> Result<()> {
    let len = (payload.len() + 1) as u32;
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(&[status])?;
    stream.write_all(payload)?;
    stream.flush()?;
    Ok(())
}

/// Serve the coordinator on `addr` until `stop` goes true. Each
/// connection gets a handler thread (connections are long-lived and
/// pipeline requests).
pub fn serve(
    coord: Arc<Coordinator>,
    addr: &str,
    stop: Arc<AtomicBool>,
) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    std::thread::Builder::new()
        .name("espresso-accept".into())
        .spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let coord = coord.clone();
                        std::thread::spawn(move || {
                            let _ = handle_conn(coord, stream);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        })
        .context("spawn acceptor")?;
    Ok(local)
}

fn handle_conn(coord: Arc<Coordinator>, mut stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true)?;
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return Ok(()), // peer closed
        };
        if frame.is_empty() {
            write_frame(&mut stream, 1, b"empty frame")?;
            continue;
        }
        match frame[0] {
            OP_PING => write_frame(&mut stream, 0, b"pong")?,
            OP_STATS => write_frame(&mut stream, 0, coord.metrics.render().as_bytes())?,
            OP_MODELS => {
                let names = coord.models().join("\n");
                write_frame(&mut stream, 0, names.as_bytes())?;
            }
            OP_PREDICT => match parse_predict(&frame[1..]) {
                Ok((model, img)) => match coord.predict(&model, img) {
                    Ok(scores) => {
                        let mut payload =
                            Vec::with_capacity(4 + scores.len() * 4);
                        payload.extend_from_slice(&(scores.len() as u32).to_le_bytes());
                        for s in &scores {
                            payload.extend_from_slice(&s.to_le_bytes());
                        }
                        write_frame(&mut stream, 0, &payload)?;
                    }
                    Err(e) => write_frame(&mut stream, 1, e.to_string().as_bytes())?,
                },
                Err(e) => write_frame(&mut stream, 1, e.to_string().as_bytes())?,
            },
            op => write_frame(&mut stream, 1, format!("unknown op {op}").as_bytes())?,
        }
    }
}

fn parse_predict(payload: &[u8]) -> Result<(String, Tensor<u8>)> {
    if payload.len() < 2 {
        bail!("short predict frame");
    }
    let name_len = u16::from_le_bytes([payload[0], payload[1]]) as usize;
    let rest = &payload[2..];
    if rest.len() < name_len + 4 {
        bail!("short predict frame");
    }
    let model = String::from_utf8(rest[..name_len].to_vec()).context("model name utf8")?;
    let img_len = u32::from_le_bytes([
        rest[name_len],
        rest[name_len + 1],
        rest[name_len + 2],
        rest[name_len + 3],
    ]) as usize;
    let img = &rest[name_len + 4..];
    if img.len() != img_len {
        bail!("image length mismatch: header {img_len}, got {}", img.len());
    }
    Ok((
        model,
        Tensor::from_vec(Shape::vector(img_len), img.to_vec()),
    ))
}

/// Simple blocking client for the protocol.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(Self { stream })
    }

    fn call(&mut self, op: u8, payload: &[u8]) -> Result<Vec<u8>> {
        let len = (payload.len() + 1) as u32;
        self.stream.write_all(&len.to_le_bytes())?;
        self.stream.write_all(&[op])?;
        self.stream.write_all(payload)?;
        self.stream.flush()?;
        let frame = read_frame(&mut self.stream)?;
        if frame.is_empty() {
            bail!("empty response");
        }
        if frame[0] != 0 {
            bail!(
                "server error: {}",
                String::from_utf8_lossy(&frame[1..])
            );
        }
        Ok(frame[1..].to_vec())
    }

    pub fn ping(&mut self) -> Result<()> {
        let r = self.call(OP_PING, &[])?;
        anyhow::ensure!(r == b"pong", "bad pong");
        Ok(())
    }

    pub fn stats(&mut self) -> Result<String> {
        Ok(String::from_utf8_lossy(&self.call(OP_STATS, &[])?).into_owned())
    }

    pub fn models(&mut self) -> Result<Vec<String>> {
        let r = self.call(OP_MODELS, &[])?;
        Ok(String::from_utf8_lossy(&r)
            .split('\n')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect())
    }

    pub fn predict(&mut self, model: &str, img: &[u8]) -> Result<Vec<f32>> {
        let mut payload = Vec::with_capacity(2 + model.len() + 4 + img.len());
        payload.extend_from_slice(&(model.len() as u16).to_le_bytes());
        payload.extend_from_slice(model.as_bytes());
        payload.extend_from_slice(&(img.len() as u32).to_le_bytes());
        payload.extend_from_slice(img);
        let r = self.call(OP_PREDICT, &payload)?;
        if r.len() < 4 {
            bail!("short predict response");
        }
        let n = u32::from_le_bytes([r[0], r[1], r[2], r[3]]) as usize;
        if r.len() != 4 + n * 4 {
            bail!("predict response length mismatch");
        }
        Ok(r[4..]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

impl Drop for Client {
    fn drop(&mut self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

// convenience re-export for callers that only have anyhow::Error
pub use anyhow::Error as TcpError;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BatchConfig;
    use crate::layers::Backend;
    use crate::net::{bmlp_spec, Network};
    use crate::runtime::NativeEngine;
    use crate::util::rng::Rng;

    fn serve_test_coord() -> (Arc<Coordinator>, std::net::SocketAddr, Arc<AtomicBool>) {
        let mut rng = Rng::new(181);
        let spec = bmlp_spec(&mut rng, 64, 1);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let coord = Arc::new(Coordinator::new(BatchConfig::default()));
        coord.register("bmlp", Arc::new(NativeEngine::new(net, "opt")));
        let stop = Arc::new(AtomicBool::new(false));
        let addr = serve(coord.clone(), "127.0.0.1:0", stop.clone()).unwrap();
        (coord, addr, stop)
    }

    #[test]
    fn full_protocol_roundtrip() {
        let (coord, addr, stop) = serve_test_coord();
        let mut client = Client::connect(&addr.to_string()).unwrap();
        client.ping().unwrap();
        assert_eq!(client.models().unwrap(), vec!["bmlp"]);
        let mut rng = Rng::new(182);
        let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
        let scores = client.predict("bmlp", &img).unwrap();
        assert_eq!(scores.len(), 10);
        // matches in-process result
        let t = Tensor::from_vec(Shape::vector(784), img);
        let direct = coord.engine("bmlp").unwrap().predict(&t).unwrap();
        assert_eq!(scores, direct);
        let stats = client.stats().unwrap();
        assert!(stats.contains("opt"), "{stats}");
        stop.store(true, Ordering::Relaxed);
    }

    #[test]
    fn unknown_model_is_an_error_frame() {
        let (_coord, addr, stop) = serve_test_coord();
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let err = client.predict("nope", &[0u8; 784]).unwrap_err();
        assert!(err.to_string().contains("unknown model"), "{err}");
        stop.store(true, Ordering::Relaxed);
    }

    #[test]
    fn concurrent_clients() {
        let (_coord, addr, stop) = serve_test_coord();
        std::thread::scope(|s| {
            for seed in 0..4u64 {
                let addr = addr.to_string();
                s.spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    let mut rng = Rng::new(seed);
                    for _ in 0..10 {
                        let img: Vec<u8> =
                            (0..784).map(|_| rng.next_u32() as u8).collect();
                        let scores = client.predict("bmlp", &img).unwrap();
                        assert_eq!(scores.len(), 10);
                    }
                });
            }
        });
        stop.store(true, Ordering::Relaxed);
    }
}
