//! Minimal TCP serving protocol (length-prefixed binary frames).
//!
//! Request frame:  `u32 len | u8 op | payload`
//!   op 1 = predict:       `u16 name_len | name | u32 img_len | img bytes`
//!   op 2 = stats:         (empty) → utf8 metrics table
//!   op 3 = ping:          (empty) → "pong"
//!   op 4 = models:        (empty) → newline-separated model names
//!   op 5 = predict_batch: `u16 name_len | name | u32 count |
//!                          count × (u32 img_len | img bytes)`
//!   op 6 = load_model:    `u16 name_len | name | u32 path_len | path` →
//!                         hot-swaps the model's weights from a server-side
//!                         `.esp` path; ok payload is a 1-score vector
//!                         holding the new version number.
//!   op 7 = health:        (empty) → utf8 table, one line per model:
//!                         `name version alive/replicas inflight
//!                         queued/queue_depth`.
//!   op 8 = drain:         (empty) → "draining"; stops admission (new
//!                         connections and new predict work are turned
//!                         away), flushes the queues, replies to every
//!                         request in flight, then the serving loops exit.
//!
//! The predict ops (1 and 5) accept an **optional deadline**: exactly 4
//! extra trailing bytes, a `u32` budget in milliseconds. The server
//! stamps the deadline at admission and sheds the request with status 3
//! instead of executing it once the budget is spent (a server-side
//! `--request-timeout-ms` applies the same way; whichever is tighter
//! wins).
//!
//! Response frame: `u32 len | u8 status | payload`
//!   status 0 = ok, 1 = err (payload utf8), 2 = overloaded (the model's
//!   admission queue is at `--queue-depth`, or the acceptor is at
//!   `--max-conns`; retry later), 3 = deadline exceeded (the request was
//!   admitted but its deadline expired before execution — distinct from
//!   overloaded so clients can tell shed-by-time from shed-by-queue).
//!   predict ok payload = `u32 n | n × f32 scores` (LE).
//!   predict_batch ok payload = `u32 count | count × (u8 status | u32 len
//!   | item)` — one entry per submitted image, in order; each item is a
//!   predict ok payload (status 0), a utf8 error (status 1), or an
//!   `overloaded` marker (status 2). Partial admission is normal: a batch
//!   that overflows the queue gets scores for the admitted prefix and
//!   status-2 entries for the rest.
//!
//! Connections are **pipelined**: requests are submitted to the
//! coordinator tagged with a per-connection sequence id and replies go
//! back strictly in request order. A client may therefore stream many
//! requests without waiting for responses — combined with op 5 this lets
//! a single socket saturate GEMM-level batching.
//!
//! One front end implements the protocol: nonblocking epoll event loops,
//! one per core (`coordinator::event`). The old thread-per-connection
//! model is retired; `--io-model threads` is rejected with an error
//! (its one-release warn-and-ignore grace window has closed). Two
//! acceptor layouts exist
//! (see [`Acceptor`]): the default binds one `SO_REUSEPORT` listener per
//! loop so the kernel spreads accepts shared-nothing across the loops;
//! `--acceptor single` keeps the previous dedicated dispatching acceptor
//! thread.
//!
//! Error handling: EOF exactly at a frame boundary is a clean close.
//! Mid-frame truncation and oversize length prefixes are **protocol
//! errors** — counted in `Metrics` (they used to be swallowed as clean
//! closes) and fatal to the connection, since the byte stream cannot be
//! resynchronized. Malformed payloads inside a well-framed request
//! (truncated predict payload, `img_len` mismatch, bad UTF-8 model name,
//! unknown op) are also counted, but answered with an err frame and the
//! connection stays alive.

use super::metrics::Metrics;
use super::Coordinator;
use crate::tensor::{Shape, Tensor};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
#[cfg(target_os = "linux")]
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

pub const OP_PREDICT: u8 = 1;
pub const OP_STATS: u8 = 2;
pub const OP_PING: u8 = 3;
pub const OP_MODELS: u8 = 4;
pub const OP_PREDICT_BATCH: u8 = 5;
pub const OP_LOAD_MODEL: u8 = 6;
pub const OP_HEALTH: u8 = 7;
pub const OP_DRAIN: u8 = 8;

pub const STATUS_OK: u8 = 0;
pub const STATUS_ERR: u8 = 1;
pub const STATUS_OVERLOADED: u8 = 2;
pub const STATUS_DEADLINE: u8 = 3;

pub(crate) const MAX_FRAME: u32 = 64 << 20;

/// Upper bound on images in one `predict_batch` frame: without it a
/// 64 MB frame could declare ~16M zero-length images and cost ~1 GB of
/// per-item structs before admission control ever sees them.
pub const MAX_BATCH_ITEMS: usize = 4096;

/// Cap on queued-but-unwritten responses per connection. A pipelining
/// client that never reads its replies eventually has its read interest
/// paused — and therefore its own TCP sends blocked — instead of growing
/// server memory without bound while `queue_depth` slots recycle at
/// batch-drain time.
pub(crate) const MAX_PIPELINE: usize = 256;

/// How reading one frame failed.
#[derive(Debug)]
enum FrameError {
    /// EOF exactly at a frame boundary — the peer closed cleanly.
    Closed,
    /// Framing violation: truncation mid-frame or an oversize length
    /// prefix. The stream cannot be resynchronized.
    Protocol(String),
    /// Transport failure (reset, shutdown, ...).
    Io(std::io::Error),
}

fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>, FrameError> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match stream.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Err(FrameError::Closed),
            Ok(0) => {
                return Err(FrameError::Protocol(format!(
                    "eof inside length prefix ({got}/4 bytes)"
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(FrameError::Protocol(format!(
            "frame length {len} exceeds maximum {MAX_FRAME}"
        )));
    }
    let mut buf = vec![0u8; len as usize];
    if let Err(e) = stream.read_exact(&mut buf) {
        return Err(if e.kind() == std::io::ErrorKind::UnexpectedEof {
            FrameError::Protocol(format!("eof inside {len}-byte frame body"))
        } else {
            FrameError::Io(e)
        });
    }
    Ok(buf)
}

/// Length prefix for a `status/op + payload` frame, or an error when the
/// frame would exceed [`MAX_FRAME`]. The old `(payload.len() + 1) as u32`
/// cast silently truncated oversize lengths, desyncing the stream for
/// every frame after it — too large must be an error, never a wrap.
pub(crate) fn frame_len_checked(payload_len: usize) -> Result<u32> {
    let total = payload_len.saturating_add(1);
    if total > MAX_FRAME as usize {
        bail!("frame too large: {payload_len} payload bytes exceed the {MAX_FRAME}-byte limit");
    }
    Ok(total as u32)
}

/// Clamp one outgoing response to the frame limit: an encodable payload
/// passes through; an oversize one is counted in [`Metrics`] and replaced
/// by a small err frame so the stream stays in sync.
pub(crate) fn checked_response(status: u8, payload: Vec<u8>, metrics: &Metrics) -> (u8, Vec<u8>) {
    if frame_len_checked(payload.len()).is_ok() {
        (status, payload)
    } else {
        metrics.record_frame_too_large();
        (STATUS_ERR, b"response exceeds frame limit".to_vec())
    }
}

fn write_frame(stream: &mut TcpStream, status: u8, payload: &[u8]) -> Result<()> {
    let len = frame_len_checked(payload.len())?;
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(&[status])?;
    stream.write_all(payload)?;
    stream.flush()?;
    Ok(())
}

pub(crate) fn encode_scores(scores: &[f32]) -> Vec<u8> {
    let mut payload = Vec::with_capacity(4 + scores.len() * 4);
    payload.extend_from_slice(&(scores.len() as u32).to_le_bytes());
    for s in scores {
        payload.extend_from_slice(&s.to_le_bytes());
    }
    payload
}

fn decode_scores(r: &[u8]) -> Result<Vec<f32>> {
    if r.len() < 4 {
        bail!("short predict response");
    }
    let n = u32::from_le_bytes([r[0], r[1], r[2], r[3]]) as usize;
    if r.len() != 4 + n * 4 {
        bail!("predict response length mismatch");
    }
    Ok(r[4..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Front-end IO model. Only the event-driven model remains; the
/// thread-per-connection baseline was retired after the A/B window
/// closed, and its flag value no longer parses (see `FromStr`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum IoModel {
    /// Nonblocking epoll event loops, one per core: thread count scales
    /// with cores, not connections.
    #[default]
    Event,
}

impl std::str::FromStr for IoModel {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "event" => Ok(IoModel::Event),
            "threads" => bail!(
                "--io-model threads was removed (the thread-per-connection front end is \
                 retired); use --io-model event"
            ),
            other => bail!("unknown io model {other:?} (expected \"event\")"),
        }
    }
}

/// How listening sockets map onto the event loops.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Acceptor {
    /// One `SO_REUSEPORT` listener per event loop (default): the kernel
    /// hashes incoming connections across the listeners, each loop
    /// accepts on its own socket inside its own epoll — shared-nothing,
    /// no handoff, no dedicated acceptor thread.
    #[default]
    Reuseport,
    /// The previous layout: one blocking acceptor thread dispatches
    /// admitted sockets round-robin to the loops.
    Single,
}

impl std::str::FromStr for Acceptor {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "reuseport" => Ok(Acceptor::Reuseport),
            "single" => Ok(Acceptor::Single),
            other => bail!("unknown acceptor {other:?} (expected \"reuseport\" or \"single\")"),
        }
    }
}

/// Serving front-end policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Concurrent-connection cap; further connects are answered with one
    /// `overloaded` frame and closed.
    pub max_conns: usize,
    /// Which front end multiplexes connections (`--io-model`).
    pub io_model: IoModel,
    /// Number of event loops (`--io-loops`); 0 = one per available core.
    pub io_loops: usize,
    /// Listener layout across the loops (`--acceptor`).
    pub acceptor: Acceptor,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            max_conns: 256,
            io_model: IoModel::default(),
            io_loops: 0,
            acceptor: Acceptor::default(),
        }
    }
}

impl ServeOptions {
    /// Resolve `io_loops = 0` to the core count.
    pub fn effective_io_loops(&self) -> usize {
        if self.io_loops > 0 {
            self.io_loops
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }
}

/// Counts live serving threads (acceptor, IO loops, reject drains) and
/// wakes shutdown the moment the count hits zero — replaces the old
/// 500 ms poll-around-a-deadline wait. Tracks the lifetime peak so
/// benches can verify the thread bound.
pub(crate) struct Latch {
    /// (live, peak)
    state: Mutex<(usize, usize)>,
    cv: Condvar,
}

impl Latch {
    pub(crate) fn new() -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new((0, 0)),
            cv: Condvar::new(),
        })
    }

    /// Register one serving thread; the guard deregisters on drop.
    /// Register BEFORE spawning and move the guard into the thread, so
    /// shutdown can never observe a not-yet-counted thread.
    pub(crate) fn register(self: &Arc<Self>) -> LatchGuard {
        let mut s = self.state.lock().unwrap();
        s.0 += 1;
        s.1 = s.1.max(s.0);
        LatchGuard(self.clone())
    }

    pub(crate) fn count(&self) -> usize {
        self.state.lock().unwrap().0
    }

    pub(crate) fn peak(&self) -> usize {
        self.state.lock().unwrap().1
    }

    /// Block until every registered thread has exited; `false` on
    /// timeout.
    pub(crate) fn wait_zero(&self, timeout: Duration) -> bool {
        let s = self.state.lock().unwrap();
        let (_s, res) = self
            .cv
            .wait_timeout_while(s, timeout, |s| s.0 > 0)
            .unwrap();
        !res.timed_out()
    }
}

pub(crate) struct LatchGuard(Arc<Latch>);

impl Drop for LatchGuard {
    fn drop(&mut self) {
        let mut s = self.0.state.lock().unwrap();
        s.0 -= 1;
        if s.0 == 0 {
            self.0.cv.notify_all();
        }
    }
}

/// State shared between the server handle and every event loop: the
/// graceful-drain flag, a waker per loop, and the deploy threads spawned
/// by `OP_LOAD_MODEL` (tracked so shutdown joins them instead of leaving
/// them detached mid-swap).
pub(crate) struct ServerCtl {
    draining: AtomicBool,
    wakers: Mutex<Vec<Box<dyn Fn() + Send + Sync>>>,
    deploys: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl ServerCtl {
    pub(crate) fn new() -> Arc<Self> {
        Arc::new(Self {
            draining: AtomicBool::new(false),
            wakers: Mutex::new(Vec::new()),
            deploys: Mutex::new(Vec::new()),
        })
    }

    pub(crate) fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Stop admission and wake every loop so it notices. Idempotent.
    pub(crate) fn begin_drain(&self) {
        if !self.draining.swap(true, Ordering::SeqCst) {
            for w in self.wakers.lock().unwrap().iter() {
                w();
            }
        }
    }

    pub(crate) fn register_waker(&self, w: Box<dyn Fn() + Send + Sync>) {
        self.wakers.lock().unwrap().push(w);
    }

    /// Track one in-flight deploy thread; finished ones are reaped
    /// opportunistically so the vector stays bounded under swap churn.
    pub(crate) fn track_deploy(&self, j: std::thread::JoinHandle<()>) {
        let mut d = self.deploys.lock().unwrap();
        d.retain(|h| !h.is_finished());
        d.push(j);
    }

    pub(crate) fn join_deploys(&self) {
        let handles: Vec<_> = self.deploys.lock().unwrap().drain(..).collect();
        for j in handles {
            let _ = j.join();
        }
    }
}

/// Handle to a running server: its bound address and a prompt shutdown.
pub struct ServerHandle {
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    latch: Arc<Latch>,
    joins: Vec<std::thread::JoinHandle<()>>,
    /// One wake per event loop: makes its epoll_wait return so it can
    /// observe `stop`.
    wakers: Vec<Box<dyn Fn() + Send + Sync>>,
    ctl: Arc<ServerCtl>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.local
    }

    /// Begin a graceful drain: new connections and new predict work are
    /// turned away, queued work is flushed and answered, and each IO
    /// loop exits once its connections are idle. Follow with
    /// [`ServerHandle::wait_idle`] and then [`ServerHandle::shutdown`].
    pub fn begin_drain(&self) {
        self.ctl.begin_drain();
    }

    pub fn draining(&self) -> bool {
        self.ctl.draining()
    }

    /// Block until every serving thread has exited (e.g. after a drain);
    /// `false` on timeout.
    pub fn wait_idle(&self, timeout: Duration) -> bool {
        self.latch.wait_zero(timeout)
    }

    /// Live serving-thread count (acceptor + IO loops + reject drains).
    /// Batcher threads are per-model-replica, not per-connection, and
    /// are not counted here.
    pub fn serving_threads(&self) -> usize {
        self.latch.count()
    }

    /// Lifetime high-water mark of [`ServerHandle::serving_threads`].
    pub fn serving_thread_peak(&self) -> usize {
        self.latch.peak()
    }

    /// Stop serving: wakes every IO loop (and the acceptor, if any),
    /// then blocks on a condvar latch that trips the moment the last
    /// serving thread exits (no polling), and joins them all.
    pub fn shutdown(&mut self) {
        if self.joins.is_empty() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        for w in &self.wakers {
            w();
        }
        // wake a blocking accept (single-acceptor mode); a wildcard bind
        // (0.0.0.0/[::]) is not connectable on every platform, so aim
        // the wake at loopback. Harmless under reuseport (one loop
        // accepts the probe, sees `stop`, and drops it).
        #[cfg(target_os = "linux")]
        {
            let mut wake = self.local;
            if wake.ip().is_unspecified() {
                wake.set_ip(match wake {
                    SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                    SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                });
            }
            let _ = TcpStream::connect(wake);
        }
        let _ = self.latch.wait_zero(Duration::from_secs(10));
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
        // deploy threads spawned by OP_LOAD_MODEL run outside the latch;
        // join them too so shutdown never abandons a half-done swap
        self.ctl.join_deploys();
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Holds one admitted connection's slot in the `--max-conns` budget;
/// freed on drop when the connection fully ends.
pub(crate) struct ConnGuard(Arc<AtomicUsize>);

impl ConnGuard {
    /// Atomically claim a connection slot against `cap`. The
    /// reserve-or-reject is one `fetch_update`, so concurrent acceptors
    /// (one per reuseport loop) can never jointly over-admit the way a
    /// load-then-increment would.
    pub(crate) fn admit(active: &Arc<AtomicUsize>, cap: usize) -> Option<Self> {
        active
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |a| {
                if a >= cap {
                    None
                } else {
                    Some(a + 1)
                }
            })
            .ok()
            .map(|_| Self(active.clone()))
    }
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// `SO_REUSEPORT` listener creation via raw syscalls (no libc crate in
/// the offline build; glibc is already linked by std).
#[cfg(target_os = "linux")]
mod reuseport {
    use std::net::{SocketAddr, TcpListener};
    use std::os::fd::FromRawFd;
    use std::os::raw::c_int;

    mod sys {
        use std::os::raw::c_int;

        pub const AF_INET: c_int = 2;
        pub const AF_INET6: c_int = 10;
        pub const SOCK_STREAM: c_int = 1;
        pub const SOCK_CLOEXEC: c_int = 0o2000000;
        pub const SOL_SOCKET: c_int = 1;
        pub const SO_REUSEADDR: c_int = 2;
        pub const SO_REUSEPORT: c_int = 15;

        extern "C" {
            pub fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
            pub fn setsockopt(
                fd: c_int,
                level: c_int,
                name: c_int,
                value: *const u8,
                len: u32,
            ) -> c_int;
            pub fn bind(fd: c_int, addr: *const u8, len: u32) -> c_int;
            pub fn listen(fd: c_int, backlog: c_int) -> c_int;
            pub fn close(fd: c_int) -> c_int;
        }
    }

    /// Serialize a `sockaddr_in` / `sockaddr_in6` for `bind(2)`.
    /// `sin_family` is native-endian, ports and addresses network-order.
    fn sockaddr_bytes(addr: SocketAddr) -> (Vec<u8>, c_int) {
        match addr {
            SocketAddr::V4(a) => {
                let mut b = vec![0u8; 16];
                b[0..2].copy_from_slice(&(sys::AF_INET as u16).to_ne_bytes());
                b[2..4].copy_from_slice(&a.port().to_be_bytes());
                b[4..8].copy_from_slice(&a.ip().octets());
                (b, sys::AF_INET)
            }
            SocketAddr::V6(a) => {
                let mut b = vec![0u8; 28];
                b[0..2].copy_from_slice(&(sys::AF_INET6 as u16).to_ne_bytes());
                b[2..4].copy_from_slice(&a.port().to_be_bytes());
                b[4..8].copy_from_slice(&a.flowinfo().to_be_bytes());
                b[8..24].copy_from_slice(&a.ip().octets());
                b[24..28].copy_from_slice(&a.scope_id().to_ne_bytes());
                (b, sys::AF_INET6)
            }
        }
    }

    /// Bind + listen on `addr` with `SO_REUSEPORT` set, so several
    /// listeners can share one port and the kernel load-balances
    /// incoming connections across them.
    pub(crate) fn listener(addr: SocketAddr) -> std::io::Result<TcpListener> {
        let (sa, domain) = sockaddr_bytes(addr);
        let fd = unsafe { sys::socket(domain, sys::SOCK_STREAM | sys::SOCK_CLOEXEC, 0) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        let fail = |fd: c_int| {
            let e = std::io::Error::last_os_error();
            unsafe {
                sys::close(fd);
            }
            Err(e)
        };
        let one: c_int = 1;
        for opt in [sys::SO_REUSEADDR, sys::SO_REUSEPORT] {
            let rc = unsafe {
                sys::setsockopt(
                    fd,
                    sys::SOL_SOCKET,
                    opt,
                    &one as *const c_int as *const u8,
                    std::mem::size_of::<c_int>() as u32,
                )
            };
            if rc < 0 {
                return fail(fd);
            }
        }
        if unsafe { sys::bind(fd, sa.as_ptr(), sa.len() as u32) } < 0 {
            return fail(fd);
        }
        if unsafe { sys::listen(fd, 1024) } < 0 {
            return fail(fd);
        }
        Ok(unsafe { TcpListener::from_raw_fd(fd) })
    }
}

/// Serve the coordinator on `addr` until the returned handle is shut
/// down. Connections multiplex over a fixed pool of epoll loops; under
/// the default [`Acceptor::Reuseport`] each loop accepts on its own
/// `SO_REUSEPORT` listener, under [`Acceptor::Single`] one dispatching
/// acceptor thread feeds them round-robin.
#[cfg(target_os = "linux")]
pub fn serve(coord: Arc<Coordinator>, addr: &str, opts: ServeOptions) -> Result<ServerHandle> {
    use super::event::{self, AcceptCtx};
    use std::net::{TcpListener, ToSocketAddrs};

    let n = opts.effective_io_loops().max(1);
    let stop = Arc::new(AtomicBool::new(false));
    let latch = Latch::new();
    let active = Arc::new(AtomicUsize::new(0));
    let reject_drains = Arc::new(AtomicUsize::new(0));
    let ctl = ServerCtl::new();

    match opts.acceptor {
        Acceptor::Reuseport => {
            // bind the first listener (may carry port 0), then clone its
            // concrete resolved address for the rest of the group
            let requested = addr
                .to_socket_addrs()
                .with_context(|| format!("resolve {addr}"))?
                .next()
                .with_context(|| format!("resolve {addr}: no addresses"))?;
            let first =
                reuseport::listener(requested).with_context(|| format!("bind {addr}"))?;
            let local = first.local_addr()?;
            let mut listeners = vec![first];
            for _ in 1..n {
                listeners.push(
                    reuseport::listener(local)
                        .with_context(|| format!("bind reuseport group member on {local}"))?,
                );
            }
            let mut joins = Vec::with_capacity(n);
            let mut wakers: Vec<Box<dyn Fn() + Send + Sync>> = Vec::with_capacity(n);
            for (i, listener) in listeners.into_iter().enumerate() {
                let ctx = AcceptCtx {
                    listener,
                    active: active.clone(),
                    max_conns: opts.max_conns,
                    reject_drains: reject_drains.clone(),
                    latch: latch.clone(),
                    stop: stop.clone(),
                };
                let l =
                    event::spawn_loop(i, coord.clone(), stop.clone(), &latch, &ctl, Some(ctx))?;
                let s = l.shared.clone();
                ctl.register_waker(Box::new({
                    let s = s.clone();
                    move || s.wake()
                }));
                wakers.push(Box::new(move || s.wake()));
                joins.push(l.join);
            }
            Ok(ServerHandle {
                local,
                stop,
                latch,
                joins,
                wakers,
                ctl,
            })
        }
        Acceptor::Single => {
            let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
            let local = listener.local_addr()?;
            let mut joins = Vec::with_capacity(n + 1);
            let mut wakers: Vec<Box<dyn Fn() + Send + Sync>> = Vec::with_capacity(n);
            let mut shared = Vec::with_capacity(n);
            for i in 0..n {
                let l = event::spawn_loop(i, coord.clone(), stop.clone(), &latch, &ctl, None)?;
                let s = l.shared.clone();
                ctl.register_waker(Box::new({
                    let s = s.clone();
                    move || s.wake()
                }));
                wakers.push(Box::new({
                    let s = s.clone();
                    move || s.wake()
                }));
                shared.push(s);
                joins.push(l.join);
            }
            // a drain must also unblock the acceptor's blocking accept()
            {
                let mut wake = local;
                if wake.ip().is_unspecified() {
                    wake.set_ip(match wake {
                        SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                        SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                    });
                }
                ctl.register_waker(Box::new(move || {
                    let _ = TcpStream::connect(wake);
                }));
            }
            let accept_guard = latch.register();
            let accept_stop = stop.clone();
            let accept_ctl = ctl.clone();
            let accept_latch = latch.clone();
            let metrics = coord.metrics.clone();
            let accept_join = std::thread::Builder::new()
                .name("espresso-accept".into())
                .spawn(move || {
                    let _guard = accept_guard;
                    let mut next = 0usize;
                    loop {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                if accept_stop.load(Ordering::SeqCst) {
                                    break; // shutdown wake-up connection
                                }
                                if accept_ctl.draining() {
                                    // answer the probe (or a late client)
                                    // once, then stop accepting for good
                                    let mut stream = stream;
                                    let _ =
                                        write_frame(&mut stream, STATUS_ERR, b"server draining");
                                    break;
                                }
                                match ConnGuard::admit(&active, opts.max_conns) {
                                    Some(guard) => {
                                        shared[next % shared.len()].push_conn(stream, guard);
                                        next += 1;
                                    }
                                    None => {
                                        metrics.record_conn_rejected();
                                        reject_conn(
                                            stream,
                                            reject_drains.clone(),
                                            &accept_latch,
                                            accept_stop.clone(),
                                        );
                                    }
                                }
                            }
                            Err(_) => {
                                if accept_stop.load(Ordering::SeqCst) || accept_ctl.draining() {
                                    break;
                                }
                                // transient accept failure (e.g.
                                // ECONNABORTED): don't spin if it persists
                                std::thread::sleep(Duration::from_millis(1));
                            }
                        }
                    }
                })
                .context("spawn acceptor")?;
            joins.insert(0, accept_join);
            Ok(ServerHandle {
                local,
                stop,
                latch,
                joins,
                wakers,
                ctl,
            })
        }
    }
}

/// The serving front end is epoll-based; there is no fallback on other
/// platforms (the retired thread-per-connection model was the last one).
#[cfg(not(target_os = "linux"))]
pub fn serve(_coord: Arc<Coordinator>, _addr: &str, _opts: ServeOptions) -> Result<ServerHandle> {
    bail!("the serving front end requires Linux (epoll)")
}

/// Cap on concurrent reject-drain threads: under a connection flood the
/// polite path below would otherwise spawn one thread per reject,
/// defeating the resource bound `--max-conns` exists to provide.
const MAX_REJECT_DRAINS: usize = 64;

/// Turn away one over-capacity connection with a readable `overloaded`
/// frame. Closing immediately would send an RST whenever the client has
/// already written its first request (unread bytes in our receive buffer
/// destroy the queued frame on Linux), so: write, half-close, then drain
/// whatever the client sent — off the accepting thread, with a hard
/// deadline so a byte-trickling peer cannot pin the drain. Past
/// `MAX_REJECT_DRAINS` concurrent drains the connection is just dropped
/// (an RST is acceptable under that much reject pressure).
pub(crate) fn reject_conn(
    mut stream: TcpStream,
    drains: Arc<AtomicUsize>,
    latch: &Arc<Latch>,
    stop: Arc<AtomicBool>,
) {
    let admitted = drains
        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |d| {
            if d >= MAX_REJECT_DRAINS {
                None
            } else {
                Some(d + 1)
            }
        })
        .is_ok();
    if !admitted {
        return;
    }
    let guard = latch.register();
    let spawned = std::thread::Builder::new()
        .name("espresso-reject".into())
        .spawn(move || {
            let _lg = guard;
            let _ = write_frame(
                &mut stream,
                STATUS_OVERLOADED,
                b"server at connection capacity",
            );
            let _ = stream.shutdown(std::net::Shutdown::Write);
            let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
            let deadline = std::time::Instant::now() + Duration::from_millis(500);
            let mut sink = [0u8; 4096];
            while std::time::Instant::now() < deadline && !stop.load(Ordering::SeqCst) {
                match stream.read(&mut sink) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => continue,
                }
            }
            drains.fetch_sub(1, Ordering::SeqCst);
        });
    if spawned.is_err() {
        drains.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Serialize a wire-batch response body from resolved (status, item)
/// pairs; oversize items are clamped to err entries so the `u32` item
/// length can never truncate.
pub(crate) fn encode_batch_body(
    items: impl Iterator<Item = (u8, Vec<u8>)>,
    count: usize,
    metrics: &Metrics,
) -> Vec<u8> {
    let mut payload = Vec::new();
    payload.extend_from_slice(&(count as u32).to_le_bytes());
    for (status, item) in items {
        let (status, item) = checked_response(status, item, metrics);
        payload.push(status);
        payload.extend_from_slice(&(item.len() as u32).to_le_bytes());
        payload.extend_from_slice(&item);
    }
    payload
}

/// Bounds-checked little cursor over a request payload.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!(
                "truncated {what}: need {n} bytes, have {}",
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self, what: &str) -> Result<u16> {
        let b = self.bytes(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.bytes(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

fn parse_model_name(c: &mut Cur) -> Result<String> {
    let name_len = c.u16("predict frame")? as usize;
    let name = c.bytes(name_len, "model name")?;
    String::from_utf8(name.to_vec()).context("model name utf8")
}

/// Optional deadline tail on the predict ops: exactly 4 trailing bytes,
/// a `u32` millisecond budget. Anything else left over is a framing
/// error (the old "no trailing bytes" rule, kept for 0 and generalized).
fn parse_deadline_tail(c: &mut Cur, what: &str) -> Result<Option<u32>> {
    match c.remaining() {
        0 => Ok(None),
        4 => Ok(Some(c.u32("deadline")?)),
        n => bail!("{what} has {n} trailing bytes (deadline tail is exactly 4)"),
    }
}

pub(crate) fn parse_predict(payload: &[u8]) -> Result<(String, Tensor<u8>, Option<u32>)> {
    let mut c = Cur::new(payload);
    let model = parse_model_name(&mut c)?;
    let img_len = c.u32("predict frame")? as usize;
    if c.remaining() != img_len && c.remaining() != img_len + 4 {
        bail!(
            "image length mismatch: header {img_len}, got {}",
            c.remaining()
        );
    }
    let img = c.bytes(img_len, "image")?;
    let tensor = Tensor::from_vec(Shape::vector(img_len), img.to_vec());
    let deadline_ms = parse_deadline_tail(&mut c, "predict frame")?;
    Ok((model, tensor, deadline_ms))
}

pub(crate) fn parse_predict_batch(payload: &[u8]) -> Result<(String, Vec<Tensor<u8>>, Option<u32>)> {
    let mut c = Cur::new(payload);
    let model = parse_model_name(&mut c)?;
    let count = c.u32("batch frame")? as usize;
    // zero-image batches are a protocol misuse, not a degenerate success:
    // answer with a clean err frame instead of an empty response body
    if count == 0 {
        bail!("empty batch (count = 0)");
    }
    // each image needs at least its 4-byte length — an absurd count is a
    // framing lie, caught before any allocation
    if count > c.remaining() / 4 {
        bail!(
            "batch count {count} impossible in {} payload bytes",
            c.remaining()
        );
    }
    if count > MAX_BATCH_ITEMS {
        bail!("batch count {count} exceeds limit {MAX_BATCH_ITEMS}");
    }
    let mut imgs = Vec::with_capacity(count);
    for _ in 0..count {
        let img_len = c.u32("batch image length")? as usize;
        let img = c.bytes(img_len, "batch image")?;
        imgs.push(Tensor::from_vec(Shape::vector(img_len), img.to_vec()));
    }
    let deadline_ms = parse_deadline_tail(&mut c, "batch frame")?;
    Ok((model, imgs, deadline_ms))
}

/// `load_model` payload: `u16 name_len | name | u32 path_len | path`.
pub(crate) fn parse_load_model(payload: &[u8]) -> Result<(String, String)> {
    let mut c = Cur::new(payload);
    let model = parse_model_name(&mut c)?;
    let path_len = c.u32("load_model frame")? as usize;
    let path = c.bytes(path_len, "model path")?;
    if c.remaining() != 0 {
        bail!("load_model frame has {} trailing bytes", c.remaining());
    }
    let path = String::from_utf8(path.to_vec()).context("model path utf8")?;
    Ok((model, path))
}

/// One reply from [`Client::try_predict`] / [`Client::predict_batch`]:
/// keeps the wire's ok / err / overloaded distinction instead of
/// flattening everything into an error string.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    Scores(Vec<f32>),
    Err(String),
    Overloaded,
    /// The request was admitted but shed when its deadline expired
    /// before execution (wire status 3).
    DeadlineExceeded,
}

impl Reply {
    pub fn scores(self) -> Result<Vec<f32>> {
        match self {
            Reply::Scores(s) => Ok(s),
            Reply::Err(e) => bail!("server error: {e}"),
            Reply::Overloaded => bail!("server overloaded"),
            Reply::DeadlineExceeded => bail!("deadline exceeded"),
        }
    }
}

/// Client-side connection policy: IO timeouts and bounded, jittered
/// retry on connect (refused/timed-out connects are common while a
/// server restarts or drains).
#[derive(Clone, Copy, Debug, Default)]
pub struct ClientOptions {
    /// Applied to connect AND to each response read; `None` blocks
    /// forever (the old behavior).
    pub timeout: Option<Duration>,
    /// Extra connect attempts after the first failure, spaced by a
    /// jittered exponential backoff starting at ~10 ms.
    pub retries: u32,
}

/// Simple blocking client for the protocol.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        Self::connect_with(addr, ClientOptions::default())
    }

    pub fn connect_with(addr: &str, opts: ClientOptions) -> Result<Self> {
        use std::net::ToSocketAddrs;
        let target = addr
            .to_socket_addrs()
            .with_context(|| format!("resolve {addr}"))?
            .next()
            .with_context(|| format!("resolve {addr}: no addresses"))?;
        // jitter seed: nothing here needs cryptographic quality, just
        // decorrelated clients
        let seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos() as u64 ^ d.as_secs())
            .unwrap_or(1);
        let mut rng = crate::util::rng::Rng::new(seed | 1);
        let mut last_err = None;
        for attempt in 0..=opts.retries {
            if attempt > 0 {
                let base = 10u64 << (attempt - 1).min(6); // 10ms..640ms
                let jittered = base / 2 + rng.next_u64() % base;
                std::thread::sleep(Duration::from_millis(jittered));
            }
            let connected = match opts.timeout {
                Some(t) => TcpStream::connect_timeout(&target, t),
                None => TcpStream::connect(target),
            };
            match connected {
                Ok(stream) => {
                    stream.set_nodelay(true)?;
                    stream.set_read_timeout(opts.timeout)?;
                    return Ok(Self { stream });
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap()).with_context(|| {
            format!("connect {addr} ({} attempts)", opts.retries as u64 + 1)
        })
    }

    fn call_status(&mut self, op: u8, payload: &[u8]) -> Result<(u8, Vec<u8>)> {
        let len = frame_len_checked(payload.len())?;
        self.stream.write_all(&len.to_le_bytes())?;
        self.stream.write_all(&[op])?;
        self.stream.write_all(payload)?;
        self.stream.flush()?;
        let frame = match read_frame(&mut self.stream) {
            Ok(f) => f,
            Err(FrameError::Closed) => bail!("server closed the connection"),
            Err(FrameError::Protocol(m)) => bail!("protocol error: {m}"),
            Err(FrameError::Io(e)) => return Err(e.into()),
        };
        if frame.is_empty() {
            bail!("empty response");
        }
        Ok((frame[0], frame[1..].to_vec()))
    }

    fn call(&mut self, op: u8, payload: &[u8]) -> Result<Vec<u8>> {
        let (status, body) = self.call_status(op, payload)?;
        match status {
            STATUS_OK => Ok(body),
            STATUS_OVERLOADED => bail!("server overloaded: {}", String::from_utf8_lossy(&body)),
            STATUS_DEADLINE => bail!("deadline exceeded: {}", String::from_utf8_lossy(&body)),
            _ => bail!("server error: {}", String::from_utf8_lossy(&body)),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        let r = self.call(OP_PING, &[])?;
        anyhow::ensure!(r == b"pong", "bad pong");
        Ok(())
    }

    pub fn stats(&mut self) -> Result<String> {
        Ok(String::from_utf8_lossy(&self.call(OP_STATS, &[])?).into_owned())
    }

    /// Per-model replica liveness / queue-depth table (op 7): one utf8
    /// line per model.
    pub fn health(&mut self) -> Result<String> {
        Ok(String::from_utf8_lossy(&self.call(OP_HEALTH, &[])?).into_owned())
    }

    /// Ask the server to drain gracefully (op 8): admission stops, work
    /// in flight is answered, then the serving loops exit.
    pub fn drain(&mut self) -> Result<()> {
        let r = self.call(OP_DRAIN, &[])?;
        anyhow::ensure!(r == b"draining", "bad drain ack");
        Ok(())
    }

    pub fn models(&mut self) -> Result<Vec<String>> {
        let r = self.call(OP_MODELS, &[])?;
        Ok(String::from_utf8_lossy(&r)
            .split('\n')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect())
    }

    /// Encode a model name into its `u16 len | bytes` wire field; names
    /// longer than the field can express are an error, not a truncated
    /// cast.
    fn encode_model_name(payload: &mut Vec<u8>, model: &str) -> Result<()> {
        anyhow::ensure!(
            model.len() <= u16::MAX as usize,
            "model name too long: {} bytes exceeds the u16 wire field",
            model.len()
        );
        payload.extend_from_slice(&(model.len() as u16).to_le_bytes());
        payload.extend_from_slice(model.as_bytes());
        Ok(())
    }

    fn predict_payload(model: &str, img: &[u8]) -> Result<Vec<u8>> {
        anyhow::ensure!(
            (img.len() as u64) < MAX_FRAME as u64,
            "image too large: {} bytes exceeds the {MAX_FRAME}-byte frame limit",
            img.len()
        );
        let mut payload = Vec::with_capacity(2 + model.len() + 4 + img.len());
        Self::encode_model_name(&mut payload, model)?;
        payload.extend_from_slice(&(img.len() as u32).to_le_bytes());
        payload.extend_from_slice(img);
        Ok(payload)
    }

    pub fn predict(&mut self, model: &str, img: &[u8]) -> Result<Vec<f32>> {
        self.try_predict(model, img)?.scores()
    }

    /// Like [`Client::predict`] but keeps the overloaded / deadline
    /// statuses distinguishable (for callers implementing
    /// backpressure/retry).
    pub fn try_predict(&mut self, model: &str, img: &[u8]) -> Result<Reply> {
        self.try_predict_deadline(model, img, None)
    }

    /// [`Client::try_predict`] with an optional request deadline in
    /// milliseconds: the server sheds the request with
    /// [`Reply::DeadlineExceeded`] instead of executing it late.
    pub fn try_predict_deadline(
        &mut self,
        model: &str,
        img: &[u8],
        deadline_ms: Option<u32>,
    ) -> Result<Reply> {
        let mut payload = Self::predict_payload(model, img)?;
        if let Some(ms) = deadline_ms {
            payload.extend_from_slice(&ms.to_le_bytes());
        }
        let (status, body) = self.call_status(OP_PREDICT, &payload)?;
        Self::decode_reply(status, &body)
    }

    fn decode_reply(status: u8, body: &[u8]) -> Result<Reply> {
        Ok(match status {
            STATUS_OK => Reply::Scores(decode_scores(body)?),
            STATUS_OVERLOADED => Reply::Overloaded,
            STATUS_DEADLINE => Reply::DeadlineExceeded,
            _ => Reply::Err(String::from_utf8_lossy(body).into_owned()),
        })
    }

    /// Submit `imgs` as ONE `predict_batch` frame (at most
    /// [`MAX_BATCH_ITEMS`] — chunk larger workloads into several frames);
    /// returns one [`Reply`] per image, in order.
    pub fn predict_batch(&mut self, model: &str, imgs: &[&[u8]]) -> Result<Vec<Reply>> {
        self.predict_batch_deadline(model, imgs, None)
    }

    /// [`Client::predict_batch`] with an optional per-request deadline
    /// in milliseconds applied to every image in the frame.
    pub fn predict_batch_deadline(
        &mut self,
        model: &str,
        imgs: &[&[u8]],
        deadline_ms: Option<u32>,
    ) -> Result<Vec<Reply>> {
        anyhow::ensure!(
            !imgs.is_empty(),
            "predict_batch needs at least one image (the server rejects count = 0)"
        );
        anyhow::ensure!(
            imgs.len() <= MAX_BATCH_ITEMS,
            "predict_batch takes at most {MAX_BATCH_ITEMS} images per frame (got {}); \
             split into multiple frames",
            imgs.len()
        );
        let mut payload = Vec::new();
        Self::encode_model_name(&mut payload, model)?;
        payload.extend_from_slice(&(imgs.len() as u32).to_le_bytes());
        for img in imgs {
            payload.extend_from_slice(&(img.len() as u32).to_le_bytes());
            payload.extend_from_slice(img);
        }
        if let Some(ms) = deadline_ms {
            payload.extend_from_slice(&ms.to_le_bytes());
        }
        let body = self.call(OP_PREDICT_BATCH, &payload)?;
        let mut c = Cur::new(&body);
        let count = c.u32("batch response")? as usize;
        anyhow::ensure!(
            count == imgs.len(),
            "batch response count {count} != submitted {}",
            imgs.len()
        );
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            let status = c.bytes(1, "batch item status")?[0];
            let len = c.u32("batch item length")? as usize;
            let item = c.bytes(len, "batch item")?;
            out.push(Self::decode_reply(status, item)?);
        }
        Ok(out)
    }

    /// Hot-swap `model`'s weights from a **server-side** `.esp` path;
    /// returns the new version number once the swap is live. Blocks
    /// through the server's load + warm + flip (tens of ms to seconds
    /// depending on model size) — run it on its own connection if
    /// latency-sensitive traffic shares the client.
    pub fn load_model(&mut self, model: &str, path: &str) -> Result<u64> {
        let mut payload = Vec::new();
        Self::encode_model_name(&mut payload, model)?;
        payload.extend_from_slice(&(path.len() as u32).to_le_bytes());
        payload.extend_from_slice(path.as_bytes());
        let body = self.call(OP_LOAD_MODEL, &payload)?;
        let scores = decode_scores(&body)?;
        anyhow::ensure!(scores.len() == 1, "malformed load_model response");
        Ok(scores[0] as u64)
    }
}

impl Drop for Client {
    fn drop(&mut self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

// convenience re-export for callers that only have anyhow::Error
pub use anyhow::Error as TcpError;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BatchConfig;
    use crate::layers::Backend;
    use crate::net::{bmlp_spec, Network};
    use crate::runtime::NativeEngine;
    use crate::util::rng::Rng;

    fn serve_test_coord() -> (Arc<Coordinator>, ServerHandle) {
        let mut rng = Rng::new(181);
        let spec = bmlp_spec(&mut rng, 64, 1);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let coord = Arc::new(Coordinator::new(BatchConfig::default()));
        coord.register("bmlp", Arc::new(NativeEngine::new(net, "opt")));
        let handle = serve(coord.clone(), "127.0.0.1:0", ServeOptions::default()).unwrap();
        (coord, handle)
    }

    #[test]
    fn full_protocol_roundtrip() {
        let (coord, handle) = serve_test_coord();
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        client.ping().unwrap();
        assert_eq!(client.models().unwrap(), vec!["bmlp"]);
        let mut rng = Rng::new(182);
        let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
        let scores = client.predict("bmlp", &img).unwrap();
        assert_eq!(scores.len(), 10);
        // matches in-process result
        let t = Tensor::from_vec(Shape::vector(784), img);
        let direct = coord.engine("bmlp").unwrap().predict(&t).unwrap();
        assert_eq!(scores, direct);
        // stats are keyed by the REGISTERED model name, not the engine
        // label "opt" (the metrics-keying regression)
        let stats = client.stats().unwrap();
        assert!(stats.contains("bmlp"), "{stats}");
        assert!(coord.metrics.snapshot("opt").is_none());
    }

    #[test]
    fn unknown_model_is_an_error_frame() {
        let (_coord, handle) = serve_test_coord();
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        let err = client.predict("nope", &[0u8; 784]).unwrap_err();
        assert!(err.to_string().contains("unknown model"), "{err}");
    }

    #[test]
    fn concurrent_clients() {
        let (_coord, handle) = serve_test_coord();
        let addr = handle.addr().to_string();
        std::thread::scope(|s| {
            for seed in 0..4u64 {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    let mut rng = Rng::new(seed);
                    for _ in 0..10 {
                        let img: Vec<u8> =
                            (0..784).map(|_| rng.next_u32() as u8).collect();
                        let scores = client.predict("bmlp", &img).unwrap();
                        assert_eq!(scores.len(), 10);
                    }
                });
            }
        });
    }

    #[test]
    fn wire_batch_roundtrip() {
        let (coord, handle) = serve_test_coord();
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        let mut rng = Rng::new(183);
        let imgs: Vec<Vec<u8>> = (0..5)
            .map(|_| (0..784).map(|_| rng.next_u32() as u8).collect())
            .collect();
        let refs: Vec<&[u8]> = imgs.iter().map(|i| i.as_slice()).collect();
        let replies = client.predict_batch("bmlp", &refs).unwrap();
        assert_eq!(replies.len(), 5);
        for (img, reply) in imgs.iter().zip(replies) {
            let t = Tensor::from_vec(Shape::vector(784), img.clone());
            let direct = coord.engine("bmlp").unwrap().predict(&t).unwrap();
            assert_eq!(reply.scores().unwrap(), direct);
        }
    }

    #[test]
    fn connection_cap_rejects_with_overloaded_frame() {
        // both acceptor layouts must enforce --max-conns; reuseport
        // admission races across loops, so the shared atomic budget is
        // load-bearing here
        for acceptor in [Acceptor::Reuseport, Acceptor::Single] {
            let mut rng = Rng::new(184);
            let spec = bmlp_spec(&mut rng, 64, 1);
            let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
            let coord = Arc::new(Coordinator::new(BatchConfig::default()));
            coord.register("bmlp", Arc::new(NativeEngine::new(net, "opt")));
            let handle = serve(
                coord.clone(),
                "127.0.0.1:0",
                ServeOptions {
                    max_conns: 1,
                    acceptor,
                    ..Default::default()
                },
            )
            .unwrap();
            let addr = handle.addr().to_string();
            let mut first = Client::connect(&addr).unwrap();
            first.ping().unwrap(); // guarantees the first connection is registered
            // second connection: the server immediately answers with one
            // unsolicited overloaded frame and closes
            let mut second = TcpStream::connect(&addr).unwrap();
            let frame = read_frame(&mut second).unwrap();
            assert_eq!(frame[0], STATUS_OVERLOADED, "{acceptor:?}: {frame:?}");
            assert!(coord.metrics.conns_rejected() >= 1);
            drop(first);
            drop(second);
            // capacity is released once the first connection fully ends
            let mut reconnected = false;
            for _ in 0..200 {
                if let Ok(mut c) = Client::connect(&addr) {
                    if c.ping().is_ok() {
                        reconnected = true;
                        break;
                    }
                }
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            assert!(reconnected, "{acceptor:?}: connection slot never released");
        }
    }

    #[test]
    fn io_model_parses_and_defaults() {
        assert_eq!("event".parse::<IoModel>().unwrap(), IoModel::Event);
        // the retired value is rejected with an error that points at the
        // replacement, not silently aliased
        let err = "threads".parse::<IoModel>().unwrap_err().to_string();
        assert!(err.contains("removed"), "{err}");
        assert!(err.contains("--io-model event"), "{err}");
        assert!("kqueue".parse::<IoModel>().is_err());
        assert_eq!(IoModel::default(), IoModel::Event);
        assert!(ServeOptions::default().effective_io_loops() >= 1);

        assert_eq!(
            "reuseport".parse::<Acceptor>().unwrap(),
            Acceptor::Reuseport
        );
        assert_eq!("single".parse::<Acceptor>().unwrap(), Acceptor::Single);
        assert!("sharded".parse::<Acceptor>().is_err());
        assert_eq!(ServeOptions::default().acceptor, Acceptor::Reuseport);
    }

    /// Satellite: oversize encodes error out instead of truncating the
    /// u32 length prefix, and the response clamp counts them.
    #[test]
    fn oversize_frames_error_instead_of_truncating() {
        assert_eq!(frame_len_checked(0).unwrap(), 1);
        assert_eq!(
            frame_len_checked(MAX_FRAME as usize - 1).unwrap(),
            MAX_FRAME
        );
        let err = frame_len_checked(MAX_FRAME as usize).unwrap_err();
        assert!(err.to_string().contains("frame too large"), "{err}");
        assert!(frame_len_checked(u32::MAX as usize + 10).is_err());

        let metrics = Metrics::new();
        let (status, payload) = checked_response(STATUS_OK, vec![0u8; 16], &metrics);
        assert_eq!((status, payload.len()), (STATUS_OK, 16));
        assert_eq!(metrics.frames_too_large(), 0);
        let (status, payload) =
            checked_response(STATUS_OK, vec![0u8; MAX_FRAME as usize + 1], &metrics);
        assert_eq!(status, STATUS_ERR);
        assert_eq!(payload, b"response exceeds frame limit".to_vec());
        assert_eq!(metrics.frames_too_large(), 1);
    }

    /// Satellite: a tiny frame claiming a huge (or zero) image count is
    /// rejected before any allocation.
    #[test]
    fn batch_count_lies_are_rejected() {
        let mut payload = Vec::new();
        payload.extend_from_slice(&4u16.to_le_bytes());
        payload.extend_from_slice(b"bmlp");
        payload.extend_from_slice(&0u32.to_le_bytes());
        let err = parse_predict_batch(&payload).unwrap_err();
        assert!(err.to_string().contains("empty batch"), "{err}");

        let mut payload = Vec::new();
        payload.extend_from_slice(&4u16.to_le_bytes());
        payload.extend_from_slice(b"bmlp");
        payload.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = parse_predict_batch(&payload).unwrap_err();
        assert!(err.to_string().contains("impossible"), "{err}");
    }

    #[test]
    fn predict_deadline_tail_parses() {
        let mut payload = Vec::new();
        payload.extend_from_slice(&4u16.to_le_bytes());
        payload.extend_from_slice(b"bmlp");
        payload.extend_from_slice(&3u32.to_le_bytes());
        payload.extend_from_slice(&[7, 8, 9]);
        let (model, img, dl) = parse_predict(&payload).unwrap();
        assert_eq!((model.as_str(), img.data.len(), dl), ("bmlp", 3, None));

        // exactly 4 trailing bytes = a deadline in ms
        payload.extend_from_slice(&250u32.to_le_bytes());
        let (_, img, dl) = parse_predict(&payload).unwrap();
        assert_eq!((img.data.len(), dl), (3, Some(250)));

        // any other tail length is a framing error
        payload.push(0);
        assert!(parse_predict(&payload).is_err());

        // batch frames take the same tail
        let mut batch = Vec::new();
        batch.extend_from_slice(&4u16.to_le_bytes());
        batch.extend_from_slice(b"bmlp");
        batch.extend_from_slice(&1u32.to_le_bytes());
        batch.extend_from_slice(&2u32.to_le_bytes());
        batch.extend_from_slice(&[1, 2]);
        let (_, imgs, dl) = parse_predict_batch(&batch).unwrap();
        assert_eq!((imgs.len(), dl), (1, None));
        batch.extend_from_slice(&99u32.to_le_bytes());
        let (_, imgs, dl) = parse_predict_batch(&batch).unwrap();
        assert_eq!((imgs.len(), dl), (1, Some(99)));
        batch.extend_from_slice(&[1, 2]);
        assert!(parse_predict_batch(&batch).is_err());
    }

    #[test]
    fn load_model_payload_parses_and_rejects_junk() {
        let mut payload = Vec::new();
        payload.extend_from_slice(&4u16.to_le_bytes());
        payload.extend_from_slice(b"bmlp");
        let path = b"/models/bmlp-v2.esp";
        payload.extend_from_slice(&(path.len() as u32).to_le_bytes());
        payload.extend_from_slice(path);
        let (model, p) = parse_load_model(&payload).unwrap();
        assert_eq!(model, "bmlp");
        assert_eq!(p, "/models/bmlp-v2.esp");

        // trailing junk is a protocol error
        payload.push(0);
        assert!(parse_load_model(&payload).is_err());
        // truncated path is too
        assert!(parse_load_model(&payload[..payload.len() - 4]).is_err());
    }

    #[test]
    fn client_rejects_unencodable_requests() {
        let (_coord, handle) = serve_test_coord();
        let mut client = Client::connect(&handle.addr().to_string()).unwrap();
        let long_name = "m".repeat(u16::MAX as usize + 1);
        let err = client.predict(&long_name, &[0u8; 4]).unwrap_err();
        assert!(err.to_string().contains("model name too long"), "{err}");
        let err = client.predict_batch("bmlp", &[]).unwrap_err();
        assert!(err.to_string().contains("at least one image"), "{err}");
        // the connection is still usable: nothing was written
        client.ping().unwrap();
    }

    /// The latch releases shutdown as soon as the last serving thread
    /// exits, under both acceptor layouts.
    #[test]
    fn shutdown_joins_serving_threads_in_both_acceptor_modes() {
        for acceptor in [Acceptor::Reuseport, Acceptor::Single] {
            let mut rng = Rng::new(190);
            let spec = bmlp_spec(&mut rng, 64, 1);
            let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
            let coord = Arc::new(Coordinator::new(BatchConfig::default()));
            coord.register("bmlp", Arc::new(NativeEngine::new(net, "opt")));
            let mut handle = serve(
                coord,
                "127.0.0.1:0",
                ServeOptions {
                    acceptor,
                    ..Default::default()
                },
            )
            .unwrap();
            let addr = handle.addr().to_string();
            let mut clients: Vec<_> = (0..4)
                .map(|_| Client::connect(&addr).unwrap())
                .collect();
            for c in &mut clients {
                c.ping().unwrap();
            }
            assert!(handle.serving_threads() >= 1, "{acceptor:?}");
            drop(clients);
            handle.shutdown();
            assert_eq!(
                handle.serving_threads(),
                0,
                "{acceptor:?}: all serving threads joined"
            );
        }
    }
}
