//! Layer-3 coordinator: replicated model registry, per-replica dynamic
//! batchers, metrics, and a TCP serving front end.
//!
//! Espresso is an inference library; this module is the deployment shell
//! a downstream user runs it behind: register engines (native binary,
//! native float, XLA artifacts, baselines) under model names — each with
//! one or more replicas behind a least-loaded dispatcher — submit
//! requests, hot-swap weights with [`Coordinator::deploy`], observe
//! latency/throughput. Pure std (threads + channels) — no async runtime
//! exists in the offline build, so we own the event loop.

pub mod batcher;
#[cfg(target_os = "linux")]
pub(crate) mod event;
pub mod metrics;
pub mod registry;
pub mod tcp;

pub use batcher::{BatchConfig, Batcher, CompletionSink, DeadlineExceeded, Submission};
pub use metrics::{Metrics, MetricsSnapshot};
pub use registry::{EngineLoader, ModelHealth, ModelVersion, Registry};

use crate::runtime::Engine;
use crate::tensor::Tensor;
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

/// A named collection of replicated engines with per-model batching and
/// hot swap. Thin façade over [`Registry`]; single-replica registration
/// keeps the pre-replication behavior exactly.
pub struct Coordinator {
    registry: Registry,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    pub fn new(batch_cfg: BatchConfig) -> Self {
        let metrics = Arc::new(Metrics::new());
        Self {
            registry: Registry::new(batch_cfg, metrics.clone()),
            metrics,
        }
    }

    /// Register an engine under a model name; spawns its batcher. All
    /// metrics for the model are keyed by `name` (the name clients
    /// address), not by the engine's own label.
    pub fn register(&self, name: &str, engine: Arc<dyn Engine>) {
        self.registry.register(name, vec![engine], None);
    }

    /// Register a model with N replica engines behind the least-loaded
    /// dispatcher. All replicas share one admission budget
    /// (`queue_depth` bounds the model, not each replica) and report
    /// into one metrics row keyed by `name`.
    pub fn register_replicated(&self, name: &str, engines: Vec<Arc<dyn Engine>>) {
        self.registry.register(name, engines, None);
    }

    /// Register a replicated model that can be hot-swapped later:
    /// `loader` rebuilds the replica set from a `.esp` path when
    /// [`Coordinator::deploy`] (or the wire `OP_LOAD_MODEL`) fires.
    pub fn register_with_loader(
        &self,
        name: &str,
        engines: Vec<Arc<dyn Engine>>,
        loader: EngineLoader,
    ) {
        self.registry.register(name, engines, Some(loader));
    }

    /// Atomically replace `model`'s weights with a new version loaded
    /// from `path`: load + warm off the dispatch path, flip the version
    /// pointer, drain the old replicas. Returns the new version number.
    /// In-flight requests finish against the version they were routed
    /// to — no reply is ever torn across the swap.
    pub fn deploy(&self, model: &str, path: &Path) -> Result<u64> {
        self.registry.deploy(model, path)
    }

    pub fn models(&self) -> Vec<String> {
        self.registry.models()
    }

    pub fn engine(&self, name: &str) -> Option<Arc<dyn Engine>> {
        self.registry.engine(name)
    }

    /// Replica count of a model's current version.
    pub fn replica_count(&self, name: &str) -> Option<usize> {
        self.registry.replica_count(name)
    }

    /// Current (monotonic) version number of a model; 1 until the first
    /// deploy.
    pub fn version(&self, name: &str) -> Option<u64> {
        self.registry.version(name)
    }

    /// The underlying registry (swap tests, serving internals).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Submit asynchronously under admission control; routes to the
    /// least-loaded replica.
    pub fn submit(&self, model: &str, img: Tensor<u8>) -> Result<Submission> {
        self.registry.submit(model, img)
    }

    /// Submit a whole vector at once (the wire-level batch op): one
    /// admission decision, requests enqueued back-to-back on ONE replica
    /// so a single client saturates GEMM-level batching.
    pub fn submit_many(&self, model: &str, imgs: Vec<Tensor<u8>>) -> Result<Vec<Submission>> {
        self.registry.submit_many(model, imgs)
    }

    /// [`Coordinator::submit_many`] with an optional client deadline
    /// stamped at admission (the wire-level deadline field).
    pub fn submit_many_deadline(
        &self,
        model: &str,
        imgs: Vec<Tensor<u8>>,
        deadline: Option<Instant>,
    ) -> Result<Vec<Submission>> {
        self.registry.submit_many_deadline(model, imgs, deadline)
    }

    /// Submit one request with sink-based completion (the event-driven
    /// serving path — no reply channel, no parked thread): the result
    /// arrives at `sink.complete(ticket, ..)` on the batcher thread.
    /// Returns `Ok(true)` if admitted, `Ok(false)` if rejected under
    /// admission control (no completion will arrive), or `Err` for an
    /// unknown model.
    pub fn submit_sink(
        &self,
        model: &str,
        img: Tensor<u8>,
        sink: &Arc<dyn CompletionSink>,
        ticket: u64,
        deadline: Option<Instant>,
    ) -> Result<bool> {
        Ok(self
            .registry
            .submit_many_sink(model, vec![img], sink, ticket, deadline)?
            .pop()
            .unwrap_or(false))
    }

    /// Vector analogue of [`Coordinator::submit_sink`]: item `i`
    /// completes under ticket `first_ticket + i`; the returned flags mark
    /// which items were admitted.
    pub fn submit_many_sink(
        &self,
        model: &str,
        imgs: Vec<Tensor<u8>>,
        sink: &Arc<dyn CompletionSink>,
        first_ticket: u64,
        deadline: Option<Instant>,
    ) -> Result<Vec<bool>> {
        self.registry
            .submit_many_sink(model, imgs, sink, first_ticket, deadline)
    }

    /// Per-model replica liveness and queue depth (the health op).
    pub fn health(&self) -> Vec<ModelHealth> {
        self.registry.health()
    }

    /// The configured server-side request timeout, if any.
    pub fn request_timeout(&self) -> Option<std::time::Duration> {
        self.registry.request_timeout()
    }

    /// Submit and wait for scores (`Overloaded` flattens to an error).
    pub fn predict(&self, model: &str, img: Tensor<u8>) -> Result<Vec<f32>> {
        self.submit(model, img)?.wait()
    }

    /// Pull the latest per-layer forward-plan profiles and workspace
    /// buffer-pool stats out of every engine that exposes them and store
    /// them in [`Metrics`] (called before rendering stats, so the tables
    /// reflect current counters). Plan profile from replica 0; pool
    /// stats summed across replicas.
    pub fn refresh_plan_profiles(&self) {
        self.registry.refresh_plan_profiles();
    }

    /// Idle housekeeping: release every replica engine's parked scratch
    /// beyond its steady-state working set, so a past burst of large
    /// batches stops pinning peak memory (engines restore their standing
    /// reservations, keeping the no-miss guarantee). Returns the number
    /// of buffers freed.
    pub fn trim_pools(&self) -> usize {
        self.registry.trim_pools()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layers::Backend;
    use crate::net::{bmlp_spec, Network};
    use crate::runtime::NativeEngine;
    use crate::tensor::Shape;
    use crate::util::rng::Rng;
    use std::time::Duration;

    fn coordinator_with_mlp() -> (Coordinator, Tensor<u8>) {
        let mut rng = Rng::new(171);
        let spec = bmlp_spec(&mut rng, 128, 1);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let coord = Coordinator::new(BatchConfig {
            max_batch: 4,
            max_wait: Duration::from_micros(200),
            ..BatchConfig::default()
        });
        coord.register("bmlp", Arc::new(NativeEngine::new(net, "opt")));
        let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
        (coord, Tensor::from_vec(Shape::vector(784), img))
    }

    #[test]
    fn predict_roundtrip() {
        let (coord, img) = coordinator_with_mlp();
        let scores = coord.predict("bmlp", img).unwrap();
        assert_eq!(scores.len(), 10);
        assert_eq!(coord.models(), vec!["bmlp"]);
        assert_eq!(coord.replica_count("bmlp"), Some(1));
        assert_eq!(coord.version("bmlp"), Some(1));
    }

    #[test]
    fn unknown_model_errors() {
        let (coord, img) = coordinator_with_mlp();
        assert!(coord.predict("nope", img).is_err());
    }

    #[test]
    fn concurrent_submissions_all_answer() {
        let (coord, img) = coordinator_with_mlp();
        let handles: Vec<_> = (0..64)
            .map(|_| coord.submit("bmlp", img.clone()).unwrap())
            .collect();
        let direct = coord.engine("bmlp").unwrap().predict(&img).unwrap();
        for h in handles {
            let scores = h.wait().unwrap();
            assert_eq!(scores, direct, "batched result == direct result");
        }
        // regression (metrics keying): stats land under the REGISTERED
        // model name, not the engine label ("opt")
        let snap = coord.metrics.snapshot("bmlp").unwrap();
        assert_eq!(snap.requests, 64);
        assert!(snap.mean_batch >= 1.0);
        assert!(
            coord.metrics.snapshot("opt").is_none(),
            "engine label must not split the model across two stats rows"
        );
    }

    /// Replicated registration: N engines, one model name, one stats
    /// row; every replica answers identically and the per-replica split
    /// is recorded under the model name.
    #[test]
    fn replicated_registration_serves_and_aggregates() {
        let mut rng = Rng::new(173);
        let spec = bmlp_spec(&mut rng, 128, 1);
        let coord = Coordinator::new(BatchConfig {
            max_batch: 4,
            max_wait: Duration::from_micros(200),
            ..BatchConfig::default()
        });
        let engines: Vec<Arc<dyn Engine>> = (0..2)
            .map(|_| {
                let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
                Arc::new(NativeEngine::new(net, "opt")) as Arc<dyn Engine>
            })
            .collect();
        coord.register_replicated("bmlp", engines);
        assert_eq!(coord.replica_count("bmlp"), Some(2));
        let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
        let img = Tensor::from_vec(Shape::vector(784), img);
        let direct = coord.engine("bmlp").unwrap().predict(&img).unwrap();
        let handles: Vec<_> = (0..32)
            .map(|_| coord.submit("bmlp", img.clone()).unwrap())
            .collect();
        for h in handles {
            assert_eq!(h.wait().unwrap(), direct, "replicas agree numerically");
        }
        let snap = coord.metrics.snapshot("bmlp").unwrap();
        assert_eq!(snap.requests, 32, "one stats row across replicas");
        assert!(coord.metrics.snapshot("opt").is_none());
        assert_eq!(
            coord.metrics.replica_served("bmlp").iter().sum::<u64>(),
            32
        );
        // trim reaches every replica without error
        let _ = coord.trim_pools();
    }

    /// Failure injection: a flaky engine's errors must surface per
    /// request (not poison the batcher) and be counted in metrics.
    #[test]
    fn engine_errors_propagate_and_are_counted() {
        struct Flaky;
        impl crate::runtime::Engine for Flaky {
            fn name(&self) -> String {
                "flaky".into()
            }
            fn input_shape(&self) -> Shape {
                Shape::vector(4)
            }
            fn predict(&self, img: &Tensor<u8>) -> anyhow::Result<Vec<f32>> {
                if img.data[0] % 2 == 0 {
                    anyhow::bail!("injected failure")
                }
                Ok(vec![1.0])
            }
        }
        let coord = Coordinator::new(BatchConfig::default());
        coord.register("f", Arc::new(Flaky));
        let img = |v: u8| Tensor::from_vec(Shape::vector(4), vec![v, 0, 0, 0]);
        assert!(coord.predict("f", img(2)).is_err());
        assert!(coord.predict("f", img(3)).is_ok());
        // batcher still alive after the error
        assert!(coord.predict("f", img(5)).is_ok());
        let snap = coord.metrics.snapshot("f").unwrap();
        assert_eq!(snap.requests, 3);
        assert_eq!(snap.errors, 1);
    }

    #[test]
    fn plan_profiles_surface_through_metrics() {
        let (coord, img) = coordinator_with_mlp();
        for _ in 0..3 {
            let _ = coord.predict("bmlp", img.clone()).unwrap();
        }
        coord.refresh_plan_profiles();
        let prof = coord.metrics.plan_profile("bmlp").unwrap();
        assert!(prof.calls() >= 1, "forwards recorded: {}", prof.calls());
        assert!(prof.total_ns() > 0);
        assert!(coord.metrics.render_plan_profiles().contains("bmlp"));
    }

    #[test]
    fn batched_and_single_paths_agree() {
        // the dynamic batcher must not change numerics
        let mut rng = Rng::new(172);
        let (coord, _) = coordinator_with_mlp();
        for _ in 0..5 {
            let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
            let t = Tensor::from_vec(Shape::vector(784), img);
            let via_coord = coord.predict("bmlp", t.clone()).unwrap();
            let via_engine = coord.engine("bmlp").unwrap().predict(&t).unwrap();
            assert_eq!(via_coord, via_engine);
        }
    }
}
