//! Replicated, hot-swappable model registry.
//!
//! Each registered model owns a **version**: an immutable set of replica
//! batchers, each wrapping its own engine instance with its own batch
//! loop, workspace pools, and tune state. Requests route to the replica
//! with the fewest in-flight requests (a per-replica atomic scoreboard;
//! no queues between dispatcher and replica beyond the batcher's own).
//! All replicas of a model draw admission slots from ONE shared budget,
//! so `--queue-depth` keeps its meaning — a bound on the model, not on
//! each replica.
//!
//! Hot swap: [`Registry::deploy`] loads a new version from a `.esp` path
//! (via the model's registered [`EngineLoader`]), warms and tunes its
//! replicas off to the side, then flips the version pointer in one
//! write-lock swap. Dispatchers hold only a cheap `Arc` clone of the
//! version they routed to, so in-flight requests on the old version
//! finish against the weights they started with — replies are always
//! version-consistent, never torn across the flip. Once the last
//! dispatcher reference drops, the deploy thread drains the old replicas
//! (each batcher's `Drop` joins its loop after the loop finishes every
//! queued request) and their OS threads exit.

use super::batcher::{BatchConfig, Batcher, CompletionSink, Submission};
use super::metrics::Metrics;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::time::{Duration, Instant};

/// Builds the replica engines for a new version of a model from a `.esp`
/// file. Returning N engines yields N replicas; the loader decides how
/// engine instances share (or don't share) loaded weights — with
/// mmap-backed specs the parsed arrays all borrow one shared mapping.
pub type EngineLoader = Arc<dyn Fn(&Path) -> Result<Vec<Arc<dyn Engine>>> + Send + Sync>;

/// One immutable generation of a model: its replica batchers. Dispatch
/// clones the `Arc<ModelVersion>` out of the entry's lock, so a version
/// stays alive exactly as long as someone may still enqueue into it.
pub struct ModelVersion {
    version: u64,
    replicas: Vec<Batcher>,
}

impl ModelVersion {
    /// Monotonic generation number (1 = initial registration).
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn replicas(&self) -> &[Batcher] {
        &self.replicas
    }

    /// The replica with the fewest in-flight requests right now. The
    /// scoreboard read is racy by design — a stale read costs one
    /// slightly-imbalanced placement, never correctness, and avoids any
    /// cross-replica lock on the hot path.
    pub fn least_loaded(&self) -> &Batcher {
        self.replicas
            .iter()
            .min_by_key(|b| b.inflight())
            .expect("a version has at least one replica")
    }
}

/// A registered model: its current version plus everything needed to
/// build the next one.
pub struct ModelEntry {
    name: String,
    cfg: BatchConfig,
    metrics: Arc<Metrics>,
    /// The model-wide admission budget, shared by every replica of every
    /// version (during a swap, old and new replicas briefly draw from the
    /// same pot — the `queue_depth` bound holds *through* the flip).
    budget: Arc<AtomicUsize>,
    current: RwLock<Arc<ModelVersion>>,
    next_version: AtomicU64,
    loader: Option<EngineLoader>,
    /// Serializes deploys per model; dispatch never takes this. The
    /// supervisor's heal takes it too, so a rebuild never races a swap.
    deploy_lock: Mutex<()>,
}

impl ModelEntry {
    /// Cheap snapshot of the current version for dispatch. Holding the
    /// returned `Arc` pins the version's replicas (and their engines)
    /// alive until the caller drops it.
    pub fn current(&self) -> Arc<ModelVersion> {
        self.current.read().unwrap().clone()
    }

    fn spawn_version(&self, engines: Vec<Arc<dyn Engine>>) -> Arc<ModelVersion> {
        let version = self.next_version.fetch_add(1, Ordering::SeqCst);
        let replicas = engines
            .into_iter()
            .enumerate()
            .map(|(i, e)| {
                Batcher::spawn_replica(
                    &self.name,
                    e,
                    self.cfg,
                    self.metrics.clone(),
                    self.budget.clone(),
                    i,
                )
            })
            .collect();
        Arc::new(ModelVersion { version, replicas })
    }

    /// Rebuild the current version's replica set if any replica died or
    /// poisoned itself, reusing the live replicas' engine instances (the
    /// engines own the weights and tuned kernels; it is the batch-loop
    /// *threads* that failed). Keeps the version number — weights did
    /// not change — and drains the old replica set like a deploy does.
    /// Returns how many replicas were dead (0 = nothing to do).
    fn heal(&self) -> usize {
        // serialize with deploys: a heal must never clobber a version
        // flip that is happening at the same moment
        let _guard = self.deploy_lock.lock().unwrap();
        let current = self.current();
        let dead = current.replicas().iter().filter(|b| b.is_dead()).count();
        if dead == 0 {
            return 0;
        }
        for _ in 0..dead {
            self.metrics.record_replica_restart(&self.name);
        }
        let replicas: Vec<Batcher> = current
            .replicas()
            .iter()
            .enumerate()
            .map(|(i, b)| {
                Batcher::spawn_replica(
                    &self.name,
                    b.engine().clone(),
                    self.cfg,
                    self.metrics.clone(),
                    self.budget.clone(),
                    i,
                )
            })
            .collect();
        let next = Arc::new(ModelVersion {
            version: current.version(),
            replicas,
        });
        let old = std::mem::replace(&mut *self.current.write().unwrap(), next);
        drop(current);
        drain_version(old);
        dead
    }

    /// Liveness/queue snapshot of this model for the health op.
    fn health(&self) -> ModelHealth {
        let current = self.current();
        let replicas = current.replicas();
        ModelHealth {
            model: self.name.clone(),
            version: current.version(),
            replicas: replicas.len(),
            alive: replicas.iter().filter(|b| !b.is_dead()).count(),
            inflight: replicas.iter().map(|b| b.inflight()).sum(),
            queued: self.budget.load(Ordering::Relaxed),
            queue_depth: self.cfg.queue_depth,
        }
    }
}

/// Point-in-time liveness view of one model (the `OP_HEALTH` payload).
#[derive(Clone, Debug)]
pub struct ModelHealth {
    pub model: String,
    pub version: u64,
    /// Replicas the current version was built with (the invariant N).
    pub replicas: usize,
    /// Replicas currently alive and not poisoned.
    pub alive: usize,
    /// In-flight requests summed across replicas.
    pub inflight: usize,
    /// Admission slots in use (queued + executing, model-wide).
    pub queued: usize,
    /// The admission bound those slots are drawn from.
    pub queue_depth: usize,
}

/// Wait for a retired version's dispatch references to drop, then drop
/// it (each batcher's `Drop` joins its loop after the loop replies to
/// everything already queued). Shared by deploys and supervisor heals.
fn drain_version(mut old: Arc<ModelVersion>) {
    let t0 = Instant::now();
    loop {
        match Arc::try_unwrap(old) {
            Ok(v) => {
                drop(v); // joins every old replica thread
                break;
            }
            Err(still_shared) => {
                if t0.elapsed() > DRAIN_TIMEOUT {
                    // give up on a synchronous drain; the last holder's
                    // drop will join the threads instead
                    drop(still_shared);
                    break;
                }
                old = still_shared;
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
}

/// How often a model's supervisor checks replica liveness.
const SUPERVISE_TICK: Duration = Duration::from_millis(20);
/// Backoff after a heal, doubled per consecutive heal (a replica that
/// dies the instant it is rebuilt should not spin the supervisor), reset
/// once a tick finds everything alive.
const RESTART_BACKOFF: Duration = Duration::from_millis(50);
const RESTART_BACKOFF_MAX: Duration = Duration::from_secs(5);
/// Lifetime cap on rebuilt replicas per model: a model whose replicas
/// keep dying past this is systematically broken — the supervisor stops
/// churning and leaves the poisoned replicas failing fast (they still
/// reply to everything, nothing hangs).
const RESTART_BUDGET: usize = 64;

/// Per-model supervisor loop: rebuild dead/poisoned replicas of the
/// current version so N replicas is an invariant, not an initial
/// condition. Holds only a `Weak` on the entry — an unregistered model
/// (or a dropped registry) ends its supervisor instead of leaking it.
fn supervise(entry: Weak<ModelEntry>, stop: Arc<AtomicBool>) {
    let mut consecutive = 0u32;
    let mut restarts_total = 0usize;
    let mut gave_up = false;
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(SUPERVISE_TICK);
        let Some(entry) = entry.upgrade() else {
            break;
        };
        if gave_up {
            continue;
        }
        let healed = entry.heal();
        if healed == 0 {
            consecutive = 0;
            continue;
        }
        restarts_total += healed;
        if restarts_total >= RESTART_BUDGET {
            eprintln!(
                "supervisor[{}]: restart budget ({RESTART_BUDGET}) exhausted, giving up",
                entry.name
            );
            gave_up = true;
            continue;
        }
        consecutive += 1;
        let backoff = RESTART_BACKOFF
            .saturating_mul(1u32 << consecutive.min(10))
            .min(RESTART_BACKOFF_MAX);
        // back off in stop-aware slices so shutdown never waits 5s
        let t0 = Instant::now();
        while t0.elapsed() < backoff && !stop.load(Ordering::SeqCst) {
            std::thread::sleep(SUPERVISE_TICK);
        }
    }
}

/// How long a deploy waits for the old version's dispatch references to
/// drop before giving up on a synchronous drain. The fallback is safe:
/// the version's `Arc` is simply dropped, and whichever straggler holds
/// the last clone runs the drain (batcher joins) when it lets go.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(10);

/// Named models, each a replicated hot-swappable [`ModelEntry`].
pub struct Registry {
    models: RwLock<HashMap<String, Arc<ModelEntry>>>,
    metrics: Arc<Metrics>,
    cfg: BatchConfig,
    /// One supervisor thread per registered model, stopped and joined
    /// when the registry drops. A replaced entry's supervisor also exits
    /// on its own once its `Weak` stops upgrading.
    supervisors: Mutex<Vec<(Arc<AtomicBool>, std::thread::JoinHandle<()>)>>,
}

impl Registry {
    pub fn new(cfg: BatchConfig, metrics: Arc<Metrics>) -> Self {
        Self {
            models: RwLock::new(HashMap::new()),
            metrics,
            cfg,
            supervisors: Mutex::new(Vec::new()),
        }
    }

    /// Register version 1 of a model over pre-built replica engines.
    /// `loader` (optional) enables [`Registry::deploy`] hot swaps later.
    /// Re-registering a name replaces the whole entry (the old version
    /// drains when its last dispatch reference drops).
    pub fn register(
        &self,
        name: &str,
        engines: Vec<Arc<dyn Engine>>,
        loader: Option<EngineLoader>,
    ) {
        assert!(!engines.is_empty(), "a model needs at least one replica");
        let entry = Arc::new(ModelEntry {
            name: name.to_string(),
            cfg: self.cfg,
            metrics: self.metrics.clone(),
            budget: Arc::new(AtomicUsize::new(0)),
            // placeholder replaced two lines down; RwLock<Arc<_>> needs
            // an initial value before spawn_version can use the entry
            current: RwLock::new(Arc::new(ModelVersion {
                version: 0,
                replicas: Vec::new(),
            })),
            next_version: AtomicU64::new(1),
            loader,
            deploy_lock: Mutex::new(()),
        });
        let v1 = entry.spawn_version(engines);
        *entry.current.write().unwrap() = v1;
        let stop = Arc::new(AtomicBool::new(false));
        let weak = Arc::downgrade(&entry);
        let join = std::thread::Builder::new()
            .name(format!("espresso-supervise-{name}"))
            .spawn({
                let stop = stop.clone();
                move || supervise(weak, stop)
            })
            .expect("spawn supervisor");
        self.supervisors.lock().unwrap().push((stop, join));
        self.models
            .write()
            .unwrap()
            .insert(name.to_string(), entry);
    }

    /// Liveness/queue snapshot of every model, sorted by name.
    pub fn health(&self) -> Vec<ModelHealth> {
        let entries: Vec<_> = self.models.read().unwrap().values().cloned().collect();
        let mut out: Vec<_> = entries.iter().map(|e| e.health()).collect();
        out.sort_by(|a, b| a.model.cmp(&b.model));
        out
    }

    /// The configured per-request timeout (the event loop stamps wire
    /// tickets with it so reply reaping agrees with batcher shedding).
    pub fn request_timeout(&self) -> Option<Duration> {
        self.cfg.request_timeout
    }

    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<_> = self.models.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn entry(&self, model: &str) -> Result<Arc<ModelEntry>> {
        self.models
            .read()
            .unwrap()
            .get(model)
            .cloned()
            .ok_or_else(|| anyhow!("unknown model {model:?}"))
    }

    /// Replica 0's engine of the current version — the direct-call oracle
    /// for tests and the CLI's non-serving paths.
    pub fn engine(&self, model: &str) -> Option<Arc<dyn Engine>> {
        let entry = self.models.read().unwrap().get(model).cloned()?;
        let current = entry.current();
        current.replicas().first().map(|b| b.engine().clone())
    }

    /// Replica count of the model's current version.
    pub fn replica_count(&self, model: &str) -> Option<usize> {
        let entry = self.models.read().unwrap().get(model).cloned()?;
        Some(entry.current().replicas().len())
    }

    /// Current version number of a model.
    pub fn version(&self, model: &str) -> Option<u64> {
        let entry = self.models.read().unwrap().get(model).cloned()?;
        Some(entry.current().version())
    }

    pub fn submit(&self, model: &str, img: Tensor<u8>) -> Result<Submission> {
        let version = self.entry(model)?.current();
        Ok(version.least_loaded().submit(img))
    }

    /// One admission decision, all requests on ONE replica — the batch
    /// must stay together to fill GEMM-level batches, which is the whole
    /// point of the wire-level batch op.
    pub fn submit_many(&self, model: &str, imgs: Vec<Tensor<u8>>) -> Result<Vec<Submission>> {
        self.submit_many_deadline(model, imgs, None)
    }

    /// [`Registry::submit_many`] with an optional client deadline.
    pub fn submit_many_deadline(
        &self,
        model: &str,
        imgs: Vec<Tensor<u8>>,
        deadline: Option<Instant>,
    ) -> Result<Vec<Submission>> {
        let version = self.entry(model)?.current();
        Ok(version.least_loaded().submit_many_deadline(imgs, deadline))
    }

    pub fn submit_many_sink(
        &self,
        model: &str,
        imgs: Vec<Tensor<u8>>,
        sink: &Arc<dyn CompletionSink>,
        first_ticket: u64,
        deadline: Option<Instant>,
    ) -> Result<Vec<bool>> {
        let version = self.entry(model)?.current();
        Ok(version
            .least_loaded()
            .submit_many_sink(imgs, sink, first_ticket, deadline))
    }

    /// Load a new version of `model` from `path`, warm it, flip the
    /// version pointer, and drain the old replicas. Returns the new
    /// version number. Requests keep flowing the whole time: dispatchers
    /// that grabbed the old version before the flip complete against the
    /// old weights; everyone after the flip sees the new ones.
    pub fn deploy(&self, model: &str, path: &Path) -> Result<u64> {
        let entry = self.entry(model)?;
        let loader = entry
            .loader
            .clone()
            .ok_or_else(|| anyhow!("model {model:?} was registered without a loader"))?;
        // one deploy at a time per model; loading + tuning happens here,
        // off the dispatch path, while traffic keeps hitting the current
        // version
        let _guard = entry.deploy_lock.lock().unwrap();
        let engines = loader(path)
            .with_context(|| format!("loading new version of {model:?} from {path:?}"))
            .map_err(|e| {
                // a deploy refused by weight-file verification failed
                // closed: count it so operators can tell "bad artifact
                // pushed" apart from generic loader errors
                if e.downcast_ref::<crate::format::IntegrityError>().is_some() {
                    self.metrics.record_integrity_reject();
                }
                e
            })?;
        if engines.is_empty() {
            bail!("loader for {model:?} returned no engines");
        }
        let next = entry.spawn_version(engines);
        let version = next.version();
        // the flip: one pointer swap under the write lock. Dispatchers
        // hold the read lock only long enough to clone the Arc, so this
        // never blocks behind an executing request.
        let old = std::mem::replace(&mut *entry.current.write().unwrap(), next);
        // drain: wait for in-flight dispatch references to drop, then
        // unwrap the version and drop its batchers — each Drop joins its
        // loop after the loop replies to everything already queued.
        drain_version(old);
        Ok(version)
    }

    /// Record per-layer plan profiles and summed pool stats for every
    /// model. The plan profile comes from replica 0 (all replicas run
    /// the same plan; one table row per model, not per replica); pool
    /// stats sum across replicas because each owns real scratch.
    pub fn refresh_plan_profiles(&self) {
        let entries: Vec<_> = self.models.read().unwrap().values().cloned().collect();
        for entry in entries {
            let current = entry.current();
            let replicas = current.replicas();
            if let Some(profile) = replicas.first().and_then(|b| b.engine().plan_profile()) {
                self.metrics.record_plan_profile(&entry.name, profile);
            }
            let mut sum: Option<crate::alloc::PoolStats> = None;
            for b in replicas {
                if let Some(p) = b.engine().pool_stats() {
                    let s = sum.get_or_insert_with(Default::default);
                    s.hits += p.hits;
                    s.affine_hits += p.affine_hits;
                    s.misses += p.misses;
                    s.evicted += p.evicted;
                    s.free_buffers += p.free_buffers;
                    s.free_elems += p.free_elems;
                    s.peak_free_elems += p.peak_free_elems;
                }
            }
            if let Some(s) = sum {
                self.metrics.record_pool_stats(&entry.name, s);
            }
        }
    }

    /// Idle housekeeping across EVERY replica of every model (a replica
    /// that dodged the trim would pin its burst scratch forever). Returns
    /// buffers freed.
    pub fn trim_pools(&self) -> usize {
        let entries: Vec<_> = self.models.read().unwrap().values().cloned().collect();
        entries
            .iter()
            .map(|e| {
                e.current()
                    .replicas()
                    .iter()
                    .map(|b| b.engine().trim_pools())
                    .sum::<usize>()
            })
            .sum()
    }
}

impl Drop for Registry {
    fn drop(&mut self) {
        let supervisors = std::mem::take(&mut *self.supervisors.lock().unwrap());
        for (stop, _) in &supervisors {
            stop.store(true, Ordering::SeqCst);
        }
        for (_, join) in supervisors {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Shape;
    use std::sync::atomic::AtomicU32;

    /// Engine whose score encodes (version, replica) so tests can tell
    /// exactly which instance answered.
    struct Tagged {
        version: f32,
        served: AtomicU32,
        delay: Duration,
    }

    impl Tagged {
        fn new(version: f32, delay: Duration) -> Arc<Self> {
            Arc::new(Self {
                version,
                served: AtomicU32::new(0),
                delay,
            })
        }
    }

    impl Engine for Tagged {
        fn name(&self) -> String {
            "tagged".into()
        }
        fn input_shape(&self) -> Shape {
            Shape::vector(4)
        }
        fn predict(&self, _img: &Tensor<u8>) -> Result<Vec<f32>> {
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            self.served.fetch_add(1, Ordering::SeqCst);
            Ok(vec![self.version])
        }
    }

    fn img(v: u8) -> Tensor<u8> {
        Tensor::from_vec(Shape::vector(4), vec![v, 0, 0, 0])
    }

    fn registry(cfg: BatchConfig) -> Registry {
        Registry::new(cfg, Arc::new(Metrics::new()))
    }

    #[test]
    fn least_loaded_prefers_idle_replica() {
        let reg = registry(BatchConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(50),
            queue_depth: 64,
            ..BatchConfig::default()
        });
        let slow = Tagged::new(1.0, Duration::from_millis(40));
        let also = Tagged::new(1.0, Duration::from_millis(40));
        reg.register(
            "m",
            vec![
                slow.clone() as Arc<dyn Engine>,
                also.clone() as Arc<dyn Engine>,
            ],
            None,
        );
        // 8 concurrent slow requests: the scoreboard must spread them
        // instead of piling everything on replica 0
        let subs: Vec<_> = (0..8).map(|i| reg.submit("m", img(i)).unwrap()).collect();
        for s in subs {
            assert_eq!(s.wait().unwrap(), vec![1.0]);
        }
        let (a, b) = (
            slow.served.load(Ordering::SeqCst),
            also.served.load(Ordering::SeqCst),
        );
        assert_eq!(a + b, 8);
        assert!(a >= 1 && b >= 1, "both replicas served: {a} vs {b}");
    }

    #[test]
    fn deploy_flips_version_and_joins_old_threads() {
        let reg = registry(BatchConfig::default());
        let loader: EngineLoader = Arc::new(|path: &Path| {
            // path's file name encodes the version tag for the test
            let tag: f32 = path
                .file_stem()
                .and_then(|s| s.to_str())
                .and_then(|s| s.parse().ok())
                .unwrap();
            Ok(vec![
                Tagged::new(tag, Duration::ZERO) as Arc<dyn Engine>,
                Tagged::new(tag, Duration::ZERO) as Arc<dyn Engine>,
            ])
        });
        reg.register(
            "m",
            vec![Tagged::new(1.0, Duration::ZERO) as Arc<dyn Engine>],
            Some(loader),
        );
        assert_eq!(reg.version("m"), Some(1));
        assert_eq!(reg.replica_count("m"), Some(1));
        assert_eq!(reg.submit("m", img(0)).unwrap().wait().unwrap(), vec![1.0]);

        let before = crate::util::os_thread_count();
        let v = reg.deploy("m", Path::new("2.esp")).unwrap();
        assert_eq!(v, 2);
        assert_eq!(reg.version("m"), Some(2));
        assert_eq!(reg.replica_count("m"), Some(2));
        assert_eq!(reg.submit("m", img(0)).unwrap().wait().unwrap(), vec![2.0]);
        // old replica's batcher thread is joined by the drain; the new
        // version added two replicas and retired one
        if let (Some(before), Some(after)) = (before, crate::util::os_thread_count()) {
            assert!(
                after <= before + 1,
                "swap must not leak threads: {before} -> {after}"
            );
        }
    }

    #[test]
    fn deploy_without_loader_errors() {
        let reg = registry(BatchConfig::default());
        reg.register(
            "m",
            vec![Tagged::new(1.0, Duration::ZERO) as Arc<dyn Engine>],
            None,
        );
        let err = reg.deploy("m", Path::new("x.esp")).unwrap_err();
        assert!(err.to_string().contains("without a loader"), "{err}");
        assert!(reg.deploy("nope", Path::new("x.esp")).is_err());
    }

    #[test]
    fn failed_deploy_keeps_current_version_serving() {
        let reg = registry(BatchConfig::default());
        let loader: EngineLoader = Arc::new(|_: &Path| anyhow::bail!("corrupt file"));
        reg.register(
            "m",
            vec![Tagged::new(1.0, Duration::ZERO) as Arc<dyn Engine>],
            Some(loader),
        );
        assert!(reg.deploy("m", Path::new("bad.esp")).is_err());
        assert_eq!(reg.version("m"), Some(1), "failed deploy must not flip");
        assert_eq!(reg.submit("m", img(0)).unwrap().wait().unwrap(), vec![1.0]);
    }

    /// Engine that panics on every request once `armed` is set: drives a
    /// replica through the poison threshold deterministically.
    struct Fuse {
        armed: std::sync::atomic::AtomicBool,
    }

    impl Engine for Fuse {
        fn name(&self) -> String {
            "fuse".into()
        }
        fn input_shape(&self) -> Shape {
            Shape::vector(4)
        }
        fn predict(&self, _img: &Tensor<u8>) -> Result<Vec<f32>> {
            if self.armed.load(Ordering::SeqCst) {
                panic!("fuse blown");
            }
            Ok(vec![42.0])
        }
    }

    /// The supervisor must notice a poisoned replica and rebuild it from
    /// the current version: replica count restored, same version number,
    /// traffic healthy again, restart counted.
    #[test]
    fn supervisor_rebuilds_poisoned_replica() {
        let metrics = Arc::new(Metrics::new());
        let reg = Registry::new(
            BatchConfig {
                max_batch: 1,
                max_wait: Duration::from_micros(50),
                ..BatchConfig::default()
            },
            metrics.clone(),
        );
        let fuse = Arc::new(Fuse {
            armed: std::sync::atomic::AtomicBool::new(false),
        });
        reg.register("m", vec![fuse.clone() as Arc<dyn Engine>], None);
        assert_eq!(reg.submit("m", img(0)).unwrap().wait().unwrap(), vec![42.0]);

        // blow the fuse: every batch panics until the replica poisons
        fuse.armed.store(true, Ordering::SeqCst);
        for _ in 0..super::super::batcher::POISON_AFTER {
            assert!(reg.submit("m", img(0)).unwrap().wait().is_err());
        }
        // heal the engine, then wait for the supervisor to rebuild
        fuse.armed.store(false, Ordering::SeqCst);
        let t0 = Instant::now();
        loop {
            if metrics.replica_restarts("m") >= 1 {
                if let Ok(sub) = reg.submit("m", img(0)) {
                    if let Ok(scores) = sub.wait() {
                        assert_eq!(scores, vec![42.0]);
                        break;
                    }
                }
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "supervisor never rebuilt the replica (restarts={})",
                metrics.replica_restarts("m")
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(reg.replica_count("m"), Some(1), "N replicas restored");
        assert_eq!(reg.version("m"), Some(1), "a heal is not a new version");
        let h = &reg.health()[0];
        assert_eq!((h.replicas, h.alive), (1, 1), "health reports recovery");
        assert_eq!(metrics.panics("m"), super::super::batcher::POISON_AFTER as u64);
    }

    #[test]
    fn health_snapshots_every_model() {
        let reg = registry(BatchConfig::default());
        reg.register(
            "b",
            vec![
                Tagged::new(1.0, Duration::ZERO) as Arc<dyn Engine>,
                Tagged::new(1.0, Duration::ZERO) as Arc<dyn Engine>,
            ],
            None,
        );
        reg.register(
            "a",
            vec![Tagged::new(1.0, Duration::ZERO) as Arc<dyn Engine>],
            None,
        );
        let h = reg.health();
        assert_eq!(h.len(), 2);
        assert_eq!(h[0].model, "a");
        assert_eq!((h[0].replicas, h[0].alive), (1, 1));
        assert_eq!(h[1].model, "b");
        assert_eq!((h[1].replicas, h[1].alive), (2, 2));
        assert_eq!(h[1].version, 1);
        assert_eq!(h[1].queued, 0);
        assert_eq!(h[1].queue_depth, BatchConfig::default().queue_depth);
    }

    #[test]
    fn unknown_model_is_an_error_everywhere() {
        let reg = registry(BatchConfig::default());
        assert!(reg.submit("ghost", img(0)).is_err());
        assert!(reg.submit_many("ghost", vec![img(0)]).is_err());
        assert!(reg.entry("ghost").is_err());
        assert!(reg.engine("ghost").is_none());
    }
}
