//! Event-driven TCP front end: one nonblocking epoll loop per core.
//!
//! The retired thread-per-connection model burned 2 OS threads per
//! socket — reader plus in-order writer — so its thread count scaled
//! with connections and the front end collapsed around a few hundred
//! sockets. This module serves the wire protocol from a fixed pool of
//! shared-nothing IO loops:
//!
//! - Each loop accepts on its **own `SO_REUSEPORT` listener** (default:
//!   the kernel hashes incoming connections across the group, so accepts
//!   never cross a thread boundary), or — under `--acceptor single` — a
//!   dispatching acceptor thread in `tcp::serve` hands admitted sockets
//!   round-robin to the loops. Either way a socket lives on exactly one
//!   loop for its whole life, so no cross-loop locking guards connection
//!   state.
//! - Each connection is a small state machine: a growable read buffer
//!   accumulates bytes until whole frames can be parsed **in place** (no
//!   intermediate per-frame `Vec` — the old blocking path allocated one
//!   per frame), and a write buffer carries serialized replies across
//!   partial writes, with `EPOLLOUT` interest registered only while a
//!   backlog exists.
//! - Predictions are submitted straight into the model batcher from the
//!   loop thread ([`Coordinator::submit_sink`]) — no thread handoff. The
//!   batcher thread pushes results into the loop's completion queue and
//!   wakes its epoll via eventfd; the loop routes them by ticket into the
//!   per-connection reply window and writes replies strictly in request
//!   order (pipelining semantics unchanged).
//! - Backpressure mirrors the threaded path's bounded reply channel: at
//!   `MAX_PIPELINE` pending replies a connection's read interest is
//!   dropped until the window drains, so a client that never reads its
//!   replies stalls its own sends instead of growing server memory.
//!
//! Buffers are recycled through a per-loop [`BufCache`] (connection churn
//! does not re-allocate read/write buffers), and epoll registration data
//! carries a `slot | generation` token so events for a closed-and-reused
//! slot are discarded. Raw `epoll`/`eventfd` are declared locally via
//! `extern "C"` — the offline build has no libc crate, but glibc is
//! already linked by std on Linux.

#![allow(clippy::too_many_arguments)]

use super::batcher::{CompletionSink, DeadlineExceeded};
use super::tcp::{
    checked_response, encode_batch_body, encode_scores, parse_load_model, parse_predict,
    parse_predict_batch, reject_conn, ConnGuard, Latch, ServerCtl, MAX_FRAME, MAX_PIPELINE,
    OP_DRAIN, OP_HEALTH, OP_LOAD_MODEL, OP_MODELS, OP_PING, OP_PREDICT, OP_PREDICT_BATCH,
    OP_STATS, STATUS_DEADLINE, STATUS_ERR, STATUS_OK, STATUS_OVERLOADED,
};
use super::Coordinator;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::{AsRawFd, RawFd};
use std::os::raw::{c_int, c_void};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Raw epoll/eventfd bindings (no libc crate in the offline build).
mod sys {
    use std::os::raw::{c_int, c_uint, c_void};

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EFD_CLOEXEC: c_int = 0o2000000;
    pub const EFD_NONBLOCK: c_int = 0o4000;

    /// Kernel `struct epoll_event` ABI: packed on x86-64 (the kernel
    /// headers force it there), naturally aligned elsewhere. Fields must
    /// be read by value, never by reference.
    #[derive(Clone, Copy)]
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn eventfd(initval: c_uint, flags: c_int) -> c_int;
        pub fn close(fd: c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    }
}

/// Owned epoll instance.
struct Epoll {
    fd: RawFd,
}

impl Epoll {
    fn new() -> Result<Self> {
        let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error()).context("epoll_create1");
        }
        Ok(Self { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, events: u32, data: u64) -> std::io::Result<()> {
        let mut ev = sys::EpollEvent { events, data };
        let rc = unsafe { sys::epoll_ctl(self.fd, op, fd, &mut ev) };
        if rc < 0 {
            Err(std::io::Error::last_os_error())
        } else {
            Ok(())
        }
    }

    fn add(&self, fd: RawFd, events: u32, data: u64) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, events, data)
    }

    fn modify(&self, fd: RawFd, events: u32, data: u64) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, events, data)
    }

    fn del(&self, fd: RawFd) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait for events (EINTR-transparent). `timeout_ms` is epoll
    /// semantics: `-1` blocks indefinitely, `0` polls, positive caps the
    /// wait — finite timeouts drive deadline reaping and drain sweeps.
    /// Returns the number of filled entries; on an unexpected error it
    /// sleeps briefly (so a persistent failure cannot hot-spin) and
    /// returns 0 — the caller rechecks the stop flag.
    fn wait(&self, events: &mut [sys::EpollEvent], timeout_ms: c_int) -> usize {
        loop {
            let rc = unsafe {
                sys::epoll_wait(self.fd, events.as_mut_ptr(), events.len() as c_int, timeout_ms)
            };
            if rc >= 0 {
                return rc as usize;
            }
            let err = std::io::Error::last_os_error();
            if err.kind() == std::io::ErrorKind::Interrupted {
                continue;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
            return 0;
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.fd);
        }
    }
}

/// Owned eventfd used to wake a loop from other threads (acceptor,
/// batcher completions, shutdown).
struct EventFd {
    fd: RawFd,
}

impl EventFd {
    fn new() -> Result<Self> {
        let fd = unsafe { sys::eventfd(0, sys::EFD_CLOEXEC | sys::EFD_NONBLOCK) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error()).context("eventfd");
        }
        Ok(Self { fd })
    }

    fn raw(&self) -> RawFd {
        self.fd
    }

    /// Wake the owning loop; callable from any thread. Failure is benign
    /// (the counter saturating still leaves the fd readable).
    fn signal(&self) {
        let one: u64 = 1;
        let _ = unsafe { sys::write(self.fd, &one as *const u64 as *const c_void, 8) };
    }

    /// Consume the pending wake counter (nonblocking).
    fn drain(&self) {
        let mut buf = 0u64;
        // one read consumes the whole eventfd counter
        let _ = unsafe { sys::read(self.fd, &mut buf as *mut u64 as *mut c_void, 8) };
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.fd);
        }
    }
}

/// The cross-thread face of one event loop: the acceptor pushes admitted
/// sockets into `inbox`, batcher threads push results into `completions`,
/// and both wake the loop's epoll through the eventfd.
pub(crate) struct LoopShared {
    wake: EventFd,
    inbox: Mutex<Vec<(TcpStream, ConnGuard)>>,
    completions: Mutex<Vec<(u64, Result<Vec<f32>>)>>,
}

impl LoopShared {
    /// Hand one admitted connection to this loop.
    pub(crate) fn push_conn(&self, stream: TcpStream, guard: ConnGuard) {
        self.inbox.lock().unwrap().push((stream, guard));
        self.wake.signal();
    }

    /// Wake the loop so it can observe external state (shutdown).
    pub(crate) fn wake(&self) {
        self.wake.signal();
    }
}

/// [`CompletionSink`] that delivers batcher results to the owning loop.
struct LoopSink(Arc<LoopShared>);

impl CompletionSink for LoopSink {
    fn complete(&self, ticket: u64, result: Result<Vec<f32>>) {
        self.0.completions.lock().unwrap().push((ticket, result));
        self.0.wake.signal();
    }
}

/// Spawned-loop handle returned to `tcp::serve`.
pub(crate) struct EventLoopHandle {
    pub(crate) shared: Arc<LoopShared>,
    pub(crate) join: std::thread::JoinHandle<()>,
}

/// Everything one loop needs to accept on its own `SO_REUSEPORT`
/// listener; `None` under the single-acceptor layout. The admission
/// budget (`active`/`max_conns`) and reject-drain cap are shared across
/// the whole listener group — [`ConnGuard::admit`] reserves atomically,
/// so concurrent per-loop acceptors cannot jointly over-admit.
pub(crate) struct AcceptCtx {
    pub(crate) listener: TcpListener,
    pub(crate) active: Arc<AtomicUsize>,
    pub(crate) max_conns: usize,
    pub(crate) reject_drains: Arc<AtomicUsize>,
    pub(crate) latch: Arc<Latch>,
    pub(crate) stop: Arc<AtomicBool>,
}

/// Epoll token reserved for the wake eventfd.
const TOKEN_WAKE: u64 = u64::MAX;
/// Epoll token reserved for the loop's own listener (reuseport mode).
const TOKEN_ACCEPT: u64 = u64::MAX - 1;
/// Bytes appended to the read buffer per `read` call.
const READ_CHUNK: usize = 16 * 1024;
/// Per-event read budget: yields back to the loop so one firehose
/// connection cannot starve the others on a level-triggered epoll.
const READ_BUDGET: usize = 256 * 1024;
/// Stop serializing replies once this much backlog is unwritten; the
/// remaining pending replies stay queued until `EPOLLOUT` drains it.
const WBUF_SOFT_CAP: usize = 1 << 20;
/// Recycled buffers kept per loop.
const BUF_CACHE: usize = 64;

fn token(slot: usize, gen: u32) -> u64 {
    (slot as u64 & 0xFFFF_FFFF) | ((gen as u64) << 32)
}

/// One reply slot in a connection's in-order response window.
enum PendingReply {
    /// Fully computed (inline ops, errors, completed predicts).
    Ready { status: u8, payload: Vec<u8> },
    /// A single predict awaiting its batcher completion.
    WaitingSingle,
    /// A wire-level batch: one frame covering every item.
    Batch {
        items: Vec<BatchItem>,
        missing: usize,
    },
}

enum BatchItem {
    Done { status: u8, payload: Vec<u8> },
    Waiting,
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    _guard: ConnGuard,
    /// Unparsed request bytes (pooled; complete frames are consumed in
    /// place).
    rbuf: Vec<u8>,
    /// Serialized-but-unwritten response bytes (pooled).
    wbuf: Vec<u8>,
    /// How much of `wbuf` has been written so far.
    wpos: usize,
    /// Sequence id of the next request parsed off this connection.
    next_seq: u64,
    /// Sequence id of the front of `pending`.
    head_seq: u64,
    /// In-order reply window, indexed by `seq - head_seq`.
    pending: VecDeque<PendingReply>,
    /// Interest bits currently registered with epoll.
    reg_events: u32,
    /// Whether the fd is currently registered with epoll at all. Dropped
    /// to `false` when no interest remains (e.g. half-closed peer with a
    /// full reply window) — `EPOLLRDHUP`/`EPOLLHUP` are level-triggered
    /// state, not consumable events, so leaving the fd registered would
    /// spin `epoll_wait` at 100% CPU until completions drain the window.
    registered: bool,
    /// `EPOLLRDHUP`/`EPOLLHUP` observed: never request `EPOLLRDHUP`
    /// again (the condition is permanent and would re-fire forever).
    rdhup_seen: bool,
    /// Peer closed its write side (clean close once replies drain).
    peer_eof: bool,
    /// Fatal protocol error queued: flush the reply window, then close.
    closing: bool,
}

impl Conn {
    fn has_backlog(&self) -> bool {
        self.wpos < self.wbuf.len()
    }

    fn wants_read(&self) -> bool {
        !self.closing && !self.peer_eof && self.pending.len() < MAX_PIPELINE
    }
}

/// Generation-tagged connection slot; the generation increments on close
/// so stale epoll events for a recycled slot index are discarded.
struct Slot {
    gen: u32,
    conn: Option<Conn>,
}

/// Where one batcher ticket's result lands.
struct TicketDest {
    slot: usize,
    gen: u32,
    seq: u64,
    /// `Some(i)` = item `i` of the wire batch at `seq`; `None` = single.
    item: Option<u32>,
    /// Reap fallback: if no completion arrives by `expires` plus a grace
    /// period (the batcher's own deadline shedding normally answers
    /// first), the loop synthesizes a `deadline exceeded` reply so the
    /// connection is never stranded by a reply that can no longer be
    /// produced.
    expires: Option<Instant>,
}

/// Pool of cleared read/write buffers recycled across connections.
#[derive(Default)]
struct BufCache {
    bufs: Vec<Vec<u8>>,
}

impl BufCache {
    fn get(&mut self) -> Vec<u8> {
        self.bufs.pop().unwrap_or_default()
    }

    fn put(&mut self, mut b: Vec<u8>) {
        if self.bufs.len() < BUF_CACHE {
            b.clear();
            self.bufs.push(b);
        }
    }
}

/// Loop-wide state shared by every connection handler on this loop (split
/// from the slot table so a connection and the table can be borrowed
/// simultaneously).
struct LoopCore {
    ep: Epoll,
    coord: Arc<Coordinator>,
    shared: Arc<LoopShared>,
    sink: Arc<dyn CompletionSink>,
    tickets: HashMap<u64, TicketDest>,
    next_ticket: u64,
    bufs: BufCache,
    /// This loop's own listener (reuseport mode); closes on loop exit.
    accept: Option<AcceptCtx>,
    /// Server-wide drain/deploy control, shared with `tcp::serve`.
    ctl: Arc<ServerCtl>,
    /// How many live tickets carry an `expires` — epoll only ticks on a
    /// finite timeout while this is nonzero (or a drain is in progress),
    /// so the deadline-free fast path keeps blocking indefinitely.
    deadline_tickets: usize,
}

impl LoopCore {
    fn put_ticket(&mut self, ticket: u64, dest: TicketDest) {
        if dest.expires.is_some() {
            self.deadline_tickets += 1;
        }
        self.tickets.insert(ticket, dest);
    }

    fn take_ticket(&mut self, ticket: u64) -> Option<TicketDest> {
        let dest = self.tickets.remove(&ticket);
        if let Some(d) = &dest {
            if d.expires.is_some() {
                self.deadline_tickets -= 1;
            }
        }
        dest
    }
}

struct EventLoop {
    core: LoopCore,
    conns: Vec<Slot>,
    free: Vec<usize>,
}

/// Spawn one IO loop; `tcp::serve` owns the handles. With `accept` set,
/// the loop also owns a listener and accepts for itself (reuseport
/// layout); without it, connections arrive via [`LoopShared::push_conn`].
pub(crate) fn spawn_loop(
    idx: usize,
    coord: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
    latch: &Arc<Latch>,
    ctl: &Arc<ServerCtl>,
    accept: Option<AcceptCtx>,
) -> Result<EventLoopHandle> {
    let shared = Arc::new(LoopShared {
        wake: EventFd::new()?,
        inbox: Mutex::new(Vec::new()),
        completions: Mutex::new(Vec::new()),
    });
    let ep = Epoll::new()?;
    ep.add(shared.wake.raw(), sys::EPOLLIN, TOKEN_WAKE)
        .context("register wake eventfd")?;
    if let Some(ctx) = &accept {
        ctx.listener
            .set_nonblocking(true)
            .context("listener nonblocking")?;
        ep.add(ctx.listener.as_raw_fd(), sys::EPOLLIN, TOKEN_ACCEPT)
            .context("register listener")?;
    }
    let guard = latch.register();
    let loop_shared = shared.clone();
    let loop_ctl = ctl.clone();
    let join = std::thread::Builder::new()
        .name(format!("espresso-io-{idx}"))
        .spawn(move || {
            let _lg = guard;
            let sink: Arc<dyn CompletionSink> = Arc::new(LoopSink(loop_shared.clone()));
            let mut el = EventLoop {
                core: LoopCore {
                    ep,
                    coord,
                    shared: loop_shared,
                    sink,
                    tickets: HashMap::new(),
                    next_ticket: 0,
                    bufs: BufCache::default(),
                    accept,
                    ctl: loop_ctl,
                    deadline_tickets: 0,
                },
                conns: Vec::new(),
                free: Vec::new(),
            };
            el.run(&stop);
        })
        .context("spawn event loop")?;
    Ok(EventLoopHandle { shared, join })
}

/// Outcome of one `accept(2)` attempt, decided while the listener ctx is
/// borrowed so the admit/register step can run with `&mut self` after.
enum AcceptStep {
    Admit(TcpStream, ConnGuard),
    Continue,
    Done,
}

/// Extra slack past a ticket's deadline before the loop synthesizes a
/// reply itself: the batcher's own shedding should answer first, so a
/// reap firing means the replica truly went dark.
const REAP_GRACE: Duration = Duration::from_millis(500);

impl EventLoop {
    fn run(&mut self, stop: &AtomicBool) {
        let mut events = vec![sys::EpollEvent { events: 0, data: 0 }; 256];
        while !stop.load(Ordering::SeqCst) {
            // block indefinitely on the fast path; tick while deadlines
            // are in flight (reaping) or a drain is finishing (sweeping)
            let timeout: c_int = if self.core.ctl.draining() {
                20
            } else if self.core.deadline_tickets > 0 {
                50
            } else {
                -1
            };
            let n = self.core.ep.wait(&mut events, timeout);
            if stop.load(Ordering::SeqCst) {
                break;
            }
            let mut woken = false;
            let mut listener_ready = false;
            for ev in events.iter().take(n) {
                // copy fields out of the (possibly packed) struct
                let data = ev.data;
                let bits = ev.events;
                if data == TOKEN_WAKE {
                    woken = true;
                } else if data == TOKEN_ACCEPT {
                    listener_ready = true;
                } else {
                    self.handle_io(data, bits);
                }
            }
            if woken {
                self.core.shared.wake.drain();
            }
            if self.core.ctl.draining() {
                // stop admission: close this loop's listener (reuseport
                // mode) so new connects are refused at the TCP level
                if let Some(ctx) = self.core.accept.take() {
                    let _ = self.core.ep.del(ctx.listener.as_raw_fd());
                }
            } else if listener_ready {
                self.accept_ready();
            }
            // always drain the side queues: a wake may have raced in
            // just after this cycle's epoll_wait returned
            self.accept_new();
            self.route_completions();
            self.reap_expired();
            if self.core.ctl.draining() {
                self.sweep_draining();
                if self.core.tickets.is_empty() && self.live_conns() == 0 {
                    break; // everything in flight answered and flushed
                }
            }
        }
        // dropping self closes every socket and releases the conn guards
    }

    fn live_conns(&self) -> usize {
        self.conns.iter().filter(|s| s.conn.is_some()).count()
    }

    /// While draining: flush every connection's reply window and close
    /// the ones with nothing left in flight, so the loop can exit once
    /// all replies are delivered.
    fn sweep_draining(&mut self) {
        let EventLoop { core, conns, free } = self;
        for slot in 0..conns.len() {
            let close = {
                let Some(s) = conns.get_mut(slot) else { continue };
                let gen = s.gen;
                let Some(conn) = s.conn.as_mut() else { continue };
                if pump_and_drain(core, slot, gen, conn).is_err() {
                    true
                } else if conn.pending.is_empty() && !conn.has_backlog() {
                    true // everything owed is on the wire: close
                } else {
                    finish_or_rearm(core, slot, gen, conn)
                }
            };
            if close {
                close_slot(core, conns, free, slot);
            }
        }
    }

    /// Synthesize `deadline exceeded` replies for tickets whose deadline
    /// passed [`REAP_GRACE`] ago without a batcher completion, so a
    /// replica that died mid-request cannot strand its connections. A
    /// late completion for a reaped ticket is discarded by the ticket
    /// lookup in [`EventLoop::route_completions`].
    fn reap_expired(&mut self) {
        if self.core.deadline_tickets == 0 {
            return;
        }
        let now = Instant::now();
        let expired: Vec<u64> = self
            .core
            .tickets
            .iter()
            .filter(|(_, d)| d.expires.is_some_and(|e| now >= e + REAP_GRACE))
            .map(|(t, _)| *t)
            .collect();
        if expired.is_empty() {
            return;
        }
        let EventLoop { core, conns, free } = self;
        let mut touched: Vec<usize> = Vec::with_capacity(expired.len());
        for ticket in expired {
            let Some(dest) = core.take_ticket(ticket) else {
                continue;
            };
            let Some(s) = conns.get_mut(dest.slot) else {
                continue;
            };
            if s.gen != dest.gen {
                continue;
            }
            let Some(conn) = s.conn.as_mut() else { continue };
            let payload = b"deadline exceeded (no reply from replica)".to_vec();
            match dest.item {
                None => set_reply(
                    conn,
                    dest.seq,
                    PendingReply::Ready {
                        status: STATUS_DEADLINE,
                        payload,
                    },
                ),
                Some(i) => {
                    fill_batch_item(conn, dest.seq, i as usize, STATUS_DEADLINE, payload)
                }
            }
            touched.push(dest.slot);
        }
        touched.sort_unstable();
        touched.dedup();
        for slot in touched {
            let close = {
                let Some(s) = conns.get_mut(slot) else { continue };
                let gen = s.gen;
                let Some(conn) = s.conn.as_mut() else { continue };
                if pump_and_drain(core, slot, gen, conn).is_err() {
                    true
                } else {
                    finish_or_rearm(core, slot, gen, conn)
                }
            };
            if close {
                close_slot(core, conns, free, slot);
            }
        }
    }

    /// Register connections the dispatching acceptor handed over
    /// (single-acceptor layout; a no-op inbox under reuseport).
    fn accept_new(&mut self) {
        let incoming: Vec<(TcpStream, ConnGuard)> = {
            let mut inbox = self.core.shared.inbox.lock().unwrap();
            std::mem::take(&mut *inbox)
        };
        for (stream, guard) in incoming {
            self.register_conn(stream, guard);
        }
    }

    /// Drain this loop's own listener (reuseport layout): accept until
    /// `WouldBlock`, admitting against the shared connection budget.
    fn accept_ready(&mut self) {
        loop {
            let step = {
                let Some(ctx) = self.core.accept.as_ref() else {
                    return;
                };
                match ctx.listener.accept() {
                    Ok((stream, _)) => {
                        if ctx.stop.load(Ordering::SeqCst) {
                            // shutdown wake-up probe (or a straggler
                            // behind it): drop it, stop accepting
                            AcceptStep::Done
                        } else {
                            match ConnGuard::admit(&ctx.active, ctx.max_conns) {
                                Some(guard) => AcceptStep::Admit(stream, guard),
                                None => {
                                    self.core.coord.metrics.record_conn_rejected();
                                    reject_conn(
                                        stream,
                                        ctx.reject_drains.clone(),
                                        &ctx.latch,
                                        ctx.stop.clone(),
                                    );
                                    AcceptStep::Continue
                                }
                            }
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => AcceptStep::Done,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => AcceptStep::Continue,
                    // transient accept failure (e.g. ECONNABORTED): let
                    // level-triggered epoll re-deliver if more are queued
                    Err(_) => AcceptStep::Done,
                }
            };
            match step {
                AcceptStep::Admit(stream, guard) => self.register_conn(stream, guard),
                AcceptStep::Continue => {}
                AcceptStep::Done => return,
            }
        }
    }

    /// Install one admitted connection into a slot and epoll.
    fn register_conn(&mut self, stream: TcpStream, guard: ConnGuard) {
        let EventLoop { core, conns, free } = self;
        if stream.set_nonblocking(true).is_err() {
            return; // dropping closes the socket + releases the guard
        }
        let _ = stream.set_nodelay(true);
        let slot = match free.pop() {
            Some(s) => s,
            None => {
                // slot 0xFFFF_FFFF / 0xFFFF_FFFE with gen 0xFFFF_FFFF
                // would make token() collide with TOKEN_WAKE /
                // TOKEN_ACCEPT; cap the table below both so a connection
                // token can never alias a reserved one
                if conns.len() >= 0xFFFF_FFFE {
                    return; // dropping closes the socket + guard
                }
                conns.push(Slot { gen: 0, conn: None });
                conns.len() - 1
            }
        };
        let gen = conns[slot].gen;
        let fd = stream.as_raw_fd();
        let want = sys::EPOLLIN | sys::EPOLLRDHUP;
        conns[slot].conn = Some(Conn {
            stream,
            _guard: guard,
            rbuf: core.bufs.get(),
            wbuf: core.bufs.get(),
            wpos: 0,
            next_seq: 0,
            head_seq: 0,
            pending: VecDeque::new(),
            reg_events: want,
            registered: true,
            rdhup_seen: false,
            peer_eof: false,
            closing: false,
        });
        if core.ep.add(fd, want, token(slot, gen)).is_err() {
            close_slot(core, conns, free, slot);
        }
    }

    /// One readiness event for a connection slot.
    fn handle_io(&mut self, data: u64, bits: u32) {
        let slot = (data & 0xFFFF_FFFF) as usize;
        let gen = (data >> 32) as u32;
        let EventLoop { core, conns, free } = self;
        let close = {
            let Some(s) = conns.get_mut(slot) else { return };
            if s.gen != gen {
                return; // stale event for a recycled slot
            }
            let Some(conn) = s.conn.as_mut() else { return };
            process_event(core, slot, gen, conn, bits)
        };
        if close {
            close_slot(core, conns, free, slot);
        }
    }

    /// Deliver batcher completions into their reply windows, then pump
    /// every touched connection.
    fn route_completions(&mut self) {
        let done: Vec<(u64, Result<Vec<f32>>)> = {
            let mut c = self.core.shared.completions.lock().unwrap();
            std::mem::take(&mut *c)
        };
        if done.is_empty() {
            return;
        }
        let EventLoop { core, conns, free } = self;
        let mut touched: Vec<usize> = Vec::with_capacity(done.len());
        for (ticket, result) in done {
            let Some(dest) = core.take_ticket(ticket) else {
                continue; // connection closed, or the ticket was reaped
            };
            let Some(s) = conns.get_mut(dest.slot) else {
                continue;
            };
            if s.gen != dest.gen {
                continue;
            }
            let Some(conn) = s.conn.as_mut() else { continue };
            let (status, payload) = match result {
                Ok(scores) => (STATUS_OK, encode_scores(&scores)),
                Err(e) if e.downcast_ref::<DeadlineExceeded>().is_some() => {
                    (STATUS_DEADLINE, b"deadline exceeded".to_vec())
                }
                // `{e:#}` keeps the context chain (e.g. which section of
                // a weight file failed its checksum) in the wire payload
                Err(e) => (STATUS_ERR, format!("{e:#}").into_bytes()),
            };
            match dest.item {
                None => set_reply(conn, dest.seq, PendingReply::Ready { status, payload }),
                Some(i) => fill_batch_item(conn, dest.seq, i as usize, status, payload),
            }
            touched.push(dest.slot);
        }
        touched.sort_unstable();
        touched.dedup();
        for slot in touched {
            let close = {
                let Some(s) = conns.get_mut(slot) else { continue };
                let gen = s.gen;
                let Some(conn) = s.conn.as_mut() else { continue };
                if pump_and_drain(core, slot, gen, conn).is_err() {
                    true
                } else {
                    finish_or_rearm(core, slot, gen, conn)
                }
            };
            if close {
                close_slot(core, conns, free, slot);
            }
        }
    }
}

/// Handle one connection's readiness bits; `true` = close the slot.
fn process_event(core: &mut LoopCore, slot: usize, gen: u32, conn: &mut Conn, bits: u32) -> bool {
    if bits & sys::EPOLLERR != 0 {
        return true;
    }
    if bits & (sys::EPOLLHUP | sys::EPOLLRDHUP) != 0 {
        conn.rdhup_seen = true;
    }
    if bits & sys::EPOLLOUT != 0 && flush(conn).is_err() {
        return true;
    }
    if bits & (sys::EPOLLIN | sys::EPOLLHUP | sys::EPOLLRDHUP) != 0
        && read_and_parse(core, slot, gen, conn).is_err()
    {
        return true;
    }
    if pump_and_drain(core, slot, gen, conn).is_err() {
        return true;
    }
    finish_or_rearm(core, slot, gen, conn)
}

/// Pump the reply window, then re-parse any frames that were already
/// buffered in `rbuf` but blocked on backpressure, repeating until
/// quiescent. `pump` frees reply-window slots, and the bytes behind them
/// are *already read off the socket* — level-triggered `EPOLLIN` will
/// never re-fire for them, and an all-inline burst (e.g. 300 pipelined
/// pings) produces no batcher completions to wake the connection either,
/// so a single parse pass would strand every frame past `MAX_PIPELINE`
/// forever. Terminates: each iteration that makes progress consumes
/// `rbuf` bytes or sets `closing`, both monotone.
fn pump_and_drain(
    core: &mut LoopCore,
    slot: usize,
    gen: u32,
    conn: &mut Conn,
) -> std::result::Result<(), ()> {
    loop {
        pump(core, conn)?;
        let seq_before = conn.next_seq;
        parse_frames(core, slot, gen, conn);
        check_eof_leftover(core, conn);
        if conn.next_seq == seq_before {
            // no new frame dispatched: rbuf holds at most a partial
            // frame, or the window/write backlog is still at its cap
            return Ok(());
        }
    }
}

/// Pull bytes into the read buffer and parse complete frames, up to the
/// fairness budget. `Err` = transport failure, close immediately.
fn read_and_parse(
    core: &mut LoopCore,
    slot: usize,
    gen: u32,
    conn: &mut Conn,
) -> std::result::Result<(), ()> {
    let mut budget = READ_BUDGET;
    while budget > 0 && conn.wants_read() {
        let old = conn.rbuf.len();
        conn.rbuf.resize(old + READ_CHUNK, 0);
        match conn.stream.read(&mut conn.rbuf[old..]) {
            Ok(0) => {
                conn.rbuf.truncate(old);
                conn.peer_eof = true;
                break;
            }
            Ok(n) => {
                conn.rbuf.truncate(old + n);
                budget = budget.saturating_sub(n);
                parse_frames(core, slot, gen, conn);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                conn.rbuf.truncate(old);
                break;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                conn.rbuf.truncate(old);
            }
            Err(_) => {
                conn.rbuf.truncate(old);
                return Err(());
            }
        }
    }
    check_eof_leftover(core, conn);
    Ok(())
}

/// After EOF, bytes that can never complete a frame are a mid-frame
/// truncation: counted and answered with a final err frame, exactly like
/// the threaded path. Deferred while the reply window is full (the
/// leftover might be complete frames waiting on backpressure).
fn check_eof_leftover(core: &mut LoopCore, conn: &mut Conn) {
    if conn.peer_eof
        && !conn.closing
        && !conn.rbuf.is_empty()
        && conn.pending.len() < MAX_PIPELINE
    {
        core.coord.metrics.record_protocol_error();
        let payload = format!("eof inside frame ({} trailing bytes)", conn.rbuf.len());
        conn.pending.push_back(PendingReply::Ready {
            status: STATUS_ERR,
            payload: payload.into_bytes(),
        });
        conn.next_seq += 1;
        conn.closing = true;
        conn.rbuf.clear();
    }
}

/// Consume every complete frame currently in the read buffer (in place —
/// no per-frame allocation) and dispatch it.
fn parse_frames(core: &mut LoopCore, slot: usize, gen: u32, conn: &mut Conn) {
    // take the buffer so frame slices don't alias the &mut Conn
    let rbuf = std::mem::take(&mut conn.rbuf);
    let mut consumed = 0usize;
    while !conn.closing && conn.pending.len() < MAX_PIPELINE {
        let avail = &rbuf[consumed..];
        if avail.len() < 4 {
            break;
        }
        let len = u32::from_le_bytes([avail[0], avail[1], avail[2], avail[3]]);
        if len > MAX_FRAME {
            // unrecoverable: the stream cannot be resynchronized
            core.coord.metrics.record_protocol_error();
            conn.pending.push_back(PendingReply::Ready {
                status: STATUS_ERR,
                payload: format!("frame length {len} exceeds maximum {MAX_FRAME}").into_bytes(),
            });
            conn.next_seq += 1;
            conn.closing = true;
            break;
        }
        let total = 4 + len as usize;
        if avail.len() < total {
            break;
        }
        dispatch_frame(core, slot, gen, conn, &avail[4..total]);
        consumed += total;
    }
    conn.rbuf = rbuf;
    if consumed > 0 {
        conn.rbuf.drain(..consumed);
    }
    if conn.closing {
        // fatal framing violation: the rest of the stream can never be
        // resynchronized, and leftover bytes must not hold the
        // connection open once the err frame is flushed
        conn.rbuf.clear();
    }
}

/// Mirror of `tcp::dispatch` for the event path: inline ops answer
/// immediately; predicts reserve tickets, push reply-window slots, and
/// submit to the batcher without leaving this thread.
fn dispatch_frame(core: &mut LoopCore, slot: usize, gen: u32, conn: &mut Conn, frame: &[u8]) {
    let seq = conn.next_seq;
    conn.next_seq += 1;
    if frame.is_empty() {
        core.coord.metrics.record_protocol_error();
        conn.pending.push_back(PendingReply::Ready {
            status: STATUS_ERR,
            payload: b"empty frame".to_vec(),
        });
        return;
    }
    // a draining server answers observation ops (ping/stats/models/
    // health) but admits no new work
    if core.ctl.draining() && matches!(frame[0], OP_PREDICT | OP_PREDICT_BATCH | OP_LOAD_MODEL) {
        conn.pending.push_back(PendingReply::Ready {
            status: STATUS_ERR,
            payload: b"server draining".to_vec(),
        });
        return;
    }
    match frame[0] {
        OP_PING => conn.pending.push_back(PendingReply::Ready {
            status: STATUS_OK,
            payload: b"pong".to_vec(),
        }),
        OP_STATS => conn.pending.push_back(PendingReply::Ready {
            status: STATUS_OK,
            payload: core.coord.metrics.render().into_bytes(),
        }),
        OP_MODELS => conn.pending.push_back(PendingReply::Ready {
            status: STATUS_OK,
            payload: core.coord.models().join("\n").into_bytes(),
        }),
        OP_PREDICT => match parse_predict(&frame[1..]) {
            Ok((model, img, deadline_ms)) => {
                // the client's wire deadline rides into the batcher
                // (which also applies the server-side request timeout);
                // `expires` arms the loop's reap fallback either way
                let deadline =
                    deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms as u64));
                let expires = deadline
                    .or_else(|| core.coord.request_timeout().map(|t| Instant::now() + t));
                let ticket = core.next_ticket;
                core.next_ticket += 1;
                // ticket goes in BEFORE submit: the completion can only
                // be routed by this same thread, later, so it always
                // finds its destination
                core.put_ticket(
                    ticket,
                    TicketDest {
                        slot,
                        gen,
                        seq,
                        item: None,
                        expires,
                    },
                );
                conn.pending.push_back(PendingReply::WaitingSingle);
                match core.coord.submit_sink(&model, img, &core.sink, ticket, deadline) {
                    Ok(true) => {}
                    Ok(false) => {
                        core.take_ticket(ticket);
                        set_reply(
                            conn,
                            seq,
                            PendingReply::Ready {
                                status: STATUS_OVERLOADED,
                                payload: b"overloaded".to_vec(),
                            },
                        );
                    }
                    Err(e) => {
                        core.take_ticket(ticket);
                        set_reply(
                            conn,
                            seq,
                            PendingReply::Ready {
                                status: STATUS_ERR,
                                payload: format!("{e:#}").into_bytes(),
                            },
                        );
                    }
                }
            }
            Err(e) => {
                core.coord.metrics.record_protocol_error();
                conn.pending.push_back(PendingReply::Ready {
                    status: STATUS_ERR,
                    payload: e.to_string().into_bytes(),
                });
            }
        },
        OP_PREDICT_BATCH => match parse_predict_batch(&frame[1..]) {
            Ok((model, imgs, deadline_ms)) => {
                let deadline =
                    deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms as u64));
                let expires = deadline
                    .or_else(|| core.coord.request_timeout().map(|t| Instant::now() + t));
                let n = imgs.len();
                let first = core.next_ticket;
                core.next_ticket += n as u64;
                for i in 0..n {
                    core.put_ticket(
                        first + i as u64,
                        TicketDest {
                            slot,
                            gen,
                            seq,
                            item: Some(i as u32),
                            expires,
                        },
                    );
                }
                conn.pending.push_back(PendingReply::Batch {
                    items: (0..n).map(|_| BatchItem::Waiting).collect(),
                    missing: n,
                });
                match core.coord.submit_many_sink(&model, imgs, &core.sink, first, deadline) {
                    Ok(admitted) => {
                        // partial admission: rejected items answer
                        // `overloaded` in place, same as the threaded path
                        for (i, ok) in admitted.iter().enumerate() {
                            if !ok {
                                core.take_ticket(first + i as u64);
                                fill_batch_item(
                                    conn,
                                    seq,
                                    i,
                                    STATUS_OVERLOADED,
                                    b"overloaded".to_vec(),
                                );
                            }
                        }
                    }
                    Err(e) => {
                        for i in 0..n {
                            core.take_ticket(first + i as u64);
                        }
                        set_reply(
                            conn,
                            seq,
                            PendingReply::Ready {
                                status: STATUS_ERR,
                                payload: format!("{e:#}").into_bytes(),
                            },
                        );
                    }
                }
            }
            Err(e) => {
                core.coord.metrics.record_protocol_error();
                conn.pending.push_back(PendingReply::Ready {
                    status: STATUS_ERR,
                    payload: e.to_string().into_bytes(),
                });
            }
        },
        OP_LOAD_MODEL => match parse_load_model(&frame[1..]) {
            Ok((model, path)) => {
                let ticket = core.next_ticket;
                core.next_ticket += 1;
                core.put_ticket(
                    ticket,
                    TicketDest {
                        slot,
                        gen,
                        seq,
                        item: None,
                        expires: None,
                    },
                );
                conn.pending.push_back(PendingReply::WaitingSingle);
                // deploy blocks through load + warm + old-version drain
                // (milliseconds to seconds) — never run it on the IO
                // loop. The result routes back through the completion
                // sink like any predict: the ok payload is a 1-score
                // vector carrying the new version number.
                let coord = core.coord.clone();
                let sink = core.sink.clone();
                let spawned = std::thread::Builder::new()
                    .name("espresso-deploy".into())
                    .spawn(move || {
                        let result = coord
                            .deploy(&model, std::path::Path::new(&path))
                            .map(|version| vec![version as f32]);
                        sink.complete(ticket, result);
                    });
                match spawned {
                    // tracked so shutdown/drain can join it instead of
                    // abandoning a half-finished deploy
                    Ok(handle) => core.ctl.track_deploy(handle),
                    Err(_) => {
                        core.take_ticket(ticket);
                        set_reply(
                            conn,
                            seq,
                            PendingReply::Ready {
                                status: STATUS_ERR,
                                payload: b"failed to start deploy thread".to_vec(),
                            },
                        );
                    }
                }
            }
            Err(e) => {
                core.coord.metrics.record_protocol_error();
                conn.pending.push_back(PendingReply::Ready {
                    status: STATUS_ERR,
                    payload: e.to_string().into_bytes(),
                });
            }
        },
        OP_HEALTH => {
            let mut out = String::new();
            for h in core.coord.health() {
                out.push_str(&format!(
                    "{} v{} replicas {}/{} inflight {} queued {}/{}\n",
                    h.model, h.version, h.alive, h.replicas, h.inflight, h.queued, h.queue_depth
                ));
            }
            conn.pending.push_back(PendingReply::Ready {
                status: STATUS_OK,
                payload: out.into_bytes(),
            });
        }
        OP_DRAIN => {
            // the ack lands in this connection's reply window before
            // the drain sweep runs, so it flushes to the wire before
            // the sweep closes the socket
            core.ctl.begin_drain();
            conn.pending.push_back(PendingReply::Ready {
                status: STATUS_OK,
                payload: b"draining".to_vec(),
            });
        }
        op => {
            core.coord.metrics.record_protocol_error();
            conn.pending.push_back(PendingReply::Ready {
                status: STATUS_ERR,
                payload: format!("unknown op {op}").into_bytes(),
            });
        }
    }
}

/// Replace the reply-window slot for `seq`.
fn set_reply(conn: &mut Conn, seq: u64, reply: PendingReply) {
    let idx = seq.wrapping_sub(conn.head_seq) as usize;
    if let Some(p) = conn.pending.get_mut(idx) {
        *p = reply;
    }
}

/// Fill one item of the wire batch at `seq`.
fn fill_batch_item(conn: &mut Conn, seq: u64, item: usize, status: u8, payload: Vec<u8>) {
    let idx = seq.wrapping_sub(conn.head_seq) as usize;
    if let Some(PendingReply::Batch { items, missing }) = conn.pending.get_mut(idx) {
        if let Some(it) = items.get_mut(item) {
            if matches!(it, BatchItem::Waiting) {
                *it = BatchItem::Done { status, payload };
                *missing -= 1;
            }
        }
    }
}

/// Serialize completed head-of-line replies into the write buffer (strict
/// request order) and flush as much as the socket accepts.
fn pump(core: &mut LoopCore, conn: &mut Conn) -> std::result::Result<(), ()> {
    let metrics = &core.coord.metrics;
    loop {
        if conn.wbuf.len() - conn.wpos >= WBUF_SOFT_CAP {
            break;
        }
        let ready = match conn.pending.front() {
            Some(PendingReply::Ready { .. }) => true,
            Some(PendingReply::Batch { missing, .. }) => *missing == 0,
            Some(PendingReply::WaitingSingle) | None => false,
        };
        if !ready {
            break;
        }
        let reply = conn.pending.pop_front().expect("front checked above");
        conn.head_seq += 1;
        let (status, payload) = match reply {
            PendingReply::Ready { status, payload } => (status, payload),
            PendingReply::Batch { items, .. } => {
                let count = items.len();
                let body = encode_batch_body(
                    items.into_iter().map(|it| match it {
                        BatchItem::Done { status, payload } => (status, payload),
                        // unreachable (missing == 0), but never panic the
                        // IO loop over one connection
                        BatchItem::Waiting => {
                            (STATUS_ERR, b"internal: missing batch item".to_vec())
                        }
                    }),
                    count,
                    metrics,
                );
                (STATUS_OK, body)
            }
            // unreachable per the readiness check; answer, don't panic
            PendingReply::WaitingSingle => {
                (STATUS_ERR, b"internal: reply not ready".to_vec())
            }
        };
        let (status, payload) = checked_response(status, payload, metrics);
        // the clamp above bounds payload.len() + 1 <= MAX_FRAME
        let len = payload.len() as u32 + 1;
        conn.wbuf.extend_from_slice(&len.to_le_bytes());
        conn.wbuf.push(status);
        conn.wbuf.extend_from_slice(&payload);
    }
    flush(conn)
}

/// Write the backlog until the socket would block; compacts the buffer.
/// `Err` = peer is gone.
fn flush(conn: &mut Conn) -> std::result::Result<(), ()> {
    while conn.wpos < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => return Err(()),
            Ok(n) => conn.wpos += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return Err(()),
        }
    }
    if conn.wpos == conn.wbuf.len() {
        conn.wbuf.clear();
        conn.wpos = 0;
    } else if conn.wpos >= WBUF_SOFT_CAP {
        // partial write of a large backlog: drop the written prefix so
        // the buffer cannot grow without bound across resumptions
        conn.wbuf.drain(..conn.wpos);
        conn.wpos = 0;
    }
    Ok(())
}

/// Decide the connection's fate after an event: close it (clean EOF with
/// everything delivered, or a flushed fatal error), or re-register the
/// interest set it currently needs. `true` = close.
fn finish_or_rearm(core: &mut LoopCore, slot: usize, gen: u32, conn: &mut Conn) -> bool {
    let flushed = !conn.has_backlog();
    if (conn.closing || conn.peer_eof)
        && conn.pending.is_empty()
        && conn.rbuf.is_empty()
        && flushed
    {
        return true;
    }
    // EPOLLRDHUP/EPOLLHUP are persistent level-triggered *state*: once
    // observed they would re-fire on every epoll_wait, so after the first
    // sighting the half-close is tracked in `rdhup_seen` instead of the
    // interest set.
    let mut want = if conn.rdhup_seen { 0 } else { sys::EPOLLRDHUP };
    if conn.wants_read() {
        want |= sys::EPOLLIN;
    }
    if !flushed {
        want |= sys::EPOLLOUT;
    }
    if want == 0 {
        // Nothing epoll can tell us (e.g. half-closed peer with a full
        // reply window). Deregister so the lingering HUP state cannot
        // busy-spin the loop; every path that reaches here has batcher
        // completions in flight, and route_completions re-arms the fd
        // once the window drains.
        if conn.registered {
            if core.ep.del(conn.stream.as_raw_fd()).is_err() {
                return true;
            }
            conn.registered = false;
            conn.reg_events = 0;
        }
    } else if !conn.registered {
        if core
            .ep
            .add(conn.stream.as_raw_fd(), want, token(slot, gen))
            .is_err()
        {
            return true;
        }
        conn.registered = true;
        conn.reg_events = want;
    } else if want != conn.reg_events {
        if core
            .ep
            .modify(conn.stream.as_raw_fd(), want, token(slot, gen))
            .is_err()
        {
            return true;
        }
        conn.reg_events = want;
    }
    false
}

/// Tear down one slot: deregister, recycle buffers, bump the generation,
/// and drop the connection (closes the socket, releases the conn guard).
/// Outstanding tickets stay in the map; their completions are discarded
/// by the generation check when they arrive.
fn close_slot(core: &mut LoopCore, conns: &mut [Slot], free: &mut Vec<usize>, slot: usize) {
    let Some(s) = conns.get_mut(slot) else { return };
    let Some(conn) = s.conn.take() else { return };
    if conn.registered {
        let _ = core.ep.del(conn.stream.as_raw_fd());
    }
    s.gen = s.gen.wrapping_add(1);
    let Conn {
        stream,
        _guard,
        rbuf,
        wbuf,
        ..
    } = conn;
    core.bufs.put(rbuf);
    core.bufs.put(wbuf);
    free.push(slot);
    drop(stream);
}
