//! Serving metrics: per-engine request counters and latency histograms.

use crate::util::stats::{fmt_ns, LogHistogram};
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

#[derive(Default)]
struct EngineMetrics {
    requests: u64,
    errors: u64,
    batches: u64,
    batched_items: u64,
    latency: LogHistogram,
    queue_wait: LogHistogram,
}

/// Thread-safe metrics sink shared by the coordinator components.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<HashMap<String, EngineMetrics>>,
    started: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(HashMap::new()),
            started: Some(Instant::now()),
        }
    }

    pub fn record_request(&self, engine: &str, latency_ns: u64, queue_ns: u64, ok: bool) {
        let mut inner = self.inner.lock().unwrap();
        let m = inner.entry(engine.to_string()).or_default();
        m.requests += 1;
        if !ok {
            m.errors += 1;
        }
        m.latency.record(latency_ns);
        m.queue_wait.record(queue_ns);
    }

    pub fn record_batch(&self, engine: &str, items: usize) {
        let mut inner = self.inner.lock().unwrap();
        let m = inner.entry(engine.to_string()).or_default();
        m.batches += 1;
        m.batched_items += items as u64;
    }

    /// Snapshot of one engine's stats.
    pub fn snapshot(&self, engine: &str) -> Option<MetricsSnapshot> {
        let inner = self.inner.lock().unwrap();
        inner.get(engine).map(|m| MetricsSnapshot {
            engine: engine.to_string(),
            requests: m.requests,
            errors: m.errors,
            batches: m.batches,
            mean_batch: if m.batches == 0 {
                0.0
            } else {
                m.batched_items as f64 / m.batches as f64
            },
            mean_latency_ns: m.latency.mean_ns(),
            p50_latency_ns: m.latency.percentile_ns(50.0),
            p95_latency_ns: m.latency.percentile_ns(95.0),
            p99_latency_ns: m.latency.percentile_ns(99.0),
            mean_queue_ns: m.queue_wait.mean_ns(),
        })
    }

    pub fn engines(&self) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        let mut keys: Vec<_> = inner.keys().cloned().collect();
        keys.sort();
        keys
    }

    /// Total requests across engines per second of uptime.
    pub fn throughput(&self) -> f64 {
        let inner = self.inner.lock().unwrap();
        let total: u64 = inner.values().map(|m| m.requests).sum();
        match self.started {
            Some(t) => total as f64 / t.elapsed().as_secs_f64().max(1e-9),
            None => 0.0,
        }
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<28} {:>9} {:>6} {:>10} {:>10} {:>10} {:>8}\n",
            "engine", "requests", "errs", "mean", "p95", "p99", "batch"
        ));
        for name in self.engines() {
            if let Some(s) = self.snapshot(&name) {
                out.push_str(&format!(
                    "{:<28} {:>9} {:>6} {:>10} {:>10} {:>10} {:>8.1}\n",
                    s.engine,
                    s.requests,
                    s.errors,
                    fmt_ns(s.mean_latency_ns),
                    fmt_ns(s.p95_latency_ns),
                    fmt_ns(s.p99_latency_ns),
                    s.mean_batch
                ));
            }
        }
        out
    }
}

/// Point-in-time view of one engine's serving stats.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub engine: String,
    pub requests: u64,
    pub errors: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub mean_latency_ns: f64,
    pub p50_latency_ns: f64,
    pub p95_latency_ns: f64,
    pub p99_latency_ns: f64,
    pub mean_queue_ns: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request("a", 1000, 100, true);
        m.record_request("a", 3000, 100, false);
        m.record_batch("a", 4);
        let s = m.snapshot("a").unwrap();
        assert_eq!(s.requests, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch - 4.0).abs() < 1e-9);
        assert!(s.mean_latency_ns > 0.0);
        assert!(m.snapshot("missing").is_none());
        assert!(m.render().contains('a'));
    }

    #[test]
    fn throughput_counts_all_engines() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.record_request("x", 100, 0, true);
        }
        assert!(m.throughput() > 0.0);
    }
}
