//! Serving metrics: per-model request counters, latency histograms, the
//! latest per-layer forward-plan profiles, and workspace buffer-pool
//! stats (hits/misses/evictions and the parked-scratch high-water).
//!
//! All per-model rows are keyed by the **registered model name** (what
//! `Coordinator::register` was given and what clients address requests
//! to), never by `Engine::name()` — several models can share an engine
//! label (e.g. two `"opt"` networks), and the stats/profile/pool tables
//! must agree on one key per model. Transport-level failures that have no
//! model to charge (framing violations, connection-capacity rejections)
//! land in global counters.

use crate::alloc::PoolStats;
use crate::net::PlanProfile;
use crate::util::stats::{fmt_ns, LogHistogram};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

#[derive(Default)]
struct EngineMetrics {
    requests: u64,
    errors: u64,
    /// Requests refused by admission control (queue at `--queue-depth`).
    rejected: u64,
    /// High-water mark of the admission queue depth.
    queue_peak: u64,
    batches: u64,
    batched_items: u64,
    /// Batches whose engine call panicked (caught at the batcher's
    /// isolation boundary; every request in the batch got an err reply).
    panics: u64,
    /// Replicas rebuilt by the registry supervisor after their batcher
    /// thread died or poisoned itself.
    replica_restarts: u64,
    /// Requests shed because their deadline passed before execution.
    deadline_exceeded: u64,
    latency: LogHistogram,
    queue_wait: LogHistogram,
}

/// Thread-safe metrics sink shared by the coordinator components.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<HashMap<String, EngineMetrics>>,
    plans: Mutex<HashMap<String, PlanProfile>>,
    pools: Mutex<HashMap<String, PoolStats>>,
    /// Requests served per replica index, keyed by registered model name.
    /// The request/latency counters above aggregate all replicas under
    /// one model row; this is the per-replica breakdown `render` prints.
    replicas: Mutex<HashMap<String, Vec<u64>>>,
    /// Framing violations (truncated/oversize frames, malformed payloads)
    /// — counted instead of being silently swallowed as peer closes.
    protocol_errors: AtomicU64,
    /// Connections refused at the acceptor's `--max-conns` cap.
    conns_rejected: AtomicU64,
    /// Responses whose encoded frame would exceed `MAX_FRAME` — refused
    /// with an err frame instead of silently truncating the length prefix
    /// (a truncated prefix desyncs the stream for every later frame).
    frames_too_large: AtomicU64,
    /// Weight files refused by format integrity verification (v4
    /// checksum/length mismatches) — a deploy that failed closed.
    integrity_rejects: AtomicU64,
    started: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(HashMap::new()),
            plans: Mutex::new(HashMap::new()),
            pools: Mutex::new(HashMap::new()),
            replicas: Mutex::new(HashMap::new()),
            protocol_errors: AtomicU64::new(0),
            conns_rejected: AtomicU64::new(0),
            frames_too_large: AtomicU64::new(0),
            integrity_rejects: AtomicU64::new(0),
            started: Some(Instant::now()),
        }
    }

    /// Store the latest per-layer plan profile snapshot for an engine
    /// (pulled from `Engine::plan_profile` by the coordinator).
    pub fn record_plan_profile(&self, engine: &str, profile: PlanProfile) {
        self.plans
            .lock()
            .unwrap()
            .insert(engine.to_string(), profile);
    }

    /// Latest plan profile recorded for an engine.
    pub fn plan_profile(&self, engine: &str) -> Option<PlanProfile> {
        self.plans.lock().unwrap().get(engine).cloned()
    }

    /// Store the latest workspace buffer-pool snapshot for an engine.
    pub fn record_pool_stats(&self, engine: &str, stats: PoolStats) {
        self.pools.lock().unwrap().insert(engine.to_string(), stats);
    }

    /// Latest buffer-pool snapshot recorded for an engine.
    pub fn pool_stats(&self, engine: &str) -> Option<PoolStats> {
        self.pools.lock().unwrap().get(engine).copied()
    }

    /// Per-layer plan tables for every engine that reported one.
    pub fn render_plan_profiles(&self) -> String {
        let plans = self.plans.lock().unwrap();
        let mut names: Vec<_> = plans.keys().cloned().collect();
        names.sort();
        let mut out = String::new();
        for name in names {
            let p = &plans[&name];
            if p.calls() == 0 {
                continue;
            }
            out.push_str(&format!("-- plan: {name} --\n"));
            out.push_str(&p.render());
        }
        out
    }

    pub fn record_request(&self, engine: &str, latency_ns: u64, queue_ns: u64, ok: bool) {
        let mut inner = self.inner.lock().unwrap();
        let m = inner.entry(engine.to_string()).or_default();
        m.requests += 1;
        if !ok {
            m.errors += 1;
        }
        m.latency.record(latency_ns);
        m.queue_wait.record(queue_ns);
    }

    /// Count one served request against a specific replica of a model.
    /// Aggregate counters stay under the model name (`record_request`);
    /// this only feeds the per-replica breakdown and dispatch checks.
    pub fn record_replica_request(&self, engine: &str, replica: usize) {
        let mut reps = self.replicas.lock().unwrap();
        let v = reps.entry(engine.to_string()).or_default();
        if v.len() <= replica {
            v.resize(replica + 1, 0);
        }
        v[replica] += 1;
    }

    /// Requests served per replica index (empty if the model never
    /// recorded replica-level traffic).
    pub fn replica_served(&self, engine: &str) -> Vec<u64> {
        self.replicas
            .lock()
            .unwrap()
            .get(engine)
            .cloned()
            .unwrap_or_default()
    }

    pub fn record_batch(&self, engine: &str, items: usize) {
        let mut inner = self.inner.lock().unwrap();
        let m = inner.entry(engine.to_string()).or_default();
        m.batches += 1;
        m.batched_items += items as u64;
    }

    /// Count `n` requests refused by a model's admission queue.
    pub fn record_rejected(&self, engine: &str, n: u64) {
        if n == 0 {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.entry(engine.to_string()).or_default().rejected += n;
    }

    /// Track the admission-queue high-water mark for a model.
    pub fn record_queue_depth(&self, engine: &str, depth: usize) {
        let mut inner = self.inner.lock().unwrap();
        let m = inner.entry(engine.to_string()).or_default();
        m.queue_peak = m.queue_peak.max(depth as u64);
    }

    /// Count one wire-protocol violation (not attributable to a model).
    pub fn record_protocol_error(&self) {
        self.protocol_errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn protocol_errors(&self) -> u64 {
        self.protocol_errors.load(Ordering::Relaxed)
    }

    /// Count one connection refused at the acceptor's capacity cap.
    pub fn record_conn_rejected(&self) {
        self.conns_rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn conns_rejected(&self) -> u64 {
        self.conns_rejected.load(Ordering::Relaxed)
    }

    /// Count one response refused because its encoded frame would
    /// overflow the `u32` length prefix / `MAX_FRAME` bound.
    pub fn record_frame_too_large(&self) {
        self.frames_too_large.fetch_add(1, Ordering::Relaxed);
    }

    pub fn frames_too_large(&self) -> u64 {
        self.frames_too_large.load(Ordering::Relaxed)
    }

    /// Count one panicking batch caught at a model's isolation boundary.
    pub fn record_panic(&self, engine: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.entry(engine.to_string()).or_default().panics += 1;
    }

    pub fn panics(&self, engine: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .get(engine)
            .map_or(0, |m| m.panics)
    }

    /// Count one replica rebuilt by the supervisor for a model.
    pub fn record_replica_restart(&self, engine: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.entry(engine.to_string()).or_default().replica_restarts += 1;
    }

    pub fn replica_restarts(&self, engine: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .get(engine)
            .map_or(0, |m| m.replica_restarts)
    }

    /// Count requests shed because their deadline expired in the queue.
    pub fn record_deadline_exceeded(&self, engine: &str, n: u64) {
        if n == 0 {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.entry(engine.to_string()).or_default().deadline_exceeded += n;
    }

    pub fn deadline_exceeded(&self, engine: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .get(engine)
            .map_or(0, |m| m.deadline_exceeded)
    }

    /// Count one weight file refused by integrity verification.
    pub fn record_integrity_reject(&self) {
        self.integrity_rejects.fetch_add(1, Ordering::Relaxed);
    }

    pub fn integrity_rejects(&self) -> u64 {
        self.integrity_rejects.load(Ordering::Relaxed)
    }

    /// Snapshot of one engine's stats.
    pub fn snapshot(&self, engine: &str) -> Option<MetricsSnapshot> {
        let inner = self.inner.lock().unwrap();
        inner.get(engine).map(|m| MetricsSnapshot {
            engine: engine.to_string(),
            requests: m.requests,
            errors: m.errors,
            rejected: m.rejected,
            queue_peak: m.queue_peak,
            batches: m.batches,
            panics: m.panics,
            replica_restarts: m.replica_restarts,
            deadline_exceeded: m.deadline_exceeded,
            mean_batch: if m.batches == 0 {
                0.0
            } else {
                m.batched_items as f64 / m.batches as f64
            },
            mean_latency_ns: m.latency.mean_ns(),
            p50_latency_ns: m.latency.percentile_ns(50.0),
            p95_latency_ns: m.latency.percentile_ns(95.0),
            p99_latency_ns: m.latency.percentile_ns(99.0),
            mean_queue_ns: m.queue_wait.mean_ns(),
        })
    }

    pub fn engines(&self) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        let mut keys: Vec<_> = inner.keys().cloned().collect();
        keys.sort();
        keys
    }

    /// Total requests recorded across every engine (the serve loop's
    /// idle detector: unchanged between two ticks ⇒ no traffic).
    pub fn total_requests(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner.values().map(|m| m.requests).sum()
    }

    /// Total requests across engines per second of uptime.
    pub fn throughput(&self) -> f64 {
        let total = self.total_requests();
        match self.started {
            Some(t) => total as f64 / t.elapsed().as_secs_f64().max(1e-9),
            None => 0.0,
        }
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<28} {:>9} {:>6} {:>7} {:>6} {:>10} {:>10} {:>10} {:>8}\n",
            "model", "requests", "errs", "rejects", "q-peak", "mean", "p95", "p99", "batch"
        ));
        for name in self.engines() {
            if let Some(s) = self.snapshot(&name) {
                out.push_str(&format!(
                    "{:<28} {:>9} {:>6} {:>7} {:>6} {:>10} {:>10} {:>10} {:>8.1}\n",
                    s.engine,
                    s.requests,
                    s.errors,
                    s.rejected,
                    s.queue_peak,
                    fmt_ns(s.mean_latency_ns),
                    fmt_ns(s.p95_latency_ns),
                    fmt_ns(s.p99_latency_ns),
                    s.mean_batch
                ));
            }
        }
        {
            // per-replica breakdown for replicated models: the table row
            // above is the sum, this line shows how dispatch spread it
            let reps = self.replicas.lock().unwrap();
            let mut names: Vec<_> = reps.keys().cloned().collect();
            names.sort();
            for name in names {
                let v = &reps[&name];
                if v.len() < 2 {
                    continue;
                }
                let parts: Vec<String> = v
                    .iter()
                    .enumerate()
                    .map(|(i, n)| format!("r{i}={n}"))
                    .collect();
                out.push_str(&format!("replicas[{name}]: {}\n", parts.join(" ")));
            }
        }
        {
            // fault counters only for models that actually saw failures —
            // the common all-zero case must not widen the table
            for name in self.engines() {
                if let Some(s) = self.snapshot(&name) {
                    if s.panics + s.replica_restarts + s.deadline_exceeded > 0 {
                        out.push_str(&format!(
                            "faults[{name}]: {} panics, {} replica restarts, {} deadline exceeded\n",
                            s.panics, s.replica_restarts, s.deadline_exceeded
                        ));
                    }
                }
            }
        }
        out.push_str(&format!(
            "transport: {} protocol errors, {} oversize frames, {} connections rejected, \
             {} integrity rejects\n",
            self.protocol_errors(),
            self.frames_too_large(),
            self.conns_rejected(),
            self.integrity_rejects()
        ));
        let ps = crate::util::parallel::pool_status();
        out.push_str(&format!(
            "threads: {} configured, {} pool workers parked, {} spawned total; \
             {} pool jobs, {} inline (below grain), {} inline (pool busy)\n",
            ps.threads, ps.workers_alive, ps.spawned, ps.jobs, ps.serial_jobs, ps.busy_jobs,
        ));
        out.push_str(&self.render_pools());
        out
    }

    /// Per-engine workspace pool table: hit/miss/eviction counters plus
    /// the parked-scratch footprint and its lifetime high-water (what an
    /// idle trim releases). Empty when no engine reported pools.
    pub fn render_pools(&self) -> String {
        let pools = self.pools.lock().unwrap();
        let mut names: Vec<_> = pools.keys().cloned().collect();
        names.sort();
        let mut out = String::new();
        for name in names {
            let p = &pools[&name];
            out.push_str(&format!(
                "pool[{name}]: {} hits ({} worker-warm), {} misses, {} evicted, \
                 {} parked buffers ({} elems, peak {} elems)\n",
                p.hits,
                p.affine_hits,
                p.misses,
                p.evicted,
                p.free_buffers,
                p.free_elems,
                p.peak_free_elems,
            ));
        }
        out
    }
}

/// Point-in-time view of one engine's serving stats.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub engine: String,
    pub requests: u64,
    pub errors: u64,
    pub rejected: u64,
    pub queue_peak: u64,
    pub batches: u64,
    pub panics: u64,
    pub replica_restarts: u64,
    pub deadline_exceeded: u64,
    pub mean_batch: f64,
    pub mean_latency_ns: f64,
    pub p50_latency_ns: f64,
    pub p95_latency_ns: f64,
    pub p99_latency_ns: f64,
    pub mean_queue_ns: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request("a", 1000, 100, true);
        m.record_request("a", 3000, 100, false);
        m.record_batch("a", 4);
        let s = m.snapshot("a").unwrap();
        assert_eq!(s.requests, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch - 4.0).abs() < 1e-9);
        assert!(s.mean_latency_ns > 0.0);
        assert!(m.snapshot("missing").is_none());
        assert!(m.render().contains('a'));
    }

    #[test]
    fn plan_profiles_render_per_engine() {
        use crate::layers::{ActKind, Backend};
        use crate::net::{Boundary, PlanProfile, ProfileRow};
        use crate::tensor::Shape;
        let m = Metrics::new();
        assert!(m.plan_profile("opt").is_none());
        let prof = PlanProfile {
            rows: vec![ProfileRow {
                name: "Dense 784x256 +BN +sign".into(),
                backend: Backend::Binary,
                in_kind: ActKind::Bytes,
                out_kind: ActKind::Bits,
                boundary: Boundary::Planes,
                out_shape: Shape::vector(256),
                calls: 4,
                total_ns: 8000,
                bytes_out: 1024,
                peak_batch: 1,
                peak_scratch_bytes: 2048,
                peak_scratch_materialized_bytes: 8192,
                par: Default::default(),
            }],
        };
        m.record_plan_profile("opt", prof);
        assert_eq!(m.plan_profile("opt").unwrap().calls(), 4);
        let table = m.render_plan_profiles();
        assert!(table.contains("plan: opt"), "{table}");
        assert!(table.contains("Dense 784x256"), "{table}");
        // engines that never ran are skipped
        m.record_plan_profile("idle", PlanProfile::default());
        assert!(!m.render_plan_profiles().contains("idle"));
    }

    #[test]
    fn pool_stats_surface_in_render() {
        let m = Metrics::new();
        assert!(m.pool_stats("opt").is_none());
        assert_eq!(m.render_pools(), "");
        m.record_pool_stats(
            "opt",
            PoolStats {
                hits: 10,
                affine_hits: 4,
                misses: 2,
                evicted: 1,
                free_buffers: 3,
                free_elems: 4096,
                peak_free_elems: 8192,
            },
        );
        let s = m.pool_stats("opt").unwrap();
        assert_eq!(s.evicted, 1);
        assert_eq!(s.peak_free_elems, 8192);
        let table = m.render_pools();
        assert!(table.contains("pool[opt]"), "{table}");
        assert!(table.contains("1 evicted"), "{table}");
        assert!(table.contains("peak 8192"), "{table}");
        // the main render appends the pool lines
        assert!(m.render().contains("pool[opt]"));
    }

    #[test]
    fn rejections_and_protocol_errors_surface() {
        let m = Metrics::new();
        m.record_request("bmlp", 1000, 100, true);
        m.record_rejected("bmlp", 0); // no-op
        m.record_rejected("bmlp", 3);
        m.record_queue_depth("bmlp", 2);
        m.record_queue_depth("bmlp", 7);
        m.record_queue_depth("bmlp", 4);
        m.record_protocol_error();
        m.record_protocol_error();
        m.record_conn_rejected();
        m.record_frame_too_large();
        let s = m.snapshot("bmlp").unwrap();
        assert_eq!(s.rejected, 3);
        assert_eq!(s.queue_peak, 7);
        assert_eq!(m.protocol_errors(), 2);
        assert_eq!(m.conns_rejected(), 1);
        assert_eq!(m.frames_too_large(), 1);
        let table = m.render();
        assert!(table.contains("rejects"), "{table}");
        assert!(table.contains("2 protocol errors"), "{table}");
        assert!(table.contains("1 oversize frames"), "{table}");
        assert!(table.contains("1 connections rejected"), "{table}");
    }

    #[test]
    fn replica_breakdown_aggregates_under_model_name() {
        let m = Metrics::new();
        // three replicas of one registered model: the table row is the
        // sum, the breakdown line carries the per-replica split
        for _ in 0..5 {
            m.record_request("bmlp", 1000, 100, true);
        }
        m.record_replica_request("bmlp", 0);
        m.record_replica_request("bmlp", 0);
        m.record_replica_request("bmlp", 2);
        m.record_replica_request("bmlp", 1);
        m.record_replica_request("bmlp", 1);
        assert_eq!(m.snapshot("bmlp").unwrap().requests, 5);
        assert_eq!(m.replica_served("bmlp"), vec![2, 2, 1]);
        assert_eq!(m.replica_served("missing"), Vec::<u64>::new());
        let table = m.render();
        assert!(table.contains("replicas[bmlp]: r0=2 r1=2 r2=1"), "{table}");
        // single-replica models don't get a redundant breakdown line
        m.record_replica_request("solo", 0);
        assert!(!m.render().contains("replicas[solo]"));
    }

    #[test]
    fn fault_counters_surface_in_render() {
        let m = Metrics::new();
        m.record_request("bmlp", 1000, 100, true);
        assert_eq!(m.panics("bmlp"), 0);
        assert!(!m.render().contains("faults[bmlp]"), "all-zero row hidden");
        m.record_panic("bmlp");
        m.record_replica_restart("bmlp");
        m.record_replica_restart("bmlp");
        m.record_deadline_exceeded("bmlp", 0); // no-op
        m.record_deadline_exceeded("bmlp", 3);
        m.record_integrity_reject();
        assert_eq!(m.panics("bmlp"), 1);
        assert_eq!(m.replica_restarts("bmlp"), 2);
        assert_eq!(m.deadline_exceeded("bmlp"), 3);
        assert_eq!(m.integrity_rejects(), 1);
        let s = m.snapshot("bmlp").unwrap();
        assert_eq!((s.panics, s.replica_restarts, s.deadline_exceeded), (1, 2, 3));
        let table = m.render();
        assert!(
            table.contains("faults[bmlp]: 1 panics, 2 replica restarts, 3 deadline exceeded"),
            "{table}"
        );
        assert!(table.contains("1 integrity rejects"), "{table}");
        // unknown models read zero everywhere
        assert_eq!(m.panics("missing"), 0);
        assert_eq!(m.replica_restarts("missing"), 0);
        assert_eq!(m.deadline_exceeded("missing"), 0);
    }

    #[test]
    fn total_requests_sums_engines() {
        let m = Metrics::new();
        m.record_request("a", 100, 0, true);
        m.record_request("b", 100, 0, true);
        m.record_request("b", 100, 0, false);
        assert_eq!(m.total_requests(), 3);
    }

    #[test]
    fn throughput_counts_all_engines() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.record_request("x", 100, 0, true);
        }
        assert!(m.throughput() > 0.0);
    }
}
