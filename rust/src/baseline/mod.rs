//! Faithful re-implementations of the systems the paper compares against
//! (§6: BinaryNet's optimized kernels and the Nervana/neon derivative).
//!
//! These baselines deliberately reproduce the *measured drawbacks* the
//! paper attributes to them, on the same substrate as our optimized
//! engine, so the Table 1/2 speedup ratios are apples-to-apples:
//!
//! * **pack-per-forward** — weights are binarized and bit-packed on
//!   *every* call (Espresso packs once at load; §6.2 "Binary optimized
//!   layers" / experiment A2);
//! * **column packing** — BinaryNet packs the weight matrix down its
//!   columns with strided accesses (the "≈4× slower" kernel of §6.2);
//!   the neon derivative uses the row packer but still re-packs per call;
//! * **no register blocking** — the GEMM is a plain dot-product sweep
//!   (one output at a time), vs our 1×4 register-blocked micro-kernel;
//! * **float first layer** — no bit-plane decomposition (§6.2
//!   "First-layer binary optimization");
//! * **GEMM only** — no GEMV fast path at batch 1 (§6.2, A3);
//! * **MLP only** — binary conv layers are not optimized (the paper's
//!   headline gap): conv layers fall back to the float path entirely.

use crate::bitpack::{mismatches, pack_matrix_cols, pack_matrix_rows, words_for};
use crate::format::{InputKind, LayerSpec, ModelSpec};
use crate::layers::BnParams;
use crate::linalg;
use crate::tensor::{Shape, Tensor};
use crate::util::parallel::parallel_for_mut_chunks;
use anyhow::{bail, Result};

/// Which baseline system to emulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaselineKind {
    /// Courbariaux/Hubara BinaryNet optimized kernels (Theano-era).
    BinaryNet,
    /// Intel Nervana neon BDNN (BinaryNet derivative; row packer).
    NeonLike,
}

enum BaseLayer {
    Dense {
        inf: usize,
        outf: usize,
        /// Stored as the framework stores it: float, `in×out`
        /// (column-major relative to our GEMM's B operand) — packing
        /// this per call is the measured overhead.
        w_t: Vec<f32>,
        /// Row-major `out×in` copy for the float paths.
        w_rows: Vec<f32>,
        bn: Option<BnParams>,
        sign: bool,
        first: bool,
    },
    /// Conv blocks run the plain float path (baselines cannot optimize
    /// them — exactly the gap Table 3 exposes).
    FloatConv(crate::layers::ConvLayer<u64>),
}

/// A baseline inference engine over the same `.esp` models.
pub struct BaselineEngine {
    pub kind: BaselineKind,
    pub name: String,
    pub input_shape: Shape,
    layers: Vec<BaseLayer>,
    ws: crate::alloc::Workspace,
}

impl BaselineEngine {
    pub fn from_spec(spec: &ModelSpec, kind: BaselineKind) -> Result<Self> {
        if spec.input_kind != InputKind::Bytes {
            bail!("baseline engines expect byte input models");
        }
        let mut layers = Vec::new();
        let mut shape = spec.input_shape;
        let mut first_dense = true;
        for l in &spec.layers {
            match l {
                LayerSpec::Dense {
                    in_features,
                    out_features,
                    sign,
                    weights,
                    bn,
                    ..
                } => {
                    let (inf, outf) = (*in_features as usize, *out_features as usize);
                    let w_rows: Vec<f32> = weights
                        .iter()
                        .map(|&x| if x >= 0.0 { 1.0 } else { -1.0 })
                        .collect();
                    // transpose to in×out: the storage layout BinaryNet
                    // packs by columns on every call
                    let mut w_t = vec![0f32; inf * outf];
                    for o in 0..outf {
                        for i in 0..inf {
                            w_t[i * outf + o] = w_rows[o * inf + i];
                        }
                    }
                    layers.push(BaseLayer::Dense {
                        inf,
                        outf,
                        w_t,
                        w_rows,
                        bn: bn.as_ref().map(|b| b.to_params()),
                        sign: *sign,
                        first: first_dense,
                    });
                    first_dense = false;
                    shape = Shape::vector(outf);
                }
                LayerSpec::Conv {
                    in_channels,
                    filters,
                    kh,
                    kw,
                    stride,
                    pad,
                    sign,
                    pool,
                    weights,
                    bn,
                    ..
                } => {
                    let mut conv = crate::layers::ConvLayer::<u64>::new(
                        *in_channels as usize,
                        *filters as usize,
                        *kh as usize,
                        *kw as usize,
                        *stride as usize,
                        *pad as usize,
                        weights,
                        bn.as_ref().map(|b| b.to_params()),
                        *sign,
                        pool.map(|(k, s)| LayerSpec::pool_spec(k, s)),
                    );
                    use crate::layers::Layer;
                    shape = conv.prepare(shape);
                    first_dense = false;
                    layers.push(BaseLayer::FloatConv(conv));
                }
                other => bail!("baseline engine cannot emulate layer {other:?}"),
            }
        }
        Ok(Self {
            kind,
            name: format!("{kind:?}-{}", spec.name),
            input_shape: spec.input_shape,
            layers,
            ws: crate::alloc::Workspace::new(),
        })
    }

    /// Forward one byte image, reproducing the baseline's per-call
    /// packing work. Returns class scores.
    pub fn predict_bytes(&self, img: &Tensor<u8>) -> Vec<f32> {
        assert_eq!(img.shape.len(), self.input_shape.len(), "input size");
        let mut act = ActF::Float(img.to_f32());
        for layer in &self.layers {
            act = self.forward_layer(layer, act);
        }
        match act {
            ActF::Float(t) => t.data,
        }
    }

    fn forward_layer(&self, layer: &BaseLayer, x: ActF) -> ActF {
        match layer {
            BaseLayer::FloatConv(conv) => {
                use crate::layers::{Act, Backend, Layer};
                let ActF::Float(t) = x;
                let out = conv
                    .forward(Act::<u64>::Float(t), Backend::Float, &self.ws)
                    .into_float();
                ActF::Float(out)
            }
            BaseLayer::Dense {
                inf,
                outf,
                w_t,
                w_rows,
                bn,
                sign,
                first,
            } => {
                let ActF::Float(t) = x;
                let xv = flatten(t, *inf);
                let mut y = if *first {
                    // float first layer: no binary optimization available
                    linalg::sgemm(&xv, w_rows, 1, *outf, *inf)
                } else {
                    // THE BASELINE HOT PATH: binarize + pack BOTH operands
                    // on every call, then an unblocked XNOR-popcount GEMM.
                    let pa = pack_matrix_rows::<u64>(&xv, 1, *inf);
                    let pb = match self.kind {
                        // strided column packing (the ≈4× slower kernel)
                        BaselineKind::BinaryNet => pack_matrix_cols::<u64>(w_t, *inf, *outf),
                        // neon derivative: row packer over the transposed copy
                        BaselineKind::NeonLike => pack_matrix_rows::<u64>(w_rows, *outf, *inf),
                    };
                    let mut out = vec![0i32; *outf];
                    naive_packed_gemm(&pa, &pb, &mut out, 1, *outf, *inf);
                    out.into_iter().map(|v| v as f32).collect()
                };
                if let Some(b) = bn {
                    b.apply(&mut y);
                }
                if *sign {
                    for v in y.iter_mut() {
                        *v = if *v >= 0.0 { 1.0 } else { -1.0 };
                    }
                }
                ActF::Float(Tensor::from_vec(Shape::vector(*outf), y))
            }
        }
    }
}

/// Baseline activations are always float (they unpack after every GEMM).
enum ActF {
    Float(Tensor<f32>),
}

fn flatten(t: Tensor<f32>, expect: usize) -> Vec<f32> {
    assert_eq!(t.shape.len(), expect, "activation size");
    t.data
}

/// Unblocked packed GEMM: one dot product per output, no register
/// blocking or panel reuse (models the pre-Espresso kernels). Public so
/// the T1 bench can measure the baseline kernel in isolation.
pub fn bench_naive_gemm(a: &[u64], b: &[u64], out: &mut [i32], m: usize, n: usize, k: usize) {
    naive_packed_gemm(a, b, out, m, n, k)
}

fn naive_packed_gemm(a: &[u64], b: &[u64], out: &mut [i32], m: usize, n: usize, k: usize) {
    let kw = words_for::<u64>(k);
    assert_eq!(a.len(), m * kw);
    assert_eq!(b.len(), n * kw);
    assert_eq!(out.len(), m * n);
    parallel_for_mut_chunks(out, n, 8, |row0, chunk| {
        for (r, crow) in chunk.chunks_mut(n).enumerate() {
            let arow = &a[(row0 + r) * kw..(row0 + r + 1) * kw];
            for (j, c) in crow.iter_mut().enumerate() {
                let brow = &b[j * kw..(j + 1) * kw];
                *c = k as i32 - 2 * mismatches(arow, brow) as i32;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layers::Backend;
    use crate::net::{argmax, bmlp_spec, Network};
    use crate::util::rng::Rng;

    #[test]
    fn baselines_numerically_match_espresso() {
        // the paper stresses Espresso is numerically equivalent to
        // BinaryNet; our baselines must produce identical predictions
        let mut rng = Rng::new(151);
        let spec = bmlp_spec(&mut rng, 256, 2);
        let espresso = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let bnet = BaselineEngine::from_spec(&spec, BaselineKind::BinaryNet).unwrap();
        let neon = BaselineEngine::from_spec(&spec, BaselineKind::NeonLike).unwrap();
        for _ in 0..5 {
            let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
            let t = Tensor::from_vec(Shape::vector(784), img);
            let se = espresso.predict_bytes(&t);
            let sb = bnet.predict_bytes(&t);
            let sn = neon.predict_bytes(&t);
            for ((a, b), c) in se.iter().zip(&sb).zip(&sn) {
                assert!((a - b).abs() < 1e-2, "espresso {a} vs binarynet {b}");
                assert!((a - c).abs() < 1e-2, "espresso {a} vs neon {c}");
            }
            assert_eq!(argmax(&se), argmax(&sb));
        }
    }

    #[test]
    fn naive_gemm_matches_blocked() {
        let mut rng = Rng::new(152);
        let (m, n, k) = (3, 17, 130);
        let a = rng.signs(m * k);
        let b = rng.signs(n * k);
        let pa = pack_matrix_rows::<u64>(&a, m, k);
        let pb = pack_matrix_rows::<u64>(&b, n, k);
        let mut naive = vec![0i32; m * n];
        naive_packed_gemm(&pa, &pb, &mut naive, m, n, k);
        let blocked = crate::bitpack::gemm::<u64>(&pa, &pb, m, n, k);
        assert_eq!(naive, blocked);
    }

    #[test]
    fn baseline_handles_conv_models_via_float_path() {
        let mut rng = Rng::new(153);
        let spec = crate::net::bcnn_spec(&mut rng, 0.125);
        let espresso = Network::<u64>::from_spec(&spec, Backend::Float).unwrap();
        let bnet = BaselineEngine::from_spec(&spec, BaselineKind::BinaryNet).unwrap();
        let img: Vec<u8> = (0..32 * 32 * 3).map(|_| rng.next_u32() as u8).collect();
        let t = Tensor::from_vec(Shape::new(32, 32, 3), img);
        let se = espresso.predict_bytes(&t);
        let sb = bnet.predict_bytes(&t);
        for (a, b) in se.iter().zip(&sb) {
            assert!((a - b).abs() < 1e-2);
        }
    }
}
