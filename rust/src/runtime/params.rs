//! Marshalling `.esp` model parameters into the argument layout the AOT
//! artifacts expect (see `python/compile/model.py` *_param_specs).
//!
//! BN folding happens here exactly as in the Python exporters: affine
//! `(a, b)` for score layers, thresholds `(tau, gamma_pos)` for sign
//! layers. Packed weights use u32 words with the same bit order as the
//! JAX side (bit i of word w = element w*32+i).

use crate::bitpack::pack_matrix_rows;
use crate::format::{BnSpec, LayerSpec, ModelSpec};
use anyhow::{bail, Result};

/// A host-side argument value ready to upload.
#[derive(Clone, Debug)]
pub enum HostArg {
    F32(Vec<f32>, Vec<usize>),
    U8(Vec<u8>, Vec<usize>),
    I8(Vec<i8>, Vec<usize>),
    U32(Vec<u32>, Vec<usize>),
}

impl HostArg {
    pub fn dims(&self) -> &[usize] {
        match self {
            HostArg::F32(_, d) | HostArg::U8(_, d) | HostArg::I8(_, d) | HostArg::U32(_, d) => d,
        }
    }

    pub fn dtype(&self) -> super::meta::DType {
        match self {
            HostArg::F32(..) => super::meta::DType::F32,
            HostArg::U8(..) => super::meta::DType::U8,
            HostArg::I8(..) => super::meta::DType::I8,
            HostArg::U32(..) => super::meta::DType::U32,
        }
    }
}

fn fold_affine(bn: &BnSpec) -> (Vec<f32>, Vec<f32>) {
    let mut a = Vec::with_capacity(bn.gamma.len());
    let mut b = Vec::with_capacity(bn.gamma.len());
    for i in 0..bn.gamma.len() {
        let sigma = (bn.var[i] + bn.eps).sqrt();
        a.push(bn.gamma[i] / sigma);
        b.push(bn.beta[i] - bn.gamma[i] * bn.mean[i] / sigma);
    }
    (a, b)
}

fn fold_threshold(bn: &BnSpec) -> (Vec<f32>, Vec<f32>) {
    let p = bn.to_params().fold();
    let gpos = p.gamma_pos.iter().map(|&g| if g { 1.0 } else { 0.0 }).collect();
    (p.tau, gpos)
}

/// Arguments for the `bmlp_float*` artifacts: (w, a, b) per dense layer.
pub fn mlp_float_args(spec: &ModelSpec) -> Result<Vec<HostArg>> {
    let mut out = Vec::new();
    for l in &spec.layers {
        match l {
            LayerSpec::Dense {
                in_features,
                out_features,
                weights,
                bn,
                ..
            } => {
                let bn = bn.as_ref().ok_or_else(|| {
                    anyhow::anyhow!("XLA MLP engines need BN on every dense layer")
                })?;
                let w: Vec<f32> = weights
                    .iter()
                    .map(|&x| if x >= 0.0 { 1.0 } else { -1.0 })
                    .collect();
                let (a, b) = fold_affine(bn);
                out.push(HostArg::F32(
                    w,
                    vec![*out_features as usize, *in_features as usize],
                ));
                out.push(HostArg::F32(a, vec![*out_features as usize]));
                out.push(HostArg::F32(b, vec![*out_features as usize]));
            }
            other => bail!("MLP artifact cannot take layer {other:?}"),
        }
    }
    Ok(out)
}

/// Arguments for the `bmlp_binary*` artifacts:
/// first layer (w int8, tau, gpos); hidden (packed u32, tau, gpos);
/// output (packed u32, a, b).
pub fn mlp_binary_args(spec: &ModelSpec) -> Result<Vec<HostArg>> {
    let n = spec.layers.len();
    let mut out = Vec::new();
    for (i, l) in spec.layers.iter().enumerate() {
        match l {
            LayerSpec::Dense {
                in_features,
                out_features,
                weights,
                bn,
                ..
            } => {
                let (inf, outf) = (*in_features as usize, *out_features as usize);
                let bn = bn.as_ref().ok_or_else(|| {
                    anyhow::anyhow!("XLA MLP engines need BN on every dense layer")
                })?;
                let w_pm1: Vec<f32> = weights
                    .iter()
                    .map(|&x| if x >= 0.0 { 1.0 } else { -1.0 })
                    .collect();
                if i == 0 {
                    let w_i8: Vec<i8> = w_pm1.iter().map(|&x| x as i8).collect();
                    let (tau, gpos) = fold_threshold(bn);
                    out.push(HostArg::I8(w_i8, vec![outf, inf]));
                    out.push(HostArg::F32(tau, vec![outf]));
                    out.push(HostArg::F32(gpos, vec![outf]));
                } else {
                    let packed = pack_matrix_rows::<u32>(&w_pm1, outf, inf);
                    let kw = packed.len() / outf;
                    out.push(HostArg::U32(packed, vec![outf, kw]));
                    if i < n - 1 {
                        let (tau, gpos) = fold_threshold(bn);
                        out.push(HostArg::F32(tau, vec![outf]));
                        out.push(HostArg::F32(gpos, vec![outf]));
                    } else {
                        let (a, b) = fold_affine(bn);
                        out.push(HostArg::F32(a, vec![outf]));
                        out.push(HostArg::F32(b, vec![outf]));
                    }
                }
            }
            other => bail!("MLP artifact cannot take layer {other:?}"),
        }
    }
    Ok(out)
}

/// Arguments for the `bcnn_float*` artifacts: (w, a, b) per conv then per
/// dense layer (conv weights already stored `[f][ky][kx][l]`).
pub fn cnn_float_args(spec: &ModelSpec) -> Result<Vec<HostArg>> {
    let mut out = Vec::new();
    for l in &spec.layers {
        let (w, f, dims, bn) = match l {
            LayerSpec::Conv {
                in_channels,
                filters,
                kh,
                kw,
                weights,
                bn,
                ..
            } => (
                weights,
                *filters as usize,
                vec![
                    *filters as usize,
                    *kh as usize,
                    *kw as usize,
                    *in_channels as usize,
                ],
                bn,
            ),
            LayerSpec::Dense {
                in_features,
                out_features,
                weights,
                bn,
                ..
            } => (
                weights,
                *out_features as usize,
                vec![*out_features as usize, *in_features as usize],
                bn,
            ),
            other => bail!("CNN artifact cannot take layer {other:?}"),
        };
        let bn = bn
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("XLA CNN engine needs BN on every layer"))?;
        let w_pm1: Vec<f32> = w.iter().map(|&x| if x >= 0.0 { 1.0 } else { -1.0 }).collect();
        let (a, b) = fold_affine(bn);
        out.push(HostArg::F32(w_pm1, dims));
        out.push(HostArg::F32(a, vec![f]));
        out.push(HostArg::F32(b, vec![f]));
    }
    Ok(out)
}

/// Validate marshalled args against a parsed `.meta` (all but the final
/// input slot, which the meta lists last).
pub fn validate_args(args: &[HostArg], meta: &super::meta::ArtifactMeta) -> Result<()> {
    if args.len() + 1 != meta.args.len() {
        bail!(
            "artifact {} expects {} args, marshalled {} params (+1 input)",
            meta.name,
            meta.args.len(),
            args.len()
        );
    }
    for (i, (arg, spec)) in args.iter().zip(&meta.args).enumerate() {
        if arg.dims() != spec.dims.as_slice() {
            bail!(
                "artifact {} arg {i}: dims {:?} != meta {:?}",
                meta.name,
                arg.dims(),
                spec.dims
            );
        }
        if arg.dtype() != spec.dtype {
            bail!(
                "artifact {} arg {i}: dtype {:?} != meta {:?}",
                meta.name,
                arg.dtype(),
                spec.dtype
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::bmlp_spec;
    use crate::runtime::meta::{ArtifactMeta, DType};
    use crate::util::rng::Rng;

    #[test]
    fn mlp_binary_arg_layout() {
        let mut rng = Rng::new(161);
        let spec = bmlp_spec(&mut rng, 256, 2);
        let args = mlp_binary_args(&spec).unwrap();
        // 3 layers x 3 args
        assert_eq!(args.len(), 9);
        assert!(matches!(args[0], HostArg::I8(..)));
        assert_eq!(args[0].dims(), &[256, 784]);
        assert!(matches!(args[3], HostArg::U32(..)));
        assert_eq!(args[3].dims(), &[256, 8]); // 256 bits -> 8 u32 words
        assert!(matches!(args[8], HostArg::F32(..)));
    }

    #[test]
    fn validate_against_meta() {
        let mut rng = Rng::new(162);
        let spec = bmlp_spec(&mut rng, 256, 2);
        let args = mlp_float_args(&spec).unwrap();
        let mut meta_text = String::from("artifact t\nargs 10\n");
        for a in &args {
            let dims: Vec<String> = a.dims().iter().map(|d| d.to_string()).collect();
            meta_text.push_str(&format!("arg float32 {}\n", dims.join(",")));
        }
        meta_text.push_str("arg float32 784\n");
        let meta = ArtifactMeta::parse(&meta_text).unwrap();
        validate_args(&args, &meta).unwrap();
        assert_eq!(meta.args.last().unwrap().dtype, DType::F32);
    }

    #[test]
    fn validate_rejects_wrong_shapes() {
        let mut rng = Rng::new(163);
        let spec = bmlp_spec(&mut rng, 128, 1);
        let args = mlp_float_args(&spec).unwrap();
        let meta = ArtifactMeta::parse("artifact t\nargs 7\narg float32 1,1\narg float32 1\narg float32 1\narg float32 1,1\narg float32 1\narg float32 1\narg float32 784\n").unwrap();
        assert!(validate_args(&args, &meta).is_err());
    }
}
