//! `.meta` sidecar files written by `python/compile/aot.py`: the exact
//! argument order, dtypes and shapes a compiled artifact expects. The
//! runtime validates its marshalled literals against this before first
//! execution, so a drifted artifact fails loudly at load, not with
//! garbage numerics.

use anyhow::{bail, Context, Result};
use std::path::Path;

/// Element dtype of an artifact argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    U8,
    I8,
    U32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "float32" => DType::F32,
            "uint8" => DType::U8,
            "int8" => DType::I8,
            "uint32" => DType::U32,
            "int32" => DType::I32,
            other => bail!("unsupported artifact dtype {other}"),
        })
    }
}

/// One argument slot.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl ArgSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Parsed `.meta` sidecar.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub args: Vec<ArgSpec>,
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<Self> {
        let mut lines = text.lines();
        let name = lines
            .next()
            .and_then(|l| l.strip_prefix("artifact "))
            .context("missing 'artifact' header")?
            .to_string();
        let n: usize = lines
            .next()
            .and_then(|l| l.strip_prefix("args "))
            .context("missing 'args' header")?
            .trim()
            .parse()
            .context("bad arg count")?;
        let mut args = Vec::with_capacity(n);
        for line in lines {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let rest = line
                .strip_prefix("arg ")
                .with_context(|| format!("bad meta line: {line}"))?;
            let (dt, dims) = rest
                .split_once(' ')
                .with_context(|| format!("bad meta line: {line}"))?;
            let dims = if dims == "scalar" {
                Vec::new()
            } else {
                dims.split(',')
                    .map(|d| d.parse::<usize>().context("bad dim"))
                    .collect::<Result<Vec<_>>>()?
            };
            args.push(ArgSpec {
                dtype: DType::parse(dt)?,
                dims,
            });
        }
        if args.len() != n {
            bail!("meta declares {n} args but lists {}", args.len());
        }
        Ok(Self { name, args })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta() {
        let text = "artifact smoke\nargs 2\narg float32 2,2\narg uint8 784\n";
        let m = ArtifactMeta::parse(text).unwrap();
        assert_eq!(m.name, "smoke");
        assert_eq!(m.args.len(), 2);
        assert_eq!(m.args[0].dtype, DType::F32);
        assert_eq!(m.args[0].dims, vec![2, 2]);
        assert_eq!(m.args[1].elements(), 784);
    }

    #[test]
    fn rejects_count_mismatch() {
        let text = "artifact x\nargs 3\narg float32 2\n";
        assert!(ArtifactMeta::parse(text).is_err());
    }

    #[test]
    fn rejects_unknown_dtype() {
        let text = "artifact x\nargs 1\narg float16 2\n";
        assert!(ArtifactMeta::parse(text).is_err());
    }

    #[test]
    fn parses_scalar_dims() {
        let text = "artifact x\nargs 1\narg int32 scalar\n";
        let m = ArtifactMeta::parse(text).unwrap();
        assert!(m.args[0].dims.is_empty());
        assert_eq!(m.args[0].elements(), 1);
    }
}
