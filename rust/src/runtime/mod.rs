//! PJRT runtime: load AOT-compiled HLO-text artifacts and serve them from
//! the Rust hot path (Python never runs at request time).
//!
//! The `xla` crate's handles wrap raw pointers (not `Send`), so each
//! compiled model runs inside a dedicated **actor thread** that owns the
//! PJRT client, the executable and the pre-uploaded parameter buffers;
//! the [`XlaEngine`] handle is `Send + Sync` and forwards predictions
//! over a channel. Parameters are uploaded to device buffers **once at
//! load time** — the same pack-once discipline the native engine uses.

pub mod meta;
pub mod params;

pub use meta::{ArgSpec, ArtifactMeta, DType};
pub use params::{cnn_float_args, mlp_binary_args, mlp_float_args, HostArg};

use crate::format::ModelSpec;
use crate::net::{Network, PlanProfile};
use crate::tensor::{Shape, Tensor};
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};

/// Uniform prediction interface over native, baseline and XLA engines —
/// what the coordinator routes requests to.
pub trait Engine: Send + Sync {
    fn name(&self) -> String;
    fn input_shape(&self) -> Shape;
    /// Classify one byte image; returns class scores.
    fn predict(&self, img: &Tensor<u8>) -> Result<Vec<f32>>;

    /// Classify a batch. Default: per-item loop; engines with a real
    /// batched GEMM override this (dynamic batching dividend).
    fn predict_batch(&self, imgs: &[&Tensor<u8>]) -> Vec<Result<Vec<f32>>> {
        imgs.iter().map(|i| self.predict(i)).collect()
    }

    /// Per-layer execution profile of the engine's compiled forward plan,
    /// if it runs one (native engines do; baselines and XLA don't).
    fn plan_profile(&self) -> Option<PlanProfile> {
        None
    }

    /// Aggregate workspace buffer-pool stats, if the engine draws scratch
    /// from pools (native engines do). Surfaced in coordinator metrics so
    /// a long-running serve can see evictions and the parked high-water.
    fn pool_stats(&self) -> Option<crate::alloc::PoolStats> {
        None
    }

    /// Release parked scratch beyond the engine's steady-state working
    /// set (idle housekeeping — the serve loop calls this when no traffic
    /// arrived in a stats interval, so a burst of large batches doesn't
    /// pin peak scratch forever). Engines with a standing reservation
    /// restore it before returning, keeping the no-miss guarantee for
    /// the next request. Returns the number of buffers freed.
    fn trim_pools(&self) -> usize {
        0
    }

    /// One-time load-time warm-up beyond pool bring-up — native engines
    /// autotune their GEMM kernels here (a few ms per distinct layer
    /// geometry, before the first request can observe the latency).
    /// Default: nothing to warm.
    fn warm(&self) {}
}

/// Native-engine adapter (the paper's CPU/GPU^opt analogues). Batched
/// prediction stacks the requests along the tensor batch axis and runs
/// ONE forward — every conv/dense layer issues a single batch-wide GEMM
/// — so the coordinator's dynamic batching is a kernel-level win for
/// CNNs and MLPs alike.
pub struct NativeEngine {
    pub net: Network<u64>,
    label: String,
    /// Batched forward enabled (default). `unbatched()` disables it for
    /// A/B measurements; results are bit-identical either way.
    batchable: bool,
    /// Batch size whose pool reservations idle trims restore (serve sets
    /// this to its `--max-batch`; defaults to 1, the load-time reserve).
    reserve_batch: usize,
}

impl NativeEngine {
    pub fn new(net: Network<u64>, label: &str) -> Self {
        // bring the kernel worker pool up at model-register time so the
        // first request never pays pool bring-up (the same load-time
        // discipline as pack-once weights and pool reservations)
        crate::util::parallel::ensure_started(crate::util::parallel::num_threads());
        // load-time kernel autotuning, same discipline: pay the few ms of
        // micro-benchmarks before the first request instead of shipping
        // untuned kernels. Skipped in debug builds (measurements would be
        // meaningless and slow the test suite) and under ESPRESSO_TUNE=off;
        // already-tuned keys are registry hits, so re-registering a model
        // with shared geometry costs nothing.
        if !cfg!(debug_assertions) && *crate::util::tune::mode() != crate::util::tune::TuneMode::Off
        {
            net.tune();
        }
        Self {
            net,
            label: label.to_string(),
            batchable: true,
            reserve_batch: 1,
        }
    }

    /// Pre-size the scratch pools for `batch` and remember it as the
    /// steady-state working set: [`Engine::trim_pools`] trims back to
    /// this reservation instead of emptying the pools, so sparse traffic
    /// keeps its no-miss guarantee while burst overshoot is released.
    pub fn reserved(mut self, batch: usize) -> Self {
        self.reserve_batch = batch.max(1);
        self.net.reserve(self.reserve_batch);
        self
    }

    /// Disable batched forward: `predict_batch` degrades to a per-image
    /// loop (baseline mode for the batching benches).
    pub fn unbatched(mut self) -> Self {
        self.batchable = false;
        self
    }

    /// Reinterpret a flat byte image (e.g. from the TCP front end) as the
    /// network's input shape so CNN layers see (h, w, c).
    fn shaped(&self, img: &Tensor<u8>) -> Tensor<u8> {
        if img.shape == self.net.input_shape {
            img.clone()
        } else {
            Tensor::from_vec(self.net.input_shape, img.data.clone())
        }
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn input_shape(&self) -> Shape {
        self.net.input_shape
    }

    fn predict(&self, img: &Tensor<u8>) -> Result<Vec<f32>> {
        anyhow::ensure!(img.batch == 1, "predict takes a single image; use predict_batch");
        anyhow::ensure!(
            img.shape.len() == self.net.input_shape.len(),
            "input size mismatch: got {}, expected {}",
            img.shape,
            self.net.input_shape
        );
        if img.shape == self.net.input_shape {
            Ok(self.net.predict_bytes(img))
        } else {
            Ok(self.net.predict_bytes(&self.shaped(img)))
        }
    }

    fn plan_profile(&self) -> Option<PlanProfile> {
        Some(self.net.profile())
    }

    fn pool_stats(&self) -> Option<crate::alloc::PoolStats> {
        Some(self.net.ws.stats_total())
    }

    fn trim_pools(&self) -> usize {
        let freed = self.net.ws.trim_all();
        // restore the steady-state working set: what an idle trim really
        // releases is the overshoot beyond the standing reservation
        self.net.reserve(self.reserve_batch);
        freed
    }

    fn warm(&self) {
        // same gate as `new`: no implicit tuning in debug builds or when
        // the user pinned the defaults
        if !cfg!(debug_assertions) && *crate::util::tune::mode() != crate::util::tune::TuneMode::Off
        {
            self.net.tune();
            // tune() re-reserves at batch 1; restore the standing batch
            self.net.reserve(self.reserve_batch);
        }
    }

    fn predict_batch(&self, imgs: &[&Tensor<u8>]) -> Vec<Result<Vec<f32>>> {
        let features = self.net.input_shape.len();
        if !self.batchable || imgs.len() <= 1 {
            return imgs.iter().map(|i| self.predict(i)).collect();
        }
        // fast path: every image already has the exact input shape —
        // one batched forward, zero copies
        if imgs
            .iter()
            .all(|i| i.shape == self.net.input_shape && i.batch == 1)
        {
            return self
                .net
                .predict_batch_bytes(imgs)
                .into_iter()
                .map(Ok)
                .collect();
        }
        // Mixed batch: conforming images (right element count, single
        // image) still share ONE batched forward; only the misfits fall
        // back to per-item predict (which reports their shape errors).
        // A single bad wire request used to de-batch the whole group to
        // a per-image loop, forfeiting the GEMM-level batching win.
        let conforming: Vec<usize> = imgs
            .iter()
            .enumerate()
            .filter(|(_, i)| i.shape.len() == features && i.batch == 1)
            .map(|(k, _)| k)
            .collect();
        if conforming.len() <= 1 {
            return imgs.iter().map(|i| self.predict(i)).collect();
        }
        let shaped: Vec<Tensor<u8>> = conforming.iter().map(|&k| self.shaped(imgs[k])).collect();
        let refs: Vec<&Tensor<u8>> = shaped.iter().collect();
        let scores = self.net.predict_batch_bytes(&refs);
        let mut out: Vec<Option<Result<Vec<f32>>>> = (0..imgs.len()).map(|_| None).collect();
        for (&k, s) in conforming.iter().zip(scores) {
            out[k] = Some(Ok(s));
        }
        out.into_iter()
            .enumerate()
            .map(|(k, o)| o.unwrap_or_else(|| self.predict(imgs[k])))
            .collect()
    }
}

/// Baseline adapter (BinaryNet / neon-like).
impl Engine for crate::baseline::BaselineEngine {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn input_shape(&self) -> Shape {
        self.input_shape
    }

    fn predict(&self, img: &Tensor<u8>) -> Result<Vec<f32>> {
        Ok(self.predict_bytes(img))
    }
}

/// Which artifact family an XLA engine runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum XlaModelKind {
    /// `bmlp_float*`: float x input.
    MlpFloat,
    /// `bmlp_binary*`: uint8 x input, packed weights (Pallas kernel HLO).
    MlpBinary,
    /// `bcnn_float*`: float (h, w, c) input.
    CnnFloat,
}

enum Req {
    Predict(Tensor<u8>, Sender<Result<Vec<f32>>>),
    Shutdown,
}

/// Handle to an actor thread owning one compiled artifact.
pub struct XlaEngine {
    label: String,
    input_shape: Shape,
    tx: Sender<Req>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl XlaEngine {
    /// Load `artifacts/<artifact>.hlo.txt` (+ `.meta`), marshal the model
    /// parameters from `spec`, compile, and upload parameter buffers.
    /// Blocks until the actor reports readiness.
    pub fn load(
        artifact_dir: &Path,
        artifact: &str,
        spec: &ModelSpec,
        kind: XlaModelKind,
    ) -> Result<Self> {
        let hlo = artifact_dir.join(format!("{artifact}.hlo.txt"));
        let meta_path = artifact_dir.join(format!("{artifact}.meta"));
        let meta = ArtifactMeta::load(&meta_path)?;
        let args = match kind {
            XlaModelKind::MlpFloat => mlp_float_args(spec)?,
            XlaModelKind::MlpBinary => mlp_binary_args(spec)?,
            XlaModelKind::CnnFloat => cnn_float_args(spec)?,
        };
        params::validate_args(&args, &meta)?;
        let input_shape = spec.input_shape;
        let (tx, rx) = channel::<Req>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let hlo_path = hlo.clone();
        let join = std::thread::Builder::new()
            .name(format!("xla-{artifact}"))
            .spawn(move || actor_main(hlo_path, args, kind, input_shape, rx, ready_tx))
            .context("spawn xla actor")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("xla actor died during load"))??;
        Ok(Self {
            label: format!("xla:{artifact}"),
            input_shape,
            tx,
            join: Some(join),
        })
    }
}

impl Engine for XlaEngine {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn input_shape(&self) -> Shape {
        self.input_shape
    }

    fn predict(&self, img: &Tensor<u8>) -> Result<Vec<f32>> {
        let (tx, rx) = channel();
        self.tx
            .send(Req::Predict(img.clone(), tx))
            .map_err(|_| anyhow!("xla actor gone"))?;
        rx.recv().map_err(|_| anyhow!("xla actor dropped reply"))?
    }
}

impl Drop for XlaEngine {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn actor_main(
    hlo: PathBuf,
    args: Vec<HostArg>,
    kind: XlaModelKind,
    input_shape: Shape,
    rx: Receiver<Req>,
    ready: Sender<Result<()>>,
) {
    // Load + compile + upload; report readiness (or the error) once.
    type Setup = (
        xla::PjRtClient,
        xla::PjRtLoadedExecutable,
        Vec<xla::PjRtBuffer>,
    );
    let setup = (|| -> Result<Setup> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().context("artifact path not utf8")?,
        )
        .map_err(|e| anyhow!("parse {hlo:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compile: {e}"))?;
        let mut bufs = Vec::with_capacity(args.len());
        for a in &args {
            let buf = upload(&client, a).map_err(|e| anyhow!("upload param: {e}"))?;
            bufs.push(buf);
        }
        Ok((client, exe, bufs))
    })();
    let (client, exe, param_bufs) = match setup {
        Ok(t) => {
            let _ = ready.send(Ok(()));
            t
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        match req {
            Req::Shutdown => break,
            Req::Predict(img, reply) => {
                let result = run_one(&client, &exe, &param_bufs, kind, input_shape, &img);
                let _ = reply.send(result);
            }
        }
    }
}

fn upload(client: &xla::PjRtClient, arg: &HostArg) -> Result<xla::PjRtBuffer> {
    let buf = match arg {
        HostArg::F32(v, d) => client.buffer_from_host_buffer::<f32>(v, d, None),
        HostArg::U8(v, d) => client.buffer_from_host_buffer::<u8>(v, d, None),
        HostArg::I8(v, d) => client.buffer_from_host_buffer::<i8>(v, d, None),
        HostArg::U32(v, d) => client.buffer_from_host_buffer::<u32>(v, d, None),
    };
    buf.map_err(|e| anyhow!("buffer_from_host_buffer: {e}"))
}

fn run_one(
    client: &xla::PjRtClient,
    exe: &xla::PjRtLoadedExecutable,
    param_bufs: &[xla::PjRtBuffer],
    kind: XlaModelKind,
    input_shape: Shape,
    img: &Tensor<u8>,
) -> Result<Vec<f32>> {
    let n = input_shape.len();
    anyhow::ensure!(img.shape.len() == n, "input size mismatch");
    let input = match kind {
        XlaModelKind::MlpBinary => {
            client.buffer_from_host_buffer::<u8>(&img.data, &[n], None)
        }
        XlaModelKind::MlpFloat => {
            let xf: Vec<f32> = img.data.iter().map(|&b| b as f32).collect();
            client.buffer_from_host_buffer::<f32>(&xf, &[n], None)
        }
        XlaModelKind::CnnFloat => {
            let xf: Vec<f32> = img.data.iter().map(|&b| b as f32).collect();
            client.buffer_from_host_buffer::<f32>(
                &xf,
                &[input_shape.m, input_shape.n, input_shape.l],
                None,
            )
        }
    }
    .map_err(|e| anyhow!("upload input: {e}"))?;
    let mut all: Vec<&xla::PjRtBuffer> = param_bufs.iter().collect();
    all.push(&input);
    let out = exe.execute_b(&all).map_err(|e| anyhow!("execute: {e}"))?;
    let lit = out[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetch output: {e}"))?;
    let tuple = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
    tuple.to_vec::<f32>().map_err(|e| anyhow!("decode: {e}"))
}

/// Directory where `make artifacts` puts compiled models.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("ESPRESSO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Check whether an artifact (hlo + meta) exists.
pub fn artifact_exists(dir: &Path, artifact: &str) -> bool {
    dir.join(format!("{artifact}.hlo.txt")).exists()
        && dir.join(format!("{artifact}.meta")).exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layers::Backend;
    use crate::net::{bmlp_spec, mnist_cnn_spec};
    use crate::util::rng::Rng;

    /// One misfit request must not de-batch the rest: conforming images
    /// share a batched forward (bit-identical to solo predicts) and the
    /// misfit gets its own error, in place.
    #[test]
    fn mixed_batch_keeps_conforming_images_batched() {
        let mut rng = Rng::new(193);
        let spec = bmlp_spec(&mut rng, 64, 1);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let engine = NativeEngine::new(net, "opt");
        let n = spec.input_shape.len();
        let good: Vec<Tensor<u8>> = (0..4)
            .map(|_| {
                Tensor::from_vec(
                    Shape::vector(n),
                    (0..n).map(|_| rng.next_u32() as u8).collect(),
                )
            })
            .collect();
        let bad = Tensor::from_vec(Shape::vector(3), vec![1, 2, 3]);
        let mut refs: Vec<&Tensor<u8>> = good.iter().collect();
        refs.insert(2, &bad);
        let results = engine.predict_batch(&refs);
        assert_eq!(results.len(), 5);
        assert!(results[2].is_err(), "misfit image reports its own error");
        let mut gi = 0;
        for (k, r) in results.iter().enumerate() {
            if k == 2 {
                continue;
            }
            let direct = engine.predict(&good[gi]).unwrap();
            assert_eq!(r.as_ref().unwrap(), &direct, "request {k}");
            gi += 1;
        }
    }

    /// Idle trims must restore the engine's standing reservation: after
    /// `reserved(B)` + `trim_pools`, a batch-B forward still draws every
    /// scratch buffer from the freelists (zero pool misses) — sparse
    /// traffic keeps the no-miss guarantee the startup reserve bought.
    #[test]
    fn trim_pools_restores_reservation() {
        let mut rng = Rng::new(191);
        let spec = mnist_cnn_spec(&mut rng, 0.25);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let engine = NativeEngine::new(net, "opt").reserved(4);
        let imgs: Vec<Tensor<u8>> = (0..4)
            .map(|_| {
                Tensor::from_vec(
                    spec.input_shape,
                    (0..spec.input_shape.len())
                        .map(|_| rng.next_u32() as u8)
                        .collect(),
                )
            })
            .collect();
        let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
        let freed = engine.trim_pools();
        assert!(freed > 0, "the standing reservation should park buffers");
        let before = engine.pool_stats().unwrap();
        for r in engine.predict_batch(&refs) {
            r.unwrap();
        }
        let after = engine.pool_stats().unwrap();
        assert_eq!(
            after.misses, before.misses,
            "trim_pools broke the standing reservation: {before:?} -> {after:?}"
        );
        assert!(after.hits > before.hits);
    }
}
