//! Datasets: procedural synthetic MNIST/CIFAR stand-ins plus a real IDX
//! reader.
//!
//! The paper evaluates *forward-pass time* on MNIST and CIFAR-10; timing
//! depends only on tensor shapes, so offline we substitute procedurally
//! generated datasets with the same shapes (28×28×1 u8, 32×32×3 u8) and a
//! learnable class structure (per-class blob prototypes + noise + jitter)
//! so that end-to-end examples can also demonstrate real classification
//! accuracy. When genuine IDX files exist on disk the loader uses them
//! instead (`load_idx_images` / `load_idx_labels`).

use crate::tensor::{Shape, Tensor};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

/// An in-memory labelled image dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub shape: Shape,
    pub images: Vec<Tensor<u8>>,
    pub labels: Vec<usize>,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

/// Per-class prototypes used by the synthetic generators. Each class is a
/// smooth random "ink blob" field; samples add pixel noise and a ±2px
/// translation so the task needs real generalization but stays learnable
/// by a binary MLP.
struct ProtoSet {
    shape: Shape,
    protos: Vec<Vec<f32>>, // class -> field in [0,1]
}

impl ProtoSet {
    fn new(shape: Shape, classes: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut protos = Vec::with_capacity(classes);
        for _ in 0..classes {
            protos.push(Self::blob_field(&mut rng, shape));
        }
        Self { shape, protos }
    }

    /// Sum of a few random Gaussian bumps, normalized to [0,1].
    fn blob_field(rng: &mut Rng, shape: Shape) -> Vec<f32> {
        let (m, n, l) = (shape.m, shape.n, shape.l);
        let bumps = 4 + rng.below(3);
        let centers: Vec<(f32, f32, f32, f32)> = (0..bumps)
            .map(|_| {
                (
                    rng.f32_range(0.15, 0.85) * m as f32,
                    rng.f32_range(0.15, 0.85) * n as f32,
                    rng.f32_range(1.5, 4.0),      // radius
                    rng.f32_range(0.6, 1.0),      // amplitude
                )
            })
            .collect();
        // per-channel tint so CIFAR-like classes differ in colour too
        let tint: Vec<f32> = (0..l).map(|_| rng.f32_range(0.4, 1.0)).collect();
        let mut field = vec![0f32; m * n * l];
        for y in 0..m {
            for x in 0..n {
                let mut v = 0f32;
                for &(cy, cx, r, a) in &centers {
                    let d2 = (y as f32 - cy).powi(2) + (x as f32 - cx).powi(2);
                    v += a * (-d2 / (2.0 * r * r)).exp();
                }
                let v = v.min(1.0);
                for c in 0..l {
                    field[(y * n + x) * l + c] = v * tint[c];
                }
            }
        }
        field
    }

    fn sample(&self, class: usize, rng: &mut Rng) -> Tensor<u8> {
        let (m, n, l) = (self.shape.m, self.shape.n, self.shape.l);
        let proto = &self.protos[class];
        let dy = rng.range_i64(-2, 2);
        let dx = rng.range_i64(-2, 2);
        let mut data = vec![0u8; m * n * l];
        for y in 0..m {
            for x in 0..n {
                let sy = y as i64 + dy;
                let sx = x as i64 + dx;
                for c in 0..l {
                    let base = if sy >= 0 && sy < m as i64 && sx >= 0 && sx < n as i64 {
                        proto[((sy as usize) * n + sx as usize) * l + c]
                    } else {
                        0.0
                    };
                    let noisy = base + rng.f32_range(-0.15, 0.15);
                    data[(y * n + x) * l + c] = (noisy.clamp(0.0, 1.0) * 255.0) as u8;
                }
            }
        }
        Tensor::from_vec(self.shape, data)
    }
}

/// Synthetic MNIST-shaped dataset: `n` samples of 28×28×1 u8, 10 classes.
pub fn synth_mnist(n: usize, seed: u64) -> Dataset {
    synth(Shape::new(28, 28, 1), 10, n, seed)
}

/// Synthetic CIFAR-shaped dataset: `n` samples of 32×32×3 u8, 10 classes.
pub fn synth_cifar(n: usize, seed: u64) -> Dataset {
    synth(Shape::new(32, 32, 3), 10, n, seed)
}

/// Generic synthetic dataset.
pub fn synth(shape: Shape, classes: usize, n: usize, seed: u64) -> Dataset {
    let protos = ProtoSet::new(shape, classes, seed);
    let mut rng = Rng::new(seed ^ 0x5EED_DA7A);
    let mut images = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % classes;
        images.push(protos.sample(class, &mut rng));
        labels.push(class);
    }
    Dataset {
        shape,
        images,
        labels,
        classes,
    }
}

// ---------------------------------------------------------------------
// IDX format (real MNIST files, when available)
// ---------------------------------------------------------------------

/// Read an IDX image file (magic 0x00000803): returns tensors of shape
/// `rows×cols×1`.
pub fn load_idx_images(path: &Path) -> Result<Vec<Tensor<u8>>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    let magic = read_be_u32(&mut f)?;
    if magic != 0x0000_0803 {
        bail!("not an IDX image file (magic {magic:#010x})");
    }
    let count = read_be_u32(&mut f)? as usize;
    let rows = read_be_u32(&mut f)? as usize;
    let cols = read_be_u32(&mut f)? as usize;
    if count > 1_000_000 || rows * cols > 1 << 20 {
        bail!("IDX dimensions exceed sanity bounds");
    }
    let shape = Shape::new(rows, cols, 1);
    let mut images = Vec::with_capacity(count);
    for _ in 0..count {
        let mut buf = vec![0u8; rows * cols];
        f.read_exact(&mut buf)?;
        images.push(Tensor::from_vec(shape, buf));
    }
    Ok(images)
}

/// Read an IDX label file (magic 0x00000801).
pub fn load_idx_labels(path: &Path) -> Result<Vec<usize>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    let magic = read_be_u32(&mut f)?;
    if magic != 0x0000_0801 {
        bail!("not an IDX label file (magic {magic:#010x})");
    }
    let count = read_be_u32(&mut f)? as usize;
    if count > 1_000_000 {
        bail!("IDX label count exceeds sanity bound");
    }
    let mut buf = vec![0u8; count];
    f.read_exact(&mut buf)?;
    Ok(buf.into_iter().map(|b| b as usize).collect())
}

/// Load real MNIST from a directory if the IDX files exist, else fall
/// back to the synthetic generator.
pub fn mnist_or_synth(dir: &Path, n: usize, seed: u64) -> Dataset {
    let img_path = dir.join("t10k-images-idx3-ubyte");
    let lbl_path = dir.join("t10k-labels-idx1-ubyte");
    if let (Ok(mut images), Ok(mut labels)) =
        (load_idx_images(&img_path), load_idx_labels(&lbl_path))
    {
        images.truncate(n);
        labels.truncate(n);
        if !images.is_empty() {
            return Dataset {
                shape: images[0].shape,
                images,
                labels,
                classes: 10,
            };
        }
    }
    synth_mnist(n, seed)
}

fn read_be_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_be_bytes(b))
}

fn read_le_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

// ---------------------------------------------------------------------
// .espdata format (test sets exported by python/compile/convert.py)
// ---------------------------------------------------------------------

/// Load an `.espdata` test-set file: magic "ESPD", version, shape
/// (m,n,l u32), count u32, `count` u8 images, `count` u8 labels.
pub fn load_espdata(path: &Path) -> Result<Dataset> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != b"ESPD" {
        bail!("not an .espdata file (magic {magic:?})");
    }
    let version = read_le_u32(&mut f)?;
    if version != 1 {
        bail!("unsupported .espdata version {version}");
    }
    let shape = Shape::new(
        read_le_u32(&mut f)? as usize,
        read_le_u32(&mut f)? as usize,
        read_le_u32(&mut f)? as usize,
    );
    let count = read_le_u32(&mut f)? as usize;
    if count > 10_000_000 || shape.len() > 1 << 24 {
        bail!(".espdata dimensions exceed sanity bounds");
    }
    let mut images = Vec::with_capacity(count);
    for _ in 0..count {
        let mut buf = vec![0u8; shape.len()];
        f.read_exact(&mut buf)?;
        images.push(Tensor::from_vec(shape, buf));
    }
    let mut labels = vec![0u8; count];
    f.read_exact(&mut labels)?;
    let classes = labels.iter().map(|&l| l as usize + 1).max().unwrap_or(1);
    Ok(Dataset {
        shape,
        images,
        labels: labels.into_iter().map(|l| l as usize).collect(),
        classes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_mnist_shapes_and_labels() {
        let d = synth_mnist(50, 7);
        assert_eq!(d.len(), 50);
        assert_eq!(d.shape, Shape::new(28, 28, 1));
        assert!(d.labels.iter().all(|&l| l < 10));
        // balanced round-robin labels
        assert_eq!(d.labels[0], 0);
        assert_eq!(d.labels[11], 1);
    }

    #[test]
    fn synth_is_deterministic_per_seed() {
        let a = synth_mnist(10, 42);
        let b = synth_mnist(10, 42);
        let c = synth_mnist(10, 43);
        assert_eq!(a.images[3].data, b.images[3].data);
        assert_ne!(a.images[3].data, c.images[3].data);
    }

    #[test]
    fn classes_are_visually_distinct() {
        // mean intra-class L1 distance must be well below inter-class
        let d = synth_mnist(40, 11);
        let dist = |a: &Tensor<u8>, b: &Tensor<u8>| -> f64 {
            a.data
                .iter()
                .zip(&b.data)
                .map(|(&x, &y)| (x as f64 - y as f64).abs())
                .sum::<f64>()
                / a.data.len() as f64
        };
        // samples 0,10,20,30 are class 0; 1,11,21,31 are class 1
        let intra = dist(&d.images[0], &d.images[10]);
        let inter = dist(&d.images[0], &d.images[1]);
        assert!(
            inter > intra * 1.2,
            "inter {inter} should exceed intra {intra}"
        );
    }

    #[test]
    fn synth_cifar_has_three_channels() {
        let d = synth_cifar(10, 3);
        assert_eq!(d.shape, Shape::new(32, 32, 3));
    }

    #[test]
    fn idx_roundtrip() {
        // write a tiny IDX pair and read it back
        let dir = std::env::temp_dir();
        let ip = dir.join("espresso_test_images_idx");
        let lp = dir.join("espresso_test_labels_idx");
        let mut ibuf = Vec::new();
        ibuf.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        ibuf.extend_from_slice(&2u32.to_be_bytes());
        ibuf.extend_from_slice(&2u32.to_be_bytes());
        ibuf.extend_from_slice(&2u32.to_be_bytes());
        ibuf.extend_from_slice(&[1, 2, 3, 4, 5, 6, 7, 8]);
        std::fs::write(&ip, &ibuf).unwrap();
        let mut lbuf = Vec::new();
        lbuf.extend_from_slice(&0x0000_0801u32.to_be_bytes());
        lbuf.extend_from_slice(&2u32.to_be_bytes());
        lbuf.extend_from_slice(&[7, 3]);
        std::fs::write(&lp, &lbuf).unwrap();
        let images = load_idx_images(&ip).unwrap();
        let labels = load_idx_labels(&lp).unwrap();
        assert_eq!(images.len(), 2);
        assert_eq!(images[0].data, vec![1, 2, 3, 4]);
        assert_eq!(labels, vec![7, 3]);
        let _ = std::fs::remove_file(&ip);
        let _ = std::fs::remove_file(&lp);
    }

    #[test]
    fn idx_rejects_wrong_magic() {
        let p = std::env::temp_dir().join("espresso_bad_idx");
        std::fs::write(&p, 0x0000_0999u32.to_be_bytes()).unwrap();
        assert!(load_idx_images(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn mnist_or_synth_falls_back() {
        let d = mnist_or_synth(Path::new("/nonexistent"), 5, 1);
        assert_eq!(d.len(), 5);
    }

    #[test]
    fn espdata_roundtrip() {
        // write the python-exporter layout by hand and read it back
        let p = std::env::temp_dir().join("espresso_test.espdata");
        let mut buf = Vec::new();
        buf.extend_from_slice(b"ESPD");
        buf.extend_from_slice(&1u32.to_le_bytes());
        for d in [1u32, 4, 1] {
            buf.extend_from_slice(&d.to_le_bytes());
        }
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(&[1, 2, 3, 4, 5, 6, 7, 8]); // 2 images of 4 bytes
        buf.extend_from_slice(&[3, 7]); // labels
        std::fs::write(&p, &buf).unwrap();
        let d = load_espdata(&p).unwrap();
        assert_eq!(d.len(), 2);
        assert_eq!(d.images[1].data, vec![5, 6, 7, 8]);
        assert_eq!(d.labels, vec![3, 7]);
        assert_eq!(d.classes, 8);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn espdata_rejects_bad_magic() {
        let p = std::env::temp_dir().join("espresso_bad.espdata");
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(load_espdata(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
