//! Small statistics helpers used by the bench harness and the
//! coordinator's latency metrics.

/// Summary statistics over a sample of observations (e.g. latencies in ns).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute summary stats; sorts a copy of the input.
    pub fn from(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self::default();
        }
        let mut xs = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Self {
            n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            max: xs[n - 1],
            p50: percentile_sorted(&xs, 50.0),
            p90: percentile_sorted(&xs, 90.0),
            p95: percentile_sorted(&xs, 95.0),
            p99: percentile_sorted(&xs, 99.0),
        }
    }
}

/// Percentile of an already-sorted sample (nearest-rank with linear
/// interpolation between adjacent order statistics).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Streaming histogram with fixed log-spaced buckets, for latency tracking
/// without storing every sample. Range: 100ns .. ~100s.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_ns: u64,
    min_ns: u64,
    max_ns: u64,
}

const LOG_BUCKETS: usize = 180; // 9 decades * 20 buckets per decade
const LOG_BASE_NS: f64 = 100.0;

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self {
            buckets: vec![0; LOG_BUCKETS],
            count: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    fn bucket_of(ns: u64) -> usize {
        if ns as f64 <= LOG_BASE_NS {
            return 0;
        }
        let idx = ((ns as f64 / LOG_BASE_NS).log10() * 20.0) as usize;
        idx.min(LOG_BUCKETS - 1)
    }

    /// Representative (geometric-mid) value of bucket `i` in ns.
    fn bucket_value(i: usize) -> f64 {
        LOG_BASE_NS * 10f64.powf((i as f64 + 0.5) / 20.0)
    }

    pub fn record(&mut self, ns: u64) {
        self.buckets[Self::bucket_of(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    pub fn min_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min_ns
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Approximate percentile from the histogram buckets.
    pub fn percentile_ns(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return Self::bucket_value(i);
            }
        }
        self.max_ns as f64
    }

    /// Merge another histogram into this one (for per-worker aggregation).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

/// Human-readable duration formatting for reports.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Human-readable byte counts.
pub fn fmt_bytes(b: usize) -> String {
    const KB: f64 = 1024.0;
    let b = b as f64;
    if b < KB {
        format!("{b:.0} B")
    } else if b < KB * KB {
        format!("{:.2} KB", b / KB)
    } else if b < KB * KB * KB {
        format!("{:.2} MB", b / KB / KB)
    } else {
        format!("{:.2} GB", b / KB / KB / KB)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::from(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentiles_roughly_match() {
        let mut h = LogHistogram::new();
        // 1000 samples uniform in [1us, 1ms]
        let mut raw = Vec::new();
        for i in 0..1000u64 {
            let ns = 1_000 + i * 999; // 1us .. ~1ms
            h.record(ns);
            raw.push(ns as f64);
        }
        raw.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let exact = percentile_sorted(&raw, 95.0);
        let approx = h.percentile_ns(95.0);
        // log-bucket resolution is ~12%, allow 25%
        assert!(
            (approx - exact).abs() / exact < 0.25,
            "approx={approx} exact={exact}"
        );
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        a.record(1_000);
        b.record(2_000);
        b.record(3_000);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.min_ns(), 1_000);
        assert_eq!(a.max_ns(), 3_000);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1_500.0), "1.50 us");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KB");
    }
}
