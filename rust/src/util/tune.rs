//! Micro-kernel autotuner (PR 7, ROADMAP item 5).
//!
//! The paper's CUDA kernels win by adapting tiling and register blocking
//! to the hardware (PAPER.md §Hardware-Adaptation); this module is the
//! CPU analogue. Every GEMM-shaped hot path (binary XNOR-popcount,
//! first-layer bit-plane, float fallback) consults a process-wide
//! *kernel-choice registry* keyed by `(simd level, family, word width,
//! n, k)`. A registry miss falls back to [`default_for`], which
//! reproduces the constants the kernels shipped with before tuning
//! existed — so an untuned process behaves exactly like the old code.
//!
//! [`tune_gemm`] fills the registry: for one `gemm_dims` triple it times
//! candidate (micro-kernel shape × tile_rows × chunk grain) combinations
//! for ~250 µs each on synthetic data through the *real* parallel kernel
//! entry points, and records the winner. The key deliberately omits `m`:
//! every legacy tile/grain formula depends only on `(n, k)`, which is
//! what lets forward-time and scratch-reservation-time lookups agree for
//! any batch size — the pool no-miss guarantee survives tuning as long
//! as reservations are re-taken after the registry changes
//! (`Network::tune` re-reserves).
//!
//! `ESPRESSO_TUNE` selects the mode: `off` pins the defaults, unset or
//! `auto` tunes into the in-process registry, and any other value is
//! treated as an on-disk cache path (loaded before first tuning, new
//! winners appended) so `serve` cold-starts skip re-tuning.

use std::collections::HashMap;
use std::fmt;
use std::io::Write as _;
use std::path::PathBuf;
use std::sync::{Mutex, OnceLock, RwLock};

use crate::alloc::BufferPool;
use crate::bitpack::bitplane::BitPlanes;
use crate::bitpack::simd;
use crate::bitpack::word::{words_for, Word};
use crate::util::rng::Rng;
use crate::util::Timer;

/// Register-blocking shape of the inner kernel: how many C values one
/// sweep of the packed/float operands produces.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MicroKernel {
    /// One A row against 4 B rows.
    Mk1x4,
    /// One A row against 8 B rows.
    Mk1x8,
    /// Two A rows against 4 B rows (binary only; others treat it as the
    /// nearest shape they implement).
    Mk2x4,
}

impl MicroKernel {
    pub fn name(self) -> &'static str {
        match self {
            MicroKernel::Mk1x4 => "1x4",
            MicroKernel::Mk1x8 => "1x8",
            MicroKernel::Mk2x4 => "2x4",
        }
    }

    fn parse(s: &str) -> Option<Self> {
        match s {
            "1x4" => Some(MicroKernel::Mk1x4),
            "1x8" => Some(MicroKernel::Mk1x8),
            "2x4" => Some(MicroKernel::Mk2x4),
            _ => None,
        }
    }
}

impl fmt::Display for MicroKernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Which kernel family a GEMM call belongs to — families have disjoint
/// inner loops, so their choices are tuned and cached independently.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    /// Packed ±1 XNOR-popcount GEMM (`bitpack::gemm`); `k` is the row
    /// length in *words*.
    Binary,
    /// First-layer bit-plane GEMM (`bitpack::bitplane`); `k` is the row
    /// length in u8 elements.
    Bitplane,
    /// Float GEMM (`linalg::gemm`); `k` is the row length in f32s.
    Float,
}

impl Family {
    pub fn name(self) -> &'static str {
        match self {
            Family::Binary => "binary",
            Family::Bitplane => "bitplane",
            Family::Float => "float",
        }
    }

    fn parse(s: &str) -> Option<Self> {
        match s {
            "binary" => Some(Family::Binary),
            "bitplane" => Some(Family::Bitplane),
            "float" => Some(Family::Float),
            _ => None,
        }
    }
}

impl fmt::Display for Family {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One tuned kernel configuration: the micro-kernel shape plus the two
/// blocking knobs the tiled/parallel entry points take.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelChoice {
    pub micro: MicroKernel,
    /// A-panel rows per streamed tile (fused conv paths).
    pub tile_rows: usize,
    /// C rows per spawn-priced parallel chunk.
    pub grain: usize,
}

impl fmt::Display for KernelChoice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} t{} g{}", self.micro, self.tile_rows, self.grain)
    }
}

/// Registry key. `level` is the SIMD dispatch level (the CPU-feature
/// component of "keyed by (cpu features, dims)"); `m` is deliberately
/// absent — see the module docs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct Key {
    level: u8,
    family: Family,
    word_bits: u32,
    n: usize,
    k: usize,
}

/// Streamed-tile panel target, matching the pre-tuner constant in the
/// fused conv path (L2-resident A panels).
const TILE_PANEL_BYTES: usize = 64 * 1024;

/// The untuned configuration — bit-for-bit the constants and grain
/// formulas the kernels used before the registry existed.
pub fn default_for(family: Family, word_bits: u32, n: usize, k: usize) -> KernelChoice {
    let row_bytes = match family {
        Family::Binary => k * (word_bits as usize / 8),
        Family::Bitplane => k,
        Family::Float => 4 * k,
    };
    let tile_rows = (TILE_PANEL_BYTES / row_bytes.max(1)).clamp(16, 256);
    let grain = match family {
        Family::Binary => ((1 << 20) / (n * k.max(1)).max(1)).max(1),
        Family::Bitplane => {
            let kw = k.div_ceil(word_bits as usize);
            ((1 << 19) / (8 * n * kw).max(1)).max(4)
        }
        Family::Float => ((1 << 18) / (n * k.max(1)).max(1)).max(1),
    };
    let micro = match family {
        Family::Binary => MicroKernel::Mk1x8,
        Family::Bitplane | Family::Float => MicroKernel::Mk1x4,
    };
    KernelChoice { micro, tile_rows, grain }
}

fn registry() -> &'static RwLock<HashMap<Key, KernelChoice>> {
    static REGISTRY: OnceLock<RwLock<HashMap<Key, KernelChoice>>> = OnceLock::new();
    REGISTRY.get_or_init(|| RwLock::new(HashMap::new()))
}

/// Hot-path registry read: the tuned choice for these dims at the
/// current dispatch level, or the legacy default on a miss. Never tunes,
/// never touches the environment or disk.
#[inline]
pub fn lookup(family: Family, word_bits: u32, n: usize, k: usize) -> KernelChoice {
    let key = Key { level: simd::level(), family, word_bits, n, k };
    if let Some(c) = registry().read().unwrap().get(&key) {
        return *c;
    }
    default_for(family, word_bits, n, k)
}

/// Tuning mode, from `ESPRESSO_TUNE`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TuneMode {
    /// Pin the legacy defaults; [`tune_gemm`] becomes a no-op.
    Off,
    /// Tune into the in-process registry only.
    Auto,
    /// Like `Auto`, seeded from + appended to an on-disk cache file.
    File(PathBuf),
}

/// The process-wide mode (`ESPRESSO_TUNE=off|auto|<path>`, read once).
pub fn mode() -> &'static TuneMode {
    static MODE: OnceLock<TuneMode> = OnceLock::new();
    MODE.get_or_init(|| match std::env::var("ESPRESSO_TUNE") {
        Err(_) => TuneMode::Auto,
        Ok(v) => match v.as_str() {
            "off" | "0" => TuneMode::Off,
            "auto" | "" => TuneMode::Auto,
            _ => TuneMode::File(PathBuf::from(v)),
        },
    })
}

/// One tuning outcome, kept for the `espresso profile` summary table.
#[derive(Clone, Debug)]
pub struct TuneRecord {
    pub family: Family,
    pub word_bits: u32,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub choice: KernelChoice,
    /// ns/call of the winning configuration.
    pub best_ns: u64,
    /// ns/call of the legacy default configuration.
    pub default_ns: u64,
}

fn records() -> &'static Mutex<Vec<TuneRecord>> {
    static RECORDS: OnceLock<Mutex<Vec<TuneRecord>>> = OnceLock::new();
    RECORDS.get_or_init(|| Mutex::new(Vec::new()))
}

/// Snapshot of every tuning decision made so far this process.
pub fn summary() -> Vec<TuneRecord> {
    records().lock().unwrap().clone()
}

/// Render tuning records as the `espresso profile` summary table.
pub fn render_summary(rows: &[TuneRecord]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<9} {:>5} {:>7} {:>7} {:>7}  {:<14} {:>12} {:>12} {:>7}\n",
        "family", "bits", "m", "n", "k", "kernel", "ns/call", "default", "gain"
    ));
    for r in rows {
        let gain = r.best_ns.max(1) as f64;
        out.push_str(&format!(
            "{:<9} {:>5} {:>7} {:>7} {:>7}  {:<14} {:>12} {:>12} {:>6.2}x\n",
            r.family.name(),
            r.word_bits,
            r.m,
            r.n,
            r.k,
            r.choice.to_string(),
            r.best_ns,
            r.default_ns,
            r.default_ns as f64 / gain,
        ));
    }
    out
}

/// Tune (or fetch the cached choice for) one `gemm_dims` triple using
/// the process mode. `k` follows the [`Family`] unit convention.
pub fn tune_gemm<W: Word>(family: Family, m: usize, n: usize, k: usize) -> KernelChoice {
    tune_gemm_with_mode::<W>(mode(), family, m, n, k)
}

/// [`tune_gemm`] with an explicit mode (testable without env races).
pub fn tune_gemm_with_mode<W: Word>(
    tm: &TuneMode,
    family: Family,
    m: usize,
    n: usize,
    k: usize,
) -> KernelChoice {
    tune_gemm_keyed::<W>(tm, simd::level(), family, m, n, k)
}

/// Innermost tuning entry with an explicit registry level, so tests can
/// pin the key while other threads play with the global dispatch.
pub(crate) fn tune_gemm_keyed<W: Word>(
    tm: &TuneMode,
    level: u8,
    family: Family,
    m: usize,
    n: usize,
    k: usize,
) -> KernelChoice {
    let word_bits = W::BITS as u32;
    if *tm == TuneMode::Off {
        return default_for(family, word_bits, n, k);
    }
    let key = Key { level, family, word_bits, n, k };
    if let TuneMode::File(path) = tm {
        load_disk_cache_once(path);
    }
    if let Some(c) = registry().read().unwrap().get(&key) {
        return *c;
    }
    let cands = candidates(family, word_bits, n, k, m);
    let times = run_tuning::<W>(family, m, n, k, &cands);
    let best = times
        .iter()
        .enumerate()
        .min_by_key(|&(_, t)| *t)
        .map(|(i, _)| i)
        .unwrap_or(0);
    let choice = cands[best];
    registry().write().unwrap().insert(key, choice);
    records().lock().unwrap().push(TuneRecord {
        family,
        word_bits,
        m,
        n,
        k,
        choice,
        best_ns: times[best],
        default_ns: times[0],
    });
    if let TuneMode::File(path) = tm {
        append_disk_cache(path, &key, &choice);
    }
    choice
}

/// Candidate grid: micro shapes this family implements × {½, 1, 2} of
/// the default tile_rows × {½, 1, 2} of the default grain. The default
/// configuration is always candidate 0, and ties go to the earliest
/// candidate, so noise can never pick a config that measured no better
/// than the legacy one.
fn candidates(family: Family, word_bits: u32, n: usize, k: usize, m: usize) -> Vec<KernelChoice> {
    let base = default_for(family, word_bits, n, k);
    let micros: &[MicroKernel] = match family {
        Family::Binary => &[MicroKernel::Mk1x8, MicroKernel::Mk1x4, MicroKernel::Mk2x4],
        Family::Bitplane | Family::Float => &[MicroKernel::Mk1x4, MicroKernel::Mk1x8],
    };
    let mut out = vec![base];
    if m <= 1 {
        // GEMV: only the micro shape matters (no tiles, fixed grain)
        for &micro in micros {
            let c = KernelChoice { micro, ..base };
            if !out.contains(&c) {
                out.push(c);
            }
        }
        return out;
    }
    for &micro in micros {
        for tf in [1usize, 0, 2] {
            let tile_rows = match tf {
                0 => (base.tile_rows / 2).max(8),
                1 => base.tile_rows,
                _ => base.tile_rows * 2,
            };
            for gf in [1usize, 0, 2] {
                let grain = match gf {
                    0 => (base.grain / 2).max(1),
                    1 => base.grain,
                    _ => base.grain * 2,
                };
                let c = KernelChoice { micro, tile_rows, grain };
                if !out.contains(&c) {
                    out.push(c);
                }
            }
        }
    }
    out
}

/// Per-candidate measurement budget. ~250 µs × ≤27 candidates keeps one
/// distinct-dims tune in the single-digit milliseconds the tentpole
/// budgets ("a few milliseconds each").
const BUDGET_NS: u64 = 250_000;
const MAX_ITERS: u32 = 64;

/// Rows of synthetic A used for GEMM-path timing: enough to cover
/// several tiles and all pool workers, capped so one candidate stays
/// inside its budget even on wide layers.
fn bench_rows(m: usize) -> usize {
    m.clamp(64, 512)
}

fn time_each<F: FnMut(KernelChoice)>(cands: &[KernelChoice], mut run: F) -> Vec<u64> {
    cands
        .iter()
        .map(|&c| {
            run(c); // warm: page in operands, fill panel pools
            let t = Timer::start();
            let mut iters = 0u32;
            loop {
                run(c);
                iters += 1;
                let el = t.elapsed_ns();
                if el >= BUDGET_NS || iters >= MAX_ITERS {
                    return (el / iters as u64).max(1);
                }
            }
        })
        .collect()
}

/// Time every candidate on synthetic operands through the real parallel
/// kernel entry points. `m == 1` times the GEMV path; larger `m` times
/// the tile-streaming GEMM path (a memcpy producer stands in for the
/// unroller — constant across candidates, so it only adds a floor).
fn run_tuning<W: Word>(
    family: Family,
    m: usize,
    n: usize,
    k: usize,
    cands: &[KernelChoice],
) -> Vec<u64> {
    let mut rng = Rng::new(0xE59E_5501 ^ ((n as u64) << 24) ^ (k as u64));
    match family {
        Family::Binary => {
            let kw = k.max(1);
            let k_bits = kw * W::BITS;
            if m <= 1 {
                let x: Vec<W> = (0..kw).map(|_| W::from_u64(rng.next_u64())).collect();
                let b: Vec<W> = (0..n * kw).map(|_| W::from_u64(rng.next_u64())).collect();
                let mut out = vec![0i32; n];
                time_each(cands, |c| {
                    crate::bitpack::gemm::gemv_words_with_choice::<W>(
                        &x, &b, &mut out, n, kw, k_bits, c,
                    )
                })
            } else {
                let mt = bench_rows(m);
                let a: Vec<W> = (0..mt * kw).map(|_| W::from_u64(rng.next_u64())).collect();
                let b: Vec<W> = (0..n * kw).map(|_| W::from_u64(rng.next_u64())).collect();
                let mut out = vec![0i32; mt * n];
                let pool = BufferPool::<W>::new();
                time_each(cands, |c| {
                    crate::bitpack::gemm::gemm_tiles_with_choice::<W>(
                        &b,
                        &mut out,
                        mt,
                        n,
                        kw,
                        k_bits,
                        c,
                        &pool,
                        &|r0, r1, panel| panel.copy_from_slice(&a[r0 * kw..r1 * kw]),
                    )
                })
            }
        }
        Family::Bitplane => {
            let kc = k.max(1);
            if m <= 1 {
                let x: Vec<u8> = (0..kc).map(|_| rng.next_u32() as u8).collect();
                let kw = words_for::<W>(kc);
                let w: Vec<W> = (0..n * kw).map(|_| W::from_u64(rng.next_u64())).collect();
                let planes = BitPlanes::<W>::decompose(&x);
                let mut out = vec![0i32; n];
                time_each(cands, |c| {
                    crate::bitpack::bitplane::bitplane_gemv_with_choice::<W>(
                        &planes, &w, &mut out, n, c,
                    )
                })
            } else {
                let mt = bench_rows(m);
                let xs: Vec<u8> = (0..mt * kc).map(|_| rng.next_u32() as u8).collect();
                let kw = words_for::<W>(kc);
                let w: Vec<W> = (0..n * kw).map(|_| W::from_u64(rng.next_u64())).collect();
                let mut out = vec![0i32; mt * n];
                let pool = BufferPool::<u8>::new();
                time_each(cands, |c| {
                    crate::bitpack::bitplane::bitplane_gemm_tiles_with_choice::<W>(
                        &w,
                        &mut out,
                        mt,
                        n,
                        kc,
                        c,
                        &pool,
                        &|r0, r1, panel| panel.copy_from_slice(&xs[r0 * kc..r1 * kc]),
                    )
                })
            }
        }
        Family::Float => {
            let kc = k.max(1);
            if m <= 1 {
                let mut x = vec![0f32; kc];
                let mut b = vec![0f32; n * kc];
                rng.fill_uniform(&mut x, -1.0, 1.0);
                rng.fill_uniform(&mut b, -1.0, 1.0);
                let mut out = vec![0f32; n];
                time_each(cands, |c| {
                    crate::linalg::gemm::sgemv_with_choice(&x, &b, &mut out, n, kc, c)
                })
            } else {
                let mt = bench_rows(m);
                let mut a = vec![0f32; mt * kc];
                let mut b = vec![0f32; n * kc];
                rng.fill_uniform(&mut a, -1.0, 1.0);
                rng.fill_uniform(&mut b, -1.0, 1.0);
                let mut out = vec![0f32; mt * n];
                let pool = BufferPool::<f32>::new();
                time_each(cands, |c| {
                    crate::linalg::gemm::sgemm_tiles_with_choice(
                        &b,
                        &mut out,
                        mt,
                        n,
                        kc,
                        c,
                        &pool,
                        &|r0, r1, panel| panel.copy_from_slice(&a[r0 * kc..r1 * kc]),
                    )
                })
            }
        }
    }
}

// ---------------------------------------------------------------------
// on-disk cache (`ESPRESSO_TUNE=<path>`)
// ---------------------------------------------------------------------

const DISK_HEADER: &str =
    "# espresso tune cache v1: level family word_bits n k micro tile_rows grain";

fn level_by_name(s: &str) -> Option<u8> {
    [
        simd::LEVEL_SCALAR,
        simd::LEVEL_AVX2,
        simd::LEVEL_AVX512,
        simd::LEVEL_NEON,
    ]
    .into_iter()
    .find(|&l| simd::level_name(l) == s)
}

fn format_line(key: &Key, choice: &KernelChoice) -> String {
    format!(
        "{} {} {} {} {} {} {} {}",
        simd::level_name(key.level),
        key.family.name(),
        key.word_bits,
        key.n,
        key.k,
        choice.micro.name(),
        choice.tile_rows,
        choice.grain,
    )
}

fn parse_line(line: &str) -> Option<(Key, KernelChoice)> {
    let mut it = line.split_whitespace();
    let level = level_by_name(it.next()?)?;
    let family = Family::parse(it.next()?)?;
    let word_bits = it.next()?.parse().ok()?;
    let n = it.next()?.parse().ok()?;
    let k = it.next()?.parse().ok()?;
    let micro = MicroKernel::parse(it.next()?)?;
    let tile_rows = it.next()?.parse().ok()?;
    let grain = it.next()?.parse().ok()?;
    if it.next().is_some() {
        return None;
    }
    Some((
        Key { level, family, word_bits, n, k },
        KernelChoice { micro, tile_rows, grain },
    ))
}

/// Seed the registry from the on-disk cache, once per process. Unknown
/// or malformed lines are skipped (forward compatibility); entries for
/// other dispatch levels are harmless — their keys never match.
fn load_disk_cache_once(path: &std::path::Path) {
    static LOADED: OnceLock<()> = OnceLock::new();
    LOADED.get_or_init(|| {
        if let Ok(text) = std::fs::read_to_string(path) {
            let mut map = registry().write().unwrap();
            for line in text.lines() {
                let line = line.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                if let Some((key, choice)) = parse_line(line) {
                    map.entry(key).or_insert(choice);
                }
            }
        }
    });
}

/// Append one freshly tuned entry to the on-disk cache; IO failures are
/// ignored (the cache is an optimization, never a correctness input).
fn append_disk_cache(path: &std::path::Path, key: &Key, choice: &KernelChoice) {
    let new_file = std::fs::metadata(path).map(|m| m.len() == 0).unwrap_or(true);
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        if new_file {
            let _ = writeln!(f, "{DISK_HEADER}");
        }
        let _ = writeln!(f, "{}", format_line(key, choice));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_reproduce_legacy_constants() {
        // binary: 64 KiB / row_bytes tile, (1<<20)/(n·kw) grain, 1×8 first
        let c = default_for(Family::Binary, 64, 128, 2);
        assert_eq!(c.micro, MicroKernel::Mk1x8);
        assert_eq!(c.tile_rows, (65536usize / 16).clamp(16, 256));
        assert_eq!(c.grain, ((1usize << 20) / (128 * 2)).max(1));
        // bitplane: row_bytes = k, grain (1<<19)/(8·n·kw) with kw words/plane
        let c = default_for(Family::Bitplane, 64, 10, 129);
        assert_eq!(c.micro, MicroKernel::Mk1x4);
        assert_eq!(c.tile_rows, (65536usize / 129).clamp(16, 256));
        assert_eq!(c.grain, ((1usize << 19) / (8 * 10 * 3)).max(4));
        // float: row_bytes = 4k, grain (1<<18)/(n·k)
        let c = default_for(Family::Float, 32, 33, 65);
        assert_eq!(c.micro, MicroKernel::Mk1x4);
        assert_eq!(c.tile_rows, (65536usize / 260).clamp(16, 256));
        assert_eq!(c.grain, ((1usize << 18) / (33 * 65)).max(1));
    }

    #[test]
    fn off_mode_returns_defaults_without_tuning() {
        let c = tune_gemm_with_mode::<u64>(&TuneMode::Off, Family::Binary, 64, 1024, 16);
        assert_eq!(c, default_for(Family::Binary, 64, 1024, 16));
    }

    /// Same (level, dims) ⇒ same `KernelChoice`: the registry makes the
    /// second call a cache hit regardless of timing noise, and `lookup`
    /// must agree with what tuning recorded.
    #[test]
    fn tuning_is_deterministic_per_key_via_registry() {
        let tm = TuneMode::Auto;
        let a = tune_gemm_keyed::<u64>(&tm, simd::LEVEL_SCALAR, Family::Binary, 48, 40, 3);
        let b = tune_gemm_keyed::<u64>(&tm, simd::LEVEL_SCALAR, Family::Binary, 48, 40, 3);
        assert_eq!(a, b);
        let key = Key {
            level: simd::LEVEL_SCALAR,
            family: Family::Binary,
            word_bits: 64,
            n: 40,
            k: 3,
        };
        assert_eq!(registry().read().unwrap().get(&key), Some(&a));
    }

    #[test]
    fn gemv_dims_tune_micro_only() {
        let tm = TuneMode::Auto;
        let c = tune_gemm_keyed::<u64>(&tm, simd::LEVEL_SCALAR, Family::Binary, 1, 64, 4);
        let base = default_for(Family::Binary, 64, 64, 4);
        assert_eq!(c.tile_rows, base.tile_rows);
        assert_eq!(c.grain, base.grain);
    }

    #[test]
    fn candidate_zero_is_the_default() {
        for family in [Family::Binary, Family::Bitplane, Family::Float] {
            for m in [1usize, 256] {
                let cands = candidates(family, 64, 100, 8, m);
                assert_eq!(cands[0], default_for(family, 64, 100, 8));
                assert!(!cands.is_empty());
            }
        }
    }

    #[test]
    fn disk_cache_line_roundtrip() {
        let key = Key {
            level: simd::LEVEL_AVX2,
            family: Family::Bitplane,
            word_bits: 32,
            n: 300,
            k: 27,
        };
        let choice = KernelChoice { micro: MicroKernel::Mk2x4, tile_rows: 48, grain: 9 };
        let line = format_line(&key, &choice);
        assert_eq!(parse_line(&line), Some((key, choice)));
        assert_eq!(parse_line("# comment"), None);
        assert_eq!(parse_line("bogus line here"), None);
        assert_eq!(parse_line(""), None);
    }

    #[test]
    fn mode_strings_parse() {
        // mode() itself memoizes the env var; exercise the match arms
        // through the parser shape instead of mutating the environment.
        assert_eq!(MicroKernel::parse("1x8"), Some(MicroKernel::Mk1x8));
        assert_eq!(MicroKernel::parse("9x9"), None);
        assert_eq!(Family::parse("float"), Some(Family::Float));
        assert_eq!(Family::parse("quantum"), None);
        assert_eq!(level_by_name("avx512"), Some(simd::LEVEL_AVX512));
        assert_eq!(level_by_name("mmx"), None);
    }
}
