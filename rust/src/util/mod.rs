//! Infrastructure substrates built from scratch for the offline
//! environment: RNG, statistics, parallel helpers, a worker pool, a
//! benchmark harness, a CLI parser, and property-testing utilities.

pub mod bench;
pub mod cli;
pub mod crc32;
pub mod fault;
pub mod parallel;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tune;

/// Monotonic wall-clock timer helper.
pub struct Timer(std::time::Instant);

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

impl Timer {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }

    pub fn elapsed_ns(&self) -> u64 {
        self.0.elapsed().as_nanos() as u64
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Number of OS threads in this process, from `/proc/self/status`
/// (`Threads:` line). Returns `None` off Linux or if the field is
/// missing. Used by serving tests/benches to verify the event-driven
/// front end keeps the thread count bounded by cores + a constant
/// instead of scaling with connections.
pub fn os_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("Threads:") {
            return rest.trim().parse().ok();
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_ns() >= 1_000_000);
        assert!(t.elapsed_ms() >= 1.0);
    }

    #[test]
    fn os_thread_count_reports_at_least_one_on_linux() {
        if cfg!(target_os = "linux") {
            assert!(os_thread_count().unwrap() >= 1);
        }
    }
}
