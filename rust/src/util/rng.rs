//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we carry our own small,
//! well-understood generators: SplitMix64 for seeding and PCG32 /
//! xoshiro256** for the streams. All benches, tests and synthetic data
//! generators seed explicitly so every run is reproducible.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the main workhorse generator.
///
/// Fast, passes BigCrush, and trivially seedable from SplitMix64 as its
/// authors recommend.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (Lemire's method, no modulo bias to speak of
    /// for the bounds we use).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; this is not on any hot path).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Random sign in {-1.0, +1.0}.
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }

    /// Random boolean with probability `p` of `true`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a slice with ±1 values.
    pub fn fill_signs(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.sign();
        }
    }

    /// Fill a slice with uniform values in `[lo, hi)`.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.f32_range(lo, hi);
        }
    }

    /// Vector of ±1 values.
    pub fn signs(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.sign()).collect()
    }

    /// Vector of raw u64 words (handy for packed-bit tests).
    pub fn words(&mut self, n: usize) -> Vec<u64> {
        (0..n).map(|_| self.next_u64()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn signs_are_pm_one_and_balanced() {
        let mut r = Rng::new(9);
        let v = r.signs(100_000);
        assert!(v.iter().all(|&x| x == 1.0 || x == -1.0));
        let pos = v.iter().filter(|&&x| x == 1.0).count();
        assert!((45_000..55_000).contains(&pos), "pos={pos}");
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(13);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(15);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
