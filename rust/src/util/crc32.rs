//! CRC-32 (IEEE 802.3, the zlib/PNG polynomial), hand-rolled for the
//! offline environment — the `.esp` v4 integrity trailer needs a
//! checksum and the container has no crc crate to lean on.
//!
//! Table-driven, one byte per step: fast enough for weight files (a few
//! hundred MB/s), and the table is built in a `const fn` so there is no
//! runtime init to race.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Incremental CRC-32 state, for writers that stream sections.
#[derive(Clone, Copy)]
pub struct Crc32(u32);

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32(0xffff_ffff)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.0;
        for &b in bytes {
            c = TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
        }
        self.0 = c;
    }

    pub fn finish(self) -> u32 {
        self.0 ^ 0xffff_ffff
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // the classic check value for the IEEE polynomial
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xe8b7_be43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414f_a339);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut inc = Crc32::new();
        for chunk in data.chunks(7) {
            inc.update(chunk);
        }
        assert_eq!(inc.finish(), crc32(&data));
    }

    #[test]
    fn detects_single_bit_flips() {
        let mut data: Vec<u8> = (0..64u8).collect();
        let orig = crc32(&data);
        for i in 0..data.len() {
            for bit in 0..8 {
                data[i] ^= 1 << bit;
                assert_ne!(crc32(&data), orig, "flip at byte {i} bit {bit}");
                data[i] ^= 1 << bit;
            }
        }
    }
}
