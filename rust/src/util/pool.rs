//! Persistent worker thread pool for the coordinator.
//!
//! The compute kernels use scoped threads (`util::parallel`); the serving
//! layer needs long-lived workers consuming `'static` jobs from a queue.
//! No tokio offline, so this is a classic mpsc-fed pool with graceful
//! shutdown.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (at least 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("espresso-worker-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn worker")
            })
            .collect();
        Self { tx, workers }
    }

    /// Submit a job for execution on some worker.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        // Send can only fail after shutdown, which drops the pool first.
        let _ = self.tx.send(Msg::Run(Box::new(f)));
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job and return a handle that can be awaited for its result.
    pub fn submit<T, F>(&self, f: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = channel();
        self.execute(move || {
            let _ = tx.send(f());
        });
        JobHandle { rx }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Msg>>>) {
    loop {
        let msg = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => job(),
            Ok(Msg::Shutdown) | Err(_) => break,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Await-able result of a submitted job.
pub struct JobHandle<T> {
    rx: Receiver<T>,
}

impl<T> JobHandle<T> {
    /// Block until the job finishes and return its result.
    pub fn join(self) -> T {
        self.rx.recv().expect("job panicked or pool shut down")
    }

    /// Non-blocking poll.
    pub fn try_join(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // graceful shutdown waits for queued jobs
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_returns_result() {
        let pool = ThreadPool::new(2);
        let h = pool.submit(|| 21 * 2);
        assert_eq!(h.join(), 42);
    }

    #[test]
    fn many_submits_in_order_of_completion() {
        let pool = ThreadPool::new(3);
        let handles: Vec<_> = (0..50).map(|i| pool.submit(move || i * i)).collect();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join(), i * i);
        }
    }

    #[test]
    fn pool_size_is_clamped() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
    }
}
