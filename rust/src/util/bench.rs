//! Hand-rolled micro/macro benchmark harness (criterion is not available
//! in the offline build).
//!
//! Protocol per benchmark: warm up for `warmup` iterations (or until
//! `warmup_time`), then measure `iters` timed runs (or until
//! `measure_time`), and report mean / p50 / p95 plus derived throughput.
//! Results can be printed as an aligned table and dumped as TSV for
//! EXPERIMENTS.md.

use super::stats::{fmt_ns, Summary};
use std::time::{Duration, Instant};

/// Configuration for a benchmark run.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Minimum warmup iterations.
    pub warmup_iters: usize,
    /// Minimum measured iterations.
    pub min_iters: usize,
    /// Maximum measured iterations.
    pub max_iters: usize,
    /// Target wall-clock budget for measurement.
    pub measure_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_iters: 5,
            max_iters: 100,
            measure_time: Duration::from_secs(2),
        }
    }
}

impl BenchConfig {
    /// Config tuned for very fast (< 1 ms) operations.
    pub fn fast() -> Self {
        Self {
            warmup_iters: 20,
            min_iters: 50,
            max_iters: 10_000,
            measure_time: Duration::from_secs(1),
        }
    }

    /// Config tuned for slow (multi-second) operations.
    pub fn slow() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            measure_time: Duration::from_secs(10),
        }
    }

    /// Scale iteration counts/budget by environment override
    /// `ESPRESSO_BENCH_QUICK=1` (used by `cargo test` smoke runs and CI).
    pub fn from_env(self) -> Self {
        if std::env::var("ESPRESSO_BENCH_QUICK").as_deref() == Ok("1") {
            Self {
                warmup_iters: 1,
                min_iters: 2,
                max_iters: 3,
                measure_time: Duration::from_millis(200),
            }
        } else {
            self
        }
    }
}

/// One benchmark measurement result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    /// Optional work units per iteration (e.g. FLOPs, items) for
    /// throughput derivation.
    pub work_per_iter: Option<f64>,
    pub work_unit: &'static str,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.summary.mean
    }

    /// Work units per second, if work was declared.
    pub fn throughput(&self) -> Option<f64> {
        self.work_per_iter
            .map(|w| w / (self.summary.mean / 1e9))
    }

    pub fn report_line(&self) -> String {
        let mut s = format!(
            "{:<44} mean {:>12}  p50 {:>12}  p95 {:>12}  (n={})",
            self.name,
            fmt_ns(self.summary.mean),
            fmt_ns(self.summary.p50),
            fmt_ns(self.summary.p95),
            self.summary.n
        );
        if let Some(tp) = self.throughput() {
            s.push_str(&format!("  {:.3e} {}/s", tp, self.work_unit));
        }
        s
    }
}

/// Run a benchmark: `f` is one iteration. Returns timing summary.
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.max_iters);
    let start = Instant::now();
    while samples.len() < cfg.max_iters
        && (samples.len() < cfg.min_iters || start.elapsed() < cfg.measure_time)
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::from(&samples),
        work_per_iter: None,
        work_unit: "",
    }
}

/// Like `bench` but annotates the result with work units per iteration so
/// `report_line` can print throughput (e.g. GOP/s for GEMMs).
pub fn bench_throughput<F: FnMut()>(
    name: &str,
    cfg: &BenchConfig,
    work_per_iter: f64,
    work_unit: &'static str,
    f: F,
) -> BenchResult {
    let mut r = bench(name, cfg, f);
    r.work_per_iter = Some(work_per_iter);
    r.work_unit = work_unit;
    r
}

/// Collects results for one table and renders it.
#[derive(Default)]
pub struct BenchTable {
    pub title: String,
    pub rows: Vec<BenchResult>,
    /// Name of the row used as the speedup reference (1.0×).
    pub baseline: Option<String>,
}

impl BenchTable {
    pub fn new(title: &str) -> Self {
        Self {
            title: title.to_string(),
            ..Default::default()
        }
    }

    pub fn baseline(mut self, name: &str) -> Self {
        self.baseline = Some(name.to_string());
        self
    }

    pub fn push(&mut self, r: BenchResult) {
        println!("  {}", r.report_line());
        self.rows.push(r);
    }

    fn baseline_mean(&self) -> Option<f64> {
        let name = self.baseline.as_ref()?;
        self.rows
            .iter()
            .find(|r| &r.name == name)
            .map(|r| r.summary.mean)
    }

    /// Render the table, with a speedup column relative to the baseline row.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let base = self.baseline_mean();
        for r in &self.rows {
            let speedup = match base {
                Some(b) if r.summary.mean > 0.0 => format!("{:>8.2}x", b / r.summary.mean),
                _ => "       -".to_string(),
            };
            out.push_str(&format!(
                "{:<44} {:>12}  {}",
                r.name,
                fmt_ns(r.summary.mean),
                speedup
            ));
            if let Some(tp) = r.throughput() {
                out.push_str(&format!("  {:.3e} {}/s", tp, r.work_unit));
            }
            out.push('\n');
        }
        out
    }

    /// TSV dump (for appending to bench logs / EXPERIMENTS.md tooling).
    pub fn tsv(&self) -> String {
        let mut out = String::from("name\tmean_ns\tp50_ns\tp95_ns\tn\tspeedup_vs_baseline\n");
        let base = self.baseline_mean();
        for r in &self.rows {
            let speedup = base.map(|b| b / r.summary.mean).unwrap_or(f64::NAN);
            out.push_str(&format!(
                "{}\t{:.0}\t{:.0}\t{:.0}\t{}\t{:.3}\n",
                r.name, r.summary.mean, r.summary.p50, r.summary.p95, r.summary.n, speedup
            ));
        }
        out
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 5,
            measure_time: Duration::from_millis(50),
        };
        let r = bench("noop", &cfg, || {
            black_box(1 + 1);
        });
        assert!(r.summary.n >= 3);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn throughput_derivation() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            min_iters: 2,
            max_iters: 2,
            measure_time: Duration::from_millis(10),
        };
        let r = bench_throughput("sleepy", &cfg, 1000.0, "item", || {
            std::thread::sleep(Duration::from_millis(1));
        });
        let tp = r.throughput().unwrap();
        // ~1000 items / 1ms = ~1e6 items/s, allow slack
        assert!(tp > 1e5 && tp < 2e6, "tp={tp}");
    }

    #[test]
    fn table_speedup_column() {
        let mk = |name: &str, mean: f64| BenchResult {
            name: name.into(),
            summary: Summary {
                n: 1,
                mean,
                ..Default::default()
            },
            work_per_iter: None,
            work_unit: "",
        };
        let mut t = BenchTable::new("demo").baseline("slow");
        t.rows.push(mk("slow", 100.0));
        t.rows.push(mk("fast", 10.0));
        let rendered = t.render();
        assert!(rendered.contains("10.00x"), "{rendered}");
        let tsv = t.tsv();
        assert!(tsv.lines().count() == 3);
    }
}
