//! Test-only fault injection for the chaos suite.
//!
//! Production code calls [`should_fire`] at a handful of failure sites
//! (batch execution, weight load, weight save). The registry is empty
//! unless a test arms it with [`arm`] or the process was started with
//! `ESPRESSO_FAULT=site:after[:times],...` — e.g.
//! `ESPRESSO_FAULT=panic-batch:3` panics the 4th batch. The disabled
//! path is one relaxed atomic load, so the hooks cost nothing in a
//! normal serving process.
//!
//! Sites:
//! - `panic-batch`: the batcher panics instead of running the batch
//!   (exercises `catch_unwind` isolation and replica supervision)
//! - `slow-batch`: the batcher sleeps [`SLOW_BATCH`] before executing
//!   (exercises deadline shedding)
//! - `corrupt-load`: `ModelSpec::load` fails with an integrity error
//!   (exercises deploy-failure containment)
//! - `partial-write`: `ModelSpec::save` truncates the file it just
//!   wrote (exercises the v4 checksum trailer)
//!
//! The registry is process-global; tests that arm faults must serialize
//! on their own mutex so parallel test threads don't trip each other's
//! injections.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// How long a `slow-batch` injection stalls the batcher.
pub const SLOW_BATCH: Duration = Duration::from_millis(100);

struct Armed {
    site: String,
    /// Calls to skip before the fault starts firing.
    after: usize,
    /// Remaining times to fire once triggered (`usize::MAX` = forever).
    times: usize,
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static ARMED: Mutex<Vec<Armed>> = Mutex::new(Vec::new());
static ENV_PARSED: AtomicBool = AtomicBool::new(false);

/// Arm `site` to fire `times` times after skipping `after` calls.
pub fn arm(site: &str, after: usize, times: usize) {
    let mut armed = ARMED.lock().unwrap();
    armed.push(Armed {
        site: site.to_string(),
        after,
        times,
    });
    ACTIVE.store(true, Ordering::SeqCst);
}

/// Disarm every fault (tests call this on the way out).
pub fn disarm_all() {
    let mut armed = ARMED.lock().unwrap();
    armed.clear();
    ACTIVE.store(false, Ordering::SeqCst);
}

fn parse_env() {
    if ENV_PARSED.swap(true, Ordering::SeqCst) {
        return;
    }
    let Ok(spec) = std::env::var("ESPRESSO_FAULT") else {
        return;
    };
    for part in spec.split(',').filter(|p| !p.is_empty()) {
        let mut f = part.split(':');
        let site = f.next().unwrap_or_default();
        let after = f.next().and_then(|v| v.parse().ok()).unwrap_or(0);
        let times = f.next().and_then(|v| v.parse().ok()).unwrap_or(1);
        if !site.is_empty() {
            arm(site, after, times);
        }
    }
}

/// Should the fault at `site` fire on this call? Decrements the armed
/// counters; returns `false` forever once a fault runs dry.
pub fn should_fire(site: &str) -> bool {
    parse_env();
    if !ACTIVE.load(Ordering::Relaxed) {
        return false;
    }
    let mut armed = ARMED.lock().unwrap();
    for a in armed.iter_mut() {
        if a.site != site || a.times == 0 {
            continue;
        }
        if a.after > 0 {
            a.after -= 1;
            continue;
        }
        if a.times != usize::MAX {
            a.times -= 1;
        }
        return true;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    // the registry is process-global: this module's tests serialize on
    // one lock so they don't see each other's armings
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_by_default() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        disarm_all();
        assert!(!should_fire("panic-batch"));
    }

    #[test]
    fn fires_after_skips_then_runs_dry() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        disarm_all();
        arm("slow-batch", 2, 2);
        assert!(!should_fire("slow-batch"), "skip 1");
        assert!(!should_fire("slow-batch"), "skip 2");
        assert!(!should_fire("corrupt-load"), "other sites untouched");
        assert!(should_fire("slow-batch"), "fire 1");
        assert!(should_fire("slow-batch"), "fire 2");
        assert!(!should_fire("slow-batch"), "dry");
        disarm_all();
    }

    #[test]
    fn disarm_clears_everything() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        disarm_all();
        arm("corrupt-load", 0, usize::MAX);
        assert!(should_fire("corrupt-load"));
        disarm_all();
        assert!(!should_fire("corrupt-load"));
    }
}
