//! Minimal command-line argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Good enough for the `espresso` CLI and the examples.

use std::collections::HashMap;

/// Parsed arguments: flags, key/value options, and positionals, in the
/// order conventions of the `espresso` CLI.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — does NOT skip argv[0].
    /// `known_flags` disambiguates `--flag positional` from
    /// `--option value`.
    pub fn parse_from_with_flags<I: IntoIterator<Item = String>>(
        it: I,
        known_flags: &[&str],
    ) -> Self {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse without declared flags (bare `--name value` binds as option).
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Self {
        Self::parse_from_with_flags(it, &[])
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn parse_env(known_flags: &[&str]) -> Self {
        Self::parse_from_with_flags(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed option lookup with default; panics with a clear message on a
    /// malformed value (CLI surface, so a panic is the right UX).
    pub fn get_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(s) => s
                .parse::<T>()
                .unwrap_or_else(|_| panic!("invalid value for --{name}: {s:?}")),
        }
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse_from_with_flags(words.iter().map(|s| s.to_string()), &["verbose", "fast"])
    }

    #[test]
    fn parses_mixture() {
        let a = parse(&[
            "serve",
            "--model",
            "bmlp",
            "--port=7878",
            "--verbose",
            "extra",
        ]);
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("model"), Some("bmlp"));
        assert_eq!(a.get("port"), Some("7878"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&["--iters", "12"]);
        assert_eq!(a.get_parse_or::<usize>("iters", 5), 12);
        assert_eq!(a.get_parse_or::<usize>("missing", 5), 5);
        assert_eq!(a.get_or("name", "dflt"), "dflt");
    }

    #[test]
    #[should_panic(expected = "invalid value")]
    fn typed_malformed_panics() {
        let a = parse(&["--iters", "twelve"]);
        a.get_parse_or::<usize>("iters", 5);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--fast"]);
        assert!(a.flag("fast"));
        assert!(a.positional.is_empty());
    }
}
