//! Tiny property-based testing helper (proptest is not available offline).
//!
//! `check` runs a predicate over `cases` randomly generated inputs and, on
//! failure, greedily shrinks the failing case with the provided shrinker
//! before panicking with a reproducible seed. Generators compose as plain
//! closures over `Rng`.

use super::rng::Rng;

/// Run `prop` on `cases` inputs drawn from `gen`. On failure, tries the
/// `shrink` candidates (smaller inputs) to find a minimal counterexample.
pub fn check<T, G, S, P>(name: &str, cases: usize, seed: u64, gen: G, shrink: S, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if prop(&input) {
            continue;
        }
        // Greedy shrink loop.
        let mut minimal = input.clone();
        let mut progressed = true;
        while progressed {
            progressed = false;
            for cand in shrink(&minimal) {
                if !prop(&cand) {
                    minimal = cand;
                    progressed = true;
                    break;
                }
            }
        }
        panic!(
            "property {name:?} failed at case {case} (seed {seed})\n\
             original: {input:?}\nshrunk:   {minimal:?}"
        );
    }
}

/// Convenience: run `prop` over random cases, no shrinking.
pub fn check_simple<T, G, P>(name: &str, cases: usize, seed: u64, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    check(name, cases, seed, gen, |_| Vec::new(), prop)
}

/// Shrinker for a usize dimension: halves and decrements toward `min`.
pub fn shrink_usize(x: usize, min: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if x > min {
        out.push(min);
        if x / 2 > min {
            out.push(x / 2);
        }
        if x - 1 > min {
            out.push(x - 1);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check_simple(
            "additive-commutes",
            200,
            1,
            |r| (r.below(1000) as i64, r.below(1000) as i64),
            |&(a, b)| a + b == b + a,
        );
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            check(
                "all-below-500",
                500,
                2,
                |r| r.below(1000),
                |&x| shrink_usize(x, 0),
                |&x| x < 500,
            );
        });
        let err = result.expect_err("property should fail");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        // shrinker should walk failures down to the boundary 500
        assert!(msg.contains("shrunk:   500"), "msg: {msg}");
    }

    #[test]
    fn shrink_usize_candidates() {
        assert_eq!(shrink_usize(10, 0), vec![0, 5, 9]);
        assert!(shrink_usize(0, 0).is_empty());
        assert_eq!(shrink_usize(3, 2), vec![2]);
    }
}
